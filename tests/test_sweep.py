"""Sweep driver, per-phase seeding, objective-reuse validation, determinism.

Covers the toolchain's shared config path (`ToolchainConfig` + phase
functions), the `SeedSequence` per-phase child seeds, the stateful
placement-objective reuse guards, and the batched sweep driver's bitwise
parity with sequential `run_toolchain` calls.
"""
import numpy as np
import pytest

from repro.core import (
    PairwiseObjective,
    ToolchainConfig,
    evaluate_placement,
    make_objective,
    partition_phase,
    phase_seeds,
    run_toolchain,
    sneap_partition,
    validate_objective,
)
from repro.core.pipeline import apply_knobs, build_traffic
from repro.launch.sweep import config_grid, pareto_flags, run_sweep
from repro.snn.simulate import profile_snn
from repro.snn.topology import make_snn


@pytest.fixture(scope="module")
def profile():
    return profile_snn(make_snn("smooth_320"), num_steps=200, seed=0)


def _stats(summary: dict) -> dict:
    """Summary minus wall-clock fields (the bitwise-comparable part)."""
    return {k: v for k, v in summary.items() if not k.endswith("_s")}


FAST = {"iters": 800}


# ---------------------------------------------------------------- seeding
def test_phase_seeds_decorrelated():
    p, m, r = phase_seeds(7)
    assert len({p, m, r}) == 3          # phases draw independent streams
    assert (p, m, r) != (7, 7, 7)       # not the raw seed threaded through
    assert phase_seeds(7) == (p, m, r)  # deterministic
    assert phase_seeds(8) != (p, m, r)


def test_partition_uses_child_seed(profile):
    res = run_toolchain(profile, mesh_w=4, mesh_h=4, seed=3,
                        mapper_kwargs=dict(FAST))
    child = phase_seeds(3)[0]
    direct = sneap_partition(profile.graph, capacity=256, seed=child,
                             max_k=16, impl="scalar", objective="cut")
    assert np.array_equal(res.partition.part, direct.part)


# ----------------------------------------------------------- determinism
def test_identical_runs_bitwise_equal(profile):
    kw = dict(mesh_w=4, mesh_h=4, seed=1, mapper_kwargs=dict(FAST))
    s1 = run_toolchain(profile, **kw).summary()
    s2 = run_toolchain(profile, **kw).summary()
    assert _stats(s1) == _stats(s2)


def test_identical_runs_bitwise_equal_volume_tree(profile):
    kw = dict(mesh_w=4, mesh_h=4, seed=2, objective="volume",
              partition_impl="vec", mapper_kwargs=dict(FAST))
    s1 = run_toolchain(profile, **kw).summary()
    s2 = run_toolchain(profile, **kw).summary()
    assert _stats(s1) == _stats(s2)


# ------------------------------------------------------- objective reuse
def test_objective_reuse_across_two_runs(profile):
    """One caller-built objective driving two identical runs is safe."""
    cfg = ToolchainConfig(mesh_w=4, mesh_h=4).resolve(profile.graph.hyper)
    pres = partition_phase(profile, cfg)
    traffic = build_traffic(profile, pres, cfg)
    obj = make_objective("pairwise", traffic, 16, 4, mesh_h=4)
    kw = dict(mesh_w=4, mesh_h=4, seed=0,
              mapper_kwargs={"objective": obj, **FAST})
    s1 = run_toolchain(profile, **kw).summary()
    s2 = run_toolchain(profile, **kw).summary()  # reused, re-attached
    assert _stats(s1) == _stats(s2)


def test_objective_reuse_mesh_mismatch_raises(profile):
    cfg = ToolchainConfig(mesh_w=4, mesh_h=4).resolve(profile.graph.hyper)
    pres = partition_phase(profile, cfg)
    traffic = build_traffic(profile, pres, cfg)
    obj = make_objective("pairwise", traffic, 16, 4, mesh_h=4)
    with pytest.raises(ValueError, match="does not match"):
        run_toolchain(profile, mesh_w=5, mesh_h=5,
                      mapper_kwargs={"objective": obj, **FAST})


def test_objective_reuse_traffic_mismatch_raises(profile):
    cfg = ToolchainConfig(mesh_w=4, mesh_h=4).resolve(profile.graph.hyper)
    pres = partition_phase(profile, cfg)
    traffic = build_traffic(profile, pres, cfg)
    stale = make_objective("pairwise", traffic * 2, 16, 4, mesh_h=4)
    with pytest.raises(ValueError, match="traffic matrix content"):
        run_toolchain(profile, mesh_w=4, mesh_h=4,
                      mapper_kwargs={"objective": stale, **FAST})


def test_validate_objective_tree_part_mismatch(profile):
    cfg = ToolchainConfig(mesh_w=4, mesh_h=4, objective="volume",
                          partition_impl="vec").resolve(profile.graph.hyper)
    pres = partition_phase(profile, cfg)
    traffic = build_traffic(profile, pres, cfg)
    obj = make_objective("tree", traffic, 16, 4, mesh_h=4,
                         hyper=profile.graph.hyper, part=pres.part)
    assert validate_objective(obj, traffic, 16, mesh_w=4, mesh_h=4,
                              part=pres.part, hyper=profile.graph.hyper)
    other = (pres.part + 1) % pres.k
    with pytest.raises(ValueError, match="partition vector content"):
        validate_objective(obj, traffic, 16, mesh_w=4, mesh_h=4,
                           part=other, hyper=profile.graph.hyper)


def test_evaluate_placement_ignores_stale_reuse():
    rng = np.random.default_rng(0)
    traffic = rng.integers(0, 40, (6, 6)).astype(np.float64)
    placement = np.arange(6, dtype=np.int64)
    fresh = evaluate_placement(placement, traffic, 9, 3, 100)
    good = PairwiseObjective(traffic, 9, 3)
    assert evaluate_placement(placement, traffic, 9, 3, 100,
                              reuse=good) == fresh
    # An objective built for *different* traffic must not leak into the
    # report: evaluate_placement falls back to a fresh build.
    stale = PairwiseObjective(traffic * 3, 9, 3)
    assert evaluate_placement(placement, traffic, 9, 3, 100,
                              reuse=stale) == fresh


# ------------------------------------------------------------------ knobs
def test_apply_knobs_restores_and_rejects_unknown():
    from repro.core import refine_vec

    before = refine_vec._KERNEL_MAX_N
    with apply_knobs({"_KERNEL_MAX_N": 7}):
        assert refine_vec._KERNEL_MAX_N == 7
    assert refine_vec._KERNEL_MAX_N == before
    with pytest.raises(RuntimeError):
        with apply_knobs({"_KERNEL_MAX_N": 7}):
            raise RuntimeError("boom")
    assert refine_vec._KERNEL_MAX_N == before
    with pytest.raises(ValueError, match="unknown refine_vec knob"):
        with apply_knobs({"_NOT_A_KNOB": 1}):
            pass


def test_knobs_change_engine_path_not_results(profile):
    kw = dict(mesh_w=4, mesh_h=4, seed=0, partition_impl="vec",
              mapper_kwargs=dict(FAST))
    base = run_toolchain(profile, **kw).summary()
    cfg = ToolchainConfig(**kw, knobs={"_KERNEL_MAX_N": 0})  # force numpy path
    knobbed = run_toolchain(profile, config=cfg).summary()
    assert _stats(base) == _stats(knobbed)


# ------------------------------------------------------------------- grid
def test_config_grid_axes():
    grid = config_grid(mesh=[(4, 4), (8, 8)], seed=[0, 1], mapper="sa",
                       score_backend=["numpy"], stepper=["jax"])
    assert len(grid) == 4
    assert {(c.mesh_w, c.mesh_h) for c in grid} == {(4, 4), (8, 8)}
    assert all(c.mapper_kwargs == {"score_backend": "numpy"} for c in grid)
    assert all(c.noc_kwargs == {"stepper": "jax"} for c in grid)
    with pytest.raises(ValueError, match="unknown sweep axis"):
        config_grid(mesh_width=[4])


def test_pareto_flags():
    rows = [
        {"energy_pj": 1.0, "avg_latency": 5.0, "total_s": 1.0},  # front
        {"energy_pj": 2.0, "avg_latency": 1.0, "total_s": 2.0},  # front
        {"energy_pj": 2.0, "avg_latency": 5.0, "total_s": 1.5},  # dominated
    ]
    assert pareto_flags(rows) == [True, True, False]


# ------------------------------------------------------------------ sweep
@pytest.fixture(scope="module")
def small_grid():
    return (
        config_grid(mesh=[(4, 4)], seed=[0, 1], mapper="sa",
                    objective=["cut", "volume"], mapper_kwargs=[dict(FAST)])
        + config_grid(mesh=[(4, 4)], seed=[0, 1], mapper="sa_jax",
                      mapper_kwargs=[{"iters": 800, "chains": 4}],
                      stepper=["jax"])
    )


def test_sweep_rows_match_sequential_bitwise(profile, small_grid):
    res = run_sweep(profile, small_grid)
    assert len(res.rows) == len(small_grid)
    for cfg, row in zip(small_grid, res.rows):
        s = run_toolchain(profile, config=cfg).summary()
        for k, v in _stats(s).items():
            assert row[k] == v, (k, cfg.mapper, cfg.seed, cfg.objective)


def test_sweep_deterministic(profile, small_grid):
    r1 = run_sweep(profile, small_grid)
    r2 = run_sweep(profile, small_grid)
    # pareto depends on total_s (a Pareto key), so it varies with timing
    drop = ("partition_s", "mapping_s", "evaluate_s", "total_s", "pareto")
    for a, b in zip(r1.rows, r2.rows):
        assert {k: v for k, v in a.items() if k not in drop} == \
               {k: v for k, v in b.items() if k not in drop}


def test_sweep_pareto_and_dedup(profile, small_grid):
    shared = {c.resolve(profile.graph.hyper).partition_key()
              for c in small_grid}
    # the sa_jax configs share partitions with the cut sa configs
    assert len(shared) < len(small_grid)
    res = run_sweep(profile, small_grid)
    front = res.front()
    assert 1 <= len(front) <= len(res.rows)
    assert all(r["pareto"] for r in front)


def test_sa_search_jax_batch_matches_single():
    from repro.core.mapping_jax import sa_search_jax, sa_search_jax_batch

    rng = np.random.default_rng(1)
    traffics = [rng.integers(0, 50, (k, k)).astype(np.float64)
                for k in (12, 14)]
    tls = [int(t.sum()) for t in traffics]
    seeds = [5, 9]
    singles = [sa_search_jax(t, 16, 4, tl, seed=s, iters=1000, chains=4)
               for t, tl, s in zip(traffics, tls, seeds)]
    batch = sa_search_jax_batch(traffics, 16, 4, tls, seeds,
                                iters=1000, chains=4)
    for s, b in zip(singles, batch):
        assert np.array_equal(s.placement, b.placement)
        assert s.avg_hop == b.avg_hop

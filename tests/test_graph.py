import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip when absent
from hypothesis import given, settings, strategies as st

from repro.core.graph import build_graph, edge_cut, partition_weights, validate_partition

from conftest import random_graph


def test_build_graph_merges_duplicates_and_drops_self_loops():
    g = build_graph(4, src=[0, 0, 1, 2, 2], dst=[1, 1, 0, 2, 3], weight=[3, 4, 5, 9, 1])
    # (0,1) appears 3 times (0->1 x2, 1->0) => merged weight 12; (2,2) dropped
    assert g.num_edges == 2
    nbrs, w = g.neighbors(0)
    assert nbrs.tolist() == [1] and w.tolist() == [12]
    assert g.total_adjwgt == 13


def test_symmetry():
    g = random_graph(50, 0.2, seed=1)
    for v in range(50):
        nbrs, w = g.neighbors(v)
        for u, wt in zip(nbrs, w):
            back_n, back_w = g.neighbors(int(u))
            i = list(back_n).index(v)
            assert back_w[i] == wt


def test_edge_cut_matches_bruteforce():
    g = random_graph(40, 0.3, seed=2)
    part = np.random.default_rng(3).integers(0, 4, 40)
    brute = 0
    for v in range(40):
        nbrs, w = g.neighbors(v)
        for u, wt in zip(nbrs, w):
            if part[v] != part[u]:
                brute += int(wt)
    assert edge_cut(g, part) == brute // 2


@given(n=st.integers(5, 60), p=st.floats(0.05, 0.5), k=st.integers(2, 5),
       seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_partition_weights_conserve_total(n, p, k, seed):
    g = random_graph(n, p, seed=seed)
    part = np.random.default_rng(seed).integers(0, k, n)
    w = partition_weights(g, part, k)
    assert w.sum() == g.total_vwgt


def test_validate_partition_raises():
    g = random_graph(20, 0.3, seed=4)
    part = np.zeros(20, dtype=np.int64)
    with pytest.raises(ValueError):
        validate_partition(g, part, k=2, capacity=10)  # all 20 in partition 0

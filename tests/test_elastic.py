import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.runtime import HeartbeatMonitor, remesh_params
from repro.runtime.elastic import remesh_params as _rm


def test_remesh_preserves_values():
    mesh_a = make_local_mesh()
    mesh_b = make_local_mesh()  # "new" mesh after failure (same devices on CPU)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    specs = {"w": P(None, None)}
    placed = remesh_params(tree, mesh_a, specs)
    moved = remesh_params(placed, mesh_b, specs)
    np.testing.assert_array_equal(np.asarray(moved["w"]), np.asarray(tree["w"]))


def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(num_hosts=4, window=8, threshold=1.5)
    for step in range(8):
        for h in range(4):
            mon.report(h, step, 1.0 if h != 2 else 3.0)
    assert mon.stragglers() == [2]


def test_rebalance_plan_conserves_shards():
    mon = HeartbeatMonitor(num_hosts=3, window=4)
    for step in range(4):
        mon.report(0, step, 1.0)
        mon.report(1, step, 1.0)
        mon.report(2, step, 5.0)
    before = {0: 4, 1: 4, 2: 4}
    after = mon.rebalance_plan(before)
    assert sum(after.values()) == 12
    assert after[2] < 4  # straggler sheds work

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip when absent
from hypothesis import given, settings, strategies as st

from repro.core.hopcost import (average_hop, core_coords, hop_distance_matrix,
                                swap_delta, traffic_matrix)


def test_traffic_matrix_counts():
    part = np.array([0, 0, 1, 2])
    src = np.array([0, 1, 2, 3, 0])
    dst = np.array([2, 3, 0, 0, 1])
    c = traffic_matrix(part, src, dst, 3)
    assert c[0, 1] == 1 and c[0, 2] == 1 and c[1, 0] == 1 and c[2, 0] == 1
    assert c[0, 0] == 1  # intra-partition spike 0->1
    assert c.sum() == 5


def test_hop_distance_vs_manual():
    d = hop_distance_matrix(25, 5)
    # core 0 = (0,0), core 24 = (4,4)
    assert d[0, 24] == 8
    assert d[0, 0] == 0
    assert d[7, 9] == 2  # (2,1)->(4,1)
    # torus wraps
    dt = hop_distance_matrix(25, 5, torus=True)
    assert dt[0, 4] == 1  # (0,0)->(4,0) wraps


def test_average_hop_algorithm1_matches_bruteforce():
    """Paper Algorithm 1 == per-spike brute force over a random instance."""
    rng = np.random.default_rng(0)
    n_neurons, k, cores, w = 50, 6, 25, 5
    part = rng.integers(0, k, n_neurons)
    placement = rng.permutation(cores)[:k]
    src = rng.integers(0, n_neurons, 500)
    dst = rng.integers(0, n_neurons, 500)
    dist = hop_distance_matrix(cores, w)
    c = traffic_matrix(part, src, dst, k)
    h = average_hop(c, placement, dist, 500)
    brute = np.mean([dist[placement[part[s]], placement[part[d]]]
                     for s, d in zip(src, dst)])
    np.testing.assert_allclose(h, brute, rtol=1e-12)


@given(k=st.integers(3, 20), seed=st.integers(0, 5000))
@settings(max_examples=30, deadline=None)
def test_swap_delta_matches_recompute(k, seed):
    rng = np.random.default_rng(seed)
    cores, w = 25, 5
    c = rng.integers(0, 50, (k, k)).astype(np.float64)
    padded = np.zeros((cores, cores))
    padded[:k, :k] = c
    sym = padded + padded.T
    placement = rng.permutation(cores)
    dist = hop_distance_matrix(cores, w).astype(np.float64)
    a, b = rng.choice(cores, 2, replace=False)

    def total(pl):
        return (dist[pl[:, None], pl[None, :]] * sym).sum() / 2

    before = total(placement)
    delta = swap_delta(sym, placement, dist, int(a), int(b))
    placement[a], placement[b] = placement[b], placement[a]
    after = total(placement)
    np.testing.assert_allclose(delta, after - before, rtol=1e-9, atol=1e-9)

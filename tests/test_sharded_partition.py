"""Sharded partitioning engine: plans, halos, parity, and scale guards.

Metamorphic contracts (ISSUE 10):

* the shard plan is a partition of [0, n) into contiguous blocks;
* halo exchange is exact — ``comm_volume_sharded`` equals the global
  ``comm_volume`` for every shard count, and sharded refinement is
  bitwise-identical to single-host (scheduling changes, semantics don't);
* sharded matching is invariant under the shard count (hash tie keys);
* fat conflict rounds keep batch gains exactly additive (the incremental
  score equals a from-scratch recount after refinement);
* the index-capacity audit raises loudly at >2^31 scale — shape math
  only, nothing near that size is allocated.
"""
import os

import numpy as np
import pytest

from repro.core.coarsen import LevelStore, coarsen, heavy_edge_matching_vec
from repro.core.graph import (
    IndexCapacityError,
    ShardedGraphView,
    build_graph,
    check_index_capacity,
    comm_volume,
    comm_volume_sharded,
    edge_partition_counts,
)
from repro.core.partition import sneap_partition
from repro.core.refine import VolumeState
from repro.core.refine_vec import refine_level_vec
from repro.sharding.planner import plan_vertex_shards

from conftest import fanout_snn_graph, random_hypergraph


def feasible_part(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Balanced random partition (unit weights, so any equal split fits)."""
    r = np.random.default_rng(seed)
    part = np.arange(n) % k
    return r.permutation(part).astype(np.int64)


# ---------------------------------------------------------------- plans


def test_plan_vertex_shards_partitions_the_range():
    plan = plan_vertex_shards(103, 4)
    assert plan.num_shards == 4
    assert plan.bounds[0] == 0 and plan.bounds[-1] == 103
    blocks = [plan.block(s) for s in range(4)]
    assert all(lo < hi for lo, hi in blocks)
    assert [lo for lo, _ in blocks[1:]] == [hi for _, hi in blocks[:-1]]
    v = np.arange(103)
    owner = plan.owner(v)
    for s, (lo, hi) in enumerate(blocks):
        assert (owner[lo:hi] == s).all()


def test_plan_vertex_shards_split_routes_sorted_rows():
    plan = plan_vertex_shards(100, 3)
    rows = np.array([0, 5, 33, 34, 66, 99])
    parts = plan.split(rows)
    assert len(parts) == 3
    got = np.concatenate(parts)
    assert np.array_equal(got, rows)
    for s, chunk in enumerate(parts):
        lo, hi = plan.block(s)
        assert ((chunk >= lo) & (chunk < hi)).all()


# ---------------------------------------------------------------- halos


def test_halo_cut_is_exactly_external_neighbors():
    g = fanout_snn_graph(200, fan=5, seed=1)
    plan = plan_vertex_shards(200, 3)
    view = ShardedGraphView(g, plan)
    for s in range(3):
        lo, hi = plan.block(s)
        halo = view.halo(s, mode="cut")
        nbrs = g.adjncy[g.xadj[lo]:g.xadj[hi]].astype(np.int64)
        expect = np.unique(nbrs[(nbrs < lo) | (nbrs >= hi)])
        assert np.array_equal(np.sort(halo), expect)


def test_local_part_poisons_outside_halo():
    g = fanout_snn_graph(120, fan=4, seed=2)
    plan = plan_vertex_shards(120, 4)
    view = ShardedGraphView(g, plan)
    part = feasible_part(120, 6)
    lp = view.local_part(1, part, mode="cut")
    lo, hi = plan.block(1)
    assert np.array_equal(lp[lo:hi], part[lo:hi])
    halo = view.halo(1, mode="cut")
    assert np.array_equal(lp[halo], part[halo])
    covered = np.zeros(120, dtype=bool)
    covered[lo:hi] = True
    covered[halo] = True
    assert (lp[~covered] == -1).all()


@pytest.mark.parametrize("num_shards", [1, 2, 3, 5])
def test_comm_volume_sharded_matches_global(num_shards):
    g = random_hypergraph(150, 900, seed=3)
    part = feasible_part(150, 7, seed=4)
    plan = plan_vertex_shards(150, num_shards)
    assert comm_volume_sharded(g.hyper, part, plan) == comm_volume(g.hyper, part)


# ----------------------------------------------------- sharded refinement


@pytest.mark.parametrize("objective", ["cut", "volume"])
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_refine_bitwise_parity(objective, shards):
    """Sharding only reschedules evaluation: identical movers, identical
    score, identical partition — for any shard count."""
    g = fanout_snn_graph(600, fan=6, seed=5)
    part = feasible_part(600, 10, seed=6)
    base_part, base_score = refine_level_vec(
        g, part, k=10, capacity=80, objective=objective)
    got_part, got_score = refine_level_vec(
        g, part, k=10, capacity=80, objective=objective, shards=shards)
    assert got_score == base_score
    assert np.array_equal(got_part, base_part)


def test_fat_round_gains_exactly_additive():
    """The incremental score (sum of batch gains) must equal a from-scratch
    recount — any non-additive admission inside a fat conflict round would
    diverge here."""
    g = fanout_snn_graph(800, fan=8, seed=7)
    part = feasible_part(800, 12, seed=8)
    new_part, score = refine_level_vec(g, part, k=12, capacity=100,
                                       objective="volume")
    assert score == comm_volume(g.hyper, new_part)
    assert score <= comm_volume(g.hyper, part)


def test_apply_moves_merges_shared_slots():
    """Two movers sharing a hyperedge and a destination column touch the
    same (edge, column) slot; the batched phi update must merge the +-1s
    instead of letting one overwrite the other."""
    g = fanout_snn_graph(60, fan=6, seed=9)
    part = feasible_part(60, 4, seed=10)
    st = VolumeState(g, part, 4)
    movers = np.arange(10, dtype=np.int64)
    prev = part[movers].copy()
    dest = (prev + 1) % 4
    st.apply_moves(movers, prev, dest)
    part2 = part.copy()
    part2[movers] = dest
    assert np.array_equal(st.phi, edge_partition_counts(g.hyper, part2, 4))


# ------------------------------------------------------- sharded matching


def test_sharded_matching_shard_count_invariant():
    g = fanout_snn_graph(500, fan=5, seed=11)
    ms = [heavy_edge_matching_vec(g, np.random.default_rng(12), max_vwgt=20,
                                  shards=s)
          for s in (1, 2, 3, 8)]
    for m in ms[1:]:
        assert np.array_equal(ms[0], m)
    m = ms[0]
    v = np.arange(500)
    assert np.array_equal(m[m], v)  # involution: partner's partner is me
    paired = m != v
    assert (g.vwgt[v[paired]] + g.vwgt[m[paired]] <= 20).all()


def test_sharded_coarsen_levels_match_any_shard_count():
    g = fanout_snn_graph(700, fan=5, seed=13)
    l2 = coarsen(g, np.random.default_rng(1), coarsen_to=100, max_vwgt=20,
                 impl="vec", shards=2)
    l5 = coarsen(g, np.random.default_rng(1), coarsen_to=100, max_vwgt=20,
                 impl="vec", shards=5)
    assert len(l2) == len(l5)
    for a, b in zip(l2, l5):
        assert np.array_equal(a.xadj, b.xadj)
        assert np.array_equal(a.adjncy, b.adjncy)
        assert np.array_equal(a.vwgt, b.vwgt)


# ------------------------------------------------------------ out-of-core


def test_levelstore_roundtrip_and_cleanup():
    g = fanout_snn_graph(400, fan=5, seed=14)
    mem = coarsen(g, np.random.default_rng(2), coarsen_to=60, max_vwgt=20,
                  impl="vec", shards=2)
    store = LevelStore()
    spill = coarsen(g, np.random.default_rng(2), coarsen_to=60, max_vwgt=20,
                    impl="vec", shards=2, store=store)
    assert spill is store
    assert len(store) == len(mem)
    for i in range(len(mem)):
        a, b = mem[i], store[i]
        assert np.array_equal(a.xadj, b.xadj)
        assert np.array_equal(a.adjncy, b.adjncy)
        assert np.array_equal(a.adjwgt, b.adjwgt)
        assert np.array_equal(a.vwgt, b.vwgt)
        assert (a.cmap is None) == (b.cmap is None)
        if a.cmap is not None:
            assert np.array_equal(a.cmap, b.cmap)
        assert (a.hyper is None) == (b.hyper is None)
        if a.hyper is not None:
            assert np.array_equal(a.hyper.hpins, b.hyper.hpins)
            assert np.array_equal(a.hyper.hfire, b.hyper.hfire)
            assert comm_volume(a.hyper, feasible_part(a.num_vertices, 4)) == \
                comm_volume(b.hyper, feasible_part(b.num_vertices, 4))
    assert len(store._cache) <= LevelStore._CACHE_SLOTS
    path = store._dir
    store.close()
    assert not os.path.exists(path)


def test_stream_levels_matches_in_memory():
    g = fanout_snn_graph(1500, fan=6, seed=15)
    kw = dict(capacity=64, seed=0, impl="vec", objective="volume",
              hyper=g.hyper, shards=2)
    in_mem = sneap_partition(g, **kw)
    streamed = sneap_partition(g, stream_levels=True, **kw)
    assert np.array_equal(in_mem.part, streamed.part)
    assert in_mem.comm_volume == streamed.comm_volume
    assert in_mem.num_levels == streamed.num_levels


# ------------------------------------------------------------- end to end


def test_end_to_end_sharded_quality_within_5pct():
    """Sharded coarsening draws different (hash) tie keys than the
    single-host rng stream, so the partitions differ — quality must not:
    the ISSUE's acceptance bound is 5% comm_volume drift."""
    g = fanout_snn_graph(4000, fan=8, seed=16)
    kw = dict(capacity=64, seed=0, impl="vec", objective="volume",
              hyper=g.hyper)
    single = sneap_partition(g, **kw)
    two = sneap_partition(g, shards=2, **kw)
    four = sneap_partition(g, shards=4, **kw)
    assert np.array_equal(two.part, four.part)  # shard-count invariance
    drift = abs(two.comm_volume - single.comm_volume) / single.comm_volume
    assert drift <= 0.05, f"sharded comm_volume drifted {drift:.1%}"


# ----------------------------------------------------- index-dtype audit


def test_index_capacity_vertex_overflow_raises():
    with pytest.raises(IndexCapacityError, match="int32"):
        check_index_capacity(2**31 + 10)


def test_index_capacity_packed_key_overflow_raises():
    # n fits int32 but n*k packed keys overflow int64: shape math only.
    with pytest.raises(IndexCapacityError):
        check_index_capacity(2**31 - 10, k=2**33)
    with pytest.raises(IndexCapacityError):
        check_index_capacity(1000, num_hyperedges=2**31 - 10, k=2**33)


def test_index_capacity_build_graph_guard_fires_before_allocating():
    # >2^31 vertices must fail fast at the boundary — if this ever
    # allocated, the test machine would notice.
    with pytest.raises(IndexCapacityError):
        build_graph(2**31 + 5, np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty(0, np.int64))


def test_index_capacity_ok_at_realistic_scale():
    check_index_capacity(10**6, num_hyperedges=10**6, k=4096)

"""Multicast NoC model: deduplicated packet traffic, XY-tree branch
accounting, conservation, and the cut-vs-volume end-to-end comparison."""
import numpy as np
import pytest

from repro.core.hopcost import traffic_matrix
from repro.nocsim import simulate_noc
from repro.nocsim.xy import link_ids_for_routes, multicast_tree_links, route_hops

from conftest import random_spike_trace as _trace


# -------------------------------------------------------- traffic matrix

def test_multicast_traffic_counts_distinct_packets():
    t, src, dst, part, _ = _trace()
    k = 6
    uni = traffic_matrix(part, src, dst, k)
    multi = traffic_matrix(part, src, dst, k, trace_t=t, cast="multicast")
    assert (multi <= uni).all()
    # Independent recount: one packet per distinct (t, src, dest partition)
    # for remote deliveries; local (diagonal) deliveries stay per-synapse.
    remote = {(int(ti), int(si), int(part[di]))
              for ti, si, di in zip(t, src, dst) if part[si] != part[di]}
    n_local = sum(1 for si, di in zip(src, dst) if part[si] == part[di])
    assert int(multi.sum()) == len(remote) + n_local
    assert int(np.diag(multi).sum()) == n_local == int(np.diag(uni).sum())


def test_multicast_traffic_requires_trace_t():
    t, src, dst, part, _ = _trace()
    with pytest.raises(ValueError):
        traffic_matrix(part, src, dst, 6, cast="multicast")


def test_unicast_traffic_unchanged_by_trace_t():
    t, src, dst, part, _ = _trace(seed=1)
    np.testing.assert_array_equal(
        traffic_matrix(part, src, dst, 6),
        traffic_matrix(part, src, dst, 6, trace_t=t, cast="unicast"),
    )


# ------------------------------------------------------------ tree links

def test_tree_links_dedup_shared_prefix():
    # Two packets of one firing from core 0 to 2 and to 5 on a 3x3 mesh:
    # XY routes 0->1->2 and 0->1->2->5 share links (0,1) and (1,2).
    src = np.array([0, 0])
    dst = np.array([2, 5])
    group = np.array([7, 7])
    ids, grp = multicast_tree_links(src, dst, group, 3, 3)
    assert (grp == 7).all()
    assert ids.shape[0] == 3  # tree: 0->1, 1->2, 2->5
    flat, _ = link_ids_for_routes(src, dst, 3, 3)
    assert flat.shape[0] == 5  # unicast would traverse 2 + 3


def test_tree_links_equal_unicast_for_distinct_groups():
    rng = np.random.default_rng(2)
    src = rng.integers(0, 9, 50)
    dst = rng.integers(0, 9, 50)
    group = np.arange(50)  # every packet its own firing: no sharing
    ids, _ = multicast_tree_links(src, dst, group, 3, 3)
    assert ids.shape[0] == int(route_hops(src, dst, 3).sum())


# ------------------------------------------------------------ simulation

def test_multicast_conservation_analytic():
    t, src, dst, part, placement = _trace(seed=3)
    s = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic",
                     cast="multicast")
    core = placement[part]
    pairs = {(int(ti), int(si), int(core[di]))
             for ti, si, di in zip(t, src, dst) if core[si] != core[di]}
    assert s.num_noc_spikes == len(pairs)  # packets == distinct fired pairs
    assert s.cast == "multicast"
    assert s.link_traversals <= s.total_hops


def test_multicast_queued_matches_analytic_static_quantities():
    t, src, dst, part, placement = _trace(seed=4)
    a = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic",
                     cast="multicast")
    q = simulate_noc(t, src, dst, part, placement, 3, 3, mode="queued",
                     link_capacity=10_000, cast="multicast")
    assert a.num_noc_spikes == q.num_noc_spikes
    assert a.total_hops == q.total_hops
    assert a.link_traversals == q.link_traversals
    np.testing.assert_allclose(a.edge_variance, q.edge_variance)
    np.testing.assert_allclose(a.dynamic_energy_pj, q.dynamic_energy_pj)
    assert q.congestion_count == 0
    np.testing.assert_allclose(q.avg_latency, q.avg_hop)


def test_multicast_never_costs_more_energy_than_unicast():
    t, src, dst, part, placement = _trace(seed=5, n_spikes=1000)
    uni = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic")
    multi = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic",
                         cast="multicast")
    assert multi.dynamic_energy_pj <= uni.dynamic_energy_pj
    assert multi.num_noc_spikes <= uni.num_noc_spikes
    assert multi.link_traversals <= uni.link_traversals


def test_multicast_keeps_every_local_delivery():
    """Core-local deliveries are synaptic events, not packets: the dedup
    must not collapse them, or local energy is undercounted vs unicast."""
    t, src, dst, part, placement = _trace(seed=7, n_spikes=800)
    uni = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic")
    multi = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic",
                         cast="multicast")
    assert multi.num_local_spikes == uni.num_local_spikes


def test_unicast_link_traversals_equal_hops():
    t, src, dst, part, placement = _trace(seed=6)
    s = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic")
    assert s.link_traversals == s.total_hops
    assert s.cast == "unicast"


# ----------------------------------------------------------- end to end

def test_toolchain_volume_objective_end_to_end():
    from repro.core import comm_volume, run_toolchain
    from repro.snn import make_snn, profile_snn

    prof = profile_snn(make_snn("smooth_320"), num_steps=250, seed=0)
    cut = run_toolchain(prof, objective="cut", mapper_kwargs={"iters": 1500})
    vol = run_toolchain(prof, objective="volume", mapper_kwargs={"iters": 1500})
    cut_mc = run_toolchain(prof, objective="cut", cast="multicast",
                           mapper_kwargs={"iters": 1500})
    # The volume-optimized partition wins its own metric...
    assert vol.partition.comm_volume <= cut.partition.comm_volume
    # ...and under the same multicast replay, does not cost more energy.
    assert vol.noc.dynamic_energy_pj <= cut_mc.noc.dynamic_energy_pj * 1.05
    # summary() reports both metrics for every run.
    for res in (cut, vol, cut_mc):
        s = res.summary()
        assert s["comm_volume"] == comm_volume(prof.hyper, res.partition.part)
        assert s["edge_cut"] == res.partition.edge_cut
        assert s["objective"] in ("cut", "volume") and s["cast"] in ("unicast", "multicast")
    assert cut.cast == "unicast" and vol.cast == "multicast"

import numpy as np

from repro.sharding.layout import logical_traffic_matrix, sneap_device_layout


def test_logical_traffic_ring_edges():
    t = logical_traffic_matrix({"data": 4, "model": 4},
                               {"data": 1.0, "model": 10.0})
    # model-axis ring neighbors exchange the model volume symmetrically
    assert t[0, 1] == 10.0 and t[1, 0] == 10.0
    assert t[0, 4] == 1.0  # data neighbor
    assert t.sum() > 0 and np.allclose(t, t.T)


def test_layout_never_regresses_identity():
    order, base, optimized = sneap_device_layout(
        {"data": 8, "model": 8}, {"data": 1e6, "model": 64e6},
        phys_w=8, iters=8_000, seed=0)
    assert sorted(order.tolist()) == list(range(64))
    assert optimized <= base + 1e-9


def test_layout_respects_dead_chips():
    order, base, optimized = sneap_device_layout(
        {"data": 6, "model": 10}, {"data": 1e6, "model": 64e6},
        phys_w=8, iters=10_000, seed=0, dead_chips=[5, 22, 40, 41])
    alive = [c for c in range(64) if c not in (5, 22, 40, 41)]
    assert sorted(order.tolist()) == alive
    assert optimized <= base


def test_layout_improves_alltoall_traffic():
    """MoE expert-parallel all-to-all on the model axis: row-major lines
    are suboptimal (compact blocks have lower mean pairwise distance);
    seeded-hot SA must strictly improve (examples/sneap_mesh_layout.py)."""
    order, base, optimized = sneap_device_layout(
        {"data": 16, "model": 16}, {"data": 5e8, "model": 5e9},
        phys_w=16, iters=120_000, seed=0, patterns={"model": "alltoall"})
    assert optimized < base * 0.95
    assert sorted(order.tolist()) == list(range(256))

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import batch_axes_of, make_local_mesh
from repro.models.model import Model
from repro.sharding import (ShardingPlan, plan_batch, plan_caches,
                            plan_opt_state, plan_params)


class FakeMesh:
    """Axis-size stub so planner rules can be tested without 256 devices."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def _plan(multi=False):
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi
                    else {"data": 16, "model": 16})
    axes = tuple(a for a in mesh.shape if a != "model")
    return ShardingPlan(mesh=mesh, batch_axes=axes)


def _params_shape(name):
    cfg = get_config(name)
    return cfg, jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))


def test_llama_param_specs():
    cfg, params = _params_shape("llama3-8b")
    plan = _plan()
    specs = plan_params(plan, params)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    # stacked (L, D, H, hd): H at -2
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model", None)
    # kv heads = 8 not divisible by 16 -> replicated, recorded in notes
    assert specs["layers"]["attn"]["wk"] == P()
    assert any("wk" in n for n in plan.notes)
    assert specs["layers"]["mlp"]["w_gate"] == P(None, None, "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["final_norm"] == P()


def test_moe_expert_sharding():
    cfg, params = _params_shape("qwen3-moe-30b-a3b")
    specs = plan_params(_plan(), params)
    assert specs["layers"]["moe"]["w_gate"] == P(None, "model", None, None)
    assert specs["layers"]["moe"]["router"] == P()


def test_kv_cache_falls_back_to_sequence_sharding():
    cfg = get_config("llama3-8b")
    caches = jax.eval_shape(lambda: Model(cfg).init_caches(128, 32768))
    plan = _plan()
    specs = plan_caches(plan, caches)
    k = specs["layers"]["k"]  # (L, B, S, KVH=8, hd): kv !% 16 -> shard S
    assert k == P(None, "data", "model", None, None)
    assert specs["layers"]["pos"] == P(None, "data", "model")


def test_kv_cache_heads_sharded_when_divisible():
    cfg = get_config("whisper-medium")  # kv heads 16
    caches = jax.eval_shape(lambda: Model(cfg).init_caches(128, 32768))
    specs = plan_caches(_plan(), caches)
    assert specs["layers"]["k"] == P(None, "data", None, "model", None)


def test_batch_specs_and_divisibility():
    plan = _plan(multi=True)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), np.int32)}
    specs = plan_batch(plan, batch)
    assert specs["tokens"] == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard over 32 -> replicated + note
    specs1 = plan_batch(plan, {"tokens": jax.ShapeDtypeStruct((1, 1), np.int32)})
    assert specs1["tokens"] == P(None, None)
    assert any("batch" in n for n in plan.notes)


def test_zero1_adds_data_axis():
    cfg, params = _params_shape("llama3-8b")
    plan = _plan()
    ospecs = plan_opt_state(plan, params, zero1=True)
    # embed (V=128256, D): V got model; D=4096 divisible by 16 -> data
    assert ospecs["embed"] == P("model", "data")
    # wq (L=32, D, H, hd): L=32 divisible by 16 -> ZeRO-1 shards the stack dim
    assert ospecs["layers"]["attn"]["wq"][0] == "data"


def test_mamba_state_sharding():
    cfg = get_config("mamba2-780m")
    caches = jax.eval_shape(lambda: Model(cfg).init_caches(128, 1))
    specs = plan_caches(_plan(), caches)
    # state (L, B, H=48, N, P): H % 16 == 0 -> model
    assert specs["layers"]["state"] == P(None, "data", "model", None, None)


def test_local_mesh_runs_real_jit():
    """End-to-end: planner specs compile on the actual (1-device) mesh."""
    mesh = make_local_mesh()
    assert batch_axes_of(mesh) == ("data",)

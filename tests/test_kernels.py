"""Per-kernel allclose vs the pure-jnp oracle (interpret mode on CPU),
sweeping shapes and dtypes as required for every Pallas kernel."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip when absent
from hypothesis import given, settings, strategies as st

from repro.core.hopcost import hop_distance_matrix, swap_delta
from repro.core.mapping import pad_traffic
from repro.kernels.gain_eval import part_degrees, part_degrees_ref
from repro.kernels.hop_eval import hop_cost, hop_cost_ref
from repro.kernels.lif_step import lif_step, lif_step_ref
from repro.kernels.link_load import link_loads, link_loads_ref
from repro.kernels.swap_delta import swap_deltas, swap_deltas_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- hop_eval

@pytest.mark.parametrize("k", [1, 7, 25, 128, 256, 300, 513])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_hop_cost_shapes_dtypes(k, dtype):
    c = RNG.integers(0, 100, (k, k)).astype(dtype)
    x = RNG.integers(0, 16, k).astype(np.float32)
    y = RNG.integers(0, 16, k).astype(np.float32)
    ref = hop_cost_ref(jnp.asarray(c, jnp.float32), jnp.asarray(x), jnp.asarray(y))
    pal = hop_cost(jnp.asarray(c, jnp.float32), jnp.asarray(x), jnp.asarray(y),
                   backend="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-6)


@given(k=st.integers(2, 60), seed=st.integers(0, 999))
@settings(max_examples=15, deadline=None)
def test_hop_cost_property(k, seed):
    r = np.random.default_rng(seed)
    c = r.integers(0, 9, (k, k)).astype(np.float32)
    x = r.integers(0, 6, k).astype(np.float32)
    y = r.integers(0, 6, k).astype(np.float32)
    pal = float(hop_cost(jnp.asarray(c), jnp.asarray(x), jnp.asarray(y),
                         backend="interpret"))
    brute = sum(c[i, j] * (abs(x[i] - x[j]) + abs(y[i] - y[j]))
                for i in range(k) for j in range(k))
    np.testing.assert_allclose(pal, brute, rtol=1e-5)


# ----------------------------------------------------------- swap_delta

@pytest.mark.parametrize("k,cores,w", [(5, 25, 5), (25, 25, 5), (100, 256, 16),
                                       (256, 256, 16)])
def test_swap_deltas_vs_ref_and_loop(k, cores, w):
    c = RNG.integers(0, 100, (k, k)).astype(np.float64)
    padded = pad_traffic(c, cores)
    sym = padded + padded.T
    placement = RNG.permutation(cores)
    x = (placement % w).astype(np.float32)
    y = (placement // w).astype(np.float32)
    ref = np.asarray(swap_deltas_ref(jnp.asarray(sym, jnp.float32),
                                     jnp.asarray(x), jnp.asarray(y)))
    pal = np.asarray(swap_deltas(jnp.asarray(sym, jnp.float32),
                                 jnp.asarray(x), jnp.asarray(y),
                                 backend="interpret"))
    np.testing.assert_allclose(pal, ref, rtol=1e-4, atol=1e-2)
    dist = hop_distance_matrix(cores, w).astype(np.float64)
    for _ in range(10):
        a, b = RNG.integers(0, cores, 2)
        expect = swap_delta(sym, placement, dist, int(a), int(b))
        np.testing.assert_allclose(ref[a, b], expect, rtol=1e-5, atol=1e-2)


def test_swap_deltas_diagonal_zero():
    k = 40
    c = RNG.integers(0, 50, (k, k)).astype(np.float32)
    sym = c + c.T
    x = RNG.integers(0, 8, k).astype(np.float32)
    y = RNG.integers(0, 8, k).astype(np.float32)
    out = np.asarray(swap_deltas(jnp.asarray(sym), jnp.asarray(x), jnp.asarray(y),
                                 backend="interpret"))
    np.testing.assert_allclose(np.diag(out), 0.0, atol=1e-3)


# -------------------------------------------------------------- gain_eval

@pytest.mark.parametrize("n,k", [(1, 1), (7, 3), (128, 128), (200, 60), (513, 130)])
def test_gain_eval_shapes(n, k):
    a = RNG.integers(0, 40, (n, n)).astype(np.float32)
    a = a + a.T
    np.fill_diagonal(a, 0)
    p = RNG.integers(0, k, n).astype(np.int32)
    ref = part_degrees_ref(jnp.asarray(a), jnp.asarray(p), k)
    pal = part_degrees(jnp.asarray(a), jnp.asarray(p), k, backend="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5)


@given(n=st.integers(2, 50), k=st.integers(1, 20), seed=st.integers(0, 999))
@settings(max_examples=15, deadline=None)
def test_gain_eval_property(n, k, seed):
    """Row sums of the degree matrix equal the vertex's total edge weight."""
    r = np.random.default_rng(seed)
    a = r.integers(0, 9, (n, n)).astype(np.float32)
    a = a + a.T
    np.fill_diagonal(a, 0)
    p = r.integers(0, k, n).astype(np.int32)
    deg = np.asarray(part_degrees(jnp.asarray(a), jnp.asarray(p), k,
                                  backend="interpret"))
    np.testing.assert_allclose(deg.sum(axis=1), a.sum(axis=1), rtol=1e-5)


@pytest.mark.parametrize("n,e,k", [(1, 1, 1), (7, 5, 3), (128, 128, 128),
                                   (150, 90, 70), (260, 513, 130)])
def test_gain_eval_connectivity_mode_shapes(n, e, k):
    """Connectivity mode (incidence @ presence) vs the jnp reference."""
    from repro.kernels.gain_eval import connectivity_degrees, connectivity_degrees_ref

    inc = (RNG.random((n, e)) < 0.2).astype(np.float32) * RNG.integers(1, 9, (n, e))
    pres = (RNG.random((e, k)) < 0.3).astype(np.float32)
    ref = connectivity_degrees_ref(jnp.asarray(inc), jnp.asarray(pres))
    pal = connectivity_degrees(jnp.asarray(inc), jnp.asarray(pres),
                               backend="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5)


def test_gain_eval_connectivity_mode_exact_volume_degrees():
    """The kernel path reproduces graph.volume_degrees bit-exactly."""
    from repro.core.graph import build_hypergraph, volume_degrees
    from repro.core.refine_vec import _dense_incidence, _volume_degrees_via_kernel

    r = np.random.default_rng(7)
    n, k = 120, 66
    src, dst = r.integers(0, n, 500), r.integers(0, n, 500)
    hg = build_hypergraph(n, src, dst, r.integers(1, 9, n))
    part = r.integers(0, k, n).astype(np.int64)
    rows = np.arange(n, dtype=np.int64)
    via_kernel = _volume_degrees_via_kernel(_dense_incidence(hg), hg, part, k,
                                            rows, "interpret")
    np.testing.assert_array_equal(via_kernel, volume_degrees(hg, part, k))


# -------------------------------------------------------------- lif_step

@pytest.mark.parametrize("n", [1, 8, 127, 128, 1000, 4096])
@pytest.mark.parametrize("dtype", [np.float32])
def test_lif_step_sweep(n, dtype):
    v = RNG.standard_normal(n).astype(dtype)
    refr = RNG.integers(0, 3, n).astype(np.int32)
    cur = RNG.standard_normal(n).astype(dtype)
    kw = dict(decay=0.9, threshold=1.0, v_reset=0.0, refractory=2)
    pal = lif_step(jnp.asarray(v), jnp.asarray(refr), jnp.asarray(cur),
                   backend="interpret", **kw)
    ref = lif_step_ref(jnp.asarray(v), jnp.asarray(refr), jnp.asarray(cur), **kw)
    np.testing.assert_allclose(np.asarray(pal[0]), np.asarray(ref[0]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(pal[1]), np.asarray(ref[1]))
    np.testing.assert_array_equal(np.asarray(pal[2]), np.asarray(ref[2]))


def test_lif_step_refractory_blocks_fire():
    v = jnp.array([5.0, 5.0])
    refr = jnp.array([2, 0], jnp.int32)
    cur = jnp.zeros(2)
    _, _, fired = lif_step(v, refr, cur, decay=1.0, threshold=1.0, v_reset=0.0,
                           refractory=2, backend="interpret")
    assert not bool(fired[0]) and bool(fired[1])


# -------------------------------------------------------------- link_load

@pytest.mark.parametrize("k,w,h", [(5, 5, 5), (25, 5, 5), (60, 16, 16),
                                   (256, 16, 16), (30, 8, 4)])
def test_link_loads_sweep(k, w, h):
    c = RNG.integers(0, 30, (k, k)).astype(np.float32)
    cores = RNG.permutation(w * h)[:k]
    x = (cores % w).astype(np.float32)
    y = (cores // w).astype(np.float32)
    ref = link_loads_ref(jnp.asarray(c), jnp.asarray(x), jnp.asarray(y), w, h)
    pal = link_loads(jnp.asarray(c), jnp.asarray(x), jnp.asarray(y), w, h,
                     backend="interpret")
    for a, b, name in zip(pal, ref, "EWSN"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   err_msg=name)


def test_link_loads_total_equals_hop_weighted_traffic():
    """Sum of all link loads == sum C[a,b] * manhattan distance."""
    k, w, h = 30, 6, 5
    c = RNG.integers(0, 20, (k, k)).astype(np.float32)
    cores = RNG.permutation(w * h)[:k]
    x = (cores % w).astype(np.float32)
    y = (cores // w).astype(np.float32)
    maps = link_loads(jnp.asarray(c), jnp.asarray(x), jnp.asarray(y), w, h,
                      backend="interpret")
    total = sum(float(np.asarray(m).sum()) for m in maps)
    expect = float(hop_cost(jnp.asarray(c), jnp.asarray(x), jnp.asarray(y),
                            backend="jnp"))
    np.testing.assert_allclose(total, expect, rtol=1e-5)

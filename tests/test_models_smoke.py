"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs; plus
prefill+decode == full-forward consistency (the serving invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model


def _inputs(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    fe = None
    if cfg.family in ("vlm", "audio"):
        fe = jax.random.normal(key, (b, cfg.frontend_seq, cfg.frontend_dim),
                               jnp.float32)
    return tokens, fe


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_loss(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens, fe = _inputs(cfg, key)
    logits, _, aux = model.forward(params, tokens, mode="train", frontend=fe)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    batch = {"tokens": tokens}
    if fe is not None:
        batch["frontend"] = fe
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    if cfg.is_moe:
        assert float(aux) > 0  # load-balance loss active


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_full_forward(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    fe = None
    if cfg.family in ("vlm", "audio"):
        fe = jax.random.normal(key, (b, cfg.frontend_seq, cfg.frontend_dim),
                               jnp.float32)
    full, _, _ = model.forward(params, tokens, mode="train", frontend=fe)
    caches = model.init_caches(b, s + 1)
    _, caches, _ = model.forward(params, tokens[:, :s], mode="prefill",
                                 caches=caches, frontend=fe)
    pos = jnp.full((b, 1), s, jnp.int32)
    dec, _, _ = model.forward(params, tokens[:, s:s + 1], mode="decode",
                              caches=caches, positions=pos)
    a = np.asarray(full[:, s], np.float32)
    d = np.asarray(dec[:, 0], np.float32)
    err = np.max(np.abs(a - d)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-2, err


@pytest.mark.parametrize("name", ["llama3-8b", "mamba2-780m", "hymba-1.5b"])
def test_train_step_updates_params(name):
    """One real optimizer step changes params and keeps them finite."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_train_step

    cfg = get_config(name).reduced()
    mesh = make_local_mesh()
    bundle = make_train_step(cfg, mesh, remat=True, zero1=False)
    params = bundle.model.init(jax.random.PRNGKey(0))
    opt_state = bundle.init_opt(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    jitted = bundle.jit_for(batch)
    before = np.asarray(params["embed"], np.float32).copy()
    params, opt_state, metrics = jitted(params, opt_state, batch)
    after = np.asarray(params["embed"], np.float32)
    assert np.isfinite(float(metrics["loss"]))
    assert not np.array_equal(before, after)
    assert np.isfinite(after).all()

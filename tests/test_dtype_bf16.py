"""Regression: bf16 production dtype must not promote through any block
(the full configs run bf16; reduced smoke configs run f32, which once hid
a carry-dtype mismatch in the layer scan)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model


@pytest.mark.parametrize("name", ARCHS)
def test_bf16_forward_all_archs(name):
    cfg = dataclasses.replace(get_config(name).reduced(),
                              param_dtype="bfloat16",
                              activation_dtype="bfloat16")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    fe = None
    if cfg.family in ("vlm", "audio"):
        fe = jax.random.normal(key, (2, cfg.frontend_seq, cfg.frontend_dim),
                               jnp.bfloat16)
    logits, _, _ = model.forward(params, tokens, mode="train", frontend=fe)
    assert logits.dtype == jnp.bfloat16
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # decode path too (this is where cache dtype mismatches bite)
    caches = model.init_caches(2, 17)
    _, caches, _ = model.forward(params, tokens, mode="prefill", caches=caches,
                                 frontend=fe)
    pos = jnp.full((2, 1), 16, jnp.int32)
    dec, _, _ = model.forward(params, tokens[:, :1], mode="decode",
                              caches=caches, positions=pos)
    assert not bool(jnp.isnan(dec.astype(jnp.float32)).any())

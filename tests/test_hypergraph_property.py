"""Property tests (hypothesis): hyperedge dedup and pin-set contraction
are exactly metric-preserving — ``comm_volume`` and brute-force λ-gains
are invariant under ``dedup_hyperedges`` and under contraction through
arbitrary cmaps, at every coarsening level."""
import numpy as np
import pytest

from repro.core.coarsen import coarsen, contract_hypergraph
from repro.core.graph import (
    Hypergraph,
    comm_volume,
    dedup_hyperedges,
    volume_degrees,
)

from conftest import layered_snn_graph, random_hypergraph

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


def stack_duplicates(h: Hypergraph, copies: int, seed: int) -> Hypergraph:
    """Concatenate ``copies`` randomly fire-scaled copies of every
    hyperedge — a duplicate factory with known ground truth: dedup must
    merge each group back to one edge with summed weights."""
    r = np.random.default_rng(seed)
    scale = r.integers(1, 4, copies * h.num_hyperedges)
    d = np.diff(h.hxadj)
    hxadj = np.concatenate([[0], np.cumsum(np.tile(d, copies))])
    pin_scale = np.repeat(scale, np.tile(d, copies))
    return Hypergraph(
        hxadj=hxadj.astype(np.int64),
        hpins=np.tile(h.hpins, copies),
        hwgt=np.tile(h.hwgt, copies) * pin_scale,
        hsrc=np.tile(h.hsrc, copies),
        hfire=np.tile(h.hfire, copies) * scale,
        num_vertices=h.num_vertices,
    )


@given(n=st.integers(10, 60), pins=st.integers(20, 200),
       copies=st.integers(2, 4), k=st.integers(2, 6),
       seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_dedup_preserves_volume_and_gains(n, pins, copies, k, seed):
    """comm_volume and the exact λ-gain matrix D* survive dedup, and the
    duplicate groups merge back to the original edge count with hfire and
    the delivered-spike ledger conserved."""
    base = random_hypergraph(n, pins, seed=seed).hyper
    stacked = stack_duplicates(base, copies, seed)
    deduped = dedup_hyperedges(stacked)
    deduped.validate(check_dedup=True)
    assert deduped.num_hyperedges == base.num_hyperedges
    assert int(deduped.hfire.sum()) == int(stacked.hfire.sum())
    assert int(deduped.hwgt.sum()) == int(stacked.hwgt.sum())
    r = np.random.default_rng(seed + 1)
    for _ in range(3):
        part = r.integers(0, k, n)
        assert comm_volume(stacked, part) == comm_volume(deduped, part)
        # Equal D* matrices imply every single-vertex λ-gain is equal.
        np.testing.assert_array_equal(volume_degrees(stacked, part, k),
                                      volume_degrees(deduped, part, k))


@given(n=st.integers(10, 80), pins=st.integers(20, 300),
       nc=st.integers(2, 20), k=st.integers(2, 6),
       seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_contraction_through_random_cmap_preserves_volume(n, pins, nc, k, seed):
    """For any cmap, a coarse partition and its projection span identical
    member partition sets — comm_volume and λ-gains are exactly equal."""
    hyper = random_hypergraph(n, pins, seed=seed).hyper
    r = np.random.default_rng(seed + 1)
    cmap = r.integers(0, nc, n)
    coarse = contract_hypergraph(hyper, cmap, nc)
    coarse.validate(check_dedup=True)
    for _ in range(3):
        part_c = r.integers(0, k, nc)
        assert comm_volume(coarse, part_c) == comm_volume(hyper, part_c[cmap])


@given(seed=st.integers(0, 1000), k=st.integers(2, 8))
@settings(max_examples=8, deadline=None)
def test_dedup_invariant_at_every_coarsening_level(seed, k):
    """Dedup (applied per level by contract_hypergraph) never changes
    comm_volume at any level: the projected volume is constant down the
    whole hierarchy, every level is duplicate-free, and re-running dedup
    is a no-op."""
    g = random_hypergraph(250, 1200, seed=seed)
    rng = np.random.default_rng(seed)
    levels = coarsen(g, rng, coarsen_to=24, impl="vec")
    part = rng.integers(0, k, levels[-1].num_vertices)
    vols = []
    for coarse in reversed(levels):
        coarse.hyper.validate(check_dedup=True)
        assert dedup_hyperedges(coarse.hyper).num_hyperedges == \
            coarse.hyper.num_hyperedges
        vols.append(comm_volume(coarse.hyper, part))
        if coarse.cmap is not None:
            part = part[coarse.cmap]
    assert len(set(vols)) == 1


def test_layered_coarsening_dedups_heavily():
    """Dense equal-weight layers are the dedup jackpot: coarse pin sets
    collapse onto each other, so deep levels carry far fewer hyperedges
    than sources — while every level still preserves comm_volume."""
    g = layered_snn_graph((128, 128, 128, 128), seed=0)
    rng = np.random.default_rng(0)
    levels = coarsen(g, rng, coarsen_to=24, impl="vec")
    assert len(levels) > 2
    fine_e = levels[0].hyper.num_hyperedges
    coarse_e = levels[-1].hyper.num_hyperedges
    assert coarse_e < fine_e // 2, (fine_e, coarse_e)
    part = rng.integers(0, 4, levels[-1].num_vertices)
    vols = []
    for coarse in reversed(levels):
        vols.append(comm_volume(coarse.hyper, part))
        if coarse.cmap is not None:
            part = part[coarse.cmap]
    assert len(set(vols)) == 1

import numpy as np
import pytest

from repro.core.hopcost import hop_distance_matrix
from repro.core.mapping import MAPPERS, pad_traffic, pso_search, sa_search, tabu_search


def _instance(k=20, cores=25, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 200, (k, k)).astype(np.float64)
    np.fill_diagonal(c, 0)
    return c, int(c.sum())


def _cost_of(placement, traffic, cores, w, trace_len):
    padded = pad_traffic(traffic, cores)
    dist = hop_distance_matrix(cores, w)
    d = dist[placement[:, None], placement[None, :]]
    return float((d * padded[: len(placement), : len(placement)]).sum() / trace_len)


@pytest.mark.parametrize("mapper", ["sa", "pso", "tabu"])
def test_mapper_improves_over_random(mapper):
    c, trace_len = _instance()
    kwargs = {"sa": dict(iters=8000), "pso": dict(iters=40, swarm=16),
              "tabu": dict(iters=60, candidates=64)}[mapper]
    res = MAPPERS[mapper](c, 25, 5, trace_len, seed=0, **kwargs)
    rng = np.random.default_rng(1)
    rand = np.mean([
        _cost_of(rng.permutation(25)[:20], c, 25, 5, trace_len) for _ in range(20)
    ])
    assert res.avg_hop < rand
    # reported cost must equal recomputed cost of the returned placement
    np.testing.assert_allclose(
        res.avg_hop, _cost_of(res.placement, c, 25, 5, trace_len), rtol=1e-9)


def test_placement_is_injective():
    c, trace_len = _instance(k=25)
    res = sa_search(c, 25, 5, trace_len, seed=0, iters=5000)
    assert len(set(res.placement.tolist())) == 25


def test_sa_deterministic():
    c, trace_len = _instance(seed=2)
    a = sa_search(c, 25, 5, trace_len, seed=7, iters=4000)
    b = sa_search(c, 25, 5, trace_len, seed=7, iters=4000)
    assert np.array_equal(a.placement, b.placement)


def test_sa_usually_best_among_mappers():
    """Paper §5.2: SA finds the best mapping within a budget (checked on
    average over seeds to avoid flakiness)."""
    wins = 0
    for seed in range(3):
        c, trace_len = _instance(seed=seed)
        sa = sa_search(c, 25, 5, trace_len, seed=seed, iters=12_000)
        pso = pso_search(c, 25, 5, trace_len, seed=seed, iters=40, swarm=16)
        tabu = tabu_search(c, 25, 5, trace_len, seed=seed, iters=50, candidates=64)
        if sa.avg_hop <= min(pso.avg_hop, tabu.avg_hop) + 1e-9:
            wins += 1
    assert wins >= 2


def test_pad_traffic_rejects_too_many_partitions():
    with pytest.raises(ValueError):
        pad_traffic(np.ones((30, 30)), 25)

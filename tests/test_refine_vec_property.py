"""Property tests for the vec partitioning engine (skip without hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip when absent
from hypothesis import given, settings, strategies as st

from repro.core.coarsen import heavy_edge_matching_vec
from repro.core.graph import edge_cut, partition_weights, validate_partition
from repro.core.partition import sneap_partition
from repro.core.refine_vec import refine_level_vec

from conftest import random_graph


@given(n=st.integers(20, 150), p=st.floats(0.05, 0.3), seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_matching_vec_property(n, p, seed):
    """Matching is an involution and respects the merged-weight cap."""
    g = random_graph(n, p, seed=seed)
    cap = 2  # unit vertex weights: every merge is allowed, at most pairs
    match = heavy_edge_matching_vec(g, np.random.default_rng(seed), max_vwgt=cap)
    assert np.array_equal(match[match], np.arange(n))
    merged = g.vwgt + g.vwgt[match]
    paired = match != np.arange(n)
    assert (merged[paired] <= cap).all()


@given(n=st.integers(30, 150), p=st.floats(0.05, 0.25), seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_refine_vec_property(n, p, seed):
    """Batched refinement: valid result, capacity kept, cut non-increasing
    and consistent, deterministic under a fixed input."""
    g = random_graph(n, p, seed=seed)
    k = max(3, n // 20)
    cap = max(8, 2 * (n // k))
    part = (np.arange(n) % k).astype(np.int64)
    c0 = edge_cut(g, part)
    out, cut = refine_level_vec(g, part, k, cap)
    assert cut <= c0
    assert cut == edge_cut(g, out)
    assert out.min() >= 0 and out.max() < k
    assert (partition_weights(g, out, k) <= cap).all()
    out2, cut2 = refine_level_vec(g, part, k, cap)
    assert np.array_equal(out, out2) and cut == cut2


@given(n=st.integers(20, 120), p=st.floats(0.05, 0.3), seed=st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_sneap_vec_parity_property(n, p, seed):
    """impl="vec" is validate_partition-clean and, under the adaptive
    small-graph floor, exactly matches the scalar engine here."""
    g = random_graph(n, p, seed=seed)
    cap = max(8, n // 6)
    s = sneap_partition(g, capacity=cap, seed=seed, impl="scalar")
    v = sneap_partition(g, capacity=cap, seed=seed, impl="vec")
    validate_partition(g, v.part, v.k, cap)
    assert v.edge_cut == edge_cut(g, v.part)
    assert np.array_equal(s.part, v.part) and s.edge_cut == v.edge_cut

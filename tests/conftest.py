import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_graph(n: int, p: float, seed: int = 0, max_w: int = 100):
    """Random undirected weighted graph as a repro.core Graph."""
    from repro.core.graph import build_graph

    r = np.random.default_rng(seed)
    mask = np.triu(r.random((n, n)) < p, k=1)
    src, dst = np.nonzero(mask)
    w = r.integers(1, max_w, src.shape[0])
    return build_graph(n, src, dst, w)

"""Shared fixtures and graph/trace builders for the test suite.

The builders are the canonical way tests construct synthetic SNN traffic;
per-file ad-hoc generators should migrate here so property tests, engine
comparisons, and NoC tests all agree on what "a random SNN" means.
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_graph(n: int, p: float, seed: int = 0, max_w: int = 100):
    """Random undirected weighted graph as a repro.core Graph."""
    from repro.core.graph import build_graph

    r = np.random.default_rng(seed)
    mask = np.triu(r.random((n, n)) < p, k=1)
    src, dst = np.nonzero(mask)
    w = r.integers(1, max_w, src.shape[0])
    return build_graph(n, src, dst, w)


def random_snn_traffic(n: int, pins: int, seed: int = 0, max_fire: int = 20):
    """Directed synapse lists + fire counts, as the profiler would emit.

    Returns (src, dst, fire): ``pins`` directed synapses between random
    neuron pairs and a per-neuron fire count in [0, max_fire).
    """
    r = np.random.default_rng(seed)
    src = r.integers(0, n, pins)
    dst = r.integers(0, n, pins)
    fire = r.integers(0, max_fire, n)
    return src, dst, fire


def random_hypergraph(n: int, pins: int, seed: int = 0, max_fire: int = 20):
    """Random SNN traffic as a Graph with its multicast hypergraph attached.

    ``pins`` is the number of directed synapses drawn; the hyperedge view
    (``.hyper``) shares the same traffic, exactly as ``profile_snn`` emits.
    """
    from repro.core.graph import build_graph, build_hypergraph

    src, dst, fire = random_snn_traffic(n, pins, seed, max_fire)
    g = build_graph(n, src, dst, fire[src])
    g.hyper = build_hypergraph(n, src, dst, fire)
    return g


def fanout_snn_graph(n: int, fan: int = 10, seed: int = 0, max_fire: int = 20):
    """Fan-out-heavy traffic (every neuron multicasts to ``fan`` targets)
    with the hypergraph attached — the regime where the cut and volume
    objectives diverge most and λ-gain refinement earns its keep."""
    from repro.core.graph import build_graph, build_hypergraph

    r = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), fan)
    dst = r.integers(0, n, n * fan)
    fire = r.integers(1, max_fire, n)
    g = build_graph(n, src, dst, fire[src])
    g.hyper = build_hypergraph(n, src, dst, fire)
    return g


def layered_snn_graph(widths, seed: int = 0, fire: int = 5):
    """mlp-shaped SNN: dense equal-weight fully-connected layers.

    Every neuron of layer i synapses onto every neuron of layer i+1 with
    identical weight (``fire`` spikes each) — the equal-weight-tie regime
    that degrades naive vectorized matching, and the structured regime
    where coarse hyperedge pin sets collapse onto each other (hyperedge
    dedup).  Returns a Graph with the hypergraph attached.
    """
    from repro.core.graph import build_graph, build_hypergraph

    widths = list(widths)
    offs = np.cumsum([0] + widths)
    n = int(offs[-1])
    srcs, dsts = [], []
    for i in range(len(widths) - 1):
        a = np.arange(offs[i], offs[i + 1])
        b = np.arange(offs[i + 1], offs[i + 2])
        srcs.append(np.repeat(a, b.shape[0]))
        dsts.append(np.tile(b, a.shape[0]))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    fires = np.full(n, fire, dtype=np.int64)
    g = build_graph(n, src, dst, fires[src])
    g.hyper = build_hypergraph(n, src, dst, fires)
    return g


def random_spike_trace(seed=0, n_neurons=30, n_spikes=400, timesteps=20,
                       k=6, cores=9):
    """Random spike trace + partition + placement for NoC simulations.

    Returns (t, src, dst, part, placement) with t sorted, matching the
    (trace_t, trace_src, trace_dst) layout ``profile_snn`` produces.
    """
    r = np.random.default_rng(seed)
    part = r.integers(0, k, n_neurons)
    placement = r.permutation(cores)[:k]
    t = np.sort(r.integers(0, timesteps, n_spikes))
    src = r.integers(0, n_neurons, n_spikes)
    dst = r.integers(0, n_neurons, n_spikes)
    return t, src, dst, part, placement

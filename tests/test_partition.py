import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip when absent
from hypothesis import given, settings, strategies as st

from repro.core.baselines import greedy_kl_partition, sco_partition
from repro.core.coarsen import coarsen, contract, heavy_edge_matching
from repro.core.graph import edge_cut, partition_weights, validate_partition
from repro.core.partition import sneap_partition

from conftest import random_graph


def test_matching_is_symmetric():
    g = random_graph(80, 0.1, seed=5)
    match = heavy_edge_matching(g, np.random.default_rng(0))
    for v in range(80):
        assert match[match[v]] == v


def test_contract_preserves_totals():
    g = random_graph(60, 0.2, seed=6)
    match = heavy_edge_matching(g, np.random.default_rng(1))
    c = contract(g, match)
    assert c.total_vwgt == g.total_vwgt
    # total edge weight = original minus weights folded inside matched pairs
    internal = sum(int(w) for v in range(60)
                   for u, w in zip(*g.neighbors(v)) if match[v] == u) // 2
    assert c.total_adjwgt == g.total_adjwgt - internal


def test_coarsen_levels_shrink():
    g = random_graph(300, 0.05, seed=7)
    levels = coarsen(g, np.random.default_rng(2), coarsen_to=32)
    sizes = [lv.num_vertices for lv in levels]
    assert sizes == sorted(sizes, reverse=True)
    assert all(lv.total_vwgt == g.total_vwgt for lv in levels)


def test_sneap_partition_valid_and_better_than_random():
    g = random_graph(200, 0.08, seed=8)
    res = sneap_partition(g, capacity=32, seed=0)
    validate_partition(g, res.part, res.k, 32)
    rng = np.random.default_rng(0)
    rand_cuts = []
    for _ in range(5):
        part = np.repeat(np.arange(res.k), -(-200 // res.k))[:200]
        rng.shuffle(part)
        rand_cuts.append(edge_cut(g, part))
    assert res.edge_cut < min(rand_cuts)


def test_sneap_deterministic():
    g = random_graph(120, 0.1, seed=9)
    a = sneap_partition(g, capacity=32, seed=3)
    b = sneap_partition(g, capacity=32, seed=3)
    assert np.array_equal(a.part, b.part) and a.edge_cut == b.edge_cut


def test_sneap_beats_or_matches_sco():
    g = random_graph(150, 0.1, seed=10)
    sneap = sneap_partition(g, capacity=32, seed=0)
    sco = sco_partition(g, capacity=32)
    assert sneap.edge_cut <= sco.edge_cut


def test_greedy_kl_valid():
    g = random_graph(100, 0.1, seed=11)
    res = greedy_kl_partition(g, capacity=32, seed=0, max_passes=3)
    validate_partition(g, res.part, res.k, 32)


@given(n=st.integers(20, 120), p=st.floats(0.05, 0.3), seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_partition_property(n, p, seed):
    """Every neuron assigned once; capacity respected; cut consistent."""
    g = random_graph(n, p, seed=seed)
    cap = max(8, n // 6)
    res = sneap_partition(g, capacity=cap, seed=seed)
    validate_partition(g, res.part, res.k, cap)
    assert res.edge_cut == edge_cut(g, res.part)
    assert partition_weights(g, res.part, res.k).sum() == n

import json
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import CheckpointManager


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(r.standard_normal((4, 4)), jnp.float32),
                       "b": jnp.asarray(r.standard_normal(4), jnp.float32)},
            "opt": {"m": jnp.zeros((4, 4)), "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(10, tree)
    restored, step = mgr.restore(tree)
    assert step == 10
    for a, b in zip(jax._src.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402  (used above lazily)


def test_latest_pointer_and_prune(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.latest_step() == 4
    assert sorted(mgr.all_steps()) == [3, 4]


def test_restore_ignores_uncommitted_tmp(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(5, tree)
    # simulate a crashed mid-write of step 6
    (tmp_path / "step_000000006.tmp").mkdir()
    (tmp_path / "step_000000006.tmp" / "arrays.npz").write_bytes(b"garbage")
    restored, step = mgr.restore(tree)
    assert step == 5


def test_latest_not_flipped_if_dir_missing(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, _tree())
    shutil.rmtree(tmp_path / "step_000000003")
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(1)
    mgr.save_async(42, tree)
    mgr.wait()
    restored, step = mgr.restore(tree)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_manifest_written(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    manifest = json.loads((tmp_path / "step_000000001" / "manifest.json").read_text())
    assert manifest["step"] == 1
    assert "params/w" in manifest["arrays"]

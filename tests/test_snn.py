import jax.numpy as jnp
import numpy as np

from repro.snn.lif import LIFParams, lif_run
from repro.snn.simulate import _expand_trace, profile_snn
from repro.snn.topology import PAPER_SNNS, make_snn


def test_lif_fires_on_suprathreshold_input():
    n = 4
    w = jnp.zeros((n, n), jnp.float32)
    drive = np.zeros((10, n), np.float32)
    drive[2, 1] = 5.0  # strong input to neuron 1 at t=2
    raster = lif_run(w, jnp.asarray(drive), LIFParams(threshold=1.0))
    assert raster[2, 1] == 1
    assert raster.sum() == 1  # nothing else fires


def test_lif_subthreshold_decays_no_fire():
    n = 2
    w = jnp.zeros((n, n), jnp.float32)
    drive = np.full((50, n), 0.05, np.float32)  # steady-state v = .05/(1-.9) = .5
    raster = lif_run(w, jnp.asarray(drive), LIFParams(decay=0.9, threshold=1.0))
    assert raster.sum() == 0


def test_lif_synaptic_propagation():
    # 0 -> 1 with strong synapse: firing 0 at t fires 1 at t+1
    w = jnp.zeros((2, 2), jnp.float32).at[0, 1].set(2.0)
    drive = np.zeros((6, 2), np.float32)
    drive[1, 0] = 2.0
    raster = lif_run(w, jnp.asarray(drive), LIFParams())
    assert raster[1, 0] == 1 and raster[2, 1] == 1


def test_expand_trace_counts():
    raster = np.zeros((3, 3), np.uint8)
    raster[0, 0] = 1
    raster[2, 1] = 1
    xadj = np.array([0, 2, 3, 3])  # n0 -> {a, b}, n1 -> {c}
    adjncy = np.array([1, 2, 2])
    t, s, d = _expand_trace(raster, xadj, adjncy)
    assert len(t) == 3
    assert (s == np.array([0, 0, 1])).all()
    assert (d == np.array([1, 2, 2])).all()
    assert (t == np.array([0, 0, 2])).all()


def test_profile_consistency_small():
    topo = make_snn("smooth_320")
    prof = profile_snn(topo, num_steps=100, seed=0)
    # graph total weight == number of trace transmissions (both count
    # per-synapse spike deliveries over the window)
    assert prof.graph.total_adjwgt == prof.num_spikes
    assert prof.graph.num_vertices == topo.num_neurons
    # every trace record rides an existing synapse
    syn = set(zip(topo.syn_src.tolist(), topo.syn_dst.tolist()))
    pick = np.random.default_rng(0).integers(0, prof.num_spikes, 50)
    for i in pick:
        assert (int(prof.trace_src[i]), int(prof.trace_dst[i])) in syn


def test_profile_cache_misses_on_content_change(tmp_path):
    """Regression: same-name, same-size topology with different weights
    must miss the cache instead of returning the stale profile."""
    topo = make_snn("smooth_320")
    first = profile_snn(topo, num_steps=100, seed=0, cache_dir=tmp_path)
    assert len(list(tmp_path.glob("profile_*.npz"))) == 1

    # Rebuild the "same" network with different synaptic weights.
    mutated = make_snn("smooth_320")
    mutated.weights = mutated.weights * 1.5
    second = profile_snn(mutated, num_steps=100, seed=0, cache_dir=tmp_path)
    assert len(list(tmp_path.glob("profile_*.npz"))) == 2  # cache miss
    assert not np.array_equal(first.fire_counts, second.fire_counts) or \
        first.num_spikes != second.num_spikes

    # The unmutated topology still hits its own entry bitwise.
    again = profile_snn(make_snn("smooth_320"), num_steps=100, seed=0,
                        cache_dir=tmp_path)
    assert len(list(tmp_path.glob("profile_*.npz"))) == 2  # cache hit
    assert np.array_equal(first.trace_t, again.trace_t)
    assert np.array_equal(first.trace_src, again.trace_src)
    assert np.array_equal(first.fire_counts, again.fire_counts)


def test_all_paper_snns_build():
    for name in PAPER_SNNS:
        topo = make_snn(name)
        assert topo.num_neurons == int(name.split("_")[1])
        assert topo.weights.shape == (topo.num_neurons,) * 2

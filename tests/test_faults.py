"""Metamorphic tests for fault injection + incremental re-mapping.

The graceful-degradation layer (PR 6) spans three modules and this suite
pins its load-bearing invariants:

* `repro.runtime.faults` / `repro.nocsim` — an *empty* fault state is
  bit-identical to the fault-free engines on every `NoCStats` field; dead
  endpoints drop, blocked XY routes detour via YX when clean, and spikes
  are conserved (delivered + local + dropped == transmissions).
* `repro.core.placecost.MigrationAwareObjective` — batched swap deltas
  are *exact* differences of totals even with migration prices and dead
  cores in play (the property the SA engine's correctness rides on).
* `repro.core.remap` — eviction vacates exactly the requested partitions
  and never repopulates them through the forbidden refine pass; both
  remap strategies are deterministic under a fixed seed and never leave a
  populated partition on a dead core; infeasible degraded meshes fail
  with an error naming the exact deficit.
* `repro.core.pipeline.run_toolchain(fault_schedule=...)` — a zero-event
  schedule reproduces the fault-free replay bit for bit, and a mid-trace
  core failure surfaces remap bookkeeping in ``summary()``.
"""
import dataclasses

import numpy as np
import pytest

from conftest import fanout_snn_graph, random_spike_trace

from repro.core import (
    MigrationAwareObjective,
    check_degraded_capacity,
    evict_dead_partitions,
    incremental_remap,
    make_objective,
    partition_weights,
    run_toolchain,
    scratch_remap,
    sneap_partition,
)
from repro.nocsim import simulate_noc
from repro.nocsim.xy import link_ids_for_routes
from repro.runtime.faults import (
    FaultEvent,
    FaultSchedule,
    FaultState,
    heartbeat_detect,
)
from repro.runtime.health import HeartbeatMonitor
from repro.snn.simulate import ProfileResult


def assert_stats_identical(a, b):
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    assert da.keys() == db.keys()
    for key in da:
        va, vb = da[key], db[key]
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            assert np.array_equal(va, vb), key
        else:
            assert va == vb, key


# ---------------------------------------------------------------------------
# fault model: zero-fault parity, drops, detours, conservation


@pytest.mark.parametrize("cast", ["unicast", "multicast"])
@pytest.mark.parametrize("mode,engine", [
    ("analytic", "batched"), ("queued", "batched"), ("queued", "ref"),
])
def test_empty_fault_state_bit_identical(cast, mode, engine):
    t, src, dst, part, placement = random_spike_trace(
        seed=2, n_spikes=600, timesteps=15)
    args = dict(mode=mode, engine=engine, cast=cast, link_capacity=2)
    plain = simulate_noc(t, src, dst, part, placement, 3, 3, **args)
    empty = simulate_noc(t, src, dst, part, placement, 3, 3,
                         faults=FaultState.none(3, 3), **args)
    assert_stats_identical(plain, empty)
    assert empty.spikes_dropped == 0 and empty.detour_hops == 0


@pytest.mark.parametrize("mode,engine", [
    ("analytic", "batched"), ("queued", "batched"), ("queued", "ref"),
])
def test_unicast_spike_conservation_under_dead_cores(mode, engine):
    t, src, dst, part, placement = random_spike_trace(
        seed=5, n_spikes=800, timesteps=10)
    state = FaultState.none(3, 3)
    state = state.apply(FaultEvent(0, "core", (1, 7)))
    s = simulate_noc(t, src, dst, part, placement, 3, 3, mode=mode,
                     engine=engine, link_capacity=2, faults=state)
    assert s.spikes_dropped > 0
    # every transmission is delivered remotely, delivered locally, or dropped
    assert s.num_noc_spikes + s.num_local_spikes + s.spikes_dropped == t.shape[0]
    base = simulate_noc(t, src, dst, part, placement, 3, 3, mode=mode,
                        engine=engine, link_capacity=2)
    assert base.num_noc_spikes + base.num_local_spikes == t.shape[0]
    assert s.num_noc_spikes < base.num_noc_spikes


def _one_packet(src_core, dst_core):
    """A single spike between two 2-neuron partitions on a 3x3 mesh."""
    t = np.array([0])
    src, dst = np.array([0]), np.array([1])
    part = np.array([0, 1])
    placement = np.array([src_core, dst_core])
    return t, src, dst, part, placement


def test_blocked_xy_route_detours_via_yx():
    # core 0 -> core 4 on 3x3: XY goes east (0->1) then north (1->4);
    # YX goes north (0->3) then east (3->4).
    t, src, dst, part, placement = _one_packet(0, 4)
    east01 = int(link_ids_for_routes(np.array([0]), np.array([1]), 3, 3)[0][0])
    north03 = int(link_ids_for_routes(np.array([0]), np.array([3]), 3, 3)[0][0])
    state = FaultState.none(3, 3).apply(FaultEvent(0, "link", (east01,)))
    s = simulate_noc(t, src, dst, part, placement, 3, 3, faults=state)
    assert s.spikes_dropped == 0
    assert s.num_noc_spikes == 1
    assert s.detour_hops == 2  # both orders are minimal: same hop count
    assert s.total_hops == 2
    # both dimension orders blocked -> the packet is dropped
    both = state.apply(FaultEvent(0, "link", (north03,)))
    s2 = simulate_noc(t, src, dst, part, placement, 3, 3, faults=both)
    assert s2.spikes_dropped == 1
    assert s2.num_noc_spikes == 0 and s2.detour_hops == 0


def test_dead_endpoint_drops_remote_and_local_spikes():
    t, src, dst, part, placement = _one_packet(0, 4)
    dead_dst = FaultState.none(3, 3).apply(FaultEvent(0, "core", (4,)))
    s = simulate_noc(t, src, dst, part, placement, 3, 3, faults=dead_dst)
    assert s.spikes_dropped == 1 and s.num_noc_spikes == 0
    # a core-local delivery dies with its core
    local = simulate_noc(t, src, np.array([0]), part, placement, 3, 3,
                         faults=FaultState.none(3, 3).apply(
                             FaultEvent(0, "core", (0,))))
    assert local.spikes_dropped == 1 and local.num_local_spikes == 0


def test_dead_core_kills_its_router_for_through_traffic():
    # core 0 -> core 2 (same row): XY and YX both run straight through
    # core 1's router; killing core 1 strands the packet.
    t, src, dst, part, placement = _one_packet(0, 2)
    state = FaultState.none(3, 3).apply(FaultEvent(0, "core", (1,)))
    s = simulate_noc(t, src, dst, part, placement, 3, 3, faults=state)
    assert s.spikes_dropped == 1 and s.num_noc_spikes == 0


# ---------------------------------------------------------------------------
# MigrationAwareObjective: exact deltas


def _wrapper(seed=0, k=12, num_cores=16, dead=(3, 11)):
    rng = np.random.default_rng(seed)
    traffic = rng.integers(0, 40, (k, k)).astype(np.int64)
    np.fill_diagonal(traffic, 0)
    base = make_objective("pairwise", traffic, num_cores, 4, mesh_h=4)
    live = rng.permutation(num_cores)
    move_weight = rng.integers(1, 50, k)
    dead_mask = np.zeros(num_cores, dtype=bool)
    dead_mask[list(dead)] = True
    obj = MigrationAwareObjective(base, live, move_weight,
                                  migration_cost=2.5, dead_cores=dead_mask,
                                  forbid_penalty=1e5)
    return obj, base, rng


def test_migration_objective_total_decomposes():
    obj, base, rng = _wrapper()
    live = obj.live
    p = rng.permutation(16)
    assert obj.total(p) == pytest.approx(base.total(p) + obj.penalty_total(p))
    # the live placement pays no migration, only any dead-core forbids
    pen_live = obj.penalty_total(live)
    forb = obj.forbid_penalty * (obj.real & obj.dead[live]).sum()
    assert pen_live == pytest.approx(forb)


def test_migration_objective_swap_deltas_exact():
    obj, _, rng = _wrapper(seed=7)
    p = rng.permutation(16)
    obj.attach(p)
    aa = rng.integers(0, 16, 64)
    bb = (aa + rng.integers(1, 16, 64)) % 16
    batch = obj.swap_delta_batch(aa, bb)
    for i in range(aa.shape[0]):
        a, b = int(aa[i]), int(bb[i])
        sd = obj.swap_delta(a, b)
        assert sd == pytest.approx(batch[i], abs=1e-9)
        p2 = p.copy()
        p2[a], p2[b] = p2[b], p2[a]
        assert sd == pytest.approx(obj.total(p2) - obj.total(p), abs=1e-6)


def test_migration_objective_apply_swaps_matches_recompute():
    obj, _, rng = _wrapper(seed=11)
    p = rng.permutation(16)
    obj.attach(p.copy())  # attach keeps a live reference; keep p pristine
    pairs = np.array([[0, 5], [1, 9], [2, 14]])  # disjoint positions
    total = obj.apply_swaps(pairs)
    q = p.copy()
    for a, b in pairs:
        q[a], q[b] = q[b], q[a]
    np.testing.assert_array_equal(obj._placement, q)
    assert total == pytest.approx(obj.total(q), abs=1e-6)
    fresh = MigrationAwareObjective(obj.base, obj.live,
                                    obj.move_cost[:obj.num_partitions] / 2.5,
                                    migration_cost=2.5, dead_cores=obj.dead,
                                    forbid_penalty=obj.forbid_penalty)
    assert fresh.attach(q) == pytest.approx(total, abs=1e-6)


# ---------------------------------------------------------------------------
# eviction + remap


@pytest.fixture(scope="module")
def live_mapping():
    """A partitioned + placed 440-neuron SNN on a 4x4 mesh (capacity 40
    partition fill, remapped later with capacity-60 hardware headroom)."""
    g = fanout_snn_graph(440, fan=8, seed=1)
    pres = sneap_partition(g, capacity=40, seed=0, impl="vec")
    rng = np.random.default_rng(0)
    placement = rng.permutation(16)[:pres.k]
    r = np.random.default_rng(3)
    t = np.sort(r.integers(0, 40, 5000))
    src = r.integers(0, 440, 5000)
    dst = r.integers(0, 440, 5000)
    return g, pres, placement, (t, src, dst)


def test_evict_dead_partitions_vacates_and_respects_forbid(live_mapping):
    g, pres, _, _ = live_mapping
    dead_parts = np.array([2, 5])
    w0 = partition_weights(g, pres.part, pres.k)
    # refine_iters=0: pure minimal-movement eviction — only the evicted
    # neurons change partition
    part2, n_evicted = evict_dead_partitions(
        g, pres.part, pres.k, capacity=60, dead_parts=dead_parts,
        refine_iters=0)
    assert n_evicted == int(w0[dead_parts].sum())
    w2 = partition_weights(g, part2, pres.k)
    assert (w2[dead_parts] == 0).all()
    assert (w2 <= 60).all()
    assert w2.sum() == w0.sum()
    kept = ~np.isin(pres.part, dead_parts)
    assert (part2[kept] == pres.part[kept]).all()
    # with the bounded refine pass, seams may shift but the vacated
    # partitions stay empty (the forbid mask) and capacity still holds
    part3, _ = evict_dead_partitions(
        g, pres.part, pres.k, capacity=60, dead_parts=dead_parts)
    w3 = partition_weights(g, part3, pres.k)
    assert (w3[dead_parts] == 0).all()
    assert (w3 <= 60).all() and w3.sum() == w0.sum()


def test_remap_deterministic_and_avoids_dead_cores(live_mapping):
    g, pres, placement, (t, src, dst) = live_mapping
    dead = np.zeros(16, dtype=bool)
    dead[[int(placement[1]), int(placement[4])]] = True
    kwargs = dict(capacity=60, seed=0, mapper_kwargs={"iters": 3000})
    inc1 = incremental_remap(g, pres.part, placement, dead, t, src, dst,
                             4, 4, k=pres.k, **kwargs)
    inc2 = incremental_remap(g, pres.part, placement, dead, t, src, dst,
                             4, 4, k=pres.k, **kwargs)
    np.testing.assert_array_equal(inc1.part, inc2.part)
    np.testing.assert_array_equal(inc1.placement, inc2.placement)
    scr1 = scratch_remap(g, pres.part, placement, dead, t, src, dst,
                         4, 4, **kwargs)
    scr2 = scratch_remap(g, pres.part, placement, dead, t, src, dst,
                         4, 4, **kwargs)
    np.testing.assert_array_equal(scr1.part, scr2.part)
    np.testing.assert_array_equal(scr1.placement, scr2.placement)
    for res in (inc1, scr1):
        w = partition_weights(g, res.part, res.k)
        cores = res.placement[:res.k][w > 0]
        assert not dead[cores].any(), res.strategy
        assert res.neurons_migrated > 0
    # the whole point: the incremental strategy moves (far) fewer neurons
    assert inc1.neurons_migrated <= scr1.neurons_migrated
    # at minimum, everything on the dead cores had to move
    displaced = int(g.vwgt[dead[np.asarray(placement)[pres.part]]].sum())
    assert inc1.neurons_migrated >= displaced


def test_remap_eviction_when_mesh_is_short_on_cores(live_mapping):
    g, pres, placement, (t, src, dst) = live_mapping
    w0 = partition_weights(g, pres.part, pres.k)
    n_real = int((w0 > 0).sum())
    # kill enough populated cores that the survivors cannot host one
    # partition each: eviction must dissolve exactly the excess
    n_dead = 16 - n_real + 2
    dead = np.zeros(16, dtype=bool)
    dead[placement[np.flatnonzero(w0 > 0)[:n_dead]]] = True
    assert n_real > 16 - int(dead.sum())
    res = incremental_remap(g, pres.part, placement, dead, t, src, dst,
                            4, 4, capacity=60, seed=0, k=pres.k,
                            mapper_kwargs={"iters": 2000})
    assert res.neurons_evicted > 0
    w2 = partition_weights(g, res.part, res.k)
    assert int((w2 > 0).sum()) <= 16 - int(dead.sum())
    assert not dead[res.placement[:res.k][w2 > 0]].any()


def test_remap_infeasible_degraded_mesh_names_deficit(live_mapping):
    g, pres, placement, (t, src, dst) = live_mapping
    dead = np.ones(16, dtype=bool)
    dead[:7] = False  # 7 live x 60 = 420 < 440 neurons
    with pytest.raises(ValueError, match=r"exceed 7 live cores.*by 20"):
        incremental_remap(g, pres.part, placement, dead, t, src, dst,
                          4, 4, capacity=60, k=pres.k)


def test_capacity_errors_name_the_deficit():
    with pytest.raises(ValueError, match=r"by 50.*needs >= 10 live cores"):
        check_degraded_capacity(100, 10, 5)
    check_degraded_capacity(100, 10, 10)  # exactly feasible: no raise
    g = fanout_snn_graph(100, fan=4, seed=0)
    with pytest.raises(ValueError, match=r"k=2 infeasible.*by 60.*need >= 5"):
        sneap_partition(g, capacity=20, k=2)
    with pytest.raises(ValueError, match="surviving partitions"):
        # vacating 3 of 5 exactly-full partitions cannot fit
        part = np.repeat(np.arange(5), 20)
        evict_dead_partitions(g, part, 5, capacity=20,
                              dead_parts=np.array([0, 1, 2]))


# ---------------------------------------------------------------------------
# failure detection


def test_heartbeat_detect_flags_exactly_the_dead_cores():
    dead = np.zeros(16, dtype=bool)
    dead[[3, 7]] = True
    monitor = HeartbeatMonitor(16)
    assert heartbeat_detect(monitor, dead) == [3, 7]
    healthy = HeartbeatMonitor(16)
    assert heartbeat_detect(healthy, np.zeros(16, dtype=bool)) == []


# ---------------------------------------------------------------------------
# scenario driver


@pytest.fixture(scope="module")
def smoke_profile():
    g = fanout_snn_graph(440, fan=8, seed=1)
    r = np.random.default_rng(3)
    n_spikes = 5000
    t = np.sort(r.integers(0, 40, n_spikes))
    src = r.integers(0, 440, n_spikes)
    dst = r.integers(0, 440, n_spikes)
    return ProfileResult(
        name="smoke", graph=g, trace_t=t, trace_src=src, trace_dst=dst,
        num_neurons=440, num_steps=40,
        fire_counts=np.bincount(src, minlength=440), seconds=0.0,
    )


_TOOLCHAIN = dict(mesh_w=4, mesh_h=4, capacity=60, seed=0,
                  partition_impl="vec", mapper_kwargs={"iters": 3000})


def test_toolchain_empty_schedule_bit_identical(smoke_profile):
    plain = run_toolchain(smoke_profile, **_TOOLCHAIN)
    empty = run_toolchain(smoke_profile, fault_schedule=FaultSchedule([]),
                          **_TOOLCHAIN)
    assert_stats_identical(plain.noc, empty.noc)
    assert plain.degradation is None
    assert empty.degradation is not None
    assert empty.degradation["remap_events"] == 0
    assert empty.summary()["spikes_dropped"] == 0


@pytest.mark.parametrize("strategy", ["incremental", "scratch"])
def test_toolchain_midtrace_core_failure_remaps(smoke_profile, strategy):
    baseline = run_toolchain(smoke_profile, **_TOOLCHAIN)
    victims = tuple(int(c) for c in baseline.mapping.placement[:2])
    sched = FaultSchedule([FaultEvent(20, "core", victims)])
    res = run_toolchain(smoke_profile, fault_schedule=sched,
                        remap_strategy=strategy, **_TOOLCHAIN)
    s = res.summary()
    assert s["remap_events"] == 1
    assert s["remap_strategy"] == strategy
    assert s["neurons_migrated"] > 0
    # spikes bound for the dead cores drop during the detection lag
    assert s["spikes_dropped"] > 0
    assert res.degradation["dead_cores"] == 2
    # conservation across the whole segmented replay
    n = res.noc
    assert (n.num_noc_spikes + n.num_local_spikes + n.spikes_dropped
            == smoke_profile.num_spikes)
    # degraded but alive: energy within a sane band of the baseline
    assert n.dynamic_energy_pj > 0
    assert res.phase_seconds["remap"] > 0


def test_toolchain_link_failure_reroutes_without_remap(smoke_profile):
    baseline = run_toolchain(smoke_profile, **_TOOLCHAIN)
    hot = int(np.argmax(baseline.noc.per_link_hops))
    sched = FaultSchedule([FaultEvent(10, "link", (hot,))])
    res = run_toolchain(smoke_profile, fault_schedule=sched, **_TOOLCHAIN)
    assert res.degradation["remap_events"] == 0
    assert res.noc.detour_hops > 0
    assert res.summary()["neurons_migrated"] == 0

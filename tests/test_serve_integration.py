"""End-to-end serving: prefill + decode loop through the jitted bundles."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import serve_batch


def _tiny(name):
    cfg = get_config(name).reduced()
    fields = dict(num_layers=2, d_model=64, vocab_size=128)
    if cfg.num_heads:
        fields.update(num_heads=2, num_kv_heads=min(cfg.num_kv_heads, 2),
                      head_dim=32)
    return dataclasses.replace(cfg, **fields)


@pytest.mark.parametrize("name", ["llama3-8b", "mamba2-780m"])
def test_serve_generates(name):
    cfg = _tiny(name)
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    res = serve_batch(cfg, mesh, prompts, gen_len=6, print_fn=lambda *_: None)
    assert res["tokens"].shape == (2, 6)
    assert (res["tokens"] >= 0).all() and (res["tokens"] < cfg.vocab_size).all()


def test_serve_greedy_deterministic():
    cfg = _tiny("llama3-8b")
    mesh = make_local_mesh()
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    a = serve_batch(cfg, mesh, prompts, gen_len=5, print_fn=lambda *_: None)
    b = serve_batch(cfg, mesh, prompts, gen_len=5, print_fn=lambda *_: None)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])

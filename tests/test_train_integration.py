"""End-to-end training: loss decreases, checkpoint restart is bit-exact."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop


def _tiny(name="llama3-8b"):
    cfg = get_config(name).reduced()
    return dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=2,
                               num_kv_heads=2, head_dim=32, d_ff=128,
                               vocab_size=128)


def test_loss_decreases():
    cfg = _tiny()
    mesh = make_local_mesh()
    out = train_loop(cfg, mesh, steps=80, batch=4, seq=32, lr=1e-2,
                     log_every=200, print_fn=lambda *_: None)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    # induction on the repeat task is slow at this scale; require a clear,
    # monotone-ish improvement rather than convergence
    assert last < first * 0.97, (first, last)


def test_checkpoint_restart_bit_exact(tmp_path):
    cfg = _tiny()
    mesh = make_local_mesh()
    # straight run to 20
    full = train_loop(cfg, mesh, steps=20, batch=2, seq=16, lr=1e-3,
                      log_every=100, print_fn=lambda *_: None)
    # same schedule (steps=20) but halt cleanly at 10 after a checkpoint,
    # then resume to 20
    train_loop(cfg, mesh, steps=20, batch=2, seq=16, lr=1e-3,
               ckpt_dir=tmp_path, ckpt_every=10, log_every=100, stop_at=10,
               print_fn=lambda *_: None)
    resumed = train_loop(cfg, mesh, steps=20, batch=2, seq=16, lr=1e-3,
                         ckpt_dir=tmp_path, resume=True, log_every=100,
                         print_fn=lambda *_: None)
    # deterministic data + optimizer: final params identical
    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_trains():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, num_experts=4,
                              moe_d_ff=32, vocab_size=128)
    mesh = make_local_mesh()
    out = train_loop(cfg, mesh, steps=20, batch=2, seq=32, lr=3e-3,
                     log_every=100, print_fn=lambda *_: None)
    assert np.isfinite(out["losses"]).all()
    assert np.mean(out["losses"][-3:]) < np.mean(out["losses"][:3])

"""End-to-end toolchain behaviour: the paper's qualitative claims hold on a
profiled SNN — SNEAP beats SpiNeMap beats SCO on cut/hop/latency/energy,
and SNEAP's partitioning phase is faster than greedy-KL at scale."""
import numpy as np
import pytest

from repro.core import run_toolchain
from repro.snn import make_snn, profile_snn


@pytest.fixture(scope="module")
def profile():
    return profile_snn(make_snn("smooth_320"), num_steps=300, seed=0)


@pytest.fixture(scope="module")
def results(profile):
    out = {}
    for method in ("sneap", "spinemap", "sco"):
        kwargs = {"iters": 4000} if method == "sneap" else {"iters": 40}
        out[method] = run_toolchain(profile, method=method, mesh_w=5, mesh_h=5,
                                    seed=0, mapper_kwargs=kwargs)
    return out


def test_partition_cut_ordering(results):
    assert results["sneap"].partition.edge_cut <= results["spinemap"].partition.edge_cut
    assert results["spinemap"].partition.edge_cut <= results["sco"].partition.edge_cut


def test_avg_hop_ordering(results):
    assert results["sneap"].mapping.avg_hop < results["sco"].mapping.avg_hop


def test_noc_metrics_ordering(results):
    s, sco = results["sneap"].noc, results["sco"].noc
    assert s.avg_latency < sco.avg_latency
    assert s.dynamic_energy_pj < sco.dynamic_energy_pj
    assert s.congestion_count <= sco.congestion_count
    assert s.edge_variance < sco.edge_variance


def test_all_partitions_fit_mesh(results):
    for r in results.values():
        assert r.partition.k <= 25
        assert len(set(r.mapping.placement.tolist())) == r.partition.k


def test_summary_reports_evaluate_seconds(results):
    for r in results.values():
        s = r.summary()
        assert s["evaluate_s"] == r.phase_seconds["evaluate"] > 0.0
        assert s["partition_s"] == r.phase_seconds["partition"]
        assert s["mapping_s"] == r.phase_seconds["mapping"]


def test_noc_kwargs_pass_through(profile, results):
    """``noc_kwargs`` mirrors partition_kwargs/mapper_kwargs: forwarded to
    simulate_noc and overriding the positional convenience args."""
    base = results["sneap"]
    ref = run_toolchain(profile, mesh_w=5, mesh_h=5, seed=0,
                        mapper_kwargs={"iters": 4000},
                        noc_kwargs={"engine": "ref"})
    # Identical partition/mapping; batched-vs-ref NoC replay parity.
    np.testing.assert_array_equal(ref.partition.part, base.partition.part)
    assert ref.noc.avg_latency == base.noc.avg_latency
    assert ref.noc.congestion_count == base.noc.congestion_count
    uncapped = run_toolchain(profile, mesh_w=5, mesh_h=5, seed=0,
                             mapper_kwargs={"iters": 4000},
                             noc_kwargs={"inject_capacity": 1_000_000,
                                         "link_capacity": 1_000_000})
    assert uncapped.noc.congestion_count == 0
    np.testing.assert_allclose(uncapped.noc.avg_latency,
                               uncapped.noc.avg_hop)


def test_sneap_partition_quality_per_time():
    """Paper Fig 4, honest form: the paper's 890x wall-time claim is against
    SpiNeMap's implementation; against our optimized greedy-KL (which
    converges early to a much worse local optimum) the faithful, testable
    invariant is *quality at comparable time* — multilevel reaches a far
    lower cut without costing more than a small constant factor of time."""
    prof = profile_snn(make_snn("smooth_1280"), num_steps=200, seed=0)
    sneap = run_toolchain(prof, method="sneap", mapper_kwargs={"iters": 200})
    spine = run_toolchain(prof, method="spinemap", mapper_kwargs={"iters": 5})
    assert sneap.partition.edge_cut < spine.partition.edge_cut * 0.5
    assert sneap.phase_seconds["partition"] < \
        max(spine.phase_seconds["partition"], 0.02) * 5

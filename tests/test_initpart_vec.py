"""Vectorized greedy region growing and the second-chance matching round."""
import numpy as np
import pytest

from repro.core.coarsen import heavy_edge_matching, heavy_edge_matching_vec
from repro.core.graph import partition_weights
from repro.core.initpart import greedy_region_growing

from conftest import random_graph


@pytest.mark.parametrize("impl", ["scalar", "vec", "auto"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_region_growing_valid_all_impls(impl, seed):
    g = random_graph(400, 0.03, seed=seed)
    k, cap = 12, 50
    part = greedy_region_growing(g, k, cap, np.random.default_rng(seed), impl=impl)
    assert part.min() >= 0 and part.max() < k
    assert (partition_weights(g, part, k) <= cap).all()


def test_region_growing_vec_tight_fit_falls_back():
    """k * capacity barely over total weight: the heap fallback must engage
    and still produce a valid packing."""
    g = random_graph(100, 0.05, seed=3)
    k, cap = 10, 10  # exactly n vertices of weight 1
    part = greedy_region_growing(g, k, cap, np.random.default_rng(0), impl="vec")
    assert (partition_weights(g, part, k) <= cap).all()


def test_region_growing_vec_more_regions_than_vertices():
    g = random_graph(50, 0.1, seed=6)
    k, cap = 80, 2
    part = greedy_region_growing(g, k, cap, np.random.default_rng(0), impl="vec")
    assert (partition_weights(g, part, k) <= cap).all()
    assert part.min() >= 0 and part.max() < k


def test_region_growing_rejects_unknown_impl():
    g = random_graph(20, 0.2, seed=4)
    with pytest.raises(ValueError):
        greedy_region_growing(g, 4, 10, np.random.default_rng(0), impl="simd")


def test_region_growing_infeasible_raises():
    g = random_graph(50, 0.1, seed=5)
    with pytest.raises(ValueError):
        greedy_region_growing(g, 2, 10, np.random.default_rng(0))


def test_second_chance_matching_closes_weight_gap():
    """The vec matching with second-chance proposals should land within a
    modest factor of the sequential heavy-edge matching's matched weight."""
    seq_w = vec_w = 0
    for seed in range(5):
        g = random_graph(300, 0.04, seed=seed)
        ids = np.arange(300)
        for name, match in (
            ("seq", heavy_edge_matching(g, np.random.default_rng(seed))),
            ("vec", heavy_edge_matching_vec(g, np.random.default_rng(seed))),
        ):
            assert np.array_equal(match[match], ids)  # involution
            matched = match != ids
            # weight of matched edges, counted once per pair
            w = 0
            for v in np.nonzero(matched)[0]:
                u = match[v]
                if v < u:
                    nbrs, wgts = g.neighbors(v)
                    w += int(wgts[list(nbrs).index(u)])
            if name == "seq":
                seq_w += w
            else:
                vec_w += w
    assert vec_w >= 0.9 * seq_w

"""Cross-engine mapping suite: scalar vs batched SA parity, the tree-hop
objective's incremental deltas against full recompute, and the tree
objective's total against the multicast replay's tree-link accounting —
plus the unified registry and the shared placement evaluator."""
import numpy as np
import pytest

from repro.core.hopcost import hop_distance_matrix, swap_delta_batch
from repro.core.mapping import (
    MAPPERS,
    OBJECTIVE_AWARE_MAPPERS,
    sa_search,
    tabu_search,
)
from repro.core.placecost import (
    PairwiseObjective,
    TreeHopObjective,
    evaluate_placement,
    make_objective,
)

from conftest import fanout_snn_graph


def _pairwise_instance(k=20, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 200, (k, k)).astype(np.float64)
    np.fill_diagonal(c, 0)
    return c, int(c.sum())


def _tree_instance(n=120, fan=8, k=12, cores=16, mesh_w=4, seed=0):
    """Fan-out SNN + random partition: (objective, traffic-like k, part)."""
    g = fanout_snn_graph(n, fan=fan, seed=seed)
    rng = np.random.default_rng(seed + 1)
    part = rng.integers(0, k, n)
    obj = TreeHopObjective(g.hyper, part, cores, mesh_w, cores // mesh_w)
    return g, part, obj


# ---------------------------------------------------------------------------
# Incremental deltas: exact against full recompute.

def test_pairwise_batch_delta_matches_scalar_formula():
    c, _ = _pairwise_instance()
    rng = np.random.default_rng(3)
    obj = PairwiseObjective(c, 25, 5)
    obj.attach(rng.permutation(25).astype(np.int64))
    aa = rng.integers(0, 25, 200)
    b0 = rng.integers(0, 24, 200)
    bb = np.where(b0 >= aa, b0 + 1, b0)
    dist = hop_distance_matrix(25, 5).astype(np.float64)
    ref = swap_delta_batch(obj.sym, obj._placement, dist, aa, bb)
    np.testing.assert_allclose(obj.swap_delta_batch(aa, bb), ref, atol=1e-9)
    # and both equal the true change of the full objective
    for a, b in zip(aa[:20], bb[:20]):
        p2 = obj._placement.copy()
        p2[a], p2[b] = p2[b], p2[a]
        np.testing.assert_allclose(
            obj.swap_delta(int(a), int(b)),
            obj.total(p2) - obj.total(obj._placement), atol=1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_tree_swap_delta_exact_against_recompute(seed):
    _, _, obj = _tree_instance(seed=seed)
    rng = np.random.default_rng(seed)
    placement = rng.permutation(16).astype(np.int64)
    obj.attach(placement)
    for _ in range(40):
        a, b = rng.choice(16, 2, replace=False)
        delta = obj.swap_delta(int(a), int(b))
        p2 = placement.copy()
        p2[a], p2[b] = p2[b], p2[a]
        np.testing.assert_allclose(
            delta, obj.total(p2) - obj.total(placement), atol=1e-9)


def test_tree_batch_delta_matches_scalar():
    _, _, obj = _tree_instance(seed=4)
    rng = np.random.default_rng(7)
    obj.attach(rng.permutation(16).astype(np.int64))
    aa = rng.integers(0, 16, 96)
    b0 = rng.integers(0, 15, 96)
    bb = np.where(b0 >= aa, b0 + 1, b0)
    batch = obj.swap_delta_batch(aa, bb)
    scalar = np.array([obj.swap_delta(int(a), int(b)) for a, b in zip(aa, bb)])
    np.testing.assert_allclose(batch, scalar, atol=1e-9)


@pytest.mark.parametrize("objective", ["pairwise", "tree"])
def test_apply_swaps_keeps_exact_total(objective):
    rng = np.random.default_rng(5)
    if objective == "pairwise":
        c, _ = _pairwise_instance(seed=5)
        obj = PairwiseObjective(c, 25, 5)
        nc = 25
    else:
        _, _, obj = _tree_instance(seed=5)
        nc = 16
    placement = rng.permutation(nc).astype(np.int64)
    obj.attach(placement)
    for m in (1, 3, 6):
        pos = rng.choice(nc, 2 * m, replace=False)
        total = obj.apply_swaps(pos.reshape(m, 2))
        np.testing.assert_allclose(total, obj.total(placement), atol=1e-9)


# ---------------------------------------------------------------------------
# Member-level aggregates: the synced incremental state must equal a
# from-scratch build after *arbitrary* accepted-swap sequences, and the
# aggregate-priced batch deltas must equal the scalar chain bitwise —
# every contribution is an integer tree-size change times an integer fire
# weight, so exact equality (not allclose) is the contract.

_AGG_TABLES = ("_cnt", "_rmin1", "_rmin2", "_rmax1", "_rmax2",
               "_cmin1", "_cmin2", "_cmax1", "_cmax2",
               "_hsp", "_vsp", "_srcx", "_srcy")


def _assert_aggregates_match_scratch(obj, hyper, part):
    """Synced tables, size cache and total == a fresh attach + sync."""
    obj._agg_sync()
    fresh = TreeHopObjective(hyper, part, obj.num_positions, obj.mesh_w,
                             obj.mesh_h)
    fresh.attach(obj._placement.copy())
    fresh._agg_sync()
    for name in _AGG_TABLES:
        np.testing.assert_array_equal(
            getattr(obj, name), getattr(fresh, name), err_msg=name)
    np.testing.assert_array_equal(obj._sizes, fresh._sizes)
    assert obj._total == fresh._total


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tree_aggregates_match_scratch_after_swap_sequences(seed):
    """Mixed scalar-pending and batched multi-pair commits leave the lazy
    aggregates identical to a from-scratch measurement at every sync."""
    g, part, obj = _tree_instance(seed=seed)
    rng = np.random.default_rng(100 + seed)
    obj.attach(rng.permutation(16).astype(np.int64))
    for step in range(24):
        if rng.random() < 0.5:
            a, b = rng.choice(16, 2, replace=False)
            d = obj.swap_delta(int(a), int(b))
            obj.apply_swaps(np.array([[a, b]]), total_delta=d)
        else:
            m = int(rng.integers(1, 4))
            pos = rng.choice(16, 2 * m, replace=False)
            obj.swap_delta_batch(pos[:m], pos[m:])  # builds/syncs lazily
            obj.apply_swaps(np.column_stack([pos[:m], pos[m:]]))
        if step % 6 == 5:
            _assert_aggregates_match_scratch(obj, g.hyper, part)
    _assert_aggregates_match_scratch(obj, g.hyper, part)


def test_tree_aggregates_directed_move_cases():
    """Directed metamorphic cases on a handmade mesh layout: dest-only
    moves (same and different column), horizontal/vertical extreme-member
    removals, and source moves — each committed swap's scalar delta,
    batch delta and aggregate state checked against full recompute."""
    from repro.core.graph import build_hypergraph

    n = 13
    src = np.array([0, 0, 0, 4, 4])
    dst = np.array([1, 2, 3, 8, 12])
    fire = np.zeros(n, dtype=np.int64)
    fire[0], fire[4] = 3, 5
    hyper = build_hypergraph(n, src, dst, fire)
    part = np.arange(n, dtype=np.int64)  # partition i == neuron i
    obj = TreeHopObjective(hyper, part, 16, 4, 4)
    # Identity placement on the 4x4 mesh: edge 0 = source core 0 with
    # members on row 0, columns 1..3 (horizontal extremes); edge 1 =
    # source core 4 with members down column 0, rows 2..3 (vertical).
    obj.attach(np.arange(16, dtype=np.int64))
    obj.swap_delta_batch(np.array([0]), np.array([1]))  # force build
    for a, b in [
        (3, 15),   # member-only: empties extreme column 3, same column re-entry
        (2, 13),   # member-only: horizontal extreme removal to a new column
        (12, 5),   # member-only: vertical extreme removal (row 3 of column 0)
        (0, 10),   # source-only move of edge 0
        (4, 3),    # source move landing on a member's old core
        (8, 12),   # member-member swap inside one edge (dest set unchanged)
    ]:
        before = obj.total(obj._placement)
        p2 = obj._placement.copy()
        p2[a], p2[b] = p2[b], p2[a]
        want = obj.total(p2) - before
        got_batch = obj.swap_delta_batch(np.array([a]), np.array([b]))[0]
        got_scalar = obj.swap_delta(a, b)
        assert got_scalar == want
        assert got_batch == want  # bitwise, not approximately
        obj.apply_swaps(np.array([[a, b]]), total_delta=got_scalar)
        _assert_aggregates_match_scratch(obj, hyper, part)


def test_tree_dedup_merges_congruent_patterns_and_stays_exact():
    """Hyperedges with identical (source partition, dest-partition set)
    merge at construction with summed fire weights, and the aggregates
    stay exact through swaps of the merged pattern's positions."""
    from repro.core.graph import build_hypergraph

    n = 8
    src = np.array([0, 0, 1, 1, 6, 6])
    dst = np.array([2, 3, 2, 3, 4, 5])
    fire = np.array([3, 5, 1, 1, 1, 1, 2, 1], dtype=np.int64)
    hyper = build_hypergraph(n, src, dst, fire)
    # Neurons 0 and 1 share partition 0 and the dest set {1, 2}: their
    # patterns are congruent under every placement and must merge.
    part = np.array([0, 0, 1, 2, 3, 4, 5, 5], dtype=np.int64)
    obj = TreeHopObjective(hyper, part, 9, 3, 3)
    assert obj.num_hyperedges == 2
    assert obj.tw.sum() == fire[0] + fire[1] + fire[6]
    rng = np.random.default_rng(11)
    obj.attach(rng.permutation(9).astype(np.int64))
    for _ in range(12):
        a, b = rng.choice(9, 2, replace=False)
        p2 = obj._placement.copy()
        p2[a], p2[b] = p2[b], p2[a]
        want = obj.total(p2) - obj.total(obj._placement)
        assert obj.swap_delta_batch(np.array([a]), np.array([b]))[0] == want
        d = obj.swap_delta(int(a), int(b))
        assert d == want
        obj.apply_swaps(np.array([[a, b]]), total_delta=d)
    _assert_aggregates_match_scratch(obj, hyper, part)


@pytest.mark.parametrize("seed", [0, 1])
def test_tree_batch_delta_bitwise_equals_scalar(seed):
    _, _, obj = _tree_instance(seed=seed)
    rng = np.random.default_rng(30 + seed)
    obj.attach(rng.permutation(16).astype(np.int64))
    for _ in range(4):
        aa = rng.integers(0, 16, 64)
        b0 = rng.integers(0, 15, 64)
        bb = np.where(b0 >= aa, b0 + 1, b0)
        batch = obj.swap_delta_batch(aa, bb)
        for i in range(64):
            assert batch[i] == obj.swap_delta(int(aa[i]), int(bb[i]))
        pos = rng.choice(16, 6, replace=False)
        obj.apply_swaps(pos.reshape(3, 2))  # mutate state between rounds


def test_tree_scalar_chain_never_builds_aggregates():
    """The propose-then-commit scalar chain must not pay for the lazy
    aggregate tables — they belong to the batched path alone."""
    _, _, obj = _tree_instance(seed=6)
    rng = np.random.default_rng(6)
    obj.attach(rng.permutation(16).astype(np.int64))
    for _ in range(10):
        a, b = rng.choice(16, 2, replace=False)
        d = obj.swap_delta(int(a), int(b))
        obj.apply_swaps(np.array([[a, b]]), total_delta=d)
    assert obj._cnt is None


# ---------------------------------------------------------------------------
# Tree objective == replay tree-link accounting.

def test_closed_form_tree_sizes_match_route_expansion():
    """`multicast_tree_sizes`'s span arithmetic counts exactly the distinct
    links of `multicast_tree_links`'s route-expansion union, on random
    meshes/groups including empty groups and dests equal to the source."""
    from repro.nocsim.xy import multicast_tree_links, multicast_tree_sizes

    rng = np.random.default_rng(0)
    for _ in range(150):
        w = int(rng.integers(2, 17))
        h = int(rng.integers(2, 17))
        ng = int(rng.integers(1, 24))
        m = int(rng.integers(1, 80))
        grp = np.sort(rng.integers(0, ng, m))
        gsrc = rng.integers(0, w * h, ng)
        src, dst = gsrc[grp], rng.integers(0, w * h, m)
        _, gid = multicast_tree_links(src, dst, grp, w, h)
        ref = np.bincount(gid, minlength=ng)
        got = multicast_tree_sizes(src, dst, grp, w, h, ng)
        np.testing.assert_array_equal(got, ref)

def test_tree_total_equals_replay_link_traversals():
    """For a fixed placement, the tree objective's total cost is exactly the
    multicast replay's per-link traversal sum: both charge one traversal
    per (firing, tree link) of the XY multicast tree."""
    from repro.nocsim import simulate_noc

    n, fan, k, w, h = 150, 6, 10, 4, 4
    rng = np.random.default_rng(11)
    src_syn = np.repeat(np.arange(n), fan)
    dst_syn = rng.integers(0, n, n * fan)
    fire = rng.integers(0, 15, n)
    from repro.core.graph import build_hypergraph
    hyper = build_hypergraph(n, src_syn, dst_syn, fire)
    part = rng.integers(0, k, n)
    placement = rng.permutation(w * h).astype(np.int64)[: k]

    # Expand the trace the profiler way: each firing of neuron i (one per
    # time step) transmits on every outgoing synapse of i.
    tt, ts, td = [], [], []
    for i in range(n):
        tgt = dst_syn[src_syn == i]
        for t in range(fire[i]):
            tt.append(np.full(tgt.shape[0], t))
            ts.append(np.full(tgt.shape[0], i))
            td.append(tgt)
    tt, ts, td = map(np.concatenate, (tt, ts, td))

    obj = TreeHopObjective(hyper, part, w * h, w, h)
    full_place = np.concatenate(
        [placement, np.setdiff1d(np.arange(w * h), placement)])
    stats = simulate_noc(tt, ts, td, part, placement, w, h,
                         mode="analytic", cast="multicast")
    assert int(round(obj.total(full_place))) == stats.link_traversals
    assert int(stats.per_link_hops.sum()) == stats.link_traversals
    # queued tree-fork engine keeps the same static accounting
    queued = simulate_noc(tt, ts, td, part, placement, w, h,
                          mode="queued", cast="multicast")
    assert queued.link_traversals == stats.link_traversals


# ---------------------------------------------------------------------------
# Scalar vs batched SA engines: quality parity at equal proposal budgets.

@pytest.mark.parametrize("objective", ["pairwise", "tree"])
def test_batched_sa_quality_matches_scalar(objective):
    tol_each, wins_needed = 1.10, 2
    ok = 0
    for seed in range(3):
        if objective == "pairwise":
            c, tl = _pairwise_instance(k=20, seed=seed)
            kwargs = {}
            nc, w = 25, 5
        else:
            g, part, obj = _tree_instance(seed=seed)
            c = np.zeros((12, 12))  # traffic only sizes the result
            # crude pairwise proxy for trace length normalization
            tl = max(int(obj.tw.sum()), 1)
            kwargs = {"objective": obj}
            nc, w = 16, 4
        scalar = sa_search(c, nc, w, tl, seed=seed, iters=8000, **kwargs)
        if objective == "tree":
            # objectives hold attached state; rebuild for an independent run
            _, _, obj2 = _tree_instance(seed=seed)
            kwargs = {"objective": obj2}
        vec = sa_search(c, nc, w, tl, seed=seed, iters=8000, impl="vec",
                        batch=32, **kwargs)
        s_cost = scalar.tree_hop if objective == "tree" else scalar.avg_hop
        v_cost = vec.tree_hop if objective == "tree" else vec.avg_hop
        if v_cost <= s_cost * tol_each + 1e-9:
            ok += 1
        assert len(set(vec.placement.tolist())) == vec.placement.shape[0]
    assert ok >= wins_needed, f"batched SA quality off on {3 - ok}/3 seeds"


def test_batched_sa_deterministic():
    c, tl = _pairwise_instance(seed=2)
    a = sa_search(c, 25, 5, tl, seed=7, iters=4000, impl="vec", batch=32)
    b = sa_search(c, 25, 5, tl, seed=7, iters=4000, impl="vec", batch=32)
    assert np.array_equal(a.placement, b.placement)
    assert a.avg_hop == b.avg_hop


def test_batched_sa_records_objective_units():
    """history/tree_hop/objective fields say what the samples mean."""
    c, tl = _pairwise_instance()
    r = sa_search(c, 25, 5, tl, seed=0, iters=2000, impl="vec")
    assert r.objective == "pairwise" and r.tree_hop is None
    _, _, obj = _tree_instance(seed=1)
    c12 = np.zeros((12, 12))
    rt = sa_search(c12, 16, 4, 100, seed=0, iters=2000, objective=obj)
    assert rt.objective == "tree"
    assert rt.tree_hop is not None
    # final history sample is the (exact) tree score, not the pairwise one
    np.testing.assert_allclose(rt.history[-1][1], rt.tree_hop, rtol=1e-9)


def test_kernel_score_backend_matches_numpy_deltas():
    """The MXU all-pairs scorer and the numpy batch produce the same deltas
    (f32 tolerance) for the same proposals."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.swap_delta import swap_deltas_pairs

    c, _ = _pairwise_instance(k=15, seed=3)
    rng = np.random.default_rng(0)
    nc, w = 25, 5
    obj = PairwiseObjective(c, nc, w)
    placement = rng.permutation(nc).astype(np.int64)
    obj.attach(placement)
    aa = rng.integers(0, nc, 64)
    b0 = rng.integers(0, nc - 1, 64)
    bb = np.where(b0 >= aa, b0 + 1, b0)
    ref = obj.swap_delta_batch(aa, bb)
    x = (np.arange(nc) % w).astype(np.float32)
    y = (np.arange(nc) // w).astype(np.float32)
    got = np.asarray(swap_deltas_pairs(
        jnp.asarray(obj.sym, jnp.float32),
        jnp.asarray(x[placement]), jnp.asarray(y[placement]),
        aa, bb, backend="jnp"))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)


def test_vec_sa_with_kernel_scoring_runs():
    c, tl = _pairwise_instance(seed=6)
    r = sa_search(c, 25, 5, tl, seed=0, iters=1500, impl="vec", batch=32,
                  score_backend="jnp")
    assert len(set(r.placement.tolist())) == 20
    # kernel scoring is pairwise-only
    _, _, obj = _tree_instance(seed=2)
    with pytest.raises(ValueError, match="pairwise"):
        sa_search(np.zeros((12, 12)), 16, 4, 10, iters=100, impl="vec",
                  objective=obj, score_backend="jnp")


# ---------------------------------------------------------------------------
# Tree-objective searches beat pairwise placement on the tree metric.

def test_tree_objective_search_lowers_tree_cost():
    g, part, obj = _tree_instance(n=200, fan=10, k=14, seed=9)
    c = np.zeros((14, 14))
    rng = np.random.default_rng(0)
    rand_costs = []
    for _ in range(10):
        rand_costs.append(obj.total(rng.permutation(16).astype(np.int64)))
    res = sa_search(c, 16, 4, 1, seed=0, iters=6000, objective=obj)
    assert res.tree_hop < np.mean(rand_costs)


def test_tabu_accepts_tree_objective():
    _, _, obj = _tree_instance(seed=3)
    res = tabu_search(np.zeros((12, 12)), 16, 4, 1, seed=0, iters=40,
                      candidates=48, objective=obj)
    assert res.objective == "tree" and res.tree_hop is not None
    assert len(set(res.placement.tolist())) == 12


# ---------------------------------------------------------------------------
# Registry and pipeline integration.

def test_registry_unifies_host_and_device_mappers():
    assert set(MAPPERS) == {"sa", "pso", "tabu", "sa_jax", "polish", "island"}
    assert OBJECTIVE_AWARE_MAPPERS == {"sa", "pso", "tabu"}


def test_polish_registry_entry_runs():
    pytest.importorskip("jax")
    c, tl = _pairwise_instance(k=12, seed=1)
    res = MAPPERS["polish"](c, 16, 4, tl, seed=0, backend="jnp")
    assert len(set(res.placement.tolist())) == 12
    rng = np.random.default_rng(1)
    rand = np.mean([
        PairwiseObjective(c, 16, 4).total(rng.permutation(16)) / tl
        for _ in range(10)
    ])
    assert res.avg_hop <= rand


def test_evaluate_placement_shared_path():
    """avg_hop from the shared evaluator == Algorithm 1 by hand; tree_hop
    == the tree objective total (same normalization)."""
    g, part, obj = _tree_instance(seed=8)
    from repro.core.hopcost import traffic_matrix
    rng = np.random.default_rng(2)
    # a toy trace over the graph's synapses
    tsrc = rng.integers(0, 120, 500)
    tdst = rng.integers(0, 120, 500)
    traffic = traffic_matrix(part, tsrc, tdst, 12)
    placement = rng.permutation(16).astype(np.int64)[:12]
    avg, tree = evaluate_placement(placement, traffic, 16, 4, 500,
                                   mesh_h=4, hyper=g.hyper, part=part)
    dist = hop_distance_matrix(16, 4)
    by_hand = float(
        (dist[placement[:, None], placement[None, :]] * traffic).sum() / 500)
    np.testing.assert_allclose(avg, by_hand, rtol=1e-12)
    full = np.concatenate([placement, np.setdiff1d(np.arange(16), placement)])
    np.testing.assert_allclose(tree, obj.total(full) / 500, rtol=1e-12)


def test_make_objective_validation():
    c, _ = _pairwise_instance()
    with pytest.raises(ValueError, match="hyper"):
        make_objective("tree", c, 25, 5)
    with pytest.raises(ValueError, match="torus"):
        g, part, _ = _tree_instance()
        make_objective("tree", c, 16, 4, hyper=g.hyper, part=part, torus=True)
    with pytest.raises(ValueError, match="unknown"):
        make_objective("voltage", c, 25, 5)


@pytest.fixture(scope="module")
def small_profile():
    from repro.snn import make_snn, profile_snn
    return profile_snn(make_snn("smooth_320"), num_steps=200, seed=0)


def test_run_toolchain_multicast_places_with_tree(small_profile):
    from repro.core import run_toolchain

    # Four seeds: both arms are finite-budget SA chains, and with the
    # SeedSequence-derived per-phase seeds a two-seed sample can draw an
    # unlucky pair (per-seed ratios span ~0.91-1.08 at this budget).
    tree_hops = {"tree": 0.0, "pairwise": 0.0}
    for seed in (0, 1, 2, 3):
        res = run_toolchain(small_profile, method="sneap", mesh_w=5, mesh_h=5,
                            capacity=16, seed=seed, cast="multicast",
                            mapper_kwargs={"iters": 12_000})
        assert res.place_objective == "tree"
        assert res.mapping.objective == "tree"
        s = res.summary()
        assert s["tree_hop"] is not None and s["tree_hop"] > 0
        assert s["place_objective"] == "tree"
        tree_hops["tree"] += s["tree_hop"]
        # explicit pairwise placement still reports tree_hop (evaluator)
        pw = run_toolchain(small_profile, method="sneap", mesh_w=5, mesh_h=5,
                           capacity=16, seed=seed, cast="multicast",
                           place_objective="pairwise",
                           mapper_kwargs={"iters": 12_000})
        assert pw.place_objective == "pairwise"
        assert pw.summary()["tree_hop"] is not None
        tree_hops["pairwise"] += pw.summary()["tree_hop"]
    # On the metric it optimizes, tree placement must not lose to pairwise
    # placement on average over seeds (both are finite-budget SA chains, so
    # single seeds can tie or flip within noise).
    assert tree_hops["tree"] <= tree_hops["pairwise"] * 1.02


def test_run_toolchain_sco_hop_comes_from_evaluator(small_profile):
    from repro.core import run_toolchain
    res = run_toolchain(small_profile, method="sco", mesh_w=5, mesh_h=5,
                        seed=0)
    assert np.isfinite(res.mapping.avg_hop)
    assert res.mapping.tree_hop is not None  # hypergraph is profiled
    # unicast default: reported avg_hop is Algorithm 1 over the placement
    from repro.core.hopcost import traffic_matrix
    traffic = traffic_matrix(res.partition.part, small_profile.trace_src,
                             small_profile.trace_dst, res.partition.k)
    avg, _ = evaluate_placement(res.mapping.placement, traffic, 25, 5,
                                int(traffic.sum()))
    np.testing.assert_allclose(res.mapping.avg_hop, avg, rtol=1e-12)


def test_run_toolchain_rejects_tree_for_device_mapper(small_profile):
    from repro.core import run_toolchain
    with pytest.raises(ValueError, match="cannot run the tree objective"):
        run_toolchain(small_profile, method="sneap", mesh_w=5, mesh_h=5,
                      capacity=16, seed=0, cast="multicast", mapper="polish",
                      place_objective="tree")
    # ... and sco, which runs no search at all, rejects it the same way
    # instead of silently placing sequentially.
    with pytest.raises(ValueError, match="sco"):
        run_toolchain(small_profile, method="sco", mesh_w=5, mesh_h=5,
                      seed=0, cast="multicast", place_objective="tree")

"""Multicast hypergraph objective: construction, comm_volume, exact λ-gains
through both refinement engines, contraction invariance, and the
objective="volume" partitioning path."""
import numpy as np
import pytest

from repro.core.graph import (
    build_graph,
    build_hypergraph,
    comm_volume,
    edge_cut,
    validate_partition,
    volume_degrees,
)
from repro.core.coarsen import coarsen
from repro.core.initpart import greedy_region_growing
from repro.core.partition import sneap_partition
from repro.core.refine import refine_level
from repro.core.refine_vec import refine_level_vec

from conftest import random_hypergraph as graph_with_hyper, random_snn_traffic


def brute_volume(hyper, part):
    vol = 0
    for e in range(hyper.num_hyperedges):
        mem = hyper.members(e)
        vol += int(hyper.hfire[e]) * (len({int(part[v]) for v in mem}) - 1)
    return vol


# ------------------------------------------------------- construction

def test_build_hypergraph_dedups_and_drops_self_pins():
    #   0 -> {1, 1, 2, 0}   (dup pin merged, self pin dropped)
    hg = build_hypergraph(3, src=[0, 0, 0, 0], dst=[1, 1, 2, 0],
                          fire_counts=np.array([5, 0, 0]))
    assert hg.num_hyperedges == 1
    assert hg.hsrc.tolist() == [0]
    s, e = hg.hxadj[0], hg.hxadj[1]
    assert sorted(hg.hpins[s:e].tolist()) == [1, 2]
    assert hg.hwgt[s:e].sum() == 15  # 2 synapses to 1, 1 to 2, 5 spikes each
    assert hg.hfire.tolist() == [5]


def test_comm_volume_matches_bruteforce():
    src, dst, fire = random_snn_traffic(40, 150, seed=1)
    hg = build_hypergraph(40, src, dst, fire)
    r = np.random.default_rng(2)
    for _ in range(10):
        part = r.integers(0, 5, 40)
        assert comm_volume(hg, part) == brute_volume(hg, part)


def test_comm_volume_equals_cut_on_unicast():
    """Every source has exactly one pin -> the two objectives coincide."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(n=st.integers(5, 50), k=st.integers(2, 5), seed=st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def check(n, k, seed):
        r = np.random.default_rng(seed)
        src = np.arange(n)
        dst = (src + r.integers(1, n, n)) % n  # one pin each, never self
        fire = r.integers(0, 20, n)
        g = build_graph(n, src, dst, fire[src])
        hg = build_hypergraph(n, src, dst, fire)
        part = r.integers(0, k, n)
        assert comm_volume(hg, part) == edge_cut(g, part)

    check()


# ----------------------------------------------------------- λ-gains

def test_volume_degrees_gains_exact():
    """D*[v, b] - D*[v, a] == vol(part) - vol(part with v -> b), exactly."""
    src, dst, fire = random_snn_traffic(35, 140, seed=3)
    hg = build_hypergraph(35, src, dst, fire)
    r = np.random.default_rng(4)
    k = 4
    for _ in range(5):
        part = r.integers(0, k, 35)
        D = volume_degrees(hg, part, k)
        base = brute_volume(hg, part)
        for v in r.integers(0, 35, 8):
            a = part[v]
            for b in range(k):
                moved = part.copy()
                moved[v] = b
                assert D[v, b] - D[v, a] == base - brute_volume(hg, moved)


def test_volume_degrees_row_subset_matches_full():
    src, dst, fire = random_snn_traffic(50, 200, seed=5)
    hg = build_hypergraph(50, src, dst, fire)
    part = np.random.default_rng(6).integers(0, 6, 50)
    full = volume_degrees(hg, part, 6)
    rows = np.array([0, 7, 13, 49])
    np.testing.assert_array_equal(volume_degrees(hg, part, 6, rows=rows),
                                  full[rows])


# ----------------------------------------------- contraction invariance

def test_comm_volume_invariant_under_contraction():
    g = graph_with_hyper(300, 1500, seed=7)
    rng = np.random.default_rng(8)
    levels = coarsen(g, rng, coarsen_to=32, impl="vec")
    assert len(levels) > 2
    part = rng.integers(0, 4, levels[-1].num_vertices)
    vols = []
    for coarse in reversed(levels):
        vols.append(comm_volume(coarse.hyper, part))
        if coarse.cmap is not None:
            part = part[coarse.cmap]
    assert len(set(vols)) == 1


def test_contraction_drops_internalized_pins():
    g = graph_with_hyper(200, 900, seed=9)
    levels = coarsen(g, np.random.default_rng(10), coarsen_to=32)
    assert levels[-1].hyper.num_pins < levels[0].hyper.num_pins


def test_contraction_conserves_delivered_spike_ledger():
    """hwgt (spikes delivered per pin) only shrinks by the deliveries that
    became core-local: a coarse level's ledger plus its internalized
    deliveries equals the fine level's total."""
    g = graph_with_hyper(200, 900, seed=13)
    levels = coarsen(g, np.random.default_rng(14), coarsen_to=32)
    for fine, coarse in zip(levels[:-1], levels[1:]):
        fh, ch, cmap = fine.hyper, coarse.hyper, coarse.cmap
        src_of_pin = fh.hsrc[fh.pin_edge].astype(np.int64)
        internal = cmap[fh.hpins.astype(np.int64)] == cmap[src_of_pin]
        assert int(ch.hwgt.sum()) == int(fh.hwgt[~internal].sum())


# ------------------------------------------------------- refinement

def _refine_case(seed, n=120, m=600, k=6, cap=30):
    g = graph_with_hyper(n, m, seed=seed, max_fire=9)
    rng = np.random.default_rng(seed)
    part = greedy_region_growing(g, k, cap, rng)
    return g, part, k, cap


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_refine_level_volume_exact_and_monotone(seed):
    g, part, k, cap = _refine_case(seed)
    v0 = comm_volume(g.hyper, part)
    refined, vol = refine_level(g, part.copy(), k, cap, objective="volume")
    assert vol == comm_volume(g.hyper, refined)  # incremental bookkeeping exact
    assert vol <= v0
    validate_partition(g, refined, k, cap)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_refine_level_vec_volume_exact_and_monotone(seed):
    g, part, k, cap = _refine_case(seed, n=400, m=2000, k=40, cap=12)
    v0 = comm_volume(g.hyper, part)
    refined, vol = refine_level_vec(g, part.copy(), k, cap, objective="volume")
    assert vol == comm_volume(g.hyper, refined)
    assert vol <= v0
    validate_partition(g, refined, k, cap)


def test_refine_level_vec_volume_kernel_interpret_parity():
    g, part, k, cap = _refine_case(3, n=200, m=1000, k=66, cap=5)
    pk, vk = refine_level_vec(g, part.copy(), k, cap, objective="volume",
                              use_kernel=True, kernel_backend="interpret")
    pn, vn = refine_level_vec(g, part.copy(), k, cap, objective="volume",
                              use_kernel=False)
    assert vk == comm_volume(g.hyper, pk)
    np.testing.assert_array_equal(pk, pn)
    assert vk == vn


def test_refine_rejects_volume_without_hyper():
    g = build_graph(10, [0, 1], [1, 2], [3, 3])
    with pytest.raises(ValueError):
        refine_level(g, np.zeros(10, dtype=np.int64), 2, 10, objective="volume")


# ------------------------------------------------------- partitioning

@pytest.mark.parametrize("impl", ["scalar", "vec"])
def test_sneap_partition_volume_objective(impl):
    g = graph_with_hyper(600, 4000, seed=11, max_fire=9)
    cut_res = sneap_partition(g, capacity=48, seed=0, impl=impl, objective="cut")
    vol_res = sneap_partition(g, capacity=48, seed=0, impl=impl, objective="volume")
    assert cut_res.objective == "cut" and vol_res.objective == "volume"
    # Both report both metrics; the volume run should not lose on its own metric.
    assert vol_res.comm_volume == comm_volume(g.hyper, vol_res.part)
    assert cut_res.comm_volume == comm_volume(g.hyper, cut_res.part)
    assert vol_res.comm_volume <= cut_res.comm_volume
    validate_partition(g, vol_res.part, vol_res.k, 48)


def test_sneap_partition_volume_requires_hyper():
    g = build_graph(50, np.arange(49), np.arange(1, 50), np.ones(49))
    with pytest.raises(ValueError):
        sneap_partition(g, capacity=10, objective="volume")


def test_greedy_kl_volume_objective():
    from repro.core.baselines import greedy_kl_partition

    g = graph_with_hyper(150, 800, seed=12, max_fire=9)
    res = greedy_kl_partition(g, capacity=30, seed=0, objective="volume")
    assert res.comm_volume == comm_volume(g.hyper, res.part)
    validate_partition(g, res.part, res.k, 30)

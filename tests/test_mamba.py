import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_decode_step, ssd_scan


def naive_ssd(x, dt, a, b_in, c_in):
    """Token-by-token linear recurrence oracle (fp64)."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    x, dt, b_in, c_in = [np.asarray(t, np.float64) for t in (x, dt, b_in, c_in)]
    a = np.asarray(a, np.float64)
    y = np.zeros((bsz, s, h, p))
    state = np.zeros((bsz, h, n, p))
    for t in range(s):
        dA = np.exp(dt[:, t] * a)  # (B,H)
        upd = np.einsum("bn,bh,bhp->bhnp", b_in[:, t], dt[:, t], x[:, t])
        state = state * dA[..., None, None] + upd
        y[:, t] = np.einsum("bn,bhnp->bhp", c_in[:, t], state)
    return y, state


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (24, 8), (7, 4)])
def test_ssd_scan_matches_naive(s, chunk):
    rng = np.random.default_rng(0)
    bsz, h, p, n = 2, 3, 4, 5
    x = rng.standard_normal((bsz, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, (bsz, s, h)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, h).astype(np.float32)
    b_in = rng.standard_normal((bsz, s, n)).astype(np.float32)
    c_in = rng.standard_normal((bsz, s, n)).astype(np.float32)
    y, final = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                        jnp.asarray(b_in), jnp.asarray(c_in), chunk)
    y_ref, state_ref = naive_ssd(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    if s % chunk == 0:  # final state only meaningful without trailing pad
        np.testing.assert_allclose(np.asarray(final), state_ref, rtol=2e-4,
                                   atol=2e-4)


def test_ssd_decode_continues_scan():
    """prefill via ssd_scan then one decode step == scan over s+1 tokens."""
    rng = np.random.default_rng(1)
    bsz, s, h, p, n, chunk = 1, 16, 2, 4, 3, 4
    x = rng.standard_normal((bsz, s + 1, h, p)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, (bsz, s + 1, h)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, h).astype(np.float32)
    b_in = rng.standard_normal((bsz, s + 1, n)).astype(np.float32)
    c_in = rng.standard_normal((bsz, s + 1, n)).astype(np.float32)

    y_full, _ = ssd_scan(*map(jnp.asarray, (x, dt, a, b_in, c_in)), chunk)
    _, state = ssd_scan(jnp.asarray(x[:, :s]), jnp.asarray(dt[:, :s]),
                        jnp.asarray(a), jnp.asarray(b_in[:, :s]),
                        jnp.asarray(c_in[:, :s]), chunk)
    y_dec, _ = ssd_decode_step(jnp.asarray(x[:, s:]), jnp.asarray(dt[:, s:]),
                               jnp.asarray(a), jnp.asarray(b_in[:, s:]),
                               jnp.asarray(c_in[:, s:]), state)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_decays():
    """With zero input, output decays towards zero (stability)."""
    bsz, s, h, p, n = 1, 8, 1, 2, 2
    x = np.zeros((bsz, s, h, p), np.float32)
    dt = np.full((bsz, s, h), 0.5, np.float32)
    a = np.array([-1.0], np.float32)
    b_in = np.ones((bsz, s, n), np.float32)
    c_in = np.ones((bsz, s, n), np.float32)
    state0 = jnp.ones((bsz, h, n, p))
    y, final = ssd_scan(*map(jnp.asarray, (x, dt, a, b_in, c_in)), 4,
                        init_state=state0)
    assert float(jnp.abs(final).max()) < 1.0

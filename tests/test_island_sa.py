"""Distributed mapping search: shard_map island SA on a multi-device mesh.

Runs in a subprocess so XLA_FLAGS can force 4 host devices without
polluting the single-device test session.
"""
import os
import subprocess
import sys
from pathlib import Path

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.core.mapping import sa_search
from repro.core.mapping_jax import island_sa

mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
k, cores, w = 12, 16, 4
c = rng.integers(0, 100, (k, k)).astype(np.float64)
np.fill_diagonal(c, 0)
trace_len = int(c.sum())
res = island_sa(c, cores, w, trace_len, mesh, rounds=2,
                iters_per_round=1500, chains_per_device=2, seed=0)
assert len(set(res.placement.tolist())) == k, "placement not injective"
ref = sa_search(c, cores, w, trace_len, seed=0, iters=6000)
assert res.avg_hop <= ref.avg_hop * 1.3, (res.avg_hop, ref.avg_hop)
print(f"ISLAND_OK hop={res.avg_hop:.4f} (serial {ref.avg_hop:.4f})")
"""


def test_island_sa_on_four_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ISLAND_OK" in out.stdout

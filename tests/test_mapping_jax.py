import jax.numpy as jnp
import numpy as np

from repro.core.hopcost import hop_distance_matrix
from repro.core.mapping import pad_traffic, sa_search
from repro.core.mapping_jax import greedy_polish, sa_search_jax


def _instance(k=15, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 100, (k, k)).astype(np.float64)
    np.fill_diagonal(c, 0)
    return c, int(c.sum())


def test_sa_jax_competitive_with_numpy_sa():
    c, trace_len = _instance()
    r_np = sa_search(c, 25, 5, trace_len, seed=0, iters=15_000)
    r_jax = sa_search_jax(c, 25, 5, trace_len, seed=0, iters=2_000, chains=4,
                          polish_backend="jnp")
    assert r_jax.avg_hop <= r_np.avg_hop * 1.15
    assert len(set(r_jax.placement.tolist())) == 15  # injective


def test_greedy_polish_reaches_swap_local_optimum():
    c, trace_len = _instance(seed=3)
    cores, w = 25, 5
    padded = pad_traffic(c, cores)
    sym = jnp.asarray(padded + padded.T, jnp.float32)
    rng = np.random.default_rng(0)
    placement = jnp.asarray(rng.permutation(cores))
    x = (jnp.arange(cores) % w).astype(jnp.float32)
    y = (jnp.arange(cores) // w).astype(jnp.float32)
    out, steps = greedy_polish(sym, placement, x, y, backend="jnp")
    # local optimum: no single swap improves
    dist = hop_distance_matrix(cores, w).astype(np.float64)
    sym_np = np.asarray(sym, np.float64)
    pl = np.asarray(out)
    from repro.core.hopcost import swap_delta
    best = min(swap_delta(sym_np, pl, dist, a, b)
               for a in range(cores) for b in range(a + 1, cores))
    assert best >= -1e-3
    assert steps >= 1


def test_polish_never_worsens():
    c, trace_len = _instance(seed=5)
    cores, w = 25, 5
    padded = pad_traffic(c, cores)
    sym_np = padded + padded.T
    dist = hop_distance_matrix(cores, w).astype(np.float64)
    rng = np.random.default_rng(1)
    placement = rng.permutation(cores)

    def cost(pl):
        return (dist[pl[:, None], pl[None, :]] * sym_np).sum() / 2

    before = cost(placement)
    out, _ = greedy_polish(jnp.asarray(sym_np, jnp.float32),
                           jnp.asarray(placement),
                           (jnp.arange(cores) % w).astype(jnp.float32),
                           (jnp.arange(cores) // w).astype(jnp.float32),
                           backend="jnp")
    after = cost(np.asarray(out))
    assert after <= before + 1e-6

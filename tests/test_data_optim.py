import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLMData
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.adamw import compress_grads


def test_data_deterministic_across_restarts():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=1)
    a = SyntheticLMData(cfg).batch(7)
    b = SyntheticLMData(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_sharding_partitions_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=2)
    full = SyntheticLMData(cfg).batch(3)["tokens"]
    parts = []
    for shard in range(4):
        c = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=2,
                       num_shards=4, shard=shard)
        parts.append(SyntheticLMData(c).batch(3)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_repeat_task_is_periodic():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=1, pattern_len=8)
    t = SyntheticLMData(cfg).batch(0)["tokens"][0]
    np.testing.assert_array_equal(t[:8], t[8:16])


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, warmup_steps=0, total_steps=200, weight_decay=0.0,
                      clip_norm=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, stats = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_applied():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=10)
    _, _, stats = adamw_update(params, {"w": jnp.full(3, 100.0)}, state, cfg)
    assert float(stats["grad_norm"]) > 1.0  # reported pre-clip


def test_compress_grads_small_error_and_unbiased():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (1000,))}
    out = compress_grads(g, key)
    err = jnp.abs(out["w"] - g["w"]).max()
    scale = jnp.abs(g["w"]).max() / 127
    assert float(err) <= float(scale)  # max error bounded by one quant step
    # stochastic rounding: mean error near zero
    assert abs(float((out["w"] - g["w"]).mean())) < float(scale) / 5

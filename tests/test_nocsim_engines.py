"""Metamorphic suite for the queued NoC replay engines.

Pins the contract between the batched two-tier replay (`repro.nocsim.replay`)
and the scalar reference engine (`sim._queued_ref`):

  (a) unicast: the batched engine reproduces every NoCStats field exactly,
      including congested windows, injection stagger, and both steppers;
  (b) with unbounded capacities the queued replay degenerates to the
      analytic latency (hops + injection stagger);
  (c) multicast tree-fork flits are strictly tighter than the replica
      upper bound per window, with static quantities (link loads, energy,
      hops, packet counts) unchanged;
  (d) every stat is invariant under permutation of trace records within a
      time step (canonical record order).
"""
from dataclasses import asdict

import numpy as np
import pytest

from repro.nocsim.sim import _queued_ref, simulate_noc  # noqa: F401
from repro.nocsim.stats import NoCStats
from repro.nocsim.xy import link_count, link_endpoints, link_ids_for_routes, next_link

from conftest import random_spike_trace


def stats_equal(a, b):
    da, db = asdict(a), asdict(b)
    mism = []
    for k in da:
        same = (np.array_equal(da[k], db[k]) if isinstance(da[k], np.ndarray)
                else da[k] == db[k])
        if not same:
            mism.append(k)
    return mism


# ------------------------------------------------------------ xy helpers


def test_route_steps_follow_stepwise_walk():
    rng = np.random.default_rng(0)
    w, h = 5, 4
    src = rng.integers(0, w * h, 50)
    dst = rng.integers(0, w * h, 50)
    ids, pkt, step = link_ids_for_routes(src, dst, w, h, with_steps=True)
    for p in range(50):
        order = np.argsort(step[pkt == p])
        mine = ids[pkt == p][order].tolist()
        cur, walked = np.array([src[p]]), []
        while cur[0] != dst[p]:
            cur, link = next_link(cur, np.array([dst[p]]), w, h)
            walked.append(int(link[0]))
        assert mine == walked  # in traversal order, not just as a multiset


def test_link_endpoints_roundtrip():
    for w, h in ((2, 2), (3, 5), (4, 4)):
        ids = np.arange(link_count(w, h))
        tail, head = link_endpoints(ids, w, h)
        nxt, link = next_link(tail, head, w, h)
        np.testing.assert_array_equal(nxt, head)  # one hop apart
        np.testing.assert_array_equal(link, ids)  # and it is this link


# ------------------------------------------------- (a) exact unicast parity


@pytest.mark.parametrize("link_capacity,inject_capacity", [
    (1, 256), (2, 256), (4, 3), (2, 1), (10_000, 256),
])
def test_batched_matches_ref_exactly(link_capacity, inject_capacity):
    for seed in range(4):
        t, src, dst, part, placement = random_spike_trace(
            seed=seed, n_spikes=1500, timesteps=8)
        ref = simulate_noc(t, src, dst, part, placement, 3, 3,
                           link_capacity=link_capacity,
                           inject_capacity=inject_capacity, engine="ref")
        new = simulate_noc(t, src, dst, part, placement, 3, 3,
                           link_capacity=link_capacity,
                           inject_capacity=inject_capacity, engine="batched")
        assert ref.congestion_count > 0 or link_capacity >= 1000 \
            or ref.avg_latency == ref.avg_hop
        assert stats_equal(ref, new) == [], (seed, link_capacity)


def test_congested_windows_actually_step():
    """The parity sweep must cover real congestion, not just fast paths."""
    t, src, dst, part, placement = random_spike_trace(
        seed=0, n_spikes=1500, timesteps=8)
    jam = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=1)
    assert jam.congestion_count > 0
    assert jam.avg_latency > jam.avg_hop


def test_jax_stepper_matches_ref():
    pytest.importorskip("jax")
    t, src, dst, part, placement = random_spike_trace(
        seed=1, n_spikes=800, timesteps=6)
    ref = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=1,
                       engine="ref")
    new = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=1,
                       engine="batched", stepper="jax")
    assert stats_equal(ref, new) == []


def test_screen_backends_do_not_change_results():
    pytest.importorskip("jax")
    t, src, dst, part, placement = random_spike_trace(
        seed=2, n_spikes=800, timesteps=6)
    base = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=2)
    for screen in ("linkload", "interpret"):
        got = simulate_noc(t, src, dst, part, placement, 3, 3,
                           link_capacity=2, screen=screen)
        assert stats_equal(base, got) == [], screen
    mc = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=2,
                      cast="multicast")
    mc2 = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=2,
                       cast="multicast", screen="linkload")
    assert stats_equal(mc, mc2) == []


def test_undrainable_window_raises():
    t, src, dst, part, placement = random_spike_trace(seed=0, n_spikes=200)
    for engine in ("ref", "batched"):
        with pytest.raises(RuntimeError):
            simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=0,
                         engine=engine, max_cycles_per_window=50)


# ------------------------------------- (b) unbounded -> analytic degeneracy


@pytest.mark.parametrize("engine", ["ref", "batched"])
def test_unbounded_capacities_degenerate_to_hops(engine):
    t, src, dst, part, placement = random_spike_trace(seed=3)
    q = simulate_noc(t, src, dst, part, placement, 3, 3,
                     link_capacity=10_000, inject_capacity=10_000,
                     engine=engine)
    a = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic")
    assert q.congestion_count == 0
    assert q.avg_latency == a.avg_latency  # == avg hop: zero queueing
    assert q.max_latency == a.max_latency
    assert q.total_hops == a.total_hops


@pytest.mark.parametrize("engine", ["ref", "batched"])
def test_unbounded_links_latency_is_hops_plus_stagger(engine):
    """With only the crossbar egress limit active, latency must equal
    hops + (injection rank // inject_capacity), computed independently."""
    inject_capacity = 2
    t, src, dst, part, placement = random_spike_trace(seed=4, n_spikes=600)
    q = simulate_noc(t, src, dst, part, placement, 3, 3,
                     link_capacity=10_000, inject_capacity=inject_capacity,
                     engine=engine)
    # Independent model over the canonical record order.
    core = placement[part]
    s, d = core[src], core[dst]
    order = np.lexsort((d, s, t))
    ts, ss, ds = t[order], s[order], d[order]
    remote = ss != ds
    ts, ss, ds = ts[remote], ss[remote], ds[remote]
    lat = []
    for step_t in np.unique(ts):
        m = ts == step_t
        ws, wd = ss[m], ds[m]
        rank = np.empty(ws.shape[0], dtype=int)
        for c in np.unique(ws):
            cm = np.flatnonzero(ws == c)
            rank[cm] = np.arange(cm.shape[0])
        hops = np.abs(ws % 3 - wd % 3) + np.abs(ws // 3 - wd // 3)
        lat.extend((rank // inject_capacity + hops).tolist())
    assert q.avg_latency == pytest.approx(np.mean(lat))
    assert q.max_latency == max(lat)
    assert q.congestion_count == 0


# ------------------------------ (c) tree-fork flits vs replica upper bound


def _per_window(t, src, dst, part, placement, **kw):
    """Run one simulate_noc per time step so window stats are observable."""
    out = []
    for step_t in np.unique(t):
        m = t == step_t
        out.append(simulate_noc(t[m], src[m], dst[m], part, placement, 3, 3,
                                **kw))
    return out


@pytest.mark.parametrize("link_capacity", [1, 2, 4])
def test_tree_latency_tighter_than_replica_per_window(link_capacity):
    t, src, dst, part, placement = random_spike_trace(
        seed=5, n_spikes=1200, timesteps=6)
    tree = _per_window(t, src, dst, part, placement, cast="multicast",
                       link_capacity=link_capacity, engine="batched")
    repl = _per_window(t, src, dst, part, placement, cast="multicast",
                       link_capacity=link_capacity, engine="ref")
    for wtree, wrepl in zip(tree, repl):
        assert wtree.avg_latency <= wrepl.avg_latency + 1e-12
        assert wtree.max_latency <= wrepl.max_latency
        assert wtree.congestion_count <= wrepl.congestion_count


def test_tree_static_quantities_match_replica_engine():
    """Tree accounting was already exact under the replica engine: link
    loads, traversals, energy, hops and packet counts must be unchanged."""
    t, src, dst, part, placement = random_spike_trace(seed=6, n_spikes=1500)
    for cap in (1, 4, 10_000):
        tree = simulate_noc(t, src, dst, part, placement, 3, 3,
                            link_capacity=cap, cast="multicast")
        repl = simulate_noc(t, src, dst, part, placement, 3, 3,
                            link_capacity=cap, cast="multicast", engine="ref")
        assert tree.cast == repl.cast == "multicast"
        assert tree.num_noc_spikes == repl.num_noc_spikes
        assert tree.num_local_spikes == repl.num_local_spikes
        assert tree.total_hops == repl.total_hops
        assert tree.link_traversals == repl.link_traversals
        np.testing.assert_array_equal(tree.per_link_hops, repl.per_link_hops)
        assert tree.dynamic_energy_pj == repl.dynamic_energy_pj
        assert tree.edge_variance == repl.edge_variance


def test_tree_engine_is_the_multicast_default():
    """ROADMAP item 2: queued multicast must not simulate replicas
    individually by default — the tree engine simulates at most as many
    flit-hops as there are tree links (< replica hop sum on shared
    prefixes) and is what a bare cast="multicast" call runs."""
    t, src, dst, part, placement = random_spike_trace(seed=7, n_spikes=1500)
    default = simulate_noc(t, src, dst, part, placement, 3, 3,
                           link_capacity=1, cast="multicast")
    tree = simulate_noc(t, src, dst, part, placement, 3, 3,
                        link_capacity=1, cast="multicast", engine="batched")
    repl = simulate_noc(t, src, dst, part, placement, 3, 3,
                        link_capacity=1, cast="multicast", engine="ref")
    assert stats_equal(default, tree) == []
    assert default.link_traversals < default.total_hops  # shared prefixes
    assert default.avg_latency < repl.avg_latency  # strictly tighter here


def test_tree_unbounded_matches_analytic_plus_stagger():
    t, src, dst, part, placement = random_spike_trace(seed=8)
    q = simulate_noc(t, src, dst, part, placement, 3, 3, cast="multicast",
                     link_capacity=10_000, inject_capacity=10_000)
    a = simulate_noc(t, src, dst, part, placement, 3, 3, cast="multicast",
                     mode="analytic")
    assert q.congestion_count == 0
    assert q.avg_latency == a.avg_latency
    assert q.cycles_simulated > 0


# ----------------------------------------- (d) permutation invariance


def _shuffle_within_steps(t, src, dst, seed):
    rng = np.random.default_rng(seed)
    idx = np.arange(t.shape[0])
    for v in np.unique(t):
        m = np.flatnonzero(t == v)
        idx[m] = rng.permutation(idx[m])
    return src[idx], dst[idx]


@pytest.mark.parametrize("cast", ["unicast", "multicast"])
@pytest.mark.parametrize("engine", ["ref", "batched"])
def test_stats_invariant_under_within_step_permutation(cast, engine):
    t, src, dst, part, placement = random_spike_trace(
        seed=9, n_spikes=1200, timesteps=6)
    base = simulate_noc(t, src, dst, part, placement, 3, 3, link_capacity=2,
                        inject_capacity=3, cast=cast, engine=engine)
    for pseed in (1, 2):
        s2, d2 = _shuffle_within_steps(t, src, dst, pseed)
        got = simulate_noc(t, s2, d2, part, placement, 3, 3, link_capacity=2,
                           inject_capacity=3, cast=cast, engine=engine)
        assert stats_equal(base, got) == [], (cast, engine, pseed)


# ------------------------------------------------------- stats plumbing


def test_per_link_hops_optional_and_guarded():
    s = NoCStats(avg_latency=0.0, max_latency=0, avg_hop=0.0, total_hops=0,
                 congestion_count=0, edge_variance=0.0, dynamic_energy_pj=0.0,
                 num_noc_spikes=0, num_local_spikes=0, cycles_simulated=0)
    assert s.per_link_hops is None
    assert s.max_link_load() == 0
    t, src, dst, part, placement = random_spike_trace(seed=10)
    q = simulate_noc(t, src, dst, part, placement, 3, 3)
    assert q.per_link_hops is not None
    assert q.max_link_load() == int(q.per_link_hops.max())


def test_simulate_noc_rejects_unknown_knobs():
    t, src, dst, part, placement = random_spike_trace(seed=0, n_spikes=50)
    for kw in ({"engine": "bogus"}, {"stepper": "bogus"}, {"screen": "bogus"},
               {"mode": "bogus"}, {"cast": "bogus"}):
        with pytest.raises(ValueError):
            simulate_noc(t, src, dst, part, placement, 3, 3, **kw)

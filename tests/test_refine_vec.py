"""Vec partitioning engine: matching/refinement invariants, scalar parity,
and the gain_eval kernel vs its reference (no hypothesis required)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coarsen import coarsen, heavy_edge_matching_vec
from repro.core.graph import edge_cut, partition_weights, validate_partition
from repro.core.partition import sneap_partition
from repro.core.refine_vec import partition_degrees, refine_level_vec, uncoarsen_vec
from repro.kernels.gain_eval import (
    gain_matrix,
    gain_matrix_ref,
    part_degrees,
    part_degrees_ref,
)

from conftest import random_graph

RNG = np.random.default_rng(0)


# ------------------------------------------------------ matching (vec)

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_matching_vec_symmetric(seed):
    g = random_graph(150, 0.08, seed=seed)
    match = heavy_edge_matching_vec(g, np.random.default_rng(seed))
    assert np.array_equal(match[match], np.arange(150))


def test_matching_vec_respects_cap():
    g = random_graph(100, 0.1, seed=3)
    # All vertex weights are 1, so a cap of 1 forbids every merge.
    match = heavy_edge_matching_vec(g, np.random.default_rng(0), max_vwgt=1)
    assert np.array_equal(match, np.arange(100))


def test_matching_vec_matches_most_vertices():
    g = random_graph(400, 0.05, seed=4)
    match = heavy_edge_matching_vec(g, np.random.default_rng(0))
    assert (match != np.arange(400)).mean() > 0.5


def test_coarsen_vec_preserves_totals():
    g = random_graph(300, 0.05, seed=5)
    levels = coarsen(g, np.random.default_rng(0), coarsen_to=32, impl="vec")
    sizes = [lv.num_vertices for lv in levels]
    assert sizes == sorted(sizes, reverse=True) and len(levels) > 1
    assert all(lv.total_vwgt == g.total_vwgt for lv in levels)


def test_coarsen_rejects_unknown_impl():
    g = random_graph(20, 0.2, seed=6)
    with pytest.raises(ValueError):
        coarsen(g, np.random.default_rng(0), impl="simd")


# -------------------------------------------------- refinement (vec)

def test_partition_degrees_matches_bincount():
    g = random_graph(120, 0.1, seed=7)
    k = 8
    part = RNG.integers(0, k, 120).astype(np.int64)
    src = np.repeat(np.arange(120), np.diff(g.xadj))
    ref = np.bincount(src * k + part[g.adjncy], weights=g.adjwgt,
                      minlength=120 * k).reshape(120, k)
    np.testing.assert_allclose(partition_degrees(g, part, k), ref)
    rows = np.array([3, 50, 117])
    np.testing.assert_allclose(partition_degrees(g, part, k, rows=rows), ref[rows])


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_refine_level_vec_invariants(seed):
    """Cut never increases, bookkeeping stays exact, capacity holds."""
    n, k, cap = 200, 10, 32
    g = random_graph(n, 0.06, seed=seed)
    part = (np.arange(n) % k).astype(np.int64)
    c0 = edge_cut(g, part)
    out, cut = refine_level_vec(g, part, k, cap)
    assert cut <= c0
    assert cut == edge_cut(g, out)
    assert (partition_weights(g, out, k) <= cap).all()
    # Input partition is not mutated.
    assert np.array_equal(part, (np.arange(n) % k))


def test_refine_level_vec_deterministic():
    g = random_graph(150, 0.08, seed=9)
    part = (np.arange(150) % 8).astype(np.int64)
    a, ca = refine_level_vec(g, part, 8, 32)
    b, cb = refine_level_vec(g, part, 8, 32)
    assert np.array_equal(a, b) and ca == cb


def test_refine_level_vec_kernel_path_parity():
    """Interpret-mode gain_eval path produces the numpy path's result."""
    g = random_graph(120, 0.1, seed=10)
    part = (np.arange(120) % 6).astype(np.int64)
    p_np, c_np = refine_level_vec(g, part, 6, 32, use_kernel=False)
    p_kn, c_kn = refine_level_vec(g, part, 6, 32, use_kernel=True,
                                  kernel_backend="interpret")
    assert np.array_equal(p_np, p_kn) and c_np == c_kn


def test_uncoarsen_vec_end_to_end():
    g = random_graph(300, 0.05, seed=11)
    k, cap = 12, 40
    rng = np.random.default_rng(0)
    levels = coarsen(g, rng, coarsen_to=4 * k, max_vwgt=cap // 3, impl="vec")
    from repro.core.initpart import greedy_region_growing

    coarse_part = greedy_region_growing(levels[-1], k, cap, rng)
    part, cut = uncoarsen_vec(levels, coarse_part, k, cap)
    validate_partition(g, part, k, cap)
    assert cut == edge_cut(g, part)


# --------------------------------------------- sneap_partition impl=vec

def test_sneap_vec_valid_and_deterministic():
    # n >= 1024 so the adaptive floor routes to the real vec engine.
    g = random_graph(1200, 0.015, seed=12)
    a = sneap_partition(g, capacity=64, seed=5, impl="vec")
    b = sneap_partition(g, capacity=64, seed=5, impl="vec")
    validate_partition(g, a.part, a.k, 64)
    assert np.array_equal(a.part, b.part) and a.edge_cut == b.edge_cut
    assert a.impl == "vec"


def test_sneap_vec_cut_near_scalar():
    g = random_graph(1500, 0.01, seed=13)
    s = sneap_partition(g, capacity=64, seed=0, impl="scalar")
    v = sneap_partition(g, capacity=64, seed=0, impl="vec")
    assert v.edge_cut <= 1.10 * s.edge_cut


def test_sneap_vec_small_graph_routes_scalar():
    g = random_graph(200, 0.08, seed=14)
    s = sneap_partition(g, capacity=32, seed=0, impl="scalar")
    v = sneap_partition(g, capacity=32, seed=0, impl="vec")
    assert np.array_equal(s.part, v.part) and s.edge_cut == v.edge_cut
    assert v.impl == "vec" and s.impl == "scalar"


def test_sneap_rejects_unknown_impl():
    g = random_graph(50, 0.2, seed=15)
    with pytest.raises(ValueError):
        sneap_partition(g, capacity=32, impl="gpu")


# ------------------------------------------------- gain_eval kernel

@pytest.mark.parametrize("n,k", [(16, 3), (130, 25), (256, 128), (300, 140)])
def test_gain_eval_degrees_interpret_vs_ref(n, k):
    a = RNG.integers(0, 50, (n, n)).astype(np.float32)
    a = a + a.T
    np.fill_diagonal(a, 0)
    p = RNG.integers(0, k, n).astype(np.int32)
    ref = part_degrees_ref(jnp.asarray(a), jnp.asarray(p), k)
    pal = part_degrees(jnp.asarray(a), jnp.asarray(p), k, backend="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5)


def test_gain_eval_gains_interpret_vs_ref():
    n, k = 90, 11
    a = RNG.integers(0, 30, (n, n)).astype(np.float32)
    a = a + a.T
    np.fill_diagonal(a, 0)
    p = RNG.integers(0, k, n).astype(np.int32)
    ref = gain_matrix_ref(jnp.asarray(a), jnp.asarray(p), k)
    pal = gain_matrix(jnp.asarray(a), jnp.asarray(p), k, backend="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5)
    # Own column is exactly zero: staying put gains nothing.
    np.testing.assert_array_equal(
        np.asarray(pal)[np.arange(n), p], np.zeros(n, np.float32)
    )


def test_gain_eval_degrees_match_csr_bincount():
    """The dense kernel agrees with the CSR partition_degrees used on CPU."""
    g = random_graph(80, 0.15, seed=16)
    k = 9
    part = RNG.integers(0, k, 80).astype(np.int64)
    adj = np.zeros((80, 80), dtype=np.float32)
    src = np.repeat(np.arange(80), np.diff(g.xadj))
    adj[src, g.adjncy] = g.adjwgt
    dense = part_degrees(jnp.asarray(adj), jnp.asarray(part, jnp.int32), k,
                         backend="interpret")
    np.testing.assert_allclose(np.asarray(dense), partition_degrees(g, part, k))

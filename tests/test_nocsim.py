import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip when absent
from hypothesis import given, settings, strategies as st

from repro.nocsim.sim import simulate_noc
from repro.nocsim.xy import link_count, link_ids_for_routes, next_link, route_hops


@given(w=st.integers(2, 8), h=st.integers(2, 8), seed=st.integers(0, 2000))
@settings(max_examples=30, deadline=None)
def test_route_expansion_matches_stepwise_walk(w, h, seed):
    rng = np.random.default_rng(seed)
    n = w * h
    src = rng.integers(0, n, 20)
    dst = rng.integers(0, n, 20)
    ids, pkt = link_ids_for_routes(src, dst, w, h)
    # stepwise walk must traverse exactly the same multiset of links
    for p in range(20):
        cur = np.array([src[p]])
        walked = []
        while cur[0] != dst[p]:
            nxt, link = next_link(cur, np.array([dst[p]]), w, h)
            walked.append(int(link[0]))
            cur = nxt
        mine = sorted(ids[pkt == p].tolist())
        assert mine == sorted(walked)
        assert len(walked) == route_hops(np.array([src[p]]), np.array([dst[p]]), w)[0]


def _tiny_trace(seed=0, n_spikes=200, timesteps=20, k=6, cores=9):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, k, 30)
    placement = rng.permutation(cores)[:k]
    t = np.sort(rng.integers(0, timesteps, n_spikes))
    src = rng.integers(0, 30, n_spikes)
    dst = rng.integers(0, 30, n_spikes)
    return t, src, dst, part, placement


def test_queued_no_congestion_latency_equals_hops():
    t, src, dst, part, placement = _tiny_trace()
    # capacity so high nothing ever queues
    s = simulate_noc(t, src, dst, part, placement, 3, 3,
                     link_capacity=10_000, mode="queued")
    assert s.congestion_count == 0
    np.testing.assert_allclose(s.avg_latency, s.avg_hop)


def test_queued_congestion_grows_latency():
    t, src, dst, part, placement = _tiny_trace(n_spikes=2000, timesteps=4)
    free = simulate_noc(t, src, dst, part, placement, 3, 3,
                        link_capacity=10_000, mode="queued")
    jam = simulate_noc(t, src, dst, part, placement, 3, 3,
                       link_capacity=1, mode="queued")
    assert jam.congestion_count > 0
    assert jam.avg_latency > free.avg_latency
    # conservation: hops identical regardless of queueing
    assert jam.total_hops == free.total_hops


def test_analytic_matches_queued_static_quantities():
    t, src, dst, part, placement = _tiny_trace(seed=3)
    a = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic")
    q = simulate_noc(t, src, dst, part, placement, 3, 3,
                     link_capacity=10_000, mode="queued")
    assert a.total_hops == q.total_hops
    assert a.num_noc_spikes == q.num_noc_spikes
    np.testing.assert_allclose(a.edge_variance, q.edge_variance)
    np.testing.assert_allclose(a.dynamic_energy_pj, q.dynamic_energy_pj)


def test_energy_proportional_to_hops():
    t, src, dst, part, placement = _tiny_trace(seed=4)
    s = simulate_noc(t, src, dst, part, placement, 3, 3, mode="analytic")
    from repro.nocsim.energy import EnergyModel
    e = EnergyModel()
    expected = s.total_hops * (e.router_pj_per_spike + e.link_pj_per_spike) \
        + s.num_local_spikes * e.local_pj_per_spike
    np.testing.assert_allclose(s.dynamic_energy_pj, expected)


def test_link_count():
    assert link_count(5, 5) == 2 * 4 * 5 + 2 * 5 * 4
    assert link_count(16, 16) == 2 * 15 * 16 * 2


# ---------------------------------------------------------------------------
# Span-aggregate helpers behind the tree-hop objective's incremental tables.


def test_span_to_closed_form_and_sentinels():
    from repro.nocsim.xy import span_to

    # origin inside [lo, hi], left of it, right of it
    assert span_to(2, 1, 5) == 4
    assert span_to(0, 1, 5) == 5
    assert span_to(7, 1, 5) == 6
    # the empty-interval sentinels (lo = dim, hi = -1) give span 0
    assert span_to(3, 8, -1) == 0
    # elementwise over arrays
    got = span_to(np.array([2, 0, 3]), np.array([1, 1, 8]), np.array([5, 5, -1]))
    np.testing.assert_array_equal(got, [4, 5, 0])


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_segment_extrema2_matches_bruteforce(seed):
    from repro.nocsim.xy import segment_extrema2

    rng = np.random.default_rng(seed)
    nseg, vmax = 50, 12
    m = int(rng.integers(1, 120))
    seg = rng.integers(0, nseg, m)
    val = rng.integers(0, vmax, m)
    useg, cnt, mn1, mn2, mx1, mx2 = segment_extrema2(seg, val, vmax)
    occupied = np.unique(seg)
    np.testing.assert_array_equal(useg, occupied)  # sparse, ascending ids
    for i, s in enumerate(occupied):
        v = np.sort(val[seg == s])
        assert cnt[i] == v.shape[0]
        assert mn1[i] == v[0] and mx1[i] == v[-1]
        if v.shape[0] >= 2:
            assert mn2[i] == v[1] and mx2[i] == v[-2]
        else:  # singleton: runner-up sentinels that span_to maps to 0
            assert mn2[i] == vmax and mx2[i] == -1


def test_segment_extrema2_empty_input():
    from repro.nocsim.xy import segment_extrema2

    out = segment_extrema2(np.empty(0, np.int64), np.empty(0, np.int64), 8)
    assert all(a.shape == (0,) for a in out)

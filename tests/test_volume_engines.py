"""Cross-engine volume refinement: scalar FM vs the vec engine's
incremental-Φ + plateau-walk path (metamorphic 5% parity, strict plateau
improvement), and the vec coarsening round-count regression pin on
mlp-shaped layered graphs."""
import numpy as np
import pytest

from repro.core.coarsen import coarsen
from repro.core.graph import comm_volume, validate_partition
from repro.core.initpart import greedy_region_growing
from repro.core.refine import refine_level
from repro.core.refine_vec import refine_level_vec, uncoarsen_vec

from conftest import fanout_snn_graph, layered_snn_graph


# Seeded sweep: (n, k, capacity, seed).  The n=1500 cases sit at
# n * k = 90_000 — far above the old `_SCALAR_NK_VOLUME` (1 << 15)
# delegation bound the vec engine used to hand such levels to the scalar
# FM queue under, so parity there is earned by the plateau walk, not by
# delegation.
SWEEP = [
    (400, 40, 12, 0),
    (400, 40, 12, 1),
    (400, 40, 12, 2),
    (400, 40, 12, 3),
    (1500, 60, 30, 0),
    (1500, 60, 30, 3),
]


@pytest.mark.parametrize("n,k,cap,seed", SWEEP)
def test_cross_engine_volume_within_5pct(n, k, cap, seed):
    """Metamorphic: both engines refine the same seeded partition of the
    same fan-out-heavy graph to comm_volume within 5% of each other."""
    g = fanout_snn_graph(n, seed=seed)
    rng = np.random.default_rng(seed)
    p0 = greedy_region_growing(g, k, cap, rng)
    ps, vs = refine_level(g, p0.copy(), k, cap, objective="volume")
    pv, vv = refine_level_vec(g, p0.copy(), k, cap, objective="volume")
    assert vs == comm_volume(g.hyper, ps)
    assert vv == comm_volume(g.hyper, pv)
    validate_partition(g, pv, k, cap)
    assert vv <= 1.05 * vs, f"vec {vv} vs scalar {vs} ({vv / vs:.3f}x)"
    # and the vec engine never does worse than its own input
    assert vv <= comm_volume(g.hyper, p0)


def test_plateau_walk_strictly_improves():
    """The Jet-style escape rounds must beat the walk-free vec engine on a
    case where positive-gain batches alone stall (capacity-tight fan-out),
    with the escape counter proving the walk actually fired."""
    g = fanout_snn_graph(400, seed=0)
    k, cap = 40, 12
    rng = np.random.default_rng(0)
    p0 = greedy_region_growing(g, k, cap, rng)
    _, v_nowalk = refine_level_vec(g, p0.copy(), k, cap, objective="volume",
                                   plateau_rounds=0)
    stats: dict = {}
    pw, v_walk = refine_level_vec(g, p0.copy(), k, cap, objective="volume",
                                  stats=stats)
    assert v_walk == comm_volume(g.hyper, pw)
    assert stats["escapes"] > 0
    assert v_walk < v_nowalk, (v_walk, v_nowalk)


def test_plateau_walk_never_regresses():
    """Best-seen rollback: with the walk on, the result is never worse
    than with it off, across seeds (negative-gain escapes must not leak)."""
    for seed in range(3):
        g = fanout_snn_graph(250, seed=seed)
        k, cap = 25, 12
        rng = np.random.default_rng(seed)
        p0 = greedy_region_growing(g, k, cap, rng)
        _, v_off = refine_level_vec(g, p0.copy(), k, cap, objective="volume",
                                    plateau_rounds=0)
        _, v_on = refine_level_vec(g, p0.copy(), k, cap, objective="volume")
        assert v_on <= v_off


def test_uncoarsen_vec_volume_never_delegates_to_scalar(monkeypatch):
    """Volume levels must run the vec refiner even at small n*k (the old
    `_SCALAR_NK_VOLUME` delegation is gone — the λ-gain FM queue is slowest
    exactly where it used to be delegated to)."""
    import repro.core.refine_vec as rv

    def boom(*a, **kw):
        raise AssertionError("volume level delegated to scalar refine_level")

    monkeypatch.setattr(rv, "refine_level", boom)
    g = fanout_snn_graph(300, seed=1)
    k, cap = 12, 32
    rng = np.random.default_rng(1)
    levels = coarsen(g, rng, coarsen_to=4 * k, max_vwgt=cap // 3, impl="vec")
    coarse_part = greedy_region_growing(levels[-1], k, cap, rng)
    part, vol = uncoarsen_vec(levels, coarse_part, k, cap, objective="volume")
    assert vol == comm_volume(g.hyper, part)
    # ... while cut levels of the same shape still delegate.
    with pytest.raises(AssertionError, match="delegated"):
        uncoarsen_vec(levels, coarse_part, k, cap, objective="cut")


def test_vec_coarsening_rounds_on_layered_graph():
    """Regression pin (ROADMAP: degree-aware role-split candidates): on an
    mlp_2048-shaped dense equal-weight layered graph at ~2k vertices, the
    vec engine's coarsening round count (levels built) must stay within 2x
    of the scalar engine's."""
    g = layered_snn_graph((512, 512, 512, 512), seed=0)
    assert g.num_vertices == 2048
    scalar_levels = coarsen(g, np.random.default_rng(0), coarsen_to=128,
                            max_vwgt=85, impl="scalar", contract_hyper=False)
    vec_levels = coarsen(g, np.random.default_rng(0), coarsen_to=128,
                         max_vwgt=85, impl="vec", contract_hyper=False)
    scalar_rounds = len(scalar_levels) - 1
    vec_rounds = len(vec_levels) - 1
    assert vec_levels[-1].num_vertices <= 2 * scalar_levels[-1].num_vertices
    assert vec_rounds <= 2 * scalar_rounds, (vec_rounds, scalar_rounds)

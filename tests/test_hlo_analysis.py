"""Unit tests for the post-SPMD HLO collective-byte parser."""
from repro.launch.hlo_analysis import collective_bytes, op_census

SAMPLE = """
HloModule jit_step

%fused_computation.1 { ... }

ENTRY %main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %fusion.1 = bf16[16,1024]{1,0} fusion(%p0), kind=kLoop
  %all-gather.1 = bf16[256,1024]{1,0} all-gather(%fusion.1), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %convert.2 = f32[16,1024]{1,0} convert(%p0)
  %all-reduce.7 = f32[16,1024]{1,0} all-reduce(%convert.2), channel_id=2, to_apply=%add
  %ar-start = f32[16,1024]{1,0} all-reduce-start(%convert.2), channel_id=3
  %ar-done = f32[16,1024]{1,0} all-reduce-done(%ar-start)
  %cp.1 = bf16[8,1,128]{2,1,0} collective-permute(%fusion.1), source_target_pairs={{0,1}}
  ROOT %t = (bf16[256,1024]{1,0}) tuple(%all-gather.1)
}
"""


def test_collective_bytes_sums_operands():
    out = collective_bytes(SAMPLE)
    # all-gather operand: bf16[16,1024] = 32768 B
    assert out["all-gather"] == 16 * 1024 * 2
    # two all-reduces (plain + start; done not double counted): f32[16,1024] x2
    assert out["all-reduce"] == 2 * 16 * 1024 * 4
    # collective-permute operand is the bf16 fusion [16,1024] (named ref)
    assert out["collective-permute"] == 16 * 1024 * 2
    assert out["_count"] == 4


def test_op_census_counts():
    c = op_census(SAMPLE)
    assert c["all-gather"] == 1
    assert c["fusion"] == 1
    assert c.get("all-reduce", 0) == 2  # plain + start

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attend, init_kv_cache, mha, update_kv_cache


def naive_attention(q, k, v, causal=True, window=None):
    """Reference: full-matrix softmax with KV-head repetition."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_mha_matches_naive(h, kvh, chunk):
    rng = jax.random.PRNGKey(0)
    b, s, hd = 2, 33, 16  # odd length exercises padding
    q = jax.random.normal(rng, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = mha(q, k, v, pos, pos, causal=True, kv_chunk=chunk)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_mha_sliding_window():
    rng = jax.random.PRNGKey(3)
    b, s, h, hd, w = 1, 48, 2, 8, 8
    q = jax.random.normal(rng, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = mha(q, k, v, pos, pos, causal=True, window=w, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_mha_cross_no_causal():
    b, sq, skv, h, hd = 2, 5, 11, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(6), (b, sq, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, skv, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, skv, h, hd))
    qpos = jnp.zeros((b, sq), jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(skv), (b, skv))
    out = mha(q, k, v, qpos, kpos, causal=False, kv_chunk=4)
    ref = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_decode_matches_mha_last_position():
    b, s, h, kvh, hd = 2, 12, 4, 2, 8
    q_all = jax.random.normal(jax.random.PRNGKey(9), (b, s, h, hd))
    k_all = jax.random.normal(jax.random.PRNGKey(10), (b, s, kvh, hd))
    v_all = jax.random.normal(jax.random.PRNGKey(11), (b, s, kvh, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = mha(q_all, k_all, v_all, pos, pos, causal=True, kv_chunk=4)
    cache = init_kv_cache(b, s, kvh, hd, jnp.float32)
    cache = update_kv_cache(cache, k_all, v_all, pos)
    dec = decode_attend(q_all[:, -1:], cache["k"], cache["v"], cache["pos"],
                        pos[:, -1:])
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-5)


def test_ring_cache_keeps_last_window():
    b, kvh, hd, w = 1, 1, 4, 8
    cache = init_kv_cache(b, w, kvh, hd, jnp.float32)
    for t in range(20):
        k_new = jnp.full((b, 1, kvh, hd), float(t))
        cache = update_kv_cache(cache, k_new, k_new, jnp.full((b, 1), t, jnp.int32))
    kept = sorted(np.asarray(cache["pos"])[0].tolist())
    assert kept == list(range(12, 20))


def test_prefill_longer_than_ring_cache():
    b, s, kvh, hd, w = 1, 20, 1, 4, 8
    k_all = jnp.arange(s, dtype=jnp.float32).reshape(1, s, 1, 1) * jnp.ones((b, s, kvh, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    cache = init_kv_cache(b, w, kvh, hd, jnp.float32)
    cache = update_kv_cache(cache, k_all, k_all, pos)
    kept = sorted(np.asarray(cache["pos"])[0].tolist())
    assert kept == list(range(12, 20))  # newest entries won deterministically

"""Spike-trace primitives shared by the partitioning and NoC layers.

Lives outside both ``repro.core`` and ``repro.nocsim`` so the multicast
packet identity has a single definition without either package importing
the other.
"""
from __future__ import annotations

import numpy as np

__all__ = ["dedupe_firings"]


def dedupe_firings(
    trace_t: np.ndarray,
    trace_src: np.ndarray,
    dest: np.ndarray,
    num_neurons: int,
    num_dest: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One multicast packet per distinct (firing = (t, src neuron), destination).

    The single definition of the multicast packet identity, shared by the
    hop-cost traffic matrix (destinations are partitions) and the NoC
    replay (destinations are cores) so the two traffic models cannot
    drift.  ``num_dest`` is the destination id space.  Returns the
    deduplicated (t, src, dest, firing_id) arrays; ``firing_id`` is equal
    for all packets replicated from one firing.
    """
    key = ((trace_t.astype(np.int64) * num_neurons + trace_src.astype(np.int64))
           * num_dest + dest.astype(np.int64))
    uniq = np.unique(key)
    firing = uniq // num_dest
    return ((firing // num_neurons).astype(trace_t.dtype),
            firing % num_neurons, uniq % num_dest, firing)

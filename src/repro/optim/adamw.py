"""AdamW with warmup+cosine schedule, global-norm clipping, and optional
int8 gradient compression (stochastic rounding) for the cross-replica
reduce — pure pytree implementation, no optax dependency.

Optimizer moments are kept in fp32 regardless of parameter dtype; the
sharding planner places them with ZeRO-1 data-axis sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "compress_grads"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0
    compress_int8: bool = False
    # "float32" (default, exact) or "bfloat16": halves optimizer HBM traffic
    # and footprint; update math still runs in fp32 (§Perf lever A3/B3).
    moment_dtype: str = "float32"


def init_opt_state(params, moment_dtype: str = "float32") -> dict:
    dt = jnp.bfloat16 if moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def compress_grads(grads, key):
    """Simulated int8 all-reduce compression: per-tensor absmax scaling with
    stochastic rounding, quantize -> dequantize.  On hardware the int8
    tensors ride the wire (4x fewer gradient bytes on the data axis); the
    numerics here are bit-identical to that path."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def q(g, k):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        scaled = g32 / scale
        noise = jax.random.uniform(k, g.shape, jnp.float32) - 0.5
        q8 = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
        return q8.astype(jnp.float32) * scale

    return jax.tree.unflatten(treedef, [q(g, k) for g, k in zip(leaves, keys)])


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    # Global-norm clip in fp32.
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m_new.astype(m.dtype), v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }

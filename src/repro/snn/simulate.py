"""Profiling phase: simulate an SNN, emit its graph + spike trace (paper §3.2).

The simulator raster is post-processed into the three artifacts the rest
of the toolchain consumes:
  * the spike-weighted undirected synapse graph G(N, S) — edge weight =
    number of spikes communicated on that synapse over the window,
  * the multicast hypergraph H(N, E) attached as ``graph.hyper`` — one
    hyperedge per firing neuron holding its destination pin set with
    per-pin spike counts (the ``objective="volume"`` partitioning metric
    and the multicast NoC replay both derive from it), and
  * the spike trace — (time_step, src_neuron, dst_neuron) per transmission
    (a neuron firing with fan-out f contributes f trace records).

If the topology declares a `target_spikes` count (Table 1), the trace is
truncated at the time step where the cumulative transmission count first
reaches the target, so benchmark traffic volumes match the paper.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, Hypergraph, build_graph, build_hypergraph

from .lif import LIFParams, lif_run
from .topology import SNNTopology

__all__ = ["ProfileResult", "profile_snn"]


@dataclass
class ProfileResult:
    name: str
    graph: Graph
    trace_t: np.ndarray  # (S,) int32 time step per transmission
    trace_src: np.ndarray  # (S,) int32 source neuron
    trace_dst: np.ndarray  # (S,) int32 destination neuron
    num_neurons: int
    num_steps: int
    fire_counts: np.ndarray  # (N,) firings per neuron over the window
    seconds: float

    @property
    def num_spikes(self) -> int:
        return int(self.trace_t.shape[0])

    @property
    def hyper(self) -> "Hypergraph | None":
        """Multicast hypergraph view of the profiled traffic."""
        return self.graph.hyper


def _expand_trace(
    raster: np.ndarray, xadj: np.ndarray, adjncy: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Expand a (T, N) raster into per-synapse transmission records."""
    fired_t, fired_i = np.nonzero(raster)
    out_deg = np.diff(xadj)
    counts = out_deg[fired_i]
    total = int(counts.sum())
    trace_t = np.repeat(fired_t, counts).astype(np.int32)
    trace_src = np.repeat(fired_i, counts).astype(np.int32)
    # Gather each firing neuron's adjacency slice without a Python loop.
    starts = xadj[fired_i]
    cum = np.concatenate([[0], np.cumsum(counts)])
    idx = np.arange(total) - np.repeat(cum[:-1], counts) + np.repeat(starts, counts)
    trace_dst = adjncy[idx].astype(np.int32)
    return trace_t, trace_src, trace_dst


def _synapse_csr(n: int, src: np.ndarray, dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    xadj = np.zeros(n + 1, dtype=np.int64)
    np.add.at(xadj, src + 1, 1)
    return np.cumsum(xadj), dst.astype(np.int64)


def _cache_key(topo: SNNTopology, num_steps: int, seed: int, params: LIFParams) -> str:
    """Content hash of everything that shapes the profiled trace.

    The key covers the synapse lists and weights plus every trace-shaping
    scalar (``input_size``/``input_rate``/``input_amp``/``target_spikes``),
    not just the topology's name and size — rebuilding a same-name,
    same-size topology with different connectivity must *miss* the cache,
    never return another topology's stale profile.  "cc" marks the
    content-keyed cache layout revision (supersedes "hg"; older files
    simply miss and are regenerated).
    """
    h = hashlib.sha1(
        f"{topo.name}/{num_steps}/{seed}/{params}/{topo.num_neurons}/"
        f"{topo.input_size}/{topo.input_rate}/{topo.input_amp}/"
        f"{topo.target_spikes}/cc".encode()
    )
    h.update(np.ascontiguousarray(topo.syn_src, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(topo.syn_dst, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(topo.weights, dtype=np.float32).tobytes())
    return h.hexdigest()[:16]


def profile_snn(
    topo: SNNTopology,
    num_steps: int = 1200,
    seed: int = 0,
    params: LIFParams = LIFParams(),
    use_pallas: bool = False,
    cache_dir: str | Path | None = None,
) -> ProfileResult:
    """Run the LIF simulation and extract graph + trace."""
    key = None
    if cache_dir is not None:
        h = _cache_key(topo, num_steps, seed, params)
        key = Path(cache_dir) / f"profile_{topo.name}_{h}.npz"
        if key.exists():
            z = np.load(key, allow_pickle=False)
            graph = Graph(z["xadj"], z["adjncy"], z["adjwgt"], z["vwgt"])
            graph.hyper = Hypergraph(
                hxadj=z["hxadj"], hpins=z["hpins"], hwgt=z["hwgt"],
                hsrc=z["hsrc"], hfire=z["hfire"],
                num_vertices=int(z["num_neurons"]),
            )
            return ProfileResult(
                name=topo.name, graph=graph, trace_t=z["trace_t"],
                trace_src=z["trace_src"], trace_dst=z["trace_dst"],
                num_neurons=int(z["num_neurons"]), num_steps=int(z["num_steps"]),
                fire_counts=z["fire_counts"], seconds=float(z["seconds"]),
            )

    t0 = time.perf_counter()
    n = topo.num_neurons
    rng = np.random.default_rng(seed)
    drive = np.zeros((num_steps, n), dtype=np.float32)
    events = rng.random((num_steps, topo.input_size)) < topo.input_rate
    drive[:, : topo.input_size] = events * topo.input_amp

    raster = lif_run(jnp.asarray(topo.weights), jnp.asarray(drive), params,
                     use_pallas=use_pallas, seed=seed)

    xadj, adjncy = _synapse_csr(n, topo.syn_src.astype(np.int64), topo.syn_dst.astype(np.int64))
    trace_t, trace_src, trace_dst = _expand_trace(raster, xadj, adjncy)

    # Truncate at the step where cumulative transmissions reach Table 1's count.
    if topo.target_spikes is not None and trace_t.shape[0] > topo.target_spikes:
        step_end = int(trace_t[topo.target_spikes - 1])
        keep = trace_t <= step_end
        trace_t, trace_src, trace_dst = trace_t[keep], trace_src[keep], trace_dst[keep]
        raster = raster[: step_end + 1]
        num_steps = step_end + 1

    fire_counts = raster.sum(axis=0).astype(np.int64)
    # Synapse graph: each directed synapse (i -> j) carried fire_counts[i] spikes.
    graph = build_graph(
        n,
        src=topo.syn_src.astype(np.int64),
        dst=topo.syn_dst.astype(np.int64),
        weight=fire_counts[topo.syn_src.astype(np.int64)],
    )
    # Multicast view: one hyperedge per source with its destination pin set.
    graph.hyper = build_hypergraph(
        n, topo.syn_src.astype(np.int64), topo.syn_dst.astype(np.int64),
        fire_counts,
    )
    seconds = time.perf_counter() - t0
    result = ProfileResult(
        name=topo.name, graph=graph, trace_t=trace_t, trace_src=trace_src,
        trace_dst=trace_dst, num_neurons=n, num_steps=num_steps,
        fire_counts=fire_counts, seconds=seconds,
    )
    if key is not None:
        key.parent.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(
            key, xadj=graph.xadj, adjncy=graph.adjncy, adjwgt=graph.adjwgt,
            vwgt=graph.vwgt, trace_t=trace_t, trace_src=trace_src,
            trace_dst=trace_dst, num_neurons=n, num_steps=num_steps,
            fire_counts=fire_counts, seconds=seconds,
            hxadj=graph.hyper.hxadj, hpins=graph.hyper.hpins,
            hwgt=graph.hyper.hwgt, hsrc=graph.hyper.hsrc,
            hfire=graph.hyper.hfire,
        )
    return result

"""Leaky integrate-and-fire dynamics, vectorized over neurons and time.

The event-driven loop of CARLsim becomes a dense time-stepped
``jax.lax.scan`` over a (T, N) spike raster.  The membrane update itself
(decay + integrate + threshold + reset) is the per-step compute hot spot
of the profiling phase; ``repro.kernels.lif_step`` provides the Pallas TPU
kernel for it and this module is wired to use either implementation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LIFParams", "lif_step_jnp", "lif_run"]


@dataclass(frozen=True)
class LIFParams:
    """Discrete-time LIF constants (per-network, scalar-broadcast)."""

    decay: float = 0.9  # membrane leak multiplier per step: v <- decay * v
    threshold: float = 1.0  # fire when v >= threshold
    v_reset: float = 0.0  # post-spike reset potential
    refractory: int = 1  # steps a neuron stays silent after firing


def lif_step_jnp(
    v: jnp.ndarray,
    refr: jnp.ndarray,
    current: jnp.ndarray,
    params: LIFParams,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One LIF step: returns (v', refr', fired).  Pure-jnp reference.

    Mirrors `repro.kernels.lif_step.ref.lif_step_ref` (the kernel oracle).
    """
    active = refr <= 0
    v = jnp.where(active, params.decay * v + current, v)
    fired = active & (v >= params.threshold)
    v = jnp.where(fired, params.v_reset, v)
    refr = jnp.where(fired, params.refractory, jnp.maximum(refr - 1, 0))
    return v, refr, fired


def lif_run(
    weights: jnp.ndarray,
    input_drive: jnp.ndarray,
    params: LIFParams,
    *,
    use_pallas: bool = False,
    seed: int = 0,
) -> np.ndarray:
    """Run T steps of a recurrently-connected LIF population.

    Args:
      weights: (N, N) synaptic matrix; weights[i, j] = strength i -> j.
        Feedforward nets are block-superdiagonal; "random" nets are sparse
        dense-stored.
      input_drive: (T, N) external input current per step (e.g. Poisson
        encoded stimulus on the input layer, zero elsewhere).
      params: LIF constants.
      use_pallas: route the membrane update through the Pallas kernel
        (interpret mode on CPU) instead of pure jnp.

    Returns:
      (T, N) uint8 spike raster (host numpy).
    """
    n = weights.shape[0]
    if use_pallas:
        from repro.kernels.lif_step.ops import lif_step as step_fn
    else:
        step_fn = functools.partial(lif_step_jnp, params=params)

    def body(carry, drive_t):
        v, refr, last_spikes = carry
        # Spikes from step t-1 arrive as current at step t (1-step synapse delay).
        syn_current = last_spikes.astype(weights.dtype) @ weights
        if use_pallas:
            v, refr, fired = step_fn(
                v, refr, syn_current + drive_t,
                decay=params.decay, threshold=params.threshold,
                v_reset=params.v_reset, refractory=params.refractory,
            )
        else:
            v, refr, fired = step_fn(v, refr, syn_current + drive_t)
        return (v, refr, fired.astype(weights.dtype)), fired

    v0 = jnp.zeros((n,), dtype=weights.dtype)
    refr0 = jnp.zeros((n,), dtype=jnp.int32)
    s0 = jnp.zeros((n,), dtype=weights.dtype)
    _, raster = jax.lax.scan(body, (v0, refr0, s0), input_drive)
    return np.asarray(raster).astype(np.uint8)

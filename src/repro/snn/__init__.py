"""SNN software-simulator substrate (the toolchain's profiling phase).

A CARLsim substitute: vectorized leaky-integrate-and-fire dynamics under
`jax.lax.scan`, network topology builders for the paper's five evaluated
SNNs, and a profiler that emits the spike-weighted synapse graph plus the
per-spike trace that the partitioning/mapping phases consume.
"""
from .lif import LIFParams, lif_run
from .simulate import ProfileResult, profile_snn
from .topology import SNNTopology, make_snn, PAPER_SNNS

__all__ = [
    "LIFParams", "lif_run", "ProfileResult", "profile_snn",
    "SNNTopology", "make_snn", "PAPER_SNNS",
]

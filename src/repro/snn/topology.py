"""Builders for the paper's five evaluated SNNs (Table 1).

| SNN         | topology              | paper spikes |
|-------------|-----------------------|--------------|
| Smooth_320  | feedforward, 2 layer  | 175,124      |
| Smooth_1280 | feedforward, 2 layer  | 981,808      |
| MLP_2048    | feedforward, 2 layer  | 15,905,792   |
| Edge_5120   | feedforward, 3 layer  | 4,570,546    |
| Random_6212 | feedforward, 3 layer  | 51,756,245   |

"Smooth"/"Edge" follow the CARLsim image-processing tutorials (local
receptive fields on 2D grids); MLP is fully connected; "Random" uses random
inter-layer connectivity.  Spike counts are matched to Table 1 by
truncating the profiled trace at the step where the cumulative transmission
count reaches the paper's number (see `simulate.profile_snn`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

__all__ = ["SNNTopology", "make_snn", "PAPER_SNNS"]


@dataclass
class SNNTopology:
    name: str
    layer_sizes: list[int]
    syn_src: np.ndarray  # (E,) int32 directed synapse sources
    syn_dst: np.ndarray  # (E,) int32 directed synapse destinations
    weights: np.ndarray  # (N, N) float32 dense synaptic matrix
    input_size: int
    input_rate: float  # Bernoulli firing probability of the stimulus
    input_amp: float
    target_spikes: int | None = None
    meta: dict = field(default_factory=dict)

    @property
    def num_neurons(self) -> int:
        return int(sum(self.layer_sizes))


def _grid(n: int) -> tuple[int, int]:
    """Near-square (h, w) with h*w >= n."""
    h = int(math.sqrt(n))
    while n % h:
        h -= 1
    return h, n // h


def _local_edges(n_src: int, n_dst: int, radius: int) -> tuple[np.ndarray, np.ndarray]:
    """Receptive-field connectivity between two 2D-gridded layers."""
    hs, ws = _grid(n_src)
    hd, wd = _grid(n_dst)
    src_r, src_c = np.divmod(np.arange(n_src), ws)
    # Scale source coords into the destination grid.
    ctr_r = (src_r * hd) // hs
    ctr_c = (src_c * wd) // ws
    offs = [(dr, dc) for dr in range(-radius, radius + 1) for dc in range(-radius, radius + 1)]
    srcs, dsts = [], []
    for dr, dc in offs:
        rr, cc = ctr_r + dr, ctr_c + dc
        ok = (rr >= 0) & (rr < hd) & (cc >= 0) & (cc < wd)
        srcs.append(np.nonzero(ok)[0])
        dsts.append(rr[ok] * wd + cc[ok])
    return np.concatenate(srcs).astype(np.int64), np.concatenate(dsts).astype(np.int64)


def _full_edges(n_src: int, n_dst: int) -> tuple[np.ndarray, np.ndarray]:
    s = np.repeat(np.arange(n_src), n_dst)
    d = np.tile(np.arange(n_dst), n_src)
    return s, d


def _random_edges(
    n_src: int, n_dst: int, p: float, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    mask = rng.random((n_src, n_dst)) < p
    s, d = np.nonzero(mask)
    return s.astype(np.int64), d.astype(np.int64)


def _assemble(
    name: str,
    layer_sizes: list[int],
    layer_edges: list[tuple[np.ndarray, np.ndarray]],
    gain: float,
    input_rate: float,
    target_spikes: int | None,
) -> SNNTopology:
    n = sum(layer_sizes)
    offsets = np.cumsum([0] + layer_sizes)
    w = np.zeros((n, n), dtype=np.float32)
    all_src, all_dst = [], []
    for li, (s, d) in enumerate(layer_edges):
        gs = s + offsets[li]
        gd = d + offsets[li + 1]
        all_src.append(gs)
        all_dst.append(gd)
        # Normalize by fan-in so a fraction ~1/gain of presynaptic activity fires a neuron.
        fan_in = np.bincount(gd, minlength=n).astype(np.float32)
        w[gs, gd] = gain / np.maximum(fan_in[gd], 1.0)
    return SNNTopology(
        name=name,
        layer_sizes=layer_sizes,
        syn_src=np.concatenate(all_src).astype(np.int32),
        syn_dst=np.concatenate(all_dst).astype(np.int32),
        weights=w,
        input_size=layer_sizes[0],
        input_rate=input_rate,
        input_amp=1.5,  # suprathreshold: an input event fires the input neuron
        target_spikes=target_spikes,
        meta={"layers": layer_sizes},
    )


def make_snn(name: str, seed: int = 0) -> SNNTopology:
    rng = np.random.default_rng(seed)
    if name == "smooth_320":
        sizes = [160, 160]
        edges = [_local_edges(160, 160, radius=1)]
        return _assemble(name, sizes, edges, gain=2.0, input_rate=0.14, target_spikes=175_124)
    if name == "smooth_1280":
        sizes = [640, 640]
        edges = [_local_edges(640, 640, radius=1)]
        return _assemble(name, sizes, edges, gain=2.0, input_rate=0.18, target_spikes=981_808)
    if name == "mlp_2048":
        sizes = [1024, 1024]
        edges = [_full_edges(1024, 1024)]
        return _assemble(name, sizes, edges, gain=2.0, input_rate=0.06, target_spikes=15_905_792)
    if name == "edge_5120":
        sizes = [2048, 2048, 1024]
        edges = [_local_edges(2048, 2048, radius=2), _local_edges(2048, 1024, radius=2)]
        return _assemble(name, sizes, edges, gain=2.5, input_rate=0.10, target_spikes=4_570_546)
    if name == "random_6212":
        sizes = [2071, 2070, 2071]
        edges = [
            _random_edges(2071, 2070, p=0.10, rng=rng),
            _random_edges(2070, 2071, p=0.10, rng=rng),
        ]
        return _assemble(name, sizes, edges, gain=2.5, input_rate=0.12, target_spikes=51_756_245)
    raise KeyError(f"unknown SNN {name!r}; have {PAPER_SNNS}")


PAPER_SNNS = ["smooth_320", "smooth_1280", "mlp_2048", "edge_5120", "random_6212"]

"""Input-shape registry: the 4 assigned shapes and per-(arch, shape)
ShapeDtypeStruct input specs for the dry-run (no allocation).

  train_4k    seq=4096   global_batch=256  -> train_step
  prefill_32k seq=32768  global_batch=32   -> prefill_step
  decode_32k  seq=32768  global_batch=128  -> serve_step (1 token, KV=seq)
  long_500k   seq=524288 global_batch=1    -> serve_step; sub-quadratic only

`applicable()` encodes the skip rules (long_500k only for SSM/hybrid; see
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import DTYPES

__all__ = ["SHAPES", "ShapeSpec", "applicable", "input_specs", "cache_specs"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return False, ("full quadratic attention: 512k decode KV cache is "
                       "intentionally out of scope (sub-quadratic archs only)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sp = SHAPES[shape_name]
    b, s = sp.global_batch, sp.seq_len
    adt = DTYPES[cfg.activation_dtype]
    specs: dict = {}
    if sp.kind == "train":
        specs["tokens"] = _sds((b, s), jnp.int32)
        specs["labels"] = _sds((b, s), jnp.int32)
    elif sp.kind == "prefill":
        specs["tokens"] = _sds((b, s), jnp.int32)
    else:  # decode: one new token against a cache of length s
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["positions"] = _sds((b, 1), jnp.int32)
    if cfg.family in ("vlm", "audio") and sp.kind != "decode":
        specs["frontend"] = _sds((b, cfg.frontend_seq, cfg.frontend_dim), adt)
    return specs


def cache_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStructs for the decode-cache pytree (serve_step input)."""
    from repro.models.model import Model

    sp = SHAPES[shape_name]
    caches = jax.eval_shape(
        lambda: Model(cfg).init_caches(sp.global_batch, sp.seq_len))
    return caches

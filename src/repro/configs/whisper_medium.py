"""whisper-medium [audio]: enc-dec, conv frontend stubbed.

24 encoder + 24 decoder layers, d_model=1024, 16H, d_ff=4096, vocab=51865.
input_specs() supplies precomputed post-conv frame embeddings (B, 1500,
1024); rope replaces whisper's absolute embeddings (structural equivalence,
see DESIGN.md).  [arXiv:2212.04356; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    encoder_layers=24,
    frontend_seq=1500,
    frontend_dim=1024,
    notes="conv frontend stubbed; enc-dec",
)

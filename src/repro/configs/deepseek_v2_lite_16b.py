"""deepseek-v2-lite-16b [moe]: MLA attention + fine-grained MoE.

27L, d_model=2048, 16H, MLA (kv_lora_rank=512, rope_head=64, qk/v head
128), vocab=102400. MoE: 64 routed experts top-6 + 2 shared, moe_d_ff=1408,
first layer dense (d_ff=10944).  NOTE: the assignment line lists both
"64e" and "160 routed"; the official DSv2-Lite config is 64 routed + 2
shared, which we follow (see DESIGN.md §Arch-applicability).
[arXiv:2405.04434; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # the single leading dense layer
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    notes="MLA latent cache; 64 routed + 2 shared experts",
)

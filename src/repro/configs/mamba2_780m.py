"""mamba2-780m [ssm]: attention-free SSD.  48L, d_model=1536, d_inner=3072
(expand 2, 48 heads of 64), ssm_state=128, vocab=50280.  O(1)-state decode
=> runs long_500k.  [arXiv:2405.21060; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    long_context_ok=True,
    notes="attention-free; head sharding -> SSD heads (DESIGN.md)",
)

"""llama-3.2-vision-11b [vlm]: 8B text backbone + 8 gated cross-attn layers.

40L total = 32 self-attention + 8 cross-attention (one after every 4 self
layers), d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=128256.  The
vision tower is a STUB: input_specs() supplies precomputed patch
embeddings (B, 1601, 4096) that the cross layers attend to.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=4,
    frontend_seq=1601,
    frontend_dim=4096,
    notes="vision frontend stubbed as precomputed patch embeddings",
)

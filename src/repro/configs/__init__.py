"""Assigned-architecture registry: `get_config(name)` / `ARCHS`."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCHS = [
    "hymba-1.5b",
    "llama-3.2-vision-11b",
    "deepseek-v2-lite-16b",
    "qwen3-moe-30b-a3b",
    "llama3-8b",
    "deepseek-67b",
    "qwen3-14b",
    "deepseek-coder-33b",
    "mamba2-780m",
    "whisper-medium",
]

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = ["ARCHS", "get_config"]

"""hymba-1.5b [hybrid]: parallel attention + Mamba-2 heads per layer.

32L, d_model=1600, 25 heads (GQA kv=5, head_dim 64), d_ff=5504,
vocab=32001, ssm_state=16.  Sliding-window attention everywhere except 3
full-attention layers (first/middle/last, following the Hymba recipe);
sub-quadratic decode => runs long_500k.  [arXiv:2411.13676; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm_state=16,
    ssm_expand=1,
    ssm_head_dim=64,
    ssm_chunk=256,
    parallel_ssm=True,
    long_context_ok=True,
    notes="parallel attn+mamba heads; SWA(1024) + 3 global layers",
)

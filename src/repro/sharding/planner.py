"""Rule-based sharding planner: param/cache/batch pytrees -> PartitionSpecs.

Rules are keyed on parameter names and *negative* dimension indices, so the
same rule applies whether a leaf is a single layer or carries one or two
leading stack dims from scan-over-layers.  Every rule is guarded by a
divisibility check against the mesh axis size — a dimension that does not
divide evenly falls back to replication and the drop is recorded in the
plan (`plan.notes`) rather than failing at compile time (e.g. GQA kv=5
heads on a 16-way model axis).

Layout convention (Megatron-style TP over the `model` axis, DP over
`data`/`pod`):
  * embedding / lm_head: vocab-parallel,
  * attention q/k/v/o: head-parallel,
  * MLP gate/up/down: ffn-parallel,
  * MoE experts: expert-parallel (E dim),
  * SSD in/out projections: inner-dim-parallel,
  * optimizer m/v: parameter sharding + ZeRO-1 over the data axes on the
    first still-replicated divisible dim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingPlan", "plan_params", "plan_caches", "plan_batch",
           "plan_opt_state", "spec_for_param",
           "VertexShardPlan", "plan_vertex_shards"]


# (name, neg_dim) -> shard over model axis.  None neg_dim = replicate.
_PARAM_RULES: list[tuple[str, int | None]] = [
    ("embed", -2),
    ("lm_head", -1),
    ("frontend_proj", -1),
    ("wq", -2), ("wk", -2), ("wv", -2), ("wo", -3),
    ("w_q", -2), ("w_uk", -2), ("w_uv", -2), ("w_o", -3),
    ("w_dkv", None), ("w_kpe", None),
    ("router", None),
    ("in_proj", -1), ("out_proj", -2),
    ("conv_w", None), ("dt_bias", None), ("a_log", None), ("d_skip", None),
    ("gate_attn", None), ("gate_mlp", None),
]
_MOE_RULES = {"w_gate": -3, "w_up": -3, "w_down": -3}
_MLP_RULES = {"w_gate": -1, "w_up": -1, "w_down": -2}


def _path_names(path) -> list[str]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return names


@dataclass
class ShardingPlan:
    mesh: Mesh
    model_axis: str = "model"
    batch_axes: tuple[str, ...] = ("data",)
    # Spread a batch-unshardable decode cache's sequence dim over the idle
    # batch axes too ("sequence-parallel decode", §Perf). False = the
    # paper-faithful baseline layout (model axis only).
    seq_parallel_decode: bool = True
    # When an attention projection's head count does not divide the model
    # axis (Hymba's 25 heads, GQA kv=5), shard its head_dim instead of
    # replicating — weight reads drop model-axis-fold at the cost of extra
    # rope/attention resharding collectives (§Perf lever C2).
    shard_head_dim_fallback: bool = False
    notes: list[str] = field(default_factory=list)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    @property
    def batch_size_divisor(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))


def _shard_dim(plan: ShardingPlan, shape, neg_dim: int | None, axis: str,
               name: str) -> P:
    if neg_dim is None:
        return P()
    ndim = len(shape)
    spec = [None] * ndim
    dim = ndim + neg_dim
    if 0 <= dim < ndim:
        if shape[dim] % plan.mesh.shape[axis] == 0:
            spec[dim] = axis
        else:
            plan.notes.append(
                f"{name}: dim {dim} size {shape[dim]} !% {axis}"
                f"({plan.mesh.shape[axis]}) -> replicated")
            return P()
    return P(*spec)


def spec_for_param(plan: ShardingPlan, path, leaf) -> P:
    names = _path_names(path)
    leaf_name = names[-1] if names else ""
    under_moe = "moe" in names
    shape = leaf.shape
    if leaf_name in _MOE_RULES and under_moe:
        return _shard_dim(plan, shape, _MOE_RULES[leaf_name], plan.model_axis,
                          "/".join(names))
    if leaf_name in _MLP_RULES and not under_moe:
        return _shard_dim(plan, shape, _MLP_RULES[leaf_name], plan.model_axis,
                          "/".join(names))
    for rule_name, neg_dim in _PARAM_RULES:
        if leaf_name == rule_name:
            spec = _shard_dim(plan, shape, neg_dim, plan.model_axis,
                              "/".join(names))
            if (spec == P() and plan.shard_head_dim_fallback
                    and leaf_name in ("wq", "wk", "wv", "wo", "w_q", "w_uk",
                                      "w_uv", "w_o")):
                hd_dim = -1 if leaf_name != "wo" and leaf_name != "w_o" else -2
                spec = _shard_dim(plan, shape, hd_dim, plan.model_axis,
                                  "/".join(names) + "(hd-fallback)")
            return spec
    # norms, scales, biases and anything unrecognized: replicate.
    return P()


def plan_params(plan: ShardingPlan, params: Any) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(plan, path, leaf), params)


# --------------------------------------------------------------- caches

def _batch_entry(plan: ShardingPlan):
    return plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]


def _spec_with(ndim: int, assigns: dict[int, Any]) -> P:
    spec: list = [None] * ndim
    for dim, ax in assigns.items():
        if 0 <= dim < ndim:
            spec[dim] = ax
    return P(*spec)


def _kv_group_specs(plan: ShardingPlan, group: dict, names) -> dict:
    """Joint strategy for a {k, v, pos} KV-cache group.

    Prefer sharding KV heads over the model axis (no extra collectives in
    attention); when head count does not divide (GQA kv < model size),
    shard the SEQUENCE dim instead — decode softmax then reduces over a
    sharded axis and GSPMD inserts the small (B, H) partial-softmax
    all-reduces, trading tiny collectives for a 16x cache-memory cut.

    When the batch itself cannot shard (long-context decode at batch=1),
    the otherwise-idle batch axes join the sequence sharding — the
    "sequence-parallel decode" layout that spreads one sequence's cache
    and attention FLOPs across the whole pod (EXPERIMENTS.md §Perf).
    """
    k = group["k"]
    msize = plan.mesh.shape[plan.model_axis]
    ndim = k.ndim
    kvh_dim, seq_dim = ndim - 2, ndim - 3
    div = plan.batch_size_divisor
    batch_ok = k.shape[ndim - 4] % div == 0
    if not batch_ok:
        plan.notes.append(f"cache {'/'.join(names)}: batch {k.shape[ndim-4]} !% {div}")
    # Sequence sharding axes: model alone, or everything when batch idles.
    seq_axes = (plan.model_axis,) if (batch_ok or not plan.seq_parallel_decode) \
        else tuple(plan.batch_axes) + (plan.model_axis,)
    seq_div = int(np.prod([plan.mesh.shape[a] for a in seq_axes]))
    seq_entry = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    if batch_ok and k.shape[kvh_dim] % msize == 0:
        kv_model = {kvh_dim: plan.model_axis}
        mode = "heads"
    elif k.shape[seq_dim] % seq_div == 0:
        kv_model = {seq_dim: seq_entry}
        mode = "seq"
    elif k.shape[kvh_dim] % msize == 0:
        kv_model = {kvh_dim: plan.model_axis}
        mode = "heads"
    else:
        kv_model = {}
        mode = "replicated"
        plan.notes.append(f"cache {'/'.join(names)}: kv heads {k.shape[kvh_dim]}"
                          f" and seq {k.shape[seq_dim]} unshardable")
    out = {}
    for name in ("k", "v"):
        assigns = dict(kv_model)
        if batch_ok:
            assigns[ndim - 4] = _batch_entry(plan)
        out[name] = _spec_with(ndim, assigns)
    pos_ndim = group["pos"].ndim
    pos_assigns = {}
    if batch_ok:
        pos_assigns[pos_ndim - 2] = _batch_entry(plan)
    if mode == "seq":
        pos_assigns[pos_ndim - 1] = seq_entry
    out["pos"] = _spec_with(pos_ndim, pos_assigns)
    return out


def _mla_group_specs(plan: ShardingPlan, group: dict, names) -> dict:
    """{c_kv, k_pe, pos}: latent has no head dim; shard the sequence dim."""
    c = group["c_kv"]
    msize = plan.mesh.shape[plan.model_axis]
    div = plan.batch_size_divisor
    ndim = c.ndim
    seq_ok = c.shape[ndim - 2] % msize == 0
    batch_ok = c.shape[ndim - 3] % div == 0
    out = {}
    for name in ("c_kv", "k_pe"):
        assigns = {}
        if batch_ok:
            assigns[ndim - 3] = _batch_entry(plan)
        if seq_ok:
            assigns[ndim - 2] = plan.model_axis
        out[name] = _spec_with(ndim, assigns)
    pos_ndim = group["pos"].ndim
    pos_assigns = {}
    if batch_ok:
        pos_assigns[pos_ndim - 2] = _batch_entry(plan)
    if seq_ok:
        pos_assigns[pos_ndim - 1] = plan.model_axis
    out["pos"] = _spec_with(pos_ndim, pos_assigns)
    return out


def _ssm_specs(plan: ShardingPlan, leaf, name: str) -> P:
    msize = plan.mesh.shape[plan.model_axis]
    div = plan.batch_size_divisor
    ndim = leaf.ndim
    if name == "state":  # (..., B, H, N, P)
        assigns = {}
        if leaf.shape[ndim - 4] % div == 0:
            assigns[ndim - 4] = _batch_entry(plan)
        if leaf.shape[ndim - 3] % msize == 0:
            assigns[ndim - 3] = plan.model_axis
        return _spec_with(ndim, assigns)
    if name == "conv":  # (..., B, K-1, C)
        assigns = {}
        if leaf.shape[ndim - 3] % div == 0:
            assigns[ndim - 3] = _batch_entry(plan)
        if leaf.shape[ndim - 1] % msize == 0:
            assigns[ndim - 1] = plan.model_axis
        return _spec_with(ndim, assigns)
    return P()


def plan_caches(plan: ShardingPlan, caches: Any) -> Any:
    """Walk the cache pytree, handling {k,v,pos} / {c_kv,k_pe,pos} groups
    jointly so every member of a group gets a consistent layout."""

    def walk(node, names):
        if isinstance(node, dict):
            keys = set(node.keys())
            if {"k", "v", "pos"} <= keys:
                specs = _kv_group_specs(plan, node, names)
                return {kk: (specs[kk] if kk in specs else walk(vv, names + [kk]))
                        for kk, vv in node.items()}
            if {"c_kv", "k_pe", "pos"} <= keys:
                specs = _mla_group_specs(plan, node, names)
                return {kk: (specs[kk] if kk in specs else walk(vv, names + [kk]))
                        for kk, vv in node.items()}
            out = {}
            for kk, vv in node.items():
                if kk in ("state", "conv") and hasattr(vv, "ndim"):
                    out[kk] = _ssm_specs(plan, vv, kk)
                else:
                    out[kk] = walk(vv, names + [kk])
            return out
        if hasattr(node, "ndim"):
            return P()
        return jax.tree.map(lambda _: P(), node)

    return walk(caches, [])


def plan_batch(plan: ShardingPlan, batch: Any) -> Any:
    def one(path, leaf):
        div = plan.batch_size_divisor
        axes = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
        if leaf.shape and leaf.shape[0] % div == 0:
            return P(*([axes] + [None] * (leaf.ndim - 1)))
        plan.notes.append(
            f"batch {'/'.join(_path_names(path))}: {leaf.shape} !% {div} -> replicated")
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(one, batch)


# ------------------------------------------------- vertex-block sharding
#
# The partitioning engine shards its O(n)/O(m) state over contiguous vertex
# blocks (CSR rows stay contiguous per shard, so per-shard adjacency slices
# are zero-copy views).  Same planner philosophy as the param rules above:
# uneven or device-incompatible layouts degrade gracefully and the drop is
# recorded in `notes` instead of failing.


@dataclass
class VertexShardPlan:
    """Contiguous vertex-block decomposition of an n-vertex graph.

    ``bounds`` is an int64 array of length ``num_shards + 1`` with
    ``bounds[0] == 0`` and ``bounds[-1] == n``; shard ``s`` owns the
    half-open vertex range ``[bounds[s], bounds[s+1])``.  When the plan was
    built with device placement and the blocks divide evenly, ``sharding``
    holds a :class:`jax.sharding.NamedSharding` over a 1-D ``vertex`` mesh
    axis for placing O(n) vertex arrays; otherwise it is ``None`` and the
    reason is in ``notes`` (single host keeps plain numpy blocks).
    """

    bounds: np.ndarray
    sharding: Any = None
    notes: list[str] = field(default_factory=list)

    @property
    def num_shards(self) -> int:
        return len(self.bounds) - 1

    @property
    def n(self) -> int:
        return int(self.bounds[-1])

    def block(self, s: int) -> tuple[int, int]:
        return int(self.bounds[s]), int(self.bounds[s + 1])

    def owner(self, vertices: np.ndarray) -> np.ndarray:
        """Shard id owning each vertex id."""
        return np.searchsorted(self.bounds, vertices, side="right") - 1

    def split(self, rows: np.ndarray) -> list[np.ndarray]:
        """Split a sorted array of vertex ids into per-shard sub-arrays."""
        cuts = np.searchsorted(rows, self.bounds[1:-1])
        return np.split(rows, cuts)

    def device_put(self, arr: np.ndarray):
        """Place an O(n) vertex array according to the plan.

        Returns a device-sharded jax array when the plan carries a
        NamedSharding, else the input unchanged (single-host numpy path).
        """
        if self.sharding is None:
            return arr
        return jax.device_put(arr, self.sharding)


def plan_vertex_shards(n: int, num_shards: int,
                       use_devices: bool | str = "auto") -> VertexShardPlan:
    """Plan ``num_shards`` contiguous near-equal vertex blocks for n vertices.

    ``use_devices="auto"`` attaches a :class:`jax.sharding.NamedSharding`
    over a 1-D ``vertex`` mesh when the host has at least ``num_shards``
    devices *and* n divides evenly (jax requires equal shards along a mesh
    axis); otherwise the plan stays host-only and records why.  Tests can
    force multiple CPU devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    num_shards = min(num_shards, max(1, n))
    bounds = (np.arange(num_shards + 1, dtype=np.int64) * n) // num_shards
    plan = VertexShardPlan(bounds=bounds)
    if use_devices is False:
        return plan
    devices = jax.devices()
    if len(devices) < num_shards:
        plan.notes.append(
            f"{len(devices)} device(s) < {num_shards} shards -> host-only blocks")
        return plan
    if n % num_shards != 0:
        plan.notes.append(
            f"n={n} !% {num_shards} shards -> host-only blocks (jax needs even)")
        return plan
    mesh = Mesh(np.asarray(devices[:num_shards]), ("vertex",))
    plan.sharding = NamedSharding(mesh, P("vertex"))
    return plan


# ----------------------------------------------------------- optimizer

def plan_opt_state(plan: ShardingPlan, params: Any, zero1: bool = True) -> Any:
    """Adam m/v: parameter spec + ZeRO-1 data-sharding of the first free dim."""
    pspecs = plan_params(plan, params)

    def one(leaf, spec: P):
        if not zero1 or leaf.ndim == 0:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        div = plan.batch_size_divisor
        for d in range(leaf.ndim):
            if entries[d] is None and leaf.shape[d] % div == 0 and leaf.shape[d] >= div:
                entries[d] = plan.batch_axes if len(plan.batch_axes) > 1 \
                    else plan.batch_axes[0]
                break
        return P(*entries)

    return jax.tree.map(one, params, pspecs)

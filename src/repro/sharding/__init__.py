from .planner import (ShardingPlan, plan_params, plan_caches, plan_batch,
                      plan_opt_state, spec_for_param)
from .layout import sneap_device_layout

__all__ = ["ShardingPlan", "plan_params", "plan_caches", "plan_batch",
           "plan_opt_state", "spec_for_param", "sneap_device_layout"]

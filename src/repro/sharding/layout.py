"""SNEAP-optimized logical->physical device layout (beyond-paper).

The paper's mapping phase places communicating partitions on a 2D mesh to
minimize hop-weighted traffic; the identical problem appears when laying
out a logical (data, model) mesh onto the physical ICI torus: model-axis
collectives (all-gather / reduce-scatter of weights and activations) carry
far more bytes than data-axis gradient reductions in TP-heavy regimes, so
the model axis should occupy physically-adjacent chips.

`sneap_device_layout` builds the partition graph from per-axis collective
traffic (bytes between logical neighbors, as measured by the dry-run HLO),
and reuses `repro.core.mapping.sa_search` with torus distance to order the
devices handed to `jax.make_mesh`.  On CPU dry-runs the "physical torus"
is the modeled 16x16-per-pod grid from DESIGN.md §3.
"""
from __future__ import annotations

import numpy as np

from repro.core.hopcost import hop_distance_matrix
from repro.core.mapping import sa_search

__all__ = ["logical_traffic_matrix", "sneap_device_layout"]


def logical_traffic_matrix(
    mesh_shape: dict[str, int],
    axis_bytes: dict[str, float],
    patterns: dict[str, str] | None = None,
) -> np.ndarray:
    """Traffic between logical devices along each mesh axis.

    axis_bytes[axis] = bytes exchanged on that axis per step (from the
    dry-run collective analysis).  patterns[axis] selects the traffic
    shape: "ring" (all-gather / reduce-scatter / all-reduce ring schedules
    — neighbor-only) or "alltoall" (MoE expert dispatch — every pair of
    devices differing only in this axis coordinate exchanges
    vol/(k-1) each way).
    """
    axes = list(mesh_shape.keys())
    sizes = [mesh_shape[a] for a in axes]
    n = int(np.prod(sizes))
    ids = np.arange(n).reshape(sizes)
    traffic = np.zeros((n, n))
    patterns = patterns or {}
    for ai, a in enumerate(axes):
        vol = axis_bytes.get(a, 0.0)
        k = sizes[ai]
        if vol <= 0 or k < 2:
            continue
        if patterns.get(a, "ring") == "alltoall":
            per_pair = vol / (k - 1)
            for shift in range(1, k):
                fwd = np.roll(ids, -shift, axis=ai)
                src = ids.reshape(-1)
                dst = fwd.reshape(-1)
                traffic[src, dst] += per_pair
        else:
            fwd = np.roll(ids, -1, axis=ai)
            src = ids.reshape(-1)
            dst = fwd.reshape(-1)
            traffic[src, dst] += vol
            traffic[dst, src] += vol
    return traffic


def sneap_device_layout(
    mesh_shape: dict[str, int],
    axis_bytes: dict[str, float],
    phys_w: int = 16,
    seed: int = 0,
    iters: int = 150_000,
    t0_frac: float = 2.0,
    dead_chips: list[int] | None = None,
    patterns: dict[str, str] | None = None,
) -> tuple[np.ndarray, float, float]:
    """Order devices so hop-weighted collective traffic on the torus is low.

    The SA chain is seeded with the identity layout, so the result never
    regresses below the default row-major order (which is already
    hop-optimal for pure ring-neighbor traffic on an intact torus — the
    win appears for non-uniform traffic or a degraded pod, see
    `dead_chips`: logical devices then route around the holes).

    Returns (device_order, baseline_avg_hop, optimized_avg_hop): feed
    `devices[device_order]` to `make_mesh_with_layout`.
    """
    traffic = logical_traffic_matrix(mesh_shape, axis_bytes, patterns)
    n_logical = traffic.shape[0]
    dead = sorted(dead_chips or [])
    n_phys = n_logical + len(dead)
    phys_h = n_phys // phys_w
    assert phys_w * phys_h == n_phys, (n_phys, phys_w)
    dist = hop_distance_matrix(n_phys, phys_w, torus=True).astype(np.float64)
    if dead:
        # Dead chips cannot host devices: make them prohibitively distant so
        # the SA search keeps real (traffic-carrying) devices off them.
        penalty = float(dist.max()) * n_phys
        dist[dead, :] += penalty
        dist[:, dead] += penalty
        for c in dead:
            dist[c, c] = 0.0
    # Pad traffic with silent "virtual" partitions pinned to the dead chips
    # by the initial placement; swaps will move real devices off them.
    if dead:
        pad = np.zeros((n_phys, n_phys))
        pad[:n_logical, :n_logical] = traffic
        traffic = pad
    alive = [c for c in range(n_phys) if c not in dead]
    ident = np.concatenate([np.asarray(alive), np.asarray(dead)]).astype(np.int64)
    tot = max(traffic.sum(), 1)
    base = float((dist[ident[:n_logical, None], ident[None, :n_logical]]
                  * traffic[:n_logical, :n_logical]).sum() / tot)
    # A seeded chain starts at a local optimum; it needs a hot start
    # (t0_frac ~2) to escape before the geometric cooling bites.
    res = sa_search(traffic, n_phys, phys_w, trace_length=int(tot),
                    seed=seed, iters=iters, t0_frac=t0_frac, torus=True,
                    init=ident)
    placement = np.asarray(res.placement)
    opt = float((dist[placement[:n_logical, None], placement[None, :n_logical]]
                 * traffic[:n_logical, :n_logical]).sum() / tot)
    on_dead = dead and bool(np.isin(placement[:n_logical], dead).any())
    if opt > base or on_dead:  # SA failed to improve the seed; keep the seed
        placement, opt = ident, base
    order = np.empty(n_logical, dtype=np.int64)
    order[:] = placement[:n_logical]
    return order, base, opt

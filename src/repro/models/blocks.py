"""Decoder/encoder blocks: one parameterized implementation per family.

Every block is a pure function (params, x, ...) -> (x, cache) with a
static `mode` in {"train", "prefill", "decode"}:
  * train   — full sequence, no cache emitted (memory-lean for grad).
  * prefill — full sequence, emits the cache decode will consume.
  * decode  — single token against the cache.

Blocks are written to be scanned over stacked (L, ...) parameters; any
per-layer heterogeneity (e.g. Hymba's 3 global-attention layers inside an
SWA stack) is expressed through *traced* per-layer scalars so one compiled
body serves the whole stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .attention import decode_attend, init_kv_cache, mha, update_kv_cache
from .layers import rms_norm, apply_rope, swiglu
from .mamba2 import init_mamba_cache, mamba_block, mamba_decode
from .mla import init_mla_cache, mla_attention, mla_decode
from .moe import moe_ffn, moe_ffn_sharded

__all__ = ["self_attention", "attn_mlp_block", "moe_block", "ssm_block",
           "hybrid_block", "cross_block", "enc_dec_block", "encoder_block",
           "cross_kv", "init_block_cache"]

BIG_WINDOW = jnp.int32(2**30)


# ---------------------------------------------------------------- attention

def _qkv(p: dict, x: jnp.ndarray, positions: jnp.ndarray, cfg):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attention(
    p: dict, x: jnp.ndarray, positions: jnp.ndarray, cfg, mode: str,
    cache: dict | None = None, window=None, kv_chunk: int = 1024,
):
    """Returns (attn_out, cache_out). `window` may be a traced scalar."""
    q, k, v = _qkv(p, x, positions, cfg)
    if mode == "decode":
        cache = update_kv_cache(cache, k, v, positions)
        out = decode_attend(q, cache["k"], cache["v"], cache["pos"], positions,
                            window=window)
    else:
        out = mha(q, k, v, positions, positions, causal=True, window=window,
                  kv_chunk=kv_chunk)
        if mode == "prefill":
            cache = update_kv_cache(cache, k, v, positions)
    # Tag the post-all-reduce activation: under the "save_collectives"
    # remat policy the bwd pass reuses it instead of re-running the TP
    # collective (EXPERIMENTS.md §Perf).
    proj = checkpoint_name(jnp.einsum("bshe,hed->bsd", out, p["wo"]),
                           "tp_collective_out")
    return proj, cache


# ------------------------------------------------------------- block bodies

def attn_mlp_block(p, x, positions, cfg, mode, cache=None, window=None,
                   kv_chunk: int = 1024):
    """Pre-norm attention + SwiGLU MLP (llama family)."""
    if cfg.use_mla:
        h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
        if mode == "decode":
            attn, cache = mla_decode(p["attn"], h, cache, positions, cfg)
        else:
            attn, new_cache = mla_attention(p["attn"], h, positions, cfg, kv_chunk)
            if mode == "prefill":
                from .mla import update_mla_cache
                cache = update_mla_cache(cache, new_cache["c_kv"],
                                         new_cache["k_pe"], positions)
    else:
        attn, cache = self_attention(p["attn"], rms_norm(x, p["attn_norm"], cfg.norm_eps),
                                     positions, cfg, mode, cache, window, kv_chunk)
    x = x + attn
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + checkpoint_name(
        swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"]),
        "tp_collective_out")
    return x, cache


def moe_block(p, x, positions, cfg, mode, cache=None, mesh_info=None,
              kv_chunk: int = 1024):
    """Attention (GQA or MLA) + routed-experts FFN (+ shared experts)."""
    if cfg.use_mla:
        h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
        if mode == "decode":
            attn, cache = mla_decode(p["attn"], h, cache, positions, cfg)
        else:
            attn, new_cache = mla_attention(p["attn"], h, positions, cfg, kv_chunk)
            if mode == "prefill":
                from .mla import update_mla_cache
                cache = update_mla_cache(cache, new_cache["c_kv"],
                                         new_cache["k_pe"], positions)
    else:
        attn, cache = self_attention(p["attn"], rms_norm(x, p["attn_norm"], cfg.norm_eps),
                                     positions, cfg, mode, cache, None, kv_chunk)
    x = x + attn
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if mesh_info is not None:
        mesh, batch_axes = mesh_info
        routed, aux = moe_ffn_sharded(h, p["moe"], cfg, mesh, batch_axes)
    else:
        routed, aux = moe_ffn(h, p["moe"], cfg.top_k, cfg.capacity_factor)
    out = routed
    if cfg.num_shared_experts:
        out = out + swiglu(h, p["shared"]["w_gate"], p["shared"]["w_up"],
                           p["shared"]["w_down"])
    out = checkpoint_name(out, "tp_collective_out")
    return x + out, cache, aux


def ssm_block(p, x, positions, cfg, mode, cache=None):
    """Pure Mamba-2 block (mamba2-780m): norm -> mixer -> residual."""
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if mode == "decode":
        out, cache = mamba_decode(p["mamba"], h, cfg, cache)
    else:
        out, new_cache = mamba_block(p["mamba"], h, cfg)
        if mode == "prefill":
            cache = new_cache
    return x + out, cache


def hybrid_block(p, x, positions, cfg, mode, cache=None, window=None,
                 kv_chunk: int = 1024):
    """Hymba: attention and Mamba-2 heads in parallel on the same input,
    outputs normalized and averaged, then a SwiGLU MLP."""
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    attn_cache = cache["attn"] if cache is not None else None
    ssm_cache = cache["ssm"] if cache is not None else None
    attn, attn_cache = self_attention(p["attn"], h, positions, cfg, mode,
                                      attn_cache, window, kv_chunk)
    if mode == "decode":
        ssm, ssm_cache = mamba_decode(p["mamba"], h, cfg, ssm_cache)
    else:
        ssm, new_ssm = mamba_block(p["mamba"], h, cfg)
        if mode == "prefill":
            ssm_cache = new_ssm
    mixed = 0.5 * (rms_norm(attn, p["attn_out_norm"], cfg.norm_eps)
                   + rms_norm(ssm, p["ssm_out_norm"], cfg.norm_eps))
    x = x + checkpoint_name(mixed, "tp_collective_out")
    h2 = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + checkpoint_name(
        swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"]),
        "tp_collective_out")
    cache = {"attn": attn_cache, "ssm": ssm_cache} if mode != "train" else None
    return x, cache


def cross_block(p, x, enc_kv: dict, cfg, mode):
    """Cross-attention + MLP (vlm image layers, whisper decoder cross part).

    enc_kv: {"k": (B,Se,KVH,hd), "v": ..., "pos": (B,Se)} — precomputed from
    encoder states (static during decode).
    """
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
    out = mha(q, enc_kv["k"], enc_kv["v"],
              jnp.zeros(q.shape[:2], jnp.int32), enc_kv["pos"],
              causal=False, kv_chunk=1024)
    attn = jnp.einsum("bshe,hed->bsd", out, p["attn"]["wo"])
    # Gated residual (llama-3.2 style tanh gate, initialized near zero).
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * attn
    h2 = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * swiglu(
        h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x


def enc_dec_block(p, x, positions, enc_kv: dict, cfg, mode: str,
                  cache: dict | None = None, kv_chunk: int = 1024):
    """Whisper decoder layer: causal self-attn + cross-attn + MLP."""
    attn, cache = self_attention(p["self_attn"],
                                 rms_norm(x, p["self_norm"], cfg.norm_eps),
                                 positions, cfg, mode, cache, None, kv_chunk)
    x = x + attn
    h = rms_norm(x, p["cross_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, p["cross_attn"]["wq"])
    out = mha(q, enc_kv["k"], enc_kv["v"],
              jnp.zeros(q.shape[:2], jnp.int32), enc_kv["pos"],
              causal=False, kv_chunk=kv_chunk)
    x = x + jnp.einsum("bshe,hed->bsd", out, p["cross_attn"]["wo"])
    h2 = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x, cache


def encoder_block(p, x, positions, cfg, kv_chunk: int = 1024):
    """Bidirectional self-attention + MLP (whisper encoder)."""
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(p["attn"], h, positions, cfg)
    out = mha(q, k, v, positions, positions, causal=False, kv_chunk=kv_chunk)
    x = x + jnp.einsum("bshe,hed->bsd", out, p["attn"]["wo"])
    h2 = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x


def cross_kv(attn_p: dict, enc_states: jnp.ndarray, cfg) -> dict:
    """Precompute cross-attention K/V from encoder states."""
    k = jnp.einsum("bsd,dhe->bshe", enc_states, attn_p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", enc_states, attn_p["wv"])
    pos = jnp.broadcast_to(jnp.arange(enc_states.shape[1], dtype=jnp.int32),
                           enc_states.shape[:2])
    return {"k": k, "v": v, "pos": pos}


# ---------------------------------------------------------------- caches

def init_block_cache(cfg, kind: str, batch: int, cache_len: int, dtype,
                     window_len: int | None = None):
    """Cache pytree for one layer of the given kind."""
    if kind == "mla":
        return init_mla_cache(batch, cache_len, cfg, dtype)
    if kind == "attn":
        length = window_len if window_len is not None else cache_len
        return init_kv_cache(batch, length, cfg.num_kv_heads, cfg.head_dim, dtype)
    if kind == "ssm":
        return init_mamba_cache(batch, cfg, dtype)
    if kind == "hybrid":
        length = window_len if window_len is not None else cache_len
        return {"attn": init_kv_cache(batch, length, cfg.num_kv_heads,
                                      cfg.head_dim, dtype),
                "ssm": init_mamba_cache(batch, cfg, dtype)}
    raise ValueError(kind)

"""Multi-head Latent Attention (DeepSeek-V2), prefill + absorbed decode.

K/V are compressed into a rank-`kv_lora_rank` latent c_kv plus one shared
decoupled rope sub-head k_pe; the cache stores only (c_kv, k_pe) — the MLA
memory saving.  Decode uses the weight-absorption identity:

  score = (q_nope W_uk^T) . c_kv + q_pe . k_pe
  out   = (softmax . c_kv) W_uv

so the per-head K/V are never materialized during decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import mha
from .layers import apply_rope

__all__ = ["mla_attention", "mla_decode", "init_mla_cache", "update_mla_cache"]

NEG_INF = -1e30


def _project_q(p: dict, x: jnp.ndarray, positions: jnp.ndarray, cfg):
    """Returns q_nope (B,S,H,hd), q_pe (B,S,H,rh) with rope applied."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])  # e = hd + rh
    q_nope = q[..., : cfg.head_dim]
    q_pe = apply_rope(q[..., cfg.head_dim :], positions, cfg.rope_theta)
    return q_nope, q_pe


def mla_attention(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S)
    cfg,
    kv_chunk: int = 1024,
) -> tuple[jnp.ndarray, dict]:
    """Prefill/train path: materializes per-head K/V from the latent.

    Returns (attn_out (B,S,D), cache{c_kv, k_pe, pos}).
    """
    b, s, _ = x.shape
    h, hd, rh = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q_nope, q_pe = _project_q(p, x, positions, cfg)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # (B,S,r)
    k_pe = apply_rope(jnp.einsum("bsd,de->bse", x, p["w_kpe"])[:, :, None, :],
                      positions, cfg.rope_theta)[:, :, 0]  # (B,S,rh)

    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])  # (B,S,H,hd)
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])  # (B,S,H,hd)

    # Assemble full q/k with the shared rope sub-head broadcast to all heads.
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)  # (B,S,H,hd+rh)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, rh))], axis=-1
    )
    scale = (hd + rh) ** -0.5
    # v is padded to hd+rh so mha's uniform head_dim applies; excess sliced off.
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, rh)))
    out = mha(q_full, k_full, v_pad, positions, positions, causal=True,
              kv_chunk=kv_chunk, softmax_scale=scale)[..., :hd]
    from jax.ad_checkpoint import checkpoint_name
    attn = checkpoint_name(jnp.einsum("bshe,hed->bsd", out, p["w_o"]),
                           "tp_collective_out")
    cache = {"c_kv": c_kv, "k_pe": k_pe, "pos": positions}
    return attn, cache


def mla_decode(
    p: dict,
    x: jnp.ndarray,  # (B, 1, D)
    cache: dict,  # c_kv (B,S,r), k_pe (B,S,rh), pos (B,S)
    positions: jnp.ndarray,  # (B, 1)
    cfg,
) -> tuple[jnp.ndarray, dict]:
    """Absorbed decode: attention in latent space, O(r) per cached token."""
    h, hd, rh = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q_nope, q_pe = _project_q(p, x, positions, cfg)  # (B,1,H,hd), (B,1,H,rh)

    c_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # (B,1,r)
    kpe_new = apply_rope(jnp.einsum("bsd,de->bse", x, p["w_kpe"])[:, :, None, :],
                         positions, cfg.rope_theta)[:, :, 0]
    cache = update_mla_cache(cache, c_new, kpe_new, positions)

    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])  # absorb W_uk
    s_lat = jnp.einsum("bshr,bcr->bshc", q_lat, cache["c_kv"],
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bshe,bce->bshc", q_pe, cache["k_pe"],
                      preferred_element_type=jnp.float32)
    s = (s_lat + s_pe) * (hd + rh) ** -0.5  # (B,1,H,C)
    valid = (cache["pos"] >= 0) & (cache["pos"] <= positions)  # (B,C); positions (B,1) bcasts
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bshc,bcr->bshr", w.astype(cache["c_kv"].dtype), cache["c_kv"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshr,rhe->bshe", out_lat, p["w_uv"])  # (B,1,H,hd)
    attn = jnp.einsum("bshe,hed->bsd", out, p["w_o"])
    return attn, cache


def init_mla_cache(batch: int, length: int, cfg, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, length, cfg.rope_head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def update_mla_cache(cache: dict, c_new, kpe_new, positions) -> dict:
    b_idx = jnp.arange(c_new.shape[0])[:, None]
    return {
        "c_kv": cache["c_kv"].at[b_idx, positions].set(c_new),
        "k_pe": cache["k_pe"].at[b_idx, positions].set(kpe_new),
        "pos": cache["pos"].at[b_idx, positions].set(positions),
    }

"""Shared building blocks: norms, rotary embeddings, SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "rope_freqs", "apply_rope", "swiglu", "init_dense",
           "cross_entropy_loss", "DTYPES", "set_scan_unroll", "scan_unroll"]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}

# Roofline-measurement switch: XLA cost analysis visits a while-loop body
# once, so FLOP / collective-byte measurement needs every lax.scan unrolled.
# Training/serving always run rolled (flag False).
_SCAN_UNROLL = False


def set_scan_unroll(flag: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(flag)


def scan_unroll():
    """Value to pass as lax.scan(..., unroll=...)."""
    return True if _SCAN_UNROLL else 1


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim // 2,) inverse frequencies."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate (..., seq, heads, head_dim) by position; fp32 math.

    positions: (..., seq) int32 — absolute token positions.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, hd/2) broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., : hd // 2].astype(jnp.float32)
    x2 = x[..., hd // 2 :].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: silu(x @ w_gate) * (x @ w_up) @ w_down."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def init_dense(key: jax.Array, shape: tuple[int, ...], dtype,
               fan_in: int | None = None):
    """Truncated-normal fan-in init (fan_in defaults to the leading dim)."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) >= 2 else 1
    std = fan_in**-0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token cross entropy in fp32. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)

"""Architecture configuration: one frozen dataclass drives every model.

Every assigned architecture is a pure-data `ArchConfig`; the model builder
(`repro.models.model`) interprets it.  Reduced (smoke-test) variants are
produced by `ArchConfig.reduced()` so CPU tests exercise the identical
code path at toy scale.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

__all__ = ["ArchConfig"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavor ---
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # SWA width; None = full attention
    global_attn_layers: tuple[int, ...] = ()  # full-attn layers in an SWA stack

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 0  # decoupled positional sub-head

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading layers with a dense MLP instead
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 1
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # --- hybrid (Hymba): parallel attention + SSM heads per layer ---
    parallel_ssm: bool = False

    # --- encoder-decoder / multimodal ---
    encoder_layers: int = 0  # >0 => enc-dec (whisper)
    cross_attn_every: int = 0  # >0 => a cross-attn layer after every N self layers (vlm)
    frontend_seq: int = 0  # stub frontend output length (audio frames / patches)
    frontend_dim: int = 0  # stub frontend embedding width

    # --- numerics ---
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # --- remat policy (perf knob, see EXPERIMENTS.md §Perf) ---
    # "full":   recompute everything in bwd (baseline, paper-faithful default)
    # "save_collectives": checkpoint the TP-collective outputs (attn/mlp/moe
    #           block outputs) so the backward pass never re-runs all-reduces
    remat_policy: str = "full"

    # --- bookkeeping ---
    long_context_ok: bool = False  # sub-quadratic decode => run long_500k
    notes: str = ""

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    def reduced(self) -> "ArchConfig":
        """Same family/flavor at smoke-test scale (CPU-runnable)."""
        scale = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            param_dtype="float32",
            activation_dtype="float32",
        )
        if self.use_mla:
            scale.update(kv_lora_rank=32, rope_head_dim=16)
        if self.is_moe:
            # capacity_factor high enough to be drop-free at toy scale, so
            # consistency tests (full == prefill+decode) hold exactly.
            scale.update(num_experts=min(self.num_experts, 8),
                         top_k=min(self.top_k, 2), moe_d_ff=64,
                         capacity_factor=8.0)
        if self.ssm_state:
            scale.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
        if self.sliding_window:
            scale.update(sliding_window=32)
        if self.global_attn_layers:
            scale.update(global_attn_layers=(0, 2, 3))
        if self.encoder_layers:
            scale.update(encoder_layers=2)
        if self.frontend_seq:
            scale.update(frontend_seq=24, frontend_dim=scale["d_model"])
        if self.cross_attn_every:
            # keep num_layers divisible into (self*per + cross) groups
            scale.update(cross_attn_every=2, num_layers=6)
        return dataclasses.replace(self, name=self.name + "-reduced", **scale)

    def params_billion(self) -> float:
        """Rough parameter count (embedding + blocks), for roofline math."""
        d = self.d_model
        emb = self.vocab_size * d
        if self.use_mla:
            r, rh = self.kv_lora_rank, self.rope_head_dim
            attn = (d * self.num_heads * (self.head_dim + rh)  # q (nope+pe)
                    + d * (r + rh)  # kv down + k_pe
                    + r * self.num_heads * self.head_dim * 2  # k_up, v_up
                    + self.num_heads * self.head_dim * d)  # o
        else:
            attn = d * self.num_heads * self.head_dim + \
                2 * d * self.num_kv_heads * self.head_dim + \
                self.num_heads * self.head_dim * d
        if self.is_moe:
            moe = 3 * d * self.moe_d_ff * self.num_experts + d * self.num_experts
            moe += 3 * d * self.moe_d_ff * self.num_shared_experts
            dense_mlp = 3 * d * self.d_ff * self.first_dense_layers
            mlp_total = moe * (self.num_layers - self.first_dense_layers) + dense_mlp
        else:
            mlp_total = 3 * d * self.d_ff * self.num_layers if self.d_ff else 0
        ssm = 0
        if self.ssm_state:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = (d * (2 * di + 2 * ns + nh) + di * d + nh) * self.num_layers
        attn_total = attn * self.num_layers if self.num_heads else 0
        if self.ssm_state and not self.parallel_ssm:
            attn_total = 0
        enc = 0
        if self.is_enc_dec:
            # encoder self-attn + mlp, plus decoder cross-attn
            enc = (attn + 3 * d * self.d_ff) * self.encoder_layers + attn * self.num_layers
        if self.cross_attn_every:
            n_cross = self.num_layers // (self.cross_attn_every)
            enc += (attn + 3 * d * self.d_ff) * n_cross
        total = emb + attn_total + mlp_total + ssm + enc
        return total / 1e9

    def active_params_billion(self) -> float:
        """Active parameters per token (MoE top-k instead of all experts)."""
        if not self.is_moe:
            return self.params_billion()
        d = self.d_model
        full = self.params_billion()
        all_moe = 3 * d * self.moe_d_ff * self.num_experts * \
            (self.num_layers - self.first_dense_layers)
        act_moe = 3 * d * self.moe_d_ff * self.top_k * \
            (self.num_layers - self.first_dense_layers)
        return full - (all_moe - act_moe) / 1e9

"""LM model zoo: the 10 assigned architectures as config-driven JAX models."""
from .config import ArchConfig
from .model import Model, build_model, init_params

__all__ = ["ArchConfig", "Model", "build_model", "init_params"]

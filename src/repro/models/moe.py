"""Mixture-of-Experts with expert parallelism (sort-based capacity dispatch).

TPU-native formulation (see DESIGN.md §3): tokens stay replicated across
the `model` mesh axis inside the MoE block, experts are sharded over it.
Each shard dispatches only the tokens routed to ITS experts into a dense
(E_local, capacity, D) buffer (argsort + cumulative-rank, no (T, E, C)
one-hot tensor is ever built), runs the expert SwiGLUs as batched matmuls,
scatters weighted outputs back, and a single psum over the model axis
combines expert contributions — the same collective volume as a TP FFN.

Under pjit the block is wrapped in shard_map so the collective schedule is
explicit and auditable in the lowered HLO (the dry-run reads it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["moe_ffn", "router_topk", "moe_ffn_sharded"]


def router_topk(logits: jnp.ndarray, top_k: int):
    """Softmax-then-top-k with renormalized combine weights.

    logits: (T, E) fp32. Returns (weights (T, K), experts (T, K) int32,
    aux_loss scalar) — aux is the standard load-balance term E * sum(f * P).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    # f_e: fraction of tokens whose top-1 hits e; P_e: mean router prob.
    top1 = experts[:, 0]
    f = jnp.bincount(top1, length=e) / top1.shape[0]
    p_mean = probs.mean(0)
    aux = e * jnp.sum(f * p_mean)
    return weights, experts, aux


def _dispatch_combine(
    x: jnp.ndarray,  # (T, D)
    weights: jnp.ndarray,  # (T, K)
    experts: jnp.ndarray,  # (T, K) global expert ids
    w_gate: jnp.ndarray,  # (E_loc, D, F)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,  # (E_loc, F, D)
    e_start: int,
    capacity: int,
) -> jnp.ndarray:
    t, d = x.shape
    k = weights.shape[1]
    e_loc = w_gate.shape[0]

    flat_e = experts.reshape(-1) - e_start  # (T*K,) local expert index
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    local = (flat_e >= 0) & (flat_e < e_loc)
    # Non-local pairs sort to a sentinel bucket past the real experts.
    sort_key = jnp.where(local, flat_e, e_loc)
    order = jnp.argsort(sort_key, stable=True)
    se, st, sw = sort_key[order], flat_t[order], flat_w[order]
    # Rank within each expert via one-hot cumsum over E_loc lanes (cheap:
    # T*K x E_loc, with E_loc = E / model_parallelism).
    onehot = jax.nn.one_hot(se, e_loc, dtype=jnp.int32)
    prior = jnp.cumsum(onehot, axis=0) - onehot  # prior count per expert
    rank = jnp.take_along_axis(prior, jnp.minimum(se, e_loc - 1)[:, None], axis=1)[:, 0]
    keep = (se < e_loc) & (rank < capacity)
    slot = jnp.where(keep, se * capacity + rank, e_loc * capacity)  # overflow slot

    buf = jnp.zeros((e_loc * capacity + 1, d), x.dtype).at[slot].set(
        jnp.where(keep[:, None], x[st], 0))
    buf = buf[:-1].reshape(e_loc, capacity, d)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", g * u, w_down)  # (E_loc, C, D)

    y_flat = jnp.concatenate([y.reshape(e_loc * capacity, d),
                              jnp.zeros((1, d), y.dtype)])
    gathered = y_flat[slot] * sw[:, None].astype(y.dtype)  # (T*K, D)
    out = jnp.zeros((t, d), y.dtype).at[st].add(jnp.where(keep[:, None], gathered, 0))
    return out


def moe_ffn(
    x: jnp.ndarray,  # (B, S, D) or (T, D)
    p: dict,  # router (D, E); w_gate/w_up (E, D, F); w_down (E, F, D)
    top_k: int,
    capacity_factor: float = 1.25,
    e_start: int = 0,
    num_experts_global: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-shard MoE. Returns (out, aux_loss)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    t = x2.shape[0]
    e_glob = num_experts_global or p["w_gate"].shape[0]
    logits = jnp.einsum("td,de->te", x2, p["router"].astype(x2.dtype))
    weights, experts, aux = router_topk(logits, top_k)
    # Floor of top_k*2 keeps tiny decode batches drop-free (a dropped token
    # at serve time would silently change the served distribution).
    capacity = max(int(capacity_factor * t * top_k / e_glob), 2 * top_k)
    out = _dispatch_combine(x2, weights.astype(x2.dtype), experts,
                            p["w_gate"], p["w_up"], p["w_down"],
                            e_start, capacity)
    return out.reshape(shape), aux


def moe_ffn_sharded(
    x: jnp.ndarray,  # (B, S, D)
    p: dict,
    cfg,
    mesh: jax.sharding.Mesh,
    batch_axes: tuple[str, ...],
    expert_axis: str = "model",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map (see module docstring)."""
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[expert_axis]
    e_glob = cfg.num_experts
    assert e_glob % n_shards == 0, (e_glob, n_shards)

    def local(x_l, router, wg, wu, wd):
        idx = jax.lax.axis_index(expert_axis)
        e_loc = wg.shape[0]
        out, aux = moe_ffn(
            x_l, {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd},
            cfg.top_k, cfg.capacity_factor,
            e_start=idx * e_loc, num_experts_global=e_glob,
        )
        out = jax.lax.psum(out, expert_axis)
        aux = jax.lax.pmean(aux, expert_axis)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    x_spec = P(batch_axes if batch_axes else None, None, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec,
                  P(None, None),  # router replicated
                  P(expert_axis, None, None),
                  P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

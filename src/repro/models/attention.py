"""Attention: GQA / sliding-window / cross / decode, flash-style blockwise.

One position-mask-driven implementation covers every flavor the assigned
architectures need:
  * causal full attention (train / prefill),
  * grouped-query attention (no KV head repeat is materialized — the query
    is reshaped to (B, S, KVH, G, hd) and contractions keep the group dim),
  * sliding-window attention with an exact ring-buffer KV cache,
  * bidirectional encoder and cross attention (causal=False),
  * single-token decode against a KV cache.

Softmax runs in fp32 with the online (running max / denominator) update,
scanning over KV chunks so the score tensor never exceeds one
(B, Sq, KVH, G, chunk) block — this is what keeps 32k prefill and 512k
hybrid decode inside HBM.  Invalid cache slots carry position -1 and are
masked out, so ragged lengths need no special casing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["mha", "decode_attend", "init_kv_cache", "update_kv_cache"]

NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int | None):
    """(..., Sq, C) boolean validity from absolute positions.

    q_pos: (B, Sq); k_pos: (B, C).  k_pos == -1 marks empty cache slots.
    """
    valid = (k_pos >= 0)[:, None, :]  # (B, 1, C)
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        valid = valid & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    return valid  # (B, Sq, C)


def mha(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KVH, hd)
    v: jnp.ndarray,  # (B, Skv, KVH, hd)
    q_pos: jnp.ndarray,  # (B, Sq) int32
    k_pos: jnp.ndarray,  # (B, Skv) int32; -1 = invalid slot
    *,
    causal: bool = True,
    window: int | None = None,
    kv_chunk: int = 1024,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else hd**-0.5

    chunk = min(kv_chunk, skv)
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        skv += pad
    nc = skv // chunk

    qg = q.reshape(b, sq, kvh, g, hd)
    kc = k.reshape(b, nc, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, inputs):
        m, l, acc = carry  # (B,Sq,KVH,G), (B,Sq,KVH,G), (B,Sq,KVH,G,hd) fp32
        k_i, v_i, p_i = inputs  # (B,C,KVH,hd), (B,C,KVH,hd), (B,C)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k_i,
                       preferred_element_type=jnp.float32) * scale
        ok = _mask(q_pos, p_i, causal, window)  # (B,Sq,C)
        s = jnp.where(ok[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    from .layers import scan_unroll
    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc),
                                  unroll=scan_unroll())
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attend(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KVH, hd)
    v_cache: jnp.ndarray,
    cache_pos: jnp.ndarray,  # (B, S) int32 absolute positions, -1 = empty
    q_pos: jnp.ndarray,  # (B, 1)
    *,
    window: int | None = None,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    """Single-token decode: one fused pass (no chunk scan needed at Sq=1)."""
    b, _, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else hd**-0.5
    qg = q.reshape(b, 1, kvh, g, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    ok = _mask(q_pos, cache_pos, True, window)
    s = jnp.where(ok[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def init_kv_cache(batch: int, length: int, kvh: int, hd: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, length, kvh, hd), dtype),
        "v": jnp.zeros((batch, length, kvh, hd), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def update_kv_cache(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                    positions: jnp.ndarray) -> dict:
    """Write new K/V at their positions, modulo the cache length.

    Full caches (length >= max position) see the identity mapping; shorter
    (sliding-window) caches behave as ring buffers.  If more tokens arrive
    than the cache holds (SWA prefill), only the trailing `length` tokens
    are written so the newest entries deterministically win.

    k_new/v_new: (B, S_new, KVH, hd); positions: (B, S_new).
    """
    length = cache["k"].shape[1]
    s_new = k_new.shape[1]
    if s_new > length:
        k_new = k_new[:, -length:]
        v_new = v_new[:, -length:]
        positions = positions[:, -length:]
    slots = positions % length
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k = cache["k"].at[b_idx, slots].set(k_new)
    v = cache["v"].at[b_idx, slots].set(v_new)
    pos = cache["pos"].at[b_idx, slots].set(positions)
    return {"k": k, "v": v, "pos": pos}

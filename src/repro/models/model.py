"""Model assembly: ArchConfig -> init / train / prefill / decode.

Layer stacks are scanned over stacked (L, ...) parameters so the HLO (and
hence SPMD-partitioning and compile time) is independent of depth; any
heterogeneity is expressed as segment schedules over sliced stacks
(Hymba's global layers, DSv2's leading dense layer, the VLM's interleaved
cross-attention groups, whisper's encoder/decoder split).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .blocks import (attn_mlp_block, cross_block, cross_kv, enc_dec_block,
                     encoder_block, hybrid_block, init_block_cache, moe_block,
                     ssm_block)
from .config import ArchConfig
from .layers import DTYPES, cross_entropy_loss, rms_norm
from .init import init_params

__all__ = ["Model", "build_model", "init_params"]


def _slice_tree(tree, i0, i1):
    return jax.tree.map(lambda a: a[i0:i1], tree)


def _index_tree(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _stack_cache(single, n: int):
    return jax.tree.map(lambda a: jnp.repeat(a[None], n, axis=0), single)


def set_scan_unroll(flag: bool) -> None:
    from .layers import set_scan_unroll as _set
    _set(flag)


def _remat_policy(name: str):
    if name == "save_collectives":
        # Keep the tagged post-all-reduce block outputs; the bwd pass then
        # never re-runs the TP collectives (EXPERIMENTS.md §Perf).
        return jax.checkpoint_policies.save_only_these_names("tp_collective_out")
    return None  # "full": recompute everything


def _scan(body, x, stacked, caches, remat: bool, policy_name: str = "full"):
    """Scan `body(x, p_i, c_i) -> (x, c_i', aux_i)` over stacked layers."""
    from .layers import scan_unroll

    def f(carry, xs):
        h, aux = carry
        p_i, c_i = xs
        h, c_new, a = body(h, p_i, c_i)
        return (h, aux + a), c_new

    if remat:
        f = jax.checkpoint(f, prevent_cse=False, policy=_remat_policy(policy_name))
    (x, aux), new_caches = jax.lax.scan(f, (x, jnp.float32(0.0)), (stacked, caches),
                                        unroll=scan_unroll())
    return x, new_caches, aux


class Model:
    """Functional model bundle for one architecture config."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def init(self, key: jax.Array) -> dict:
        return init_params(self.cfg, key)

    # ------------------------------------------------------------- caches
    def init_caches(self, batch: int, cache_len: int) -> Any:
        cfg = self.cfg
        dt = DTYPES[cfg.activation_dtype]
        fam = cfg.family
        if fam in ("dense", "moe"):
            kind = "mla" if cfg.use_mla else "attn"
            single = init_block_cache(cfg, kind, batch, cache_len, dt)
            caches = {"layers": _stack_cache(single, cfg.num_layers - cfg.first_dense_layers)}
            if cfg.first_dense_layers:
                caches["dense0"] = _stack_cache(single, cfg.first_dense_layers)
            return caches
        if fam == "ssm":
            single = init_block_cache(cfg, "ssm", batch, cache_len, dt)
            return {"layers": _stack_cache(single, cfg.num_layers)}
        if fam == "hybrid":
            n_glob = len(cfg.global_attn_layers)
            swa = init_block_cache(cfg, "hybrid", batch, cache_len, dt,
                                   window_len=min(cfg.sliding_window, cache_len))
            glob = init_block_cache(cfg, "hybrid", batch, cache_len, dt)
            return {"swa": _stack_cache(swa, cfg.num_layers - n_glob),
                    "global": _stack_cache(glob, n_glob)}
        if fam == "vlm":
            per = cfg.cross_attn_every
            groups = cfg.num_layers // (per + 1)
            single = init_block_cache(cfg, "attn", batch, cache_len, dt)
            ck = {
                "k": jnp.zeros((groups, batch, cfg.frontend_seq, cfg.num_kv_heads,
                                cfg.head_dim), dt),
                "v": jnp.zeros((groups, batch, cfg.frontend_seq, cfg.num_kv_heads,
                                cfg.head_dim), dt),
                "pos": jnp.full((groups, batch, cfg.frontend_seq), -1, jnp.int32),
            }
            return {"self": _stack_cache(_stack_cache(single, per), groups),
                    "cross_kv": ck}
        if fam == "audio":
            single = init_block_cache(cfg, "attn", batch, cache_len, dt)
            ck = {
                "k": jnp.zeros((cfg.num_layers, batch, cfg.frontend_seq,
                                cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((cfg.num_layers, batch, cfg.frontend_seq,
                                cfg.num_kv_heads, cfg.head_dim), dt),
                "pos": jnp.full((cfg.num_layers, batch, cfg.frontend_seq), -1,
                                jnp.int32),
            }
            return {"layers": _stack_cache(single, cfg.num_layers), "cross": ck}
        raise ValueError(fam)

    # ------------------------------------------------------------ forward
    def forward(
        self,
        params: dict,
        tokens: jnp.ndarray,  # (B, S)
        *,
        mode: str = "train",
        caches: Any = None,
        positions: jnp.ndarray | None = None,
        frontend: jnp.ndarray | None = None,  # (B, Sf, Df) stub embeddings
        mesh_info=None,
        remat: bool = False,
        kv_chunk: int = 1024,
    ):
        """Returns (logits, caches, aux_loss)."""
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = params["embed"][tokens]
        fam = cfg.family

        if fam in ("dense", "moe"):
            x, caches, aux = self._fwd_decoder(params, x, positions, mode,
                                               caches, mesh_info, remat, kv_chunk)
        elif fam == "ssm":
            def body(h, p_i, c_i):
                h, c = ssm_block(p_i, h, positions, cfg, mode, c_i)
                return h, c, jnp.float32(0.0)
            lcaches = caches["layers"] if caches is not None else None
            x, lcaches, aux = _scan(body, x, params["layers"], lcaches, remat,
                                    self.cfg.remat_policy)
            caches = {"layers": lcaches} if lcaches is not None else None
        elif fam == "hybrid":
            x, caches, aux = self._fwd_hybrid(params, x, positions, mode,
                                              caches, remat, kv_chunk)
        elif fam == "vlm":
            x, caches, aux = self._fwd_vlm(params, x, positions, mode, caches,
                                           frontend, remat, kv_chunk)
        elif fam == "audio":
            x, caches, aux = self._fwd_audio(params, x, positions, mode, caches,
                                             frontend, remat, kv_chunk)
        else:
            raise ValueError(fam)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return logits, caches, aux

    # ------------------------------------------------- family sub-forwards
    def _fwd_decoder(self, params, x, positions, mode, caches, mesh_info,
                     remat, kv_chunk):
        cfg = self.cfg
        aux_total = jnp.float32(0.0)
        if cfg.first_dense_layers:
            d0 = caches["dense0"] if caches is not None else None
            for i in range(cfg.first_dense_layers):
                c_i = _index_tree(d0, i) if d0 is not None else None
                x, c_new = attn_mlp_block(_index_tree(params["dense0"], i), x,
                                          positions, cfg, mode, c_i,
                                          kv_chunk=kv_chunk)
                if d0 is not None:
                    d0 = jax.tree.map(lambda full, new, ii=i: full.at[ii].set(new),
                                      d0, c_new)
        if cfg.is_moe:
            def body(h, p_i, c_i):
                h, c, aux = moe_block(p_i, h, positions, cfg, mode, c_i,
                                      mesh_info, kv_chunk)
                return h, c, aux
        else:
            def body(h, p_i, c_i):
                h, c = attn_mlp_block(p_i, h, positions, cfg, mode, c_i,
                                      window=cfg.sliding_window, kv_chunk=kv_chunk)
                return h, c, jnp.float32(0.0)
        lcaches = caches["layers"] if caches is not None else None
        x, lcaches, aux = _scan(body, x, params["layers"], lcaches, remat,
                                cfg.remat_policy)
        aux_total = aux_total + aux
        if caches is not None:
            caches = dict(caches, layers=lcaches)
            if cfg.first_dense_layers:
                caches["dense0"] = d0
        return x, caches, aux_total

    def _fwd_hybrid(self, params, x, positions, mode, caches, remat, kv_chunk):
        cfg = self.cfg
        glob = sorted(cfg.global_attn_layers)
        n_layers = cfg.num_layers
        swa_c = caches["swa"] if caches is not None else None
        glob_c = caches["global"] if caches is not None else None

        def swa_body(h, p_i, c_i):
            h, c = hybrid_block(p_i, h, positions, cfg, mode, c_i,
                                window=cfg.sliding_window, kv_chunk=kv_chunk)
            return h, c, jnp.float32(0.0)

        swa_idx = 0
        new_swa, new_glob = [], []
        layer = 0
        for gi, gpos in enumerate(glob + [n_layers]):
            n_swa_seg = gpos - layer
            if n_swa_seg > 0:
                seg_p = _slice_tree(params["swa"], swa_idx, swa_idx + n_swa_seg)
                seg_c = (_slice_tree(swa_c, swa_idx, swa_idx + n_swa_seg)
                         if swa_c is not None else None)
                x, seg_c_new, _ = _scan(swa_body, x, seg_p, seg_c, remat,
                                        cfg.remat_policy)
                if seg_c_new is not None:
                    new_swa.append(seg_c_new)
                swa_idx += n_swa_seg
                layer = gpos
            if gpos < n_layers:
                c_i = _index_tree(glob_c, gi) if glob_c is not None else None
                x, c_new = hybrid_block(_index_tree(params["global"], gi), x,
                                        positions, cfg, mode, c_i, window=None,
                                        kv_chunk=kv_chunk)
                if c_new is not None:
                    new_glob.append(c_new)
                layer = gpos + 1
        if caches is not None:
            swa_out = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_swa) \
                if len(new_swa) > 1 else (new_swa[0] if new_swa else None)
            glob_out = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_glob) \
                if new_glob else None
            caches = {"swa": swa_out, "global": glob_out}
        return x, caches, jnp.float32(0.0)

    def _fwd_vlm(self, params, x, positions, mode, caches, frontend, remat,
                 kv_chunk):
        cfg = self.cfg
        per = cfg.cross_attn_every

        def self_body(h, p_i, c_i):
            h, c = attn_mlp_block(p_i, h, positions, cfg, mode, c_i,
                                  kv_chunk=kv_chunk)
            return h, c, jnp.float32(0.0)

        def group_body(h, gp_self, gp_cross, gc_self, gc_cross_kv):
            h, c_self, _ = _scan(self_body, h, gp_self, gc_self, remat,
                                 cfg.remat_policy)
            if mode == "decode":
                enc_kv = gc_cross_kv
            else:
                enc_kv = cross_kv(gp_cross["attn"], frontend, cfg)
            h = cross_block(gp_cross, h, enc_kv, cfg, mode)
            # Only persist cross K/V when building a decode cache.
            return h, c_self, (enc_kv if mode != "train" else None)

        def f(carry, xs):
            h = carry
            gp_self, gp_cross, gc_self, gc_ckv = xs
            h, c_self, enc_kv = group_body(h, gp_self, gp_cross, gc_self, gc_ckv)
            return h, (c_self, enc_kv)

        gc_self = caches["self"] if caches is not None else None
        gc_ckv = caches["cross_kv"] if caches is not None else None
        from .layers import scan_unroll
        x, (new_self, new_ckv) = jax.lax.scan(
            f, x, (params["self"], params["cross"], gc_self, gc_ckv),
            unroll=scan_unroll())
        if caches is not None:
            caches = {"self": new_self, "cross_kv": new_ckv}
        return x, caches, jnp.float32(0.0)

    def _fwd_audio(self, params, x, positions, mode, caches, frontend, remat,
                   kv_chunk):
        cfg = self.cfg
        if mode == "decode":
            enc_states = None  # cross K/V comes from the cache
        else:
            enc = frontend
            if "frontend_proj" in params:
                enc = jnp.einsum("bsd,de->bse", enc, params["frontend_proj"])
            enc_pos = jnp.broadcast_to(
                jnp.arange(enc.shape[1], dtype=jnp.int32), enc.shape[:2])

            def enc_body(h, p_i, c_i):
                return encoder_block(p_i, h, enc_pos, cfg, kv_chunk), None, jnp.float32(0.0)

            enc_states, _, _ = _scan(enc_body, enc, params["encoder"], None,
                                     remat, cfg.remat_policy)
            enc_states = rms_norm(enc_states, params["enc_norm"], cfg.norm_eps)

        def dec_body(h, xs_i):
            p_i, c_i, ckv_i = xs_i
            if mode == "decode":
                enc_kv = ckv_i
            else:
                enc_kv = cross_kv(p_i["cross_attn"], enc_states, cfg)
            h, c = enc_dec_block(p_i, h, positions, enc_kv, cfg, mode, c_i,
                                 kv_chunk)
            return h, (c, enc_kv if mode != "train" else None)

        def f(carry, xs):
            h = carry
            h, out = dec_body(h, xs)
            return h, out

        lcaches = caches["layers"] if caches is not None else None
        ckv = caches["cross"] if caches is not None else None
        from .layers import scan_unroll
        x, (new_caches, new_ckv) = jax.lax.scan(
            f, x, (params["layers"], lcaches, ckv),
            unroll=scan_unroll())
        if caches is not None:
            caches = {"layers": new_caches, "cross": new_ckv}
        return x, caches, jnp.float32(0.0)

    # --------------------------------------------------------------- loss
    def loss(self, params, batch, *, mesh_info=None, remat: bool = False,
             kv_chunk: int = 1024, aux_weight: float = 0.01):
        logits, _, aux = self.forward(
            params, batch["tokens"], mode="train",
            frontend=batch.get("frontend"), mesh_info=mesh_info, remat=remat,
            kv_chunk=kv_chunk)
        if "labels" in batch:
            ce = cross_entropy_loss(logits, batch["labels"])
        else:  # next-token prediction: shift by one
            ce = cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)

"""Parameter initialization: per-block init fns + stacked (vmapped) layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import DTYPES, init_dense

__all__ = ["init_params"]


def _attn_init(key, cfg: ArchConfig, dt):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": init_dense(k1, (d, h, hd), dt, fan_in=d),
        "wk": init_dense(k2, (d, kvh, hd), dt, fan_in=d),
        "wv": init_dense(k3, (d, kvh, hd), dt, fan_in=d),
        "wo": init_dense(k4, (h, hd, d), dt, fan_in=h * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _mla_init(key, cfg: ArchConfig, dt):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    r, rh = cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_q": init_dense(ks[0], (d, h, hd + rh), dt, fan_in=d),
        "w_dkv": init_dense(ks[1], (d, r), dt, fan_in=d),
        "w_kpe": init_dense(ks[2], (d, rh), dt, fan_in=d),
        "w_uk": init_dense(ks[3], (r, h, hd), dt, fan_in=r),
        "w_uv": init_dense(ks[4], (r, h, hd), dt, fan_in=r),
        "w_o": init_dense(ks[5], (h, hd, d), dt, fan_in=h * hd),
    }


def _mlp_init(key, d: int, f: int, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, (d, f), dt),
        "w_up": init_dense(k2, (d, f), dt),
        "w_down": init_dense(k3, (f, d), dt),
    }


def _moe_init(key, cfg: ArchConfig, dt):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": init_dense(k1, (d, e), jnp.float32),
        "w_gate": init_dense(k2, (e, d, f), dt, fan_in=d),
        "w_up": init_dense(k3, (e, d, f), dt, fan_in=d),
        "w_down": init_dense(k4, (e, f, d), dt, fan_in=f),
    }


def _mamba_init(key, cfg: ArchConfig, dt):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": init_dense(k1, (d, 2 * di + 2 * n + h), dt, fan_in=d),
        "conv_w": init_dense(k2, (cfg.conv_kernel, di + 2 * n), dt, fan_in=cfg.conv_kernel),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": init_dense(k3, (di, d), dt, fan_in=di),
    }


def _norm(d, dt):
    return jnp.ones((d,), dt)


def _dense_block_init(key, cfg: ArchConfig, dt):
    k1, k2 = jax.random.split(key)
    attn = _mla_init(k1, cfg, dt) if cfg.use_mla else _attn_init(k1, cfg, dt)
    return {
        "attn": attn,
        "mlp": _mlp_init(k2, cfg.d_model, cfg.d_ff, dt),
        "attn_norm": _norm(cfg.d_model, dt),
        "mlp_norm": _norm(cfg.d_model, dt),
    }


def _moe_block_init(key, cfg: ArchConfig, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    attn = _mla_init(k1, cfg, dt) if cfg.use_mla else _attn_init(k1, cfg, dt)
    p = {
        "attn": attn,
        "moe": _moe_init(k2, cfg, dt),
        "attn_norm": _norm(cfg.d_model, dt),
        "mlp_norm": _norm(cfg.d_model, dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = _mlp_init(k3, cfg.d_model,
                                cfg.moe_d_ff * cfg.num_shared_experts, dt)
    return p


def _ssm_block_init(key, cfg: ArchConfig, dt):
    return {
        "mamba": _mamba_init(key, cfg, dt),
        "pre_norm": _norm(cfg.d_model, dt),
    }


def _hybrid_block_init(key, cfg: ArchConfig, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn": _attn_init(k1, cfg, dt),
        "mamba": _mamba_init(k2, cfg, dt),
        "mlp": _mlp_init(k3, cfg.d_model, cfg.d_ff, dt),
        "attn_norm": _norm(cfg.d_model, dt),
        "attn_out_norm": _norm(cfg.d_model, dt),
        "ssm_out_norm": _norm(cfg.d_model, dt),
        "mlp_norm": _norm(cfg.d_model, dt),
    }


def _cross_block_init(key, cfg: ArchConfig, dt):
    k1, k2 = jax.random.split(key)
    return {
        "attn": _attn_init(k1, cfg, dt),
        "mlp": _mlp_init(k2, cfg.d_model, cfg.d_ff, dt),
        "attn_norm": _norm(cfg.d_model, dt),
        "mlp_norm": _norm(cfg.d_model, dt),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def _enc_dec_block_init(key, cfg: ArchConfig, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": _attn_init(k1, cfg, dt),
        "cross_attn": _attn_init(k2, cfg, dt),
        "mlp": _mlp_init(k3, cfg.d_model, cfg.d_ff, dt),
        "self_norm": _norm(cfg.d_model, dt),
        "cross_norm": _norm(cfg.d_model, dt),
        "mlp_norm": _norm(cfg.d_model, dt),
    }


def _encoder_block_init(key, cfg: ArchConfig, dt):
    k1, k2 = jax.random.split(key)
    return {
        "attn": _attn_init(k1, cfg, dt),
        "mlp": _mlp_init(k2, cfg.d_model, cfg.d_ff, dt),
        "attn_norm": _norm(cfg.d_model, dt),
        "mlp_norm": _norm(cfg.d_model, dt),
    }


def _stack(fn, key, n: int, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args))(keys)


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = DTYPES[cfg.param_dtype]
    kemb, khead, kblocks, kenc = jax.random.split(key, 4)
    params: dict = {
        "embed": init_dense(kemb, (cfg.vocab_size, cfg.d_model), dt, fan_in=cfg.d_model),
        "final_norm": _norm(cfg.d_model, dt),
        "lm_head": init_dense(khead, (cfg.d_model, cfg.vocab_size), dt),
    }
    fam = cfg.family
    if fam == "dense":
        params["layers"] = _stack(_dense_block_init, kblocks, cfg.num_layers, cfg, dt)
    elif fam == "moe":
        k1, k2 = jax.random.split(kblocks)
        n_moe = cfg.num_layers - cfg.first_dense_layers
        params["layers"] = _stack(_moe_block_init, k1, n_moe, cfg, dt)
        if cfg.first_dense_layers:
            params["dense0"] = _stack(_dense_block_init, k2,
                                      cfg.first_dense_layers, cfg, dt)
    elif fam == "ssm":
        params["layers"] = _stack(_ssm_block_init, kblocks, cfg.num_layers, cfg, dt)
    elif fam == "hybrid":
        k1, k2 = jax.random.split(kblocks)
        n_glob = len(cfg.global_attn_layers)
        params["swa"] = _stack(_hybrid_block_init, k1, cfg.num_layers - n_glob, cfg, dt)
        params["global"] = _stack(_hybrid_block_init, k2, n_glob, cfg, dt)
    elif fam == "vlm":
        k1, k2 = jax.random.split(kblocks)
        n_cross = cfg.num_layers // (cfg.cross_attn_every + 1)
        n_self = cfg.num_layers - n_cross
        per = cfg.cross_attn_every
        groups = n_self // per
        assert groups == n_cross, (n_self, n_cross, per)
        # Nested stack: (groups, per, ...) for self layers, (groups, ...) cross.
        params["self"] = _stack(
            lambda k, c, d: _stack(_dense_block_init, k, per, c, d), k1, groups, cfg, dt)
        params["cross"] = _stack(_cross_block_init, k2, groups, cfg, dt)
    elif fam == "audio":
        k1, k2, k3 = jax.random.split(kblocks, 3)
        params["encoder"] = _stack(_encoder_block_init, k1, cfg.encoder_layers, cfg, dt)
        params["enc_norm"] = _norm(cfg.d_model, dt)
        params["layers"] = _stack(_enc_dec_block_init, k2, cfg.num_layers, cfg, dt)
        if cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
            params["frontend_proj"] = init_dense(k3, (cfg.frontend_dim, cfg.d_model), dt)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params

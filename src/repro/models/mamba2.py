"""Mamba-2 (SSD, state-space duality) block: chunked train scan + O(1) decode.

Chunked SSD (arXiv:2405.21060 §6): the sequence is split into chunks of Q
tokens; within a chunk the contribution is a small attention-like quadratic
form (MXU-friendly), across chunks a single `lax.scan` carries the
(H, N, P) state.  Decode keeps a constant-size state — this is why the ssm
and hybrid architectures are the ones that run the long_500k shape.

Layout: x (B, S, H, P) head-split inner activations, B/C (B, S, N) with a
single B/C group, dt (B, S, H), A (H,) negative reals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rms_norm

__all__ = ["ssd_scan", "ssd_decode_step", "mamba_block", "mamba_decode",
           "init_mamba_cache"]


def _segsum(dA: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum_{j<k<=i} dA[k].

    dA: (..., Q); returns (..., Q, Q) with -inf above the diagonal.
    """
    q = dA.shape[-1]
    cum = jnp.cumsum(dA, axis=-1)
    # out[i, j] = cum[i] - cum[j] (sum over k in (j, i]); mask j > i.
    out = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    a: jnp.ndarray,  # (H,) negative
    b_in: jnp.ndarray,  # (B, S, N)
    c_in: jnp.ndarray,  # (B, S, N)
    chunk: int,
    init_state: jnp.ndarray | None = None,  # (B, H, N, P)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,N,P)). fp32 internals."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    s_orig = s
    if s % chunk:
        # Trailing pad: dt=0 => decay 1 and zero state contribution, so
        # causal outputs for the real positions are unaffected.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // chunk

    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b_in.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c_in.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    dA = dtf * a  # (B,nc,Q,H)

    # Intra-chunk (diagonal) term: attention-like with decay kernel L.
    seg = _segsum(dA.transpose(0, 1, 3, 2))  # (B,nc,H,Q,Q)
    ldecay = jnp.exp(seg)
    scores = jnp.einsum("bcin,bcjn->bcij", cf, bf)  # (B,nc,Q,Q)
    xdt = xf * dtf[..., None]  # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, ldecay, xdt)

    # Per-chunk end states: sum_j B_j decay(end, j) xdt_j.
    cum = jnp.cumsum(dA, axis=2)  # (B,nc,Q,H)
    total = cum[:, :, -1:, :]  # (B,nc,1,H)
    decay_to_end = jnp.exp(total - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bf, decay_to_end, xdt)

    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(total[:, :, 0, :])  # (B,nc,H)
    s0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st_in = carry  # (B,H,N,P)
        dec, st_chunk = inp  # (B,H), (B,H,N,P)
        st_out = st_in * dec[..., None, None] + st_chunk
        return st_out, st_in  # emit the state *entering* the chunk

    from .layers import scan_unroll
    dec_t = chunk_decay.transpose(1, 0, 2)  # (nc,B,H)
    st_t = states.transpose(1, 0, 2, 3, 4)  # (nc,B,H,N,P)
    final, entering = jax.lax.scan(step, s0, (dec_t, st_t), unroll=scan_unroll())
    entering = entering.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    # Off-diagonal term: state entering the chunk read out at each position.
    decay_from_start = jnp.exp(cum)  # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bcih,bchnp->bcihp", cf, decay_from_start, entering)

    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def ssd_decode_step(
    x: jnp.ndarray,  # (B, 1, H, P)
    dt: jnp.ndarray,  # (B, 1, H)
    a: jnp.ndarray,  # (H,)
    b_in: jnp.ndarray,  # (B, 1, N)
    c_in: jnp.ndarray,  # (B, 1, N)
    state: jnp.ndarray,  # (B, H, N, P) fp32
) -> tuple[jnp.ndarray, jnp.ndarray]:
    xf = x[:, 0].astype(jnp.float32)  # (B,H,P)
    dtf = dt[:, 0].astype(jnp.float32)  # (B,H)
    bf = b_in[:, 0].astype(jnp.float32)  # (B,N)
    cf = c_in[:, 0].astype(jnp.float32)
    dA = jnp.exp(dtf * a)  # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", bf, dtf, xf)
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cf, state)
    return y[:, None].astype(x.dtype), state


def _split_proj(z: jnp.ndarray, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zs = jnp.split(z, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    gate, xs, b_in, c_in, dt = zs
    return gate, xs, b_in, c_in, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, cache: jnp.ndarray | None):
    """Depthwise causal conv1d. u: (B, S, C); w: (K, C).

    Returns (out (B,S,C), new_cache (B, K-1, C)).
    """
    k = w.shape[0]
    if cache is None:
        cache = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([cache, u], axis=1)  # (B, S+K-1, C)
    out = sum(ext[:, i : i + u.shape[1]] * w[i] for i in range(k))
    new_cache = ext[:, -(k - 1):] if k > 1 else cache
    return jax.nn.silu(out), new_cache


def mamba_block(
    p: dict, x: jnp.ndarray, cfg,
    init_state: jnp.ndarray | None = None,
    conv_cache: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Full Mamba-2 mixer over a sequence. x: (B, S, D)."""
    b, s, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    gate, xs, b_in, c_in, dt = _split_proj(z, cfg)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    conv_out, conv_cache = _causal_conv(conv_in, p["conv_w"], conv_cache)
    xs, b_in, c_in = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(b, s, h, pdim)
    y, state = ssd_scan(xh, dt, a, b_in, c_in, cfg.ssm_chunk, init_state)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(gate), p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y.reshape(-1, cfg.d_inner), p["out_proj"])
    return out.reshape(b, s, -1), {"state": state, "conv": conv_cache}


def mamba_decode(
    p: dict, x: jnp.ndarray, cfg, cache: dict,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode. x: (B, 1, D); cache {state, conv}."""
    b = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    gate, xs, b_in, c_in, dt = _split_proj(z, cfg)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    conv_out, conv_cache = _causal_conv(conv_in, p["conv_w"], cache["conv"])
    xs, b_in, c_in = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(b, 1, h, pdim)
    y, state = ssd_decode_step(xh, dt, a, b_in, c_in, cache["state"])
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(b, 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(gate), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"state": state, "conv": conv_cache}


def init_mamba_cache(batch: int, cfg, dtype) -> dict:
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }

"""Pallas TPU kernels: dense (n, k) per-partition degree matrices.

Two modes share the (BM, BN, BK) tiled-matmul grid:

* ``part_degrees_pallas`` — edge-cut degrees A[i, kk] @ onehot(p)[kk, j].
  The one-hot factor is never materialized in HBM: each (BK, BN) tile is
  rebuilt on the fly inside the kernel by comparing the (BK, 1)
  partition-id block against a broadcasted column iota.  That keeps HBM
  traffic at the adjacency tiles alone and turns the refiner's per-vertex
  bincount into an MXU-saturating launch scoring every vertex against
  every partition at once.
* ``connectivity_matmul_pallas`` — the communication-volume analog
  B[i, kk] @ P[kk, j], where B is the hfire-weighted vertex×hyperedge
  incidence and P the per-hyperedge partition-presence matrix [Φ(e, p)
  thresholded].  P depends on the whole pin set, so unlike the one-hot it
  is a real (E, k) input rather than an in-kernel rebuild — the kernel is
  a straight tiled f32 matmul on the same block layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["part_degrees_pallas", "connectivity_matmul_pallas"]

BM = 128
BN = 128
BK = 128


def _degrees_kernel(adj_ref, part_ref, out_ref, *, nk: int):
    j = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pk = part_ref[...]  # (BK, 1) f32 partition ids (padding rows hold -1)
    cols = jax.lax.broadcasted_iota(jnp.float32, (BK, BN), 1) + j * BN
    onehot = (pk == cols).astype(jnp.float32)  # (BK, BN) tile, built in VMEM
    out_ref[...] += jnp.dot(adj_ref[...], onehot, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def part_degrees_pallas(
    adj: jnp.ndarray,
    part: jnp.ndarray,
    k: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """adj: (n, n) f32 dense adjacency; part: (n,) int. Returns (n, k) f32.

    Rows/columns are zero-padded to the 128-tile grid; padded partition
    entries are set to -1 so their one-hot rows are all zero (and padded
    adjacency columns are zero anyway).
    """
    n = adj.shape[0]
    npad = max(BM, -(-n // BM) * BM)
    kpad = max(BN, -(-k // BN) * BN)
    adj = adj.astype(jnp.float32)
    if npad != n:
        adj = jnp.pad(adj, ((0, npad - n), (0, npad - n)))
    pcol = jnp.full((npad, 1), -1.0, jnp.float32).at[:n, 0].set(
        part.astype(jnp.float32)
    )

    nk = npad // BK
    grid = (npad // BM, kpad // BN, nk)
    out = pl.pallas_call(
        functools.partial(_degrees_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),  # A[i, kk]
            pl.BlockSpec((BK, 1), lambda i, j, kk: (kk, 0)),  # part[kk]
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, kpad), jnp.float32),
        interpret=interpret,
    )(adj, pcol)
    return out[:n, :k]


def _matmul_kernel(a_ref, b_ref, out_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def connectivity_matmul_pallas(
    inc: jnp.ndarray,
    pres: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """inc: (n, E) f32 incidence; pres: (E, k) f32 presence.  Returns (n, k).

    The connectivity-mode degree matrix D* = inc @ pres as a tiled MXU
    matmul; inputs are zero-padded to the 128-tile grid (zero rows/columns
    contribute nothing to the accumulation).
    """
    n, ne = inc.shape
    k = pres.shape[1]
    npad = max(BM, -(-n // BM) * BM)
    epad = max(BK, -(-ne // BK) * BK)
    kpad = max(BN, -(-k // BN) * BN)
    inc = inc.astype(jnp.float32)
    pres = pres.astype(jnp.float32)
    if (npad, epad) != (n, ne):
        inc = jnp.pad(inc, ((0, npad - n), (0, epad - ne)))
    if (epad, kpad) != (ne, k):
        pres = jnp.pad(pres, ((0, epad - ne), (0, kpad - k)))

    grid = (npad // BM, kpad // BN, epad // BK)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),  # inc[i, kk]
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),  # pres[kk, j]
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, kpad), jnp.float32),
        interpret=interpret,
    )(inc, pres)
    return out[:n, :k]

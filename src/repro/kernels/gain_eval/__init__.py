from .ops import connectivity_degrees, gain_matrix, part_degrees
from .ref import connectivity_degrees_ref, gain_matrix_ref, part_degrees_ref

__all__ = [
    "part_degrees", "gain_matrix", "connectivity_degrees",
    "part_degrees_ref", "gain_matrix_ref", "connectivity_degrees_ref",
]

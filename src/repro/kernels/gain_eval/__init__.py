from .ops import gain_matrix, part_degrees
from .ref import gain_matrix_ref, part_degrees_ref

__all__ = ["part_degrees", "gain_matrix", "part_degrees_ref", "gain_matrix_ref"]

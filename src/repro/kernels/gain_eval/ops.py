"""Public wrappers: per-partition degree and gain matrix evaluation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import connectivity_matmul_pallas, part_degrees_pallas
from .ref import (
    connectivity_degrees_ref,
    gain_matrix_ref,
    part_degrees_ref,
    part_onehot,
)

__all__ = ["part_degrees", "gain_matrix", "connectivity_degrees"]


def part_degrees(
    adj: jnp.ndarray,
    part: jnp.ndarray,
    k: int,
    backend: str = "auto",
) -> jnp.ndarray:
    """(n, k) f32 per-partition degrees D[v, b] = sum_{u: part[u]=b} adj[v, u]."""
    if backend == "jnp":
        return part_degrees_ref(adj, part, k)
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return part_degrees_pallas(adj, part, k, interpret=not on_tpu)
    if backend == "pallas":
        return part_degrees_pallas(adj, part, k, interpret=False)
    if backend == "interpret":
        return part_degrees_pallas(adj, part, k, interpret=True)
    raise ValueError(f"unknown backend {backend!r}")


def connectivity_degrees(
    inc: jnp.ndarray,
    pres: jnp.ndarray,
    backend: str = "auto",
) -> jnp.ndarray:
    """(n, k) f32 connectivity-mode degrees D* = incidence @ presence."""
    if backend == "jnp":
        return connectivity_degrees_ref(inc, pres)
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return connectivity_matmul_pallas(inc, pres, interpret=not on_tpu)
    if backend == "pallas":
        return connectivity_matmul_pallas(inc, pres, interpret=False)
    if backend == "interpret":
        return connectivity_matmul_pallas(inc, pres, interpret=True)
    raise ValueError(f"unknown backend {backend!r}")


def gain_matrix(
    adj: jnp.ndarray,
    part: jnp.ndarray,
    k: int,
    backend: str = "auto",
) -> jnp.ndarray:
    """(n, k) f32 move gains (D minus own-column internal degree, 0 on own).

    The matmul dominates, so only the degree evaluation is kernelized; the
    gain epilogue is cheap O(nk) elementwise jnp shared by all backends.
    """
    if backend == "jnp":
        return gain_matrix_ref(adj, part, k)
    deg = part_degrees(adj, part, k, backend=backend)
    own = jnp.take_along_axis(deg, part[:, None].astype(jnp.int32), axis=1)
    return (deg - own) * (1.0 - part_onehot(part, k))

"""Pure-jnp oracle for the dense per-partition degree / gain matrices.

For a dense weighted adjacency A (n, n) and a partition vector p (n,),
the per-partition degree matrix is the one-hot matmul

    D = A @ onehot(p)          D[v, b] = sum of w(v, u) over u with p[u] = b

Column p[v] of row v is v's internal degree ID[v]; every other column is
the external degree ED[v]_b.  The move gain used by the batched refiner
(`repro.core.refine_vec`) is then pure elementwise arithmetic:

    gain[v, b] = D[v, b] - D[v, p[v]]     (0 in the own column)

This is the matrix form of the scalar refiner's per-vertex
``np.bincount`` — lifted so the Pallas kernel can evaluate every vertex
against every partition as a tiled MXU matmul.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "part_onehot",
    "part_degrees_ref",
    "gain_matrix_ref",
    "connectivity_degrees_ref",
]


def connectivity_degrees_ref(inc: jnp.ndarray, pres: jnp.ndarray) -> jnp.ndarray:
    """(n, k) f32 connectivity-mode degrees D* = incidence @ presence.

    ``inc`` is the hfire-weighted vertex×hyperedge incidence and ``pres``
    the per-hyperedge partition presence matrix; the product sums, per
    vertex and partition, the fire counts of incident hyperedges with a
    member present there (the volume objective's λ-gain matrix, see
    `repro.core.graph.volume_degrees`).
    """
    return inc.astype(jnp.float32) @ pres.astype(jnp.float32)


def part_onehot(part: jnp.ndarray, k: int) -> jnp.ndarray:
    """(n, k) f32 one-hot of the partition vector."""
    return (part[:, None] == jnp.arange(k, dtype=part.dtype)[None, :]).astype(
        jnp.float32
    )


def part_degrees_ref(adj: jnp.ndarray, part: jnp.ndarray, k: int) -> jnp.ndarray:
    """(n, k) f32 per-partition degree matrix D = A @ onehot(p)."""
    return adj.astype(jnp.float32) @ part_onehot(part, k)


def gain_matrix_ref(adj: jnp.ndarray, part: jnp.ndarray, k: int) -> jnp.ndarray:
    """(n, k) f32 move gains; own column is exactly zero."""
    deg = part_degrees_ref(adj, part, k)
    own = jnp.take_along_axis(deg, part[:, None].astype(jnp.int32), axis=1)
    gains = deg - own
    return gains * (1.0 - part_onehot(part, k))

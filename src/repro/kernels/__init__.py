"""Pallas TPU kernels for the toolchain's compute hot spots.

The paper's performance insight is replacing simulator calls with analytic
evaluation inside the mapping search loop; these kernels push that one
level further by making the evaluation itself a tiled on-chip reduction
and by batch-evaluating entire SA swap neighborhoods on the MXU.

  hop_eval   — Algorithm 1: traffic x Manhattan-distance reduction.
  swap_delta — all-pairs SA swap deltas via a fused S @ D matmul epilogue.
  gain_eval  — dense (n, k) refinement degrees/gains via one-hot matmul.
  lif_step   — LIF membrane update + spike detect (profiling hot spot).
  link_load  — per-link XY load histogram (edge variance / congestion).

Each kernel subpackage carries `kernel.py` (pl.pallas_call + BlockSpec),
`ops.py` (jit'd public wrapper, `interpret=` switch), and `ref.py` (the
pure-jnp oracle used by tests and as the CPU fallback).
"""

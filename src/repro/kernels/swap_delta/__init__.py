from .ops import swap_deltas
from .ref import swap_deltas_ref

__all__ = ["swap_deltas", "swap_deltas_ref"]

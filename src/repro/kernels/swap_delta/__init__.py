from .ops import swap_deltas, swap_deltas_pairs
from .ref import swap_deltas_ref

__all__ = ["swap_deltas", "swap_deltas_pairs", "swap_deltas_ref"]

"""Pure-jnp oracle for all-pairs SA swap deltas.

For symmetric traffic S = C + C^T and placed-distance matrix
D[i, j] = manhattan(place_i, place_j), the change in total hop-weighted
traffic when partitions a and b exchange cores is

  delta[a, b] = (S D)[a, b] + (D S)[a, b] - r[a] - r[b]
                - (S[a, a] + S[b, b] - 2 S[a, b]) * D[a, b]

with r[a] = sum_j S[a, j] D[a, j].  This is the matrix form of the paper's
O(K) incremental swap evaluation (`repro.core.hopcost.swap_delta`), lifted
to evaluate the *entire* O(K^2) neighborhood as two matmuls — the MXU
reformulation the Pallas kernel implements.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["swap_deltas_ref", "distance_matrix"]


def distance_matrix(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return (jnp.abs(x[:, None] - x[None, :]) + jnp.abs(y[:, None] - y[None, :])).astype(jnp.float32)


def swap_deltas_ref(sym: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """sym: (K, K) f32 symmetric traffic; x, y: (K,) f32. Returns (K, K) f32."""
    sym = sym.astype(jnp.float32)
    d = distance_matrix(x, y)
    sd = sym @ d
    ds = d @ sym
    r = jnp.sum(sym * d, axis=1)
    diag = jnp.diagonal(sym)
    delta = sd + ds - r[:, None] - r[None, :] - (diag[:, None] + diag[None, :] - 2.0 * sym) * d
    return delta

"""Pallas TPU kernel: all-pairs SA swap deltas as a fused MXU matmul.

Grid (i, j, kk): classic tiled matmul accumulation over kk for BOTH
products S@D and D@S; the distance tiles D[kk, j], D[i, kk], D[i, j] are
rebuilt on the fly from the (K,) coordinate vectors (D is never stored in
HBM).  The final kk step applies the epilogue

  out = SD + DS - r_i - r_j - (diag_i + diag_j - 2 S_ij) * D_ij

turning the paper's one-swap-at-a-time SA inner loop into a single
MXU-saturating launch that scores the entire O(K^2) neighborhood.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["swap_deltas_pallas"]

BM = 128
BN = 128
BK = 128


def _swap_kernel(
    s_ik_ref, s_kj_ref, s_ij_ref,
    xi_ref, yi_ref, xj_ref, yj_ref, xkr_ref, ykr_ref, xkc_ref, ykc_ref,
    r_i_ref, r_j_ref, diag_i_ref, diag_j_ref,
    out_ref, acc2_ref,
    *, nk: int,
):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    xi, yi = xi_ref[...], yi_ref[...]  # (BM, 1)
    xj, yj = xj_ref[...], yj_ref[...]  # (1, BN)
    xkr, ykr = xkr_ref[...], ykr_ref[...]  # (BK, 1)
    xkc, ykc = xkc_ref[...], ykc_ref[...]  # (1, BK)

    d_kj = jnp.abs(xkr - xj) + jnp.abs(ykr - yj)  # (BK, BN)
    d_ik = jnp.abs(xi - xkc) + jnp.abs(yi - ykc)  # (BM, BK)

    out_ref[...] += jnp.dot(s_ik_ref[...], d_kj, preferred_element_type=jnp.float32)
    acc2_ref[...] += jnp.dot(d_ik, s_kj_ref[...], preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _epilogue():
        d_ij = jnp.abs(xi - xj) + jnp.abs(yi - yj)  # (BM, BN)
        s_ij = s_ij_ref[...]
        out_ref[...] = (
            out_ref[...]
            + acc2_ref[...]
            - r_i_ref[...]
            - r_j_ref[...]
            - (diag_i_ref[...] + diag_j_ref[...] - 2.0 * s_ij) * d_ij
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def swap_deltas_pallas(
    sym: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """sym: (K, K) f32 symmetric padded traffic; x, y: (K,) f32 placed coords.

    Returns (K, K) f32 delta matrix.  Padded partitions (zero traffic rows)
    produce deltas that only involve zero traffic, i.e. exact zeros — safe.
    """
    k = sym.shape[0]
    kp = max(BM, -(-k // BM) * BM)
    pad = kp - k
    if pad:
        sym = jnp.pad(sym, ((0, pad), (0, pad)))
        # Padded coords at (0, 0): distance contributions are multiplied by
        # zero traffic everywhere, so the value is irrelevant.
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    sym = sym.astype(jnp.float32)
    xr = x.astype(jnp.float32).reshape(kp, 1)
    yr = y.astype(jnp.float32).reshape(kp, 1)
    xc = x.astype(jnp.float32).reshape(1, kp)
    yc = y.astype(jnp.float32).reshape(1, kp)

    # Cheap O(K^2) elementwise pre-pass (vs the O(K^3) matmul in-kernel).
    d = jnp.abs(xr - xc) + jnp.abs(yr - yc)
    r = jnp.sum(sym * d, axis=1, keepdims=True)  # (KP, 1)
    diag = jnp.diagonal(sym).reshape(kp, 1)

    nk = kp // BK
    grid = (kp // BM, kp // BN, nk)
    out = pl.pallas_call(
        functools.partial(_swap_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),  # S[i, kk]
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),  # S[kk, j]
            pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),  # S[i, j]
            pl.BlockSpec((BM, 1), lambda i, j, kk: (i, 0)),  # x rows
            pl.BlockSpec((BM, 1), lambda i, j, kk: (i, 0)),  # y rows
            pl.BlockSpec((1, BN), lambda i, j, kk: (0, j)),  # x cols
            pl.BlockSpec((1, BN), lambda i, j, kk: (0, j)),  # y cols
            pl.BlockSpec((BK, 1), lambda i, j, kk: (kk, 0)),  # x k-rows
            pl.BlockSpec((BK, 1), lambda i, j, kk: (kk, 0)),  # y k-rows
            pl.BlockSpec((1, BK), lambda i, j, kk: (0, kk)),  # x k-cols
            pl.BlockSpec((1, BK), lambda i, j, kk: (0, kk)),  # y k-cols
            pl.BlockSpec((BM, 1), lambda i, j, kk: (i, 0)),  # r rows
            pl.BlockSpec((1, BN), lambda i, j, kk: (0, j)),  # r cols
            pl.BlockSpec((BM, 1), lambda i, j, kk: (i, 0)),  # diag rows
            pl.BlockSpec((1, BN), lambda i, j, kk: (0, j)),  # diag cols
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kp, kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(sym, sym, sym, xr, yr, xc, yc, xr, yr, xc, yc, r, r.reshape(1, kp), diag,
      diag.reshape(1, kp))
    return out[:k, :k]

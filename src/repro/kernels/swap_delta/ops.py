"""Public wrapper: batched SA swap-delta evaluation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import swap_deltas_pallas
from .ref import swap_deltas_ref

__all__ = ["swap_deltas"]


def swap_deltas(
    sym: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    backend: str = "auto",
) -> jnp.ndarray:
    """(K, K) matrix of hop-cost deltas for swapping partitions a and b.

    `sym` must be the symmetrized traffic C + C^T (zero-padded to the core
    count if virtual partitions are in play).
    """
    if backend == "jnp":
        return swap_deltas_ref(sym, x.astype(jnp.float32), y.astype(jnp.float32))
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return swap_deltas_pallas(sym, x, y, interpret=not on_tpu)
    if backend == "pallas":
        return swap_deltas_pallas(sym, x, y, interpret=False)
    if backend == "interpret":
        return swap_deltas_pallas(sym, x, y, interpret=True)
    raise ValueError(f"unknown backend {backend!r}")


def swap_deltas_pairs(
    sym: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    aa,
    bb,
    backend: str = "auto",
):
    """Deltas of B specific candidate pairs, via the all-pairs batch.

    The batched mapping engine's device scoring path: one MXU launch
    scores the entire O(K^2) neighborhood, from which the proposed
    ``(aa[i], bb[i])`` candidates are gathered.  Cheaper than B separate
    incremental deltas whenever B is a reasonable fraction of K^2 — the
    crossover on real hardware is tracked with the `gain_eval`/`link_load`
    thresholds (see ROADMAP).
    """
    full = swap_deltas(sym, x, y, backend=backend)
    return full[jnp.asarray(aa), jnp.asarray(bb)]

"""Pallas TPU kernel: tiled hop-cost reduction (Algorithm 1 on the VPU).

The (K, K) traffic matrix is tiled into (BM, BN) VMEM blocks; each grid
step loads one block plus the matching row/column coordinate slices,
computes |dx| + |dy| on the fly (the distance matrix is never materialized
in HBM — at K = 16k partitions it would be 1 GiB), multiplies and reduces
on-chip, and accumulates into a scalar accumulator that lives in VMEM
across the serial grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hop_cost_pallas"]

# VPU-aligned tile: 8 sublanes x 128 lanes minimum for f32.
BM = 256
BN = 256


def _hop_kernel(traffic_ref, xr_ref, yr_ref, xc_ref, yc_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[0, 0] = jnp.float32(0.0)

    c = traffic_ref[...]  # (BM, BN)
    xr = xr_ref[...]  # (BM, 1)
    yr = yr_ref[...]
    xc = xc_ref[...]  # (1, BN)
    yc = yc_ref[...]
    dist = jnp.abs(xr - xc) + jnp.abs(yr - yc)  # (BM, BN) broadcast
    out_ref[0, 0] += jnp.sum(c * dist, dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hop_cost_pallas(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """traffic: (K, K) f32; x, y: (K,) f32. Returns scalar f32 total hop cost.

    K is padded to a multiple of the block size; padded traffic entries are
    zero so they contribute nothing.
    """
    k = traffic.shape[0]
    kp = max(BM, -(-k // BM) * BM)
    pad = kp - k
    if pad:
        traffic = jnp.pad(traffic, ((0, pad), (0, pad)))
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    xr = x.reshape(kp, 1)
    yr = y.reshape(kp, 1)
    xc = x.reshape(1, kp)
    yc = y.reshape(1, kp)
    grid = (kp // BM, kp // BN)
    out = pl.pallas_call(
        _hop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BN), lambda i, j: (i, j)),  # traffic tile
            pl.BlockSpec((BM, 1), lambda i, j: (i, 0)),  # row x
            pl.BlockSpec((BM, 1), lambda i, j: (i, 0)),  # row y
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),  # col x
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),  # col y
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(traffic.astype(jnp.float32), xr.astype(jnp.float32), yr.astype(jnp.float32),
      xc.astype(jnp.float32), yc.astype(jnp.float32))
    return out[0, 0]

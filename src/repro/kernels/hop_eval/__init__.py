from .ops import hop_cost
from .ref import hop_cost_ref

__all__ = ["hop_cost", "hop_cost_ref"]

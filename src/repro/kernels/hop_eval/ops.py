"""Public wrapper for the hop-cost kernel.

On CPU (this container) the Pallas kernel runs in interpret mode; on TPU
it compiles natively.  `backend="jnp"` selects the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import hop_cost_pallas
from .ref import hop_cost_ref

__all__ = ["hop_cost"]


def hop_cost(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    backend: str = "auto",
) -> jnp.ndarray:
    """Total hop-weighted traffic: sum C[a,b] * manhattan(a, b).

    backend: "auto" (pallas on TPU, interpret elsewhere), "pallas",
    "interpret", or "jnp" (oracle).
    """
    if backend == "jnp":
        return hop_cost_ref(traffic.astype(jnp.float32), x.astype(jnp.float32),
                            y.astype(jnp.float32))
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return hop_cost_pallas(traffic, x, y, interpret=not on_tpu)
    if backend == "pallas":
        return hop_cost_pallas(traffic, x, y, interpret=False)
    if backend == "interpret":
        return hop_cost_pallas(traffic, x, y, interpret=True)
    raise ValueError(f"unknown backend {backend!r}")

"""Pure-jnp oracle for the hop-cost reduction (paper Algorithm 1).

H_total = sum_{a,b} C[a,b] * (|x_a - x_b| + |y_a - y_b|)

where (x_i, y_i) is the mesh coordinate of the core partition i is placed
on.  `average hop` = H_total / trace_length (done by the caller: the
kernel's job is the O(K^2) contraction).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["hop_cost_ref"]


def hop_cost_ref(traffic: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """traffic: (K, K) f32; x, y: (K,) f32 placed coordinates. Returns scalar f32."""
    dx = jnp.abs(x[:, None] - x[None, :])
    dy = jnp.abs(y[:, None] - y[None, :])
    return jnp.sum(traffic * (dx + dy), dtype=jnp.float32)

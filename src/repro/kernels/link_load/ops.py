"""Public wrapper: per-link XY load maps + edge variance."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import link_loads_pallas
from .ref import link_loads_ref

__all__ = ["link_loads", "edge_variance"]


def link_loads(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    mesh_w: int,
    mesh_h: int,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    if backend == "jnp":
        return link_loads_ref(traffic, x, y, mesh_w, mesh_h)
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return link_loads_pallas(traffic, x, y, mesh_w=mesh_w, mesh_h=mesh_h,
                                 interpret=not on_tpu)
    if backend == "pallas":
        return link_loads_pallas(traffic, x, y, mesh_w=mesh_w, mesh_h=mesh_h,
                                 interpret=False)
    if backend == "interpret":
        return link_loads_pallas(traffic, x, y, mesh_w=mesh_w, mesh_h=mesh_h,
                                 interpret=True)
    raise ValueError(f"unknown backend {backend!r}")


def edge_variance(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    mesh_w: int,
    mesh_h: int,
    backend: str = "auto",
) -> jnp.ndarray:
    """Paper Eq. 4-5 over partition-level traffic (per-edge total hops)."""
    e, w_, s, n = link_loads(traffic, x, y, mesh_w, mesh_h, backend=backend)
    flat = jnp.concatenate([e.ravel(), w_.ravel(), s.ravel(), n.ravel()])
    return jnp.var(flat)

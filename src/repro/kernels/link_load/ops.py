"""Public wrappers: per-link XY load maps, edge variance, window screening.

``window_link_loads`` is the NoC replay's hot-path entry point: it turns a
batch of per-window core-to-core traffic matrices into flat per-link load
vectors (the ``repro.nocsim.xy`` directed-link id layout), which the
batched queued engine uses to screen contention-free windows without any
cycle stepping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nocsim.xy import link_count

from .kernel import link_loads_pallas
from .ref import link_loads_ref

__all__ = ["link_loads", "edge_variance", "flatten_link_maps",
           "window_link_loads"]


def link_loads(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    mesh_w: int,
    mesh_h: int,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    if backend == "jnp":
        return link_loads_ref(traffic, x, y, mesh_w, mesh_h)
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return link_loads_pallas(traffic, x, y, mesh_w=mesh_w, mesh_h=mesh_h,
                                 interpret=not on_tpu)
    if backend == "pallas":
        return link_loads_pallas(traffic, x, y, mesh_w=mesh_w, mesh_h=mesh_h,
                                 interpret=False)
    if backend == "interpret":
        return link_loads_pallas(traffic, x, y, mesh_w=mesh_w, mesh_h=mesh_h,
                                 interpret=True)
    raise ValueError(f"unknown backend {backend!r}")


def flatten_link_maps(
    e: jnp.ndarray, w_: jnp.ndarray, s: jnp.ndarray, n: jnp.ndarray,
    mesh_w: int, mesh_h: int,
) -> jnp.ndarray:
    """Concatenate (E, W, S, N) maps into the flat directed-link id layout.

    Row-major raveling of each map lands every entry exactly at its
    ``repro.nocsim.xy`` link id: ``east[y, x] -> y*(W-1)+x`` and so on for
    the W/S/N blocks, so the result aligns with ``link_ids_for_routes``
    bincounts.  Maps may arrive padded (Pallas kernel output); only the
    leading (H, W-1) / (W, H-1) blocks are real.
    """
    e = e[:mesh_h, :mesh_w - 1]
    w_ = w_[:mesh_h, :mesh_w - 1]
    s = s[:mesh_w, :mesh_h - 1]
    n = n[:mesh_w, :mesh_h - 1]
    return jnp.concatenate([e.ravel(), w_.ravel(), s.ravel(), n.ravel()])


def window_link_loads(
    traffic: np.ndarray,
    mesh_w: int,
    mesh_h: int,
    backend: str = "auto",
    chunk: int = 256,
) -> np.ndarray:
    """Per-window flat link loads from (B, K, K) core-to-core traffic.

    K must equal ``mesh_w * mesh_h`` (each matrix row/col is a mesh core in
    row-major coordinates).  Returns an int64 (B, num_links) array in the
    ``xy`` link id layout.  Loads are computed in f32 on device (exact for
    per-window counts below 2**24) and batched ``chunk`` windows at a time
    to bound device memory.
    """
    k = mesh_w * mesh_h
    if traffic.shape[-2:] != (k, k):
        raise ValueError(f"traffic must be (B, {k}, {k}), got {traffic.shape}")
    x = jnp.arange(k, dtype=jnp.int32) % mesh_w
    y = jnp.arange(k, dtype=jnp.int32) // mesh_w

    def one(c):
        maps = link_loads(c, x, y, mesh_w, mesh_h, backend=backend)
        return flatten_link_maps(*maps, mesh_w, mesh_h)

    # The jnp oracle vmaps cleanly; the Pallas kernel goes through lax.map
    # (a scan — one trace, no vmap batching rule needed for pallas_call).
    batched = jax.vmap(one) if backend == "jnp" else (lambda b: jax.lax.map(one, b))
    out = []
    for lo in range(0, traffic.shape[0], chunk):
        batch = jnp.asarray(traffic[lo:lo + chunk], dtype=jnp.float32)
        out.append(np.asarray(batched(batch)))
    nl = link_count(mesh_w, mesh_h)
    loads = np.concatenate(out) if out else np.empty((0, nl), dtype=np.float32)
    return np.rint(loads).astype(np.int64)


def edge_variance(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    mesh_w: int,
    mesh_h: int,
    backend: str = "auto",
) -> jnp.ndarray:
    """Paper Eq. 4-5 over partition-level traffic (per-edge total hops)."""
    e, w_, s, n = link_loads(traffic, x, y, mesh_w, mesh_h, backend=backend)
    flat = jnp.concatenate([e.ravel(), w_.ravel(), s.ravel(), n.ravel()])
    return jnp.var(flat)

"""Pure-jnp oracle for per-link XY load maps.

Under XY routing a packet from (xa, ya) to (xb, yb) first crosses the
horizontal links of row ya between xa and xb, then the vertical links of
column xb between ya and yb.  Summing partition-to-partition traffic over
those closed-form conditions yields the four directional load maps:

  east[y, w]  = sum C[a,b] * [ya==y] * [xa <= w <  xb]
  west[y, w]  = sum C[a,b] * [ya==y] * [xb <= w <  xa]
  south[x, q] = sum C[a,b] * [xb==x] * [ya <= q <  yb]
  north[x, q] = sum C[a,b] * [xb==x] * [yb <= q <  ya]

(w indexes the link between columns w and w+1; q the link between rows q
and q+1.)  Edge variance (paper Eq. 4-5) is the variance of the
concatenated maps.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["link_loads_ref"]


def link_loads_ref(
    traffic: jnp.ndarray,
    xa: jnp.ndarray,
    ya: jnp.ndarray,
    mesh_w: int,
    mesh_h: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """traffic: (K, K) f32; xa, ya: (K,) placed coords. Returns (E, W, S, N).

    E/W: (H, W-1); S/N: (W, H-1), all f32.
    """
    c = traffic.astype(jnp.float32)
    x = xa.astype(jnp.int32)
    y = ya.astype(jnp.int32)
    wlinks = jnp.arange(mesh_w - 1)
    hlinks = jnp.arange(mesh_h - 1)
    rows = jnp.arange(mesh_h)
    cols = jnp.arange(mesh_w)

    # (K, K, links) indicator stacks; fine at oracle scale.
    east_cond = (x[:, None, None] <= wlinks) & (wlinks < x[None, :, None])
    west_cond = (x[None, :, None] <= wlinks) & (wlinks < x[:, None, None])
    south_cond = (y[:, None, None] <= hlinks) & (hlinks < y[None, :, None])
    north_cond = (y[None, :, None] <= hlinks) & (hlinks < y[:, None, None])

    row_a = (y[:, None] == rows).astype(jnp.float32)  # (K, H)
    col_b = (x[:, None] == cols).astype(jnp.float32)  # (K, W)

    e_ab = c[:, :, None] * east_cond  # (K, K, W-1)
    w_ab = c[:, :, None] * west_cond
    s_ab = c[:, :, None] * south_cond
    n_ab = c[:, :, None] * north_cond

    east = jnp.einsum("abw,ah->hw", e_ab, row_a)
    west = jnp.einsum("abw,ah->hw", w_ab, row_a)
    south = jnp.einsum("abq,bx->xq", s_ab, col_b)
    north = jnp.einsum("abq,bx->xq", n_ab, col_b)
    return east, west, south, north

"""Pallas TPU kernel: per-link XY load maps via indicator matmuls.

The closed-form link-usage conditions (see ref.py) factor into
indicator-matrix products, turning the route histogram into MXU work:

  east = Y_a^T @ ( [w >= x_a] . (C @ [x_b > w]) )          (H, W-1)
  south = X_b^T @ ( [q < y_b] . (C^T-contract-a over [y_a <= q]) )

The grid walks row-bands of C (BM partitions at a time); every indicator
is rebuilt in VMEM from the coordinate vectors and a broadcasted iota, so
only C itself streams from HBM.  Output maps are (8, 128)-padded and
accumulated across the serial grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["link_loads_pallas"]

BM = 128
LANES = 128
SUB = 8


def _pad_to(n: int, m: int) -> int:
    return max(m, -(-n // m) * m)


def _kernel(c_ref, xa_ref, ya_ref, xb_ref, yb_ref,
            e_ref, w_ref, s_ref, n_ref,
            *, mesh_w: int, mesh_h: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        e_ref[...] = jnp.zeros_like(e_ref)
        w_ref[...] = jnp.zeros_like(w_ref)
        s_ref[...] = jnp.zeros_like(s_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    c = c_ref[...]  # (BM, K)
    xa = xa_ref[...]  # (BM, 1) f32
    ya = ya_ref[...]
    xb = xb_ref[...]  # (1, K)
    yb = yb_ref[...]
    k = c.shape[1]
    hp = e_ref.shape[0]  # padded H (rows of E/W maps)
    wp = e_ref.shape[1]  # padded W-1 lanes
    wp2 = s_ref.shape[0]  # padded W (rows of S/N maps)
    hq = s_ref.shape[1]  # padded H-1 lanes

    f32 = jnp.float32
    wlink = lax.broadcasted_iota(f32, (1, wp), 1)  # link index w
    qlink = lax.broadcasted_iota(f32, (1, hq), 1)  # link index q
    wvalid = wlink < (mesh_w - 1)
    qvalid = qlink < (mesh_h - 1)

    # ---- horizontal (row of a) ----
    u_e = jnp.where((xb.T > wlink) & wvalid, 1.0, 0.0)  # (K, Wp)
    u_w = jnp.where((xb.T <= wlink) & wvalid, 1.0, 0.0)
    t_e = jnp.dot(c, u_e, preferred_element_type=f32)  # (BM, Wp)
    t_w = jnp.dot(c, u_w, preferred_element_type=f32)
    m_ge = jnp.where(wlink >= xa, 1.0, 0.0)  # (BM, Wp) bcast
    m_lt = jnp.where(wlink < xa, 1.0, 0.0)
    hrow = lax.broadcasted_iota(f32, (BM, hp), 1)
    y_onehot = jnp.where(hrow == ya, 1.0, 0.0)  # (BM, Hp)
    e_ref[...] += lax.dot_general(y_onehot, t_e * m_ge,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32)
    w_ref[...] += lax.dot_general(y_onehot, t_w * m_lt,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32)

    # ---- vertical (column of b) ----
    v_s = jnp.where((qlink >= ya) & qvalid, 1.0, 0.0)  # (BM, Hq): [y_a <= q]
    v_n = jnp.where((qlink < ya) & qvalid, 1.0, 0.0)  # (BM, Hq): [q < y_a]
    p_s = lax.dot_general(c, v_s, (((0,), (0,)), ((), ())),
                          preferred_element_type=f32)  # (K, Hq)
    p_n = lax.dot_general(c, v_n, (((0,), (0,)), ((), ())),
                          preferred_element_type=f32)
    m_s = jnp.where(qlink < yb.T, 1.0, 0.0)  # (K, Hq): [q < y_b]
    m_n = jnp.where(qlink >= yb.T, 1.0, 0.0)  # (K, Hq): [y_b <= q]
    wcol = lax.broadcasted_iota(f32, (k, wp2), 1)
    x_onehot = jnp.where(wcol == xb.T, 1.0, 0.0)  # (K, Wp2)
    s_ref[...] += lax.dot_general(x_onehot, p_s * m_s,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32)
    n_ref[...] += lax.dot_general(x_onehot, p_n * m_n,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32)


@functools.partial(jax.jit, static_argnames=("mesh_w", "mesh_h", "interpret"))
def link_loads_pallas(
    traffic: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    mesh_w: int,
    mesh_h: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """traffic: (K, K) f32; x, y: (K,). Returns (E, W, S, N) load maps."""
    kk = traffic.shape[0]
    kp = _pad_to(kk, BM)
    pad = kp - kk
    if pad:
        traffic = jnp.pad(traffic, ((0, pad), (0, pad)))
        # Padded partitions carry zero traffic; coords (0,0) are harmless.
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    c = traffic.astype(jnp.float32)
    xr = x.astype(jnp.float32).reshape(kp, 1)
    yr = y.astype(jnp.float32).reshape(kp, 1)
    xc = x.astype(jnp.float32).reshape(1, kp)
    yc = y.astype(jnp.float32).reshape(1, kp)

    hp = _pad_to(mesh_h, SUB)
    wp = _pad_to(mesh_w - 1, LANES)
    wp2 = _pad_to(mesh_w, SUB)
    hq = _pad_to(mesh_h - 1, LANES)
    grid = (kp // BM,)
    e, w_, s, n = pl.pallas_call(
        functools.partial(_kernel, mesh_w=mesh_w, mesh_h=mesh_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, kp), lambda i: (i, 0)),  # C row band
            pl.BlockSpec((BM, 1), lambda i: (i, 0)),  # x_a
            pl.BlockSpec((BM, 1), lambda i: (i, 0)),  # y_a
            pl.BlockSpec((1, kp), lambda i: (0, 0)),  # x_b (full)
            pl.BlockSpec((1, kp), lambda i: (0, 0)),  # y_b (full)
        ],
        out_specs=[
            pl.BlockSpec((hp, wp), lambda i: (0, 0)),
            pl.BlockSpec((hp, wp), lambda i: (0, 0)),
            pl.BlockSpec((wp2, hq), lambda i: (0, 0)),
            pl.BlockSpec((wp2, hq), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hp, wp), jnp.float32),
            jax.ShapeDtypeStruct((hp, wp), jnp.float32),
            jax.ShapeDtypeStruct((wp2, hq), jnp.float32),
            jax.ShapeDtypeStruct((wp2, hq), jnp.float32),
        ],
        interpret=interpret,
    )(c, xr, yr, xc, yc)
    return (e[:mesh_h, : mesh_w - 1], w_[:mesh_h, : mesh_w - 1],
            s[:mesh_w, : mesh_h - 1], n[:mesh_w, : mesh_h - 1])

from .ops import edge_variance, flatten_link_maps, link_loads, window_link_loads
from .ref import link_loads_ref

__all__ = ["edge_variance", "flatten_link_maps", "link_loads",
           "link_loads_ref", "window_link_loads"]

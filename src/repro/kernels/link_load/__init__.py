from .ops import link_loads
from .ref import link_loads_ref

__all__ = ["link_loads", "link_loads_ref"]

from .ops import lif_step
from .ref import lif_step_ref

__all__ = ["lif_step", "lif_step_ref"]

"""Public wrapper for the LIF step kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import lif_step_pallas
from .ref import lif_step_ref

__all__ = ["lif_step"]


def lif_step(
    v: jnp.ndarray,
    refr: jnp.ndarray,
    current: jnp.ndarray,
    *,
    decay: float,
    threshold: float,
    v_reset: float,
    refractory: int,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    kw = dict(decay=float(decay), threshold=float(threshold),
              v_reset=float(v_reset), refractory=int(refractory))
    if backend == "jnp":
        return lif_step_ref(v, refr, current, **kw)
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return lif_step_pallas(v, refr, current, interpret=not on_tpu, **kw)
    if backend == "pallas":
        return lif_step_pallas(v, refr, current, interpret=False, **kw)
    if backend == "interpret":
        return lif_step_pallas(v, refr, current, interpret=True, **kw)
    raise ValueError(f"unknown backend {backend!r}")

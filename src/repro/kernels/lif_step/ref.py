"""Pure-jnp oracle for the LIF membrane-update step."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lif_step_ref"]


def lif_step_ref(
    v: jnp.ndarray,
    refr: jnp.ndarray,
    current: jnp.ndarray,
    *,
    decay: float,
    threshold: float,
    v_reset: float,
    refractory: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One LIF step over any shape. Returns (v', refr', fired:bool)."""
    active = refr <= 0
    v2 = jnp.where(active, decay * v + current, v)
    fired = active & (v2 >= threshold)
    v_out = jnp.where(fired, v_reset, v2)
    refr_out = jnp.where(fired, refractory, jnp.maximum(refr - 1, 0)).astype(refr.dtype)
    return v_out, refr_out, fired

"""Pallas TPU kernel: LIF membrane update + spike detect.

Elementwise VPU work tiled as (BR, 128) VMEM blocks over the flattened
neuron state.  This is the per-step hot spot of the profiling phase: at
population N and T time steps the simulator calls it T times (the synaptic
matmul between steps is XLA's job; keeping the state update fused in one
kernel avoids four separate HBM round-trips for v/refr/fired).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lif_step_pallas"]

BR = 8
LANES = 128


def _lif_kernel(v_ref, refr_ref, cur_ref, vo_ref, ro_ref, fo_ref,
                *, decay, threshold, v_reset, refractory):
    v = v_ref[...]
    refr = refr_ref[...]
    cur = cur_ref[...]
    active = refr <= 0
    v2 = jnp.where(active, decay * v + cur, v)
    fired = active & (v2 >= threshold)
    vo_ref[...] = jnp.where(fired, v_reset, v2)
    ro_ref[...] = jnp.where(fired, refractory, jnp.maximum(refr - 1, 0)).astype(refr.dtype)
    fo_ref[...] = fired.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("decay", "threshold", "v_reset",
                                              "refractory", "interpret"))
def lif_step_pallas(
    v: jnp.ndarray,
    refr: jnp.ndarray,
    current: jnp.ndarray,
    *,
    decay: float,
    threshold: float,
    v_reset: float,
    refractory: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """v, current: (N,) f32; refr: (N,) i32. Returns (v', refr', fired:bool)."""
    n = v.shape[0]
    tile = BR * LANES
    npad = max(tile, -(-n // tile) * tile)
    pad = npad - n

    def pad1(a, fill):
        return jnp.pad(a, (0, pad), constant_values=fill) if pad else a

    v2 = pad1(v.astype(jnp.float32), 0.0).reshape(-1, LANES)
    # Padding neurons sit in permanent refractory so they never fire.
    r2 = pad1(refr.astype(jnp.int32), 2**30).reshape(-1, LANES)
    c2 = pad1(current.astype(jnp.float32), 0.0).reshape(-1, LANES)
    rows = v2.shape[0]
    grid = (rows // BR,)
    vo, ro, fo = pl.pallas_call(
        functools.partial(_lif_kernel, decay=decay, threshold=threshold,
                          v_reset=v_reset, refractory=refractory),
        grid=grid,
        in_specs=[pl.BlockSpec((BR, LANES), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((BR, LANES), lambda i: (i, 0))] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(v2, r2, c2)
    return (vo.reshape(-1)[:n], ro.reshape(-1)[:n], fo.reshape(-1)[:n].astype(bool))

"""SNEAP partitioning phase: the multilevel driver (paper §3.3).

Coarsening -> initial partitioning -> uncoarsening with refinement, under
the neuromorphic-core capacity constraint (<= `capacity` neurons/core).

Two interchangeable engines drive the coarsen/refine hot path:

* ``impl="scalar"`` — the paper-faithful sequential algorithms
  (`coarsen.heavy_edge_matching` + `refine.refine_level`): random-order
  matching and a one-vertex-at-a-time FM-style priority queue.  Best cut
  quality; per-vertex Python loops make it O(n) interpreter iterations.
* ``impl="vec"`` — array-parallel engine
  (`coarsen.heavy_edge_matching_vec` + `refine_vec.refine_level_vec`):
  round-based mutual-proposal matching and batched conflict-free
  positive-gain refinement, all as whole-array numpy passes (with an
  optional `kernels.gain_eval` Pallas path on TPU).  Within a few percent
  of the scalar cut at a tiny fraction of the time — the engine to use
  for ≳10^4-neuron graphs.

Two objectives drive both engines (selected by ``objective``):

* ``objective="cut"`` — minimize spikes on cut synapses (`graph.edge_cut`),
  the paper's stated metric.
* ``objective="volume"`` — minimize the connectivity-(λ−1) communication
  volume (`graph.comm_volume`) over the multicast hypergraph attached to
  the profiled graph: a source pays its fire count once per *distinct*
  remote destination partition, matching what the multicast NoC simulator
  measures.  Requires ``graph.hyper`` (set by `snn.simulate.profile_snn`).

Both produce `validate_partition`-clean results and share every other
knob; `benchmarks/bench_partition.py` tracks their cut/time trade-off.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass

import numpy as np

from .coarsen import coarsen
from .graph import (
    Graph,
    Hypergraph,
    comm_volume,
    edge_cut,
    partition_weights,
    validate_partition,
)
from .initpart import greedy_region_growing
from .refine import uncoarsen

__all__ = ["PartitionResult", "sneap_partition"]

# Below this vertex count the vec engine routes to the scalar algorithms:
# array-parallel passes have nothing to amortize on tiny graphs, while the
# scalar FM queue's stronger hill-climbing still matters there (small-k
# cuts are seed-sensitive and label-propagation-style refinement stalls).
_VEC_MIN_N = 1024


@dataclass
class PartitionResult:
    part: np.ndarray  # (n,) partition id per neuron
    k: int
    edge_cut: int  # spikes communicated between partitions ("global traffic")
    capacity: int
    num_levels: int
    seconds: float
    impl: str = "scalar"
    objective: str = "cut"  # which metric refinement optimized
    comm_volume: int | None = None  # connectivity-(λ−1) volume, when hyper known

    def partition_sizes(self, graph: Graph) -> np.ndarray:
        return partition_weights(graph, self.part, self.k)


def sneap_partition(
    graph: Graph,
    capacity: int = 256,
    k: int | None = None,
    seed: int = 0,
    coarsen_to: int | None = None,
    max_nonimproving: int = 64,
    slack: float = 1.10,
    max_k: int | None = None,
    impl: str = "scalar",
    objective: str = "cut",
    hyper: Hypergraph | None = None,
    plateau_rounds: int | None = None,
    shards=None,
    stream_levels: bool = False,
) -> PartitionResult:
    """Partition an SNN graph into k parts of <= `capacity` neurons each.

    Args:
      graph: spike-weighted CSR graph from the profiling phase.
      capacity: neurons per neuromorphic core (256 for the paper's crossbars).
      k: number of partitions; default = ceil(total_neurons / capacity) with
         ~10% slack so refinement has room to move vertices.
      slack: multiplies k upward when k is derived (never above feasibility).
      impl: "scalar" (sequential reference) or "vec" (array-parallel
         matching + batched refinement; see module docstring).  "vec"
         adapts: graphs under ``_VEC_MIN_N`` vertices run the scalar
         algorithms outright, and during uncoarsening small few-partition
         *cut* levels delegate to the scalar FM refiner (`refine_vec`
         bounds); volume levels always use the vec refiner (incremental Φ
         + plateau walk — faster than the λ-gain FM queue at equal
         quality).
      objective: "cut" (spikes on cut synapses) or "volume" (multicast
         communication volume over the hypergraph; see module docstring).
      hyper: multicast hypergraph; defaults to ``graph.hyper`` and, when
         passed explicitly, overrides it (without mutating the caller's
         graph).  Required for ``objective="volume"``; when present,
         ``comm_volume`` is reported on the result under either objective.
      plateau_rounds: stall budget of the vec refiner's Jet-style
         zero/negative-gain plateau walk (quality <-> time knob; None =
         per-objective default, 0 disables).  Ignored by ``impl="scalar"``.
      shards: shard count (or ``sharding.planner.VertexShardPlan``) for the
         device-sharded vec engine: matching proposes per vertex-block edge
         slice and refinement evaluates per block against halo-assembled
         partition views, bounding per-shard peak memory.  Matching results
         are invariant under the shard count (hash tie keys on global edge
         ids) and refinement is identical to single-host for a fixed
         matching, so any two shard counts >= 1 produce the same partition.
         ``None`` keeps the original single-host rng paths byte-for-byte.
         Ignored by ``impl="scalar"``.
      stream_levels: spill each coarsening level to a temporary on-disk
         ``coarsen.LevelStore`` and uncoarsen out-of-core, holding at most
         two levels resident (vec impl only).  Same result as in-memory
         levels; trades re-load I/O for peak RSS.
    """
    if impl not in ("scalar", "vec"):
        raise ValueError(f"unknown partitioning impl {impl!r}")
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    if hyper is not None:
        # An explicit hypergraph wins over the attached one; rebind on a
        # shallow copy so the caller's graph is not mutated.
        graph = dataclasses.replace(graph, hyper=hyper)
    hyper = graph.hyper
    if objective == "volume" and hyper is None:
        raise ValueError(
            "objective='volume' needs the multicast hypergraph: pass hyper= or "
            "use a graph profiled by snn.simulate.profile_snn"
        )
    requested_impl = impl
    if impl == "vec" and graph.num_vertices < _VEC_MIN_N:
        impl = "scalar"
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    total = graph.total_vwgt
    min_k = math.ceil(total / capacity)
    if k is None:
        k = max(min_k, math.ceil(min_k * slack))
        if max_k is not None:
            k = min(k, max_k)  # cannot exceed the mesh's core count
    if k < min_k:
        deficit = total - k * capacity
        raise ValueError(
            f"k={k} infeasible: {total} neurons exceed {k} cores x capacity "
            f"{capacity} = {k * capacity} slots by {deficit}; need >= {min_k} "
            f"cores (or {math.ceil(total / k)} capacity)"
        )
    if coarsen_to is None:
        coarsen_to = max(4 * k, 128)

    # Coarse vertices must stay well under capacity or region growing jams.
    max_vwgt = max(1, capacity // 3)
    store = None
    if stream_levels and impl == "vec":
        from .coarsen import LevelStore

        store = LevelStore()
    levels = coarsen(graph, rng, coarsen_to=coarsen_to, max_vwgt=max_vwgt,
                     impl=impl, contract_hyper=objective == "volume",
                     shards=shards if impl == "vec" else None, store=store)
    coarse_part = greedy_region_growing(
        levels[-1], k, capacity, rng,
        impl="auto" if impl == "vec" else "scalar",
    )
    if impl == "vec":
        from .refine_vec import uncoarsen_vec

        part, score = uncoarsen_vec(levels, coarse_part, k, capacity,
                                    max_nonimproving, objective=objective,
                                    plateau_rounds=plateau_rounds,
                                    shards=shards)
    else:
        part, score = uncoarsen(levels, coarse_part, k, capacity,
                                max_nonimproving, objective=objective)
    num_levels = len(levels)
    if store is not None:
        store.close()
    seconds = time.perf_counter() - t0
    validate_partition(graph, part, k, capacity)
    if objective == "cut":
        cut = score
        assert cut == edge_cut(graph, part), "incremental cut bookkeeping diverged"
        vol = comm_volume(hyper, part) if hyper is not None else None
    else:
        vol = score
        assert vol == comm_volume(hyper, part), "incremental volume bookkeeping diverged"
        cut = edge_cut(graph, part)
    return PartitionResult(
        part=part, k=k, edge_cut=cut, capacity=capacity,
        num_levels=num_levels, seconds=seconds, impl=requested_impl,
        objective=objective, comm_volume=vol,
    )

"""Uncoarsening + boundary refinement (paper §3.3).

The partitioning of the coarsest graph is projected back level by level.
At every level a refinement pass runs with a single global priority queue:
vertices whose total external degree (ED) is >= their internal degree (ID)
enter the queue with gain = max_b ED[v]_b − ID[v]; the highest-gain vertex
moves to its best partition b (subject to core capacity).  Moves continue
until `x` consecutive moves fail to decrease the inter-partition edge
weight, at which point the trailing non-improving moves are undone.

As the paper notes, this single-queue / boundary-only scheme has weaker
hill-climbing than full Kernighan–Lin, but is dramatically faster — that
trade is the point of the multilevel paradigm.

This is the *scalar* refinement engine: best cut quality, O(n) Python
iterations per pass.  ``refine_vec.refine_level_vec`` is the batched
array-parallel alternative for large graphs; ``uncoarsen_vec`` picks
between the two per level (see `repro.core.partition` for the engine
overview).
"""
from __future__ import annotations

import heapq
import itertools

import numpy as np

from .graph import Graph

__all__ = ["refine_level", "project", "uncoarsen"]


def _degrees(graph: Graph, part: np.ndarray, v: int, k: int) -> tuple[int, np.ndarray]:
    """Return (ID[v], ED[v] as a (k,) array)."""
    nbrs, wgts = graph.neighbors(v)
    per_part = np.bincount(part[nbrs], weights=wgts, minlength=k)
    own = part[v]
    internal = per_part[own]
    per_part = per_part.copy()
    per_part[own] = 0
    return int(internal), per_part


def refine_level(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    max_passes: int = 4,
) -> tuple[np.ndarray, int]:
    """Refine `part` in place over up to `max_passes` FM-style passes.

    Returns (part, edge_cut).
    """
    from .graph import edge_cut, partition_weights

    part = part.astype(np.int64)
    pweight = partition_weights(graph, part, k)
    cut = edge_cut(graph, part)
    counter = itertools.count()

    for _ in range(max_passes):
        start_cut = cut
        locked = np.zeros(graph.num_vertices, dtype=bool)
        heap: list[tuple[int, int, int]] = []

        def push(v: int) -> None:
            internal, ext = _degrees(graph, part, v, k)
            if ext.sum() >= internal and ext.sum() > 0:
                b = int(np.argmax(ext))
                gain = int(ext[b]) - internal
                heapq.heappush(heap, (-gain, next(counter), v))

        for v in range(graph.num_vertices):
            push(v)

        history: list[tuple[int, int, int]] = []  # (vertex, from, to)
        best_cut = cut
        best_len = 0
        since_best = 0

        while heap and since_best < max_nonimproving:
            neg_gain, _, v = heapq.heappop(heap)
            if locked[v]:
                continue
            internal, ext = _degrees(graph, part, v, k)
            if ext.sum() == 0 or ext.sum() < internal:
                continue
            # Re-derive the best target under the capacity constraint.
            order = np.argsort(-ext, kind="stable")
            target = -1
            for b in order:
                if ext[b] <= 0:
                    break
                if pweight[b] + graph.vwgt[v] <= capacity:
                    target = int(b)
                    break
            if target < 0:
                continue
            gain = int(ext[target]) - internal
            if -neg_gain != gain:
                # Stale entry — requeue with the fresh gain.
                heapq.heappush(heap, (-gain, next(counter), v))
                continue

            src = int(part[v])
            part[v] = target
            pweight[src] -= graph.vwgt[v]
            pweight[target] += graph.vwgt[v]
            cut -= gain
            locked[v] = True
            history.append((v, src, target))
            if cut < best_cut:
                best_cut = cut
                best_len = len(history)
                since_best = 0
            else:
                since_best += 1
            nbrs, _ = graph.neighbors(v)
            for u in nbrs:
                if not locked[u]:
                    push(int(u))

        # Undo the trailing non-improving moves (paper: "the last x moves are undone").
        for v, src, target in reversed(history[best_len:]):
            part[v] = src
            pweight[src] += graph.vwgt[v]
            pweight[target] -= graph.vwgt[v]
        cut = best_cut

        if cut >= start_cut:
            break
    return part, cut


def project(coarse_part: np.ndarray, cmap: np.ndarray) -> np.ndarray:
    """Project a coarse partition vector onto the finer graph via cmap."""
    return coarse_part[cmap]


def uncoarsen(
    levels: list[Graph],
    coarse_part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
) -> tuple[np.ndarray, int]:
    """Walk levels coarse→fine, projecting and refining at each level."""
    part = coarse_part
    part, cut = refine_level(levels[-1], part, k, capacity, max_nonimproving)
    for fine, coarse in zip(reversed(levels[:-1]), reversed(levels[1:])):
        part = project(part, coarse.cmap)
        part, cut = refine_level(fine, part, k, capacity, max_nonimproving)
    return part, cut

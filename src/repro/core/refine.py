"""Uncoarsening + boundary refinement (paper §3.3).

The partitioning of the coarsest graph is projected back level by level.
At every level a refinement pass runs with a single global priority queue:
vertices whose total external degree (ED) is >= their internal degree (ID)
enter the queue with gain = max_b ED[v]_b − ID[v]; the highest-gain vertex
moves to its best partition b (subject to core capacity).  Moves continue
until `x` consecutive moves fail to decrease the objective, at which point
the trailing non-improving moves are undone.

Two objectives share the queue machinery (selected by ``objective``):

* ``"cut"`` — spikes on cut synapses; per-vertex degrees come from one
  ``np.bincount`` over the CSR neighborhood.
* ``"volume"`` — connectivity-(λ−1) communication volume over the graph's
  attached multicast hypergraph; the degree row is ``graph.volume_degrees``
  and the λ-gain of a move is exactly D*[v, target] − D*[v, own] (see
  ``repro.core.graph.volume_degrees``).

As the paper notes, this single-queue / boundary-only scheme has weaker
hill-climbing than full Kernighan–Lin, but is dramatically faster — that
trade is the point of the multilevel paradigm.

This is the *scalar* refinement engine: best quality, O(n) Python
iterations per pass.  ``refine_vec.refine_level_vec`` is the batched
array-parallel alternative for large graphs; ``uncoarsen_vec`` picks
between the two per level (see `repro.core.partition` for the engine
overview).
"""
from __future__ import annotations

import heapq
import itertools

import numpy as np

from .graph import (
    Graph,
    comm_volume,
    csr_gather,
    edge_cut,
    edge_partition_counts,
    presence_degrees,
)

__all__ = ["refine_level", "project", "uncoarsen", "CutState", "VolumeState"]

# Cap on rows * k entries a batched degree evaluation materializes at once
# (~128 MB of float64); larger batches are swept in row chunks.  Shared
# with the vec refiner.
_MAX_DEG_ENTRIES = 16_000_000


class CutState:
    """Stateless per-vertex (ID, ED) degrees for the edge-cut objective."""

    def __init__(self, graph: Graph, part: np.ndarray, k: int):
        self.graph = graph
        self.k = k
        self.eval_chunk = max(1, _MAX_DEG_ENTRIES // max(k, 1))

    def score(self, part: np.ndarray) -> int:
        return edge_cut(self.graph, part)

    def degrees(self, part: np.ndarray, v: int) -> tuple[int, np.ndarray]:
        nbrs, wgts = self.graph.neighbors(v)
        per_part = np.bincount(part[nbrs], weights=wgts, minlength=self.k)
        own = part[v]
        internal = per_part[own]
        per_part = per_part.copy()
        per_part[own] = 0
        return int(internal), per_part

    def degrees_rows(self, part: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """(R, k) degree matrix for a batch of vertices (own column included)."""
        g = self.graph
        eidx, local = csr_gather(g.xadj, rows)
        deg = np.bincount(
            local * self.k + part[g.adjncy[eidx]].astype(np.int64),
            weights=g.adjwgt[eidx],
            minlength=rows.shape[0] * self.k,
        )
        return deg.reshape(rows.shape[0], self.k)

    @staticmethod
    def admissible(internal: int, ext: np.ndarray) -> bool:
        """Paper's boundary filter: total external degree >= internal."""
        s = ext.sum()
        return s >= internal and s > 0

    @staticmethod
    def admissible_rows(internal: np.ndarray, ext: np.ndarray) -> np.ndarray:
        s = ext.sum(axis=1)
        return (s >= internal) & (s > 0)

    def apply_move(self, v: int, src: int, dst: int) -> None:
        pass  # degrees derive from `part` alone

    def touched(self, v: int, src: int, dst: int) -> np.ndarray:
        return self.graph.neighbors(v)[0]


class VolumeState:
    """Incremental λ-gain degrees for the communication-volume objective.

    Maintains the (E, k) member-count table Φ(e, p) across moves so each
    queue operation is a small gather over the vertex's incident hyperedges
    instead of a from-scratch recount: D*[v, b] = Σ_{e ∋ v} hfire[e] ×
    [Φ(e, b) > (b == part[v])], and the exact λ-gain of moving v from a to
    b is D*[v, b] − D*[v, a] (see ``graph.volume_degrees``).
    """

    # Below this n*k the queue churn of full FM exploration is affordable
    # and its hill-climbing (tentative negative-gain moves + undo) matters
    # most; above it, only non-negative-gain vertices enter the queue.
    _EXPLORE_NK = 1 << 14

    def __init__(self, graph: Graph, part: np.ndarray, k: int):
        if graph.hyper is None:
            raise ValueError("objective='volume' requires graph.hyper")
        self.hyper = graph.hyper
        self.k = k
        self.vxadj, self.vedges = self.hyper.incidence()
        self.phi = edge_partition_counts(self.hyper, part, k)
        self.hfire_f = self.hyper.hfire.astype(np.float64)
        self.explore = graph.num_vertices * k <= self._EXPLORE_NK
        # A batch's dense product scales with its incidence degree, not its
        # row count — bound the chunk by the expansion (see presence_degrees).
        avg_inc = ((self.hyper.num_pins + self.hyper.num_hyperedges)
                   / max(graph.num_vertices, 1))
        self.eval_chunk = max(1, int(_MAX_DEG_ENTRIES / (k * max(avg_inc, 1.0))))

    def score(self, part: np.ndarray) -> int:
        return comm_volume(self.hyper, part)

    def _incident(self, v: int) -> np.ndarray:
        return self.vedges[self.vxadj[v]:self.vxadj[v + 1]]

    def degrees(self, part: np.ndarray, v: int) -> tuple[int, np.ndarray]:
        eids = self._incident(v)
        own = int(part[v])
        if eids.shape[0] == 0:
            return 0, np.zeros(self.k)
        sub = self.phi[eids]
        pres = sub > 0
        pres[:, own] = sub[:, own] > 1  # v itself always sits in its own column
        row = self.hfire_f[eids] @ pres
        internal = row[own]
        row[own] = 0
        return int(internal), row

    def degrees_rows(self, part: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """(R, k) D* matrix for a batch of vertices from the live Φ table."""
        idx, local = csr_gather(self.vxadj, rows)
        eids = self.vedges[idx]
        counts = (self.vxadj[rows + 1] - self.vxadj[rows]).astype(np.int64)
        return presence_degrees(self.phi[eids], self.hfire_f[eids], counts,
                                local, part[rows], self.k)

    def admissible(self, internal: int, ext: np.ndarray) -> bool:
        """Queue filter.  The cut filter's ED-sum over k−1 presence columns
        almost always exceeds the own column, so it admits every vertex and
        the queue churns.  On small instances (``explore``) any vertex with
        external presence is queued — full FM hill-climbing via tentative
        negative-gain moves, where quality is seed-sensitive; at scale only
        non-negative best λ-gains enter (the undo window still explores
        plateaus via zero-gain moves)."""
        m = ext.max()
        if self.explore:
            return m > 0
        return m > 0 and m >= internal

    def admissible_rows(self, internal: np.ndarray, ext: np.ndarray) -> np.ndarray:
        m = ext.max(axis=1)
        if self.explore:
            return m > 0
        return (m > 0) & (m >= internal)

    def apply_move(self, v: int, src: int, dst: int) -> None:
        eids = self._incident(v)  # unique per vertex, so fancy-index is safe
        self.phi[eids, src] -= 1
        self.phi[eids, dst] += 1

    def apply_moves(self, movers: np.ndarray, srcs: np.ndarray,
                    dsts: np.ndarray) -> None:
        """Batch Φ update for a simultaneous mover set.

        Movers may share hyperedges — the fat conflict rounds admit several
        movers per edge when no presence indicator is at risk — so the same
        (hyperedge, column) slot can receive multiple ±1 updates.  Plain
        fancy indexing would silently drop the duplicates; instead the
        updates are merged per unique flat slot key (``edge * k + column``)
        and applied buffered, which is both exact and faster than the
        unbuffered ``np.add.at`` scatter.
        """
        idx, local = csr_gather(self.vxadj, movers)
        eids = self.vedges[idx]
        flat = self.phi.reshape(-1)
        sk, sc = np.unique(eids * self.k + srcs[local], return_counts=True)
        flat[sk] -= sc.astype(np.int32)
        dk, dc = np.unique(eids * self.k + dsts[local], return_counts=True)
        flat[dk] += dc.astype(np.int32)

    def touched_moves(self, movers: np.ndarray, srcs: np.ndarray,
                      dsts: np.ndarray) -> np.ndarray:
        """Batch form of ``touched`` for a simultaneous mover set.

        Call *after* ``apply_moves``; returns every vertex whose cached D*
        row may have changed, applying the same critical-edge filter (only
        hyperedges where a move crossed a presence threshold invalidate
        their members — see ``touched``).  Valid for fat batches too: the
        fat conflict predicate only admits multiple movers on a slot whose
        post-batch count stays >= 2, so any slot that can cross a presence
        threshold has exactly one mover and the per-move filter is exact;
        multi-mover slots stay at >= 2 members, which the ``<= 1`` /
        ``<= 2`` tests conservatively cover.
        """
        idx, local = csr_gather(self.vxadj, movers)
        eids = self.vedges[idx]
        critical = ((self.phi[eids, srcs[local]] <= 1)
                    | (self.phi[eids, dsts[local]] <= 2))
        eids = eids[critical]
        pidx, _ = csr_gather(self.hyper.hxadj, eids)
        return np.concatenate([self.hyper.hpins[pidx].astype(np.int64),
                               self.hyper.hsrc[eids].astype(np.int64)])

    def touched(self, v: int, src: int, dst: int) -> np.ndarray:
        """Members whose D* rows changed when v moved src→dst.

        Call *after* ``apply_move``.  A co-member's presence term for an
        edge e only flips when the move crossed a threshold: Φ(e, src)
        dropped to 0 or 1 (some member lost its last other-member there) or
        Φ(e, dst) rose to 1 or 2 (some member gained its first).  Edges
        between well-populated partitions are skipped entirely — most of
        them, on plateau-heavy volume landscapes.
        """
        eids = self._incident(v)
        critical = (self.phi[eids, src] <= 1) | (self.phi[eids, dst] <= 2)
        eids = eids[critical]
        pidx, _ = csr_gather(self.hyper.hxadj, eids)
        return np.concatenate([self.hyper.hpins[pidx].astype(np.int64),
                               self.hyper.hsrc[eids].astype(np.int64)])


_STATES = {"cut": CutState, "volume": VolumeState}


def refine_level(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    max_passes: int = 4,
    objective: str = "cut",
) -> tuple[np.ndarray, int]:
    """Refine `part` in place over up to `max_passes` FM-style passes.

    Returns (part, objective value) — edge cut or communication volume.
    """
    from .graph import partition_weights

    if objective not in _STATES:
        raise ValueError(f"unknown objective {objective!r}")
    part = part.astype(np.int64)
    state = _STATES[objective](graph, part, k)
    pweight = partition_weights(graph, part, k)
    cut = state.score(part)
    counter = itertools.count()

    _NOT_QUEUED = np.iinfo(np.int64).min

    for _ in range(max_passes):
        start_cut = cut
        locked = np.zeros(graph.num_vertices, dtype=bool)
        heap: list[tuple[int, int, int]] = []
        # Latest gain queued per vertex; pops whose entry disagrees are
        # stale and skipped without a degree recount, and re-evaluations
        # that leave the gain unchanged push no duplicate entry.
        queued_gain = np.full(graph.num_vertices, _NOT_QUEUED, dtype=np.int64)

        def push_chunk(rows: np.ndarray) -> None:
            deg = state.degrees_rows(part, rows)
            own = part[rows]
            r = np.arange(rows.shape[0])
            internal = deg[r, own].copy()
            deg[r, own] = 0
            adm = state.admissible_rows(internal, deg)
            targets = np.argmax(deg, axis=1)
            gains = (deg[r, targets] - internal).astype(np.int64)
            queued_gain[rows[~adm]] = _NOT_QUEUED  # invalidate old entries
            fresh = adm & (gains != queued_gain[rows])
            queued_gain[rows[fresh]] = gains[fresh]
            for v, gain in zip(rows[fresh], gains[fresh]):
                heapq.heappush(heap, (-int(gain), next(counter), int(v)))

        def push_many(rows: np.ndarray) -> None:
            """Batch-evaluate candidate rows and queue the admissible ones.

            One (R, k) degree matrix replaces R per-vertex recounts — the
            λ-gain path touches every member of every incident hyperedge,
            so the per-vertex form would dominate refinement time.
            Evaluated in chunks so the dense matrix (and the volume path's
            (incidence, k) product behind it) stays within the memory cap.
            """
            for lo in range(0, rows.shape[0], state.eval_chunk):
                push_chunk(rows[lo:lo + state.eval_chunk])

        push_many(np.arange(graph.num_vertices, dtype=np.int64))

        history: list[tuple[int, int, int]] = []  # (vertex, from, to)
        best_cut = cut
        best_len = 0
        since_best = 0

        while heap and since_best < max_nonimproving:
            neg_gain, _, v = heapq.heappop(heap)
            if locked[v] or queued_gain[v] != -neg_gain:
                continue  # locked, superseded, or invalidated entry
            internal, ext = state.degrees(part, v)
            if not state.admissible(internal, ext):
                queued_gain[v] = _NOT_QUEUED
                continue
            # Re-derive the best target under the capacity constraint.
            order = np.argsort(-ext, kind="stable")
            target = -1
            for b in order:
                if ext[b] <= 0:
                    break
                if pweight[b] + graph.vwgt[v] <= capacity:
                    target = int(b)
                    break
            if target < 0:
                # Invalidate so a later push_many (after capacity frees up)
                # re-queues the same gain instead of deduping it away.
                queued_gain[v] = _NOT_QUEUED
                continue
            gain = int(ext[target]) - internal
            if -neg_gain != gain:
                # Capacity rerouted the target — requeue with the real gain.
                queued_gain[v] = gain
                heapq.heappush(heap, (-gain, next(counter), v))
                continue

            src = int(part[v])
            part[v] = target
            pweight[src] -= graph.vwgt[v]
            pweight[target] += graph.vwgt[v]
            state.apply_move(v, src, target)
            cut -= gain
            locked[v] = True
            history.append((v, src, target))
            if cut < best_cut:
                best_cut = cut
                best_len = len(history)
                since_best = 0
            else:
                since_best += 1
            stale = np.unique(state.touched(v, src, target).astype(np.int64))
            push_many(stale[~locked[stale]])

        # Undo the trailing non-improving moves (paper: "the last x moves are undone").
        for v, src, target in reversed(history[best_len:]):
            part[v] = src
            pweight[src] += graph.vwgt[v]
            pweight[target] -= graph.vwgt[v]
            state.apply_move(v, target, src)
        cut = best_cut

        if cut >= start_cut:
            break
    return part, cut


def project(coarse_part: np.ndarray, cmap: np.ndarray) -> np.ndarray:
    """Project a coarse partition vector onto the finer graph via cmap."""
    return coarse_part[cmap]


def uncoarsen(
    levels: list[Graph],
    coarse_part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    objective: str = "cut",
) -> tuple[np.ndarray, int]:
    """Walk levels coarse→fine, projecting and refining at each level."""
    part = coarse_part
    part, cut = refine_level(levels[-1], part, k, capacity, max_nonimproving,
                             objective=objective)
    for fine, coarse in zip(reversed(levels[:-1]), reversed(levels[1:])):
        part = project(part, coarse.cmap)
        part, cut = refine_level(fine, part, k, capacity, max_nonimproving,
                                 objective=objective)
    return part, cut

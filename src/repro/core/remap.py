"""Incremental re-mapping after core failures (graceful degradation).

When cores die mid-run (see `repro.runtime.faults`), the live mapping is
broken in two ways: neurons hosted on the failed cores are unreachable,
and — if the mesh was packed — there may no longer be enough live cores
for one partition each.  This module repairs the mapping with as little
neuron movement as possible:

1. **Eviction** (only when the dead partitions cannot simply relocate,
   i.e. more real partitions than live cores): neurons of the failed
   cores' partitions are redistributed into surviving partitions under
   the capacity constraint, targets chosen by their external partition
   degrees (the refiner's own gain rows, `refine_vec.partition_degrees` /
   `graph.volume_degrees`), admitted per target through
   `graph.grouped_admission` — then a *bounded* `refine_level_vec` pass
   (``plateau_rounds=0``, ``forbid`` = the vacated partitions) recovers
   local cut quality without unbounded churn.
2. **Warm-started placement search**: the batched SA engine restarts
   from the live placement under a `placecost.MigrationAwareObjective`,
   which prices every position that leaves its live core at
   ``migration_cost`` x its neuron count (and makes dead cores
   prohibitively expensive for non-empty partitions), so hop/tree-hop
   gains are traded against bytes actually moved between cores.

`scratch_remap` is the from-scratch baseline the paper-style benchmarks
compare against: re-partition the whole SNN onto the surviving cores and
search a fresh placement, ignoring where neurons currently live.  Both
strategies return a `RemapResult` whose ``neurons_migrated`` counts
neurons whose *physical core* changed — the degradation benchmark's
headline metric next to the degraded energy/latency.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

import numpy as np

from .graph import (
    Graph,
    grouped_admission,
    partition_weights,
    validate_partition,
    volume_degrees,
)
from .hopcost import traffic_matrix
from .mapping import MappingResult, sa_search
from .partition import sneap_partition
from .placecost import MigrationAwareObjective, evaluate_placement, make_objective
from .refine_vec import partition_degrees, refine_level_vec

__all__ = [
    "RemapResult",
    "check_degraded_capacity",
    "evict_dead_partitions",
    "incremental_remap",
    "scratch_remap",
]


@dataclass
class RemapResult:
    part: np.ndarray  # (n,) repaired partition id per neuron
    placement: np.ndarray  # (num_cores,) full permutation, no real part on a dead core
    k: int
    strategy: str  # "incremental" | "scratch"
    neurons_migrated: int  # neurons whose physical core changed vs the live mapping
    neurons_evicted: int  # neurons reassigned out of failed partitions
    seconds: float
    mapping: MappingResult
    migration_cost: float  # per-neuron migration price the search used


def check_degraded_capacity(
    n_neurons: int, capacity: int, live_cores: int, what: str = "live cores"
) -> None:
    """Raise an actionable error when the degraded mesh cannot hold the SNN.

    Names the exact deficit: how many neurons exceed the surviving slot
    count and how many cores the network actually needs.
    """
    slots = int(capacity) * int(live_cores)
    n_neurons = int(n_neurons)
    if n_neurons > slots:
        deficit = n_neurons - slots
        need = math.ceil(n_neurons / max(int(capacity), 1))
        raise ValueError(
            f"degraded mesh infeasible: {n_neurons} neurons exceed "
            f"{live_cores} {what} x capacity {capacity} = {slots} slots by "
            f"{deficit}; the network needs >= {need} {what}"
        )


def _full_placement(placement: np.ndarray, num_cores: int) -> np.ndarray:
    """Extend a (k,) placement to a full (num_cores,) permutation.

    Virtual positions (empty partitions) take the unused cores in sorted
    order — they carry no traffic and no migration weight, so any
    deterministic completion is equivalent.
    """
    placement = np.asarray(placement, dtype=np.int64)
    if placement.shape[0] == num_cores:
        return placement.copy()
    used = np.zeros(num_cores, dtype=bool)
    used[placement] = True
    return np.concatenate([placement, np.flatnonzero(~used)])


def evict_dead_partitions(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    dead_parts: np.ndarray,
    objective: str = "cut",
    refine_iters: int = 8,
) -> tuple[np.ndarray, int]:
    """Vacate ``dead_parts`` by moving their neurons into survivors.

    Returns (new part vector, neurons evicted).  Targets are chosen
    greedily by each evicted neuron's external degree toward surviving
    partitions (cut) or its connectivity degree D* (volume) — the same
    gain rows the batched refiner uses — and admitted per target under
    the remaining headroom; rejected neurons retarget next round.  A
    bounded `refine_level_vec` pass (``forbid`` = the vacated partitions,
    no plateau walk) then cleans up the greedy seams; ``refine_iters=0``
    skips it for a pure minimal-movement eviction.
    """
    part = np.asarray(part, dtype=np.int64).copy()
    dead_parts = np.asarray(dead_parts, dtype=np.int64)
    forbid = np.zeros(k, dtype=bool)
    forbid[dead_parts] = True
    evicted = np.flatnonzero(forbid[part])
    if evicted.shape[0] == 0:
        return part, 0
    total = int(graph.vwgt.sum())
    check_degraded_capacity(
        total, capacity, k - int(forbid.sum()), what="surviving partitions"
    )
    hyper = graph.hyper
    if objective == "volume" and hyper is None:
        raise ValueError("objective='volume' eviction requires graph.hyper")

    pweight = partition_weights(graph, part, k)
    vwgt = graph.vwgt
    if objective == "volume":
        deg = volume_degrees(hyper, part, k, rows=evicted)
    else:
        deg = partition_degrees(graph, part, k, rows=evicted)
    deg[:, forbid] = -np.inf  # never target a vacated partition

    done = np.zeros(evicted.shape[0], dtype=bool)
    while not done.all():
        idx = np.flatnonzero(~done)
        verts = evicted[idx]
        headroom = capacity - pweight
        feasible = headroom[None, :] >= vwgt[verts][:, None]
        score = np.where(feasible, deg[idx], -np.inf)
        tgt = np.argmax(score, axis=1)
        valid = np.isfinite(score[np.arange(verts.shape[0]), tgt])
        if not valid.any():
            stuck = int(vwgt[verts].sum())
            room = int(np.maximum(headroom[~forbid], 0).sum())
            raise ValueError(
                f"eviction stalled: {stuck} neuron weight from failed "
                f"partitions exceeds the surviving partitions' remaining "
                f"headroom {room} (deficit {stuck - room}) under capacity "
                f"{capacity}"
            )
        sel, tg = idx[valid], tgt[valid]
        gains = deg[sel, tg]
        order = np.lexsort((sel, -gains, tg))
        sel, tg = sel[order], tg[order]
        admit = grouped_admission(tg, vwgt[evicted[sel]], headroom)
        # The top candidate of every target group fits its pre-round
        # headroom by construction, so each round makes progress.
        adm_idx, adm_tgt = sel[admit], tg[admit]
        part[evicted[adm_idx]] = adm_tgt
        np.add.at(pweight, adm_tgt, vwgt[evicted[adm_idx]])
        done[adm_idx] = True

    if refine_iters:
        part, _ = refine_level_vec(
            graph, part, k, capacity, max_iters=refine_iters,
            objective=objective, plateau_rounds=0, forbid=forbid,
        )
    validate_partition(graph, part, k, capacity)
    if forbid[part].any():  # pragma: no cover - forbid mask guarantees this
        raise RuntimeError("refine repopulated a vacated partition")
    return part, int(evicted.shape[0])


def _repair_dead(obj, full: np.ndarray, real_pos: np.ndarray,
                 dead: np.ndarray) -> np.ndarray:
    """Force any real partition left on a dead core onto a live one.

    The forbid penalty makes such states prohibitively expensive, so the
    SA chain all but never ends in one — this is the deterministic safety
    net that turns "all but never" into "never": each offender swaps with
    the cheapest weightless position currently on a live core.
    """
    viol = np.flatnonzero(real_pos & dead[full])
    if viol.shape[0] == 0:
        return full
    obj.attach(full)
    for j in viol:
        free = np.flatnonzero(~real_pos & ~dead[full])
        if free.shape[0] == 0:
            raise RuntimeError("no live core left for a displaced partition")
        deltas = obj.swap_delta_batch(np.full(free.shape[0], j), free)
        obj.apply_swaps(np.array([[j, int(free[np.argmin(deltas)])]]))
    return full


def incremental_remap(
    graph: Graph,
    part: np.ndarray,
    placement: np.ndarray,
    dead_cores: np.ndarray,
    trace_t: np.ndarray,
    trace_src: np.ndarray,
    trace_dst: np.ndarray,
    mesh_w: int,
    mesh_h: int,
    capacity: int = 256,
    cast: str = "unicast",
    place_objective: str = "pairwise",
    partition_objective: str = "cut",
    migration_cost: float | str = "auto",
    refine_iters: int = 8,
    evict: bool | str = "auto",
    seed: int = 0,
    mapper_kwargs: dict | None = None,
    k: int | None = None,
) -> RemapResult:
    """Repair a live mapping around failed cores with minimal migration.

    ``part``/``placement`` are the live partition vector and placement
    ((k,) or full permutation); ``dead_cores`` the (num_cores,) failure
    mask.  Eviction runs only when required (``evict="auto"``: more real
    partitions than live cores) or forced (``evict=True``) — when the
    mesh has spare live cores, relocating a failed core's partition
    wholesale migrates exactly its own neurons and keeps the partition
    coherent, which is strictly cheaper than scattering it.

    ``migration_cost="auto"`` prices moving *every* neuron at the live
    placement's full objective cost — i.e. moving a fraction f of the SNN
    must buy at least a fraction f of the current hop cost.  Pass an
    explicit per-neuron cost to tilt the trade-off.  ``mapper_kwargs``
    forwards to `mapping.sa_search` (default ``impl="vec"``).
    """
    t0 = time.perf_counter()
    num_cores = mesh_w * mesh_h
    dead = np.asarray(dead_cores, dtype=bool)
    if dead.shape[0] != num_cores:
        raise ValueError(
            f"dead_cores covers {dead.shape[0]} != {num_cores} cores"
        )
    part = np.asarray(part, dtype=np.int64)
    if k is None:
        k = int(part.max()) + 1
    total = int(graph.vwgt.sum())
    live_cores = num_cores - int(dead.sum())
    check_degraded_capacity(total, capacity, live_cores)
    old_full = _full_placement(placement, num_cores)
    w0 = partition_weights(graph, part, k)
    # Only *populated* partitions on dead cores need rescue; eviction is
    # mandatory only when the survivors plus the displaced can no longer
    # get one live core each (wholesale relocation is cheaper otherwise).
    dead_parts = np.flatnonzero(dead[old_full[:k]] & (w0 > 0))
    n_real = int((w0 > 0).sum())
    if evict is True:
        to_evict = dead_parts  # forced: vacate every failed partition
    elif evict == "auto" and n_real > live_cores:
        # Minimal merge: only the excess partitions beyond the live-core
        # count must dissolve; the other displaced ones relocate wholesale
        # (same neurons moved, partition kept coherent).  Evict the
        # smallest failed partitions — fewest neurons scattered.
        excess = n_real - live_cores
        to_evict = dead_parts[np.argsort(w0[dead_parts], kind="stable")[:excess]]
    else:
        to_evict = dead_parts[:0]
    part2, n_evicted = part.copy(), 0
    if to_evict.shape[0]:
        part2, n_evicted = evict_dead_partitions(
            graph, part2, k, capacity, to_evict,
            objective=partition_objective, refine_iters=refine_iters,
        )

    hyper = graph.hyper
    traffic = traffic_matrix(part2, trace_src, trace_dst, k,
                             trace_t=trace_t, cast=cast)
    trace_len = max(int(traffic.sum()), 1)
    base = make_objective(place_objective, traffic, num_cores, mesh_w,
                          mesh_h=mesh_h, hyper=hyper, part=part2)
    w = partition_weights(graph, part2, k).astype(np.float64)
    base_live = base.total(old_full)
    if migration_cost == "auto":
        migration_cost = base_live / max(total, 1)
    migration_cost = float(migration_cost)
    # Finite but unbeatable: no single swap's hop gain approaches 1e3x the
    # whole live cost, so SA never parks a real partition on a dead core —
    # yet deltas remain exact differences of totals (the metamorphic tests
    # check them on faulty meshes too).
    forbid_penalty = 1e3 * abs(base_live) + 1e6
    wrapper = MigrationAwareObjective(
        base, old_full, w, migration_cost, dead_cores=dead,
        forbid_penalty=forbid_penalty,
    )
    real_pos = np.zeros(num_cores, dtype=bool)
    real_pos[:k] = w > 0
    # Repair *before* the search: SA derives its initial temperature from
    # the seed placement's cost, and a seed still paying forbid penalties
    # (displaced partitions on their dead cores) would inflate T by ~1e3x
    # and turn the whole budget into a random walk.  Relocating the
    # violators first gives the chain a feasible, penalty-free start.
    start_full = _repair_dead(wrapper, old_full.copy(), real_pos, dead)
    mk = dict(impl="vec")
    mk.update(mapper_kwargs or {})
    mres = sa_search(traffic, num_cores, mesh_w, trace_len, seed=seed,
                     init=start_full, objective=wrapper, **mk)
    new_full = _full_placement(mres.placement, num_cores)
    new_full = _repair_dead(wrapper, new_full, real_pos, dead)
    mres.placement = new_full[:k].copy()
    mres.avg_hop, mres.tree_hop = evaluate_placement(
        mres.placement, traffic, num_cores, mesh_w, trace_len,
        mesh_h=mesh_h, hyper=hyper, part=part2,
    )

    moved = old_full[part] != new_full[part2]
    return RemapResult(
        part=part2, placement=new_full, k=k, strategy="incremental",
        neurons_migrated=int(graph.vwgt[moved].sum()),
        neurons_evicted=n_evicted,
        seconds=time.perf_counter() - t0, mapping=mres,
        migration_cost=migration_cost,
    )


def scratch_remap(
    graph: Graph,
    part: np.ndarray,
    placement: np.ndarray,
    dead_cores: np.ndarray,
    trace_t: np.ndarray,
    trace_src: np.ndarray,
    trace_dst: np.ndarray,
    mesh_w: int,
    mesh_h: int,
    capacity: int = 256,
    cast: str = "unicast",
    place_objective: str = "pairwise",
    partition_objective: str = "cut",
    partition_impl: str = "vec",
    seed: int = 0,
    mapper_kwargs: dict | None = None,
    partition_kwargs: dict | None = None,
) -> RemapResult:
    """From-scratch re-map onto the surviving cores (baseline strategy).

    Re-partitions the whole SNN (``max_k`` = live core count) and searches
    a fresh placement with migration priced at zero — only dead cores are
    forbidden.  The live mapping is used solely to count how many neurons
    the result would physically move.
    """
    t0 = time.perf_counter()
    num_cores = mesh_w * mesh_h
    dead = np.asarray(dead_cores, dtype=bool)
    if dead.shape[0] != num_cores:
        raise ValueError(
            f"dead_cores covers {dead.shape[0]} != {num_cores} cores"
        )
    part = np.asarray(part, dtype=np.int64)
    total = int(graph.vwgt.sum())
    live_cores = num_cores - int(dead.sum())
    check_degraded_capacity(total, capacity, live_cores)
    old_full = _full_placement(placement, num_cores)

    pres = sneap_partition(
        graph, capacity=capacity, seed=seed, max_k=live_cores,
        impl=partition_impl, objective=partition_objective,
        **(partition_kwargs or {}),
    )
    part2, k2 = pres.part, pres.k
    hyper = graph.hyper
    traffic = traffic_matrix(part2, trace_src, trace_dst, k2,
                             trace_t=trace_t, cast=cast)
    trace_len = max(int(traffic.sum()), 1)
    base = make_objective(place_objective, traffic, num_cores, mesh_w,
                          mesh_h=mesh_h, hyper=hyper, part=part2)
    w = partition_weights(graph, part2, k2).astype(np.float64)
    # Deterministic feasible seed: real partitions on the first live
    # cores, everything else (spare live cores, then dead ones) after.
    live_ids = np.flatnonzero(~dead)
    init_full = np.concatenate([live_ids, np.flatnonzero(dead)])
    forbid_penalty = 1e3 * abs(base.total(init_full)) + 1e6
    wrapper = MigrationAwareObjective(
        base, init_full, w, migration_cost=0.0, dead_cores=dead,
        forbid_penalty=forbid_penalty,
    )
    mk = dict(impl="vec")
    mk.update(mapper_kwargs or {})
    mres = sa_search(traffic, num_cores, mesh_w, trace_len, seed=seed,
                     init=init_full, objective=wrapper, **mk)
    new_full = _full_placement(mres.placement, num_cores)
    real_pos = np.zeros(num_cores, dtype=bool)
    real_pos[:k2] = w > 0
    new_full = _repair_dead(wrapper, new_full, real_pos, dead)
    mres.placement = new_full[:k2].copy()
    mres.avg_hop, mres.tree_hop = evaluate_placement(
        mres.placement, traffic, num_cores, mesh_w, trace_len,
        mesh_h=mesh_h, hyper=hyper, part=part2,
    )

    moved = old_full[part] != new_full[part2]
    return RemapResult(
        part=part2, placement=new_full, k=k2, strategy="scratch",
        neurons_migrated=int(graph.vwgt[moved].sum()),
        neurons_evicted=0,
        seconds=time.perf_counter() - t0, mapping=mres,
        migration_cost=0.0,
    )

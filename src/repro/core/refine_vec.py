"""Array-parallel boundary refinement (the "vec" partitioning engine).

The scalar engine in ``refine.py`` follows the paper: a single global
priority queue pops one boundary vertex at a time, re-deriving its
per-partition external degrees with a fresh ``np.bincount`` per pop.  That
is O(n) Python iterations per pass and dominates end-to-end partitioning
time on large SNNs.

This module is the Jet/label-propagation-style alternative: one shot of

    ``np.bincount(row * k + part[adjncy], weights=adjwgt)``

produces the external degree of *every* boundary vertex toward *every*
partition simultaneously; gains for all boundary vertices follow by
elementwise arithmetic, and a conflict-free batch of positive-gain moves
is applied per iteration:

1. every boundary vertex picks its best feasible target partition
   (capacity-checked against the pre-batch partition weights);
2. candidates adjacent to a higher-gain candidate are suppressed (one
   Luby-style round), so the surviving movers form an independent set and
   their gains are exact and additive;
3. movers are admitted in gain order per target partition under the
   remaining capacity (grouped cumulative-sum bookkeeping, no Python
   loop over vertices);
4. repeat until no positive-gain move exists (a fixed point).

Each iteration strictly decreases the integer edge cut, so termination is
guaranteed.  The batch scheme has weaker hill-climbing than the scalar
FM-style queue (no tentative negative-gain moves), which is why
``sneap_partition`` accepts both engines and the tests hold the vec cut to
a small tolerance of the scalar cut rather than equality.

For large k the dense per-partition degree matrix is also expressible as
``A @ onehot(part)`` — a tiled one-hot matmul the MXU eats for breakfast;
``repro.kernels.gain_eval`` implements exactly that and is used here when
running on TPU with a graph small enough to densify (coarse levels).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph, edge_cut, partition_weights
from .refine import project, refine_level

__all__ = ["partition_degrees", "refine_level_vec", "uncoarsen_vec"]

# Small-problem delegation bounds.  At few partitions the batched
# positive-gain passes stall in local optima that the scalar FM queue
# escapes (it tries negative-gain moves and undoes the failures), and the
# queue is cheap there — so `uncoarsen_vec` hands levels with
# n * k <= _SCALAR_NK and k <= _SCALAR_MAX_K to the scalar refiner.  Both
# bounds matter: FM's per-move cost grows with k (a bincount plus a sort
# of the k-wide degree vector per queue operation), so delegating a
# many-partition level would burn the very speedup this module exists for.
_SCALAR_NK = 1 << 20
_SCALAR_MAX_K = 64

# Densifying the adjacency for the gain_eval kernel is only worthwhile on
# TPU and only for graphs whose dense (n, n) form fits comfortably in HBM.
_KERNEL_MAX_N = 4096
_KERNEL_MIN_K = 64

# Cap on boundary_rows * k entries materialized at once by the numpy path
# (~128 MB of float64); larger boundaries are swept in row chunks.
_MAX_DEG_ENTRIES = 16_000_000


def _row_edges(graph: Graph, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Gather the CSR edges of ``rows``: (edge index array, local row id array)."""
    xadj = graph.xadj
    counts = (xadj[rows + 1] - xadj[rows]).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    # Ranges-to-indices: start of each row repeated, plus a within-row ramp.
    starts = np.repeat(xadj[rows], counts)
    ramp = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    local = np.repeat(np.arange(rows.shape[0], dtype=np.int64), counts)
    return starts + ramp, local


def partition_degrees(
    graph: Graph,
    part: np.ndarray,
    k: int,
    rows: np.ndarray | None = None,
) -> np.ndarray:
    """(R, k) weighted histogram of neighbor partitions for each row vertex.

    Column ``part[v]`` of row v holds v's internal degree; every other
    column b holds the external degree ED[v]_b.  ``rows=None`` computes all
    n rows (the issue's one-shot formula); passing the boundary-vertex
    subset keeps the matrix small on fine levels.
    """
    if rows is None:
        rows = np.arange(graph.num_vertices, dtype=np.int64)
    eidx, local = _row_edges(graph, rows)
    deg = np.bincount(
        local * k + part[graph.adjncy[eidx]].astype(np.int64),
        weights=graph.adjwgt[eidx],
        minlength=rows.shape[0] * k,
    )
    return deg.reshape(rows.shape[0], k)


def _dense_adjacency(graph: Graph) -> np.ndarray:
    """(n, n) f32 dense adjacency for the gain_eval kernel path."""
    n = graph.num_vertices
    adj = np.zeros((n, n), dtype=np.float32)
    src = np.repeat(np.arange(n), np.diff(graph.xadj))
    adj[src, graph.adjncy] = graph.adjwgt
    return adj


def _degrees_via_kernel(adj: np.ndarray, part: np.ndarray, k: int,
                        rows: np.ndarray, backend: str) -> np.ndarray:
    """Row-subset degrees via the gain_eval tiled one-hot matmul kernel."""
    import jax.numpy as jnp

    from repro.kernels.gain_eval import part_degrees

    deg = part_degrees(jnp.asarray(adj), jnp.asarray(part, jnp.int32), k,
                       backend=backend)
    return np.asarray(deg, dtype=np.float64)[rows]


def refine_level_vec(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    max_iters: int = 200,
    use_kernel: bool | None = None,
    kernel_backend: str = "auto",
) -> tuple[np.ndarray, int]:
    """Refine ``part`` by batched positive-gain moves; returns (part, cut).

    ``use_kernel=None`` auto-enables the gain_eval Pallas path on TPU for
    levels small enough to densify — and only when the total edge weight
    fits in float32's exact-integer range (< 2^24), since the kernel
    accumulates spike counts in f32 and the incremental cut bookkeeping
    demands exact integer gains.  True forces it (tests run it in
    interpret mode via ``kernel_backend="interpret"``), False keeps the
    pure-numpy (exact float64) bincount path.
    """
    part = part.astype(np.int64).copy()
    n = graph.num_vertices
    xadj, adjncy, adjwgt, vwgt = graph.xadj, graph.adjncy, graph.adjwgt, graph.vwgt
    pweight = partition_weights(graph, part, k)
    cut = edge_cut(graph, part)
    if graph.adjncy.shape[0] == 0:
        return part, cut
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(xadj))
    nbr = adjncy.astype(np.int64)
    if use_kernel is None:
        use_kernel = False
        if (n <= _KERNEL_MAX_N and k >= _KERNEL_MIN_K
                and int(adjwgt.sum()) < (1 << 24)):
            try:
                import jax

                use_kernel = jax.default_backend() == "tpu"
            except Exception:
                use_kernel = False

    adj_dense = _dense_adjacency(graph) if use_kernel else None
    chunk = max(1, _MAX_DEG_ENTRIES // max(k, 1))
    # Cached per-vertex move state.  A cached (gain, target) stays exact
    # until a neighbor moves (gains depend only on neighbor partitions) or
    # the vertex itself moves, so each iteration only re-evaluates the
    # "active" set: last batch's movers plus their neighborhoods.
    gain_full = np.full(n, -np.inf)
    target_full = np.full(n, -1, dtype=np.int64)
    mask = np.zeros(n, dtype=bool)
    on_cut = part[src] != part[nbr]
    if not on_cut.any():
        return part, cut
    mask[src[on_cut]] = True
    active = np.nonzero(mask)[0]
    refreshed = False  # True after a full re-evaluation of stale candidates

    for _ in range(max_iters):
        # Re-evaluate active rows in chunks so the (rows, k) degree matrix
        # stays within the memory cap.  Targets are chosen by gain alone;
        # capacity is enforced exactly at admission time below (a full
        # feasibility mask here would double the per-iteration (rows, k)
        # work for a constraint that rarely binds under the k slack).
        for lo in range(0, active.shape[0], chunk):
            rows_v = active[lo:lo + chunk]
            if use_kernel:
                deg = _degrees_via_kernel(adj_dense, part, k, rows_v,
                                          kernel_backend)
            else:
                deg = partition_degrees(graph, part, k, rows=rows_v)
            own = part[rows_v]
            rows = np.arange(rows_v.shape[0])
            internal = deg[rows, own]  # advanced indexing: already a copy
            deg[rows, own] = -np.inf
            t = np.argmax(deg, axis=1)
            target_full[rows_v] = t
            gain_full[rows_v] = deg[rows, t] - internal
        is_cand = gain_full > 0
        cand_idx = np.nonzero(is_cand)[0]
        if cand_idx.shape[0] == 0:
            break

        # One Luby round: a candidate is suppressed by any adjacent candidate
        # with strictly higher (gain, -id) priority.  Survivors are an
        # independent set, so their gains are exact and additive.  Only the
        # candidates' own adjacency rows are scanned, not all m edges.
        eidx, local = _row_edges(graph, cand_idx)
        u = cand_idx[local]
        v = nbr[eidx]
        conflict = is_cand[v]
        u, v = u[conflict], v[conflict]
        beaten = (gain_full[v] > gain_full[u]) | (
            (gain_full[v] == gain_full[u]) & (v < u)
        )
        suppressed = np.zeros(n, dtype=bool)
        suppressed[u[beaten]] = True
        movers = cand_idx[~suppressed[cand_idx]]
        if movers.shape[0] == 0:  # unreachable: the max-priority candidate survives
            break

        # Capacity admission: per target partition, admit in gain order while
        # the cumulative moved weight fits in the pre-batch headroom.
        mt = target_full[movers]
        mg = gain_full[movers]
        order = np.lexsort((movers, -mg, mt))
        movers, mt, mg = movers[order], mt[order], mg[order]
        mw = vwgt[movers]
        cw = np.cumsum(mw)
        new_grp = np.empty(movers.shape[0], dtype=bool)
        new_grp[0] = True
        new_grp[1:] = mt[1:] != mt[:-1]
        grp_starts = np.nonzero(new_grp)[0]
        grp_sizes = np.diff(np.append(grp_starts, movers.shape[0]))
        within = cw - np.repeat(cw[grp_starts] - mw[grp_starts], grp_sizes)
        admit = within <= capacity - pweight[mt]
        moved, dest, moved_gain = movers[admit], mt[admit], mg[admit]
        if moved.shape[0] == 0:
            # Every candidate was admission-rejected under the *current*
            # partition weights; their cached targets may be stale.  Refresh
            # them all once, then give up if still stuck.
            if refreshed:
                break
            refreshed = True
            active = np.nonzero(is_cand)[0]
            continue
        refreshed = False

        np.subtract.at(pweight, part[moved], vwgt[moved])
        np.add.at(pweight, dest, vwgt[moved])
        part[moved] = dest
        cut -= int(round(moved_gain.sum()))

        # Next active set: the movers and everything adjacent to one.
        eidx, _ = _row_edges(graph, moved)
        mask[:] = False
        mask[moved] = True
        mask[adjncy[eidx]] = True
        active = np.nonzero(mask)[0]
    return part, cut


def uncoarsen_vec(
    levels: list[Graph],
    coarse_part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    use_kernel: bool | None = None,
    scalar_nk: int = _SCALAR_NK,
    scalar_max_k: int = _SCALAR_MAX_K,
) -> tuple[np.ndarray, int]:
    """Walk levels coarse->fine, refining each level with whichever engine
    its shape favors: the scalar FM queue for small few-partition levels
    (see _SCALAR_NK/_SCALAR_MAX_K), the batched vec refiner otherwise.
    ``max_nonimproving`` applies to the scalar-delegated levels."""

    def refine(g: Graph, p: np.ndarray) -> tuple[np.ndarray, int]:
        if k <= scalar_max_k and g.num_vertices * k <= scalar_nk:
            return refine_level(g, p, k, capacity, max_nonimproving)
        return refine_level_vec(g, p, k, capacity, use_kernel=use_kernel)

    part, cut = refine(levels[-1], coarse_part)
    for fine, coarse in zip(reversed(levels[:-1]), reversed(levels[1:])):
        part = project(part, coarse.cmap)
        part, cut = refine(fine, part)
    return part, cut

"""Array-parallel boundary refinement (the "vec" partitioning engine).

The scalar engine in ``refine.py`` follows the paper: a single global
priority queue pops one boundary vertex at a time, re-deriving its
per-partition degrees with a fresh ``np.bincount`` per pop.  That is O(n)
Python iterations per pass and dominates end-to-end partitioning time on
large SNNs.

This module is the Jet/label-propagation-style alternative: one shot of

    ``np.bincount(row * k + part[adjncy], weights=adjwgt)``

produces the external degree of *every* boundary vertex toward *every*
partition simultaneously; gains for all boundary vertices follow by
elementwise arithmetic, and a conflict-free batch of positive-gain moves
is applied per iteration:

1. every boundary vertex picks its best feasible target partition
   (capacity-checked against the pre-batch partition weights);
2. candidates adjacent to a higher-gain candidate are suppressed (one
   Luby-style round), so the surviving movers form an independent set and
   their gains are exact and additive;
3. movers are admitted in gain order per target partition under the
   remaining capacity (grouped cumulative-sum bookkeeping, no Python
   loop over vertices);
4. repeat until no positive-gain move exists (a fixed point).

Both objectives run through the same loop (selected by ``objective``):

* ``"cut"`` — the (rows, k) degree matrix above; conflicts are graph
  adjacency.
* ``"volume"`` — the degree matrix generalizes to the per-source
  distinct-partition presence matrix D* (λ-gain of a move =
  D*[v, b] − D*[v, own], exact), and conflicts are scoped per
  **(hyperedge, partition-column) slot**, not per hyperedge: a candidate
  move (v, a→b) touches the slots (e, a) and (e, b) of each incident
  hyperedge e, and a slot is *contended* only when at least two candidates
  touch it AND its member count Φ(e, c) sits near a presence threshold
  (Φ < 2, or Φ minus the slot's candidate leavers < 2).  On a thick slot
  no ±1 traffic can flip the [Φ > 0] / [Φ > 1] indicators any gain or
  cached D* row depends on, so arbitrarily many movers may share it with
  exactly additive gains; only near-threshold slots serialize to one
  max-priority winner per round.  This is the "fewer, fatter rounds"
  restructure: a hub hyperedge between well-populated partitions no longer
  throttles its members to one mover per round (the old per-hyperedge
  scoping's fixed-dispatch bound on fan-out graphs), while destination
  *capacity* contention stays exactly handled by grouped admission.  The
  member-count table Φ(e, p) behind D* is maintained *incrementally*
  across batches via the scalar engine's ``refine.VolumeState`` (one
  merged scatter per accepted mover set, the batch mirror of the FM
  queue's per-move delta updates) instead of being recounted from the
  partition vector every batch, and stale-gain invalidation applies the
  same critical-edge filter: only hyperedges where a move crossed a
  presence threshold re-activate their members.

**Sharded execution** (``shards=``): the same loop runs over contiguous
vertex blocks from a ``repro.sharding.planner.plan_vertex_shards`` plan.
Each iteration proposes per shard — degree rows are evaluated against a
halo-assembled local partition view (one gather of boundary labels per
round, the halo exchange; see ``graph.ShardedGraphView``) and the dense
(rows, k) chunk plus the optional row cache are sized per *block* rather
than per graph — then the mover set is committed globally through the
same conflict selection and capacity admission.  Results are bitwise
identical to the single-host path (evaluation is pure per row; only the
scheduling and memory layout change), which is what lets a million-vertex
level refine with per-shard-bounded dense state.

When the positive-gain fixed point is reached the engine does not stop:
a bounded Jet-style **plateau walk** runs zero- and bounded-negative-gain
escape rounds (``gain >= -plateau_eps * internal``) through the same
Luby/admission machinery, with two oscillation guards — a per-vertex move
cooldown (a plateau mover sits out the next ``plateau_cooldown`` escape
rounds) and best-seen rollback (the best partition observed is restored on
exit, so the returned objective never regresses).  Each escape either
opens new positive-gain moves (resetting the budget when a new best is
reached) or burns one of ``plateau_rounds`` stall credits.  This is what
lets the batch engine match the scalar FM queue's hill-climbing on volume
plateaus without delegating levels to its O(n)-Python queue.

For large k the dense per-partition degree matrix is also expressible as
``A @ onehot(part)`` — a tiled one-hot matmul the MXU eats for breakfast;
``repro.kernels.gain_eval`` implements exactly that and is used here when
running on TPU with a graph small enough to densify (coarse levels).  The
volume objective has the analogous dense form ``B @ presence`` (incidence
times per-hyperedge partition presence) — the kernel's "connectivity"
mode.
"""
from __future__ import annotations

import numpy as np

from .graph import (
    Graph,
    Hypergraph,
    ShardedGraphView,
    _mix64,
    comm_volume,
    csr_gather as _csr_gather,
    edge_cut,
    edge_partition_counts,
    grouped_admission,
    partition_weights,
    volume_degrees,
)
from .refine import _MAX_DEG_ENTRIES, VolumeState, project, refine_level

__all__ = ["partition_degrees", "refine_level_vec", "uncoarsen_vec"]

# Small-problem delegation bounds for the *cut* objective.  At few
# partitions the batched positive-gain passes benefit from the scalar FM
# queue's stronger hill-climbing, and the queue is cheap there — so
# `uncoarsen_vec` hands cut levels with n * k <= _SCALAR_NK and
# k <= _SCALAR_MAX_K to the scalar refiner.  Both bounds matter: FM's
# per-move cost grows with k (a bincount plus a sort of the k-wide degree
# vector per queue operation), so delegating a many-partition level would
# burn the very speedup this module exists for.  Volume levels are *never*
# delegated: λ-gain queue operations touch every member of every incident
# hyperedge (fan-out × heavier than a cut bincount, and worst at coarse
# levels where incidence density peaks), and the plateau walk closes the
# quality gap the delegation used to paper over.
_SCALAR_NK = 1 << 20
_SCALAR_MAX_K = 64

# Plateau-walk defaults: stall credits (consecutive escape rounds without
# a new best) per objective, negative-gain tolerance as a fraction of the
# vertex's internal degree, and the mover cooldown in escape rounds.
# eps = 1.0 admits every move toward a partition the vertex has *any*
# external presence in (gain >= -internal, the full boundary) — on
# capacity-tight landscapes the barrier is feasibility rather than a
# zero-gain plateau, and deep-negative first steps are what open chains
# that scalar FM finds with its tentative-move window; larger eps is
# equivalent (the external-presence condition already binds) and smaller
# eps strands the walk at the first capacity wall.  The cut objective
# keeps the walk off by default: its quality gap to scalar FM was already
# within a few percent and the walk would spend the engine's headline
# speed advantage on it.
_PLATEAU_ROUNDS = {"cut": 0, "volume": 12}
_PLATEAU_EPS = 1.0
_PLATEAU_COOLDOWN = 2
# Stall credits refund only on *meaningful* improvement (this fraction of
# the best objective, at least 1): the jittered escapes keep shaving
# epsilons off forever, and refunding on every new best would let the
# walk's tail consume multiples of the descent phase's time.  A hard cap
# of _PLATEAU_TOTAL x the credit budget bounds total escapes regardless.
_PLATEAU_TOL = 0.002
_PLATEAU_TOTAL = 8
# Iteration safety net per objective: plateau escapes + recovery need far
# more (cheap, active-set-bounded) iterations than pure positive descent.
_MAX_ITERS = {"cut": 200, "volume": 2000}

# Conflict-free mover selection runs this many iterated Luby rounds per
# batch (see ``select_movers``).
_LUBY_ROUNDS = 4

# Densifying for the gain_eval kernel is only worthwhile on TPU and only
# for problems whose dense form fits comfortably in HBM (adjacency (n, n)
# for cut; incidence (n, E) for volume).
_KERNEL_MAX_N = 4096
_KERNEL_MIN_K = 64

# Live (E, k) int32 Φ table cap (~128 MB): above it the volume path falls
# back to from-scratch per-chunk recounts instead of incremental updates.
_PHI_MAX_ENTRIES = 32_000_000

# Slot-contention counts come from whole-table ``np.bincount`` passes while
# the Φ table stays under this entry count (~8 MB int64 per count — a tight
# C loop with no zeroing pass); larger tables use persistent int32 count
# buffers updated with ``np.add.at`` and zeroed at the touched keys only.
_SLOT_BINCOUNT_MAX = 1 << 20

# Cached (n, k) degree/D* matrix cap (~128 MB float64).  Degree rows are
# independent of partition *weights* — only target choice is — so caching
# them makes capacity-retargeting a pure masked argmax over cached rows
# instead of a fresh incidence gather per stale target.
_DEG_CACHE_ENTRIES = 16_000_000

# Coarse volume levels are incidence-dense (hyperedges outlive vertices
# under contraction, so per-vertex incidence degree grows every level) and
# the per-pair gather epilogue becomes indexing-overhead-bound there.  When
# the dense (n, E) member-incidence matrix fits this entry cap (~64 MB of
# float64), D* rows come from one BLAS matmul against the live Φ presence
# instead — the CPU mirror of the gain_eval kernel's connectivity mode.
_DENSE_EVAL_ENTRIES = 8_000_000

# Boundary batches share `refine._MAX_DEG_ENTRIES`: rows * k entries per
# evaluation chunk (~128 MB of float64); larger boundaries are swept in
# row chunks.


class _HostShardPlan:
    """Minimal contiguous vertex-block plan (fallback when jax/planner is
    unavailable); duck-type-compatible with ``planner.VertexShardPlan``."""

    def __init__(self, n: int, num_shards: int):
        num_shards = max(1, min(int(num_shards), max(1, n)))
        self.bounds = (np.arange(num_shards + 1, dtype=np.int64) * n) // num_shards
        self.sharding = None
        self.notes = ["host-only blocks (planner unavailable)"]

    @property
    def num_shards(self) -> int:
        return len(self.bounds) - 1

    def block(self, s: int) -> tuple[int, int]:
        return int(self.bounds[s]), int(self.bounds[s + 1])


def _as_vertex_plan(n: int, shards):
    """Normalize a ``shards=`` argument (int or plan object) to a plan."""
    if shards is None:
        return None
    if hasattr(shards, "bounds"):
        return shards
    try:
        from repro.sharding.planner import plan_vertex_shards

        return plan_vertex_shards(n, int(shards))
    except ImportError:
        return _HostShardPlan(n, int(shards))


class _ShardedRowCache:
    """(n, k) float64 row cache stored as one array per vertex block.

    On a sharded run each block's rows live with their shard (the
    per-device memory model), so the cache is enabled whenever the largest
    *block* fits ``_DEG_CACHE_ENTRIES`` even when the global (n, k) matrix
    would not.  Rows arriving at ``get``/``set``/``add_at`` are global
    vertex ids; they are routed to blocks by the plan bounds.
    """

    def __init__(self, bounds: np.ndarray, k: int):
        self.bounds = np.asarray(bounds, dtype=np.int64)
        self.k = k
        self.blocks = [
            np.zeros((int(hi - lo), k))
            for lo, hi in zip(self.bounds[:-1], self.bounds[1:])
        ]

    def _owners(self, rows: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.bounds, rows, side="right") - 1

    def get(self, rows: np.ndarray) -> np.ndarray:
        out = np.empty((rows.shape[0], self.k))
        own = self._owners(rows)
        for s, blk in enumerate(self.blocks):
            m = own == s
            if m.any():
                out[m] = blk[rows[m] - self.bounds[s]]
        return out

    def set(self, rows: np.ndarray, vals: np.ndarray) -> None:
        own = self._owners(rows)
        for s, blk in enumerate(self.blocks):
            m = own == s
            if m.any():
                blk[rows[m] - self.bounds[s]] = vals[m]

    def add_at(self, rows: np.ndarray, cols: np.ndarray,
               vals: np.ndarray) -> None:
        own = self._owners(rows)
        for s, blk in enumerate(self.blocks):
            m = own == s
            if m.any():
                np.add.at(blk, (rows[m] - self.bounds[s], cols[m]), vals[m])


def _row_edges(graph: Graph, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Gather the CSR edges of ``rows``: (edge index array, local row id array)."""
    return _csr_gather(graph.xadj, rows)


def partition_degrees(
    graph: Graph,
    part: np.ndarray,
    k: int,
    rows: np.ndarray | None = None,
) -> np.ndarray:
    """(R, k) weighted histogram of neighbor partitions for each row vertex.

    Column ``part[v]`` of row v holds v's internal degree; every other
    column b holds the external degree ED[v]_b.  ``rows=None`` computes all
    n rows (the issue's one-shot formula); passing the boundary-vertex
    subset keeps the matrix small on fine levels.
    """
    if rows is None:
        rows = np.arange(graph.num_vertices, dtype=np.int64)
    eidx, local = _row_edges(graph, rows)
    deg = np.bincount(
        local * k + part[graph.adjncy[eidx]].astype(np.int64),
        weights=graph.adjwgt[eidx],
        minlength=rows.shape[0] * k,
    )
    return deg.reshape(rows.shape[0], k)


def _dense_adjacency(graph: Graph) -> np.ndarray:
    """(n, n) f32 dense adjacency for the gain_eval kernel path."""
    n = graph.num_vertices
    adj = np.zeros((n, n), dtype=np.float32)
    adj[graph.edge_src, graph.adjncy] = graph.adjwgt
    return adj


def _dense_incidence(hyper: Hypergraph) -> np.ndarray:
    """(n, E) f32 member incidence, hfire-weighted, for the connectivity mode."""
    inc = np.zeros((hyper.num_vertices, hyper.num_hyperedges), dtype=np.float32)
    e_ids = np.arange(hyper.num_hyperedges)
    inc[hyper.hsrc.astype(np.int64), e_ids] = hyper.hfire
    inc[hyper.hpins.astype(np.int64), hyper.pin_edge] = hyper.hfire[hyper.pin_edge]
    return inc


def _degrees_via_kernel(adj: np.ndarray, part: np.ndarray, k: int,
                        rows: np.ndarray, backend: str) -> np.ndarray:
    """Row-subset degrees via the gain_eval tiled one-hot matmul kernel."""
    import jax.numpy as jnp

    from repro.kernels.gain_eval import part_degrees

    deg = part_degrees(jnp.asarray(adj), jnp.asarray(part, jnp.int32), k,
                       backend=backend)
    return np.asarray(deg, dtype=np.float64)[rows]


def _volume_degrees_via_kernel(inc: np.ndarray, hyper: Hypergraph,
                               part: np.ndarray, k: int, rows: np.ndarray,
                               backend: str,
                               phi: np.ndarray | None = None) -> np.ndarray:
    """Row-subset D* via the gain_eval kernel's connectivity mode.

    base = B @ [Φ>0] counts every member (the row vertex included); the own
    column is overwritten with the B @ [Φ>1] gather, which demands a second
    member — exactly ``graph.volume_degrees``.  ``phi`` is the caller's
    live member-count table when it maintains one (recomputed otherwise).
    """
    import jax.numpy as jnp

    from repro.kernels.gain_eval import connectivity_degrees

    if phi is None:
        phi = edge_partition_counts(hyper, part, k)
    pres = jnp.asarray(
        np.concatenate([(phi > 0), (phi > 1)], axis=1).astype(np.float32)
    )
    both = np.asarray(connectivity_degrees(jnp.asarray(inc), pres,
                                           backend=backend), dtype=np.float64)
    base, alt = both[rows, :k], both[rows, k:]
    own = part[rows]
    r = np.arange(rows.shape[0])
    base[r, own] = alt[r, own]
    return base


def refine_level_vec(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    max_iters: int | None = None,
    use_kernel: bool | None = None,
    kernel_backend: str = "auto",
    objective: str = "cut",
    plateau_rounds: int | None = None,
    plateau_eps: float = _PLATEAU_EPS,
    plateau_cooldown: int = _PLATEAU_COOLDOWN,
    stats: dict | None = None,
    forbid: np.ndarray | None = None,
    shards=None,
) -> tuple[np.ndarray, int]:
    """Refine ``part`` by batched moves; returns (part, score).

    ``shards`` (int, ``VertexShardPlan``, or None) selects the sharded
    execution mode: degree evaluation proceeds block-by-block against
    halo-assembled local partition views, and the row cache is sized per
    block (see the module docstring).  Semantically identical to the
    single-host path — same movers, same score — with per-shard-bounded
    dense intermediates; the kernel/dense-matmul fast paths are disabled
    in favor of the chunked per-shard path.

    ``forbid`` is an optional (k,) boolean mask of partitions that may not
    *receive* movers (their effective capacity is zero); vertices already
    inside one are still free to leave.  The degraded re-mapper uses it to
    keep the post-eviction refine from repopulating partitions whose cores
    failed.

    ``score`` is the edge cut or communication volume per ``objective``.
    Positive-gain batches run to a fixed point; then up to
    ``plateau_rounds`` Jet-style zero/negative-gain escape rounds
    (tolerance ``-plateau_eps * internal degree``, per-vertex cooldown of
    ``plateau_cooldown`` rounds, best-seen rollback on exit) walk the
    engine off plateaus — the returned score is the best observed and
    never exceeds the input's.  ``plateau_rounds=None`` picks the
    per-objective default (see ``_PLATEAU_ROUNDS``); 0 disables the walk.

    ``use_kernel=None`` auto-enables the gain_eval Pallas path on TPU for
    levels small enough to densify — and only when the total weight fits in
    float32's exact-integer range (< 2^24), since the kernel accumulates
    spike counts in f32 and the incremental bookkeeping demands exact
    integer gains.  True forces it (tests run it in interpret mode via
    ``kernel_backend="interpret"``), False keeps the pure-numpy (exact
    float64) bincount path.
    """
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    hyper = graph.hyper
    if objective == "volume" and hyper is None:
        raise ValueError("objective='volume' requires graph.hyper")
    part = part.astype(np.int64).copy()
    n = graph.num_vertices
    adjncy, adjwgt, vwgt = graph.adjncy, graph.adjwgt, graph.vwgt
    pweight = partition_weights(graph, part, k)
    cap = np.full(k, capacity, dtype=np.int64)
    if forbid is not None:
        cap[np.asarray(forbid, dtype=bool)] = 0
    cut = edge_cut(graph, part) if objective == "cut" else comm_volume(hyper, part)
    if graph.adjncy.shape[0] == 0:
        return part, cut
    if plateau_rounds is None:
        plateau_rounds = _PLATEAU_ROUNDS[objective]
    if max_iters is None:
        max_iters = _MAX_ITERS[objective]
    plan = _as_vertex_plan(n, shards)
    sview = None
    if plan is not None and plan.num_shards > 1:
        sview = ShardedGraphView(graph, plan)
        use_kernel = False  # sharded mode keeps the chunked per-block path
    src = graph.edge_src
    nbr = adjncy.astype(np.int64)
    # Incremental Φ bookkeeping (the scalar FM queue's VolumeState, driven
    # in batch mode) unless the dense (E, k) table would blow the memory
    # cap — then each chunk recounts Φ for its incident edges from scratch.
    vstate = None
    dense_inc = None
    if objective == "volume":
        if cut == 0:
            return part, cut  # every hyperedge spans one partition already
        if hyper.num_hyperedges * k <= _PHI_MAX_ENTRIES:
            vstate = VolumeState(graph, part, k)
            ne = hyper.num_hyperedges
            avg_inc = (hyper.num_pins + ne) / max(n, 1)
            # Dense only where it wins: the sparse epilogue costs ~avg_inc
            # gather-bound entries per (row, column), the matmul ne
            # BLAS-rate flops — crossover around a 16x flop discount.
            if (sview is None and n * ne <= _DENSE_EVAL_ENTRIES
                    and avg_inc * 16 >= ne):
                # Exact in float64: entries are hfire-weighted 0/1 sums.
                dense_inc = _dense_incidence(hyper).astype(np.float64)
    # Persistent flat slot buffers for select_movers, addressed by the
    # packed key e * k + c directly — no per-call unique/searchsorted
    # compression.  phi.size <= _PHI_MAX_ENTRIES < 2**31 whenever vstate
    # exists, so int32 keys index them exactly; each call zeroes only the
    # entries it touched.  Tables small enough for whole-table bincounts
    # skip the toucher/leaver count buffers entirely (see select_movers).
    slot_cnt = slot_out = slot_rank = slot_done = None
    if vstate is not None:
        if vstate.phi.size > _SLOT_BINCOUNT_MAX:
            slot_cnt = np.zeros(vstate.phi.size, dtype=np.int32)
            slot_out = np.zeros(vstate.phi.size, dtype=np.int32)
        slot_rank = np.zeros(vstate.phi.size, dtype=np.int32)
        slot_done = np.zeros(vstate.phi.size, dtype=bool)
    if use_kernel is None:
        use_kernel = False
        total_w = (int(adjwgt.sum()) if objective == "cut"
                   else int(hyper.hfire.sum()) * 2)
        dense_ok = (n <= _KERNEL_MAX_N if objective == "cut"
                    else n <= _KERNEL_MAX_N and hyper.num_hyperedges <= _KERNEL_MAX_N)
        if dense_ok and k >= _KERNEL_MIN_K and total_w < (1 << 24):
            try:
                import jax

                use_kernel = jax.default_backend() == "tpu"
            except Exception:
                use_kernel = False

    if use_kernel:
        dense = (_dense_adjacency(graph) if objective == "cut"
                 else _dense_incidence(hyper))
    else:
        dense = None
    # The volume path materializes a (pairs, k) product where pairs is the
    # chunk's total incidence degree — bound the chunk by that expansion,
    # not just rows * k, or fan-out-heavy graphs blow the memory cap.
    row_cost = float(k)
    if objective == "volume" and n:
        avg_inc = (hyper.num_pins + hyper.num_hyperedges) / n
        row_cost *= max(avg_inc, 1.0)
    chunk = max(1, int(_MAX_DEG_ENTRIES / row_cost))

    def eval_rows(rows_v: np.ndarray, pvec: np.ndarray) -> np.ndarray:
        """Degree rows of ``rows_v`` read against partition view ``pvec``
        (the global vector, or a shard's halo-assembled local view)."""
        if objective == "cut":
            if use_kernel:
                return _degrees_via_kernel(dense, pvec, k, rows_v, kernel_backend)
            return partition_degrees(graph, pvec, k, rows=rows_v)
        if use_kernel:
            return _volume_degrees_via_kernel(
                dense, hyper, pvec, k, rows_v, kernel_backend,
                phi=None if vstate is None else vstate.phi)
        if dense_inc is not None:
            # One (rows, E) @ (E, 2k) BLAS call against the live Φ
            # presence: base counts any member, the own column demands a
            # second one (the row vertex always sits there itself).
            pres = np.concatenate(
                [vstate.phi > 0, vstate.phi > 1], axis=1).astype(np.float64)
            both = dense_inc[rows_v] @ pres
            base, alt = both[:, :k], both[:, k:]
            own = pvec[rows_v]
            r = np.arange(rows_v.shape[0])
            base[r, own] = alt[r, own]
            return base
        if vstate is not None:
            return vstate.degrees_rows(pvec, rows_v)
        return volume_degrees(hyper, pvec, k, rows=rows_v)

    # Halo flavor each shard's evals need: the live-Φ path reads only
    # block-local labels, the from-scratch paths read neighbors (cut) or
    # hyperedge co-members (volume).
    if objective == "cut":
        _halo_mode = "cut"
    elif vstate is not None:
        _halo_mode = "local"
    else:
        _halo_mode = "volume"

    def eval_chunks(need: np.ndarray):
        """Yield (rows chunk, partition view) pairs covering ``need``.

        Single host: flat chunks against the global vector.  Sharded: rows
        are routed to their vertex blocks (``need`` arrives sorted) and
        each block's chunks evaluate against its halo-assembled local view
        — one halo exchange per shard per iteration; labels outside
        block + halo are poisoned, so an out-of-halo read fails loudly.
        """
        if sview is None:
            for lo in range(0, need.shape[0], chunk):
                yield need[lo:lo + chunk], part
            return
        for s, rows_s in enumerate(
                np.split(need, np.searchsorted(need, plan.bounds[1:-1]))):
            if rows_s.shape[0] == 0:
                continue
            lpart = sview.local_part(s, part, mode=_halo_mode)
            for lo in range(0, rows_s.shape[0], chunk):
                yield rows_s[lo:lo + chunk], lpart

    def _slot_phi(slots: np.ndarray) -> np.ndarray:
        """Member counts Φ(e, c) for packed (hyperedge, column) slot keys
        ``e * k + c`` — from the live table when one exists, else counted
        from the partition vector for just the slots' distinct edges."""
        if vstate is not None:
            return vstate.phi.reshape(-1)[slots].astype(np.int64)
        ue = np.unique(slots // k)
        pidx, pl = _csr_gather(hyper.hxadj, ue)
        mkeys = np.concatenate([
            ue[pl] * k + part[hyper.hpins[pidx]],
            ue * k + part[hyper.hsrc[ue].astype(np.int64)],
        ])
        mkeys.sort()
        return (np.searchsorted(mkeys, slots, side="right")
                - np.searchsorted(mkeys, slots, side="left"))

    def select_movers(cand_idx: np.ndarray,
                      jitter_round: int | None = None) -> np.ndarray:
        """Greedy conflict-free mover selection: iterated Luby rounds.

        Each round, a candidate survives if no co-scoped candidate has
        strictly higher (gain, -id) priority; survivors join the mover
        set, candidates co-scoped with a survivor drop out, and the
        merely-beaten re-enter the next round.

        Cut: scopes are graph edges, so the pairwise scan over candidates'
        adjacency rows is degree-bounded.

        Volume: scopes are the **(hyperedge, column) slots** a move's ±1
        Φ-updates land on — (e, own) and (e, target) for each incident
        edge e.  A slot is *contended* only when at least two candidates
        touch it and its count sits near a presence threshold:

            touchers(e, c) > 1  and  (Φ(e, c) < 2
                                      or Φ(e, c) − leavers(e, c) < 2)

        Any mover subset confined to uncontended slots leaves every
        [Φ > 0] / [Φ > 1] indicator unchanged there, so batch gains stay
        exactly additive and the two-column delta updates stay exact;
        contended slots admit one max-priority toucher per round (tracked
        across rounds like the old per-edge flags).  Contention is
        computed once per call over the full candidate set — safety is
        monotone under taking subsets (fewer touchers, fewer leavers), so
        later rounds never need to re-derive it.  Compared with the old
        per-hyperedge scoping this is the "fat rounds" restructure: a hub
        edge spanning well-populated partitions admits all its movers at
        once instead of one per round.

        ``jitter_round`` (plateau escapes) perturbs the selection priority
        with a deterministic per-round hash of (vertex, round): consecutive
        escape rounds then explore *different* independent sets instead of
        replaying the same batch out and back — the deterministic-orbit
        failure mode of batch negative-gain walks.  Applied gains stay the
        exact cached values; only who wins the conflict changes.
        """
        g_sel = gain_full
        if jitter_round is not None:
            cg = gain_full[cand_idx]
            span = float(cg.max() - cg.min())
            if span > 0:
                u = (_mix64(cand_idx.astype(np.uint64),
                            np.uint64(2 * jitter_round + 1)).astype(np.float64)
                     / float(1 << 64))
                g_sel = gain_full.copy()
                g_sel[cand_idx] = cg + 0.5 * span * u
        chosen: list[np.ndarray] = []
        remaining = cand_idx
        if objective == "volume":
            vxadj, vedges = hyper.incidence()
            nc = cand_idx.shape[0]
            # One pair per (candidate, incident edge, side): every slot a
            # move's +-1 lands on.  Gathered once; the rounds below work on
            # boolean-masked views of these arrays, never re-gathering.
            ei0, lc0 = _csr_gather(vxadj, cand_idx)
            eids0 = vedges[ei0]
            lc2 = np.concatenate([lc0, lc0])
            if slot_done is not None:
                # Flat persistent buffers addressed by the packed key
                # e * k + c, computed in int32 outright (phi.size < 2^31
                # whenever the live table exists, so the arithmetic is
                # exact and skips an int64 pass + downcast).
                base = eids0.astype(np.int32) * np.int32(k)
                key = np.concatenate([
                    base + part[cand_idx].astype(np.int32)[lc0],
                    base + target_full[cand_idx].astype(np.int32)[lc0],
                ])
                half = eids0.shape[0]
                if slot_cnt is None:
                    # Small table: two straight bincounts beat the buffered
                    # fancy-index adds and need no zeroing afterwards.
                    t_cnt = np.bincount(key, minlength=vstate.phi.size)
                    o_cnt = np.bincount(key[:half],
                                        minlength=vstate.phi.size)
                else:
                    ones = np.ones(key.shape[0], dtype=np.int32)
                    np.add.at(slot_cnt, key, ones)
                    np.add.at(slot_out, key[:half], ones[:half])
                    t_cnt, o_cnt = slot_cnt, slot_out
                ps = vstate.phi.reshape(-1)[key]
                cm = (t_cnt[key] > 1) & ((ps < 2) | (ps - o_cnt[key] < 2))
                if t_cnt is slot_cnt:  # zero only what this call touched
                    slot_cnt[key] = 0
                    slot_out[key] = 0
                used_buf, rank_buf, persistent = slot_done, slot_rank, True
            else:
                skey = np.concatenate([
                    eids0 * k + part[cand_idx][lc0],       # leaving slots
                    eids0 * k + target_full[cand_idx][lc0],  # entering
                ])
                # No phi table (level too big to densify): compress the
                # slot keys first, then use call-local buffers.
                slots = np.unique(skey)
                key = np.searchsorted(slots, skey)
                t_cnt = np.bincount(key, minlength=slots.shape[0])
                o_cnt = np.bincount(key[:eids0.shape[0]],
                                    minlength=slots.shape[0])
                phi_slot = _slot_phi(slots)
                cm = ((t_cnt[key] > 1)
                      & ((phi_slot[key] < 2)
                         | (phi_slot[key] - o_cnt[key] < 2)))
                used_buf = np.zeros(slots.shape[0], dtype=bool)
                rank_buf = np.zeros(slots.shape[0], dtype=np.int32)
                persistent = False
            # Fat-round payoff: a candidate touching no contended slot
            # conflicts with nobody and wins outright; only contended
            # candidates enter the priority rounds.
            rmask = np.bincount(lc2[cm], minlength=nc) > 0
            free = cand_idx[~rmask]
            if free.shape[0]:
                chosen.append(free)
            # Dense (gain, -id) ranks as priorities, computed once per
            # call: rank comparisons are order-isomorphic to the pairwise
            # tie-breaking, and stay valid on every remaining-subset.
            pri = np.empty(nc, dtype=np.int32)
            pri[np.lexsort((cand_idx, -g_sel[cand_idx]))] = np.arange(
                nc, 0, -1, dtype=np.int32)
            ckey, clc = key[cm], lc2[cm]  # contended pairs only
            for _ in range(_LUBY_ROUNDS):
                if not rmask.any():
                    break
                ap = rmask[clc]  # this round's live contended pairs
                akey, alc = ckey[ap], clc[ap]
                excl = np.bincount(alc[used_buf[akey]], minlength=nc) > 0
                rank_buf[akey] = 0
                np.maximum.at(rank_buf, akey, pri[alc])
                lost = np.bincount(alc[rank_buf[akey] > pri[alc]],
                                   minlength=nc) > 0
                win = rmask & ~excl & ~lost
                winners = cand_idx[win]
                if winners.shape[0]:
                    chosen.append(winners)
                    used_buf[akey[win[alc]]] = True
                rmask &= ~excl & lost
            if persistent:  # zero only what this call touched
                used_buf[ckey] = False
                rank_buf[ckey] = 0
            if not chosen:
                return np.empty(0, dtype=np.int64)
            return np.concatenate(chosen)
        # 0 = not a candidate, 1 = still in the running, 2 = chosen.
        status = np.zeros(n, dtype=np.int8)
        status[cand_idx] = 1
        for _ in range(_LUBY_ROUNDS):
            if remaining.shape[0] == 0:
                break
            nr = remaining.shape[0]
            # Segment-any over the (pair -> candidate) map as bincounts of
            # the offending pair subset (buffered C loops; the equivalent
            # ``np.logical_or.at`` is unbuffered and ~10x slower here).
            eidx, local = _row_edges(graph, remaining)
            u, v = remaining[local], nbr[eidx]
            excl = np.bincount(local[status[v] == 2], minlength=nr) > 0
            beat = (status[v] == 1) & (
                (g_sel[v] > g_sel[u])
                | ((g_sel[v] == g_sel[u]) & (v < u))
            )
            lost = np.bincount(local[beat], minlength=nr) > 0
            win = ~excl & ~lost
            winners = remaining[win]
            status[remaining[excl]] = 0  # out of the running for good
            if winners.shape[0]:
                chosen.append(winners)
                status[winners] = 2
            remaining = remaining[~excl & lost]
        if not chosen:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(chosen)

    def touched_by(moved: np.ndarray, srcs: np.ndarray,
                   dsts: np.ndarray) -> np.ndarray:
        """Vertices whose cached gains are stale after `moved` move."""
        if objective == "cut":
            eidx, _ = _row_edges(graph, moved)
            return adjncy[eidx].astype(np.int64)
        if vstate is not None:
            # Critical-edge filter: only hyperedges where the move crossed
            # a presence threshold invalidate their members' D* rows.
            return vstate.touched_moves(moved, srcs, dsts)
        vxadj, vedges = hyper.incidence()
        eidx, _ = _csr_gather(vxadj, moved)
        ue = np.unique(vedges[eidx])
        pidx, _ = _csr_gather(hyper.hxadj, ue)
        return np.concatenate([hyper.hpins[pidx].astype(np.int64),
                               hyper.hsrc[ue].astype(np.int64)])

    # Cached per-vertex move state.  A cached (gain, target) stays exact
    # until a co-member moves (gains depend only on other members'
    # partitions) or the vertex itself moves, so each iteration only
    # re-evaluates the "active" set: last batch's movers plus their scopes.
    gain_full = np.full(n, -np.inf)
    internal_full = np.zeros(n)
    target_full = np.full(n, -1, dtype=np.int64)
    mask = np.zeros(n, dtype=bool)
    if vstate is not None:
        # Volume: members of multi-partition hyperedges — a pin can carry a
        # λ-gain without sitting on any cut *graph* edge (two pins of one
        # source need not be adjacent), so the graph boundary undershoots.
        multi = np.nonzero((vstate.phi > 0).sum(axis=1) > 1)[0]
        pidx, _ = _csr_gather(hyper.hxadj, multi)
        mask[hyper.hpins[pidx].astype(np.int64)] = True
        mask[hyper.hsrc[multi].astype(np.int64)] = True
    else:
        on_cut = part[src] != part[nbr]
        if not on_cut.any():
            return part, cut
        mask[src[on_cut]] = True
    active = np.nonzero(mask)[0]

    # Plateau-walk state: best-seen snapshot (rollback target), stall
    # credits (refunded on meaningful improvement only; see _PLATEAU_TOL),
    # the total-escape cap, and the per-vertex escape-round cooldown.
    best_cut = cut
    best_part = part.copy()
    stall = 0
    escapes = 0
    moves_total = 0
    it = -1
    credit_base = cut
    cooled_until = np.full(n, -1, dtype=np.int64)

    if sview is None:
        use_deg_cache = n * k <= _DEG_CACHE_ENTRIES
        deg_cache = np.zeros((n, k)) if use_deg_cache else None
    else:
        # Per-device memory model: each block's rows cache with their
        # shard, so the gate is the largest block — a graph whose global
        # (n, k) matrix is too big can still cache when split s ways.
        max_block = int(np.diff(np.asarray(plan.bounds)).max())
        use_deg_cache = max_block * k <= _DEG_CACHE_ENTRIES
        deg_cache = _ShardedRowCache(plan.bounds, k) if use_deg_cache else None

    def cache_rows(rows: np.ndarray) -> np.ndarray:
        return deg_cache[rows] if sview is None else deg_cache.get(rows)

    def cache_store(rows: np.ndarray, deg: np.ndarray) -> None:
        if sview is None:
            deg_cache[rows] = deg
        else:
            deg_cache.set(rows, deg)

    def cache_scatter(rows: np.ndarray, cols: np.ndarray,
                      vals: np.ndarray) -> None:
        if sview is None:
            np.add.at(deg_cache, (rows, cols), vals)
        else:
            deg_cache.add_at(rows, cols, vals)
    # Rows whose deg_cache entry is current.  Volume rows with the row
    # cache are maintained *incrementally* (see delta_update): a move
    # changes a co-member's D* row in exactly two columns, so the batch
    # applies two-column scatters instead of re-gathering whole rows —
    # the full batch mirror of the scalar FM queue's delta updates.
    known = np.zeros(n, dtype=bool)
    use_delta = vstate is not None and use_deg_cache

    def delta_update(moved: np.ndarray, prevp: np.ndarray,
                     destp: np.ndarray) -> np.ndarray:
        """Two-column D* delta scatter for a conflict-free mover batch.

        Call after ``apply_moves`` (Φ holds post-move counts) and after
        clearing ``known[moved]`` (movers share no hyperedge, so a mover's
        row only changes through its own move — it gets a full re-eval).
        For a move src→dst on edge e with post-move counts φs = Φ(e,src),
        φd = Φ(e,dst), a member u with δc = [part[u] == c] sees exactly

            D*[u, src] -= hfire[e]  iff φs == δsrc
            D*[u, dst] += hfire[e]  iff φd == δdst + 1

        and no other column changes.  Nonzero deltas imply φs <= 1 or
        φd <= 2 — precisely the critical-edge filter — so non-critical
        edges are skipped wholesale.  Returns the member vertices of the
        critical edges (the rows whose targets must be re-chosen).
        """
        idx, local = _csr_gather(vstate.vxadj, moved)
        eids = vstate.vedges[idx]
        cs = prevp[local]
        cd = destp[local]
        phi_s = vstate.phi[eids, cs].astype(np.int64)
        phi_d = vstate.phi[eids, cd].astype(np.int64)
        crit = (phi_s <= 1) | (phi_d <= 2)
        eids, cs, cd = eids[crit], cs[crit], cd[crit]
        phi_s, phi_d = phi_s[crit], phi_d[crit]
        pidx, el = _csr_gather(hyper.hxadj, eids)
        mem = np.concatenate([hyper.hpins[pidx].astype(np.int64),
                              hyper.hsrc[eids].astype(np.int64)])
        j = np.concatenate([el, np.arange(eids.shape[0], dtype=np.int64)])
        pu = part[mem]
        w = vstate.hfire_f[eids]
        hit_s = phi_s[j] == (cs[j] == pu)
        hit_d = phi_d[j] == (cd[j] == pu) + 1
        ks = known[mem] & hit_s
        kd = known[mem] & hit_d
        cache_scatter(mem[ks], cs[j][ks], -w[j][ks])
        cache_scatter(mem[kd], cd[j][kd], w[j][kd])
        # Only rows that actually changed re-enter the active set; a member
        # whose both indicator thresholds were missed has a byte-identical
        # row and an exact cached gain (feasibility staleness is caught by
        # the global stale-target check).
        return mem[hit_s | hit_d]

    def choose_targets(rows_v: np.ndarray, deg: np.ndarray) -> None:
        """Refresh the (gain, target) caches of ``rows_v`` from their
        degree rows: best *feasible* foreign column under the current
        partition weights (the scalar FM queue's walk down the degree
        vector to the first partition with room, as one masked argmax).
        Cumulative capacity is still enforced exactly at admission.

        ``deg`` is always a fresh per-call matrix (an eval result or a
        row-cache gather, both already stored/copied), so the feasibility
        masking mutates it in place instead of allocating a second
        (rows, k) array via ``np.where``; uniform-weight row sets — every
        finest level — reduce it to masking the handful of *full columns*.
        """
        own = part[rows_v]
        rows = np.arange(rows_v.shape[0])
        internal = deg[rows, own]  # advanced indexing: already a copy
        w = vwgt[rows_v]
        head = cap - pweight
        if w.shape[0] and w[0] == w[-1] and (w == w[0]).all():
            bad = head < w[0]
            if bad.any():
                deg[:, bad] = -np.inf
        else:
            deg[w[:, None] > head[None, :]] = -np.inf
        deg[rows, own] = -np.inf
        t = np.argmax(deg, axis=1)
        target_full[rows_v] = t
        internal_full[rows_v] = internal
        gain_full[rows_v] = deg[rows, t] - internal

    for it in range(max_iters):
        # Evaluate rows whose cached degree row is missing or invalid, in
        # chunks so the (rows, k) matrix stays within the memory cap; rows
        # kept current by delta_update only need their target re-chosen.
        if deg_cache is not None:
            ka = known[active]
            need, cached_rows = active[~ka], active[ka]
        else:
            need, cached_rows = active, None
        for rows_v, pvec in eval_chunks(need):
            deg = eval_rows(rows_v, pvec)
            if deg_cache is not None:
                cache_store(rows_v, deg)
                known[rows_v] = True
            choose_targets(rows_v, deg)
        if cached_rows is not None and cached_rows.shape[0]:
            choose_targets(cached_rows, cache_rows(cached_rows))
        # A cached target goes stale when its partition fills up.  Degree
        # rows themselves only change when a co-member moves, so with the
        # row cache retargeting is a pure masked argmax — no re-gather;
        # without it the rows re-enter the active set for re-evaluation.
        stale = np.isfinite(gain_full) & (vwgt > (cap - pweight)[target_full])
        srows = np.nonzero(stale)[0]
        if srows.shape[0]:
            if use_deg_cache:
                choose_targets(srows, cache_rows(srows))
                srows = np.empty(0, dtype=np.int64)
            else:
                gain_full[srows] = -np.inf
        is_cand = gain_full > 0
        plateau_move = False
        if not is_cand.any():
            if srows.shape[0]:
                active = srows  # retarget the stale rows before concluding
                continue
            # Positive fixed point: spend a stall credit on a Jet-style
            # escape round of zero/bounded-negative-gain moves.  Movers on
            # cooldown sit out (oscillation guard); a vertex with no
            # external presence toward its target (gain + internal == 0)
            # never escapes — such moves only churn isolated vertices.
            if (stall >= plateau_rounds
                    or escapes >= _PLATEAU_TOTAL * plateau_rounds):
                break
            stall += 1
            escapes += 1
            plateau_move = True
            is_cand = ((gain_full >= -plateau_eps * internal_full)
                       & (gain_full + internal_full > 0)
                       & (cooled_until < it))
        cand_idx = np.nonzero(is_cand)[0]
        if cand_idx.shape[0] == 0:
            if plateau_move:
                eligible = ((gain_full >= -plateau_eps * internal_full)
                            & (gain_full + internal_full > 0))
                if eligible.any():
                    # Every escape candidate is merely on cooldown: burn
                    # the stall credit and let the cooldowns expire instead
                    # of ending refinement (still bounded by the credit and
                    # total-escape caps).
                    active = np.empty(0, dtype=np.int64)
                    continue
            break

        # Iterated Luby rounds: movers form a conflict-free set, so their
        # gains are exact and additive.  Only the candidates' own scope
        # rows are scanned, not all m edges.
        movers = select_movers(cand_idx, jitter_round=it if plateau_move else None)
        if movers.shape[0] == 0:  # unreachable: the max-priority candidate survives
            break

        # Capacity admission: per target partition, admit in gain order while
        # the cumulative moved weight fits in the pre-batch headroom.
        mt = target_full[movers]
        mg = gain_full[movers]
        order = np.lexsort((movers, -mg, mt))
        movers, mt, mg = movers[order], mt[order], mg[order]
        admit = grouped_admission(mt, vwgt[movers], cap - pweight)
        moved, dest, moved_gain = movers[admit], mt[admit], mg[admit]
        if moved.shape[0] == 0:
            # Unreachable: the stale-target filter above guarantees every
            # surviving candidate's target has headroom for it right now,
            # so the top mover per target group always admits.
            break

        moves_total += moved.shape[0]
        prev = part[moved].copy()
        np.subtract.at(pweight, prev, vwgt[moved])
        np.add.at(pweight, dest, vwgt[moved])
        part[moved] = dest
        cut -= int(round(moved_gain.sum()))
        if vstate is not None:
            vstate.apply_moves(moved, prev, dest)
        if plateau_move:
            cooled_until[moved] = it + plateau_cooldown
        if cut < best_cut:
            best_cut = cut
            best_part = part.copy()
            if cut <= credit_base - max(1.0, _PLATEAU_TOL * credit_base):
                stall = 0
                credit_base = cut

        # Next active set: the movers, everything co-scoped with one, and
        # the stale-target rows awaiting feasible retargeting.  Capacity-
        # rejected movers keep their (still exact) cached gains and re-run
        # through admission next round.
        known[moved] = False  # a mover's own row changes in every column
        if use_delta:
            touched = delta_update(moved, prev, dest)
        else:
            touched = touched_by(moved, prev, dest)
            if deg_cache is not None:
                known[touched] = False
        mask[:] = False
        mask[moved] = True
        mask[srows] = True
        mask[touched] = True
        active = np.nonzero(mask)[0]
    if stats is not None:
        # Engine introspection for tests and benchmarks (cheap counters).
        stats["iterations"] = stats.get("iterations", 0) + it + 1
        stats["escapes"] = stats.get("escapes", 0) + escapes
        stats["moves"] = stats.get("moves", 0) + moves_total
    if cut > best_cut:  # plateau walk ended below its best — roll back
        part, cut = best_part, best_cut
    return part, cut


def uncoarsen_vec(
    levels,
    coarse_part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    use_kernel: bool | None = None,
    scalar_nk: int = _SCALAR_NK,
    scalar_max_k: int = _SCALAR_MAX_K,
    objective: str = "cut",
    plateau_rounds: int | None = None,
    shards=None,
) -> tuple[np.ndarray, int]:
    """Walk levels coarse->fine, refining each level with whichever engine
    its shape favors: the scalar FM queue for small few-partition *cut*
    levels (see _SCALAR_NK/_SCALAR_MAX_K), the batched vec refiner
    otherwise.  Volume levels always use the vec refiner — with the
    incremental Φ table and the plateau walk it matches the scalar queue's
    quality at a fraction of the time (the λ-gain queue's per-move cost is
    worst exactly where delegation used to send it).  ``max_nonimproving``
    applies to the scalar-delegated levels; ``plateau_rounds`` and
    ``shards`` thread through to ``refine_level_vec`` (a shard *count* is
    re-planned per level, since each level has its own vertex count).

    ``levels`` is any integer-indexable sequence of Graphs — a plain list
    or ``coarsen.LevelStore``; levels are accessed one index at a time,
    finest last, so an out-of-core store only ever holds two levels
    resident.
    """

    def refine(g: Graph, p: np.ndarray) -> tuple[np.ndarray, int]:
        if (objective == "cut" and k <= scalar_max_k
                and g.num_vertices * k <= scalar_nk):
            return refine_level(g, p, k, capacity, max_nonimproving,
                                objective=objective)
        level_shards = shards
        if shards is not None and not hasattr(shards, "bounds"):
            level_shards = _as_vertex_plan(g.num_vertices, shards)
        return refine_level_vec(g, p, k, capacity, use_kernel=use_kernel,
                                objective=objective,
                                plateau_rounds=plateau_rounds,
                                shards=level_shards)

    nlev = len(levels)
    part, cut = refine(levels[nlev - 1], coarse_part)
    for i in range(nlev - 2, -1, -1):
        part = project(part, levels[i + 1].cmap)
        part, cut = refine(levels[i], part)
    return part, cut

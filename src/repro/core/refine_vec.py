"""Array-parallel boundary refinement (the "vec" partitioning engine).

The scalar engine in ``refine.py`` follows the paper: a single global
priority queue pops one boundary vertex at a time, re-deriving its
per-partition degrees with a fresh ``np.bincount`` per pop.  That is O(n)
Python iterations per pass and dominates end-to-end partitioning time on
large SNNs.

This module is the Jet/label-propagation-style alternative: one shot of

    ``np.bincount(row * k + part[adjncy], weights=adjwgt)``

produces the external degree of *every* boundary vertex toward *every*
partition simultaneously; gains for all boundary vertices follow by
elementwise arithmetic, and a conflict-free batch of positive-gain moves
is applied per iteration:

1. every boundary vertex picks its best feasible target partition
   (capacity-checked against the pre-batch partition weights);
2. candidates adjacent to a higher-gain candidate are suppressed (one
   Luby-style round), so the surviving movers form an independent set and
   their gains are exact and additive;
3. movers are admitted in gain order per target partition under the
   remaining capacity (grouped cumulative-sum bookkeeping, no Python
   loop over vertices);
4. repeat until no positive-gain move exists (a fixed point).

Both objectives run through the same loop (selected by ``objective``):

* ``"cut"`` — the (rows, k) degree matrix above; conflicts are graph
  adjacency.
* ``"volume"`` — the degree matrix generalizes to the per-source
  distinct-partition presence matrix D* (λ-gain of a move =
  D*[v, b] − D*[v, own], exact), and two candidates conflict when they
  share a *hyperedge* (two pins of one source need not be graph-adjacent,
  but their λ-gains interact).  The member-count table Φ(e, p) behind D*
  is maintained *incrementally* across batches via the scalar engine's
  ``refine.VolumeState`` (one small scatter per accepted mover set, the
  batch mirror of the FM queue's per-move delta updates) instead of being
  recounted from the partition vector every batch, and stale-gain
  invalidation applies the same critical-edge filter: only hyperedges
  where a move crossed a presence threshold re-activate their members.

When the positive-gain fixed point is reached the engine does not stop:
a bounded Jet-style **plateau walk** runs zero- and bounded-negative-gain
escape rounds (``gain >= -plateau_eps * internal``) through the same
Luby/admission machinery, with two oscillation guards — a per-vertex move
cooldown (a plateau mover sits out the next ``plateau_cooldown`` escape
rounds) and best-seen rollback (the best partition observed is restored on
exit, so the returned objective never regresses).  Each escape either
opens new positive-gain moves (resetting the budget when a new best is
reached) or burns one of ``plateau_rounds`` stall credits.  This is what
lets the batch engine match the scalar FM queue's hill-climbing on volume
plateaus without delegating levels to its O(n)-Python queue.

For large k the dense per-partition degree matrix is also expressible as
``A @ onehot(part)`` — a tiled one-hot matmul the MXU eats for breakfast;
``repro.kernels.gain_eval`` implements exactly that and is used here when
running on TPU with a graph small enough to densify (coarse levels).  The
volume objective has the analogous dense form ``B @ presence`` (incidence
times per-hyperedge partition presence) — the kernel's "connectivity"
mode.
"""
from __future__ import annotations

import numpy as np

from .graph import (
    Graph,
    Hypergraph,
    _mix64,
    comm_volume,
    csr_gather as _csr_gather,
    edge_cut,
    edge_partition_counts,
    grouped_admission,
    partition_weights,
    volume_degrees,
)
from .refine import _MAX_DEG_ENTRIES, VolumeState, project, refine_level

__all__ = ["partition_degrees", "refine_level_vec", "uncoarsen_vec"]

# Small-problem delegation bounds for the *cut* objective.  At few
# partitions the batched positive-gain passes benefit from the scalar FM
# queue's stronger hill-climbing, and the queue is cheap there — so
# `uncoarsen_vec` hands cut levels with n * k <= _SCALAR_NK and
# k <= _SCALAR_MAX_K to the scalar refiner.  Both bounds matter: FM's
# per-move cost grows with k (a bincount plus a sort of the k-wide degree
# vector per queue operation), so delegating a many-partition level would
# burn the very speedup this module exists for.  Volume levels are *never*
# delegated: λ-gain queue operations touch every member of every incident
# hyperedge (fan-out × heavier than a cut bincount, and worst at coarse
# levels where incidence density peaks), and the plateau walk closes the
# quality gap the delegation used to paper over.
_SCALAR_NK = 1 << 20
_SCALAR_MAX_K = 64

# Plateau-walk defaults: stall credits (consecutive escape rounds without
# a new best) per objective, negative-gain tolerance as a fraction of the
# vertex's internal degree, and the mover cooldown in escape rounds.
# eps = 1.0 admits every move toward a partition the vertex has *any*
# external presence in (gain >= -internal, the full boundary) — on
# capacity-tight landscapes the barrier is feasibility rather than a
# zero-gain plateau, and deep-negative first steps are what open chains
# that scalar FM finds with its tentative-move window; larger eps is
# equivalent (the external-presence condition already binds) and smaller
# eps strands the walk at the first capacity wall.  The cut objective
# keeps the walk off by default: its quality gap to scalar FM was already
# within a few percent and the walk would spend the engine's headline
# speed advantage on it.
_PLATEAU_ROUNDS = {"cut": 0, "volume": 12}
_PLATEAU_EPS = 1.0
_PLATEAU_COOLDOWN = 2
# Stall credits refund only on *meaningful* improvement (this fraction of
# the best objective, at least 1): the jittered escapes keep shaving
# epsilons off forever, and refunding on every new best would let the
# walk's tail consume multiples of the descent phase's time.  A hard cap
# of _PLATEAU_TOTAL x the credit budget bounds total escapes regardless.
_PLATEAU_TOL = 0.002
_PLATEAU_TOTAL = 8
# Iteration safety net per objective: plateau escapes + recovery need far
# more (cheap, active-set-bounded) iterations than pure positive descent.
_MAX_ITERS = {"cut": 200, "volume": 2000}

# Conflict-free mover selection runs this many iterated Luby rounds per
# batch (see ``select_movers``).
_LUBY_ROUNDS = 4

# Densifying for the gain_eval kernel is only worthwhile on TPU and only
# for problems whose dense form fits comfortably in HBM (adjacency (n, n)
# for cut; incidence (n, E) for volume).
_KERNEL_MAX_N = 4096
_KERNEL_MIN_K = 64

# Live (E, k) int32 Φ table cap (~128 MB): above it the volume path falls
# back to from-scratch per-chunk recounts instead of incremental updates.
_PHI_MAX_ENTRIES = 32_000_000

# Cached (n, k) degree/D* matrix cap (~128 MB float64).  Degree rows are
# independent of partition *weights* — only target choice is — so caching
# them makes capacity-retargeting a pure masked argmax over cached rows
# instead of a fresh incidence gather per stale target.
_DEG_CACHE_ENTRIES = 16_000_000

# Coarse volume levels are incidence-dense (hyperedges outlive vertices
# under contraction, so per-vertex incidence degree grows every level) and
# the per-pair gather epilogue becomes indexing-overhead-bound there.  When
# the dense (n, E) member-incidence matrix fits this entry cap (~64 MB of
# float64), D* rows come from one BLAS matmul against the live Φ presence
# instead — the CPU mirror of the gain_eval kernel's connectivity mode.
_DENSE_EVAL_ENTRIES = 8_000_000

# Boundary batches share `refine._MAX_DEG_ENTRIES`: rows * k entries per
# evaluation chunk (~128 MB of float64); larger boundaries are swept in
# row chunks.


def _row_edges(graph: Graph, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Gather the CSR edges of ``rows``: (edge index array, local row id array)."""
    return _csr_gather(graph.xadj, rows)


def partition_degrees(
    graph: Graph,
    part: np.ndarray,
    k: int,
    rows: np.ndarray | None = None,
) -> np.ndarray:
    """(R, k) weighted histogram of neighbor partitions for each row vertex.

    Column ``part[v]`` of row v holds v's internal degree; every other
    column b holds the external degree ED[v]_b.  ``rows=None`` computes all
    n rows (the issue's one-shot formula); passing the boundary-vertex
    subset keeps the matrix small on fine levels.
    """
    if rows is None:
        rows = np.arange(graph.num_vertices, dtype=np.int64)
    eidx, local = _row_edges(graph, rows)
    deg = np.bincount(
        local * k + part[graph.adjncy[eidx]].astype(np.int64),
        weights=graph.adjwgt[eidx],
        minlength=rows.shape[0] * k,
    )
    return deg.reshape(rows.shape[0], k)


def _dense_adjacency(graph: Graph) -> np.ndarray:
    """(n, n) f32 dense adjacency for the gain_eval kernel path."""
    n = graph.num_vertices
    adj = np.zeros((n, n), dtype=np.float32)
    adj[graph.edge_src, graph.adjncy] = graph.adjwgt
    return adj


def _dense_incidence(hyper: Hypergraph) -> np.ndarray:
    """(n, E) f32 member incidence, hfire-weighted, for the connectivity mode."""
    inc = np.zeros((hyper.num_vertices, hyper.num_hyperedges), dtype=np.float32)
    e_ids = np.arange(hyper.num_hyperedges)
    inc[hyper.hsrc.astype(np.int64), e_ids] = hyper.hfire
    inc[hyper.hpins.astype(np.int64), hyper.pin_edge] = hyper.hfire[hyper.pin_edge]
    return inc


def _degrees_via_kernel(adj: np.ndarray, part: np.ndarray, k: int,
                        rows: np.ndarray, backend: str) -> np.ndarray:
    """Row-subset degrees via the gain_eval tiled one-hot matmul kernel."""
    import jax.numpy as jnp

    from repro.kernels.gain_eval import part_degrees

    deg = part_degrees(jnp.asarray(adj), jnp.asarray(part, jnp.int32), k,
                       backend=backend)
    return np.asarray(deg, dtype=np.float64)[rows]


def _volume_degrees_via_kernel(inc: np.ndarray, hyper: Hypergraph,
                               part: np.ndarray, k: int, rows: np.ndarray,
                               backend: str,
                               phi: np.ndarray | None = None) -> np.ndarray:
    """Row-subset D* via the gain_eval kernel's connectivity mode.

    base = B @ [Φ>0] counts every member (the row vertex included); the own
    column is overwritten with the B @ [Φ>1] gather, which demands a second
    member — exactly ``graph.volume_degrees``.  ``phi`` is the caller's
    live member-count table when it maintains one (recomputed otherwise).
    """
    import jax.numpy as jnp

    from repro.kernels.gain_eval import connectivity_degrees

    if phi is None:
        phi = edge_partition_counts(hyper, part, k)
    pres = jnp.asarray(
        np.concatenate([(phi > 0), (phi > 1)], axis=1).astype(np.float32)
    )
    both = np.asarray(connectivity_degrees(jnp.asarray(inc), pres,
                                           backend=backend), dtype=np.float64)
    base, alt = both[rows, :k], both[rows, k:]
    own = part[rows]
    r = np.arange(rows.shape[0])
    base[r, own] = alt[r, own]
    return base


def refine_level_vec(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    max_iters: int | None = None,
    use_kernel: bool | None = None,
    kernel_backend: str = "auto",
    objective: str = "cut",
    plateau_rounds: int | None = None,
    plateau_eps: float = _PLATEAU_EPS,
    plateau_cooldown: int = _PLATEAU_COOLDOWN,
    stats: dict | None = None,
    forbid: np.ndarray | None = None,
) -> tuple[np.ndarray, int]:
    """Refine ``part`` by batched moves; returns (part, score).

    ``forbid`` is an optional (k,) boolean mask of partitions that may not
    *receive* movers (their effective capacity is zero); vertices already
    inside one are still free to leave.  The degraded re-mapper uses it to
    keep the post-eviction refine from repopulating partitions whose cores
    failed.

    ``score`` is the edge cut or communication volume per ``objective``.
    Positive-gain batches run to a fixed point; then up to
    ``plateau_rounds`` Jet-style zero/negative-gain escape rounds
    (tolerance ``-plateau_eps * internal degree``, per-vertex cooldown of
    ``plateau_cooldown`` rounds, best-seen rollback on exit) walk the
    engine off plateaus — the returned score is the best observed and
    never exceeds the input's.  ``plateau_rounds=None`` picks the
    per-objective default (see ``_PLATEAU_ROUNDS``); 0 disables the walk.

    ``use_kernel=None`` auto-enables the gain_eval Pallas path on TPU for
    levels small enough to densify — and only when the total weight fits in
    float32's exact-integer range (< 2^24), since the kernel accumulates
    spike counts in f32 and the incremental bookkeeping demands exact
    integer gains.  True forces it (tests run it in interpret mode via
    ``kernel_backend="interpret"``), False keeps the pure-numpy (exact
    float64) bincount path.
    """
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    hyper = graph.hyper
    if objective == "volume" and hyper is None:
        raise ValueError("objective='volume' requires graph.hyper")
    part = part.astype(np.int64).copy()
    n = graph.num_vertices
    adjncy, adjwgt, vwgt = graph.adjncy, graph.adjwgt, graph.vwgt
    pweight = partition_weights(graph, part, k)
    cap = np.full(k, capacity, dtype=np.int64)
    if forbid is not None:
        cap[np.asarray(forbid, dtype=bool)] = 0
    cut = edge_cut(graph, part) if objective == "cut" else comm_volume(hyper, part)
    if graph.adjncy.shape[0] == 0:
        return part, cut
    if plateau_rounds is None:
        plateau_rounds = _PLATEAU_ROUNDS[objective]
    if max_iters is None:
        max_iters = _MAX_ITERS[objective]
    src = graph.edge_src
    nbr = adjncy.astype(np.int64)
    # Incremental Φ bookkeeping (the scalar FM queue's VolumeState, driven
    # in batch mode) unless the dense (E, k) table would blow the memory
    # cap — then each chunk recounts Φ for its incident edges from scratch.
    vstate = None
    dense_inc = None
    if objective == "volume":
        if cut == 0:
            return part, cut  # every hyperedge spans one partition already
        if hyper.num_hyperedges * k <= _PHI_MAX_ENTRIES:
            vstate = VolumeState(graph, part, k)
            ne = hyper.num_hyperedges
            avg_inc = (hyper.num_pins + ne) / max(n, 1)
            # Dense only where it wins: the sparse epilogue costs ~avg_inc
            # gather-bound entries per (row, column), the matmul ne
            # BLAS-rate flops — crossover around a 16x flop discount.
            if n * ne <= _DENSE_EVAL_ENTRIES and avg_inc * 16 >= ne:
                # Exact in float64: entries are hfire-weighted 0/1 sums.
                dense_inc = _dense_incidence(hyper).astype(np.float64)
    if use_kernel is None:
        use_kernel = False
        total_w = (int(adjwgt.sum()) if objective == "cut"
                   else int(hyper.hfire.sum()) * 2)
        dense_ok = (n <= _KERNEL_MAX_N if objective == "cut"
                    else n <= _KERNEL_MAX_N and hyper.num_hyperedges <= _KERNEL_MAX_N)
        if dense_ok and k >= _KERNEL_MIN_K and total_w < (1 << 24):
            try:
                import jax

                use_kernel = jax.default_backend() == "tpu"
            except Exception:
                use_kernel = False

    if use_kernel:
        dense = (_dense_adjacency(graph) if objective == "cut"
                 else _dense_incidence(hyper))
    else:
        dense = None
    # The volume path materializes a (pairs, k) product where pairs is the
    # chunk's total incidence degree — bound the chunk by that expansion,
    # not just rows * k, or fan-out-heavy graphs blow the memory cap.
    row_cost = float(k)
    if objective == "volume" and n:
        avg_inc = (hyper.num_pins + hyper.num_hyperedges) / n
        row_cost *= max(avg_inc, 1.0)
    chunk = max(1, int(_MAX_DEG_ENTRIES / row_cost))

    def eval_rows(rows_v: np.ndarray) -> np.ndarray:
        if objective == "cut":
            if use_kernel:
                return _degrees_via_kernel(dense, part, k, rows_v, kernel_backend)
            return partition_degrees(graph, part, k, rows=rows_v)
        if use_kernel:
            return _volume_degrees_via_kernel(
                dense, hyper, part, k, rows_v, kernel_backend,
                phi=None if vstate is None else vstate.phi)
        if dense_inc is not None:
            # One (rows, E) @ (E, 2k) BLAS call against the live Φ
            # presence: base counts any member, the own column demands a
            # second one (the row vertex always sits there itself).
            pres = np.concatenate(
                [vstate.phi > 0, vstate.phi > 1], axis=1).astype(np.float64)
            both = dense_inc[rows_v] @ pres
            base, alt = both[:, :k], both[:, k:]
            own = part[rows_v]
            r = np.arange(rows_v.shape[0])
            base[r, own] = alt[r, own]
            return base
        if vstate is not None:
            return vstate.degrees_rows(part, rows_v)
        return volume_degrees(hyper, part, k, rows=rows_v)

    def select_movers(cand_idx: np.ndarray,
                      jitter_round: int | None = None) -> np.ndarray:
        """Greedy conflict-free mover selection: iterated Luby rounds.

        Each round, a candidate survives if no co-scoped candidate has
        strictly higher (gain, -id) priority; survivors join the mover
        set, candidates co-scoped with a survivor drop out, and the
        merely-beaten re-enter the next round.  One round alone yields
        only a handful of movers on fan-out-heavy graphs (a hub hyperedge
        suppresses all but one of its members), degenerating the batch
        engine to near-sequential moves — a few rounds approach a maximal
        independent set at a fraction of the per-iteration eval cost.

        Cut: scopes are graph edges, so the pairwise scan over candidates'
        adjacency rows is degree-bounded.  Volume: scopes are hyperedges —
        the pairwise form would square a hub edge's pin count, so instead
        each hyperedge reduces its candidate members to one max priority
        and a candidate loses iff some incident edge's max beats it
        (O(candidate incidences), no pin expansion).

        ``jitter_round`` (plateau escapes) perturbs the selection priority
        with a deterministic per-round hash of (vertex, round): consecutive
        escape rounds then explore *different* independent sets instead of
        replaying the same batch out and back — the deterministic-orbit
        failure mode of batch negative-gain walks.  Applied gains stay the
        exact cached values; only who wins the conflict changes.
        """
        g_sel = gain_full
        if jitter_round is not None:
            cg = gain_full[cand_idx]
            span = float(cg.max() - cg.min())
            if span > 0:
                u = (_mix64(cand_idx.astype(np.uint64),
                            np.uint64(2 * jitter_round + 1)).astype(np.float64)
                     / float(1 << 64))
                g_sel = gain_full.copy()
                g_sel[cand_idx] = cg + 0.5 * span * u
        chosen: list[np.ndarray] = []
        remaining = cand_idx
        if objective == "volume":
            vxadj, vedges = hyper.incidence()
            edge_used = np.zeros(hyper.num_hyperedges, dtype=bool)
        else:
            # 0 = not a candidate, 1 = still in the running, 2 = chosen.
            status = np.zeros(n, dtype=np.int8)
            status[cand_idx] = 1
        for _ in range(_LUBY_ROUNDS):
            if remaining.shape[0] == 0:
                break
            nr = remaining.shape[0]
            # Segment-any over the (pair -> candidate) map as bincounts of
            # the offending pair subset (buffered C loops; the equivalent
            # ``np.logical_or.at`` is unbuffered and ~10x slower here).
            if objective == "cut":
                eidx, local = _row_edges(graph, remaining)
                u, v = remaining[local], nbr[eidx]
                excl = np.bincount(local[status[v] == 2], minlength=nr) > 0
                beat = (status[v] == 1) & (
                    (g_sel[v] > g_sel[u])
                    | ((g_sel[v] == g_sel[u]) & (v < u))
                )
                lost = np.bincount(local[beat], minlength=nr) > 0
            else:
                # Dense (gain, -id) ranks as priorities: distinct ints that
                # induce exactly the pairwise tie-breaking above, with no
                # packing overflow to guard.
                pri = np.empty(nr, dtype=np.int64)
                pri[np.lexsort((remaining, -g_sel[remaining]))] = np.arange(
                    nr, 0, -1)
                eidx, local = _csr_gather(vxadj, remaining)
                eids = vedges[eidx]
                excl = np.bincount(local[edge_used[eids]], minlength=nr) > 0
                edge_max = np.full(hyper.num_hyperedges, 0, dtype=np.int64)
                np.maximum.at(edge_max, eids, pri[local])
                lost = np.bincount(local[edge_max[eids] > pri[local]],
                                   minlength=nr) > 0
            win = ~excl & ~lost
            winners = remaining[win]
            if objective == "cut":
                status[remaining[excl]] = 0  # out of the running for good
            if winners.shape[0]:
                chosen.append(winners)
                if objective == "cut":
                    status[winners] = 2
                else:
                    edge_used[eids[win[local]]] = True
            remaining = remaining[~excl & lost]
        if not chosen:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(chosen)

    def touched_by(moved: np.ndarray, srcs: np.ndarray,
                   dsts: np.ndarray) -> np.ndarray:
        """Vertices whose cached gains are stale after `moved` move."""
        if objective == "cut":
            eidx, _ = _row_edges(graph, moved)
            return adjncy[eidx].astype(np.int64)
        if vstate is not None:
            # Critical-edge filter: only hyperedges where the move crossed
            # a presence threshold invalidate their members' D* rows.
            return vstate.touched_moves(moved, srcs, dsts)
        vxadj, vedges = hyper.incidence()
        eidx, _ = _csr_gather(vxadj, moved)
        ue = np.unique(vedges[eidx])
        pidx, _ = _csr_gather(hyper.hxadj, ue)
        return np.concatenate([hyper.hpins[pidx].astype(np.int64),
                               hyper.hsrc[ue].astype(np.int64)])

    # Cached per-vertex move state.  A cached (gain, target) stays exact
    # until a co-member moves (gains depend only on other members'
    # partitions) or the vertex itself moves, so each iteration only
    # re-evaluates the "active" set: last batch's movers plus their scopes.
    gain_full = np.full(n, -np.inf)
    internal_full = np.zeros(n)
    target_full = np.full(n, -1, dtype=np.int64)
    mask = np.zeros(n, dtype=bool)
    if vstate is not None:
        # Volume: members of multi-partition hyperedges — a pin can carry a
        # λ-gain without sitting on any cut *graph* edge (two pins of one
        # source need not be adjacent), so the graph boundary undershoots.
        multi = np.nonzero((vstate.phi > 0).sum(axis=1) > 1)[0]
        pidx, _ = _csr_gather(hyper.hxadj, multi)
        mask[hyper.hpins[pidx].astype(np.int64)] = True
        mask[hyper.hsrc[multi].astype(np.int64)] = True
    else:
        on_cut = part[src] != part[nbr]
        if not on_cut.any():
            return part, cut
        mask[src[on_cut]] = True
    active = np.nonzero(mask)[0]

    # Plateau-walk state: best-seen snapshot (rollback target), stall
    # credits (refunded on meaningful improvement only; see _PLATEAU_TOL),
    # the total-escape cap, and the per-vertex escape-round cooldown.
    best_cut = cut
    best_part = part.copy()
    stall = 0
    escapes = 0
    moves_total = 0
    it = -1
    credit_base = cut
    cooled_until = np.full(n, -1, dtype=np.int64)

    use_deg_cache = n * k <= _DEG_CACHE_ENTRIES
    deg_cache = np.zeros((n, k)) if use_deg_cache else None
    # Rows whose deg_cache entry is current.  Volume rows with the row
    # cache are maintained *incrementally* (see delta_update): a move
    # changes a co-member's D* row in exactly two columns, so the batch
    # applies two-column scatters instead of re-gathering whole rows —
    # the full batch mirror of the scalar FM queue's delta updates.
    known = np.zeros(n, dtype=bool)
    use_delta = vstate is not None and use_deg_cache

    def delta_update(moved: np.ndarray, prevp: np.ndarray,
                     destp: np.ndarray) -> np.ndarray:
        """Two-column D* delta scatter for a conflict-free mover batch.

        Call after ``apply_moves`` (Φ holds post-move counts) and after
        clearing ``known[moved]`` (movers share no hyperedge, so a mover's
        row only changes through its own move — it gets a full re-eval).
        For a move src→dst on edge e with post-move counts φs = Φ(e,src),
        φd = Φ(e,dst), a member u with δc = [part[u] == c] sees exactly

            D*[u, src] -= hfire[e]  iff φs == δsrc
            D*[u, dst] += hfire[e]  iff φd == δdst + 1

        and no other column changes.  Nonzero deltas imply φs <= 1 or
        φd <= 2 — precisely the critical-edge filter — so non-critical
        edges are skipped wholesale.  Returns the member vertices of the
        critical edges (the rows whose targets must be re-chosen).
        """
        idx, local = _csr_gather(vstate.vxadj, moved)
        eids = vstate.vedges[idx]
        cs = prevp[local]
        cd = destp[local]
        phi_s = vstate.phi[eids, cs].astype(np.int64)
        phi_d = vstate.phi[eids, cd].astype(np.int64)
        crit = (phi_s <= 1) | (phi_d <= 2)
        eids, cs, cd = eids[crit], cs[crit], cd[crit]
        phi_s, phi_d = phi_s[crit], phi_d[crit]
        pidx, el = _csr_gather(hyper.hxadj, eids)
        mem = np.concatenate([hyper.hpins[pidx].astype(np.int64),
                              hyper.hsrc[eids].astype(np.int64)])
        j = np.concatenate([el, np.arange(eids.shape[0], dtype=np.int64)])
        pu = part[mem]
        w = vstate.hfire_f[eids]
        hit_s = phi_s[j] == (cs[j] == pu)
        hit_d = phi_d[j] == (cd[j] == pu) + 1
        ks = known[mem] & hit_s
        kd = known[mem] & hit_d
        np.add.at(deg_cache, (mem[ks], cs[j][ks]), -w[j][ks])
        np.add.at(deg_cache, (mem[kd], cd[j][kd]), w[j][kd])
        # Only rows that actually changed re-enter the active set; a member
        # whose both indicator thresholds were missed has a byte-identical
        # row and an exact cached gain (feasibility staleness is caught by
        # the global stale-target check).
        return mem[hit_s | hit_d]

    def choose_targets(rows_v: np.ndarray, deg: np.ndarray) -> None:
        """Refresh the (gain, target) caches of ``rows_v`` from their
        degree rows: best *feasible* foreign column under the current
        partition weights (the scalar FM queue's walk down the degree
        vector to the first partition with room, as one masked argmax).
        Cumulative capacity is still enforced exactly at admission."""
        own = part[rows_v]
        rows = np.arange(rows_v.shape[0])
        internal = deg[rows, own]  # advanced indexing: already a copy
        m = np.where(pweight[None, :] + vwgt[rows_v][:, None] <= cap[None, :],
                     deg, -np.inf)
        m[rows, own] = -np.inf
        t = np.argmax(m, axis=1)
        target_full[rows_v] = t
        internal_full[rows_v] = internal
        gain_full[rows_v] = m[rows, t] - internal

    for it in range(max_iters):
        # Evaluate rows whose cached degree row is missing or invalid, in
        # chunks so the (rows, k) matrix stays within the memory cap; rows
        # kept current by delta_update only need their target re-chosen.
        if deg_cache is not None:
            ka = known[active]
            need, cached_rows = active[~ka], active[ka]
        else:
            need, cached_rows = active, None
        for lo in range(0, need.shape[0], chunk):
            rows_v = need[lo:lo + chunk]
            deg = eval_rows(rows_v)
            if deg_cache is not None:
                deg_cache[rows_v] = deg
                known[rows_v] = True
            choose_targets(rows_v, deg)
        if cached_rows is not None and cached_rows.shape[0]:
            choose_targets(cached_rows, deg_cache[cached_rows])
        # A cached target goes stale when its partition fills up.  Degree
        # rows themselves only change when a co-member moves, so with the
        # row cache retargeting is a pure masked argmax — no re-gather;
        # without it the rows re-enter the active set for re-evaluation.
        stale = np.isfinite(gain_full) & (pweight[target_full] + vwgt > cap[target_full])
        srows = np.nonzero(stale)[0]
        if srows.shape[0]:
            if use_deg_cache:
                choose_targets(srows, deg_cache[srows])
                srows = np.empty(0, dtype=np.int64)
            else:
                gain_full[srows] = -np.inf
        is_cand = gain_full > 0
        plateau_move = False
        if not is_cand.any():
            if srows.shape[0]:
                active = srows  # retarget the stale rows before concluding
                continue
            # Positive fixed point: spend a stall credit on a Jet-style
            # escape round of zero/bounded-negative-gain moves.  Movers on
            # cooldown sit out (oscillation guard); a vertex with no
            # external presence toward its target (gain + internal == 0)
            # never escapes — such moves only churn isolated vertices.
            if (stall >= plateau_rounds
                    or escapes >= _PLATEAU_TOTAL * plateau_rounds):
                break
            stall += 1
            escapes += 1
            plateau_move = True
            is_cand = ((gain_full >= -plateau_eps * internal_full)
                       & (gain_full + internal_full > 0)
                       & (cooled_until < it))
        cand_idx = np.nonzero(is_cand)[0]
        if cand_idx.shape[0] == 0:
            if plateau_move:
                eligible = ((gain_full >= -plateau_eps * internal_full)
                            & (gain_full + internal_full > 0))
                if eligible.any():
                    # Every escape candidate is merely on cooldown: burn
                    # the stall credit and let the cooldowns expire instead
                    # of ending refinement (still bounded by the credit and
                    # total-escape caps).
                    active = np.empty(0, dtype=np.int64)
                    continue
            break

        # Iterated Luby rounds: movers form a conflict-free set, so their
        # gains are exact and additive.  Only the candidates' own scope
        # rows are scanned, not all m edges.
        movers = select_movers(cand_idx, jitter_round=it if plateau_move else None)
        if movers.shape[0] == 0:  # unreachable: the max-priority candidate survives
            break

        # Capacity admission: per target partition, admit in gain order while
        # the cumulative moved weight fits in the pre-batch headroom.
        mt = target_full[movers]
        mg = gain_full[movers]
        order = np.lexsort((movers, -mg, mt))
        movers, mt, mg = movers[order], mt[order], mg[order]
        admit = grouped_admission(mt, vwgt[movers], cap - pweight)
        moved, dest, moved_gain = movers[admit], mt[admit], mg[admit]
        if moved.shape[0] == 0:
            # Unreachable: the stale-target filter above guarantees every
            # surviving candidate's target has headroom for it right now,
            # so the top mover per target group always admits.
            break

        moves_total += moved.shape[0]
        prev = part[moved].copy()
        np.subtract.at(pweight, prev, vwgt[moved])
        np.add.at(pweight, dest, vwgt[moved])
        part[moved] = dest
        cut -= int(round(moved_gain.sum()))
        if vstate is not None:
            vstate.apply_moves(moved, prev, dest)
        if plateau_move:
            cooled_until[moved] = it + plateau_cooldown
        if cut < best_cut:
            best_cut = cut
            best_part = part.copy()
            if cut <= credit_base - max(1.0, _PLATEAU_TOL * credit_base):
                stall = 0
                credit_base = cut

        # Next active set: the movers, everything co-scoped with one, and
        # the stale-target rows awaiting feasible retargeting.  Capacity-
        # rejected movers keep their (still exact) cached gains and re-run
        # through admission next round.
        known[moved] = False  # a mover's own row changes in every column
        if use_delta:
            touched = delta_update(moved, prev, dest)
        else:
            touched = touched_by(moved, prev, dest)
            if deg_cache is not None:
                known[touched] = False
        mask[:] = False
        mask[moved] = True
        mask[srows] = True
        mask[touched] = True
        active = np.nonzero(mask)[0]
    if stats is not None:
        # Engine introspection for tests and benchmarks (cheap counters).
        stats["iterations"] = stats.get("iterations", 0) + it + 1
        stats["escapes"] = stats.get("escapes", 0) + escapes
        stats["moves"] = stats.get("moves", 0) + moves_total
    if cut > best_cut:  # plateau walk ended below its best — roll back
        part, cut = best_part, best_cut
    return part, cut


def uncoarsen_vec(
    levels: list[Graph],
    coarse_part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    use_kernel: bool | None = None,
    scalar_nk: int = _SCALAR_NK,
    scalar_max_k: int = _SCALAR_MAX_K,
    objective: str = "cut",
    plateau_rounds: int | None = None,
) -> tuple[np.ndarray, int]:
    """Walk levels coarse->fine, refining each level with whichever engine
    its shape favors: the scalar FM queue for small few-partition *cut*
    levels (see _SCALAR_NK/_SCALAR_MAX_K), the batched vec refiner
    otherwise.  Volume levels always use the vec refiner — with the
    incremental Φ table and the plateau walk it matches the scalar queue's
    quality at a fraction of the time (the λ-gain queue's per-move cost is
    worst exactly where delegation used to send it).  ``max_nonimproving``
    applies to the scalar-delegated levels; ``plateau_rounds`` threads
    through to ``refine_level_vec``."""

    def refine(g: Graph, p: np.ndarray) -> tuple[np.ndarray, int]:
        if (objective == "cut" and k <= scalar_max_k
                and g.num_vertices * k <= scalar_nk):
            return refine_level(g, p, k, capacity, max_nonimproving,
                                objective=objective)
        return refine_level_vec(g, p, k, capacity, use_kernel=use_kernel,
                                objective=objective,
                                plateau_rounds=plateau_rounds)

    part, cut = refine(levels[-1], coarse_part)
    for fine, coarse in zip(reversed(levels[:-1]), reversed(levels[1:])):
        part = project(part, coarse.cmap)
        part, cut = refine(fine, part)
    return part, cut

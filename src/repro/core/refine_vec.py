"""Array-parallel boundary refinement (the "vec" partitioning engine).

The scalar engine in ``refine.py`` follows the paper: a single global
priority queue pops one boundary vertex at a time, re-deriving its
per-partition degrees with a fresh ``np.bincount`` per pop.  That is O(n)
Python iterations per pass and dominates end-to-end partitioning time on
large SNNs.

This module is the Jet/label-propagation-style alternative: one shot of

    ``np.bincount(row * k + part[adjncy], weights=adjwgt)``

produces the external degree of *every* boundary vertex toward *every*
partition simultaneously; gains for all boundary vertices follow by
elementwise arithmetic, and a conflict-free batch of positive-gain moves
is applied per iteration:

1. every boundary vertex picks its best feasible target partition
   (capacity-checked against the pre-batch partition weights);
2. candidates adjacent to a higher-gain candidate are suppressed (one
   Luby-style round), so the surviving movers form an independent set and
   their gains are exact and additive;
3. movers are admitted in gain order per target partition under the
   remaining capacity (grouped cumulative-sum bookkeeping, no Python
   loop over vertices);
4. repeat until no positive-gain move exists (a fixed point).

Both objectives run through the same loop (selected by ``objective``):

* ``"cut"`` — the (rows, k) degree matrix above; conflicts are graph
  adjacency.
* ``"volume"`` — the degree matrix generalizes to the per-source
  distinct-partition presence matrix D* of ``graph.volume_degrees``
  (λ-gain of a move = D*[v, b] − D*[v, own], exact), and two candidates
  conflict when they share a *hyperedge* (two pins of one source need not
  be graph-adjacent, but their λ-gains interact).

Each iteration strictly decreases the integer objective, so termination is
guaranteed.  The batch scheme has weaker hill-climbing than the scalar
FM-style queue (no tentative negative-gain moves), which is why
``sneap_partition`` accepts both engines and the tests hold the vec cut to
a small tolerance of the scalar cut rather than equality.

For large k the dense per-partition degree matrix is also expressible as
``A @ onehot(part)`` — a tiled one-hot matmul the MXU eats for breakfast;
``repro.kernels.gain_eval`` implements exactly that and is used here when
running on TPU with a graph small enough to densify (coarse levels).  The
volume objective has the analogous dense form ``B @ presence`` (incidence
times per-hyperedge partition presence) — the kernel's "connectivity"
mode.
"""
from __future__ import annotations

import numpy as np

from .graph import (
    Graph,
    Hypergraph,
    comm_volume,
    csr_gather as _csr_gather,
    edge_cut,
    edge_partition_counts,
    grouped_admission,
    partition_weights,
    volume_degrees,
)
from .refine import _MAX_DEG_ENTRIES, project, refine_level

__all__ = ["partition_degrees", "refine_level_vec", "uncoarsen_vec"]

# Small-problem delegation bounds.  At few partitions the batched
# positive-gain passes stall in local optima that the scalar FM queue
# escapes (it tries negative-gain moves and undoes the failures), and the
# queue is cheap there — so `uncoarsen_vec` hands levels with
# n * k <= _SCALAR_NK and k <= _SCALAR_MAX_K to the scalar refiner.  Both
# bounds matter: FM's per-move cost grows with k (a bincount plus a sort
# of the k-wide degree vector per queue operation), so delegating a
# many-partition level would burn the very speedup this module exists for.
_SCALAR_NK = 1 << 20
_SCALAR_MAX_K = 64
# Volume-objective λ-gain queue operations touch every member of every
# incident hyperedge (fan-out × heavier than a cut bincount), so the vec
# engine only hands the very coarsest levels to the scalar FM queue there.
_SCALAR_NK_VOLUME = 1 << 15

# Densifying for the gain_eval kernel is only worthwhile on TPU and only
# for problems whose dense form fits comfortably in HBM (adjacency (n, n)
# for cut; incidence (n, E) for volume).
_KERNEL_MAX_N = 4096
_KERNEL_MIN_K = 64

# Boundary batches share `refine._MAX_DEG_ENTRIES`: rows * k entries per
# evaluation chunk (~128 MB of float64); larger boundaries are swept in
# row chunks.


def _row_edges(graph: Graph, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Gather the CSR edges of ``rows``: (edge index array, local row id array)."""
    return _csr_gather(graph.xadj, rows)


def partition_degrees(
    graph: Graph,
    part: np.ndarray,
    k: int,
    rows: np.ndarray | None = None,
) -> np.ndarray:
    """(R, k) weighted histogram of neighbor partitions for each row vertex.

    Column ``part[v]`` of row v holds v's internal degree; every other
    column b holds the external degree ED[v]_b.  ``rows=None`` computes all
    n rows (the issue's one-shot formula); passing the boundary-vertex
    subset keeps the matrix small on fine levels.
    """
    if rows is None:
        rows = np.arange(graph.num_vertices, dtype=np.int64)
    eidx, local = _row_edges(graph, rows)
    deg = np.bincount(
        local * k + part[graph.adjncy[eidx]].astype(np.int64),
        weights=graph.adjwgt[eidx],
        minlength=rows.shape[0] * k,
    )
    return deg.reshape(rows.shape[0], k)


def _dense_adjacency(graph: Graph) -> np.ndarray:
    """(n, n) f32 dense adjacency for the gain_eval kernel path."""
    n = graph.num_vertices
    adj = np.zeros((n, n), dtype=np.float32)
    adj[graph.edge_src, graph.adjncy] = graph.adjwgt
    return adj


def _dense_incidence(hyper: Hypergraph) -> np.ndarray:
    """(n, E) f32 member incidence, hfire-weighted, for the connectivity mode."""
    inc = np.zeros((hyper.num_vertices, hyper.num_hyperedges), dtype=np.float32)
    e_ids = np.arange(hyper.num_hyperedges)
    inc[hyper.hsrc.astype(np.int64), e_ids] = hyper.hfire
    inc[hyper.hpins.astype(np.int64), hyper.pin_edge] = hyper.hfire[hyper.pin_edge]
    return inc


def _degrees_via_kernel(adj: np.ndarray, part: np.ndarray, k: int,
                        rows: np.ndarray, backend: str) -> np.ndarray:
    """Row-subset degrees via the gain_eval tiled one-hot matmul kernel."""
    import jax.numpy as jnp

    from repro.kernels.gain_eval import part_degrees

    deg = part_degrees(jnp.asarray(adj), jnp.asarray(part, jnp.int32), k,
                       backend=backend)
    return np.asarray(deg, dtype=np.float64)[rows]


def _volume_degrees_via_kernel(inc: np.ndarray, hyper: Hypergraph,
                               part: np.ndarray, k: int, rows: np.ndarray,
                               backend: str) -> np.ndarray:
    """Row-subset D* via the gain_eval kernel's connectivity mode.

    base = B @ [Φ>0] counts every member (the row vertex included); the own
    column is overwritten with the B @ [Φ>1] gather, which demands a second
    member — exactly ``graph.volume_degrees``.
    """
    import jax.numpy as jnp

    from repro.kernels.gain_eval import connectivity_degrees

    phi = edge_partition_counts(hyper, part, k)
    pres = jnp.asarray(
        np.concatenate([(phi > 0), (phi > 1)], axis=1).astype(np.float32)
    )
    both = np.asarray(connectivity_degrees(jnp.asarray(inc), pres,
                                           backend=backend), dtype=np.float64)
    base, alt = both[rows, :k], both[rows, k:]
    own = part[rows]
    r = np.arange(rows.shape[0])
    base[r, own] = alt[r, own]
    return base


def refine_level_vec(
    graph: Graph,
    part: np.ndarray,
    k: int,
    capacity: int,
    max_iters: int = 200,
    use_kernel: bool | None = None,
    kernel_backend: str = "auto",
    objective: str = "cut",
) -> tuple[np.ndarray, int]:
    """Refine ``part`` by batched positive-gain moves; returns (part, score).

    ``score`` is the edge cut or communication volume per ``objective``.
    ``use_kernel=None`` auto-enables the gain_eval Pallas path on TPU for
    levels small enough to densify — and only when the total weight fits in
    float32's exact-integer range (< 2^24), since the kernel accumulates
    spike counts in f32 and the incremental bookkeeping demands exact
    integer gains.  True forces it (tests run it in interpret mode via
    ``kernel_backend="interpret"``), False keeps the pure-numpy (exact
    float64) bincount path.
    """
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    hyper = graph.hyper
    if objective == "volume" and hyper is None:
        raise ValueError("objective='volume' requires graph.hyper")
    part = part.astype(np.int64).copy()
    n = graph.num_vertices
    adjncy, adjwgt, vwgt = graph.adjncy, graph.adjwgt, graph.vwgt
    pweight = partition_weights(graph, part, k)
    cut = edge_cut(graph, part) if objective == "cut" else comm_volume(hyper, part)
    if graph.adjncy.shape[0] == 0:
        return part, cut
    src = graph.edge_src
    nbr = adjncy.astype(np.int64)
    if use_kernel is None:
        use_kernel = False
        total_w = (int(adjwgt.sum()) if objective == "cut"
                   else int(hyper.hfire.sum()) * 2)
        dense_ok = (n <= _KERNEL_MAX_N if objective == "cut"
                    else n <= _KERNEL_MAX_N and hyper.num_hyperedges <= _KERNEL_MAX_N)
        if dense_ok and k >= _KERNEL_MIN_K and total_w < (1 << 24):
            try:
                import jax

                use_kernel = jax.default_backend() == "tpu"
            except Exception:
                use_kernel = False

    if use_kernel:
        dense = (_dense_adjacency(graph) if objective == "cut"
                 else _dense_incidence(hyper))
    else:
        dense = None
    # The volume path materializes a (pairs, k) product where pairs is the
    # chunk's total incidence degree — bound the chunk by that expansion,
    # not just rows * k, or fan-out-heavy graphs blow the memory cap.
    row_cost = float(k)
    if objective == "volume" and n:
        avg_inc = (hyper.num_pins + hyper.num_hyperedges) / n
        row_cost *= max(avg_inc, 1.0)
    chunk = max(1, int(_MAX_DEG_ENTRIES / row_cost))

    def eval_rows(rows_v: np.ndarray) -> np.ndarray:
        if objective == "cut":
            if use_kernel:
                return _degrees_via_kernel(dense, part, k, rows_v, kernel_backend)
            return partition_degrees(graph, part, k, rows=rows_v)
        if use_kernel:
            return _volume_degrees_via_kernel(dense, hyper, part, k, rows_v,
                                              kernel_backend)
        return volume_degrees(hyper, part, k, rows=rows_v)

    def suppressed_movers(cand_idx: np.ndarray) -> np.ndarray:
        """One Luby round: the suppressed-candidate mask for this batch.

        A candidate loses to any co-scoped candidate of strictly higher
        (gain, -id) priority.  Cut: scopes are graph edges, so the pairwise
        scan over candidates' adjacency rows is degree-bounded.  Volume:
        scopes are hyperedges — the pairwise form would square a hub
        edge's pin count, so instead each hyperedge reduces its candidate
        members to one packed max priority and a candidate is suppressed
        iff some incident edge's max beats it (O(candidate incidences),
        no pin expansion).
        """
        suppressed = np.zeros(n, dtype=bool)
        if objective == "cut":
            eidx, local = _row_edges(graph, cand_idx)
            u, v = cand_idx[local], nbr[eidx]
            conflict = is_cand[v]
            u, v = u[conflict], v[conflict]
            beaten = (gain_full[v] > gain_full[u]) | (
                (gain_full[v] == gain_full[u]) & (v < u)
            )
            suppressed[u[beaten]] = True
            return suppressed
        # Packed (gain, -id) priority; distinct ids -> distinct keys, so
        # per-edge maxima induce exactly the pairwise tie-breaking above.
        gmax = int(gain_full[cand_idx].max())
        if gmax >= (1 << 62) // (n + 1):
            raise OverflowError("gains too large for the packed Luby keys")
        pri = gain_full[cand_idx].astype(np.int64) * (n + 1) + (n - cand_idx)
        vxadj, vedges = hyper.incidence()
        eidx, local = _csr_gather(vxadj, cand_idx)
        eids = vedges[eidx]
        edge_max = np.full(hyper.num_hyperedges, -1, dtype=np.int64)
        np.maximum.at(edge_max, eids, pri[local])
        lost = edge_max[eids] > pri[local]
        suppressed[cand_idx[local[lost]]] = True
        return suppressed

    def touched_by(moved: np.ndarray) -> np.ndarray:
        """Vertices whose cached gains are stale after `moved` move."""
        if objective == "cut":
            eidx, _ = _row_edges(graph, moved)
            return adjncy[eidx].astype(np.int64)
        vxadj, vedges = hyper.incidence()
        eidx, _ = _csr_gather(vxadj, moved)
        ue = np.unique(vedges[eidx])
        pidx, _ = _csr_gather(hyper.hxadj, ue)
        return np.concatenate([hyper.hpins[pidx].astype(np.int64),
                               hyper.hsrc[ue].astype(np.int64)])

    # Cached per-vertex move state.  A cached (gain, target) stays exact
    # until a co-member moves (gains depend only on other members'
    # partitions) or the vertex itself moves, so each iteration only
    # re-evaluates the "active" set: last batch's movers plus their scopes.
    gain_full = np.full(n, -np.inf)
    target_full = np.full(n, -1, dtype=np.int64)
    mask = np.zeros(n, dtype=bool)
    on_cut = part[src] != part[nbr]
    if not on_cut.any():
        return part, cut
    mask[src[on_cut]] = True
    active = np.nonzero(mask)[0]
    refreshed = False  # True after a full re-evaluation of stale candidates

    for _ in range(max_iters):
        # Re-evaluate active rows in chunks so the (rows, k) degree matrix
        # stays within the memory cap.  Targets are chosen by gain alone;
        # capacity is enforced exactly at admission time below (a full
        # feasibility mask here would double the per-iteration (rows, k)
        # work for a constraint that rarely binds under the k slack).
        for lo in range(0, active.shape[0], chunk):
            rows_v = active[lo:lo + chunk]
            deg = eval_rows(rows_v)
            own = part[rows_v]
            rows = np.arange(rows_v.shape[0])
            internal = deg[rows, own]  # advanced indexing: already a copy
            deg[rows, own] = -np.inf
            t = np.argmax(deg, axis=1)
            target_full[rows_v] = t
            gain_full[rows_v] = deg[rows, t] - internal
        is_cand = gain_full > 0
        cand_idx = np.nonzero(is_cand)[0]
        if cand_idx.shape[0] == 0:
            break

        # One Luby round: survivors form a conflict-free set, so their
        # gains are exact and additive.  Only the candidates' own scope
        # rows are scanned, not all m edges.
        suppressed = suppressed_movers(cand_idx)
        movers = cand_idx[~suppressed[cand_idx]]
        if movers.shape[0] == 0:  # unreachable: the max-priority candidate survives
            break

        # Capacity admission: per target partition, admit in gain order while
        # the cumulative moved weight fits in the pre-batch headroom.
        mt = target_full[movers]
        mg = gain_full[movers]
        order = np.lexsort((movers, -mg, mt))
        movers, mt, mg = movers[order], mt[order], mg[order]
        admit = grouped_admission(mt, vwgt[movers], capacity - pweight)
        moved, dest, moved_gain = movers[admit], mt[admit], mg[admit]
        if moved.shape[0] == 0:
            # Every candidate was admission-rejected under the *current*
            # partition weights; their cached targets may be stale.  Refresh
            # them all once, then give up if still stuck.
            if refreshed:
                break
            refreshed = True
            active = np.nonzero(is_cand)[0]
            continue
        refreshed = False

        np.subtract.at(pweight, part[moved], vwgt[moved])
        np.add.at(pweight, dest, vwgt[moved])
        part[moved] = dest
        cut -= int(round(moved_gain.sum()))

        # Next active set: the movers and everything co-scoped with one.
        mask[:] = False
        mask[moved] = True
        mask[touched_by(moved)] = True
        active = np.nonzero(mask)[0]
    return part, cut


def uncoarsen_vec(
    levels: list[Graph],
    coarse_part: np.ndarray,
    k: int,
    capacity: int,
    max_nonimproving: int = 64,
    use_kernel: bool | None = None,
    scalar_nk: int = _SCALAR_NK,
    scalar_max_k: int = _SCALAR_MAX_K,
    objective: str = "cut",
) -> tuple[np.ndarray, int]:
    """Walk levels coarse->fine, refining each level with whichever engine
    its shape favors: the scalar FM queue for small few-partition levels
    (see _SCALAR_NK/_SCALAR_MAX_K), the batched vec refiner otherwise.
    ``max_nonimproving`` applies to the scalar-delegated levels."""

    if objective == "volume":
        scalar_nk = min(scalar_nk, _SCALAR_NK_VOLUME)

    def refine(g: Graph, p: np.ndarray) -> tuple[np.ndarray, int]:
        if k <= scalar_max_k and g.num_vertices * k <= scalar_nk:
            return refine_level(g, p, k, capacity, max_nonimproving,
                                objective=objective)
        return refine_level_vec(g, p, k, capacity, use_kernel=use_kernel,
                                objective=objective)

    part, cut = refine(levels[-1], coarse_part)
    for fine, coarse in zip(reversed(levels[:-1]), reversed(levels[1:])):
        part = project(part, coarse.cmap)
        part, cut = refine(fine, part)
    return part, cut

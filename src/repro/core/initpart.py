"""Initial partitioning step of the multilevel paradigm (paper §3.3).

Greedy region growing on the coarsest graph G_c: seed each partition with a
random unassigned vertex, then repeatedly pull in the unassigned vertex
connected to the partition by the heaviest edge, until the partition's
total vertex weight reaches the capacity bound (the number of neurons a
neuromorphic core can accommodate).
"""
from __future__ import annotations

import heapq

import numpy as np

from .graph import Graph

__all__ = ["greedy_region_growing"]


def greedy_region_growing(
    graph: Graph,
    k: int,
    capacity: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Return part[v] in [0, k) with per-partition vertex weight <= capacity."""
    n = graph.num_vertices
    if k * capacity < graph.total_vwgt:
        raise ValueError(
            f"infeasible: k={k} cores x capacity={capacity} < total weight {graph.total_vwgt}"
        )
    part = np.full(n, -1, dtype=np.int64)
    pweight = np.zeros(k, dtype=np.int64)
    xadj, adjncy, adjwgt, vwgt = graph.xadj, graph.adjncy, graph.adjwgt, graph.vwgt
    seed_order = iter(rng.permutation(n))

    def next_seed() -> int | None:
        for s in seed_order:
            if part[s] == -1:
                return int(s)
        return None

    for p in range(k):
        seed = next_seed()
        if seed is None:
            break
        if pweight[p] + vwgt[seed] > capacity:
            continue  # degenerate: oversized single vertex for remaining space
        part[seed] = p
        pweight[p] += vwgt[seed]
        # Max-heap of (−edge weight, vertex) edges from the partition frontier.
        heap: list[tuple[int, int]] = []
        s, e = xadj[seed], xadj[seed + 1]
        for u, w in zip(adjncy[s:e], adjwgt[s:e]):
            heapq.heappush(heap, (-int(w), int(u)))
        while heap:
            negw, u = heapq.heappop(heap)
            if part[u] != -1:
                continue
            if pweight[p] + vwgt[u] > capacity:
                continue  # skip; a lighter frontier vertex may still fit
            part[u] = p
            pweight[p] += vwgt[u]
            s, e = xadj[u], xadj[u + 1]
            for v2, w2 in zip(adjncy[s:e], adjwgt[s:e]):
                if part[v2] == -1:
                    heapq.heappush(heap, (-int(w2), int(v2)))

    # Leftovers (disconnected or skipped): place into lightest feasible partition.
    for v in np.nonzero(part == -1)[0]:
        order = np.argsort(pweight, kind="stable")
        placed = False
        for p in order:
            if pweight[p] + vwgt[v] <= capacity:
                part[v] = p
                pweight[p] += vwgt[v]
                placed = True
                break
        if not placed:
            raise RuntimeError("could not place vertex within capacity — infeasible instance")
    return part

"""Initial partitioning step of the multilevel paradigm (paper §3.3).

Greedy region growing on the coarsest graph G_c: seed each partition with a
random unassigned vertex, then repeatedly pull in the unassigned vertex
connected to the partition by the heaviest edge, until the partition's
total vertex weight reaches the capacity bound (the number of neurons a
neuromorphic core can accommodate).

Two engines share the contract:

* the sequential heap walk (``impl="scalar"``) — grows one partition at a
  time to capacity, exactly the paper's loop; and
* a frontier-at-once vectorized grower (``impl="vec"``) — all k regions
  grow simultaneously in rounds: one ``np.maximum.at`` segment-argmax over
  the CSR arrays finds every unassigned vertex's heaviest edge into the
  assigned region, and a grouped-cumsum admission (identical to the vec
  refiner's) admits frontier vertices per partition in weight order under
  capacity.  No per-vertex Python work.

``impl="auto"`` (what the vec partitioning engine requests) picks the
vectorized grower unless the instance is a tight fit — when
``k * capacity`` barely exceeds the total vertex weight, round-based
balanced growth strands heavy vertices that only the one-region-at-a-time
heap walk can still pack, so the heap version stays the fallback there.
"""
from __future__ import annotations

import heapq

import numpy as np

from .graph import Graph, grouped_admission

__all__ = ["greedy_region_growing"]

# Tight-fit guard: below this slack factor the vectorized grower falls back
# to the sequential heap walk (see module docstring).
_VEC_MIN_SLACK = 1.05


def _place_leftovers(
    part: np.ndarray, pweight: np.ndarray, vwgt: np.ndarray, capacity: int
) -> np.ndarray:
    """Assign part==-1 vertices, heaviest first, to the lightest feasible
    partition (heavy-first packing wastes the least headroom)."""
    leftover = np.nonzero(part == -1)[0]
    for v in leftover[np.argsort(-vwgt[leftover], kind="stable")]:
        order = np.argsort(pweight, kind="stable")
        placed = False
        for p in order:
            if pweight[p] + vwgt[v] <= capacity:
                part[v] = p
                pweight[p] += vwgt[v]
                placed = True
                break
        if not placed:
            raise RuntimeError("could not place vertex within capacity — infeasible instance")
    return part


def _grow_scalar(
    graph: Graph, k: int, capacity: int, rng: np.random.Generator
) -> np.ndarray:
    n = graph.num_vertices
    part = np.full(n, -1, dtype=np.int64)
    pweight = np.zeros(k, dtype=np.int64)
    xadj, adjncy, adjwgt, vwgt = graph.xadj, graph.adjncy, graph.adjwgt, graph.vwgt
    seed_order = iter(rng.permutation(n))

    def next_seed() -> int | None:
        for s in seed_order:
            if part[s] == -1:
                return int(s)
        return None

    for p in range(k):
        seed = next_seed()
        if seed is None:
            break
        if pweight[p] + vwgt[seed] > capacity:
            continue  # degenerate: oversized single vertex for remaining space
        part[seed] = p
        pweight[p] += vwgt[seed]
        # Max-heap of (−edge weight, vertex) edges from the partition frontier.
        heap: list[tuple[int, int]] = []
        s, e = xadj[seed], xadj[seed + 1]
        for u, w in zip(adjncy[s:e], adjwgt[s:e]):
            heapq.heappush(heap, (-int(w), int(u)))
        while heap:
            negw, u = heapq.heappop(heap)
            if part[u] != -1:
                continue
            if pweight[p] + vwgt[u] > capacity:
                continue  # skip; a lighter frontier vertex may still fit
            part[u] = p
            pweight[p] += vwgt[u]
            s, e = xadj[u], xadj[u + 1]
            for v2, w2 in zip(adjncy[s:e], adjwgt[s:e]):
                if part[v2] == -1:
                    heapq.heappush(heap, (-int(w2), int(v2)))

    return _place_leftovers(part, pweight, vwgt, capacity)


def _grow_vec(
    graph: Graph, k: int, capacity: int, rng: np.random.Generator
) -> np.ndarray:
    n = graph.num_vertices
    part = np.full(n, -1, dtype=np.int64)
    pweight = np.zeros(k, dtype=np.int64)
    adjncy, adjwgt, vwgt = graph.adjncy, graph.adjwgt, graph.vwgt
    edge_src = graph.edge_src
    nbr = adjncy.astype(np.int64)

    # Seed every region at once with distinct random vertices that fit
    # (fewer seeds than regions when n < k; the extras stay empty).
    seeds = rng.permutation(n)[:k]
    fits = vwgt[seeds] <= capacity
    seeds = seeds[fits]
    seed_parts = np.arange(seeds.shape[0], dtype=np.int64)
    part[seeds] = seed_parts
    pweight[seed_parts] = vwgt[seeds]

    if int(adjwgt.max(initial=0)) >= (1 << 62) // max(k, 1):
        raise OverflowError("edge weights too large for the packed frontier keys")

    for _ in range(n):
        # Frontier: edges from an assigned vertex into an unassigned one.
        live = (part[edge_src] >= 0) & (part[nbr] == -1)
        if not live.any():
            break
        v_ids = nbr[live]
        # Heaviest-edge pull per unassigned vertex as one packed segment-max
        # (weight * k + partition; ties break toward the higher partition id).
        best = np.full(n, -1, dtype=np.int64)
        np.maximum.at(best, v_ids, adjwgt[live] * k + part[edge_src[live]])
        cand = np.nonzero(best >= 0)[0]
        bw = best[cand] // k
        bp = best[cand] % k
        # Admission: per partition, admit in pull-weight order while the
        # cumulative vertex weight fits in the remaining headroom (the
        # refiner's grouped-cumsum step, shared via graph.grouped_admission).
        order = np.lexsort((cand, -bw, bp))
        cand, bp = cand[order], bp[order]
        admit = grouped_admission(bp, vwgt[cand], capacity - pweight)
        if not admit.any():
            break  # every frontier vertex is blocked by capacity
        grown, gp = cand[admit], bp[admit]
        part[grown] = gp
        np.add.at(pweight, gp, vwgt[grown])

    try:
        return _place_leftovers(part, pweight, vwgt, capacity)
    except RuntimeError:
        # Round-based growth packed the regions too evenly to absorb a
        # heavy leftover; the one-region-at-a-time heap walk leaves more
        # uneven headroom, so retry with it before declaring infeasibility.
        return _grow_scalar(graph, k, capacity, rng)


def greedy_region_growing(
    graph: Graph,
    k: int,
    capacity: int,
    rng: np.random.Generator,
    impl: str = "scalar",
) -> np.ndarray:
    """Return part[v] in [0, k) with per-partition vertex weight <= capacity.

    ``impl``: "scalar" (sequential heap walk), "vec" (frontier-at-once
    rounds; falls back to scalar on tight-fit instances), or "auto"
    (vec when the instance has slack, scalar otherwise).
    """
    if impl not in ("scalar", "vec", "auto"):
        raise ValueError(f"unknown region-growing impl {impl!r}")
    if k * capacity < graph.total_vwgt:
        raise ValueError(
            f"infeasible: k={k} cores x capacity={capacity} < total weight {graph.total_vwgt}"
        )
    tight = k * capacity < _VEC_MIN_SLACK * graph.total_vwgt
    if impl in ("vec", "auto") and not tight:
        return _grow_vec(graph, k, capacity, rng)
    return _grow_scalar(graph, k, capacity, rng)

"""Mapping phase: place partitions on the NoC mesh (paper §3.4).

Three heuristic searches over placements — Simulated Annealing (the
paper's winner), Particle Swarm Optimization (SpiNeMap's placer), and Tabu
search — all scored through a pluggable placement objective
(`repro.core.placecost`): the paper's pairwise Eq. 2 hop cost, or the
tree-hop objective whose cost is the hfire-weighted XY multicast-tree link
count (the quantity the tree-fork NoC replay actually measures under
``cast="multicast"``).

Placements are represented as a permutation of all `num_cores` cores: the
objective zero-pads with `num_cores - k` virtual partitions, so a "swap
with a virtual partition" implements moving a real partition to an empty
core.  All searches share the same neighborhood (swap two positions).

``sa_search`` has two engines, mirroring the partitioner's
``impl="scalar"|"vec"`` split:

* ``impl="scalar"`` — the paper-faithful serial chain: one proposal at a
  time, scored by the O(k) incremental delta.  The parity reference.
* ``impl="vec"`` — the batched engine: ``batch`` candidate swaps proposed
  per step, scored in one vectorized delta call (numpy, or the
  `repro.kernels.swap_delta` MXU batch via ``score_backend``), Metropolis
  acceptance applied elementwise, and a conflict-free (position-disjoint)
  accepted subset committed at once with an exact cost resync.

The device searches (population SA, kernel-powered greedy polish, island
SA) live in `repro.core.mapping_jax` but are registered here in
``MAPPERS`` (``"sa_jax"``, ``"polish"``, ``"island"``) so every consumer
selects a mapper through one registry.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .placecost import PairwiseObjective

__all__ = [
    "MappingResult",
    "pad_traffic",
    "sa_search",
    "tabu_search",
    "pso_search",
    "MAPPERS",
    "OBJECTIVE_AWARE_MAPPERS",
]


@dataclass
class MappingResult:
    placement: np.ndarray  # (k,) core id per (real) partition
    avg_hop: float  # pairwise Eq. 2 average hops per packet (Fig. 5 units)
    seconds: float
    # Convergence history: (time_axis, best_cost) samples (Fig 5).  The
    # cost samples are in the units of the objective that DROVE the search
    # (the `objective` field below: "pairwise" = Eq. 2 avg hops per
    # packet, "tree" = avg multicast tree-link traversals per packet),
    # normalized by trace_length — do not mix histories across objectives
    # on one convergence plot without checking that field.  Host searches
    # record elapsed seconds for the time axis; device searches
    # (mapping_jax) run the whole chain inside one lax.scan where
    # wall-clock sampling is impossible, so they record the
    # temperature-epoch index instead and `seconds` holds the single
    # post-run elapsed measurement.
    history: list[tuple[float, float]] = field(default_factory=list)
    evaluations: int = 0
    # Average multicast tree-link traversals per packet of the final
    # placement (same normalization as avg_hop).  Filled by searches that
    # ran the tree objective; the pipeline's shared evaluator
    # (`placecost.evaluate_placement`) fills it for every method when the
    # profiled hypergraph is available.
    tree_hop: float | None = None
    # Which placement objective the search minimized — and hence the units
    # of the `history` samples ("pairwise" or "tree").
    objective: str = "pairwise"


def pad_traffic(traffic: np.ndarray, num_cores: int) -> np.ndarray:
    """Zero-pad a (k, k) traffic matrix to (num_cores, num_cores)."""
    k = traffic.shape[0]
    if k > num_cores:
        raise ValueError(f"{k} partitions > {num_cores} cores")
    out = np.zeros((num_cores, num_cores), dtype=np.float64)
    out[:k, :k] = traffic
    return out


def _resolve_objective(objective, traffic, num_cores, mesh_w, torus):
    """Default to the paper's pairwise objective when none is supplied."""
    if objective is None:
        return PairwiseObjective(traffic, num_cores, mesh_w, torus=torus)
    if objective.num_positions != num_cores:
        raise ValueError(
            f"objective built for {objective.num_positions} cores, got {num_cores}"
        )
    return objective


def _finalize(
    obj, best: np.ndarray, traffic: np.ndarray, num_cores: int, mesh_w: int,
    trace_length: int, torus: bool, start: float, history: list, evals: int,
) -> MappingResult:
    """Exact final scoring shared by all host searches.

    Recomputes the driving objective from scratch (guards incremental
    drift) and always reports the pairwise ``avg_hop`` — when the search
    ran the tree objective, the Eq. 2 score is evaluated on the side so
    Fig. 5 comparisons across objectives stay in one unit.
    """
    k = traffic.shape[0]
    score = obj.total(best) / trace_length
    seconds = time.perf_counter() - start
    history.append((seconds, score))
    if obj.name == "pairwise":
        avg_hop, tree_hop = float(score), None
    else:
        pw = PairwiseObjective(traffic, num_cores, mesh_w, torus=torus)
        avg_hop, tree_hop = float(pw.total(best) / trace_length), float(score)
    return MappingResult(
        placement=best[:k].copy(), avg_hop=avg_hop, seconds=seconds,
        history=history, evaluations=evals, tree_hop=tree_hop,
        objective=obj.name,
    )


def sa_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    time_budget: float | None = None,
    iters: int = 20_000,
    t0_frac: float = 0.25,
    alpha: float = 0.95,
    sweeps_per_temp: int | None = None,
    torus: bool = False,
    init: np.ndarray | None = None,
    impl: str = "scalar",
    batch: int = 256,
    score_backend: str = "numpy",
    objective=None,
) -> MappingResult:
    """Simulated annealing over placements (paper §3.4.1).

    Accepts uphill moves with prob exp(-delta/T); geometric cooling.  The
    O(k) incremental swap delta makes each step cheap — the analytic-eval
    insight that gives SNEAP its end-to-end speedup.  `init` seeds the
    chain (e.g. the identity layout for mesh-layout optimization); the
    returned best never regresses below the seed.

    ``impl="scalar"`` is the serial reference chain; ``impl="vec"`` scores
    ``batch`` proposals per step in one vectorized delta call and commits
    a conflict-free accepted subset (see the module docstring).  ``iters``
    counts *proposals* under both engines, so equal budgets do equal
    search work.  ``score_backend`` (vec + pairwise only) routes the batch
    scoring through the `kernels/swap_delta` all-pairs MXU kernel
    ("jnp" | "pallas" | "interpret" | "auto") instead of the numpy batch
    delta.  ``objective`` is a `repro.core.placecost` objective instance;
    None means the paper's pairwise Eq. 2 cost built from ``traffic``.
    """
    if impl not in ("scalar", "vec"):
        raise ValueError(f"unknown impl {impl!r}")
    start = time.perf_counter()
    rng = np.random.default_rng(seed)
    k = traffic.shape[0]
    trace_length = max(trace_length, 1)  # zero-traffic profiles normalize by 1
    obj = _resolve_objective(objective, traffic, num_cores, mesh_w, torus)

    placement = (np.asarray(init, dtype=np.int64).copy() if init is not None
                 else rng.permutation(num_cores).astype(np.int64))
    cost = obj.attach(placement)
    best = placement.copy()
    best_cost = cost
    # Initial temperature: a fraction of the initial per-spike cost scale.
    T = max(t0_frac * cost / max(k, 1), 1e-9)
    if sweeps_per_temp is None:
        sweeps_per_temp = max(num_cores, 32)
    history = [(0.0, best_cost / trace_length)]
    evals = 0

    if impl == "vec":
        scorer = _make_batch_scorer(obj, num_cores, mesh_w, score_backend)
        # On small meshes a large batch is mostly conflicts against one
        # placement state; clamp to ~2 proposals per position.
        batch = max(2, min(batch, 2 * num_cores))
        # Continuous form of the scalar engine's per-sweep geometric
        # cooling: after `batch` proposals the temperature has decayed by
        # the same factor a scalar chain's would over that many steps.
        cool = alpha ** (batch / sweeps_per_temp)
        it = 0
        while it < iters:
            aa = rng.integers(0, num_cores, size=batch)
            b0 = rng.integers(0, num_cores - 1, size=batch)
            bb = np.where(b0 >= aa, b0 + 1, b0)
            deltas = scorer(placement, aa, bb)
            evals += batch
            it += batch
            accept = (deltas <= 0) | (
                rng.random(batch) < np.exp(np.minimum(-deltas / T, 0.0))
            )
            idx = np.flatnonzero(accept)
            if idx.shape[0]:
                # Conflict-free subset, Luby-style: a candidate survives
                # iff it owns (= has the smallest index among candidates
                # touching) both of its positions; survivors are
                # position-disjoint, so their swaps commute.
                owner = np.full(num_cores, batch, dtype=np.int64)
                np.minimum.at(owner, aa[idx], idx)
                np.minimum.at(owner, bb[idx], idx)
                keep = idx[(owner[aa[idx]] == idx) & (owner[bb[idx]] == idx)]
                if keep.shape[0]:
                    cost = obj.apply_swaps(
                        np.stack([aa[keep], bb[keep]], axis=1)
                    )
                    if cost < best_cost - 1e-9:
                        best_cost = cost
                        best = placement.copy()
                        history.append(
                            (time.perf_counter() - start,
                             best_cost / trace_length)
                        )
            T = max(T * cool, 1e-12)
            if time_budget is not None and time.perf_counter() - start > time_budget:
                break
        return _finalize(obj, best, traffic, num_cores, mesh_w, trace_length,
                         torus, start, history, evals)

    it = 0
    while it < iters:
        improved_at_temp = False
        for _ in range(sweeps_per_temp):
            a = int(rng.integers(num_cores))
            b = int(rng.integers(num_cores - 1))
            b = b + 1 if b >= a else b
            delta = obj.swap_delta(a, b)
            evals += 1
            it += 1
            if delta <= 0 or rng.random() < np.exp(-delta / T):
                cost = obj.apply_swaps(np.array([[a, b]]), total_delta=delta)
                if cost < best_cost - 1e-9:
                    best_cost = cost
                    best = placement.copy()
                    improved_at_temp = True
                    history.append((time.perf_counter() - start, best_cost / trace_length))
            if time_budget is not None and time.perf_counter() - start > time_budget:
                it = iters
                break
        T *= alpha
        if T < 1e-12 and not improved_at_temp:
            break
    return _finalize(obj, best, traffic, num_cores, mesh_w, trace_length,
                     torus, start, history, evals)


def _make_batch_scorer(obj, num_cores: int, mesh_w: int, score_backend: str):
    """Candidate-batch scorer for the vec engine.

    "numpy" asks the objective itself (incremental batch delta);
    otherwise the pairwise objective is rescored through the all-pairs
    `kernels/swap_delta` MXU batch and the candidate pairs gathered from
    the full delta matrix (f32 on device — quality-equivalent, bitwise
    different from the f64 host deltas).
    """
    if score_backend == "numpy":
        return lambda placement, aa, bb: obj.swap_delta_batch(aa, bb)
    if obj.name != "pairwise":
        raise ValueError(
            f"score_backend={score_backend!r} supports only the pairwise "
            f"objective, not {obj.name!r}"
        )
    import jax.numpy as jnp

    from repro.kernels.swap_delta import swap_deltas_pairs

    from .hopcost import core_coords

    sym_d = jnp.asarray(obj.sym, dtype=jnp.float32)
    coords = core_coords(num_cores, mesh_w).astype(np.float32)
    x, y = coords[:, 0], coords[:, 1]

    def scorer(placement, aa, bb):
        deltas = swap_deltas_pairs(
            sym_d,
            jnp.asarray(x[placement]),
            jnp.asarray(y[placement]),
            aa, bb,
            backend=score_backend,
        )
        return np.asarray(deltas, dtype=np.float64)

    return scorer


def tabu_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    time_budget: float | None = None,
    iters: int = 400,
    tenure: int | None = None,
    candidates: int = 256,
    torus: bool = False,
    objective=None,
) -> MappingResult:
    """Tabu search: best-of-candidate-swaps with a recency tabu list.

    The candidate neighborhood is scored in one batched delta call per
    step (the same vectorized scorer the vec SA engine uses), with
    selection semantics identical to the historical per-candidate loop:
    earliest strict minimum among non-tabu or aspirating candidates.
    """
    start = time.perf_counter()
    rng = np.random.default_rng(seed)
    trace_length = max(trace_length, 1)  # zero-traffic profiles normalize by 1
    obj = _resolve_objective(objective, traffic, num_cores, mesh_w, torus)
    if tenure is None:
        tenure = max(8, num_cores // 4)

    placement = rng.permutation(num_cores).astype(np.int64)
    cost = obj.attach(placement)
    best, best_cost = placement.copy(), cost
    tabu_until = np.zeros((num_cores, num_cores), dtype=np.int64)
    history = [(0.0, best_cost / trace_length)]
    evals = 0
    for step in range(iters):
        pa = rng.integers(0, num_cores, size=candidates)
        pb = rng.integers(0, num_cores, size=candidates)
        lo, hi = np.minimum(pa, pb), np.maximum(pa, pb)
        valid = lo != hi
        deltas = obj.swap_delta_batch(lo, hi)
        evals += int(valid.sum())
        is_tabu = tabu_until[lo, hi] > step
        aspires = cost + deltas < best_cost - 1e-9
        ok = valid & (~is_tabu | aspires)
        if not ok.any():
            break
        i = int(np.argmin(np.where(ok, deltas, np.inf)))
        a, b = int(lo[i]), int(hi[i])
        cost = obj.apply_swaps(np.array([[a, b]]), total_delta=float(deltas[i]))
        tabu_until[a, b] = step + tenure
        if cost < best_cost - 1e-9:
            best_cost = cost
            best = placement.copy()
            history.append((time.perf_counter() - start, best_cost / trace_length))
        if time_budget is not None and time.perf_counter() - start > time_budget:
            break
    return _finalize(obj, best, traffic, num_cores, mesh_w, trace_length,
                     torus, start, history, evals)


def pso_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    time_budget: float | None = None,
    iters: int = 200,
    swarm: int = 32,
    w: float = 0.72,
    c1: float = 1.49,
    c2: float = 1.49,
    torus: bool = False,
    objective=None,
) -> MappingResult:
    """Random-key PSO (SpiNeMap's placer, §2.2): particles are continuous
    priority vectors; argsort decodes a vector into a core permutation."""
    start = time.perf_counter()
    rng = np.random.default_rng(seed)
    trace_length = max(trace_length, 1)  # zero-traffic profiles normalize by 1
    obj = _resolve_objective(objective, traffic, num_cores, mesh_w, torus)

    def decode(x: np.ndarray) -> np.ndarray:
        return np.argsort(x).astype(np.int64)

    pos = rng.standard_normal((swarm, num_cores))
    vel = np.zeros_like(pos)
    pbest = pos.copy()
    pbest_cost = np.array([obj.total(decode(p)) for p in pos])
    g = int(np.argmin(pbest_cost))
    gbest, gbest_cost = pbest[g].copy(), float(pbest_cost[g])
    history = [(0.0, gbest_cost / trace_length)]
    evals = swarm
    for _ in range(iters):
        r1 = rng.random((swarm, num_cores))
        r2 = rng.random((swarm, num_cores))
        vel = w * vel + c1 * r1 * (pbest - pos) + c2 * r2 * (gbest[None, :] - pos)
        pos = pos + vel
        costs = np.array([obj.total(decode(p)) for p in pos])
        evals += swarm
        better = costs < pbest_cost
        pbest[better] = pos[better]
        pbest_cost[better] = costs[better]
        g = int(np.argmin(pbest_cost))
        if pbest_cost[g] < gbest_cost - 1e-9:
            gbest, gbest_cost = pbest[g].copy(), float(pbest_cost[g])
            history.append((time.perf_counter() - start, gbest_cost / trace_length))
        if time_budget is not None and time.perf_counter() - start > time_budget:
            break
    return _finalize(obj, decode(gbest), traffic, num_cores, mesh_w,
                     trace_length, torus, start, history, evals)


def _device_mapper(fn_name: str):
    """Registry hook for a `mapping_jax` search, imported on first call so
    selecting a host mapper never pays the jax import."""

    def call(*args, **kwargs):
        from . import mapping_jax

        return getattr(mapping_jax, fn_name)(*args, **kwargs)

    call.__name__ = call.__qualname__ = fn_name
    call.__doc__ = f"Lazy registry hook for repro.core.mapping_jax.{fn_name}."
    return call


# One registry for every placement search, host and device alike.  Device
# entries resolve lazily into `repro.core.mapping_jax`; "island" requires a
# `mesh=` kwarg (a jax.sharding.Mesh) on call.
MAPPERS = {
    "sa": sa_search,
    "pso": pso_search,
    "tabu": tabu_search,
    "sa_jax": _device_mapper("sa_search_jax"),
    "polish": _device_mapper("polish_search"),
    "island": _device_mapper("island_sa"),
}

# Mappers that accept an `objective=` placement objective.  The device
# searches run the pairwise Eq. 2 objective only (their inner loops are
# gather-arithmetic reformulations of it); callers wanting tree-objective
# placement must pick a host mapper.
OBJECTIVE_AWARE_MAPPERS = frozenset({"sa", "pso", "tabu"})

"""Mapping phase: place partitions on the NoC mesh (paper §3.4).

Three heuristic searches over placements — Simulated Annealing (the
paper's winner), Particle Swarm Optimization (SpiNeMap's placer), and Tabu
search — all scored by the analytic average-hop evaluator instead of a
hardware simulator.

Placements are represented as a permutation of all `num_cores` cores: the
traffic matrix is zero-padded with `num_cores - k` virtual partitions, so a
"swap with a virtual partition" implements moving a real partition to an
empty core.  All three searches share the same neighborhood (swap two
positions) and the same objective (Eq. 2: minimize average hop H).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .hopcost import hop_distance_matrix, swap_delta

__all__ = ["MappingResult", "pad_traffic", "sa_search", "tabu_search", "pso_search", "MAPPERS"]


@dataclass
class MappingResult:
    placement: np.ndarray  # (k,) core id per (real) partition
    avg_hop: float
    seconds: float
    # Convergence history: (time_axis, best_avg_hop) samples (Fig 5).  Host
    # searches record elapsed seconds; device searches (mapping_jax) run the
    # whole chain inside one lax.scan where wall-clock sampling is
    # impossible, so they record the temperature-epoch index instead and
    # `seconds` holds the single post-run elapsed measurement.
    history: list[tuple[float, float]] = field(default_factory=list)
    evaluations: int = 0


def pad_traffic(traffic: np.ndarray, num_cores: int) -> np.ndarray:
    """Zero-pad a (k, k) traffic matrix to (num_cores, num_cores)."""
    k = traffic.shape[0]
    if k > num_cores:
        raise ValueError(f"{k} partitions > {num_cores} cores")
    out = np.zeros((num_cores, num_cores), dtype=np.float64)
    out[:k, :k] = traffic
    return out


def _total_cost(sym: np.ndarray, placement: np.ndarray, dist: np.ndarray) -> float:
    d = dist[placement[:, None], placement[None, :]]
    return float((d * sym).sum() / 2.0)


def sa_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    time_budget: float | None = None,
    iters: int = 20_000,
    t0_frac: float = 0.25,
    alpha: float = 0.95,
    sweeps_per_temp: int | None = None,
    torus: bool = False,
    init: np.ndarray | None = None,
) -> MappingResult:
    """Simulated annealing over placements (paper §3.4.1).

    Accepts uphill moves with prob exp(-delta/T); geometric cooling.  The
    O(k) incremental `swap_delta` makes each step cheap — the analytic-eval
    insight that gives SNEAP its end-to-end speedup.  `init` seeds the
    chain (e.g. the identity layout for mesh-layout optimization); the
    returned best never regresses below the seed.
    """
    start = time.perf_counter()
    rng = np.random.default_rng(seed)
    k = traffic.shape[0]
    padded = pad_traffic(traffic, num_cores)
    sym = padded + padded.T
    dist = hop_distance_matrix(num_cores, mesh_w, torus=torus).astype(np.float64)

    placement = (np.asarray(init, dtype=np.int64).copy() if init is not None
                 else rng.permutation(num_cores).astype(np.int64))
    cost = _total_cost(sym, placement, dist)
    best = placement.copy()
    best_cost = cost
    # Initial temperature: a fraction of the initial per-spike cost scale.
    T = max(t0_frac * cost / max(k, 1), 1e-9)
    if sweeps_per_temp is None:
        sweeps_per_temp = max(num_cores, 32)
    history = [(0.0, best_cost / trace_length)]
    evals = 0
    it = 0
    while it < iters:
        improved_at_temp = False
        for _ in range(sweeps_per_temp):
            a = int(rng.integers(num_cores))
            b = int(rng.integers(num_cores - 1))
            b = b + 1 if b >= a else b
            delta = swap_delta(sym, placement, dist, a, b)
            evals += 1
            it += 1
            if delta <= 0 or rng.random() < np.exp(-delta / T):
                placement[a], placement[b] = placement[b], placement[a]
                cost += delta
                if cost < best_cost - 1e-9:
                    best_cost = cost
                    best = placement.copy()
                    improved_at_temp = True
                    history.append((time.perf_counter() - start, best_cost / trace_length))
            if time_budget is not None and time.perf_counter() - start > time_budget:
                it = iters
                break
        T *= alpha
        if T < 1e-12 and not improved_at_temp:
            break
    seconds = time.perf_counter() - start
    # Recompute exactly from the best placement (guards incremental drift).
    avg = _total_cost(sym, best, dist) / trace_length
    history.append((seconds, avg))
    return MappingResult(placement=best[:k], avg_hop=float(avg), seconds=seconds,
                         history=history, evaluations=evals)


def tabu_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    time_budget: float | None = None,
    iters: int = 400,
    tenure: int | None = None,
    candidates: int = 256,
    torus: bool = False,
) -> MappingResult:
    """Tabu search: best-of-candidate-swaps with a recency tabu list."""
    start = time.perf_counter()
    rng = np.random.default_rng(seed)
    k = traffic.shape[0]
    padded = pad_traffic(traffic, num_cores)
    sym = padded + padded.T
    dist = hop_distance_matrix(num_cores, mesh_w, torus=torus).astype(np.float64)
    if tenure is None:
        tenure = max(8, num_cores // 4)

    placement = rng.permutation(num_cores).astype(np.int64)
    cost = _total_cost(sym, placement, dist)
    best, best_cost = placement.copy(), cost
    tabu_until = np.zeros((num_cores, num_cores), dtype=np.int64)
    history = [(0.0, best_cost / trace_length)]
    evals = 0
    for step in range(iters):
        pairs_a = rng.integers(0, num_cores, size=candidates)
        pairs_b = rng.integers(0, num_cores, size=candidates)
        chosen = None
        chosen_delta = None
        for a, b in zip(pairs_a, pairs_b):
            if a == b:
                continue
            a, b = int(min(a, b)), int(max(a, b))
            delta = swap_delta(sym, placement, dist, a, b)
            evals += 1
            is_tabu = tabu_until[a, b] > step
            aspires = cost + delta < best_cost - 1e-9
            if is_tabu and not aspires:
                continue
            if chosen_delta is None or delta < chosen_delta:
                chosen, chosen_delta = (a, b), delta
        if chosen is None:
            break
        a, b = chosen
        placement[a], placement[b] = placement[b], placement[a]
        cost += chosen_delta
        tabu_until[a, b] = step + tenure
        if cost < best_cost - 1e-9:
            best_cost = cost
            best = placement.copy()
            history.append((time.perf_counter() - start, best_cost / trace_length))
        if time_budget is not None and time.perf_counter() - start > time_budget:
            break
    seconds = time.perf_counter() - start
    avg = _total_cost(sym, best, dist) / trace_length
    history.append((seconds, avg))
    return MappingResult(placement=best[:k], avg_hop=float(avg), seconds=seconds,
                         history=history, evaluations=evals)


def pso_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    time_budget: float | None = None,
    iters: int = 200,
    swarm: int = 32,
    w: float = 0.72,
    c1: float = 1.49,
    c2: float = 1.49,
    torus: bool = False,
) -> MappingResult:
    """Random-key PSO (SpiNeMap's placer, §2.2): particles are continuous
    priority vectors; argsort decodes a vector into a core permutation."""
    start = time.perf_counter()
    rng = np.random.default_rng(seed)
    k = traffic.shape[0]
    padded = pad_traffic(traffic, num_cores)
    sym = padded + padded.T
    dist = hop_distance_matrix(num_cores, mesh_w, torus=torus).astype(np.float64)

    def decode(x: np.ndarray) -> np.ndarray:
        return np.argsort(x).astype(np.int64)

    pos = rng.standard_normal((swarm, num_cores))
    vel = np.zeros_like(pos)
    pbest = pos.copy()
    pbest_cost = np.array([_total_cost(sym, decode(p), dist) for p in pos])
    g = int(np.argmin(pbest_cost))
    gbest, gbest_cost = pbest[g].copy(), float(pbest_cost[g])
    history = [(0.0, gbest_cost / trace_length)]
    evals = swarm
    for _ in range(iters):
        r1 = rng.random((swarm, num_cores))
        r2 = rng.random((swarm, num_cores))
        vel = w * vel + c1 * r1 * (pbest - pos) + c2 * r2 * (gbest[None, :] - pos)
        pos = pos + vel
        costs = np.array([_total_cost(sym, decode(p), dist) for p in pos])
        evals += swarm
        better = costs < pbest_cost
        pbest[better] = pos[better]
        pbest_cost[better] = costs[better]
        g = int(np.argmin(pbest_cost))
        if pbest_cost[g] < gbest_cost - 1e-9:
            gbest, gbest_cost = pbest[g].copy(), float(pbest_cost[g])
            history.append((time.perf_counter() - start, gbest_cost / trace_length))
        if time_budget is not None and time.perf_counter() - start > time_budget:
            break
    seconds = time.perf_counter() - start
    placement = decode(gbest)
    avg = _total_cost(sym, placement, dist) / trace_length
    history.append((seconds, avg))
    return MappingResult(placement=placement[:k], avg_hop=float(avg), seconds=seconds,
                         history=history, evaluations=evals)


MAPPERS = {"sa": sa_search, "pso": pso_search, "tabu": tabu_search}

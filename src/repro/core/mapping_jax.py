"""Device-resident mapping search (beyond-paper acceleration).

The paper runs one serial SA chain on a host CPU.  Here the same search is
reformulated for accelerators:

  * `sa_search_jax` — a *population* of SA chains advanced in lock-step by
    one `lax.scan`; each chain proposes a random swap, scores it with the
    O(K) incremental delta (gather arithmetic, vmapped over chains), and
    applies Metropolis acceptance.  Thousands of chains cost the same
    wall-clock as one.
  * `greedy_polish` — full-neighborhood steepest descent: the
    `swap_delta` Pallas kernel scores all O(K^2) swaps per step on the
    MXU and the single best swap is applied until no swap improves.
  * `island_sa` — shard_map island parallelism: chain populations run per
    device, periodically all-gathering the global best and re-seeding the
    worst chains (parallel tempering across the TPU mesh).

All variants share the objective of paper Eq. 2 (minimize average hop) —
their inner loops are gather-arithmetic reformulations of the pairwise
delta, so they do not take a `placecost` objective (see
`mapping.OBJECTIVE_AWARE_MAPPERS`).  They are not a parallel API: every
search here is registered in `repro.core.mapping.MAPPERS` ("sa_jax",
"polish" via the uniform-signature `polish_search` adapter, and "island",
which needs a `mesh=` kwarg), so `run_toolchain(mapper=...)` selects them
like any host mapper.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.swap_delta import swap_deltas

from .hopcost import hop_distance_matrix
from .mapping import MappingResult, pad_traffic

__all__ = [
    "sa_search_jax",
    "sa_search_jax_batch",
    "greedy_polish",
    "polish_search",
    "island_sa",
]


def _coords(num_cores: int, mesh_w: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    ids = jnp.arange(num_cores)
    return (ids % mesh_w).astype(jnp.float32), (ids // mesh_w).astype(jnp.float32)


def _cost(sym: jnp.ndarray, placement: jnp.ndarray, dist: jnp.ndarray) -> jnp.ndarray:
    d = dist[placement[:, None], placement[None, :]]
    return jnp.sum(sym * d) / 2.0


def _delta_one(sym, dist, placement, a, b):
    """O(K) incremental swap delta.

    The formula and its derivation live in one place:
    `repro.core.hopcost.swap_delta` (the host/numpy original).  This is its
    jnp twin, kept branch-free so it traces cleanly under scan/vmap.
    """
    ca = placement[a]
    cb = placement[b]
    d_a = dist[ca, placement]
    d_b = dist[cb, placement]
    diff = (sym[a] - sym[b]) * (d_b - d_a)
    return jnp.sum(diff) - diff[a] - diff[b]


@functools.partial(jax.jit, static_argnames=("iters", "sweeps_per_temp"))
def _sa_population(
    sym: jnp.ndarray,
    dist: jnp.ndarray,
    placements: jnp.ndarray,  # (P, NC)
    key: jnp.ndarray,
    t0: jnp.ndarray,
    iters: int,
    sweeps_per_temp: int,
    alpha: float = 0.95,
):
    nc = placements.shape[1]

    def chain_step(state, key_t):
        placement, cost, T = state
        ka, kb, ku = jax.random.split(key_t, 3)
        a = jax.random.randint(ka, (), 0, nc)
        b0 = jax.random.randint(kb, (), 0, nc - 1)
        b = jnp.where(b0 >= a, b0 + 1, b0)
        delta = _delta_one(sym, dist, placement, a, b)
        accept = (delta <= 0) | (jax.random.uniform(ku) < jnp.exp(-delta / T))
        pa, pb = placement[a], placement[b]
        new_placement = placement.at[a].set(jnp.where(accept, pb, pa))
        new_placement = new_placement.at[b].set(jnp.where(accept, pa, pb))
        new_cost = jnp.where(accept, cost + delta, cost)
        return (new_placement, new_cost, T), new_cost

    def temp_epoch(carry, key_e):
        placement, cost, T = carry
        keys = jax.random.split(key_e, sweeps_per_temp)
        (placement, cost, _), costs = jax.lax.scan(
            chain_step, (placement, cost, T), keys
        )
        return (placement, cost, T * alpha), jnp.min(costs)

    def run_chain(placement, key_c, t_init):
        cost = _cost(sym, placement, dist)
        epochs = max(iters // sweeps_per_temp, 1)
        keys = jax.random.split(key_c, epochs)
        (placement, cost, _), best_hist = jax.lax.scan(
            temp_epoch, (placement, cost, t_init), keys
        )
        return placement, cost, best_hist

    keys = jax.random.split(key, placements.shape[0])
    return jax.vmap(run_chain, in_axes=(0, 0, None))(placements, keys, t0)


def sa_search_jax(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    iters: int = 20_000,
    chains: int = 16,
    sweeps_per_temp: int = 64,
    t0_frac: float = 0.25,
    torus: bool = False,
    polish: bool = True,
    polish_backend: str = "auto",
) -> MappingResult:
    """Population SA on device + optional kernel-powered greedy polish."""
    start = time.perf_counter()
    k = traffic.shape[0]
    trace_length = max(trace_length, 1)  # zero-traffic profiles normalize by 1
    padded = pad_traffic(np.asarray(traffic, dtype=np.float64), num_cores)
    sym = jnp.asarray(padded + padded.T, dtype=jnp.float32)
    dist = jnp.asarray(
        hop_distance_matrix(num_cores, mesh_w, torus=torus), dtype=jnp.float32
    )
    key = jax.random.PRNGKey(seed)
    kinit, krun = jax.random.split(key)
    placements = jax.vmap(lambda kk: jax.random.permutation(kk, num_cores))(
        jax.random.split(kinit, chains)
    )
    c0 = _cost(sym, placements[0], dist)
    t0 = t0_frac * c0 / max(k, 1)
    placements, costs, best_hist = _sa_population(
        sym, dist, placements, krun, t0, iters, sweeps_per_temp
    )
    best_i = int(jnp.argmin(costs))
    best = placements[best_i]
    if polish:
        x, y = _coords(num_cores, mesh_w)
        best, _ = greedy_polish(sym, best, x, y, backend=polish_backend)
    final_cost = float(_cost(sym, best, dist))
    seconds = time.perf_counter() - start
    # The scan runs entirely on device, so per-epoch wall-clock timestamps
    # do not exist; history is keyed by temperature-epoch index instead
    # (see MappingResult.history), with elapsed time recorded once above.
    best_by_epoch = np.minimum.accumulate(
        np.asarray(best_hist, dtype=np.float64).min(axis=0)
    )
    hist = [(float(i), c / trace_length) for i, c in enumerate(best_by_epoch)]
    return MappingResult(
        placement=np.asarray(best)[:k].astype(np.int64),
        avg_hop=final_cost / trace_length,
        seconds=seconds,
        history=hist,
        evaluations=int(iters) * int(chains),
    )


@functools.partial(jax.jit, static_argnames=("iters", "sweeps_per_temp"))
def _sa_population_multi(
    syms: jnp.ndarray,       # (C, NC, NC)
    dist: jnp.ndarray,       # (NC, NC) shared across configs
    placements: jnp.ndarray, # (C, P, NC)
    keys: jnp.ndarray,       # (C, 2)
    t0s: jnp.ndarray,        # (C,)
    iters: int,
    sweeps_per_temp: int,
):
    """`_sa_population` vmapped over a bucket of same-shape configs.

    One device program advances every config's whole chain population in
    lock-step; the per-config math is element-for-element the single-call
    path's, so batched results are bitwise those of C sequential
    `_sa_population` calls (pinned by the sweep parity tests).
    """
    return jax.vmap(
        lambda s, p, k, t: _sa_population(s, dist, p, k, t, iters, sweeps_per_temp)
    )(syms, placements, keys, t0s)


def sa_search_jax_batch(
    traffics: list[np.ndarray],
    num_cores: int,
    mesh_w: int,
    trace_lengths: list[int],
    seeds: list[int],
    iters: int = 20_000,
    chains: int = 16,
    sweeps_per_temp: int = 64,
    t0_frac: float = 0.25,
    torus: bool = False,
    polish: bool = True,
    polish_backend: str = "auto",
) -> list[MappingResult]:
    """Batched `sa_search_jax`: one device program for a whole config bucket.

    All configs must share ``(num_cores, mesh_w, iters, chains,
    sweeps_per_temp, torus)`` — that is what makes their populations
    stackable into one ``(C, P, NC)`` vmapped scan (the sweep driver's
    bucketing key).  Traffic matrices may have different ``k`` (they are
    zero-padded to ``num_cores`` exactly as the single path pads).  Each
    config's RNG stream, initial placements, and temperature schedule are
    derived per-seed identically to ``sa_search_jax(seed=s)``, so element
    ``i`` of the returned list is bitwise the single call's result; the
    polish tail runs per config through the same shape-cached kernel.
    Reported ``seconds`` are the bucket wall-clock amortized per config.
    """
    start = time.perf_counter()
    c = len(traffics)
    if not (len(trace_lengths) == len(seeds) == c):
        raise ValueError("traffics, trace_lengths, seeds must align")
    if c == 0:
        return []
    ks = [int(t.shape[0]) for t in traffics]
    syms_np = np.empty((c, num_cores, num_cores), dtype=np.float64)
    for i, t in enumerate(traffics):
        padded = pad_traffic(np.asarray(t, dtype=np.float64), num_cores)
        syms_np[i] = padded + padded.T
    syms = jnp.asarray(syms_np, dtype=jnp.float32)
    dist = jnp.asarray(
        hop_distance_matrix(num_cores, mesh_w, torus=torus), dtype=jnp.float32
    )
    kruns = []
    placements = []
    for s in seeds:
        kinit, krun = jax.random.split(jax.random.PRNGKey(int(s)))
        kruns.append(krun)
        placements.append(
            jax.vmap(lambda kk: jax.random.permutation(kk, num_cores))(
                jax.random.split(kinit, chains)
            )
        )
    placements = jnp.stack(placements)  # (C, P, NC)
    c0s = jax.vmap(lambda s, p: _cost(s, p, dist))(syms, placements[:, 0])
    t0s = t0_frac * c0s / jnp.asarray([max(k, 1) for k in ks], dtype=c0s.dtype)
    placements, costs, best_hists = _sa_population_multi(
        syms, dist, placements, jnp.stack(kruns), t0s, iters, sweeps_per_temp
    )
    if polish:
        x, y = _coords(num_cores, mesh_w)
    results = []
    for i in range(c):
        best_i = int(jnp.argmin(costs[i]))
        best = placements[i, best_i]
        if polish:
            best, _ = greedy_polish(syms[i], best, x, y, backend=polish_backend)
        denom = max(int(trace_lengths[i]), 1)
        final_cost = float(_cost(syms[i], best, dist))
        best_by_epoch = np.minimum.accumulate(
            np.asarray(best_hists[i], dtype=np.float64).min(axis=0)
        )
        hist = [(float(j), cst / denom) for j, cst in enumerate(best_by_epoch)]
        results.append(MappingResult(
            placement=np.asarray(best)[: ks[i]].astype(np.int64),
            avg_hop=final_cost / denom,
            seconds=0.0,
            history=hist,
            evaluations=int(iters) * int(chains),
        ))
    seconds = (time.perf_counter() - start) / c
    for r in results:
        r.seconds = seconds
    return results


@functools.partial(jax.jit, static_argnames=("max_steps", "backend"))
def _polish_loop(sym, placement, x, y, max_steps: int, backend: str):
    nc = placement.shape[0]
    eye = jnp.eye(nc, dtype=bool)

    def body(state):
        placement, improved, steps = state
        px = x[placement]
        py = y[placement]
        deltas = swap_deltas(sym, px, py, backend=backend)
        deltas = jnp.where(eye, jnp.inf, deltas)
        flat = jnp.argmin(deltas)
        a, b = flat // nc, flat % nc
        best_delta = deltas[a, b]
        do = best_delta < -1e-6
        pa, pb = placement[a], placement[b]
        placement = placement.at[a].set(jnp.where(do, pb, pa))
        placement = placement.at[b].set(jnp.where(do, pa, pb))
        return placement, do, steps + 1

    def cond(state):
        _, improved, steps = state
        return improved & (steps < max_steps)

    placement, _, steps = jax.lax.while_loop(cond, body, (placement, jnp.bool_(True), 0))
    return placement, steps


def greedy_polish(
    sym: jnp.ndarray,
    placement: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    max_steps: int = 256,
    backend: str = "auto",
) -> tuple[jnp.ndarray, int]:
    """Steepest-descent over the full swap neighborhood (swap_delta kernel).

    Each step scores all O(K^2) swaps in one kernel launch and applies the
    best one; terminates at a local optimum of the swap neighborhood —
    strictly stronger than the paper's first-improvement SA tail.
    """
    placement, steps = _polish_loop(sym, placement, x, y, max_steps, backend)
    return placement, int(steps)


def polish_search(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    seed: int = 0,
    init: np.ndarray | None = None,
    max_steps: int = 256,
    backend: str = "auto",
    torus: bool = False,
) -> MappingResult:
    """Uniform-signature mapper over `greedy_polish` (registry: "polish").

    Starts from ``init`` (or a seeded random permutation) and runs
    full-neighborhood steepest descent to a swap-local optimum.  The
    swap-delta kernel rebuilds plain Manhattan distances from coordinates,
    so torus meshes are not supported.
    """
    if torus:
        raise ValueError("polish_search is mesh-only (kernel distance is Manhattan)")
    start = time.perf_counter()
    k = traffic.shape[0]
    trace_length = max(trace_length, 1)  # zero-traffic profiles normalize by 1
    padded = pad_traffic(np.asarray(traffic, dtype=np.float64), num_cores)
    sym = jnp.asarray(padded + padded.T, dtype=jnp.float32)
    dist = jnp.asarray(hop_distance_matrix(num_cores, mesh_w), dtype=jnp.float32)
    placement = (np.asarray(init, dtype=np.int64).copy() if init is not None
                 else np.random.default_rng(seed).permutation(num_cores))
    x, y = _coords(num_cores, mesh_w)
    best, steps = greedy_polish(sym, jnp.asarray(placement), x, y,
                                max_steps=max_steps, backend=backend)
    final_cost = float(_cost(sym, best, dist))
    seconds = time.perf_counter() - start
    # One kernel launch scores the whole O(K^2) neighborhood per step.
    return MappingResult(
        placement=np.asarray(best)[:k].astype(np.int64),
        avg_hop=final_cost / trace_length,
        seconds=seconds,
        history=[(float(steps), final_cost / trace_length)],
        evaluations=int(steps) * num_cores * num_cores,
    )


def island_sa(
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    mesh: jax.sharding.Mesh,
    axis: str = "data",
    seed: int = 0,
    rounds: int = 4,
    iters_per_round: int = 4_000,
    chains_per_device: int = 4,
    torus: bool = False,
) -> MappingResult:
    """Island-model SA under shard_map: independent populations per device,
    periodic all-gather of the global best to reseed each island's worst
    chain (the distributed-search story for large meshes)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    start = time.perf_counter()
    k = traffic.shape[0]
    trace_length = max(trace_length, 1)  # zero-traffic profiles normalize by 1
    padded = pad_traffic(np.asarray(traffic, dtype=np.float64), num_cores)
    sym = jnp.asarray(padded + padded.T, dtype=jnp.float32)
    dist = jnp.asarray(
        hop_distance_matrix(num_cores, mesh_w, torus=torus), dtype=jnp.float32
    )
    n_dev = mesh.shape[axis]
    total_chains = n_dev * chains_per_device

    key = jax.random.PRNGKey(seed)
    kinit, krun = jax.random.split(key)
    placements = jax.vmap(lambda kk: jax.random.permutation(kk, num_cores))(
        jax.random.split(kinit, total_chains)
    )
    keys = jax.random.split(krun, total_chains * rounds).reshape(total_chains, rounds, 2)
    c0 = _cost(sym, placements[0], dist)
    t0 = 0.25 * c0 / max(k, 1)

    def island(placements_l, keys_l):
        # placements_l: (chains_per_device, NC); keys_l: (cpd, rounds, 2)
        t_now = t0
        for r in range(rounds):
            placements_l, costs_l, _ = _sa_population(
                sym, dist, placements_l, keys_l[0, r], jnp.asarray(t_now),
                iters_per_round, 64,
            )
            # Exchange: adopt the global best into the locally worst slot.
            all_costs = jax.lax.all_gather(costs_l, axis)  # (n_dev, cpd)
            all_place = jax.lax.all_gather(placements_l, axis)
            flat_costs = all_costs.reshape(-1)
            gbest = jnp.argmin(flat_costs)
            gplace = all_place.reshape(-1, placements_l.shape[1])[gbest]
            worst = jnp.argmax(costs_l)
            placements_l = placements_l.at[worst].set(gplace)
            t_now = t_now * (0.95 ** (iters_per_round // 64))
        costs_l = jax.vmap(lambda p: _cost(sym, p, dist))(placements_l)
        return placements_l, costs_l

    sharded = shard_map(
        island, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_rep=False,
    )
    placements, costs = sharded(placements, keys)
    best_i = int(jnp.argmin(costs))
    best = placements[best_i]
    final_cost = float(_cost(sym, best, dist))
    seconds = time.perf_counter() - start
    return MappingResult(
        placement=np.asarray(best)[:k].astype(np.int64),
        avg_hop=final_cost / trace_length,
        seconds=seconds,
        history=[(seconds, final_cost / trace_length)],
        evaluations=rounds * iters_per_round * total_chains,
    )

"""Average-hop evaluation (paper §3.4.2, Algorithm 1).

The paper's key engineering insight: under static XY routing the hop count
of a spike is just the Manhattan distance between source and destination
cores, so the search loop can score a candidate mapping analytically
instead of invoking a hardware simulator.  This file is the host/numpy
reference; `repro.kernels.hop_eval` is the Pallas TPU version and
`repro.kernels.swap_delta` batch-evaluates SA neighborhoods.

Two traffic models feed the evaluation (``traffic_matrix``'s ``cast``):

* ``"unicast"`` — one packet per spike transmission, i.e. per synapse
  crossing.  A neuron firing into d remote partitions is counted d_syn
  times (once per destination synapse) — the paper's Algorithm 1.
* ``"multicast"`` — one packet per (firing, destination partition): a
  neuron's fan-out into a partition is a single replicated packet, which
  is what a multicast NoC actually injects.  Requires the trace time
  stamps to identify firings.
"""
from __future__ import annotations

import numpy as np

from repro.trace import dedupe_firings

__all__ = [
    "traffic_matrix",
    "core_coords",
    "hop_distance_matrix",
    "average_hop",
    "swap_delta",
    "swap_delta_batch",
]


def traffic_matrix(
    part: np.ndarray,
    trace_src: np.ndarray,
    trace_dst: np.ndarray,
    k: int,
    trace_t: np.ndarray | None = None,
    cast: str = "unicast",
) -> np.ndarray:
    """C[i, j] = number of packets sent from partition i to partition j.

    Built from the spike trace (Algorithm 1 lines 5-9); the diagonal holds
    intra-partition deliveries, which never enter the NoC (0 hops).
    ``cast="unicast"`` counts one packet per transmission; ``"multicast"``
    (requires ``trace_t``) deduplicates transmissions of one firing toward
    the same destination partition into a single packet.
    """
    pi = part[trace_src].astype(np.int64)
    pj = part[trace_dst].astype(np.int64)
    if cast == "multicast":
        if trace_t is None:
            raise ValueError("multicast traffic needs trace_t to identify firings")
        # One packet per distinct (firing, dest partition) — off-diagonal
        # only: intra-partition deliveries are synaptic events, not
        # packets, and keep their per-transmission counts so the matrix
        # totals match `nocsim.simulate_noc`'s accounting (which shares
        # `dedupe_firings` for the packet identity).
        remote = pi != pj
        _, rsrc, rpj, _ = dedupe_firings(trace_t[remote], trace_src[remote],
                                         pj[remote], int(part.shape[0]), k)
        pi = np.concatenate([pi[~remote], part[rsrc].astype(np.int64)])
        pj = np.concatenate([pj[~remote], rpj])
    elif cast != "unicast":
        raise ValueError(f"unknown cast {cast!r}")
    flat = np.bincount(pi * k + pj, minlength=k * k)
    return flat.reshape(k, k).astype(np.int64)


def core_coords(num_cores: int, mesh_w: int) -> np.ndarray:
    """(num_cores, 2) int array of (x, y) for row-major core ids."""
    ids = np.arange(num_cores)
    return np.stack([ids % mesh_w, ids // mesh_w], axis=1)


def hop_distance_matrix(num_cores: int, mesh_w: int, torus: bool = False) -> np.ndarray:
    """(num_cores, num_cores) hop distances under XY routing.

    `torus=False` is the paper's NoC mesh (plain Manhattan); `torus=True`
    is the TPU-ICI variant with wraparound links (used by the beyond-paper
    device-layout optimizer, see DESIGN.md §3).
    """
    c = core_coords(num_cores, mesh_w)
    dx = np.abs(c[:, None, 0] - c[None, :, 0])
    dy = np.abs(c[:, None, 1] - c[None, :, 1])
    if torus:
        w = mesh_w
        h = (num_cores + mesh_w - 1) // mesh_w
        dx = np.minimum(dx, w - dx)
        dy = np.minimum(dy, h - dy)
    return (dx + dy).astype(np.int32)


def average_hop(
    traffic: np.ndarray,
    placement: np.ndarray,
    dist: np.ndarray,
    trace_length: int,
) -> float:
    """H = sum_{a,b} d(M(a), M(b)) * C(a, b) / trace_length  (Algorithm 1)."""
    d = dist[placement[:, None], placement[None, :]]
    return float((d * traffic).sum() / trace_length)


def swap_delta(
    sym_traffic: np.ndarray,
    placement: np.ndarray,
    dist: np.ndarray,
    a: int,
    b: int,
) -> float:
    """Change in total hop-weighted traffic if partitions a and b swap cores.

    `sym_traffic` must be C + C.T.  O(k) instead of re-evaluating the full
    O(k^2) objective — the SA inner-loop trick.  Canonical definition of the
    formula; `repro.core.mapping_jax._delta_one` (device twin) and
    `repro.kernels.swap_delta` (all-pairs MXU batch) both implement it.
    """
    ca, cb = placement[a], placement[b]
    d_a = dist[ca, placement]
    d_b = dist[cb, placement]
    diff = (sym_traffic[a] - sym_traffic[b]) * (d_b - d_a)
    # Exclude j in {a, b}: the a<->b term is invariant (d symmetric) and the
    # self terms ride on the zero diagonal of dist but not of sym_traffic diff.
    return float(diff.sum() - diff[a] - diff[b])


def swap_delta_batch(
    sym_traffic: np.ndarray,
    placement: np.ndarray,
    dist: np.ndarray,
    aa: np.ndarray,
    bb: np.ndarray,
) -> np.ndarray:
    """`swap_delta` for B candidate pairs in one vectorized call.

    Returns the (B,) array of deltas for swapping ``(aa[i], bb[i])`` — each
    evaluated against the *same* ``placement`` (candidates are independent
    alternatives, not a sequence).  Canonical/reference form of the batch
    formula: the batched mapping engine's hot path is
    `placecost.PairwiseObjective.swap_delta_batch`, which computes the same
    quantity through its placement-permuted distance-column cache (and is
    pinned against this function by the engine tests); the all-pairs MXU
    form lives in `repro.kernels.swap_delta`.
    """
    aa = np.asarray(aa, dtype=np.int64)
    bb = np.asarray(bb, dtype=np.int64)
    d_a = dist[placement[aa][:, None], placement[None, :]]  # (B, K)
    d_b = dist[placement[bb][:, None], placement[None, :]]
    diff = (sym_traffic[aa] - sym_traffic[bb]) * (d_b - d_a)
    rows = np.arange(aa.shape[0])
    return diff.sum(axis=1) - diff[rows, aa] - diff[rows, bb]

"""End-to-end SNEAP toolchain: profile -> partition -> map -> evaluate.

Also drives the two baseline toolchains (SpiNeMap, SCO) over the same
profiled trace so the paper's Figures 4-8 comparisons are apples-to-apples.

The ``objective`` knob threads the partitioning metric through the whole
stack: ``"cut"`` (spikes on cut synapses, the paper's metric) or
``"volume"`` (multicast communication volume).  ``cast`` independently
selects the NoC traffic model used for placement scoring and replay —
by default it follows the objective ("volume" → "multicast"), so the
partitioner, the placement search, and the simulator all measure the same
quantity.  ``ToolchainResult.summary()`` reports both metrics for every
run, which is what lets Figures 4-8 be regenerated under either model.

One config path serves two drivers: `run_toolchain` executes a single
`ToolchainConfig` end to end, and `repro.launch.sweep.run_sweep` executes
a whole grid of them through the *same* phase functions
(`partition_phase` / `mapping_phase` / `evaluate_phase`), deduplicating
shared phases and batching device searches — so a sweep row is bitwise
the stats of the corresponding single run.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from repro.nocsim import NoCStats, combine_stats, simulate_noc
from repro.runtime.faults import FaultSchedule, FaultState, heartbeat_detect
from repro.runtime.health import HeartbeatMonitor

if TYPE_CHECKING:  # avoid core <-> snn circular import; only a type hint
    from repro.snn.simulate import ProfileResult

from .baselines import greedy_kl_partition, sco_partition, sco_place
from .hopcost import traffic_matrix
from .mapping import MAPPERS, OBJECTIVE_AWARE_MAPPERS, MappingResult
from .partition import PartitionResult, sneap_partition
from .placecost import evaluate_placement, make_objective, validate_objective
from .remap import incremental_remap, scratch_remap

__all__ = [
    "ToolchainConfig",
    "ToolchainResult",
    "phase_seeds",
    "apply_knobs",
    "partition_phase",
    "mapping_phase",
    "evaluate_phase",
    "run_toolchain",
]


def phase_seeds(seed: int) -> tuple[int, int, int]:
    """Independent per-phase child seeds of one run seed.

    ``(partition_seed, mapping_seed, remap_seed)``, derived via
    ``np.random.SeedSequence(seed).spawn()`` so the phases' random streams
    are statistically independent.  Historically the one run ``seed`` was
    threaded verbatim into both ``sneap_partition`` and the mapper search,
    so sweep replicates that varied only the seed drew lockstep-correlated
    partition and placement streams; deriving children fixes that (and
    deterministically changes every seeded run's exact results relative to
    pre-fix versions — same quality, different draws).
    """
    children = np.random.SeedSequence(seed).spawn(3)
    return tuple(int(c.generate_state(1)[0]) for c in children)


@dataclass
class ToolchainConfig:
    """Full configuration of one toolchain run.

    Mirrors `run_toolchain`'s keyword surface one-for-one (minus the
    fault-scenario arguments, which stay per-call); `repro.launch.sweep`
    builds grids of these and runs them through the shared phase
    functions.  ``resolve()`` fills the ``cast``/``place_objective``
    defaults and validates the enums; ``requested_place`` preserves
    whether the caller *explicitly* asked for a placement objective
    (explicit tree requests must error loudly on searches that cannot
    honor them, while defaulted ones silently fall back).
    """

    method: str = "sneap"
    mesh_w: int = 5
    mesh_h: int = 5
    capacity: int = 256
    mapper: str = "sa"
    seed: int = 0
    noc_mode: str = "queued"
    link_capacity: int = 4
    mapper_kwargs: dict = field(default_factory=dict)
    partition_impl: str = "scalar"
    objective: str = "cut"
    cast: str | None = None
    place_objective: str | None = None
    partition_kwargs: dict = field(default_factory=dict)
    noc_kwargs: dict = field(default_factory=dict)
    # Module-level engine threshold overrides applied for the run's
    # duration, e.g. {"_KERNEL_MAX_N": 1024} to move the vec refiner's
    # device-kernel crossover (see `repro.core.refine_vec`).  Swept by
    # `repro.launch.sweep` to measure data-driven defaults.
    knobs: dict = field(default_factory=dict)
    # Filled by resolve(); callers normally never set these directly.
    requested_place: str | None = None
    resolved: bool = False

    @property
    def num_cores(self) -> int:
        return self.mesh_w * self.mesh_h

    def resolve(self, hyper=None) -> "ToolchainConfig":
        """Validated copy with the ``cast``/``place_objective`` defaults filled."""
        if self.resolved:
            return self
        if self.objective not in ("cut", "volume"):
            raise ValueError(f"unknown objective {self.objective!r}")
        cast = self.cast
        if cast is None:
            cast = "multicast" if self.objective == "volume" else "unicast"
        place = self.place_objective
        if place is None:
            # Only SNEAP upgrades to the tree objective by default: the
            # baselines reproduce published toolchains that place with
            # pairwise spike counts (SpiNeMap's PSO, SCO's sequence), so
            # they keep Eq. 2 unless the caller explicitly overrides.
            place = ("tree" if cast == "multicast" and hyper is not None
                     and self.method == "sneap" else "pairwise")
        if place not in ("pairwise", "tree"):
            raise ValueError(f"unknown place_objective {place!r}")
        if self.method not in ("sneap", "spinemap", "sco"):
            raise ValueError(f"unknown method {self.method!r}")
        return dataclasses.replace(
            self, cast=cast, place_objective=place,
            requested_place=self.place_objective,
            mapper_kwargs=dict(self.mapper_kwargs),
            partition_kwargs=dict(self.partition_kwargs),
            noc_kwargs=dict(self.noc_kwargs),
            knobs=dict(self.knobs),
            resolved=True,
        )

    # -- sweep sharing keys ------------------------------------------------
    def partition_key(self) -> tuple:
        """Configs with equal keys produce bitwise-identical partitions.

        The mapping/evaluation knobs are excluded on purpose: two sweep
        configs that differ only there share one partitioning run.  The
        seed component is the *derived* partition child seed, so configs
        with different run seeds never collide, and sco (which draws no
        randomness) keys seed-free.
        """
        part_seed = 0 if self.method == "sco" else phase_seeds(self.seed)[0]
        impl = self.partition_impl if self.method == "sneap" else ""
        kw = self.partition_kwargs if self.method == "sneap" else {}
        return (self.method, self.capacity, self.num_cores, impl,
                self.objective, part_seed, tuple(sorted(kw.items())),
                tuple(sorted(self.knobs.items())))

    def traffic_key(self) -> tuple:
        """Configs with equal keys share one (k, k) traffic matrix."""
        return self.partition_key() + (self.cast,)


@dataclass
class ToolchainResult:
    method: str
    snn: str
    partition: PartitionResult
    mapping: MappingResult
    noc: NoCStats
    phase_seconds: dict = field(default_factory=dict)
    objective: str = "cut"
    cast: str = "unicast"
    place_objective: str = "pairwise"
    # Fault-scenario bookkeeping (None on fault-free runs): remap event
    # count/strategy, total remap seconds, neurons migrated/evicted, final
    # dead core/link counts — see run_toolchain's fault_schedule.
    degradation: dict | None = None

    @property
    def total_seconds(self) -> float:
        return sum(self.phase_seconds.values())

    def summary(self) -> dict:
        out = {
            "method": self.method,
            "snn": self.snn,
            "objective": self.objective,
            "cast": self.cast,
            "place_objective": self.place_objective,
            "k": self.partition.k,
            "edge_cut": self.partition.edge_cut,
            "comm_volume": self.partition.comm_volume,
            "avg_hop": self.mapping.avg_hop,
            "tree_hop": self.mapping.tree_hop,
            "avg_latency": self.noc.avg_latency,
            "energy_pj": self.noc.dynamic_energy_pj,
            "congestion": self.noc.congestion_count,
            "edge_var": self.noc.edge_variance,
            "spikes_dropped": self.noc.spikes_dropped,
            "detour_hops": self.noc.detour_hops,
            "partition_s": self.phase_seconds.get("partition", 0.0),
            "mapping_s": self.phase_seconds.get("mapping", 0.0),
            "evaluate_s": self.phase_seconds.get("evaluate", 0.0),
            "total_s": self.total_seconds,
        }
        if self.degradation is not None:
            out["remap_s"] = self.degradation["remap_s"]
            out["neurons_migrated"] = self.degradation["neurons_migrated"]
            out["remap_events"] = self.degradation["remap_events"]
            out["remap_strategy"] = self.degradation["remap_strategy"]
        return out


@contextmanager
def apply_knobs(knobs: dict):
    """Temporarily override `repro.core.refine_vec` module thresholds.

    Knob names must be existing refine_vec attributes (e.g.
    ``_KERNEL_MAX_N``, ``_KERNEL_MIN_K``, ``_PHI_MAX_ENTRIES``,
    ``_DEG_CACHE_ENTRIES``, ``_DENSE_EVAL_ENTRIES``); unknown names raise
    rather than silently sweeping a no-op axis.  Originals are restored on
    exit even on error, so one config's knobs never leak into the next.
    """
    if not knobs:
        yield
        return
    from . import refine_vec

    saved = {}
    for name in knobs:
        if not hasattr(refine_vec, name):
            raise ValueError(f"unknown refine_vec knob {name!r}")
        saved[name] = getattr(refine_vec, name)
    try:
        for name, value in knobs.items():
            setattr(refine_vec, name, value)
        yield
    finally:
        for name, value in saved.items():
            setattr(refine_vec, name, value)


def partition_phase(profile: "ProfileResult", cfg: ToolchainConfig) -> PartitionResult:
    """Run the configured partitioner (seeded with the partition child seed).

    ``cfg.knobs`` overrides are live for the duration of this phase only —
    they tune refiner thresholds, which nothing downstream reads.
    """
    with apply_knobs(cfg.knobs):
        return _partition_phase(profile, cfg)


def _partition_phase(profile: "ProfileResult", cfg: ToolchainConfig) -> PartitionResult:
    part_seed = phase_seeds(cfg.seed)[0]
    if cfg.method == "sneap":
        pres = sneap_partition(profile.graph, capacity=cfg.capacity,
                               seed=part_seed, max_k=cfg.num_cores,
                               impl=cfg.partition_impl, objective=cfg.objective,
                               **cfg.partition_kwargs)
    elif cfg.method == "spinemap":
        pres = greedy_kl_partition(profile.graph, capacity=cfg.capacity,
                                   seed=part_seed, max_k=cfg.num_cores,
                                   objective=cfg.objective)
    elif cfg.method == "sco":
        pres = sco_partition(profile.graph, capacity=cfg.capacity,
                             objective=cfg.objective)
    else:
        raise ValueError(f"unknown method {cfg.method!r}")
    if pres.k > cfg.num_cores:
        raise ValueError(
            f"{pres.k} partitions exceed {cfg.num_cores} cores; "
            f"enlarge mesh or capacity"
        )
    return pres


def build_traffic(profile: "ProfileResult", pres: PartitionResult,
                  cfg: ToolchainConfig) -> np.ndarray:
    """The (k, k) partition traffic matrix of a run (deterministic)."""
    return traffic_matrix(pres.part, profile.trace_src, profile.trace_dst,
                          pres.k, trace_t=profile.trace_t, cast=cfg.cast)


def mapping_phase(
    profile: "ProfileResult",
    pres: PartitionResult,
    cfg: ToolchainConfig,
    traffic: np.ndarray | None = None,
    objective=None,
) -> tuple[MappingResult, str, np.ndarray, int]:
    """Run the placement search + the shared evaluator.

    ``traffic``/``objective`` let the sweep driver hand in artifacts
    shared across configs (both are deterministic functions of the
    partition and config, so sharing cannot change any stat; a shared
    objective instance is safe because every search re-``attach``es it).
    Returns ``(mres, place_objective, traffic, trace_len)`` — the final
    place_objective may differ from the configured one where a search
    cannot honor it (sco, device mappers).
    """
    cfg = cfg.resolve(profile.graph.hyper)
    hyper = profile.graph.hyper
    num_cores = cfg.num_cores
    place_objective = cfg.place_objective
    map_seed = phase_seeds(cfg.seed)[1]
    if traffic is None:
        traffic = build_traffic(profile, pres, cfg)
    # Normalize average hop by the packet count of the chosen traffic model
    # (== num_spikes for unicast; deduplicated multicast packets otherwise).
    trace_len = int(traffic.sum())
    mapper_kwargs = dict(cfg.mapper_kwargs)
    if cfg.method == "sco":
        if cfg.requested_place == "tree":
            raise ValueError(
                "method 'sco' places sequentially (no search), so an "
                "explicit place_objective='tree' cannot be honored"
            )
        mres = sco_place(pres.k, num_cores)
        place_objective = mres.objective  # no search ran; reported units
    else:
        mapper_name = "pso" if cfg.method == "spinemap" else cfg.mapper
        search = MAPPERS[mapper_name]
        if mapper_name in OBJECTIVE_AWARE_MAPPERS:
            if "objective" in mapper_kwargs:
                # A caller-supplied objective is stateful (attached
                # placement, aggregate tables) and construction-bound to
                # one (traffic, partition, mesh); reusing it across runs
                # whose partition differs would silently score the wrong
                # trees — reject loudly instead.
                validate_objective(mapper_kwargs["objective"], traffic,
                                   num_cores, mesh_w=cfg.mesh_w,
                                   mesh_h=cfg.mesh_h, part=pres.part,
                                   hyper=hyper,
                                   torus=mapper_kwargs.get("torus", False))
            else:
                mapper_kwargs["objective"] = objective if objective is not None \
                    else make_objective(
                        place_objective, traffic, num_cores, cfg.mesh_w,
                        mesh_h=cfg.mesh_h, hyper=hyper, part=pres.part,
                    )
            place_objective = mapper_kwargs["objective"].name
        elif place_objective == "tree":
            # Device mappers run the pairwise Eq. 2 reformulation only.
            if cfg.requested_place == "tree":
                raise ValueError(
                    f"mapper {mapper_name!r} cannot run the tree objective; "
                    f"pick one of {sorted(OBJECTIVE_AWARE_MAPPERS)}"
                )
            place_objective = "pairwise"
        mres = search(traffic, num_cores, cfg.mesh_w, trace_len,
                      seed=map_seed, **mapper_kwargs)
    # One reporting path for every method: avg_hop (pairwise Eq. 2) and
    # tree_hop both come from the shared evaluator, never from the search.
    # The objective that drove the search (if any) is reused so its
    # construction cost is not paid twice; `evaluate_placement` validates
    # it against this run's traffic/partition before trusting it.
    mres.avg_hop, mres.tree_hop = evaluate_placement(
        mres.placement, traffic, num_cores, cfg.mesh_w, trace_len,
        mesh_h=cfg.mesh_h, hyper=hyper, part=pres.part,
        reuse=mapper_kwargs.get("objective"),
    )
    return mres, place_objective, traffic, trace_len


def evaluate_phase(
    profile: "ProfileResult",
    pres: PartitionResult,
    mres: MappingResult,
    cfg: ToolchainConfig,
) -> NoCStats:
    """Fault-free NoC replay of the profiled trace under a finished mapping."""
    cfg = cfg.resolve(profile.graph.hyper)
    noc_args = dict(link_capacity=cfg.link_capacity, mode=cfg.noc_mode,
                    cast=cfg.cast)
    noc_args.update(cfg.noc_kwargs)
    return simulate_noc(
        profile.trace_t, profile.trace_src, profile.trace_dst,
        pres.part, mres.placement, cfg.mesh_w, cfg.mesh_h, **noc_args,
    )


def run_toolchain(
    profile: "ProfileResult",
    method: str = "sneap",
    mesh_w: int = 5,
    mesh_h: int = 5,
    capacity: int = 256,
    mapper: str = "sa",
    seed: int = 0,
    noc_mode: str = "queued",
    link_capacity: int = 4,
    mapper_kwargs: dict | None = None,
    partition_impl: str = "scalar",
    objective: str = "cut",
    cast: str | None = None,
    place_objective: str | None = None,
    partition_kwargs: dict | None = None,
    noc_kwargs: dict | None = None,
    fault_schedule: FaultSchedule | None = None,
    remap_strategy: str = "incremental",
    remap_kwargs: dict | None = None,
    detect_windows: int = 2,
    config: ToolchainConfig | None = None,
) -> ToolchainResult:
    """Run one toolchain (sneap | spinemap | sco) over a profiled SNN.

    * sneap:    multilevel partitioning + SA placement (paper default).
    * spinemap: greedy-KL partitioning + PSO placement.
    * sco:      sequential packing + sequential placement.

    ``partition_impl`` selects the sneap partitioning engine ("scalar" or
    "vec" — see `repro.core.partition`); ignored by the baselines.
    ``objective`` selects the partitioning metric ("cut" or "volume");
    ``cast`` the NoC traffic model ("unicast" or "multicast"), defaulting
    to the model that matches the objective.  ``place_objective`` selects
    the quantity the placement search minimizes ("pairwise" or "tree") the
    same way: by default it follows ``cast`` — multicast replay charges
    one traversal per (firing, tree link), so multicast runs place with
    the tree-hop objective and unicast runs with the paper's pairwise
    Eq. 2 (see `repro.core.placecost`).  Device mappers ("sa_jax",
    "polish", "island") always run pairwise.  ``partition_kwargs`` are
    forwarded to ``sneap_partition`` (e.g. ``plateau_rounds`` to trade
    volume quality for time; ignored by the baselines).  ``noc_kwargs``
    are forwarded to ``simulate_noc`` (e.g. ``inject_capacity``,
    ``energy``, ``engine``, ``stepper``, ``screen``) and override the
    ``link_capacity``/``noc_mode``/``cast`` arguments on conflict.
    ``config`` replaces all of the above with one `ToolchainConfig`
    (mutually exclusive with passing individual knobs).

    Seeding: the one ``seed`` is split into independent per-phase child
    seeds via ``np.random.SeedSequence(seed).spawn()`` (`phase_seeds`), so
    the partition, mapping, and re-map random streams are decorrelated —
    sweep replicates that vary only ``seed`` draw independent partition
    *and* placement randomness instead of lockstep-correlated streams.
    Results remain fully deterministic per seed.

    Sweeps: to run a whole grid of configurations over one (or more)
    profiled SNNs, use `repro.launch.sweep.run_sweep` instead of looping
    over ``run_toolchain`` — it executes `ToolchainConfig` grids through
    these same phase functions, deduplicates shared partition/traffic
    work across configs, batches same-shape ``mapper="sa_jax"`` searches
    into one vmapped device program, and emits a per-workload Pareto
    report over (energy, latency, toolchain seconds); each sweep row is
    bitwise the stats of the corresponding single ``run_toolchain`` call
    (`results/bench_sweep.csv` records the wall-clock advantage).

    Performance of the evaluation phase: ``noc_mode="queued"`` runs the
    batched two-tier replay (`repro.nocsim.replay`) — contention-free
    windows are scored analytically from whole-window link loads and the
    static XY schedule, and only truly contending packets are
    cycle-stepped, jointly across windows.  On bursty traces this is
    10-20x the scalar reference engine (``noc_kwargs={"engine": "ref"}``),
    which remains available for parity diffs; on saturated traces where
    every window queues heavily, a pigeonhole detector routes provably
    congested windows straight to the stepper (skipping the schedule
    screen) and the engines run neck and neck (~1.2x).
    Under ``cast="multicast"`` the replay simulates true tree-fork flits
    (one flit per firing, forking at branch routers), which is both
    faster than the old per-replica simulation and reports strictly
    tighter latency/congestion.  ``ToolchainResult.summary()`` reports
    ``evaluate_s`` next to ``partition_s``/``mapping_s`` so the phase
    balance is visible per run.

    Performance of the mapping phase: ``mapper_kwargs={"impl": "vec"}``
    runs the SA search's batched engine — ``batch`` candidate swaps are
    scored per step in one vectorized delta call (optionally through the
    `kernels/swap_delta` MXU batch via ``score_backend``) and a
    conflict-free accepted subset is committed with an exact cost resync.
    At 256 cores this is ~7x the scalar chain's proposals per second at
    matched quality (``results/bench_mapping_engine.csv``); the scalar
    chain (``impl="scalar"``, the default) remains the parity reference.
    The tree objective's batched path scores swaps from member-level
    span aggregates (per-hyperedge top-2 column extremes plus
    per-(edge, column) top-2 row extremes — see
    `repro.core.placecost.TreeHopObjective`), so a destination move
    prices each incident edge in O(1) instead of re-measuring its
    route geometry: ~4x the scalar chain at 256 cores and first
    usable at 1024 cores (32x32), where the same wall-clock budget
    buys the batched engine a few percent *better* tree cost
    (``eqclock_delta`` in the CSV).  Every search reports both
    ``avg_hop`` and ``tree_hop`` through the shared evaluator
    regardless of which objective drove it.

    Performance of ``objective="volume"``: with ``partition_impl="vec"``
    the refiner keeps the Φ(e, p) member-count table and the D* degree
    matrix incremental across move batches and walks plateaus with bounded
    escape rounds, so volume partitioning runs at cut-path speed (often
    faster, since hyperedge dedup shrinks coarse levels) while matching
    the scalar FM queue's quality within a few percent.  With
    ``partition_impl="scalar"`` the λ-gain FM queue is the paper-faithful
    reference but pays a per-move cost proportional to the incident pin
    count times k — expect it to be ~5-15x slower than the cut objective
    on fan-out-heavy graphs; prefer the vec engine for graceful volume at
    scale.

    Partition phase at scale: for million-neuron SNNs pass
    ``partition_kwargs={"shards": S, "stream_levels": True}`` (vec impl
    only).  ``shards`` runs coarsening's matching per vertex-block edge
    slice and refinement per block against halo-assembled partition
    views, bounding per-shard working memory; tie-breaking hashes global
    edge ids, so the result is invariant under the shard count (any two
    values of ``S`` produce the identical partition) and ``shards=None``
    keeps the single-host rng path byte-for-byte.  ``stream_levels``
    spills each coarsening level to an on-disk `repro.core.coarsen.
    LevelStore` and uncoarsens out-of-core with at most two levels
    resident, for identical results at bounded peak RSS.
    ``benchmarks/bench_scale.py`` tracks both: the 1M-neuron/10M-synapse
    run and the sharded-vs-single-host quality parity gate (<= 5%
    comm_volume drift; measured ~0.03% at 100k neurons).

    Graceful degradation: ``fault_schedule`` (a `repro.runtime.faults.
    FaultSchedule`) injects core/link failures at trace-window boundaries.
    The evaluation phase then replays the trace in *segments*: each
    segment runs under the cumulative fault state (XY routes crossing a
    dead link or core detour via the YX escape order or drop — see
    `repro.nocsim.sim.simulate_noc`), and after each core-failure event
    the failed cores are detected through the `repro.runtime.health.
    HeartbeatMonitor` straggler test (synthetic per-core step times), the
    next ``detect_windows`` trace windows replay on the *stale* mapping —
    spikes to the dead cores drop there — and the mapping is then
    repaired in place by `repro.core.remap` (``remap_strategy``:
    ``"incremental"`` warm-starts the batched SA from the live placement
    under a migration-priced objective, ``"scratch"`` re-partitions onto
    the surviving cores; ``remap_kwargs`` forwards to it).  Segment stats
    are merged exactly (`repro.nocsim.combine_stats`); ``summary()``
    additionally reports ``spikes_dropped``/``detour_hops`` (always) and
    ``remap_s``/``neurons_migrated``/``remap_events``/``remap_strategy``
    for degraded runs, and ``phase_seconds["remap"]`` isolates repair
    time.  A ``fault_schedule`` of zero events is bit-identical to
    ``fault_schedule=None``.  Link-only failures re-route but never
    trigger a re-map: the placement objectives price hops, not individual
    links, so a re-map could not see the failure anyway.
    """
    if config is not None:
        cfg = config
    else:
        cfg = ToolchainConfig(
            method=method, mesh_w=mesh_w, mesh_h=mesh_h, capacity=capacity,
            mapper=mapper, seed=seed, noc_mode=noc_mode,
            link_capacity=link_capacity, mapper_kwargs=dict(mapper_kwargs or {}),
            partition_impl=partition_impl, objective=objective, cast=cast,
            place_objective=place_objective,
            partition_kwargs=dict(partition_kwargs or {}),
            noc_kwargs=dict(noc_kwargs or {}),
        )
    cfg = cfg.resolve(profile.graph.hyper)
    phase: dict[str, float] = {}

    t0 = time.perf_counter()
    pres = partition_phase(profile, cfg)
    phase["partition"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    mres, place_objective, traffic, trace_len = mapping_phase(profile, pres, cfg)
    phase["mapping"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    if fault_schedule is None:
        noc = evaluate_phase(profile, pres, mres, cfg)
        phase["evaluate"] = time.perf_counter() - t0
        degradation = None
    else:
        noc_args = dict(link_capacity=cfg.link_capacity, mode=cfg.noc_mode,
                        cast=cfg.cast)
        noc_args.update(cfg.noc_kwargs)
        noc, degradation = _faulty_replay(
            profile, pres, mres, cfg.mesh_w, cfg.mesh_h, cfg.capacity,
            noc_args, phase, fault_schedule, remap_strategy, remap_kwargs,
            detect_windows, cfg.objective, cfg.cast, place_objective,
            phase_seeds(cfg.seed)[2],
        )
    return ToolchainResult(
        method=cfg.method, snn=profile.name, partition=pres, mapping=mres,
        noc=noc, phase_seconds=phase, objective=cfg.objective, cast=cfg.cast,
        place_objective=place_objective, degradation=degradation,
    )


def _faulty_replay(
    profile: "ProfileResult",
    pres: PartitionResult,
    mres: MappingResult,
    mesh_w: int,
    mesh_h: int,
    capacity: int,
    noc_args: dict,
    phase: dict,
    schedule: FaultSchedule,
    remap_strategy: str,
    remap_kwargs: dict | None,
    detect_windows: int,
    objective: str,
    cast: str,
    place_objective: str,
    seed: int,
) -> tuple[NoCStats, dict]:
    """Segmented trace replay across failure events, re-mapping between.

    Timeline per core-failure event at window ``te``: the trace up to
    ``te`` replays on the current mapping/fault state; the failure is
    detected via the HeartbeatMonitor straggler test; the next
    ``detect_windows`` windows replay on the *stale* mapping under the new
    fault state (this is where spikes to dead cores drop); the mapping is
    repaired; replay resumes on the new mapping.  Link-only events update
    the fault state at ``te`` with no detection lag and no re-map.
    ``seed`` is the run's remap child seed (see `phase_seeds`).
    """
    if remap_strategy not in ("incremental", "scratch"):
        raise ValueError(f"unknown remap_strategy {remap_strategy!r}")
    t0 = time.perf_counter()
    trace_t = np.asarray(profile.trace_t, dtype=np.int64)
    trace_src = np.asarray(profile.trace_src, dtype=np.int64)
    trace_dst = np.asarray(profile.trace_dst, dtype=np.int64)
    if trace_t.shape[0] and (np.diff(trace_t) < 0).any():
        order = np.argsort(trace_t, kind="stable")
        trace_t, trace_src, trace_dst = (
            trace_t[order], trace_src[order], trace_dst[order])
    t_end = int(trace_t[-1]) + 1 if trace_t.shape[0] else 0

    state = FaultState.none(mesh_w, mesh_h)
    cur_part, cur_place, cur_k = pres.part, np.asarray(mres.placement), pres.k
    segments: list[NoCStats] = []
    replay_s = 0.0
    remap_s = 0.0
    migrated = evicted = remaps = 0

    def replay(lo: int, hi: int) -> None:
        nonlocal replay_s
        i0 = int(np.searchsorted(trace_t, lo))
        i1 = int(np.searchsorted(trace_t, hi))
        if i0 == i1:
            return
        r0 = time.perf_counter()
        segments.append(simulate_noc(
            trace_t[i0:i1], trace_src[i0:i1], trace_dst[i0:i1],
            cur_part, cur_place, mesh_w, mesh_h, faults=state, **noc_args,
        ))
        replay_s += time.perf_counter() - r0

    cursor = 0
    for te in schedule.event_times():
        te = int(te)
        if te >= t_end:
            break  # nothing left to replay past this point
        replay(cursor, te)
        cursor = max(cursor, te)
        had_core_fault = False
        for ev in schedule.events_at(te):
            state = state.apply(ev)
            had_core_fault |= ev.kind == "core"
        if not had_core_fault:
            continue  # link re-routing needs no detection lag or re-map
        # Failure detection: the monitor sees synthetic per-core step
        # times (dead cores straggle) and flags them; the re-map trusts
        # the *detected* set, not the schedule's ground truth.
        monitor = HeartbeatMonitor(mesh_w * mesh_h)
        detected = heartbeat_detect(monitor, state.dead_cores)
        dead_mask = np.zeros(mesh_w * mesh_h, dtype=bool)
        dead_mask[detected] = True
        # Detection lag: stale mapping under the new fault state — spikes
        # bound for the dead cores drop here.
        detect_end = min(cursor + max(detect_windows, 0), t_end)
        later = [t for t in schedule.event_times() if t > te]
        if later:
            detect_end = min(detect_end, int(later[0]))
        replay(cursor, detect_end)
        cursor = detect_end
        r0 = time.perf_counter()
        if remap_strategy == "incremental":
            res = incremental_remap(
                profile.graph, cur_part, cur_place, dead_mask,
                trace_t, trace_src, trace_dst, mesh_w, mesh_h,
                capacity=capacity, cast=cast,
                place_objective=place_objective,
                partition_objective=objective, seed=seed, k=cur_k,
                **(remap_kwargs or {}),
            )
        else:
            res = scratch_remap(
                profile.graph, cur_part, cur_place, dead_mask,
                trace_t, trace_src, trace_dst, mesh_w, mesh_h,
                capacity=capacity, cast=cast,
                place_objective=place_objective,
                partition_objective=objective, seed=seed,
                **(remap_kwargs or {}),
            )
        remap_s += time.perf_counter() - r0
        cur_part, cur_place, cur_k = res.part, res.placement, res.k
        migrated += res.neurons_migrated
        evicted += res.neurons_evicted
        remaps += 1
    replay(cursor, t_end)

    if segments:
        noc = combine_stats(segments)
    else:  # empty trace: one degenerate replay for well-formed stats
        r0 = time.perf_counter()
        noc = simulate_noc(
            trace_t, trace_src, trace_dst, cur_part, cur_place,
            mesh_w, mesh_h, faults=state, **noc_args,
        )
        replay_s += time.perf_counter() - r0
    phase["evaluate"] = replay_s
    phase["remap"] = remap_s
    # Driver overhead (slicing, detection) rides in "evaluate" implicitly
    # via total wall time minus the accounted parts; keep it visible:
    phase["scenario"] = max(
        time.perf_counter() - t0 - replay_s - remap_s, 0.0)
    degradation = {
        "events": len(schedule),
        "remap_events": remaps,
        "remap_strategy": remap_strategy,
        "remap_s": remap_s,
        "neurons_migrated": migrated,
        "neurons_evicted": evicted,
        "detect_windows": detect_windows,
        "dead_cores": int(state.dead_cores.sum()),
        "dead_links": int(state.dead_links.sum()),
        "final_k": cur_k,
    }
    return noc, degradation

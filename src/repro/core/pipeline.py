"""End-to-end SNEAP toolchain: profile -> partition -> map -> evaluate.

Also drives the two baseline toolchains (SpiNeMap, SCO) over the same
profiled trace so the paper's Figures 4-8 comparisons are apples-to-apples.

The ``objective`` knob threads the partitioning metric through the whole
stack: ``"cut"`` (spikes on cut synapses, the paper's metric) or
``"volume"`` (multicast communication volume).  ``cast`` independently
selects the NoC traffic model used for placement scoring and replay —
by default it follows the objective ("volume" → "multicast"), so the
partitioner, the placement search, and the simulator all measure the same
quantity.  ``ToolchainResult.summary()`` reports both metrics for every
run, which is what lets Figures 4-8 be regenerated under either model.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from repro.nocsim import NoCStats, simulate_noc

if TYPE_CHECKING:  # avoid core <-> snn circular import; only a type hint
    from repro.snn.simulate import ProfileResult

from .baselines import greedy_kl_partition, sco_partition, sco_place
from .hopcost import hop_distance_matrix, traffic_matrix
from .mapping import MAPPERS, MappingResult
from .partition import PartitionResult, sneap_partition

__all__ = ["ToolchainResult", "run_toolchain"]


@dataclass
class ToolchainResult:
    method: str
    snn: str
    partition: PartitionResult
    mapping: MappingResult
    noc: NoCStats
    phase_seconds: dict = field(default_factory=dict)
    objective: str = "cut"
    cast: str = "unicast"

    @property
    def total_seconds(self) -> float:
        return sum(self.phase_seconds.values())

    def summary(self) -> dict:
        return {
            "method": self.method,
            "snn": self.snn,
            "objective": self.objective,
            "cast": self.cast,
            "k": self.partition.k,
            "edge_cut": self.partition.edge_cut,
            "comm_volume": self.partition.comm_volume,
            "avg_hop": self.mapping.avg_hop,
            "avg_latency": self.noc.avg_latency,
            "energy_pj": self.noc.dynamic_energy_pj,
            "congestion": self.noc.congestion_count,
            "edge_var": self.noc.edge_variance,
            "partition_s": self.phase_seconds.get("partition", 0.0),
            "mapping_s": self.phase_seconds.get("mapping", 0.0),
            "evaluate_s": self.phase_seconds.get("evaluate", 0.0),
            "total_s": self.total_seconds,
        }


def run_toolchain(
    profile: "ProfileResult",
    method: str = "sneap",
    mesh_w: int = 5,
    mesh_h: int = 5,
    capacity: int = 256,
    mapper: str = "sa",
    seed: int = 0,
    noc_mode: str = "queued",
    link_capacity: int = 4,
    mapper_kwargs: dict | None = None,
    partition_impl: str = "scalar",
    objective: str = "cut",
    cast: str | None = None,
    partition_kwargs: dict | None = None,
    noc_kwargs: dict | None = None,
) -> ToolchainResult:
    """Run one toolchain (sneap | spinemap | sco) over a profiled SNN.

    * sneap:    multilevel partitioning + SA placement (paper default).
    * spinemap: greedy-KL partitioning + PSO placement.
    * sco:      sequential packing + sequential placement.

    ``partition_impl`` selects the sneap partitioning engine ("scalar" or
    "vec" — see `repro.core.partition`); ignored by the baselines.
    ``objective`` selects the partitioning metric ("cut" or "volume");
    ``cast`` the NoC traffic model ("unicast" or "multicast"), defaulting
    to the model that matches the objective.  ``partition_kwargs`` are
    forwarded to ``sneap_partition`` (e.g. ``plateau_rounds`` to trade
    volume quality for time; ignored by the baselines).  ``noc_kwargs``
    are forwarded to ``simulate_noc`` (e.g. ``inject_capacity``,
    ``energy``, ``engine``, ``stepper``, ``screen``) and override the
    ``link_capacity``/``noc_mode``/``cast`` arguments on conflict.

    Performance of the evaluation phase: ``noc_mode="queued"`` runs the
    batched two-tier replay (`repro.nocsim.replay`) — contention-free
    windows are scored analytically from whole-window link loads and the
    static XY schedule, and only truly contending packets are
    cycle-stepped, jointly across windows.  On bursty traces this is
    10-20x the scalar reference engine (``noc_kwargs={"engine": "ref"}``),
    which remains available for parity diffs; on saturated traces where
    every window queues heavily both engines do comparable element-work.
    Under ``cast="multicast"`` the replay simulates true tree-fork flits
    (one flit per firing, forking at branch routers), which is both
    faster than the old per-replica simulation and reports strictly
    tighter latency/congestion.  ``ToolchainResult.summary()`` reports
    ``evaluate_s`` next to ``partition_s``/``mapping_s`` so the phase
    balance is visible per run.

    Performance of ``objective="volume"``: with ``partition_impl="vec"``
    the refiner keeps the Φ(e, p) member-count table and the D* degree
    matrix incremental across move batches and walks plateaus with bounded
    escape rounds, so volume partitioning runs at cut-path speed (often
    faster, since hyperedge dedup shrinks coarse levels) while matching
    the scalar FM queue's quality within a few percent.  With
    ``partition_impl="scalar"`` the λ-gain FM queue is the paper-faithful
    reference but pays a per-move cost proportional to the incident pin
    count times k — expect it to be ~5-15x slower than the cut objective
    on fan-out-heavy graphs; prefer the vec engine for volume at scale.
    """
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    if cast is None:
        cast = "multicast" if objective == "volume" else "unicast"
    num_cores = mesh_w * mesh_h
    phase: dict[str, float] = {}
    mapper_kwargs = dict(mapper_kwargs or {})
    partition_kwargs = dict(partition_kwargs or {})
    noc_kwargs = dict(noc_kwargs or {})

    t0 = time.perf_counter()
    if method == "sneap":
        pres = sneap_partition(profile.graph, capacity=capacity, seed=seed,
                               max_k=num_cores, impl=partition_impl,
                               objective=objective, **partition_kwargs)
    elif method == "spinemap":
        pres = greedy_kl_partition(profile.graph, capacity=capacity, seed=seed,
                                   max_k=num_cores, objective=objective)
    elif method == "sco":
        pres = sco_partition(profile.graph, capacity=capacity,
                             objective=objective)
    else:
        raise ValueError(f"unknown method {method!r}")
    phase["partition"] = time.perf_counter() - t0
    if pres.k > num_cores:
        raise ValueError(
            f"{pres.k} partitions exceed {num_cores} cores; enlarge mesh or capacity"
        )

    t0 = time.perf_counter()
    traffic = traffic_matrix(pres.part, profile.trace_src, profile.trace_dst,
                             pres.k, trace_t=profile.trace_t, cast=cast)
    # Normalize average hop by the packet count of the chosen traffic model
    # (== num_spikes for unicast; deduplicated multicast packets otherwise).
    trace_len = int(traffic.sum())
    if method == "sco":
        mres = sco_place(pres.k, num_cores)
        dist = hop_distance_matrix(num_cores, mesh_w)
        d = dist[mres.placement[:, None], mres.placement[None, :]]
        mres.avg_hop = float((d * traffic).sum() / trace_len)
    else:
        search = MAPPERS["pso" if method == "spinemap" else mapper]
        mres = search(traffic, num_cores, mesh_w, trace_len, seed=seed, **mapper_kwargs)
    phase["mapping"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    noc_args = dict(link_capacity=link_capacity, mode=noc_mode, cast=cast)
    noc_args.update(noc_kwargs)
    noc = simulate_noc(
        profile.trace_t, profile.trace_src, profile.trace_dst,
        pres.part, mres.placement, mesh_w, mesh_h, **noc_args,
    )
    phase["evaluate"] = time.perf_counter() - t0
    return ToolchainResult(
        method=method, snn=profile.name, partition=pres, mapping=mres,
        noc=noc, phase_seconds=phase, objective=objective, cast=cast,
    )

"""Baseline toolchains the paper compares against (§5).

* SpiNeMap [Balaji et al., TVLSI'19]: SpiNeCluster — a greedy
  Kernighan–Lin partitioner that works directly on the *full* graph with
  per-partition priority queues over *all* vertices (no multilevel
  coarsening — this is why SNEAP wins 890x on partitioning time), plus
  SpiNePlacer — a PSO placement search.
* SCO [Lee et al., TACO'19]: sequential mapping that packs neurons into
  cores in index order to minimize core usage, with no communication
  optimization at all.
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
from dataclasses import dataclass

import numpy as np

from .graph import Graph, comm_volume, edge_cut, partition_weights, validate_partition
from .mapping import MappingResult, pso_search
from .partition import PartitionResult
from .refine import CutState, VolumeState

__all__ = ["greedy_kl_partition", "sco_partition", "sco_place"]


def greedy_kl_partition(
    graph: Graph,
    capacity: int = 256,
    k: int | None = None,
    seed: int = 0,
    max_passes: int = 8,
    slack: float = 1.10,
    max_k: int | None = None,
    objective: str = "cut",
) -> PartitionResult:
    """SpiNeCluster: greedy KL on the uncoarsened graph.

    Every pass scans *all* vertices into per-partition priority queues and
    greedily applies the best gain moves until none improve.  Identical
    objective to `sneap_partition` — ``"cut"`` (inter-partition spikes) or
    ``"volume"`` (multicast communication volume) under the capacity
    constraint — but no multilevel compression, so each pass is O(n log n)
    on the full graph and many passes are needed.
    """
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    n = graph.num_vertices
    total = graph.total_vwgt
    min_k = math.ceil(total / capacity)
    if k is None:
        k = max(min_k, math.ceil(min_k * slack))
        if max_k is not None:
            k = min(k, max_k)

    # Random balanced initial assignment (SpiNeMap starts unoptimized).
    part = np.repeat(np.arange(k), math.ceil(n / k))[:n]
    rng.shuffle(part)
    part = part.astype(np.int64)
    pweight = partition_weights(graph, part, k)
    state = (CutState if objective == "cut" else VolumeState)(graph, part, k)
    cut = state.score(part)
    counter = itertools.count()

    def degrees(v: int) -> tuple[int, np.ndarray]:
        return state.degrees(part, v)

    for _ in range(max_passes):
        start_cut = cut
        # k priority queues, all vertices considered (the "generalized KL"
        # the SNEAP paper contrasts against in §3.3).
        queues: list[list[tuple[int, int, int]]] = [[] for _ in range(k)]
        for v in range(n):
            internal, ext = degrees(v)
            if ext.sum() == 0:
                continue
            b = int(np.argmax(ext))
            gain = int(ext[b]) - internal
            heapq.heappush(queues[part[v]], (-gain, next(counter), v))
        moved = np.zeros(n, dtype=bool)
        improved = True
        while improved:
            improved = False
            # Greedy: take the globally best head among the k queues.
            best_q, best_gain = -1, None
            for q in range(k):
                while queues[q] and moved[queues[q][0][2]]:
                    heapq.heappop(queues[q])
                if queues[q]:
                    g = -queues[q][0][0]
                    if best_gain is None or g > best_gain:
                        best_q, best_gain = q, g
            if best_q < 0:
                break
            _, _, v = heapq.heappop(queues[best_q])
            internal, ext = degrees(v)
            order = np.argsort(-ext, kind="stable")
            for b in order:
                if ext[b] <= 0:
                    break
                gain = int(ext[b]) - internal
                if gain <= 0:
                    break
                if pweight[b] + graph.vwgt[v] > capacity:
                    continue
                src = int(part[v])
                part[v] = int(b)
                pweight[src] -= graph.vwgt[v]
                pweight[b] += graph.vwgt[v]
                state.apply_move(v, src, int(b))
                cut -= gain
                moved[v] = True
                improved = True
                break
        if cut >= start_cut:
            break
    seconds = time.perf_counter() - t0
    validate_partition(graph, part, k, capacity)
    assert cut == state.score(part)
    vol = comm_volume(graph.hyper, part) if graph.hyper is not None else None
    return PartitionResult(
        part=part, k=k, edge_cut=edge_cut(graph, part), capacity=capacity,
        num_levels=1, seconds=seconds, objective=objective, comm_volume=vol,
    )


def sco_partition(graph: Graph, capacity: int = 256,
                  objective: str = "cut") -> PartitionResult:
    """SCO: sequential packing — fill each core to capacity in neuron order.

    Minimizes the number of cores used; ignores spike traffic entirely
    (``objective`` only selects which metric the result reports as its
    optimization target — the packing is identical).
    """
    if objective not in ("cut", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    t0 = time.perf_counter()
    n = graph.num_vertices
    part = np.empty(n, dtype=np.int64)
    p, w = 0, 0
    for v in range(n):
        if w + graph.vwgt[v] > capacity:
            p += 1
            w = 0
        part[v] = p
        w += graph.vwgt[v]
    k = p + 1
    seconds = time.perf_counter() - t0
    validate_partition(graph, part, k, capacity)
    vol = comm_volume(graph.hyper, part) if graph.hyper is not None else None
    return PartitionResult(part=part, k=k, edge_cut=edge_cut(graph, part),
                           capacity=capacity, num_levels=1, seconds=seconds,
                           objective=objective, comm_volume=vol)


def sco_place(k: int, num_cores: int) -> MappingResult:
    """SCO placement: partitions land on cores in row-major sequence.

    No search runs, so no metric is computed here — ``avg_hop``/``tree_hop``
    start NaN/None and are filled by the pipeline's shared evaluator
    (`repro.core.placecost.evaluate_placement`), the same code path every
    other method's reported hop comes from.
    """
    if k > num_cores:
        raise ValueError(f"{k} partitions > {num_cores} cores")
    return MappingResult(placement=np.arange(k, dtype=np.int64), avg_hop=float("nan"),
                         seconds=0.0, history=[], evaluations=0)


# SpiNeMap's placer is PSO; re-export for pipeline symmetry.
spinemap_place = pso_search

"""Placement objectives for the mapping phase (paper §3.4, unified engine).

Every mapping search (`repro.core.mapping`) scores candidate placements
through one of the objectives defined here, so the search engines are
objective-agnostic and the quantity the mapper minimizes can be chosen to
match the NoC traffic model the evaluation phase simulates:

* ``PairwiseObjective`` — the paper's Eq. 2: total hop-weighted pairwise
  traffic ``sum_{i,j} d(M(i), M(j)) * C[i, j]``.  Exact for unicast
  replay, but under multicast it double-counts shared XY-tree prefixes.
* ``TreeHopObjective`` — the hfire-weighted XY multicast-tree link count:
  each hyperedge (source partition, destination-partition set) pays its
  fire count once per *link of its multicast tree*, the same accounting
  the tree-fork replay charges per (firing, tree link) traversal
  (`repro.nocsim.xy.multicast_tree_sizes`).  Minimizing it minimizes the
  replay's ``link_traversals`` — and with it dynamic energy — directly.

Both objectives expose the same engine-facing contract:

  ``attach(placement)``          bind a placement, return its exact cost;
  ``swap_delta(a, b)``           incremental cost change of one swap;
  ``swap_delta_batch(aa, bb)``   (B,) independent candidate deltas;
  ``apply_swaps(pairs)``         commit disjoint swaps, return exact cost;
  ``total(placement)``           stateless full evaluation.

The tree objective keeps its incremental state as a per-hyperedge tree-size
cache plus a CSR partition→hyperedge incidence index, so a swap re-evaluates
only the hyperedges incident to the two swapped partitions.  Identical
(source partition, destination set) hyperedges are merged at construction
(their trees are congruent under every placement), which collapses the
neuron-granularity hypergraph to at most one entry per distinct
partition-level multicast pattern.

`evaluate_placement` is the single post-search reporting path: every
toolchain method's ``avg_hop`` (pairwise, Fig. 5 comparability) and
``tree_hop`` come from here, regardless of which objective drove — or
didn't drive — the search.
"""
from __future__ import annotations

import numpy as np

from repro.nocsim.xy import multicast_tree_sizes, segment_extrema2, span_to

from .graph import Hypergraph, csr_gather
from .hopcost import hop_distance_matrix, swap_delta

__all__ = [
    "PairwiseObjective",
    "TreeHopObjective",
    "MigrationAwareObjective",
    "make_objective",
    "validate_objective",
    "evaluate_placement",
    "PLACE_OBJECTIVES",
]


def _sorted_isect(kx: np.ndarray, ky: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Membership masks of the intersection of two ascending key arrays.

    Both inputs must be sorted with no internal duplicates (the incidence
    keys are: candidate-major gathers over edge-sorted CSR rows, and a
    position holds a given role in an edge at most once) — one
    `searchsorted` merge then marks, on each side, the entries whose key
    appears on the other side.
    """
    mx = np.zeros(kx.shape[0], dtype=bool)
    my = np.zeros(ky.shape[0], dtype=bool)
    if kx.shape[0] and ky.shape[0]:
        ins = np.searchsorted(ky, kx)
        ok = np.flatnonzero(ins < ky.shape[0])
        mx[ok] = ky[ins[ok]] == kx[ok]
        my[ins[mx]] = True
    return mx, my


class PairwiseObjective:
    """Eq. 2 hop-weighted pairwise traffic (the paper's mapping objective).

    Owns the shared search preamble — zero-padding the (k, k) traffic
    matrix to the core count, symmetrizing it, and building the hop
    distance matrix — that used to be copied across ``sa_search``,
    ``tabu_search`` and ``pso_search``.
    """

    name = "pairwise"

    def __init__(
        self,
        traffic: np.ndarray,
        num_cores: int,
        mesh_w: int,
        torus: bool = False,
    ):
        k = int(traffic.shape[0])
        if k > num_cores:
            raise ValueError(f"{k} partitions > {num_cores} cores")
        padded = np.zeros((num_cores, num_cores), dtype=np.float64)
        padded[:k, :k] = traffic
        self.num_partitions = k
        self.num_positions = num_cores
        self.mesh_w = mesh_w
        self.torus = torus
        self.sym = padded + padded.T
        self.dist = hop_distance_matrix(num_cores, mesh_w, torus=torus).astype(
            np.float64
        )
        self._placement: np.ndarray | None = None
        # Placement-permuted distance columns, attached-state cache:
        # _dist_p[c, j] = dist[c, placement[j]].  Lets the batch scorer use
        # contiguous row gathers instead of broadcast fancy indexing (the
        # difference between ~5 ms and ~0.3 ms per 512-candidate batch at
        # 256 cores); a committed swap of positions (a, b) just swaps
        # columns a and b.
        self._dist_p: np.ndarray | None = None
        self._total = 0.0

    # -- stateless ---------------------------------------------------------
    def total(self, placement: np.ndarray) -> float:
        """Exact Eq. 2 total of a placement.

        Accepts the full ``num_cores`` permutation or any prefix covering
        the real partitions (virtual-partition traffic is zero, so the
        truncated sum is identical) — which is what lets the shared
        evaluator score a (k,)-length finished placement directly.
        """
        m = placement.shape[0]
        if m < self.num_partitions:
            raise ValueError(f"placement covers {m} < {self.num_partitions} partitions")
        d = self.dist[placement[:, None], placement[None, :]]
        return float((d * self.sym[:m, :m]).sum() / 2.0)

    # -- engine-facing incremental API ------------------------------------
    def attach(self, placement: np.ndarray) -> float:
        self._placement = placement
        self._dist_p = np.ascontiguousarray(self.dist[:, placement])
        self._total = self.total(placement)
        return self._total

    def swap_delta(self, a: int, b: int) -> float:
        return swap_delta(self.sym, self._placement, self.dist, a, b)

    def swap_delta_batch(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        """Vectorized `hopcost.swap_delta_batch` over the attached placement.

        Same formula, but the placed distances come from the cached
        ``_dist_p`` columns so both distance operands are plain row
        gathers.
        """
        aa = np.asarray(aa, dtype=np.int64)
        bb = np.asarray(bb, dtype=np.int64)
        p, dp = self._placement, self._dist_p
        diff = (self.sym[aa] - self.sym[bb]) * (dp[p[bb]] - dp[p[aa]])
        rows = np.arange(aa.shape[0])
        return diff.sum(axis=1) - diff[rows, aa] - diff[rows, bb]

    def apply_swaps(self, pairs: np.ndarray, total_delta: float | None = None) -> float:
        """Commit position-disjoint swaps to the attached placement.

        A single swap updates the cached total with the O(K) incremental
        delta (``total_delta`` lets the engine hand back the delta it
        already scored, skipping the recompute); larger batches swap all
        positions at once and re-evaluate the O(K^2) total exactly (one
        row gather + reduction — still far cheaper per proposal than
        scoring the batch), so the returned cost is exact either way and
        incremental drift cannot accumulate past the final re-evaluation.
        """
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        p = self._placement
        if pairs.shape[0] == 0:
            return self._total
        aa, bb = pairs[:, 0], pairs[:, 1]
        if pairs.shape[0] == 1:
            a, b = int(aa[0]), int(bb[0])
            self._total += (self.swap_delta(a, b) if total_delta is None
                            else total_delta)
            p[a], p[b] = p[b], p[a]
        else:
            p[aa], p[bb] = p[bb].copy(), p[aa].copy()
        self._dist_p[:, aa], self._dist_p[:, bb] = (
            self._dist_p[:, bb].copy(), self._dist_p[:, aa].copy()
        )
        if pairs.shape[0] > 1:
            self._total = float(
                (self.sym * self._dist_p[p]).sum() / 2.0
            )
        return self._total


class TreeHopObjective:
    """hfire-weighted XY multicast-tree link count (tree-hop objective).

    cost(M) = sum_e  w_e * |tree(M(src_e), {M(d) : d in dests_e})|

    where e ranges over the distinct partition-level multicast patterns of
    ``hyper`` under ``part`` (hyperedges with identical source partition
    and destination-partition set merged, ``w_e`` their summed fire
    counts) and ``tree`` is the union of deterministic XY routes — exactly
    the per-firing link set the tree-fork replay traverses, so
    ``total(placement)`` equals the multicast replay's ``link_traversals``
    for that placement.

    Swaps are scored incrementally: a CSR index maps each placement
    position (partition) to the hyperedges it is source or destination of,
    and each incident tree is re-priced in O(1) from member-level
    aggregates instead of being re-measured member by member.

    Aggregate invariants (maintained for the attached placement; all
    quantities integer, so incremental sizes are *exact*, never drift):

    * ``_cnt[e, c]`` — number of destination members of hyperedge ``e``
      placed in mesh column ``c``.  Members are distinct partitions and a
      placement is a permutation, so within one column of one edge the
      member *rows* are distinct.
    * ``_rmin1/_rmin2/_rmax1/_rmax2[e, c]`` — the two extreme (and
      strictly distinct) destination rows of edge ``e`` in column ``c``,
      with sentinels ``mesh_h``/``-1`` when fewer than two members occupy
      the column (`repro.nocsim.xy.segment_extrema2`).
    * ``_cmin1/_cmin2/_cmax1/_cmax2[e]`` — the two extreme *distinct
      occupied* columns of edge ``e`` (sentinels ``mesh_w``/``-1``).

    A tree's size is then the closed form (`repro.nocsim.xy.span_to`)

      ``size(e) = span_to(sx, _cmin1[e], _cmax1[e])
                + sum_c  [_cnt[e, c] > 0] * span_to(sy, _rmin1[e,c], _rmax1[e,c])``

    with ``(sx, sy)`` the source partition's core coordinates — the same
    horizontal-segment + per-column-vertical-segment algebra
    `multicast_tree_sizes` evaluates by sorting route offsets, pinned
    equal by the engine tests.  Because the aggregates do not involve the
    source position at all, a candidate that moves only the *source* of an
    edge re-evaluates this form over unchanged aggregates (O(mesh_w));
    a candidate that moves one *destination* member re-prices the edge in
    O(1): top-2 extremes make removal of a non-extreme member free and
    extreme removal a fallback to the runner-up, insertion is a min/max
    against the new coordinate.  Only candidates touching two members (or
    a member and the source) of the same edge fall back to the exact
    route-expansion re-measure.

    The aggregates are maintained *lazily*: they are built on the first
    `swap_delta_batch` call and commits only mark their member-touched
    edges dirty, so the one batched rebuild reduction per search step is
    amortized over the whole candidate batch — and the scalar
    propose-then-commit chain (`swap_delta` + pending reuse), which never
    scores batches, never pays for aggregates at all.  Any accepted-swap
    sequence leaves the synced aggregates identical to a from-scratch
    attach.
    """

    name = "tree"

    def __init__(
        self,
        hyper: Hypergraph,
        part: np.ndarray,
        num_cores: int,
        mesh_w: int,
        mesh_h: int | None = None,
    ):
        part = np.asarray(part, dtype=np.int64)
        k = int(part.max()) + 1 if part.shape[0] else 0
        if k > num_cores:
            raise ValueError(f"{k} partitions > {num_cores} cores")
        self.num_partitions = k
        self.num_positions = num_cores
        # Construction inputs, kept for `validate_objective`: the derived
        # tables are bound to exactly this (hyper, part) pair, so reuse
        # under a different partitioning must be detectable.
        self._part = part.copy()
        self._hyper = hyper
        self.mesh_w = mesh_w
        self.mesh_h = (
            mesh_h if mesh_h is not None else -(-num_cores // mesh_w)
        )
        if self.mesh_w * self.mesh_h < num_cores:
            raise ValueError("mesh smaller than num_cores")

        # Partition-level destination sets: distinct dest partitions per
        # hyperedge, excluding the source's own partition (core-local
        # deliveries never enter the NoC).
        ps_all = part[hyper.hsrc.astype(np.int64)]
        pp = part[hyper.hpins.astype(np.int64)]
        pe = hyper.pin_edge
        remote = pp != ps_all[pe]
        ukey = np.unique(pe[remote] * np.int64(max(k, 1)) + pp[remote])
        uedge, dpart = ukey // max(k, 1), ukey % max(k, 1)
        eids, ecount = np.unique(uedge, return_counts=True)

        # Merge hyperedges whose (source partition, dest set) coincide:
        # their multicast trees are congruent under every placement, so
        # only the summed fire count matters.  Dest sets are compared
        # exactly as k-bit bitset rows.
        ne = eids.shape[0]
        ps = ps_all[eids]
        fire = hyper.hfire[eids].astype(np.float64)
        nb = (k + 63) // 64 if k else 1
        bits = np.zeros((ne, nb), dtype=np.uint64)
        row = np.repeat(np.arange(ne, dtype=np.int64), ecount)
        np.bitwise_or.at(
            bits, (row, dpart >> 6), np.uint64(1) << (dpart & 63).astype(np.uint64)
        )
        sig = np.concatenate([ps[:, None].astype(np.uint64), bits], axis=1)
        _, rep, inv = np.unique(sig, axis=0, return_index=True, return_inverse=True)
        t = rep.shape[0]
        self.tw = np.bincount(inv, weights=fire, minlength=t)
        self.tsrc = ps[rep]
        lens = ecount[rep]
        self.tptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        ent, _ = csr_gather(
            np.concatenate([[0], np.cumsum(ecount)]).astype(np.int64), rep
        )
        self.tdst = dpart[ent]
        self.num_hyperedges = t
        self.lens = lens.astype(np.int64)

        # Split CSR incidence indexes: position -> hyperedges it is a
        # destination member of (`imlist`/`imptr`) and position ->
        # hyperedges it is the source of (`islist`/`isptr`).  Positions
        # >= k (virtual partitions) have empty rows, so swaps among them
        # are free, exactly as the pairwise objective's zero-padded
        # traffic makes them.  Keeping the roles in separate indexes lets
        # the batch scorer run the O(1) member-move and O(w) source-move
        # paths over homogeneous record arrays with no per-record role
        # masking; rows are edge-sorted (a position holds a given role in
        # an edge at most once, so ids within a row are strictly
        # increasing), so a candidate-major gather yields globally
        # ascending (candidate, edge) keys and edges incident to *both*
        # swapped positions fall out of `searchsorted` merges instead of
        # per-batch argsorts.
        meid = np.repeat(np.arange(t, dtype=np.int64), lens)
        order = np.lexsort((meid, self.tdst))
        self.imlist = meid[order]
        imptr = np.zeros(num_cores + 1, dtype=np.int64)
        np.add.at(imptr, self.tdst + 1, 1)
        self.imptr = np.cumsum(imptr)
        order = np.argsort(self.tsrc, kind="stable")
        self.islist = np.arange(t, dtype=np.int64)[order]
        isptr = np.zeros(num_cores + 1, dtype=np.int64)
        np.add.at(isptr, self.tsrc + 1, 1)
        self.isptr = np.cumsum(isptr)

        self._placement: np.ndarray | None = None
        self._sizes: np.ndarray | None = None
        self._total = 0.0
        # Member-level aggregate tables (see the class docstring), built
        # lazily by the first `swap_delta_batch` and re-synced from the
        # `_dirty` edge list a commit leaves behind.
        self._cnt: np.ndarray | None = None
        self._rmin1 = self._rmin2 = self._rmax1 = self._rmax2 = None
        self._cmin1 = self._cmin2 = self._cmax1 = self._cmax2 = None
        self._dirty: list[np.ndarray] = []
        self._dirty_src: list[np.ndarray] = []
        # Last single-pair proposal scored by `swap_delta`: (a, b, edges,
        # their re-measured sizes).  `apply_swaps` of that same pair
        # reuses the measurement instead of paying the geometry twice —
        # the propose-then-commit pattern of the scalar SA chain.
        self._pending: tuple | None = None

    # -- geometry ----------------------------------------------------------
    def _tree_sizes(
        self, edges: np.ndarray, src_core: np.ndarray, dst_core: np.ndarray,
        inst: np.ndarray, n: int,
    ) -> np.ndarray:
        return multicast_tree_sizes(
            src_core, dst_core, inst, self.mesh_w, self.mesh_h, n
        )

    def _sizes_of(self, edges: np.ndarray, placement: np.ndarray) -> np.ndarray:
        """Tree-link count of each listed hyperedge under ``placement``."""
        ent, inst = csr_gather(self.tptr, edges)
        src_core = placement[self.tsrc[edges]][inst]
        dst_core = placement[self.tdst[ent]]
        return self._tree_sizes(edges, src_core, dst_core, inst, edges.shape[0])

    # -- stateless ---------------------------------------------------------
    def total(self, placement: np.ndarray) -> float:
        edges = np.arange(self.num_hyperedges, dtype=np.int64)
        return float((self.tw * self._sizes_of(edges, placement)).sum())

    # -- aggregate maintenance ---------------------------------------------
    def _agg_rebuild(self, edges: np.ndarray) -> None:
        """Recompute the member-level aggregates of ``edges`` from scratch.

        One batched top-2 reduction over the listed edges' members under
        the attached placement — the vectorized form of the per-column
        rescan an extreme-member removal needs, applied wholesale to the
        touched edges of a commit.
        """
        w, h = self.mesh_w, self.mesh_h
        ent, inst = csr_gather(self.tptr, edges)
        d = self._placement[self.tdst[ent]]
        c, r = d % w, d // w
        # Sentinel-reset the listed edges' cells, then scatter the sparse
        # top-2 reduction back over just the occupied ones — at larger
        # meshes most (edge, column) cells are empty, and never
        # materializing them keeps a commit's rebuild proportional to the
        # members gathered, not the mesh width.
        self._cnt[edges] = 0
        self._rmin1[edges] = h
        self._rmin2[edges] = h
        self._rmax1[edges] = -1
        self._rmax2[edges] = -1
        self._cmin1[edges] = w
        self._cmin2[edges] = w
        self._cmax1[edges] = -1
        self._cmax2[edges] = -1
        useg, cnt, rmin1, rmin2, rmax1, rmax2 = segment_extrema2(
            inst * w + c, r, h
        )
        if useg.shape[0] == 0:
            return
        ue, uc = useg // w, useg % w
        gfi = edges[ue] * w + uc
        self._cntf[gfi] = cnt
        self._rmin1f[gfi] = rmin1
        self._rmin2f[gfi] = rmin2
        self._rmax1f[gfi] = rmax1
        self._rmax2f[gfi] = rmax2
        # Top-2 distinct occupied columns per edge, off the same sparse
        # run: `useg` ascends, so each edge's occupied columns form one
        # contiguous ascending slice whose boundary entries are the
        # extremes and their runners-up.
        m = ue.shape[0]
        lastc = np.empty(m, dtype=bool)
        lastc[-1] = True
        np.not_equal(ue[1:], ue[:-1], out=lastc[:-1])
        firstc = np.empty(m, dtype=bool)
        firstc[0] = True
        firstc[1:] = lastc[:-1]
        fidx = np.flatnonzero(firstc)
        lidx = np.flatnonzero(lastc)
        eid = edges[ue[fidx]]
        self._cmin1[eid] = uc[fidx]
        self._cmax1[eid] = uc[lidx]
        has2 = lidx > fidx
        self._cmin2[eid[has2]] = uc[fidx[has2] + 1]
        self._cmax2[eid[has2]] = uc[lidx[has2] - 1]

    def _sizes_from_agg(self, edges: np.ndarray) -> np.ndarray:
        """Closed-form tree sizes of ``edges`` from the synced span caches."""
        return (self._hsp[edges] + self._vsp[edges].sum(axis=1)).astype(np.int64)

    # -- engine-facing incremental API ------------------------------------
    def attach(self, placement: np.ndarray) -> float:
        edges = np.arange(self.num_hyperedges, dtype=np.int64)
        self._placement = placement
        self._sizes = self._sizes_of(edges, placement)
        self._total = float((self.tw * self._sizes).sum())
        self._pending = None
        # Aggregates are placement-derived: invalidate wholesale, the
        # first batch scoring against this placement rebuilds them.
        self._cnt = None
        self._dirty = []
        self._dirty_src = []
        return self._total

    def _span_refresh(self, edges: np.ndarray) -> None:
        """Refresh the derived per-edge span caches of ``edges``.

        ``_srcx/_srcy`` are the source core's coordinates, ``_hsp`` the
        edge's current horizontal span and ``_vsp[:, c]`` its current
        vertical span in column ``c`` (0 for unoccupied columns, by the
        sentinel algebra) — all derived from the aggregate tables plus the
        attached placement, so the member-move path reads the *current*
        spans as gathers and computes only the changed ones.
        """
        s = self._placement[self.tsrc[edges]]
        w = self.mesh_w
        sx = (s % w).astype(np.int32)
        sy = (s // w).astype(np.int32)
        self._srcx[edges] = sx
        self._srcy[edges] = sy
        self._hsp[edges] = span_to(sx, self._cmin1[edges], self._cmax1[edges])
        self._vsp[edges] = span_to(
            sy[:, None], self._rmin1[edges], self._rmax1[edges]
        )

    def _agg_sync(self) -> None:
        """Bring the aggregate tables up to date with the placement.

        The first call allocates and builds every table; later calls
        rebuild only what commits marked dirty since the last sync — a
        full member reduction for edges whose *members* moved, just the
        derived span caches for edges whose *source* moved — one batched
        pass per search step, amortized over the whole candidate batch
        scored against it.
        """
        t, w = self.num_hyperedges, self.mesh_w
        if self._cnt is None:
            self._cnt = np.zeros((t, w), dtype=np.int32)
            self._rmin1 = np.empty((t, w), dtype=np.int32)
            self._rmin2 = np.empty((t, w), dtype=np.int32)
            self._rmax1 = np.empty((t, w), dtype=np.int32)
            self._rmax2 = np.empty((t, w), dtype=np.int32)
            self._cmin1 = np.empty(t, dtype=np.int32)
            self._cmin2 = np.empty(t, dtype=np.int32)
            self._cmax1 = np.empty(t, dtype=np.int32)
            self._cmax2 = np.empty(t, dtype=np.int32)
            self._vsp = np.empty((t, w), dtype=np.int32)
            self._hsp = np.empty(t, dtype=np.int32)
            self._srcx = np.empty(t, dtype=np.int32)
            self._srcy = np.empty(t, dtype=np.int32)
            # Raveled views of the per-(edge, column) tables: the
            # member-move path gathers at computed flat indices, cheaper
            # than 2D fancy indexing (the tables are written in place by
            # `_agg_rebuild`, so the views stay valid).
            self._cntf = self._cnt.ravel()
            self._rmin1f = self._rmin1.ravel()
            self._rmin2f = self._rmin2.ravel()
            self._rmax1f = self._rmax1.ravel()
            self._rmax2f = self._rmax2.ravel()
            self._vspf = self._vsp.ravel()
            edges = np.arange(t, dtype=np.int64)
            self._agg_rebuild(edges)
            self._span_refresh(edges)
        else:
            mem = None
            if self._dirty:
                d = self._dirty
                mem = d[0] if len(d) == 1 else np.unique(np.concatenate(d))
                self._agg_rebuild(mem)
            d = self._dirty_src + ([mem] if mem is not None else [])
            if d:
                edges = d[0] if len(d) == 1 else np.unique(np.concatenate(d))
                self._span_refresh(edges)
        self._dirty = []
        self._dirty_src = []

    def _incident(self, positions: np.ndarray) -> np.ndarray:
        """Deduplicated hyperedges incident to any of ``positions``."""
        me, _ = csr_gather(self.imptr, positions)
        se, _ = csr_gather(self.isptr, positions)
        return np.unique(np.concatenate([self.imlist[me], self.islist[se]]))

    def swap_delta(self, a: int, b: int) -> float:
        e = self._incident(np.array([a, b], dtype=np.int64))
        if e.shape[0] == 0:
            self._pending = None
            return 0.0
        p2 = self._placement.copy()
        p2[a], p2[b] = p2[b], p2[a]
        new_sizes = self._sizes_of(e, p2)
        self._pending = (int(a), int(b), e, new_sizes)
        return float((self.tw[e] * (new_sizes - self._sizes[e])).sum())

    def swap_delta_batch(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        """(B,) independent candidate deltas against the attached placement.

        Aggregate-priced: each candidate re-prices only the hyperedges
        incident to its two positions — O(1) per edge whose destination
        *member* moves, O(mesh_w) per edge whose *source* moves, and the
        exact route-expansion fallback only for the rare edges incident
        to both swapped positions.  Every contribution is an integer
        tree-size change times the integer fire weight, each delta a sum
        of exactly representable floats — so batched deltas equal the
        scalar `swap_delta` values bitwise, not approximately.
        """
        aa = np.asarray(aa, dtype=np.int64)
        bb = np.asarray(bb, dtype=np.int64)
        nb = aa.shape[0]
        self._agg_sync()
        p = self._placement
        t, w = self.num_hyperedges, self.mesh_w
        mea, mca = csr_gather(self.imptr, aa)
        meb, mcb = csr_gather(self.imptr, bb)
        sea, sca = csr_gather(self.isptr, aa)
        seb, scb = csr_gather(self.isptr, bb)
        ma_e, mb_e = self.imlist[mea], self.imlist[meb]
        sa_e, sb_e = self.islist[sea], self.islist[seb]
        if (ma_e.shape[0] + mb_e.shape[0] + sa_e.shape[0] + sb_e.shape[0]) == 0:
            return np.zeros(nb, dtype=np.float64)
        paa, pbb = p[aa], p[bb]
        p32a, p32b = paa.astype(np.int32), pbb.astype(np.int32)

        # Dual incidence — an edge touching both swapped positions — comes
        # out of sorted-key merges between the four role-homogeneous
        # incidence gathers.  Member+member duals just exchange two dest
        # cores: the dest multiset (and so the tree) is unchanged and the
        # contribution exactly zero, so both records are dropped.  Only
        # source+member duals need the exact route-expansion fallback.
        mm_a, mm_b = _sorted_isect(mca * t + ma_e, mcb * t + mb_e)
        sm_a, sm_b = _sorted_isect(sca * t + sa_e, mcb * t + mb_e)
        ms_a, ms_b = _sorted_isect(mca * t + ma_e, scb * t + sb_e)
        fb_e = fb_c = None
        if sm_a.any() or ms_a.any():
            fb_e = np.concatenate([sa_e[sm_a], ma_e[ms_a]])
            fb_c = np.concatenate([sca[sm_a], mca[ms_a]])
            sa_e, sca = sa_e[~sm_a], sca[~sm_a]
            sb_e, scb = sb_e[~ms_b], scb[~ms_b]
        drop = mm_a | ms_a
        if drop.any():
            keep = ~drop
            ma_e, mca = ma_e[keep], mca[keep]
        drop = mm_b | sm_b
        if drop.any():
            keep = ~drop
            mb_e, mcb = mb_e[keep], mcb[keep]

        # One single-sided record per remaining (candidate, edge): that
        # candidate moves the record's incident position from core `o`
        # to core `n2`, the other position doesn't touch this edge.
        # Coordinates and spans are int32 throughout — half the memory
        # traffic of the default int64, which is what bounds this path.

        # Destination-member move: O(1) re-pricing from the top-2
        # extremes — remove (old column, old row), insert (new column,
        # new row), re-span only the one or two affected segments against
        # the cached current spans.
        cand = np.concatenate([mca, mcb])
        deltas = np.zeros(nb, dtype=np.float64)
        if cand.shape[0]:
            e = np.concatenate([ma_e, mb_e])
            o = np.concatenate([p32a[mca], p32b[mcb]])
            n2 = np.concatenate([p32b[mca], p32a[mcb]])
            c, r = o % w, o // w
            c2, r2 = n2 % w, n2 // w
            sx, sy = self._srcx[e], self._srcy[e]
            fi = e * w + c
            fi2 = e * w + c2
            cmax1, cmax2 = self._cmax1[e], self._cmax2[e]
            cmin1, cmin2 = self._cmin1[e], self._cmin2[e]
            gone = self._cntf[fi] == 1  # removal empties column c
            cmax_rm = np.where(gone & (c == cmax1), cmax2, cmax1)
            cmin_rm = np.where(gone & (c == cmin1), cmin2, cmin1)
            hs = span_to(
                sx, np.minimum(cmin_rm, c2), np.maximum(cmax_rm, c2)
            ) - self._hsp[e]
            # Old column: rows are distinct within a column, so removing
            # the extreme falls back to the runner-up exactly.
            rmax1c, rmax2c = self._rmax1f[fi], self._rmax2f[fi]
            rmin1c, rmin2c = self._rmin1f[fi], self._rmin2f[fi]
            rmax_rm = np.where(r == rmax1c, rmax2c, rmax1c)
            rmin_rm = np.where(r == rmin1c, rmin2c, rmin1c)
            same = c2 == c
            prmax = np.where(same, np.maximum(rmax_rm, r2), rmax_rm)
            prmin = np.where(same, np.minimum(rmin_rm, r2), rmin_rm)
            v_c = span_to(sy, prmin, prmax) - self._vspf[fi]
            # New column (when different): plain insertion against the
            # current extremes (sentinels make the empty case exact).
            rmax1c2, rmin1c2 = self._rmax1f[fi2], self._rmin1f[fi2]
            v_c2 = np.where(
                same,
                0,
                span_to(sy, np.minimum(rmin1c2, r2), np.maximum(rmax1c2, r2))
                - self._vspf[fi2],
            )
            contrib = self.tw[e] * (hs + v_c + v_c2)
            # (the cast is for numpy's empty-weighted-bincount int64 quirk)
            deltas += np.bincount(cand, weights=contrib, minlength=nb).astype(
                np.float64, copy=False
            )

        # Source move: aggregates are source-independent, so the new size
        # is the closed form over unchanged tables at the new source core
        # (sentinel columns span 0, so no occupancy mask is needed).
        cand = np.concatenate([sca, scb])
        if cand.shape[0]:
            e = np.concatenate([sa_e, sb_e])
            s2 = np.concatenate([p32b[sca], p32a[scb]])
            sx, sy = s2 % w, s2 // w
            hspan = span_to(sx, self._cmin1[e], self._cmax1[e])
            vspan = span_to(sy[:, None], self._rmin1[e], self._rmax1[e]).sum(
                axis=1, dtype=np.int64
            )
            contrib = self.tw[e] * (hspan + vspan - self._sizes[e])
            deltas += np.bincount(cand, weights=contrib, minlength=nb).astype(
                np.float64, copy=False
            )

        if fb_e is not None:
            ci = fb_c
            ent2, inst2 = csr_gather(self.tptr, fb_e)

            def swapped_core(x, i):
                px = p[x]
                px = np.where(x == aa[i], pbb[i], px)
                return np.where(x == bb[i], paa[i], px)

            src_core = swapped_core(self.tsrc[fb_e], ci)[inst2]
            dst_core = swapped_core(self.tdst[ent2], ci[inst2])
            ns = self._tree_sizes(fb_e, src_core, dst_core, inst2, fb_e.shape[0])
            deltas += np.bincount(
                ci, weights=self.tw[fb_e] * (ns - self._sizes[fb_e]), minlength=nb
            )
        return deltas

    def apply_swaps(self, pairs: np.ndarray, total_delta: float | None = None) -> float:
        """Commit position-disjoint swaps; re-measure incident trees once.

        Exact: hyperedges not incident to any swapped position keep their
        cached tree size, incident ones are re-measured under the final
        placement, so the returned total is the true cost — no incremental
        drift even though the batch was *scored* with per-candidate
        deltas.  Committing the single pair `swap_delta` just scored
        reuses its measurement (``total_delta`` itself is ignored here:
        the size cache must be refreshed regardless, and the pending
        measurement already carries the delta).  When the lazy aggregate
        tables are live, edges whose *members* moved are marked dirty for
        the next `swap_delta_batch` sync; source-only edges stay clean —
        the aggregates never involve the source position.
        """
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        if pairs.shape[0] == 0:
            return self._total
        p = self._placement
        aa, bb = pairs[:, 0], pairs[:, 1]
        pending = self._pending
        self._pending = None
        p[aa], p[bb] = p[bb].copy(), p[aa].copy()
        use_pending = (pairs.shape[0] == 1 and pending is not None
                       and pending[0] == int(aa[0]) and pending[1] == int(bb[0]))
        if use_pending:
            _, _, touched, new_sizes = pending
        if not use_pending or self._cnt is not None:
            pos = np.concatenate([aa, bb])
            me, _ = csr_gather(self.imptr, pos)
            se, _ = csr_gather(self.isptr, pos)
            mem = self.imlist[me]
            srcd = self.islist[se]
            if not use_pending:
                touched = np.unique(np.concatenate([mem, srcd]))
        if self._cnt is not None:
            if mem.shape[0]:
                self._dirty.append(np.unique(mem))
            # Source-touched edges keep their aggregates but the derived
            # span caches read the source coordinates — refresh those.
            # (Each edge has one source and commits swap distinct
            # positions, so this list is duplicate-free as built.)
            if srcd.shape[0]:
                self._dirty_src.append(srcd)
            # Sync here rather than at the next batch scoring call: the
            # refreshed span caches then price the touched trees in
            # closed form, cheaper than the route-expansion re-measure.
            self._agg_sync()
            if not use_pending:
                new_sizes = (self._sizes_from_agg(touched) if touched.shape[0]
                             else self._sizes[touched])
        elif not use_pending:
            new_sizes = (self._sizes_of(touched, p) if touched.shape[0]
                         else self._sizes[touched])
        if touched.shape[0]:
            self._total += float(
                (self.tw[touched] * (new_sizes - self._sizes[touched])).sum()
            )
            self._sizes[touched] = new_sizes
        return self._total


class MigrationAwareObjective:
    """Wrap a placement objective with per-position migration pricing.

    Used by the incremental re-mapper (`repro.core.remap`): the search
    starts from the *live* placement and every candidate is charged, on
    top of the base hop/tree-hop cost, for the neurons it would move:

      penalty(M) = sum_j  move_cost[j] * [M(j) != live(j)]
                 + forbid * sum_j [w_j > 0] * dead[M(j)]

    where ``move_cost[j] = migration_cost * move_weight[j]`` (the neuron
    count of partition j — virtual positions weigh zero, so parking them
    anywhere is free) and the ``forbid`` term makes placing a *real*
    partition on a failed core worse than any achievable hop gain while
    staying finite, so swap deltas remain exactly the difference of
    totals and the metamorphic delta tests hold on faulty meshes too.

    The wrapper satisfies the same engine contract as the base objective
    and shares the attached placement array with it (``attach`` binds the
    identical object to both), so the base's committed swaps are visible
    here without synchronization.  ``name`` is ``"mig+<base>"`` — never a
    bare objective name, so reporting paths that special-case
    ``"pairwise"``/``"tree"`` re-score through a clean objective instead
    of leaking the penalty into avg_hop.
    """

    def __init__(
        self,
        base,
        live_placement: np.ndarray,
        move_weight: np.ndarray,
        migration_cost: float,
        dead_cores: np.ndarray | None = None,
        forbid_penalty: float = 0.0,
    ):
        n = base.num_positions
        live = np.asarray(live_placement, dtype=np.int64)
        if live.shape[0] != n:
            raise ValueError(
                f"live placement covers {live.shape[0]} != {n} positions"
            )
        w = np.zeros(n, dtype=np.float64)
        mw = np.asarray(move_weight, dtype=np.float64)
        w[: mw.shape[0]] = mw
        self.base = base
        self.name = f"mig+{base.name}"
        self.num_positions = n
        self.num_partitions = base.num_partitions
        self.live = live.copy()
        self.move_cost = w * float(migration_cost)
        self.real = w > 0
        self.dead = (
            np.zeros(n, dtype=bool) if dead_cores is None
            else np.asarray(dead_cores, dtype=bool).copy()
        )
        self.forbid_penalty = float(forbid_penalty)
        self._placement: np.ndarray | None = None
        self._pen_total = 0.0

    # -- penalty geometry --------------------------------------------------
    def _pen(self, pos: np.ndarray, core: np.ndarray) -> np.ndarray:
        """Penalty of placing partition(s) ``pos`` on core(s) ``core``."""
        moved = self.move_cost[pos] * (core != self.live[pos])
        forbid = self.forbid_penalty * (self.real[pos] & self.dead[core])
        return moved + forbid

    def penalty_total(self, placement: np.ndarray) -> float:
        pos = np.arange(placement.shape[0], dtype=np.int64)
        return float(self._pen(pos, placement).sum())

    # -- stateless ---------------------------------------------------------
    def total(self, placement: np.ndarray) -> float:
        return self.base.total(placement) + self.penalty_total(placement)

    # -- engine-facing incremental API ------------------------------------
    def attach(self, placement: np.ndarray) -> float:
        base_total = self.base.attach(placement)
        self._placement = self.base._placement
        self._pen_total = self.penalty_total(self._placement)
        return base_total + self._pen_total

    def _swap_pen_delta(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        p = self._placement
        return (
            self._pen(aa, p[bb]) + self._pen(bb, p[aa])
            - self._pen(aa, p[aa]) - self._pen(bb, p[bb])
        )

    def swap_delta(self, a: int, b: int) -> float:
        aa = np.array([a], dtype=np.int64)
        bb = np.array([b], dtype=np.int64)
        return self.base.swap_delta(a, b) + float(self._swap_pen_delta(aa, bb)[0])

    def swap_delta_batch(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        aa = np.asarray(aa, dtype=np.int64)
        bb = np.asarray(bb, dtype=np.int64)
        return self.base.swap_delta_batch(aa, bb) + self._swap_pen_delta(aa, bb)

    def apply_swaps(self, pairs: np.ndarray, total_delta: float | None = None) -> float:
        # The engine's total_delta includes the penalty part, which the
        # base must not fold into its hop total — commit through the base
        # with its own exact accounting and refresh the O(K) penalty.
        base_total = self.base.apply_swaps(pairs)
        self._pen_total = self.penalty_total(self._placement)
        return base_total + self._pen_total


PLACE_OBJECTIVES = ("pairwise", "tree")


def make_objective(
    kind: str,
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    mesh_h: int | None = None,
    torus: bool = False,
    hyper: Hypergraph | None = None,
    part: np.ndarray | None = None,
):
    """Build a placement objective by name.

    ``"pairwise"`` needs only the (k, k) traffic matrix; ``"tree"``
    additionally needs the profiled multicast hypergraph and the partition
    vector (to form destination-partition sets), and is mesh-only (XY
    trees have no torus form).
    """
    if kind == "pairwise":
        return PairwiseObjective(traffic, num_cores, mesh_w, torus=torus)
    if kind == "tree":
        if hyper is None or part is None:
            raise ValueError("tree objective needs hyper= and part=")
        if torus:
            raise ValueError("tree objective is mesh-only (no torus XY trees)")
        return TreeHopObjective(hyper, part, num_cores, mesh_w, mesh_h)
    raise ValueError(f"unknown placement objective {kind!r}")


def validate_objective(
    obj,
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int | None = None,
    mesh_h: int | None = None,
    part: np.ndarray | None = None,
    hyper: Hypergraph | None = None,
    torus: bool = False,
    strict: bool = True,
) -> bool:
    """Check that ``obj`` was built for this run's (traffic, partition, mesh).

    Objective instances are stateful *and* construction-bound: a
    ``PairwiseObjective`` bakes in the symmetrized traffic matrix, a
    ``TreeHopObjective`` its partition-level multicast patterns.  Reusing
    one across runs whose partition or traffic differ (the sweep hazard:
    one ``mapper_kwargs={"objective": ...}`` dict shared over a config
    grid) silently scores the wrong quantity.  Returns True when the
    instance matches; on mismatch raises ``ValueError`` naming the
    mismatched facet (``strict=True``, the search-time behavior) or
    returns False (``strict=False``, the reporting-time behavior —
    `evaluate_placement` then rebuilds a fresh objective instead).

    Content comparisons run only when the identity fast path fails, so
    the common flow — one objective built and consumed inside one run —
    validates at pointer-compare cost.
    """
    def fail(msg: str) -> bool:
        if strict:
            raise ValueError(
                f"reused {obj.name!r} objective does not match this run: "
                f"{msg}; build a fresh objective per (traffic, partition, "
                f"mesh) — see make_objective()"
            )
        return False

    name = getattr(obj, "name", None)
    if name not in ("pairwise", "tree"):
        return fail(f"unexpected objective name {name!r}")
    if obj.num_positions != num_cores:
        return fail(f"built for {obj.num_positions} cores, run has {num_cores}")
    if mesh_w is not None and obj.mesh_w != mesh_w:
        return fail(f"built for mesh_w={obj.mesh_w}, run has {mesh_w}")
    k = int(traffic.shape[0])
    if name == "pairwise":
        if obj.torus != torus:
            return fail(f"built with torus={obj.torus}, run has {torus}")
        if obj.num_partitions != k:
            return fail(f"built for k={obj.num_partitions}, run has k={k}")
        sym = np.asarray(traffic, dtype=np.float64)
        if not np.array_equal(obj.sym[:k, :k], sym + sym.T):
            return fail("traffic matrix content differs")
        return True
    if torus:
        return fail("tree objective is mesh-only, run is torus")
    if mesh_h is not None and obj.mesh_h != mesh_h:
        return fail(f"built for mesh_h={obj.mesh_h}, run has {mesh_h}")
    if part is not None:
        part = np.asarray(part, dtype=np.int64)
        if obj._part is not part and not np.array_equal(obj._part, part):
            return fail("partition vector content differs")
    if hyper is not None and obj._hyper is not hyper:
        h0 = obj._hyper
        same = (
            np.array_equal(h0.hxadj, hyper.hxadj)
            and np.array_equal(h0.hpins, hyper.hpins)
            and np.array_equal(h0.hsrc, hyper.hsrc)
            and np.array_equal(h0.hfire, hyper.hfire)
        )
        if not same:
            return fail("hypergraph content differs")
    return True


def evaluate_placement(
    placement: np.ndarray,
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    mesh_h: int | None = None,
    hyper: Hypergraph | None = None,
    part: np.ndarray | None = None,
    torus: bool = False,
    reuse=None,
) -> tuple[float, float | None]:
    """Score a finished placement under both objectives: (avg_hop, tree_hop).

    The one reporting path every toolchain method goes through (SA/tabu/PSO
    searches, device mappers, and SCO's sequential placement alike), so
    cross-method comparisons are never an artifact of who computed the
    metric.  ``avg_hop`` is the paper's Eq. 2 average (pairwise hops per
    packet of the run's traffic model); ``tree_hop`` is the multicast
    tree-link traversals per packet under the same normalization, or None
    when no hypergraph is available (or on torus meshes, which have no XY
    trees).  ``reuse`` accepts an already-built objective instance (either
    kind — e.g. the one that drove the search) so its construction cost is
    not paid twice; it is *validated* against this call's traffic/
    partition/mesh first (`validate_objective`) and silently replaced by a
    fresh build on mismatch, so an objective carried over from a different
    run can never skew the reported stats; scoring through a matching one
    is stateless (``total``), so its attached search state is irrelevant.
    """
    placement = np.asarray(placement, dtype=np.int64)
    denom = max(trace_length, 1)

    def usable(kind: str) -> bool:
        return (reuse is not None and getattr(reuse, "name", None) == kind
                and validate_objective(reuse, traffic, num_cores, mesh_w,
                                       mesh_h=mesh_h, part=part, hyper=hyper,
                                       torus=torus, strict=False))

    pw = (reuse if usable("pairwise")
          else PairwiseObjective(traffic, num_cores, mesh_w, torus=torus))
    avg_hop = pw.total(placement) / denom
    tree_hop = None
    if usable("tree"):
        tree_hop = reuse.total(placement) / denom
    elif hyper is not None and part is not None and not torus:
        tree = TreeHopObjective(hyper, part, num_cores, mesh_w, mesh_h)
        tree_hop = tree.total(placement) / denom
    return avg_hop, tree_hop

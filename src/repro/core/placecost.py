"""Placement objectives for the mapping phase (paper §3.4, unified engine).

Every mapping search (`repro.core.mapping`) scores candidate placements
through one of the objectives defined here, so the search engines are
objective-agnostic and the quantity the mapper minimizes can be chosen to
match the NoC traffic model the evaluation phase simulates:

* ``PairwiseObjective`` — the paper's Eq. 2: total hop-weighted pairwise
  traffic ``sum_{i,j} d(M(i), M(j)) * C[i, j]``.  Exact for unicast
  replay, but under multicast it double-counts shared XY-tree prefixes.
* ``TreeHopObjective`` — the hfire-weighted XY multicast-tree link count:
  each hyperedge (source partition, destination-partition set) pays its
  fire count once per *link of its multicast tree*, the same accounting
  the tree-fork replay charges per (firing, tree link) traversal
  (`repro.nocsim.xy.multicast_tree_sizes`).  Minimizing it minimizes the
  replay's ``link_traversals`` — and with it dynamic energy — directly.

Both objectives expose the same engine-facing contract:

  ``attach(placement)``          bind a placement, return its exact cost;
  ``swap_delta(a, b)``           incremental cost change of one swap;
  ``swap_delta_batch(aa, bb)``   (B,) independent candidate deltas;
  ``apply_swaps(pairs)``         commit disjoint swaps, return exact cost;
  ``total(placement)``           stateless full evaluation.

The tree objective keeps its incremental state as a per-hyperedge tree-size
cache plus a CSR partition→hyperedge incidence index, so a swap re-evaluates
only the hyperedges incident to the two swapped partitions.  Identical
(source partition, destination set) hyperedges are merged at construction
(their trees are congruent under every placement), which collapses the
neuron-granularity hypergraph to at most one entry per distinct
partition-level multicast pattern.

`evaluate_placement` is the single post-search reporting path: every
toolchain method's ``avg_hop`` (pairwise, Fig. 5 comparability) and
``tree_hop`` come from here, regardless of which objective drove — or
didn't drive — the search.
"""
from __future__ import annotations

import numpy as np

from repro.nocsim.xy import multicast_tree_sizes

from .graph import Hypergraph, csr_gather
from .hopcost import hop_distance_matrix, swap_delta

__all__ = [
    "PairwiseObjective",
    "TreeHopObjective",
    "MigrationAwareObjective",
    "make_objective",
    "evaluate_placement",
    "PLACE_OBJECTIVES",
]


class PairwiseObjective:
    """Eq. 2 hop-weighted pairwise traffic (the paper's mapping objective).

    Owns the shared search preamble — zero-padding the (k, k) traffic
    matrix to the core count, symmetrizing it, and building the hop
    distance matrix — that used to be copied across ``sa_search``,
    ``tabu_search`` and ``pso_search``.
    """

    name = "pairwise"

    def __init__(
        self,
        traffic: np.ndarray,
        num_cores: int,
        mesh_w: int,
        torus: bool = False,
    ):
        k = int(traffic.shape[0])
        if k > num_cores:
            raise ValueError(f"{k} partitions > {num_cores} cores")
        padded = np.zeros((num_cores, num_cores), dtype=np.float64)
        padded[:k, :k] = traffic
        self.num_partitions = k
        self.num_positions = num_cores
        self.sym = padded + padded.T
        self.dist = hop_distance_matrix(num_cores, mesh_w, torus=torus).astype(
            np.float64
        )
        self._placement: np.ndarray | None = None
        # Placement-permuted distance columns, attached-state cache:
        # _dist_p[c, j] = dist[c, placement[j]].  Lets the batch scorer use
        # contiguous row gathers instead of broadcast fancy indexing (the
        # difference between ~5 ms and ~0.3 ms per 512-candidate batch at
        # 256 cores); a committed swap of positions (a, b) just swaps
        # columns a and b.
        self._dist_p: np.ndarray | None = None
        self._total = 0.0

    # -- stateless ---------------------------------------------------------
    def total(self, placement: np.ndarray) -> float:
        """Exact Eq. 2 total of a placement.

        Accepts the full ``num_cores`` permutation or any prefix covering
        the real partitions (virtual-partition traffic is zero, so the
        truncated sum is identical) — which is what lets the shared
        evaluator score a (k,)-length finished placement directly.
        """
        m = placement.shape[0]
        if m < self.num_partitions:
            raise ValueError(f"placement covers {m} < {self.num_partitions} partitions")
        d = self.dist[placement[:, None], placement[None, :]]
        return float((d * self.sym[:m, :m]).sum() / 2.0)

    # -- engine-facing incremental API ------------------------------------
    def attach(self, placement: np.ndarray) -> float:
        self._placement = placement
        self._dist_p = np.ascontiguousarray(self.dist[:, placement])
        self._total = self.total(placement)
        return self._total

    def swap_delta(self, a: int, b: int) -> float:
        return swap_delta(self.sym, self._placement, self.dist, a, b)

    def swap_delta_batch(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        """Vectorized `hopcost.swap_delta_batch` over the attached placement.

        Same formula, but the placed distances come from the cached
        ``_dist_p`` columns so both distance operands are plain row
        gathers.
        """
        aa = np.asarray(aa, dtype=np.int64)
        bb = np.asarray(bb, dtype=np.int64)
        p, dp = self._placement, self._dist_p
        diff = (self.sym[aa] - self.sym[bb]) * (dp[p[bb]] - dp[p[aa]])
        rows = np.arange(aa.shape[0])
        return diff.sum(axis=1) - diff[rows, aa] - diff[rows, bb]

    def apply_swaps(self, pairs: np.ndarray, total_delta: float | None = None) -> float:
        """Commit position-disjoint swaps to the attached placement.

        A single swap updates the cached total with the O(K) incremental
        delta (``total_delta`` lets the engine hand back the delta it
        already scored, skipping the recompute); larger batches swap all
        positions at once and re-evaluate the O(K^2) total exactly (one
        row gather + reduction — still far cheaper per proposal than
        scoring the batch), so the returned cost is exact either way and
        incremental drift cannot accumulate past the final re-evaluation.
        """
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        p = self._placement
        if pairs.shape[0] == 0:
            return self._total
        aa, bb = pairs[:, 0], pairs[:, 1]
        if pairs.shape[0] == 1:
            a, b = int(aa[0]), int(bb[0])
            self._total += (self.swap_delta(a, b) if total_delta is None
                            else total_delta)
            p[a], p[b] = p[b], p[a]
        else:
            p[aa], p[bb] = p[bb].copy(), p[aa].copy()
        self._dist_p[:, aa], self._dist_p[:, bb] = (
            self._dist_p[:, bb].copy(), self._dist_p[:, aa].copy()
        )
        if pairs.shape[0] > 1:
            self._total = float(
                (self.sym * self._dist_p[p]).sum() / 2.0
            )
        return self._total


class TreeHopObjective:
    """hfire-weighted XY multicast-tree link count (tree-hop objective).

    cost(M) = sum_e  w_e * |tree(M(src_e), {M(d) : d in dests_e})|

    where e ranges over the distinct partition-level multicast patterns of
    ``hyper`` under ``part`` (hyperedges with identical source partition
    and destination-partition set merged, ``w_e`` their summed fire
    counts) and ``tree`` is the union of deterministic XY routes — exactly
    the per-firing link set the tree-fork replay traverses, so
    ``total(placement)`` equals the multicast replay's ``link_traversals``
    for that placement.

    Swaps are scored incrementally: a CSR index maps each placement
    position (partition) to the hyperedges it is source or destination of,
    and only those trees are re-measured under the candidate placement.
    """

    name = "tree"

    def __init__(
        self,
        hyper: Hypergraph,
        part: np.ndarray,
        num_cores: int,
        mesh_w: int,
        mesh_h: int | None = None,
    ):
        part = np.asarray(part, dtype=np.int64)
        k = int(part.max()) + 1 if part.shape[0] else 0
        if k > num_cores:
            raise ValueError(f"{k} partitions > {num_cores} cores")
        self.num_partitions = k
        self.num_positions = num_cores
        self.mesh_w = mesh_w
        self.mesh_h = (
            mesh_h if mesh_h is not None else -(-num_cores // mesh_w)
        )
        if self.mesh_w * self.mesh_h < num_cores:
            raise ValueError("mesh smaller than num_cores")

        # Partition-level destination sets: distinct dest partitions per
        # hyperedge, excluding the source's own partition (core-local
        # deliveries never enter the NoC).
        ps_all = part[hyper.hsrc.astype(np.int64)]
        pp = part[hyper.hpins.astype(np.int64)]
        pe = hyper.pin_edge
        remote = pp != ps_all[pe]
        ukey = np.unique(pe[remote] * np.int64(max(k, 1)) + pp[remote])
        uedge, dpart = ukey // max(k, 1), ukey % max(k, 1)
        eids, ecount = np.unique(uedge, return_counts=True)

        # Merge hyperedges whose (source partition, dest set) coincide:
        # their multicast trees are congruent under every placement, so
        # only the summed fire count matters.  Dest sets are compared
        # exactly as k-bit bitset rows.
        ne = eids.shape[0]
        ps = ps_all[eids]
        fire = hyper.hfire[eids].astype(np.float64)
        nb = (k + 63) // 64 if k else 1
        bits = np.zeros((ne, nb), dtype=np.uint64)
        row = np.repeat(np.arange(ne, dtype=np.int64), ecount)
        np.bitwise_or.at(
            bits, (row, dpart >> 6), np.uint64(1) << (dpart & 63).astype(np.uint64)
        )
        sig = np.concatenate([ps[:, None].astype(np.uint64), bits], axis=1)
        _, rep, inv = np.unique(sig, axis=0, return_index=True, return_inverse=True)
        t = rep.shape[0]
        self.tw = np.bincount(inv, weights=fire, minlength=t)
        self.tsrc = ps[rep]
        lens = ecount[rep]
        self.tptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        ent, _ = csr_gather(
            np.concatenate([[0], np.cumsum(ecount)]).astype(np.int64), rep
        )
        self.tdst = dpart[ent]
        self.num_hyperedges = t

        # CSR position -> incident hyperedge ids (source or destination).
        # Positions >= k (virtual partitions) have empty rows, so swaps
        # among them are free, exactly as the pairwise objective's
        # zero-padded traffic makes them.
        pos = np.concatenate([self.tsrc, self.tdst])
        eid = np.concatenate(
            [np.arange(t, dtype=np.int64), np.repeat(np.arange(t, dtype=np.int64), lens)]
        )
        order = np.argsort(pos, kind="stable")
        self.ilist = eid[order]
        iptr = np.zeros(num_cores + 1, dtype=np.int64)
        np.add.at(iptr, pos + 1, 1)
        self.iptr = np.cumsum(iptr)

        self._placement: np.ndarray | None = None
        self._sizes: np.ndarray | None = None
        self._total = 0.0
        # Last single-pair proposal scored by `swap_delta`: (a, b, edges,
        # their re-measured sizes).  `apply_swaps` of that same pair
        # reuses the measurement instead of paying the geometry twice —
        # the propose-then-commit pattern of the scalar SA chain.
        self._pending: tuple | None = None

    # -- geometry ----------------------------------------------------------
    def _tree_sizes(
        self, edges: np.ndarray, src_core: np.ndarray, dst_core: np.ndarray,
        inst: np.ndarray, n: int,
    ) -> np.ndarray:
        return multicast_tree_sizes(
            src_core, dst_core, inst, self.mesh_w, self.mesh_h, n
        )

    def _sizes_of(self, edges: np.ndarray, placement: np.ndarray) -> np.ndarray:
        """Tree-link count of each listed hyperedge under ``placement``."""
        ent, inst = csr_gather(self.tptr, edges)
        src_core = placement[self.tsrc[edges]][inst]
        dst_core = placement[self.tdst[ent]]
        return self._tree_sizes(edges, src_core, dst_core, inst, edges.shape[0])

    # -- stateless ---------------------------------------------------------
    def total(self, placement: np.ndarray) -> float:
        edges = np.arange(self.num_hyperedges, dtype=np.int64)
        return float((self.tw * self._sizes_of(edges, placement)).sum())

    # -- engine-facing incremental API ------------------------------------
    def attach(self, placement: np.ndarray) -> float:
        edges = np.arange(self.num_hyperedges, dtype=np.int64)
        self._placement = placement
        self._sizes = self._sizes_of(edges, placement)
        self._total = float((self.tw * self._sizes).sum())
        self._pending = None
        return self._total

    def _incident(self, positions: np.ndarray) -> np.ndarray:
        """Deduplicated hyperedges incident to any of ``positions``."""
        ent, _ = csr_gather(self.iptr, positions)
        return np.unique(self.ilist[ent])

    def swap_delta(self, a: int, b: int) -> float:
        e = self._incident(np.array([a, b], dtype=np.int64))
        if e.shape[0] == 0:
            self._pending = None
            return 0.0
        p2 = self._placement.copy()
        p2[a], p2[b] = p2[b], p2[a]
        new_sizes = self._sizes_of(e, p2)
        self._pending = (int(a), int(b), e, new_sizes)
        return float((self.tw[e] * (new_sizes - self._sizes[e])).sum())

    def swap_delta_batch(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        """(B,) independent candidate deltas against the attached placement.

        Re-measures only the hyperedges incident to each candidate's two
        positions — all candidates expanded into one flat (candidate,
        hyperedge, destination) replica list and measured by a single
        `multicast_tree_sizes` call.
        """
        aa = np.asarray(aa, dtype=np.int64)
        bb = np.asarray(bb, dtype=np.int64)
        nb = aa.shape[0]
        p = self._placement
        ea, ca = csr_gather(self.iptr, aa)
        eb, cb = csr_gather(self.iptr, bb)
        cand = np.concatenate([ca, cb])
        edges = self.ilist[np.concatenate([ea, eb])]
        # One evaluation per distinct (candidate, hyperedge): a hyperedge
        # incident to both swapped positions must not be counted twice.
        ukey = np.unique(cand * np.int64(self.num_hyperedges) + edges)
        if ukey.shape[0] == 0:
            return np.zeros(nb, dtype=np.float64)
        c, e = ukey // self.num_hyperedges, ukey % self.num_hyperedges
        ent, inst = csr_gather(self.tptr, e)
        # Each candidate's placement is the attached one with two entries
        # exchanged; materializing all B small rows once turns the member
        # core lookups into plain 2D gathers.
        pmat = np.broadcast_to(p, (nb, p.shape[0])).copy()
        rows = np.arange(nb)
        pmat[rows, aa] = p[bb]
        pmat[rows, bb] = p[aa]
        src_core = pmat[c, self.tsrc[e]][inst]
        dst_core = pmat[c[inst], self.tdst[ent]]
        new_sizes = self._tree_sizes(e, src_core, dst_core, inst, e.shape[0])
        deltas = np.zeros(nb, dtype=np.float64)
        np.add.at(deltas, c, self.tw[e] * (new_sizes - self._sizes[e]))
        return deltas

    def apply_swaps(self, pairs: np.ndarray, total_delta: float | None = None) -> float:
        """Commit position-disjoint swaps; re-measure incident trees once.

        Exact: hyperedges not incident to any swapped position keep their
        cached tree size, incident ones are re-measured under the final
        placement, so the returned total is the true cost — no incremental
        drift even though the batch was *scored* with per-candidate deltas.
        Committing the single pair `swap_delta` just scored reuses its
        measurement (``total_delta`` itself is ignored here: the size
        cache must be refreshed regardless, and the pending measurement
        already carries the delta).
        """
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        if pairs.shape[0] == 0:
            return self._total
        p = self._placement
        aa, bb = pairs[:, 0], pairs[:, 1]
        pending = self._pending
        self._pending = None
        if (pairs.shape[0] == 1 and pending is not None
                and pending[0] == int(aa[0]) and pending[1] == int(bb[0])):
            _, _, touched, new_sizes = pending
            p[aa], p[bb] = p[bb].copy(), p[aa].copy()
        else:
            p[aa], p[bb] = p[bb].copy(), p[aa].copy()
            touched = self._incident(np.concatenate([aa, bb]))
            new_sizes = (self._sizes_of(touched, p) if touched.shape[0]
                         else self._sizes[touched])
        if touched.shape[0]:
            self._total += float(
                (self.tw[touched] * (new_sizes - self._sizes[touched])).sum()
            )
            self._sizes[touched] = new_sizes
        return self._total


class MigrationAwareObjective:
    """Wrap a placement objective with per-position migration pricing.

    Used by the incremental re-mapper (`repro.core.remap`): the search
    starts from the *live* placement and every candidate is charged, on
    top of the base hop/tree-hop cost, for the neurons it would move:

      penalty(M) = sum_j  move_cost[j] * [M(j) != live(j)]
                 + forbid * sum_j [w_j > 0] * dead[M(j)]

    where ``move_cost[j] = migration_cost * move_weight[j]`` (the neuron
    count of partition j — virtual positions weigh zero, so parking them
    anywhere is free) and the ``forbid`` term makes placing a *real*
    partition on a failed core worse than any achievable hop gain while
    staying finite, so swap deltas remain exactly the difference of
    totals and the metamorphic delta tests hold on faulty meshes too.

    The wrapper satisfies the same engine contract as the base objective
    and shares the attached placement array with it (``attach`` binds the
    identical object to both), so the base's committed swaps are visible
    here without synchronization.  ``name`` is ``"mig+<base>"`` — never a
    bare objective name, so reporting paths that special-case
    ``"pairwise"``/``"tree"`` re-score through a clean objective instead
    of leaking the penalty into avg_hop.
    """

    def __init__(
        self,
        base,
        live_placement: np.ndarray,
        move_weight: np.ndarray,
        migration_cost: float,
        dead_cores: np.ndarray | None = None,
        forbid_penalty: float = 0.0,
    ):
        n = base.num_positions
        live = np.asarray(live_placement, dtype=np.int64)
        if live.shape[0] != n:
            raise ValueError(
                f"live placement covers {live.shape[0]} != {n} positions"
            )
        w = np.zeros(n, dtype=np.float64)
        mw = np.asarray(move_weight, dtype=np.float64)
        w[: mw.shape[0]] = mw
        self.base = base
        self.name = f"mig+{base.name}"
        self.num_positions = n
        self.num_partitions = base.num_partitions
        self.live = live.copy()
        self.move_cost = w * float(migration_cost)
        self.real = w > 0
        self.dead = (
            np.zeros(n, dtype=bool) if dead_cores is None
            else np.asarray(dead_cores, dtype=bool).copy()
        )
        self.forbid_penalty = float(forbid_penalty)
        self._placement: np.ndarray | None = None
        self._pen_total = 0.0

    # -- penalty geometry --------------------------------------------------
    def _pen(self, pos: np.ndarray, core: np.ndarray) -> np.ndarray:
        """Penalty of placing partition(s) ``pos`` on core(s) ``core``."""
        moved = self.move_cost[pos] * (core != self.live[pos])
        forbid = self.forbid_penalty * (self.real[pos] & self.dead[core])
        return moved + forbid

    def penalty_total(self, placement: np.ndarray) -> float:
        pos = np.arange(placement.shape[0], dtype=np.int64)
        return float(self._pen(pos, placement).sum())

    # -- stateless ---------------------------------------------------------
    def total(self, placement: np.ndarray) -> float:
        return self.base.total(placement) + self.penalty_total(placement)

    # -- engine-facing incremental API ------------------------------------
    def attach(self, placement: np.ndarray) -> float:
        base_total = self.base.attach(placement)
        self._placement = self.base._placement
        self._pen_total = self.penalty_total(self._placement)
        return base_total + self._pen_total

    def _swap_pen_delta(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        p = self._placement
        return (
            self._pen(aa, p[bb]) + self._pen(bb, p[aa])
            - self._pen(aa, p[aa]) - self._pen(bb, p[bb])
        )

    def swap_delta(self, a: int, b: int) -> float:
        aa = np.array([a], dtype=np.int64)
        bb = np.array([b], dtype=np.int64)
        return self.base.swap_delta(a, b) + float(self._swap_pen_delta(aa, bb)[0])

    def swap_delta_batch(self, aa: np.ndarray, bb: np.ndarray) -> np.ndarray:
        aa = np.asarray(aa, dtype=np.int64)
        bb = np.asarray(bb, dtype=np.int64)
        return self.base.swap_delta_batch(aa, bb) + self._swap_pen_delta(aa, bb)

    def apply_swaps(self, pairs: np.ndarray, total_delta: float | None = None) -> float:
        # The engine's total_delta includes the penalty part, which the
        # base must not fold into its hop total — commit through the base
        # with its own exact accounting and refresh the O(K) penalty.
        base_total = self.base.apply_swaps(pairs)
        self._pen_total = self.penalty_total(self._placement)
        return base_total + self._pen_total


PLACE_OBJECTIVES = ("pairwise", "tree")


def make_objective(
    kind: str,
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    mesh_h: int | None = None,
    torus: bool = False,
    hyper: Hypergraph | None = None,
    part: np.ndarray | None = None,
):
    """Build a placement objective by name.

    ``"pairwise"`` needs only the (k, k) traffic matrix; ``"tree"``
    additionally needs the profiled multicast hypergraph and the partition
    vector (to form destination-partition sets), and is mesh-only (XY
    trees have no torus form).
    """
    if kind == "pairwise":
        return PairwiseObjective(traffic, num_cores, mesh_w, torus=torus)
    if kind == "tree":
        if hyper is None or part is None:
            raise ValueError("tree objective needs hyper= and part=")
        if torus:
            raise ValueError("tree objective is mesh-only (no torus XY trees)")
        return TreeHopObjective(hyper, part, num_cores, mesh_w, mesh_h)
    raise ValueError(f"unknown placement objective {kind!r}")


def evaluate_placement(
    placement: np.ndarray,
    traffic: np.ndarray,
    num_cores: int,
    mesh_w: int,
    trace_length: int,
    mesh_h: int | None = None,
    hyper: Hypergraph | None = None,
    part: np.ndarray | None = None,
    torus: bool = False,
    reuse=None,
) -> tuple[float, float | None]:
    """Score a finished placement under both objectives: (avg_hop, tree_hop).

    The one reporting path every toolchain method goes through (SA/tabu/PSO
    searches, device mappers, and SCO's sequential placement alike), so
    cross-method comparisons are never an artifact of who computed the
    metric.  ``avg_hop`` is the paper's Eq. 2 average (pairwise hops per
    packet of the run's traffic model); ``tree_hop`` is the multicast
    tree-link traversals per packet under the same normalization, or None
    when no hypergraph is available (or on torus meshes, which have no XY
    trees).  ``reuse`` accepts an already-built objective instance (either
    kind — e.g. the one that drove the search) so its construction cost is
    not paid twice; scoring through it is stateless.
    """
    placement = np.asarray(placement, dtype=np.int64)
    denom = max(trace_length, 1)
    pw = (reuse if reuse is not None and reuse.name == "pairwise"
          else PairwiseObjective(traffic, num_cores, mesh_w, torus=torus))
    avg_hop = pw.total(placement) / denom
    tree_hop = None
    if reuse is not None and reuse.name == "tree":
        tree_hop = reuse.total(placement) / denom
    elif hyper is not None and part is not None and not torus:
        tree = TreeHopObjective(hyper, part, num_cores, mesh_w, mesh_h)
        tree_hop = tree.total(placement) / denom
    return avg_hop, tree_hop

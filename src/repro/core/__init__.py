"""SNEAP core: the paper's contribution.

Partitioning (multilevel graph/hypergraph partitioning minimizing either
inter-partition spikes or multicast communication volume), mapping
(SA/PSO/Tabu placement minimizing average hop under XY routing), analytic
hop evaluation (Algorithm 1), baselines (SpiNeMap, SCO), and the
end-to-end toolchain pipeline.
"""
from .baselines import greedy_kl_partition, sco_partition, sco_place
from .graph import (
    Graph,
    Hypergraph,
    build_graph,
    build_hypergraph,
    comm_volume,
    dedup_hyperedges,
    edge_cut,
    partition_weights,
    validate_partition,
    volume_degrees,
)
from .hopcost import (
    average_hop,
    core_coords,
    hop_distance_matrix,
    swap_delta,
    swap_delta_batch,
    traffic_matrix,
)
from .mapping import (
    MAPPERS,
    OBJECTIVE_AWARE_MAPPERS,
    MappingResult,
    pso_search,
    sa_search,
    tabu_search,
)
from .partition import PartitionResult, sneap_partition
from .pipeline import (
    ToolchainConfig,
    ToolchainResult,
    evaluate_phase,
    mapping_phase,
    partition_phase,
    phase_seeds,
    run_toolchain,
)
from .placecost import (
    PLACE_OBJECTIVES,
    MigrationAwareObjective,
    PairwiseObjective,
    TreeHopObjective,
    evaluate_placement,
    make_objective,
    validate_objective,
)
from .remap import (
    RemapResult,
    check_degraded_capacity,
    evict_dead_partitions,
    incremental_remap,
    scratch_remap,
)

__all__ = [
    "Graph", "Hypergraph", "build_graph", "build_hypergraph",
    "dedup_hyperedges", "edge_cut", "comm_volume", "volume_degrees",
    "partition_weights", "validate_partition",
    "average_hop", "core_coords", "hop_distance_matrix", "swap_delta",
    "swap_delta_batch", "traffic_matrix",
    "MAPPERS", "OBJECTIVE_AWARE_MAPPERS", "MappingResult",
    "pso_search", "sa_search", "tabu_search",
    "PLACE_OBJECTIVES", "PairwiseObjective", "TreeHopObjective",
    "MigrationAwareObjective", "evaluate_placement", "make_objective",
    "validate_objective",
    "RemapResult", "check_degraded_capacity", "evict_dead_partitions",
    "incremental_remap", "scratch_remap",
    "PartitionResult", "sneap_partition",
    "greedy_kl_partition", "sco_partition", "sco_place",
    "ToolchainConfig", "ToolchainResult", "run_toolchain",
    "phase_seeds", "partition_phase", "mapping_phase", "evaluate_phase",
]

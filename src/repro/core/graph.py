"""Spike-weighted SNN graphs in CSR form.

The profiling phase (``repro.snn.simulate``) produces an undirected graph
G(N, S): vertices are neurons, an edge (i, j) carries the number of spikes
communicated on the synapse between i and j during the profiled window
(paper §3.2).  All partitioning machinery operates on this CSR structure.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["Graph", "build_graph", "edge_cut", "partition_weights", "validate_partition"]


@dataclass
class Graph:
    """Undirected weighted graph in CSR (symmetric adjacency, both directions stored).

    Attributes:
      xadj:   (n+1,) int64 — CSR row offsets.
      adjncy: (m,)   int32 — neighbor indices (each undirected edge appears twice).
      adjwgt: (m,)   int64 — edge weights (spike counts).
      vwgt:   (n,)   int64 — vertex weights (neuron multiplicity; 1 at level 0).
    """

    xadj: np.ndarray
    adjncy: np.ndarray
    adjwgt: np.ndarray
    vwgt: np.ndarray
    # Maps each vertex of this (coarse) graph back to vertices of the parent
    # finer graph; None at level 0.
    cmap: np.ndarray | None = field(default=None, repr=False)

    @property
    def num_vertices(self) -> int:
        return int(self.vwgt.shape[0])

    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return int(self.adjncy.shape[0] // 2)

    @property
    def total_vwgt(self) -> int:
        return int(self.vwgt.sum())

    @property
    def total_adjwgt(self) -> int:
        """Sum of edge weights (each undirected edge counted once)."""
        return int(self.adjwgt.sum() // 2)

    def degree(self, v: int) -> int:
        return int(self.xadj[v + 1] - self.xadj[v])

    def neighbors(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.xadj[v], self.xadj[v + 1]
        return self.adjncy[s:e], self.adjwgt[s:e]


def build_graph(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,
    vwgt: np.ndarray | None = None,
) -> Graph:
    """Build a symmetric CSR graph from weighted (src, dst, weight) edge triples.

    Duplicate (src, dst) pairs are merged by summing weights; self-loops are
    dropped (a neuron's spike to itself never crosses the NoC).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    weight = np.asarray(weight, dtype=np.int64)
    keep = src != dst
    src, dst, weight = src[keep], dst[keep], weight[keep]

    # Canonicalize each undirected edge to (min, max) and merge duplicates.
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    key = lo * num_vertices + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, weight = key[order], lo[order], hi[order], weight[order]
    uniq, start = np.unique(key, return_index=True)
    merged_w = np.add.reduceat(weight, start) if len(key) else weight
    lo, hi = lo[start], hi[start]

    # Expand to both directions and sort by source for CSR.
    all_src = np.concatenate([lo, hi])
    all_dst = np.concatenate([hi, lo])
    all_w = np.concatenate([merged_w, merged_w])
    order = np.argsort(all_src, kind="stable")
    all_src, all_dst, all_w = all_src[order], all_dst[order], all_w[order]

    xadj = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(xadj, all_src + 1, 1)
    xadj = np.cumsum(xadj)
    if vwgt is None:
        vwgt = np.ones(num_vertices, dtype=np.int64)
    return Graph(
        xadj=xadj,
        adjncy=all_dst.astype(np.int32),
        adjwgt=all_w.astype(np.int64),
        vwgt=np.asarray(vwgt, dtype=np.int64),
    )


def edge_cut(graph: Graph, part: np.ndarray) -> int:
    """Sum of weights of edges whose endpoints lie in different partitions.

    This is the partitioning objective: the number of spikes communicated
    *between* partitions (paper §3.3, "global traffic").
    """
    src = np.repeat(np.arange(graph.num_vertices), np.diff(graph.xadj))
    cut_mask = part[src] != part[graph.adjncy]
    return int(graph.adjwgt[cut_mask].sum() // 2)


def partition_weights(graph: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """(k,) vertex weight (neuron count) per partition."""
    w = np.zeros(k, dtype=np.int64)
    np.add.at(w, part, graph.vwgt)
    return w


def validate_partition(graph: Graph, part: np.ndarray, k: int, capacity: int) -> None:
    """Raise if `part` is not a valid k-way partition within core capacity."""
    if part.shape != (graph.num_vertices,):
        raise ValueError(f"partition vector shape {part.shape} != ({graph.num_vertices},)")
    if part.min() < 0 or part.max() >= k:
        raise ValueError(f"partition ids outside [0, {k})")
    w = partition_weights(graph, part, k)
    if (w > capacity).any():
        bad = np.nonzero(w > capacity)[0]
        raise ValueError(f"partitions {bad.tolist()} exceed capacity {capacity}: {w[bad].tolist()}")

"""Spike-weighted SNN graphs in CSR form — plus the multicast hypergraph.

The profiling phase (``repro.snn.simulate``) produces two views of the same
traffic:

* an undirected graph G(N, S): vertices are neurons, an edge (i, j) carries
  the number of spikes communicated on the synapse between i and j during
  the profiled window (paper §3.2).  ``edge_cut`` over this graph is the
  classic partitioning objective — it counts every cut *synapse*.
* a hypergraph H(N, E): one hyperedge per firing neuron, holding its
  destination pin set with per-pin spike counts.  On a real NoC a neuron
  whose spikes fan out to d destination cores injects one multicast packet
  replicated along at most d branches — not d independent unicasts — so the
  matching objective is the hMETIS-style connectivity-(λ−1) communication
  volume ``comm_volume``: each source pays its fire count once per *distinct*
  remote destination partition, not once per cut synapse.

On pure unicast traffic (every source has exactly one pin) the two
objectives coincide; on fan-out-heavy SNNs edge-cut over-counts multicast
packets and the partitioner optimizes a different quantity than the NoC
simulator measures.  All partitioning machinery accepts either objective
(see ``repro.core.partition``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Graph",
    "Hypergraph",
    "IndexCapacityError",
    "check_index_capacity",
    "ShardedGraphView",
    "build_graph",
    "build_hypergraph",
    "dedup_hyperedges",
    "edge_cut",
    "comm_volume",
    "comm_volume_sharded",
    "volume_degrees",
    "presence_degrees",
    "edge_partition_counts",
    "csr_gather",
    "grouped_admission",
    "partition_weights",
    "validate_partition",
]


class IndexCapacityError(ValueError):
    """A graph/hypergraph shape exceeds what the index dtypes can address.

    Vertex ids are stored int32 (``adjncy``/``hpins``/``hsrc``); packed
    (row, column) keys — ``edge * k + part`` and friends — are int64.  Past
    those bounds arithmetic would wrap *silently*, so the builders raise
    this named error at the boundary instead.  Checks are pure shape math:
    no allocation happens before the raise.
    """


_INT32_MAX = np.iinfo(np.int32).max
_INT64_MAX = np.iinfo(np.int64).max


def check_index_capacity(
    num_vertices: int,
    num_hyperedges: int = 0,
    k: int = 1,
) -> None:
    """Raise :class:`IndexCapacityError` if shapes overflow the index dtypes.

    Guards (shape math only, no allocation):
      * vertex ids must fit int32 — ``adjncy``/``hpins``/``hsrc`` store them
        as int32 and a 2^31-th vertex would wrap negative;
      * canonical edge keys ``lo * n + hi`` must fit int64;
      * packed Φ keys ``edge * k + part`` must fit int64 (k up to the
        partition count, edges up to max(n, E)).
    """
    n = int(num_vertices)
    ne = max(int(num_hyperedges), n)
    if n > _INT32_MAX:
        raise IndexCapacityError(
            f"num_vertices={n} exceeds int32 vertex-id capacity "
            f"({_INT32_MAX}); adjncy/hpins/hsrc store int32 ids"
        )
    if n and n > _INT64_MAX // max(n, 1):
        raise IndexCapacityError(
            f"num_vertices={n}: edge keys lo*n+hi overflow int64"
        )
    if k and ne > _INT64_MAX // max(int(k), 1):
        raise IndexCapacityError(
            f"{ne} edges x k={k} partitions: packed keys edge*k+part "
            "overflow int64"
        )


def csr_gather(xadj: np.ndarray, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Gather the CSR entry indices of ``rows``: (entry index, local row id).

    The ranges-to-indices expansion shared by every CSR consumer: start of
    each row repeated, plus a within-row ramp.
    """
    counts = (xadj[rows + 1] - xadj[rows]).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    starts = np.repeat(xadj[rows], counts)
    ramp = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    local = np.repeat(np.arange(rows.shape[0], dtype=np.int64), counts)
    return starts + ramp, local


def grouped_admission(
    groups: np.ndarray, weights: np.ndarray, headroom: np.ndarray
) -> np.ndarray:
    """Admit entries per group while their cumulative weight fits.

    Entries must arrive pre-sorted by group (then by admission priority
    within each group); ``headroom[g]`` is group g's remaining capacity.
    Returns a boolean admit mask: within each group, the longest prefix
    whose running weight stays within headroom — the grouped-cumsum
    admission step shared by the batched refiner and the vectorized
    region grower.
    """
    m = groups.shape[0]
    if m == 0:
        return np.zeros(0, dtype=bool)
    cw = np.cumsum(weights)
    new_grp = np.empty(m, dtype=bool)
    new_grp[0] = True
    new_grp[1:] = groups[1:] != groups[:-1]
    grp_starts = np.nonzero(new_grp)[0]
    grp_sizes = np.diff(np.append(grp_starts, m))
    within = cw - np.repeat(cw[grp_starts] - weights[grp_starts], grp_sizes)
    return within <= headroom[groups]


@dataclass
class Graph:
    """Undirected weighted graph in CSR (symmetric adjacency, both directions stored).

    Attributes:
      xadj:   (n+1,) int64 — CSR row offsets.
      adjncy: (m,)   int32 — neighbor indices (each undirected edge appears twice).
      adjwgt: (m,)   int64 — edge weights (spike counts).
      vwgt:   (n,)   int64 — vertex weights (neuron multiplicity; 1 at level 0).
    """

    xadj: np.ndarray
    adjncy: np.ndarray
    adjwgt: np.ndarray
    vwgt: np.ndarray
    # Maps each vertex of this (coarse) graph back to vertices of the parent
    # finer graph; None at level 0.
    cmap: np.ndarray | None = field(default=None, repr=False)
    # Multicast hyperedge view of the same traffic; contracted alongside the
    # graph during coarsening when present.
    hyper: "Hypergraph | None" = field(default=None, repr=False)
    _edge_src: np.ndarray | None = field(default=None, repr=False, compare=False)

    @property
    def num_vertices(self) -> int:
        return int(self.vwgt.shape[0])

    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return int(self.adjncy.shape[0] // 2)

    @property
    def total_vwgt(self) -> int:
        return int(self.vwgt.sum())

    @property
    def total_adjwgt(self) -> int:
        """Sum of edge weights (each undirected edge counted once)."""
        return int(self.adjwgt.sum() // 2)

    @property
    def edge_src(self) -> np.ndarray:
        """(m,) int64 CSR row index of each directed edge, computed lazily once.

        Hot loops (edge cut, batched refinement, contraction) all need the
        ``np.repeat`` source expansion; caching it here makes those calls
        O(m) gathers instead of re-materializing the expansion every time.
        """
        if self._edge_src is None:
            self._edge_src = np.repeat(
                np.arange(self.num_vertices, dtype=np.int64), np.diff(self.xadj)
            )
        return self._edge_src

    def degree(self, v: int) -> int:
        return int(self.xadj[v + 1] - self.xadj[v])

    def neighbors(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.xadj[v], self.xadj[v + 1]
        return self.adjncy[s:e], self.adjwgt[s:e]


@dataclass
class Hypergraph:
    """Multicast traffic in CSR form: hyperedge e = source ``hsrc[e]`` + pins.

    One hyperedge per source neuron with outgoing synapses.  ``hpins`` holds
    the destination vertices (deduplicated per hyperedge, never equal to the
    source), ``hwgt`` the spikes delivered to each pin over the window, and
    ``hfire`` the source's fire count — the number of multicast packets the
    source injects toward each distinct destination partition.

    The connectivity objective weighs hyperedges by ``hfire`` alone;
    ``hwgt`` is the per-destination delivered-spike ledger (a pin that
    absorbs several parallel synapses carries their sum), kept so coarse
    levels preserve delivered-spike totals exactly — external deliveries
    are conserved under contraction and only pins collapsing into their
    source (core-local deliveries) leave the ledger.

    Attributes:
      hxadj: (E+1,) int64 — CSR offsets into hpins/hwgt.
      hpins: (P,)   int32 — destination vertex ids.
      hwgt:  (P,)   int64 — spikes delivered to that pin.
      hsrc:  (E,)   int32 — source vertex of each hyperedge.
      hfire: (E,)   int64 — spikes fired by the source (hyperedge weight).
    """

    hxadj: np.ndarray
    hpins: np.ndarray
    hwgt: np.ndarray
    hsrc: np.ndarray
    hfire: np.ndarray
    num_vertices: int
    _pin_edge: np.ndarray | None = field(default=None, repr=False, compare=False)
    _incidence: tuple[np.ndarray, np.ndarray] | None = field(
        default=None, repr=False, compare=False
    )

    @property
    def num_hyperedges(self) -> int:
        return int(self.hsrc.shape[0])

    @property
    def num_pins(self) -> int:
        return int(self.hpins.shape[0])

    @property
    def pin_edge(self) -> np.ndarray:
        """(P,) int64 hyperedge id of each pin (cached CSR row expansion)."""
        if self._pin_edge is None:
            self._pin_edge = np.repeat(
                np.arange(self.num_hyperedges, dtype=np.int64), np.diff(self.hxadj)
            )
        return self._pin_edge

    def incidence(self) -> tuple[np.ndarray, np.ndarray]:
        """Vertex → hyperedge CSR: (vxadj (n+1,), vedges) listing, for every
        vertex, the hyperedges it belongs to (as source or pin).

        Pins never equal their source and are deduplicated per hyperedge, so
        each (vertex, hyperedge) membership appears exactly once.
        """
        if self._incidence is None:
            n = self.num_vertices
            verts = np.concatenate(
                [self.hpins.astype(np.int64), self.hsrc.astype(np.int64)]
            )
            edges = np.concatenate(
                [self.pin_edge, np.arange(self.num_hyperedges, dtype=np.int64)]
            )
            order = np.argsort(verts, kind="stable")
            verts, edges = verts[order], edges[order]
            vxadj = np.zeros(n + 1, dtype=np.int64)
            np.add.at(vxadj, verts + 1, 1)
            self._incidence = (np.cumsum(vxadj), edges)
        return self._incidence

    def members(self, e: int) -> np.ndarray:
        """All vertices of hyperedge e: the source followed by its pins."""
        s, t = self.hxadj[e], self.hxadj[e + 1]
        return np.concatenate([[self.hsrc[e]], self.hpins[s:t]])

    def validate(self, check_dedup: bool = False) -> None:
        """Raise if the structural invariants every consumer relies on fail.

        Always checked: CSR offsets well-formed, array shapes consistent,
        vertex ids in range, pins strictly increasing within each hyperedge
        (which implies per-edge pin dedup), no pin equal to its source, and
        non-negative weights.  ``check_dedup=True`` additionally asserts no
        two hyperedges share the same (source, pin set) — the invariant
        ``dedup_hyperedges`` establishes and contraction preserves.
        """
        ne, p, n = self.num_hyperedges, self.num_pins, self.num_vertices
        if self.hxadj.shape != (ne + 1,) or self.hxadj[0] != 0:
            raise ValueError("hxadj must be (E+1,) starting at 0")
        if int(self.hxadj[-1]) != p or (np.diff(self.hxadj) < 0).any():
            raise ValueError("hxadj must increase monotonically to num_pins")
        if self.hwgt.shape != (p,) or self.hfire.shape != (ne,):
            raise ValueError("hwgt/hfire shapes inconsistent with pins/edges")
        if p and not (0 <= int(self.hpins.min()) <= int(self.hpins.max()) < n):
            raise ValueError("pin ids outside [0, num_vertices)")
        if ne and not (0 <= int(self.hsrc.min()) <= int(self.hsrc.max()) < n):
            raise ValueError("source ids outside [0, num_vertices)")
        if (self.hwgt < 0).any() or (self.hfire < 0).any():
            raise ValueError("negative hyperedge weights")
        pe = self.pin_edge
        if (self.hpins == self.hsrc[pe]).any():
            raise ValueError("pin equals its hyperedge's source")
        interior = np.ones(p, dtype=bool)
        if p:
            starts = self.hxadj[:-1]
            interior[starts[starts < p]] = False  # first pin of each edge
        if (np.diff(self.hpins.astype(np.int64), prepend=-1)[interior] <= 0).any():
            raise ValueError("pins not strictly increasing within a hyperedge")
        if check_dedup and ne > 1:
            deduped = dedup_hyperedges(self)
            if deduped.num_hyperedges != ne:
                raise ValueError(
                    f"{ne - deduped.num_hyperedges} duplicate (source, pin set) "
                    "hyperedges present"
                )


def build_hypergraph(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    fire_counts: np.ndarray,
) -> Hypergraph:
    """Build the multicast hypergraph from directed synapse (src, dst) pairs.

    One hyperedge per distinct source with at least one non-self pin; pin
    weights are the source's fire count (spikes delivered on that synapse),
    duplicates merged by summing.
    """
    check_index_capacity(num_vertices)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    fire_counts = np.asarray(fire_counts, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    key = src * num_vertices + dst
    uniq, counts = np.unique(key, return_counts=True)
    usrc = uniq // num_vertices
    upin = uniq % num_vertices
    uwgt = fire_counts[usrc] * counts  # duplicate synapses merge

    esrc, estart = np.unique(usrc, return_index=True)
    hxadj = np.concatenate([estart, [usrc.shape[0]]]).astype(np.int64)
    return Hypergraph(
        hxadj=hxadj,
        hpins=upin.astype(np.int32),
        hwgt=uwgt.astype(np.int64),
        hsrc=esrc.astype(np.int32),
        hfire=fire_counts[esrc].astype(np.int64),
        num_vertices=num_vertices,
    )


# Distinct splitmix64 seeds for the two independent pin-set hashes below.
_DEDUP_SEED_1 = np.uint64(0x9E3779B97F4A7C15)
_DEDUP_SEED_2 = np.uint64(0xD1B54A32D192ED03)


def _mix64(x: np.ndarray, seed: np.uint64) -> np.ndarray:
    """splitmix64 finalizer over uint64 values (vectorized, wrapping)."""
    z = x + seed
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def dedup_hyperedges(hyper: Hypergraph) -> Hypergraph:
    """Merge hyperedges with identical (source, pin set), summing weights.

    Two hyperedges with the same source and the same pin set have identical
    member sets, so they span the same partitions under *every* partition
    vector: merging them while summing ``hfire`` (and per-pin ``hwgt``)
    preserves ``comm_volume``, ``volume_degrees``, and the delivered-spike
    ledger exactly.  Contraction mass-produces such duplicates on structured
    SNNs (every source in a dense layer ends up with the same coarse pin
    set), and each duplicate removed shrinks the Φ table and every λ-gain
    evaluation at that level — see ``coarsen.contract_hypergraph``.

    Identity is established exactly: edges are grouped by (source, degree,
    two independent 64-bit pin-set hashes) and neighbors in the sorted
    order are verified pin-by-pin before merging, so a hash collision can
    only ever *miss* a merge, never create a wrong one.  Relies on pins
    being sorted within each hyperedge (a ``Hypergraph`` invariant; see
    ``validate``).  Surviving edges keep the first-occurrence order of
    their group's lowest original edge id, so the result is deterministic.
    """
    ne = hyper.num_hyperedges
    # Duplicates need at least two hyperedges sharing a source.
    if ne <= 1 or np.unique(hyper.hsrc).shape[0] == ne:
        return hyper
    d = np.diff(hyper.hxadj)
    pins64 = hyper.hpins.astype(np.uint64)
    h1 = np.zeros(ne, dtype=np.uint64)
    h2 = np.zeros(ne, dtype=np.uint64)
    nonempty = np.nonzero(d > 0)[0]
    if nonempty.shape[0]:
        starts = hyper.hxadj[:-1][nonempty]
        h1[nonempty] = np.add.reduceat(_mix64(pins64, _DEDUP_SEED_1), starts)
        h2[nonempty] = np.add.reduceat(_mix64(pins64, _DEDUP_SEED_2), starts)
    order = np.lexsort((h2, h1, d, hyper.hsrc))
    src_o, d_o = hyper.hsrc[order], d[order]
    same = np.zeros(ne, dtype=bool)
    same[1:] = (
        (src_o[1:] == src_o[:-1]) & (d_o[1:] == d_o[:-1])
        & (h1[order][1:] == h1[order][:-1]) & (h2[order][1:] == h2[order][:-1])
    )
    if same.any():
        # Verify candidate pairs pin-by-pin (positions align: equal degree,
        # both sorted).  A mismatching pair starts a new group instead.
        ci = np.nonzero(same)[0]
        ia, _ = csr_gather(hyper.hxadj, order[ci - 1])
        ib, _ = csr_gather(hyper.hxadj, order[ci])
        cnt = d[order[ci]]
        nz = np.nonzero(cnt > 0)[0]
        if nz.shape[0]:
            pos = (np.cumsum(cnt) - cnt)[nz]
            mism = np.add.reduceat(hyper.hpins[ia] != hyper.hpins[ib], pos)
            same[ci[nz[mism > 0]]] = False
    if not same.any():
        return hyper

    grp = np.cumsum(~same) - 1  # group id per sorted position
    ngrp = int(grp[-1]) + 1
    # Representative of each group: its lowest original edge id (keeps the
    # output order stable under permutations of the input).
    rep = np.full(ngrp, ne, dtype=np.int64)
    np.minimum.at(rep, grp, order)
    hfire_new = np.zeros(ngrp, dtype=np.int64)
    np.add.at(hfire_new, grp, hyper.hfire[order])

    perm = np.argsort(rep, kind="stable")  # group -> output rank
    rank = np.empty(ngrp, dtype=np.int64)
    rank[perm] = np.arange(ngrp)
    rep_out = rep[perm]
    out_d = d[rep_out]
    hxadj_new = np.concatenate([[0], np.cumsum(out_d)]).astype(np.int64)

    # Scatter every member's pins into its group's output rows; pin j of a
    # member aligns with pin j of the representative, so hwgt sums
    # positionwise and hpins writes are idempotent across members.
    idx, local = csr_gather(hyper.hxadj, order)
    within = idx - np.repeat(hyper.hxadj[:-1][order], d[order])
    out_pos = hxadj_new[:-1][rank[grp[local]]] + within
    total = int(hxadj_new[-1])
    hwgt_new = np.zeros(total, dtype=np.int64)
    np.add.at(hwgt_new, out_pos, hyper.hwgt[idx])
    hpins_new = np.zeros(total, dtype=np.int32)
    hpins_new[out_pos] = hyper.hpins[idx]
    return Hypergraph(
        hxadj=hxadj_new,
        hpins=hpins_new,
        hwgt=hwgt_new,
        hsrc=hyper.hsrc[rep_out],
        hfire=hfire_new[perm],
        num_vertices=hyper.num_vertices,
    )


def build_graph(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,
    vwgt: np.ndarray | None = None,
) -> Graph:
    """Build a symmetric CSR graph from weighted (src, dst, weight) edge triples.

    Duplicate (src, dst) pairs are merged by summing weights; self-loops are
    dropped (a neuron's spike to itself never crosses the NoC).
    """
    check_index_capacity(num_vertices)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    weight = np.asarray(weight, dtype=np.int64)
    keep = src != dst
    src, dst, weight = src[keep], dst[keep], weight[keep]

    # Canonicalize each undirected edge to (min, max) and merge duplicates.
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    key = lo * num_vertices + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, weight = key[order], lo[order], hi[order], weight[order]
    uniq, start = np.unique(key, return_index=True)
    merged_w = np.add.reduceat(weight, start) if len(key) else weight
    lo, hi = lo[start], hi[start]

    # Expand to both directions and sort by source for CSR.
    all_src = np.concatenate([lo, hi])
    all_dst = np.concatenate([hi, lo])
    all_w = np.concatenate([merged_w, merged_w])
    order = np.argsort(all_src, kind="stable")
    all_src, all_dst, all_w = all_src[order], all_dst[order], all_w[order]

    xadj = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(xadj, all_src + 1, 1)
    xadj = np.cumsum(xadj)
    if vwgt is None:
        vwgt = np.ones(num_vertices, dtype=np.int64)
    return Graph(
        xadj=xadj,
        adjncy=all_dst.astype(np.int32),
        adjwgt=all_w.astype(np.int64),
        vwgt=np.asarray(vwgt, dtype=np.int64),
    )


def edge_cut(graph: Graph, part: np.ndarray) -> int:
    """Sum of weights of edges whose endpoints lie in different partitions.

    The classic partitioning objective: the number of spikes communicated
    *between* partitions counted once per cut synapse (paper §3.3, "global
    traffic").  Over-counts multicast packets on fan-out traffic — see
    ``comm_volume`` for the NoC-faithful alternative.
    """
    cut_mask = part[graph.edge_src] != part[graph.adjncy]
    return int(graph.adjwgt[cut_mask].sum() // 2)


def comm_volume(hyper: Hypergraph, part: np.ndarray) -> int:
    """Connectivity-(λ−1) communication volume of a partition.

    For each hyperedge e let λ(e) be the number of distinct partitions its
    members (source + pins) span; the volume is sum_e hfire[e] * (λ(e) − 1):
    each firing injects one multicast packet per distinct partition beyond
    the source's own.  Equals ``edge_cut`` on pure unicast hypergraphs.
    """
    part = np.asarray(part, dtype=np.int64)
    ne = hyper.num_hyperedges
    if ne == 0:
        return 0
    k = int(part.max()) + 1
    check_index_capacity(hyper.num_vertices, ne, k)
    keys = np.concatenate(
        [
            hyper.pin_edge * k + part[hyper.hpins],
            np.arange(ne, dtype=np.int64) * k + part[hyper.hsrc],
        ]
    )
    uniq = np.unique(keys)
    lam = np.bincount(uniq // k, minlength=ne)
    return int((hyper.hfire * (lam - 1)).sum())


class ShardedGraphView:
    """Vertex-block sharded view of a :class:`Graph` (and its hypergraph).

    Built from a ``VertexShardPlan`` (``repro.sharding.planner``) — here the
    plan is duck-typed (``bounds``/``num_shards``/``block``) so the numpy
    core never imports jax.  Each shard owns a contiguous vertex block;
    because CSR rows are contiguous, a shard's adjacency slice
    ``adjncy[xadj[lo]:xadj[hi]]`` is a zero-copy view.  The view's job is
    the *halo* bookkeeping: for each shard, the set of non-local vertices
    whose partition labels the shard's gain evaluations read.  Halos are
    static (they depend on structure, not on the partition), so they are
    computed once and the per-round "halo exchange" is a single gather of
    ``part`` at the halo indices.

    ``local_part`` assembles a full-length partition array holding only
    block + halo values, everything else poisoned with ``fill`` — any
    evaluation that reads outside its declared halo hits the poison and
    fails loudly, which is how the metamorphic tests prove halo
    sufficiency.
    """

    def __init__(self, graph: Graph, plan) -> None:
        self.graph = graph
        self.plan = plan
        self._halos: dict[tuple[int, str], np.ndarray] = {}

    @property
    def num_shards(self) -> int:
        return self.plan.num_shards

    def halo(self, s: int, mode: str = "cut") -> np.ndarray:
        """Sorted non-local vertex ids shard ``s`` reads (computed once).

        ``mode="cut"``: neighbors of the block across graph edges.
        ``mode="volume"``: co-members (source + pins) of every hyperedge
        incident to the block — the multicast pin halo.
        ``mode="local"``: empty — for evaluations that read only
        block-local labels (e.g. D* rows from a live Φ table).
        """
        key = (s, mode)
        if key not in self._halos:
            lo, hi = self.plan.block(s)
            g = self.graph
            if mode == "local":
                self._halos[key] = np.empty(0, dtype=np.int64)
                return self._halos[key]
            if mode == "cut":
                ext = np.unique(g.adjncy[g.xadj[lo]:g.xadj[hi]].astype(np.int64))
            elif mode == "volume":
                hyper = g.hyper
                if hyper is None:
                    raise ValueError("volume halo needs graph.hyper")
                vxadj, vedges = hyper.incidence()
                ue = np.unique(vedges[vxadj[lo]:vxadj[hi]])
                if ue.shape[0]:
                    pidx, _ = csr_gather(hyper.hxadj, ue)
                    ext = np.unique(np.concatenate([
                        hyper.hpins[pidx].astype(np.int64),
                        hyper.hsrc[ue].astype(np.int64),
                    ]))
                else:
                    ext = np.empty(0, dtype=np.int64)
            else:
                raise ValueError(f"unknown halo mode {mode!r}")
            self._halos[key] = ext[(ext < lo) | (ext >= hi)]
        return self._halos[key]

    def local_part(self, s: int, part: np.ndarray, mode: str = "cut",
                   fill: int = -1) -> np.ndarray:
        """Assemble shard ``s``'s view of ``part``: block + halo, rest poisoned."""
        lo, hi = self.plan.block(s)
        lpart = np.full(part.shape[0], fill, dtype=part.dtype)
        lpart[lo:hi] = part[lo:hi]
        halo = self.halo(s, mode)
        lpart[halo] = part[halo]  # the halo exchange: one gather per round
        return lpart


def comm_volume_sharded(hyper: Hypergraph, part: np.ndarray, plan) -> int:
    """``comm_volume`` computed shard-by-shard through halo-local views.

    Each hyperedge is owned by the shard holding its source vertex; a shard
    computes λ over its own edges reading only block + volume-halo partition
    labels, and the partial volumes sum to the global objective for *every*
    shard count — the halo-exchange correctness property the sharded engine
    relies on.  Reads outside the declared halo raise (poison check) rather
    than silently mis-counting.
    """
    part = np.asarray(part, dtype=np.int64)
    ne = hyper.num_hyperedges
    if ne == 0:
        return 0
    k = int(part.max()) + 1
    check_index_capacity(hyper.num_vertices, ne, k)
    g = Graph(
        xadj=np.zeros(hyper.num_vertices + 1, dtype=np.int64),
        adjncy=np.empty(0, dtype=np.int32),
        adjwgt=np.empty(0, dtype=np.int64),
        vwgt=np.ones(hyper.num_vertices, dtype=np.int64),
        hyper=hyper,
    )
    view = ShardedGraphView(g, plan)
    owner = np.searchsorted(np.asarray(plan.bounds), hyper.hsrc,
                            side="right") - 1
    total = 0
    for s in range(plan.num_shards):
        eids = np.nonzero(owner == s)[0].astype(np.int64)
        if eids.shape[0] == 0:
            continue
        lpart = view.local_part(s, part, mode="volume")
        pidx, plocal = csr_gather(hyper.hxadj, eids)
        pin_p = lpart[hyper.hpins[pidx]]
        src_p = lpart[hyper.hsrc[eids]]
        if (pin_p < 0).any() or (src_p < 0).any():
            raise AssertionError(
                f"shard {s} read a partition label outside its halo")
        keys = np.concatenate([
            plocal * k + pin_p,
            np.arange(eids.shape[0], dtype=np.int64) * k + src_p,
        ])
        lam = np.bincount(np.unique(keys) // k, minlength=eids.shape[0])
        total += int((hyper.hfire[eids] * (lam - 1)).sum())
    return total


def edge_partition_counts(hyper: Hypergraph, part: np.ndarray, k: int) -> np.ndarray:
    """(E, k) member counts Φ(e, p): how many members (source + pins) of each
    hyperedge lie in each partition.  λ(e) is the number of nonzero columns
    of row e; refiners maintain this table incrementally across moves.
    int32 — counts are bounded by an edge's pin count, and the dense table
    is the volume refiners' dominant allocation on large graphs."""
    part = np.asarray(part, dtype=np.int64)
    ne = hyper.num_hyperedges
    check_index_capacity(hyper.num_vertices, ne, k)
    keys = np.concatenate([
        hyper.pin_edge * k + part[hyper.hpins].astype(np.int64),
        np.arange(ne, dtype=np.int64) * k + part[hyper.hsrc].astype(np.int64),
    ])
    return np.bincount(keys, minlength=ne * k).reshape(ne, k).astype(np.int32)


def presence_degrees(
    phi_pairs: np.ndarray,
    w: np.ndarray,
    counts: np.ndarray,
    local: np.ndarray,
    own: np.ndarray,
    k: int,
) -> np.ndarray:
    """Shared D* accumulation over (row, incident hyperedge) pairs.

    Given, per pair, the member counts Φ(e, ·) of the incident hyperedge
    (``phi_pairs``, (P, k)) and its weight (``w``, (P,)), plus the pair→row
    CSR structure (``counts`` per row, ``local`` row id per pair — grouped
    by row, as ``csr_gather`` emits) and each row vertex's own partition,
    returns the (R, k) matrix D*[v, p] = Σ_e w_e [Φ(e, p) > (p == own[v])]:
    presence of *any* member for foreign columns, of a *second* member for
    the own column (the row vertex itself always sits there).  Both the
    from-scratch ``volume_degrees`` and the refiner's live-Φ-table variant
    reduce to this epilogue; keep the threshold logic here only.

    Pairs must be grouped by row so the per-row sums are two
    ``np.add.reduceat`` segment reductions (``np.add.at`` is unbuffered
    and an order of magnitude slower here).
    """
    nr = counts.shape[0]
    out = np.zeros((nr, k), dtype=np.float64)
    if phi_pairs.shape[0] == 0:
        return out
    nonempty = np.nonzero(counts > 0)[0]
    starts = (np.cumsum(counts) - counts)[nonempty]
    out[nonempty] = np.add.reduceat(w[:, None] * (phi_pairs > 0), starts, axis=0)
    own_fix = np.add.reduceat(
        w * (phi_pairs[np.arange(local.shape[0]), own[local]] > 1), starts
    )
    out[np.arange(nr), own] = 0.0
    out[nonempty, own[nonempty]] = own_fix
    return out


def volume_degrees(
    hyper: Hypergraph,
    part: np.ndarray,
    k: int,
    rows: np.ndarray | None = None,
) -> np.ndarray:
    """(R, k) float64 connectivity degree matrix D* for the volume objective.

    D*[v, p] = sum over hyperedges e containing v of hfire[e] * [e has a
    member other than v in partition p].  The exact λ-gain of moving v from
    its partition a to b is then D*[v, b] − D*[v, a] — the same shape as the
    edge-cut refiners' (external − internal) degree arithmetic, so both the
    scalar FM queue and the batched vec refiner consume this matrix
    unchanged.  Entries are integer-valued (exact in float64).
    """
    part = np.asarray(part, dtype=np.int64)
    if rows is None:
        rows = np.arange(hyper.num_vertices, dtype=np.int64)
    rows = np.asarray(rows, dtype=np.int64)
    nr = rows.shape[0]
    out = np.zeros((nr, k), dtype=np.float64)
    if hyper.num_hyperedges == 0 or nr == 0:
        return out

    vxadj, vedges = hyper.incidence()
    idx, local = csr_gather(vxadj, rows)
    if idx.shape[0] == 0:
        return out
    eids = vedges[idx]  # incident hyperedge per (row, edge) pair

    # Partition member counts Φ(e, p) for the distinct incident hyperedges.
    ue, einv = np.unique(eids, return_inverse=True)
    hu = ue.shape[0]
    pidx, pin_local = csr_gather(hyper.hxadj, ue)
    keys = np.concatenate(
        [
            pin_local * k + part[hyper.hpins[pidx]],
            np.arange(hu, dtype=np.int64) * k + part[hyper.hsrc[ue]],
        ]
    )
    phi = np.bincount(keys, minlength=hu * k).reshape(hu, k)

    counts = (vxadj[rows + 1] - vxadj[rows]).astype(np.int64)
    return presence_degrees(phi[einv], hyper.hfire[eids].astype(np.float64),
                            counts, local, part[rows], k)


def partition_weights(graph: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """(k,) vertex weight (neuron count) per partition."""
    w = np.zeros(k, dtype=np.int64)
    np.add.at(w, part, graph.vwgt)
    return w


def validate_partition(graph: Graph, part: np.ndarray, k: int, capacity: int) -> None:
    """Raise if `part` is not a valid k-way partition within core capacity."""
    if part.shape != (graph.num_vertices,):
        raise ValueError(f"partition vector shape {part.shape} != ({graph.num_vertices},)")
    if part.min() < 0 or part.max() >= k:
        raise ValueError(f"partition ids outside [0, {k})")
    w = partition_weights(graph, part, k)
    if (w > capacity).any():
        bad = np.nonzero(w > capacity)[0]
        raise ValueError(f"partitions {bad.tolist()} exceed capacity {capacity}: {w[bad].tolist()}")

"""Coarsening step of the multilevel partitioning paradigm (paper §3.3).

Heavy-edge matching: vertices are visited in random order; an unmatched
vertex m is folded with the unmatched neighbor n maximizing the weight of
edge (m, n).  Matched pairs become single vertices of the next-coarser
graph; parallel edges merge by summing weights.  Coarsening repeats level
by level until the graph is small or stops shrinking.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph

__all__ = ["heavy_edge_matching", "contract", "coarsen"]


def heavy_edge_matching(graph: Graph, rng: np.random.Generator) -> np.ndarray:
    """Return match[v] = partner vertex (or v itself if unmatched)."""
    n = graph.num_vertices
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    xadj, adjncy, adjwgt = graph.xadj, graph.adjncy, graph.adjwgt
    for v in order:
        if match[v] != -1:
            continue
        s, e = xadj[v], xadj[v + 1]
        nbrs = adjncy[s:e]
        wgts = adjwgt[s:e]
        free = match[nbrs] == -1
        if free.any():
            cand_n = nbrs[free]
            cand_w = wgts[free]
            u = int(cand_n[np.argmax(cand_w)])
            match[v] = u
            match[u] = v
        else:
            match[v] = v
    return match


def contract(graph: Graph, match: np.ndarray) -> Graph:
    """Contract matched pairs into the next-coarser graph.

    Returns a Graph whose ``cmap`` maps fine vertices -> coarse vertices.
    """
    n = graph.num_vertices
    # Assign coarse ids: the lower-numbered endpoint of each pair owns the id.
    rep = np.minimum(np.arange(n), match)
    uniq, cmap = np.unique(rep, return_inverse=True)
    nc = uniq.shape[0]

    cvwgt = np.zeros(nc, dtype=np.int64)
    np.add.at(cvwgt, cmap, graph.vwgt)

    src = np.repeat(np.arange(n), np.diff(graph.xadj))
    csrc = cmap[src]
    cdst = cmap[graph.adjncy]
    keep = csrc != cdst  # internal (matched) edges disappear
    csrc, cdst, cw = csrc[keep], cdst[keep], graph.adjwgt[keep]

    # Merge parallel edges (both directions are present symmetrically).
    key = csrc.astype(np.int64) * nc + cdst
    order = np.argsort(key, kind="stable")
    key, csrc, cdst, cw = key[order], csrc[order], cdst[order], cw[order]
    uniq_key, start = np.unique(key, return_index=True)
    merged_w = np.add.reduceat(cw, start) if len(key) else cw
    msrc = (uniq_key // nc).astype(np.int64)
    mdst = (uniq_key % nc).astype(np.int64)

    xadj = np.zeros(nc + 1, dtype=np.int64)
    np.add.at(xadj, msrc + 1, 1)
    xadj = np.cumsum(xadj)
    return Graph(
        xadj=xadj,
        adjncy=mdst.astype(np.int32),
        adjwgt=merged_w.astype(np.int64),
        vwgt=cvwgt,
        cmap=cmap,
    )


def coarsen(
    graph: Graph,
    rng: np.random.Generator,
    coarsen_to: int = 128,
    max_vwgt: int | None = None,
    shrink_floor: float = 0.95,
    max_levels: int = 40,
) -> list[Graph]:
    """Coarsen level by level; returns [G_0, G_1, ..., G_c] (fine -> coarse).

    Stops when the graph has <= ``coarsen_to`` vertices, stops shrinking
    (|G_{i+1}| > shrink_floor * |G_i|), or ``max_levels`` is hit.
    ``max_vwgt`` bounds the merged vertex weight so that coarse vertices
    stay placeable within a core's neuron capacity.
    """
    levels = [graph]
    for _ in range(max_levels):
        g = levels[-1]
        if g.num_vertices <= coarsen_to or g.num_edges == 0:
            break
        match = heavy_edge_matching(g, rng)
        if max_vwgt is not None:
            # Undo matches whose merged weight would exceed the cap.
            v = np.arange(g.num_vertices)
            over = (g.vwgt + g.vwgt[match]) > max_vwgt
            bad = over & (match != v)
            match = match.copy()
            match[bad] = v[bad]
            partner_bad = bad[match]
            match[partner_bad] = v[partner_bad]
        coarse = contract(g, match)
        if coarse.num_vertices > shrink_floor * g.num_vertices:
            break
        levels.append(coarse)
    return levels

"""Coarsening step of the multilevel partitioning paradigm (paper §3.3).

Heavy-edge matching: vertices are visited in random order; an unmatched
vertex m is folded with the unmatched neighbor n maximizing the weight of
edge (m, n).  Matched pairs become single vertices of the next-coarser
graph; parallel edges merge by summing weights.  Coarsening repeats level
by level until the graph is small or stops shrinking.

Two matching engines share the `match[v] = partner` contract:

* ``heavy_edge_matching`` — the paper's sequential visit-in-random-order
  loop (reference implementation, O(n) Python iterations).
* ``heavy_edge_matching_vec`` — round-based propose–accept matching with
  a random proposer/acceptor role split per round: proposers pick their
  heaviest free acceptor neighbor via one vectorized segment-argmax over
  the CSR arrays, acceptors lock in their heaviest proposer, and the
  disjoint roles keep accepted pairs conflict-free.  A few rounds reach a
  near-maximal matching with no per-vertex Python work (details and the
  tie-breaking rationale on the function itself).
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from .graph import Graph, Hypergraph, _mix64, dedup_hyperedges

__all__ = [
    "heavy_edge_matching",
    "heavy_edge_matching_vec",
    "contract",
    "contract_hypergraph",
    "coarsen",
    "LevelStore",
]


def _shard_bounds(n: int, shards) -> np.ndarray | None:
    """Contiguous vertex-block bounds from a shard count or plan.

    Accepts ``None`` (single-host mode), an int shard count, or any object
    with a ``bounds`` attribute (``sharding.planner.VertexShardPlan``); the
    core stays numpy-only by never importing the planner.
    """
    if shards is None:
        return None
    if hasattr(shards, "bounds"):
        return np.asarray(shards.bounds, dtype=np.int64)
    s = max(1, int(shards))
    return (np.arange(s + 1, dtype=np.int64) * n) // s


def heavy_edge_matching(graph: Graph, rng: np.random.Generator) -> np.ndarray:
    """Return match[v] = partner vertex (or v itself if unmatched)."""
    n = graph.num_vertices
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    xadj, adjncy, adjwgt = graph.xadj, graph.adjncy, graph.adjwgt
    for v in order:
        if match[v] != -1:
            continue
        s, e = xadj[v], xadj[v + 1]
        nbrs = adjncy[s:e]
        wgts = adjwgt[s:e]
        free = match[nbrs] == -1
        if free.any():
            cand_n = nbrs[free]
            cand_w = wgts[free]
            u = int(cand_n[np.argmax(cand_w)])
            match[v] = u
            match[u] = v
        else:
            match[v] = v
    return match


_TIE_BITS = 20  # per-edge random tie-break key width


def heavy_edge_matching_vec(
    graph: Graph,
    rng: np.random.Generator | None = None,
    max_vwgt: int | None = None,
    max_rounds: int = 64,
    shards=None,
) -> np.ndarray:
    """Array-parallel heavy-edge matching (same contract as the scalar loop).

    ``shards`` (None, int, or a plan with ``bounds``) selects the sharded
    engine: per-round work proceeds over per-shard *edge-range slices* of
    the CSR arrays (rows are contiguous, so a vertex block's edges are one
    zero-copy slice), proposals commit into global (n,)-sized arrays, and
    acceptance runs once globally — the halo exchange is implicit in the
    free/proposer lookups at boundary neighbors.  Peak per-shard memory is
    O(block edges), not O(m).  Tie keys come from a splitmix64 hash of the
    *global* edge index (not per-call rng draws), so the matching is
    invariant under the shard count: ``shards=1`` and ``shards=8`` produce
    bitwise-identical matchings.  ``shards=None`` keeps the original
    rng-tie path (and its recorded benchmark results) byte-for-byte.

    Propose-accept rounds with a random role split: each round every free
    vertex is coin-flipped into proposer or acceptor; proposers pick their
    heaviest free acceptor neighbor via one segment-argmax over the CSR
    arrays, and each acceptor locks in its heaviest proposer.  Because the
    two roles are disjoint, accepted pairs never conflict — no sequential
    tie-breaking is needed and the whole round is whole-array numpy.

    Weight ties break by fresh per-edge random keys each round.  That
    matters: profiled SNN graphs carry many equal spike counts, and any
    deterministic tie-break points whole neighborhoods at one vertex, so a
    round locks in O(1) pairs instead of O(n) (dense equal-weight layers
    degrade worst — mutual-proposal matching stalls outright there).

    Each round runs a "second chance" pass: proposers that lost the
    acceptance step (their target locked in a heavier proposer) re-propose
    to their best *still unmatched* acceptor neighbor under the same role
    split.  That recovers most of the matched-weight gap vs. the sequential
    loop, which never wastes a visit on an already-taken neighbor.

    ``max_vwgt`` filters candidate edges up front so merged vertices never
    exceed the cap.
    """
    bounds = _shard_bounds(graph.num_vertices, shards)
    if bounds is not None:
        return _matching_vec_sharded(graph, rng, max_vwgt, max_rounds, bounds)
    n = graph.num_vertices
    match = np.full(n, -1, dtype=np.int64)
    xadj, adjncy, adjwgt, vwgt = graph.xadj, graph.adjncy, graph.adjwgt, graph.vwgt
    m = adjncy.shape[0]
    if m:
        if rng is None:
            rng = np.random.default_rng(0)
        # Both int64 packings must fit: the (weight << tie) proposal key and
        # the (weight * n + vertex) acceptance key.
        if int(adjwgt.max()) >= min(1 << (62 - _TIE_BITS), (1 << 62) // max(n, 1)):
            raise OverflowError("edge weights too large for the packed match keys")
        src = graph.edge_src
        nbr = adjncy.astype(np.int64)
        nonempty = xadj[:-1] < xadj[1:]
        starts = xadj[:-1][nonempty]
        cap_ok = True
        if max_vwgt is not None:
            cap_ok = (vwgt[src] + vwgt[nbr]) <= max_vwgt
        for _ in range(max_rounds):
            free = match == -1
            if not (free[src] & free[nbr] & cap_ok).any():
                break
            proposer = rng.random(n) < 0.5
            # Two passes per round: the second gives proposers that lost the
            # acceptance step a chance to re-propose to a still-free acceptor.
            for _pass in range(2):
                free = match == -1
                ok = free[src] & free[nbr] & cap_ok & proposer[src] & ~proposer[nbr]
                if not ok.any():
                    break  # unlucky coin flips or round exhausted
                # Lexicographic (weight, random tie) as one int64 key; CSR rows
                # are contiguous, so one reduceat over non-empty rows is the
                # whole segment-max.
                key = np.where(
                    ok,
                    (adjwgt << _TIE_BITS) + rng.integers(0, 1 << _TIE_BITS, m),
                    -1,
                )
                rowmax = np.full(n, -1, dtype=np.int64)
                rowmax[nonempty] = np.maximum.reduceat(key, starts)
                hit = ok & (key == rowmax[src])
                proposal = np.full(n, n, dtype=np.int64)
                np.minimum.at(proposal, src[hit], nbr[hit])
                prop_from = np.nonzero(proposal < n)[0]
                # Acceptance: each target keeps its heaviest proposer; the
                # (weight, proposer-id) key makes the winner recoverable as
                # key % n.
                pw = rowmax[prop_from] >> _TIE_BITS
                acc = np.full(n, -1, dtype=np.int64)
                np.maximum.at(acc, proposal[prop_from], pw * n + prop_from)
                targets = np.nonzero(acc >= 0)[0]
                winners = acc[targets] % n
                match[targets] = winners
                match[winners] = targets
    unmatched = match == -1
    match[unmatched] = np.nonzero(unmatched)[0]
    return match


def _matching_vec_sharded(
    graph: Graph,
    rng: np.random.Generator | None,
    max_vwgt: int | None,
    max_rounds: int,
    bounds: np.ndarray,
) -> np.ndarray:
    """Sharded propose–accept matching (see ``heavy_edge_matching_vec``).

    Per pass, each shard scans only its own edge slice and commits local
    proposals; the single global acceptance step then resolves every
    cross-shard collision at once.  All randomness is shard-count
    independent: proposer coin flips are one global ``rng.random(n)`` per
    round, and tie keys hash the global edge index with one per-pass seed.
    """
    n = graph.num_vertices
    match = np.full(n, -1, dtype=np.int64)
    xadj, adjncy, adjwgt, vwgt = graph.xadj, graph.adjncy, graph.adjwgt, graph.vwgt
    if adjncy.shape[0] == 0:
        match[:] = np.arange(n)
        return match
    if rng is None:
        rng = np.random.default_rng(0)
    if int(adjwgt.max()) >= min(1 << (62 - _TIE_BITS), (1 << 62) // max(n, 1)):
        raise OverflowError("edge weights too large for the packed match keys")
    nshards = bounds.shape[0] - 1
    tie_mask = np.uint64((1 << _TIE_BITS) - 1)

    def shard_slices(s: int):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        return lo, hi, int(xadj[lo]), int(xadj[hi])

    for _ in range(max_rounds):
        free = match == -1
        alive = False
        for s in range(nshards):
            lo, hi, e0, e1 = shard_slices(s)
            if e0 == e1:
                continue
            deg = np.diff(xadj[lo:hi + 1])
            nbr_s = adjncy[e0:e1]
            ok = np.repeat(free[lo:hi], deg) & free[nbr_s]
            if max_vwgt is not None:
                ok &= (np.repeat(vwgt[lo:hi], deg) + vwgt[nbr_s]) <= max_vwgt
            if ok.any():
                alive = True
                break
        if not alive:
            break
        proposer = rng.random(n) < 0.5
        # Two passes per round, like the single-host engine: the second
        # lets proposers that lost acceptance re-propose to a still-free
        # acceptor.
        for _pass in range(2):
            tie_seed = np.uint64(int(rng.integers(1 << 62)))
            free = match == -1
            proposal = np.full(n, n, dtype=np.int64)
            best_w = np.zeros(n, dtype=np.int64)
            for s in range(nshards):
                lo, hi, e0, e1 = shard_slices(s)
                if e0 == e1:
                    continue
                deg = np.diff(xadj[lo:hi + 1])
                nbr_s = adjncy[e0:e1].astype(np.int64)
                loc_src = np.repeat(np.arange(hi - lo), deg)
                ok = (np.repeat(free[lo:hi] & proposer[lo:hi], deg)
                      & free[nbr_s] & ~proposer[nbr_s])
                if max_vwgt is not None:
                    ok &= (np.repeat(vwgt[lo:hi], deg) + vwgt[nbr_s]) <= max_vwgt
                if not ok.any():
                    continue
                tie = (_mix64(np.arange(e0, e1, dtype=np.uint64), tie_seed)
                       & tie_mask).astype(np.int64)
                key = np.where(ok, (adjwgt[e0:e1] << _TIE_BITS) + tie, -1)
                nonempty = deg > 0
                rowmax = np.full(hi - lo, -1, dtype=np.int64)
                rowmax[nonempty] = np.maximum.reduceat(
                    key, (xadj[lo:hi] - e0)[nonempty])
                hit = ok & (key == rowmax[loc_src])
                np.minimum.at(proposal, loc_src[hit] + lo, nbr_s[hit])
                best_w[lo:hi] = np.where(rowmax >= 0, rowmax >> _TIE_BITS, 0)
            prop_from = np.nonzero(proposal < n)[0]
            if prop_from.shape[0] == 0:
                break
            acc = np.full(n, -1, dtype=np.int64)
            np.maximum.at(acc, proposal[prop_from],
                          best_w[prop_from] * n + prop_from)
            targets = np.nonzero(acc >= 0)[0]
            winners = acc[targets] % n
            match[targets] = winners
            match[winners] = targets
    unmatched = match == -1
    match[unmatched] = np.nonzero(unmatched)[0]
    return match


def contract_hypergraph(hyper: Hypergraph, cmap: np.ndarray, nc: int) -> Hypergraph:
    """Contract hyperedges through a fine→coarse vertex map.

    Pins remap through ``cmap`` and merge within each hyperedge (weights
    summed); pins that collapse into their own source are dropped (their
    deliveries became core-local), as are hyperedges left with no pins.
    Because a partition of the coarse graph induces the same member
    partition sets, ``comm_volume`` is preserved exactly under projection —
    which is what makes λ-gains exact at every level of refinement.

    Hyperedges whose (source, pin set) became identical under the remap are
    merged by ``graph.dedup_hyperedges`` (hfire and per-pin hwgt summed) —
    also volume-preserving, since identical member sets span identical
    partition sets.  On structured SNNs (dense layers) most hyperedges
    collapse this way after a few levels, shrinking the Φ table and every
    λ-gain evaluation during refinement.
    """
    hsrc = cmap[hyper.hsrc.astype(np.int64)]
    pins = cmap[hyper.hpins.astype(np.int64)]
    pe = hyper.pin_edge
    keep = pins != hsrc[pe]
    pe, pins, wgt = pe[keep], pins[keep], hyper.hwgt[keep]

    # Merge duplicate pins within each hyperedge (np.unique sorts the packed
    # key, so merged pins come out grouped by hyperedge — CSR-ready).
    key = pe * nc + pins
    order = np.argsort(key, kind="stable")
    key, wgt = key[order], wgt[order]
    uniq, start = np.unique(key, return_index=True)
    merged_w = np.add.reduceat(wgt, start) if len(key) else wgt
    mpe = uniq // nc
    mpins = uniq % nc

    # Compact away empty hyperedges.
    ne = hyper.num_hyperedges
    counts = np.bincount(mpe, minlength=ne)
    nonempty = counts > 0
    hxadj = np.concatenate([[0], np.cumsum(counts[nonempty])]).astype(np.int64)
    return dedup_hyperedges(Hypergraph(
        hxadj=hxadj,
        hpins=mpins.astype(np.int32),
        hwgt=merged_w.astype(np.int64),
        hsrc=hsrc[nonempty].astype(np.int32),
        hfire=hyper.hfire[nonempty],
        num_vertices=nc,
    ))


def contract(graph: Graph, match: np.ndarray, contract_hyper: bool = True) -> Graph:
    """Contract matched pairs into the next-coarser graph.

    Returns a Graph whose ``cmap`` maps fine vertices -> coarse vertices;
    an attached ``hyper`` view is contracted alongside unless
    ``contract_hyper=False`` (the edge-cut objective never reads coarse
    hypergraphs, so cut-path callers skip the per-level pin merge).
    """
    n = graph.num_vertices
    # Assign coarse ids: the lower-numbered endpoint of each pair owns the id.
    rep = np.minimum(np.arange(n), match)
    uniq, cmap = np.unique(rep, return_inverse=True)
    nc = uniq.shape[0]

    cvwgt = np.zeros(nc, dtype=np.int64)
    np.add.at(cvwgt, cmap, graph.vwgt)

    src = graph.edge_src
    csrc = cmap[src]
    cdst = cmap[graph.adjncy]
    keep = csrc != cdst  # internal (matched) edges disappear
    csrc, cdst, cw = csrc[keep], cdst[keep], graph.adjwgt[keep]

    # Merge parallel edges (both directions are present symmetrically).
    key = csrc.astype(np.int64) * nc + cdst
    order = np.argsort(key, kind="stable")
    key, csrc, cdst, cw = key[order], csrc[order], cdst[order], cw[order]
    uniq_key, start = np.unique(key, return_index=True)
    merged_w = np.add.reduceat(cw, start) if len(key) else cw
    msrc = (uniq_key // nc).astype(np.int64)
    mdst = (uniq_key % nc).astype(np.int64)

    xadj = np.zeros(nc + 1, dtype=np.int64)
    np.add.at(xadj, msrc + 1, 1)
    xadj = np.cumsum(xadj)
    return Graph(
        xadj=xadj,
        adjncy=mdst.astype(np.int32),
        adjwgt=merged_w.astype(np.int64),
        vwgt=cvwgt,
        cmap=cmap,
        hyper=(contract_hypergraph(graph.hyper, cmap, nc)
               if contract_hyper and graph.hyper is not None else None),
    )


def coarsen(
    graph: Graph,
    rng: np.random.Generator,
    coarsen_to: int = 128,
    max_vwgt: int | None = None,
    shrink_floor: float = 0.95,
    max_levels: int = 40,
    impl: str = "scalar",
    contract_hyper: bool = True,
    shards=None,
    store: "LevelStore | None" = None,
):
    """Coarsen level by level; returns [G_0, G_1, ..., G_c] (fine -> coarse).

    Stops when the graph has <= ``coarsen_to`` vertices, stops shrinking
    (|G_{i+1}| > shrink_floor * |G_i|), or ``max_levels`` is hit.
    ``max_vwgt`` bounds the merged vertex weight so that coarse vertices
    stay placeable within a core's neuron capacity.  ``impl`` selects the
    matching engine: ``"scalar"`` (sequential reference) or ``"vec"``
    (round-based array-parallel matching).  ``contract_hyper=False`` skips
    the per-level hypergraph contraction (see ``contract``).

    ``shards`` threads through to ``heavy_edge_matching_vec`` (vec impl
    only; the scalar reference loop ignores it).  ``store`` selects
    out-of-core streaming: each level is appended to the ``LevelStore``
    (spilled to disk) as soon as it is contracted, and only the current
    level stays resident — the returned object is the store itself, which
    ``uncoarsen_vec`` walks one index at a time.  With ``store=None`` the
    in-memory list of levels is returned as before.
    """
    if impl not in ("scalar", "vec"):
        raise ValueError(f"unknown coarsening impl {impl!r}")
    out = store if store is not None else []
    out.append(graph)
    prev = graph
    for _ in range(max_levels):
        g = prev
        if g.num_vertices <= coarsen_to or g.num_edges == 0:
            break
        if impl == "vec":
            match = heavy_edge_matching_vec(g, rng, max_vwgt=max_vwgt,
                                            shards=shards)
        else:
            match = heavy_edge_matching(g, rng)
        if max_vwgt is not None:
            # Undo matches whose merged weight would exceed the cap.
            v = np.arange(g.num_vertices)
            over = (g.vwgt + g.vwgt[match]) > max_vwgt
            bad = over & (match != v)
            match = match.copy()
            match[bad] = v[bad]
            partner_bad = bad[match]
            match[partner_bad] = v[partner_bad]
        coarse = contract(g, match, contract_hyper=contract_hyper)
        if coarse.num_vertices > shrink_floor * g.num_vertices:
            break
        out.append(coarse)
        prev = coarse
    return out


class LevelStore:
    """Disk-backed sequence of level graphs for out-of-core uncoarsening.

    ``append`` spills a level (Graph plus any attached Hypergraph) to one
    ``.npz`` file and drops the reference; ``__getitem__`` reloads on
    demand through a two-entry cache.  That is exactly the access pattern
    of ``uncoarsen_vec``'s coarse→fine walk — ``levels[i + 1].cmap`` then
    ``levels[i]`` — so a full multilevel hierarchy never holds more than
    two levels resident, regardless of depth.  Supports ``len`` and
    negative indices like the plain list ``coarsen`` builds in memory.
    """

    _CACHE_SLOTS = 2

    def __init__(self, directory: str | None = None):
        self._own = directory is None
        self._dir = (tempfile.mkdtemp(prefix="sneap_levels_")
                     if directory is None else str(directory))
        os.makedirs(self._dir, exist_ok=True)
        self._count = 0
        self._cache: dict[int, Graph] = {}

    def __len__(self) -> int:
        return self._count

    def _path(self, i: int) -> str:
        return os.path.join(self._dir, f"level_{i:04d}.npz")

    def append(self, g: Graph) -> None:
        arrays = {"xadj": g.xadj, "adjncy": g.adjncy, "adjwgt": g.adjwgt,
                  "vwgt": g.vwgt}
        if g.cmap is not None:
            arrays["cmap"] = g.cmap
        if g.hyper is not None:
            h = g.hyper
            arrays.update(hxadj=h.hxadj, hpins=h.hpins, hwgt=h.hwgt,
                          hsrc=h.hsrc, hfire=h.hfire,
                          hyper_nv=np.int64(h.num_vertices))
        np.savez(self._path(self._count), **arrays)
        self._count += 1

    def __getitem__(self, i: int) -> Graph:
        if i < 0:
            i += self._count
        if not 0 <= i < self._count:
            raise IndexError(f"level {i} of {self._count}")
        if i in self._cache:
            return self._cache[i]
        with np.load(self._path(i)) as z:
            hyper = None
            if "hxadj" in z:
                hyper = Hypergraph(hxadj=z["hxadj"], hpins=z["hpins"],
                                   hwgt=z["hwgt"], hsrc=z["hsrc"],
                                   hfire=z["hfire"],
                                   num_vertices=int(z["hyper_nv"]))
            g = Graph(xadj=z["xadj"], adjncy=z["adjncy"], adjwgt=z["adjwgt"],
                      vwgt=z["vwgt"],
                      cmap=z["cmap"] if "cmap" in z else None,
                      hyper=hyper)
        while len(self._cache) >= self._CACHE_SLOTS:
            self._cache.pop(next(iter(self._cache)))
        self._cache[i] = g
        return g

    def close(self) -> None:
        """Drop the cache and, for store-owned temp dirs, the spill files."""
        self._cache.clear()
        if not self._own:
            return
        for i in range(self._count):
            try:
                os.remove(self._path(i))
            except OSError:
                pass
        try:
            os.rmdir(self._dir)
        except OSError:
            pass

"""Launchers: mesh construction, jitted train/serve steps, dry-run, roofline,
and the batched toolchain sweep driver (`repro.launch.sweep`)."""
from .sweep import SweepResult, config_grid, pareto_flags, run_sweep

__all__ = ["SweepResult", "config_grid", "pareto_flags", "run_sweep"]

"""Launchers: mesh construction, jitted train/serve steps, dry-run, roofline."""

import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks the device count on first
# init).  The two lines above are the only code allowed before this
# docstring per the dry-run contract.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:   jax.jit(step, in_shardings=..., out_shardings=...)
                    .lower(**ShapeDtypeStructs).compile()
must SUCCEED on the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh.
The compiled artifact yields cost_analysis (FLOPs / bytes), memory
analysis, and the partitioned HLO whose collective operand bytes feed the
roofline (launch/roofline.py).  Results append to a JSONL ledger so the
sweep is resumable.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.launch.hlo_analysis import collective_bytes, op_census
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["run_cell", "main"]


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
        return out
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def run_cell(arch: str, shape: str, multi_pod: bool, kv_chunk: int = 1024,
             zero1: bool = True, remat: bool = True, verbose: bool = True,
             unroll: bool = False, ssm_chunk: int | None = None) -> dict:
    """Lower+compile one cell; returns the JSONL record.

    unroll=True unrolls the layer scan (and uses it for roofline FLOP /
    collective-byte measurement — XLA cost analysis visits a rolled while
    body only once).  ssm_chunk overrides the SSD chunk so the unrolled
    chunk count stays bounded at long sequences.
    """
    import dataclasses

    from repro.models import model as model_mod

    rec: dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kv_chunk": kv_chunk, "zero1": zero1, "remat": remat,
                 "unroll": unroll}
    cfg = get_config(arch)
    if ssm_chunk is not None and cfg.ssm_state:
        cfg = dataclasses.replace(cfg, ssm_chunk=ssm_chunk)
        rec["ssm_chunk"] = ssm_chunk
    model_mod.set_scan_unroll(unroll)
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec
    sp = SHAPES[shape]
    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        specs = input_specs(cfg, shape)
        if sp.kind == "train":
            bundle = make_train_step(cfg, mesh, remat=remat, zero1=zero1,
                                     kv_chunk=kv_chunk)
            params_sds = jax.eval_shape(
                lambda: bundle.model.init(jax.random.PRNGKey(0)))
            opt_sds = jax.eval_shape(bundle.init_opt, params_sds)
            batch = {k: v for k, v in specs.items()}
            jitted = bundle.jit_for(batch)
            lowered = jitted.lower(params_sds, opt_sds, batch)
        elif sp.kind == "prefill":
            bundle = make_prefill_step(cfg, mesh, cache_len=sp.seq_len,
                                       kv_chunk=kv_chunk)
            params_sds = jax.eval_shape(
                lambda: bundle.model.init(jax.random.PRNGKey(0)))
            batch = {k: v for k, v in specs.items()}
            jitted = bundle.jit_for(batch)
            lowered = jitted.lower(params_sds, batch)
        else:  # decode
            bundle = make_serve_step(cfg, mesh, cache_len=sp.seq_len,
                                     kv_chunk=kv_chunk)
            params_sds = jax.eval_shape(
                lambda: bundle.model.init(jax.random.PRNGKey(0)))
            caches_sds = jax.eval_shape(
                lambda: bundle.model.init_caches(sp.global_batch, sp.seq_len))
            jitted = bundle.jit_for(sp.global_batch)
            lowered = jitted.lower(params_sds, caches_sds, specs["tokens"],
                                   specs["positions"])
        t_lower = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1

        hlo = compiled.as_text()
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            cost=_cost_analysis(compiled),
            memory=_memory_analysis(compiled),
            collectives=collective_bytes(hlo),
            ops=op_census(hlo),
            num_params=sum(int(v.size) for v in jax.tree.leaves(params_sds)),
            plan_notes=bundle.plan.notes[:20],
        )
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {rec['mesh']}: OK "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
            print(f"  memory_analysis: {rec['memory']}")
            print(f"  cost_analysis: flops={rec['cost'].get('flops')} "
                  f"bytes={rec['cost'].get('bytes accessed')}")
            print(f"  collectives: {rec['collectives']}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {rec['mesh']}: "
                  f"FAILED {type(e).__name__}: {e}")
    return rec


def _done_cells(path: Path) -> set[tuple]:
    done = set()
    if path.exists():
        for line in path.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skip"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                continue
    return done


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    done = set() if args.force else _done_cells(out)

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = (arch, shape, "2x16x16" if multi else "16x16")
                if key in done:
                    continue
                rec = run_cell(arch, shape, multi, kv_chunk=args.kv_chunk,
                               zero1=not args.no_zero1, remat=not args.no_remat)
                with out.open("a") as f:
                    f.write(json.dumps(rec) + "\n")
                n_ok += rec["status"] == "ok"
                n_err += rec["status"] == "error"
                n_skip += rec["status"] == "skip"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} errors -> {out}")


if __name__ == "__main__":
    main()

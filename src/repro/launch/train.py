"""Fault-tolerant training driver.

Runs any --arch (full or --reduced) on the local mesh with the same
jitted train_step the dry-run lowers for the production meshes:
checkpoint/restart (atomic, async), deterministic data resume, straggler
bookkeeping, and optional failure injection (--fail-at) to demonstrate
recovery:

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 50
  # simulate a node failure and restart:
  PYTHONPATH=src python -m repro.launch.train ... --fail-at 120
  PYTHONPATH=src python -m repro.launch.train ... --resume
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data import DataConfig, SyntheticLMData
from repro.optim import AdamWConfig
from repro.runtime import CheckpointManager, HeartbeatMonitor
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step

__all__ = ["main", "train_loop"]


def train_loop(cfg, mesh, steps: int, batch: int, seq: int, ckpt_dir=None,
               ckpt_every: int = 50, resume: bool = False, fail_at: int | None = None,
               lr: float = 3e-4, log_every: int = 10, seed: int = 0,
               remat: bool = False, stop_at: int | None = None,
               print_fn=print) -> dict:
    """`steps` fixes the LR schedule; `stop_at` halts early (clean), so a
    stopped-then-resumed run sees the identical schedule as a straight run."""
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 5), total_steps=steps)
    bundle = make_train_step(cfg, mesh, opt=opt_cfg, remat=remat, zero1=False)
    model = bundle.model

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch, seed=seed))

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = bundle.init_opt(params)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if resume and mgr is not None and mgr.latest_step() is not None:
        (params, opt_state), start_step = mgr.restore((params, opt_state))
        print_fn(f"[train] resumed from step {start_step}")

    def make_batch(step):
        b = data.batch(step)
        if cfg.family in ("vlm", "audio"):
            rng = np.random.default_rng(seed * 7919 + step)
            b["frontend"] = rng.standard_normal(
                (batch, cfg.frontend_seq, cfg.frontend_dim)).astype(np.float32)
        return b

    jitted = bundle.jit_for(jax.eval_shape(lambda: jax.tree.map(
        lambda a: a, make_batch(0))))
    monitor = HeartbeatMonitor(num_hosts=1)
    losses = []
    t_start = time.perf_counter()
    for step in range(start_step, steps):
        t0 = time.perf_counter()
        params, opt_state, metrics = jitted(params, opt_state, make_batch(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.report(0, step, time.perf_counter() - t0)
        if step % log_every == 0 or step == steps - 1:
            print_fn(f"[train] step {step:5d} loss {loss:8.4f} "
                     f"lr {float(metrics['lr']):.2e} "
                     f"gnorm {float(metrics['grad_norm']):8.3f} "
                     f"({time.perf_counter() - t0:.2f}s/step)")
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save_async(step + 1, (params, opt_state))
        if stop_at is not None and step + 1 >= stop_at:
            if mgr:
                mgr.wait()
            return {"losses": losses, "final_loss": losses[-1] if losses else None,
                    "seconds": time.perf_counter() - t_start, "params": params}
        if fail_at is not None and step + 1 >= fail_at:
            print_fn(f"[train] simulated failure at step {step + 1} — restart "
                     "with --resume")
            if mgr:
                mgr.wait()
            sys.exit(17)
    if mgr is not None:
        mgr.wait()  # drain any in-flight async save before the final commit
        if mgr.latest_step() != steps:
            mgr.save(steps, (params, opt_state))
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "seconds": time.perf_counter() - t_start, "params": params}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    out = train_loop(cfg, mesh, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     resume=args.resume, fail_at=args.fail_at, lr=args.lr,
                     remat=args.remat, seed=args.seed)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"in {out['seconds']:.1f}s")


if __name__ == "__main__":
    main()

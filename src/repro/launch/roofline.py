import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs      / (chips x 197e12 FLOP/s bf16)
  memory     = HLO_bytes      / (chips x 819e9  B/s HBM)
  collective = wire bytes     / (chips x 4 links x 50e9 B/s ICI)

HLO_FLOPs / bytes / collective-bytes must be *exact over the layer loop*,
but XLA cost analysis visits a rolled while body once.  Unrolling the full
stack compiles in minutes-to-hours, so each cell is measured by compiling
the UNROLLED step at two truncated depths (n1 < n2 repeating units) and
extrapolating the exactly-linear-in-L counters to the full depth:

    v(L) = v(n2) + (v(n2) - v(n1)) / (n2 - n1) * (L - n2)

All quantities are per-chip (the partitioned module's shapes are already
per-device).  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives the
useful-compute ratio that catches remat/dispatch waste.
"""
import argparse
import dataclasses
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW_PER_LINK = 50e9  # B/s
ICI_LINKS = 4  # 2D torus: 4 links/chip

__all__ = ["truncate_config", "measure_cell", "roofline_terms", "main"]


def truncate_config(cfg, units: int):
    """Scale the repeating unit down while keeping every flavor intact."""
    fam = cfg.family
    if fam in ("dense", "ssm"):
        return dataclasses.replace(cfg, num_layers=units)
    if fam == "moe":
        return dataclasses.replace(
            cfg, num_layers=units + cfg.first_dense_layers)
    if fam == "hybrid":
        # keep exactly 3 global layers; scale the SWA count
        n = units + 3
        return dataclasses.replace(
            cfg, num_layers=n, global_attn_layers=(0, n // 2, n - 1))
    if fam == "vlm":
        per = cfg.cross_attn_every
        return dataclasses.replace(cfg, num_layers=(per + 1) * units)
    if fam == "audio":
        return dataclasses.replace(cfg, num_layers=units, encoder_layers=units)
    raise ValueError(fam)


def _units_of(cfg) -> int:
    """Number of repeating units in the full config."""
    fam = cfg.family
    if fam in ("dense", "ssm"):
        return cfg.num_layers
    if fam == "moe":
        return cfg.num_layers - cfg.first_dense_layers
    if fam == "hybrid":
        return cfg.num_layers - len(cfg.global_attn_layers)
    if fam == "vlm":
        return cfg.num_layers // (cfg.cross_attn_every + 1)
    if fam == "audio":
        return cfg.num_layers
    raise ValueError(fam)


def _counters(rec: dict) -> dict:
    c = {"flops": rec["cost"].get("flops", 0.0),
         "bytes": rec["cost"].get("bytes accessed", 0.0)}
    for k, v in rec.get("collectives", {}).items():
        if not k.startswith("_"):
            c[f"coll:{k}"] = float(v)
    return c


def measure_cell(arch: str, shape: str, n1: int = 2, n2: int = 4,
                 kv_chunk: int = 1024, overrides: dict | None = None,
                 step_kwargs: dict | None = None,
                 verbose: bool = True) -> dict:
    """Two truncated-unrolled compiles -> extrapolated per-chip counters."""
    import repro.launch.dryrun as dryrun
    from repro.configs import get_config

    cfg = get_config(arch)
    full_units = _units_of(cfg)
    n2 = min(n2, full_units)
    n1 = min(n1, max(n2 - 1, 1))

    recs = {}
    for n in (n1, n2):
        tcfg = truncate_config(cfg, n)
        if overrides:
            tcfg = dataclasses.replace(tcfg, **overrides)
        # monkey-level injection: run_cell reads configs by name, so call the
        # lower-level path with an explicit cfg
        rec = _run_truncated(tcfg, shape, kv_chunk=kv_chunk, verbose=verbose,
                             step_kwargs=step_kwargs or {})
        if rec["status"] != "ok":
            return {"arch": arch, "shape": shape, "status": "error",
                    "error": rec.get("error"), "at_units": n}
        recs[n] = rec

    v1 = _counters(recs[n1])
    v2 = _counters(recs[n2])
    keys = set(v1) | set(v2)
    out = {}
    for k in keys:
        a, b = v1.get(k, 0.0), v2.get(k, 0.0)
        if n2 == n1:
            out[k] = b
        else:
            slope = (b - a) / (n2 - n1)
            out[k] = b + slope * (full_units - n2)
    return {"arch": arch, "shape": shape, "status": "ok", "counters": out,
            "n1": n1, "n2": n2, "units": full_units,
            "compile_s": [recs[n1].get("compile_s"), recs[n2].get("compile_s")],
            "kv_chunk": kv_chunk, "overrides": overrides or {},
            "step_kwargs": step_kwargs or {}}


def _run_truncated(tcfg, shape: str, kv_chunk: int, verbose: bool,
                   step_kwargs: dict | None = None) -> dict:
    """run_cell clone that takes an explicit (truncated) config."""
    import time
    import traceback

    import jax

    from repro.configs.shapes import SHAPES, applicable, input_specs
    from repro.launch.hlo_analysis import collective_bytes, op_census
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (make_prefill_step, make_serve_step,
                                    make_train_step)
    from repro.models import model as model_mod
    import repro.launch.dryrun as dryrun

    step_kwargs = step_kwargs or {}
    rec = {"arch": tcfg.name, "shape": shape, "mesh": "16x16"}
    ok, reason = applicable(tcfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec
    sp = SHAPES[shape]
    model_mod.set_scan_unroll(True)
    try:
        t0 = time.perf_counter()
        mesh = make_production_mesh(multi_pod=False)
        specs = input_specs(tcfg, shape)
        if sp.kind == "train":
            bundle = make_train_step(tcfg, mesh, kv_chunk=kv_chunk,
                                     **step_kwargs)
            params_sds = jax.eval_shape(
                lambda: bundle.model.init(jax.random.PRNGKey(0)))
            opt_sds = jax.eval_shape(bundle.init_opt, params_sds)
            lowered = bundle.jit_for(specs).lower(params_sds, opt_sds, specs)
        elif sp.kind == "prefill":
            bundle = make_prefill_step(tcfg, mesh, cache_len=sp.seq_len,
                                       kv_chunk=kv_chunk, **step_kwargs)
            params_sds = jax.eval_shape(
                lambda: bundle.model.init(jax.random.PRNGKey(0)))
            lowered = bundle.jit_for(specs).lower(params_sds, specs)
        else:
            bundle = make_serve_step(tcfg, mesh, cache_len=sp.seq_len,
                                     kv_chunk=kv_chunk, **step_kwargs)
            params_sds = jax.eval_shape(
                lambda: bundle.model.init(jax.random.PRNGKey(0)))
            caches_sds = jax.eval_shape(
                lambda: bundle.model.init_caches(sp.global_batch, sp.seq_len))
            lowered = bundle.jit_for(sp.global_batch).lower(
                params_sds, caches_sds, specs["tokens"], specs["positions"])
        compiled = lowered.compile()
        hlo = compiled.as_text()
        rec.update(status="ok",
                   compile_s=round(time.perf_counter() - t0, 2),
                   cost=dryrun._cost_analysis(compiled),
                   collectives=collective_bytes(hlo),
                   ops=op_census(hlo))
        if verbose:
            print(f"[roofline] {tcfg.name} x {shape} unrolled: "
                  f"{rec['compile_s']}s, flops={rec['cost'].get('flops'):.3e}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-1500:])
        if verbose:
            print(f"[roofline] {tcfg.name} x {shape}: FAILED {e}")
    finally:
        model_mod.set_scan_unroll(False)
    return rec


def model_flops(cfg, shape_name: str) -> float:
    """6*N*D (active params for MoE) per step, global across chips."""
    from repro.configs.shapes import SHAPES

    sp = SHAPES[shape_name]
    n_active = cfg.active_params_billion() * 1e9
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * n_active * tokens
    if sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * n_active * tokens
    tokens = sp.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def roofline_terms(counters: dict, chips: int = 256) -> dict:
    """Per-step times in seconds (per-chip counters in, fleet-wide model)."""
    coll = sum(v for k, v in counters.items() if k.startswith("coll:"))
    compute_s = counters.get("flops", 0.0) / PEAK_FLOPS
    memory_s = counters.get("bytes", 0.0) / HBM_BW
    collective_s = coll / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {**terms, "dominant": dominant, "bound_s": bound,
            "coll_bytes": coll}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--out", default="results/roofline_raw.jsonl")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, get_config
    from repro.configs.shapes import SHAPES, applicable

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if out.exists() and not args.force:
        for line in out.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("status") == "ok":
                    done.add((r["arch"], r["shape"]))
            except json.JSONDecodeError:
                pass
    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if (arch, shape) in done:
                continue
            ok, reason = applicable(cfg, shape)
            if not ok:
                rec = {"arch": arch, "shape": shape, "status": "skip",
                       "reason": reason}
            else:
                kv = args.kv_chunk
                if shape in ("prefill_32k",):
                    kv = max(kv, 4096)  # bound inner-chunk unroll copies
                ssm_override = {}
                if cfg.ssm_state and shape == "prefill_32k":
                    ssm_override = {"ssm_chunk": 2048}
                rec = measure_cell(arch, shape, kv_chunk=kv,
                                   overrides=ssm_override or None)
                if rec["status"] == "ok":
                    mf = model_flops(cfg, shape)
                    rec["model_flops_global"] = mf
                    rec["roofline"] = roofline_terms(rec["counters"])
                    hlo_global = rec["counters"].get("flops", 0.0) * 256
                    rec["useful_ratio"] = (mf / hlo_global) if hlo_global else None
            with out.open("a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"[roofline] written -> {out}")


if __name__ == "__main__":
    main()

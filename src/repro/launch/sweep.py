"""Fleet-scale batched toolchain sweeps (design-space exploration).

The production question is rarely "run the toolchain once" but "which
(k, mesh, objective, mapper, seed) is best for this workload" — the
design-space-exploration step related flows run as a sequential outer
loop.  `run_sweep` executes a whole `ToolchainConfig` grid over one or
more profiled SNNs through the *same* phase functions as
`repro.core.run_toolchain` (`partition_phase` / `mapping_phase` /
`evaluate_phase`), so every sweep row carries bitwise the stats of the
corresponding single run, while the driver wins wall-clock three ways:

  * **phase dedup** — configs agreeing on the partition-relevant knobs
    share one partitioning run (`ToolchainConfig.partition_key`), one
    traffic matrix (`traffic_key`), and one placement-objective build;
  * **device batching** — same-shape ``mapper="sa_jax"`` configs are
    stacked into one vmapped device program
    (`repro.core.mapping_jax.sa_search_jax_batch`), advancing every
    config's whole chain population in lock-step;
  * **jit-cache reuse** — ``stepper="jax"`` replays pad packet arrays to
    power-of-two shapes (`repro.nocsim.replay_jax`), so the grid's
    evaluations bucket into a handful of compiled programs.

The grid can also carry engine-threshold overrides
(``knobs={"_KERNEL_MAX_N": ...}``, ``score_backend``, ``stepper``,
``screen``) so one sweep measures the CPU-reasoned crossover defaults on
real hardware; `benchmarks/bench_sweep.py` records the resulting
data-driven defaults in ``results/bench_sweep.csv``.

Per workload the report flags the Pareto front over
(energy_pj, avg_latency, total_s) — minimum energy, minimum replay
latency, minimum toolchain seconds — the three axes the SNEAP paper
trades (418x toolchain speedup at matched mapping quality).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.mapping import OBJECTIVE_AWARE_MAPPERS
from repro.core.pipeline import (
    ToolchainConfig,
    ToolchainResult,
    build_traffic,
    evaluate_phase,
    mapping_phase,
    partition_phase,
    phase_seeds,
)
from repro.core.placecost import evaluate_placement, make_objective

__all__ = ["config_grid", "run_sweep", "pareto_flags", "SweepResult"]

PARETO_KEYS = ("energy_pj", "avg_latency", "total_s")

# Grid axes that are not ToolchainConfig fields but sugar over its dicts.
_MAPPER_KW_AXES = ("score_backend",)
_NOC_KW_AXES = ("stepper", "screen")


def config_grid(**axes) -> list[ToolchainConfig]:
    """Cartesian product of config axes -> list of `ToolchainConfig`.

    Each axis value may be a list (swept) or a scalar (fixed).  Axis names
    are `ToolchainConfig` field names plus sugar: ``mesh`` takes
    ``(mesh_w, mesh_h)`` tuples, ``score_backend`` lands in
    ``mapper_kwargs``, ``stepper``/``screen`` in ``noc_kwargs``.  Order is
    deterministic (row-major over the axes as given).

        config_grid(mesh=[(8, 8), (16, 16)], seed=[0, 1, 2],
                    objective=["cut", "volume"], mapper="sa_jax")
    """
    fields = {f.name for f in dataclasses.fields(ToolchainConfig)}
    for name in axes:
        if name != "mesh" and name not in _MAPPER_KW_AXES \
                and name not in _NOC_KW_AXES and name not in fields:
            raise ValueError(f"unknown sweep axis {name!r}")
    names = list(axes)
    lists = [v if isinstance(v, (list, tuple)) else [v] for v in axes.values()]
    out = []
    for combo in itertools.product(*lists):
        kw: dict = {}
        mk: dict = {}
        nk: dict = {}
        for name, value in zip(names, combo):
            if name == "mesh":
                kw["mesh_w"], kw["mesh_h"] = value
            elif name in _MAPPER_KW_AXES:
                mk[name] = value
            elif name in _NOC_KW_AXES:
                nk[name] = value
            elif name == "mapper_kwargs":
                mk.update(value)
            elif name == "noc_kwargs":
                nk.update(value)
            else:
                kw[name] = value
        out.append(ToolchainConfig(mapper_kwargs=mk, noc_kwargs=nk, **kw))
    return out


def pareto_flags(rows: list[dict], keys: tuple = PARETO_KEYS) -> list[bool]:
    """Non-dominated flags (minimization on every key) for one workload."""
    vals = [tuple(float(r[k]) for k in keys) for r in rows]
    flags = [True] * len(rows)
    for i, a in enumerate(vals):
        for b in vals:
            if b != a and all(y <= x for x, y in zip(a, b)):
                flags[i] = False
                break
        else:
            # Duplicate points dominate each other under strict `!=` only;
            # equal rows are all kept on the front.
            continue
    return flags


@dataclass
class SweepResult:
    """All sweep rows plus the grid-level wall clock.

    ``rows`` holds one dict per (workload, config): the run's
    `ToolchainResult.summary()` stats (bitwise those of the matching
    single `run_toolchain` call) plus the config axes and a ``pareto``
    flag computed per workload over `PARETO_KEYS`.  Shared-phase seconds
    are amortized over the configs that shared them, so summing
    ``total_s`` over rows reproduces the sweep's real compute.
    """

    rows: list[dict] = field(default_factory=list)
    seconds: float = 0.0
    pareto_keys: tuple = PARETO_KEYS

    def front(self, workload: str | None = None) -> list[dict]:
        return [r for r in self.rows
                if r["pareto"] and workload in (None, r["snn"])]

    def write_csv(self, path) -> None:
        import csv

        with open(path, "w", newline="") as f:
            writer = csv.DictWriter(f, fieldnames=list(self.rows[0]))
            writer.writeheader()
            writer.writerows(self.rows)


def _bucketable(cfg: ToolchainConfig) -> bool:
    """True when the config's search can join a vmapped sa_jax bucket."""
    return (cfg.method == "sneap" and cfg.mapper == "sa_jax"
            and "objective" not in cfg.mapper_kwargs)


def run_sweep(
    profiles,
    configs: list[ToolchainConfig],
    batch_device: bool = True,
    pareto_keys: tuple = PARETO_KEYS,
    progress=None,
) -> SweepResult:
    """Run a config grid over profiled SNN workload(s); see module docstring.

    ``profiles`` is one `ProfileResult` or a list; ``configs`` typically
    comes from `config_grid`.  ``batch_device=False`` disables the vmapped
    sa_jax bucketing (each search then runs through `mapping_phase` like
    any host mapper — useful for parity diffs).  ``progress`` is an
    optional callable receiving short status strings.
    """
    if not isinstance(profiles, (list, tuple)):
        profiles = [profiles]
    say = progress if progress is not None else (lambda msg: None)
    t_sweep = time.perf_counter()
    all_rows: list[dict] = []

    for profile in profiles:
        hyper = profile.graph.hyper
        cfgs = [c.resolve(hyper) for c in configs]
        n = len(cfgs)

        # -- partition phase, deduplicated --------------------------------
        # parts: partition_key -> [PartitionResult, seconds, share_count]
        parts: dict = {}
        for c in cfgs:
            key = c.partition_key()
            if key not in parts:
                t0 = time.perf_counter()
                pres = partition_phase(profile, c)
                parts[key] = [pres, time.perf_counter() - t0, 0]
            parts[key][2] += 1
        say(f"{profile.name}: {len(parts)} partition runs for {n} configs")

        # -- shared traffic matrices and placement objectives --------------
        traffics: dict = {}
        for c in cfgs:
            tk = c.traffic_key()
            if tk not in traffics:
                traffics[tk] = build_traffic(
                    profile, parts[c.partition_key()][0], c)
        objectives: dict = {}

        # -- mapping phase: device buckets + host singles ------------------
        # mapping_out[i] = (mres, place_objective, traffic, trace_len, sec)
        mapping_out: list = [None] * n
        buckets: dict = {}
        for i, c in enumerate(cfgs):
            if batch_device and _bucketable(c):
                bkey = (c.num_cores, c.mesh_w,
                        tuple(sorted(c.mapper_kwargs.items())))
                buckets.setdefault(bkey, []).append(i)

        for bkey, idxs in buckets.items():
            t0 = time.perf_counter()
            from repro.core.mapping_jax import sa_search_jax_batch

            bc = [cfgs[i] for i in idxs]
            for c in bc:
                if c.requested_place == "tree":
                    raise ValueError(
                        "mapper 'sa_jax' cannot run the tree objective"
                    )
            trs = [traffics[c.traffic_key()] for c in bc]
            tls = [int(t.sum()) for t in trs]
            seeds = [phase_seeds(c.seed)[1] for c in bc]
            say(f"{profile.name}: sa_jax bucket of {len(idxs)} configs "
                f"(cores={bkey[0]})")
            mresults = sa_search_jax_batch(
                trs, bc[0].num_cores, bc[0].mesh_w, tls, seeds,
                **bc[0].mapper_kwargs,
            )
            for i, c, mres, tr, tl in zip(idxs, bc, mresults, trs, tls):
                pres = parts[c.partition_key()][0]
                # Same reporting path as mapping_phase's device branch.
                mres.avg_hop, mres.tree_hop = evaluate_placement(
                    mres.placement, tr, c.num_cores, c.mesh_w, tl,
                    mesh_h=c.mesh_h, hyper=hyper, part=pres.part,
                )
                po = ("pairwise" if c.place_objective == "tree"
                      else c.place_objective)
                mapping_out[i] = (mres, po, tr, tl, None)
            per = (time.perf_counter() - t0) / len(idxs)
            for i in idxs:
                mapping_out[i] = mapping_out[i][:4] + (per,)

        for i, c in enumerate(cfgs):
            if mapping_out[i] is not None:
                continue
            pres = parts[c.partition_key()][0]
            traffic = traffics[c.traffic_key()]
            obj = None
            mapper_name = "pso" if c.method == "spinemap" else c.mapper
            if (c.method != "sco" and mapper_name in OBJECTIVE_AWARE_MAPPERS
                    and "objective" not in c.mapper_kwargs):
                okey = c.traffic_key() + (c.place_objective, c.mesh_w, c.mesh_h)
                if okey not in objectives:
                    objectives[okey] = make_objective(
                        c.place_objective, traffic, c.num_cores, c.mesh_w,
                        mesh_h=c.mesh_h, hyper=hyper, part=pres.part,
                    )
                obj = objectives[okey]
            t0 = time.perf_counter()
            mres, po, traffic, tl = mapping_phase(
                profile, pres, c, traffic=traffic, objective=obj)
            mapping_out[i] = (mres, po, traffic, tl,
                              time.perf_counter() - t0)

        # -- evaluation phase + rows ---------------------------------------
        rows: list[dict] = []
        for i, c in enumerate(cfgs):
            entry = parts[c.partition_key()]
            pres, psec = entry[0], entry[1] / entry[2]
            mres, po, traffic, tl, msec = mapping_out[i]
            t0 = time.perf_counter()
            noc = evaluate_phase(profile, pres, mres, c)
            esec = time.perf_counter() - t0
            result = ToolchainResult(
                method=c.method, snn=profile.name, partition=pres,
                mapping=mres, noc=noc,
                phase_seconds={"partition": psec, "mapping": msec,
                               "evaluate": esec},
                objective=c.objective, cast=c.cast, place_objective=po,
            )
            row = result.summary()
            row.update(
                mapper=c.mapper, seed=c.seed, mesh_w=c.mesh_w,
                mesh_h=c.mesh_h, capacity=c.capacity,
                partition_impl=c.partition_impl,
                score_backend=c.mapper_kwargs.get("score_backend", ""),
                stepper=c.noc_kwargs.get("stepper", "numpy"),
                screen=c.noc_kwargs.get("screen", "numpy"),
                knobs=";".join(f"{k}={v}"
                               for k, v in sorted(c.knobs.items())),
            )
            rows.append(row)
        for row, flag in zip(rows, pareto_flags(rows, pareto_keys)):
            row["pareto"] = int(flag)
        all_rows.extend(rows)
        say(f"{profile.name}: {sum(r['pareto'] for r in rows)} of "
            f"{len(rows)} configs on the Pareto front")

    return SweepResult(rows=all_rows,
                       seconds=time.perf_counter() - t_sweep,
                       pareto_keys=pareto_keys)

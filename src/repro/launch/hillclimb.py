import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb: measure optimization variants for the three chosen
cells against their paper-faithful baselines (results/roofline_raw.jsonl).

Each record is one hypothesis->change->measure iteration; the narrative
lives in EXPERIMENTS.md §Perf.
"""
import json
from pathlib import Path

from repro.launch.roofline import measure_cell, model_flops, roofline_terms

OUT = Path("results/perf_iterations.jsonl")

# (tag, arch, shape, config overrides, step kwargs, hypothesis)
VARIANTS = [
    ("ds67b.A1_save_collectives", "deepseek-67b", "train_4k",
     {"remat_policy": "save_collectives"}, {},
     "remat re-runs the 2 TP all-reduces/layer in bwd recompute; saving the "
     "tagged post-collective activations should cut all-reduce bytes ~1/3 "
     "and compute ~25% at the cost of 2*B*S*D bf16 per layer of saved acts"),
    ("ds67b.A2_no_zero1", "deepseek-67b", "train_4k",
     {"remat_policy": "save_collectives"}, {"zero1": False},
     "ZeRO-1 opt sharding forces grad reduce-scatter + param all-gather on "
     "the data axis; replicating opt state should trade those collectives "
     "for 8x more optimizer HBM"),
    ("qwen3moe.B1_save_collectives", "qwen3-moe-30b-a3b", "train_4k",
     {"remat_policy": "save_collectives"}, {},
     "same as A1 for the MoE stack (attention psum + expert-combine psum "
     "are both re-run under full remat)"),
    ("qwen3moe.B2_capacity_1.0", "qwen3-moe-30b-a3b", "train_4k",
     {"remat_policy": "save_collectives", "capacity_factor": 1.0}, {},
     "dispatch buffers scale with capacity; cf 1.25->1.0 cuts expert matmul "
     "FLOPs and dispatch bytes 20% at the cost of more dropped tokens"),
    ("hymba.C1_seq_parallel_decode", "hymba-1.5b", "long_500k",
     {}, {"seq_parallel_decode": True},
     "long_500k has batch=1 so the data axis idles; sharding the global-"
     "layer KV cache sequence over (data x model)=256 should cut per-chip "
     "cache bytes ~16x vs model-only sharding and spread attention FLOPs"),
    ("hymba.C0_baseline_relower", "hymba-1.5b", "long_500k",
     {}, {"seq_parallel_decode": False},
     "re-measure the paper-faithful baseline layout under the current code "
     "as the control for C1"),
    # --- round 2 ---
    ("ds67b.A3_bf16_moments", "deepseek-67b", "train_4k",
     {"remat_policy": "save_collectives"},
     {"zero1": False, "moment_dtype": "bfloat16"},
     "on top of A2, bf16 Adam moments halve optimizer HBM reads+writes "
     "(~16.8 GB/chip/step for 4.2e9 local params); update math stays fp32"),
    ("qwen3moe.B3_bf16_moments", "qwen3-moe-30b-a3b", "train_4k",
     {"remat_policy": "save_collectives", "capacity_factor": 1.0},
     {"moment_dtype": "bfloat16"},
     "same bf16-moment lever on the MoE cell (expert weights dominate "
     "optimizer state)"),
    ("hymba.C2_shard_head_dim", "hymba-1.5b", "long_500k",
     {}, {"seq_parallel_decode": True, "shard_head_dim_fallback": True},
     "C1 left ~8.4 GB/chip of bytes; the replicated attention projections "
     "(25 heads !% 16) are ~0.65 GB/chip of weight reads — sharding their "
     "head_dim (64 % 16 == 0) should recover most of that at the cost of "
     "rope-half resharding collectives"),
]


def main() -> None:
    from repro.configs import get_config

    OUT.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if OUT.exists():
        for line in OUT.read_text().splitlines():
            try:
                done.add(json.loads(line)["tag"])
            except Exception:  # noqa: BLE001
                pass
    for tag, arch, shape, overrides, step_kwargs, hypothesis in VARIANTS:
        if tag in done:
            continue
        print(f"[hillclimb] {tag} ...")
        rec = measure_cell(arch, shape, overrides=overrides or None,
                           step_kwargs=step_kwargs or None)
        rec["tag"] = tag
        rec["hypothesis"] = hypothesis
        if rec["status"] == "ok":
            rec["roofline"] = roofline_terms(rec["counters"])
            cfg = get_config(arch)
            mf = model_flops(cfg, shape)
            hlo_glob = rec["counters"].get("flops", 0.0) * 256
            rec["useful_ratio"] = mf / hlo_glob if hlo_glob else None
        with OUT.open("a") as f:
            f.write(json.dumps(rec) + "\n")
        print(f"[hillclimb] {tag}: {rec['status']} "
              f"{rec.get('roofline', {})}")


if __name__ == "__main__":
    main()

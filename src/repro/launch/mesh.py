"""Mesh construction for the production pods.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis
carries only DCN-class gradient reductions; ICI-class collectives stay
inside a pod.

Everything is a function (never module-level) so importing this module
does not touch jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh", "make_mesh_with_layout",
           "batch_axes_of"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {axes} {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    try:  # more devices than needed (single-pod mesh under the 512 flag)
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older make_mesh without `devices=`
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_mesh_with_layout(device_order: np.ndarray, *, multi_pod: bool = False):
    """Production mesh with a SNEAP-optimized logical->physical layout
    (see repro.sharding.layout): `device_order[i]` is the physical device
    that logical position i should occupy."""
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devs = np.asarray(jax.devices())[np.asarray(device_order)].reshape(shape)
    return Mesh(devs, axes)


def batch_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")

"""Jitted, sharded train/prefill/serve steps for any (arch, mesh).

`make_*_step` returns the jitted function plus the in/out sharding pytrees
(the dry-run lowers the same functions with ShapeDtypeStructs; real
training calls them with live arrays — one code path for both).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.sharding import (ShardingPlan, plan_batch, plan_caches,
                            plan_opt_state, plan_params)

from .mesh import batch_axes_of

__all__ = ["StepBundle", "make_train_step", "make_prefill_step",
           "make_serve_step", "make_plan"]


@dataclass
class StepBundle:
    fn: object  # jitted step
    in_shardings: tuple
    out_shardings: object
    plan: ShardingPlan


def make_plan(mesh, **kw) -> ShardingPlan:
    return ShardingPlan(mesh=mesh, batch_axes=batch_axes_of(mesh), **kw)


def _mesh_info(cfg: ArchConfig, mesh, plan: ShardingPlan):
    if cfg.is_moe and mesh is not None and "model" in mesh.axis_names \
            and mesh.shape["model"] > 1 and cfg.num_experts % mesh.shape["model"] == 0:
        return (mesh, plan.batch_axes)
    return None


def make_train_step(cfg: ArchConfig, mesh, opt: AdamWConfig | None = None,
                    remat: bool = True, zero1: bool = True,
                    kv_chunk: int = 1024,
                    moment_dtype: str | None = None) -> StepBundle:
    model = Model(cfg)
    plan = make_plan(mesh)
    opt = opt or AdamWConfig()
    if moment_dtype is not None:
        import dataclasses as _dc
        opt = _dc.replace(opt, moment_dtype=moment_dtype)
    minfo = _mesh_info(cfg, mesh, plan)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = plan_params(plan, params_shape)
    ospecs = {
        "m": plan_opt_state(plan, params_shape, zero1),
        "v": plan_opt_state(plan, params_shape, zero1),
        "step": P(),
    }

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, mesh_info=minfo, remat=remat,
                                       kv_chunk=kv_chunk)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, stats = adamw_update(params, grads, opt_state, opt)
        return new_params, new_opt, {"loss": loss, **metrics, **stats}

    def batch_specs(batch):
        return plan_batch(plan, batch)

    ns = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    def jit_for(batch_tree):
        bspecs = batch_specs(batch_tree)
        return jax.jit(
            train_step,
            in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs)),
            out_shardings=(ns(pspecs), ns(ospecs),
                           ns(jax.tree.map(lambda _: P(), {
                               "loss": 0, "ce": 0, "aux": 0,
                               "grad_norm": 0, "lr": 0}))),
            donate_argnums=(0, 1),
        )

    bundle = StepBundle(fn=None, in_shardings=(pspecs, ospecs), out_shardings=pspecs,
                        plan=plan)
    bundle.jit_for = jit_for  # shape-dependent jit builder
    bundle.model = model
    bundle.param_specs = pspecs
    bundle.opt_specs = ospecs
    bundle.init_opt = functools.partial(init_opt_state,
                                        moment_dtype=opt.moment_dtype)
    return bundle


def make_prefill_step(cfg: ArchConfig, mesh, cache_len: int,
                      kv_chunk: int = 1024,
                      seq_parallel_decode: bool = True) -> StepBundle:
    model = Model(cfg)
    plan = make_plan(mesh, seq_parallel_decode=seq_parallel_decode)
    minfo = _mesh_info(cfg, mesh, plan)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = plan_params(plan, params_shape)

    def prefill_step(params, batch):
        b, s = batch["tokens"].shape
        caches = model.init_caches(b, cache_len)
        logits, caches, _ = model.forward(
            params, batch["tokens"], mode="prefill", caches=caches,
            frontend=batch.get("frontend"), mesh_info=minfo, kv_chunk=kv_chunk)
        return logits[:, -1:], caches

    ns = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    def jit_for(batch_tree):
        bspecs = plan_batch(plan, batch_tree)
        cache_shape = jax.eval_shape(
            lambda: model.init_caches(batch_tree["tokens"].shape[0], cache_len))
        cspecs = plan_caches(plan, cache_shape)
        out_logits = P()
        return jax.jit(prefill_step,
                       in_shardings=(ns(pspecs), ns(bspecs)),
                       out_shardings=(NamedSharding(mesh, out_logits), ns(cspecs)))

    bundle = StepBundle(fn=None, in_shardings=(pspecs,), out_shardings=None,
                        plan=plan)
    bundle.jit_for = jit_for
    bundle.model = model
    bundle.param_specs = pspecs
    return bundle


def make_serve_step(cfg: ArchConfig, mesh, cache_len: int,
                    kv_chunk: int = 1024,
                    seq_parallel_decode: bool = True,
                    shard_head_dim_fallback: bool = False) -> StepBundle:
    """serve_step: one new token per sequence against the decode cache."""
    model = Model(cfg)
    plan = make_plan(mesh, seq_parallel_decode=seq_parallel_decode,
                     shard_head_dim_fallback=shard_head_dim_fallback)
    minfo = _mesh_info(cfg, mesh, plan)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = plan_params(plan, params_shape)

    def serve_step(params, caches, tokens, positions):
        logits, caches, _ = model.forward(
            params, tokens, mode="decode", caches=caches, positions=positions,
            mesh_info=minfo, kv_chunk=kv_chunk)
        return logits, caches

    ns = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    def jit_for(batch_size: int):
        cache_shape = jax.eval_shape(lambda: model.init_caches(batch_size, cache_len))
        cspecs = plan_caches(plan, cache_shape)
        tok_spec = plan_batch(plan, {
            "tokens": jax.ShapeDtypeStruct((batch_size, 1), jnp.int32)})["tokens"]
        return jax.jit(serve_step,
                       in_shardings=(ns(pspecs), ns(cspecs),
                                     NamedSharding(mesh, tok_spec),
                                     NamedSharding(mesh, tok_spec)),
                       out_shardings=(NamedSharding(mesh, P()), ns(cspecs)),
                       donate_argnums=(1,))  # caches update in place

    bundle = StepBundle(fn=None, in_shardings=(pspecs,), out_shardings=None,
                        plan=plan)
    bundle.jit_for = jit_for
    bundle.model = model
    bundle.param_specs = pspecs
    return bundle

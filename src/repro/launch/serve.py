"""Batched serving driver: prefill a batch of prompts, then decode.

Uses the same prefill/serve steps the dry-run lowers; greedy or
temperature sampling; reports prefill and per-token decode latency:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_prefill_step, make_serve_step

__all__ = ["main", "serve_batch"]


def serve_batch(cfg, mesh, prompts: np.ndarray, gen_len: int,
                temperature: float = 0.0, seed: int = 0,
                frontend: np.ndarray | None = None, print_fn=print) -> dict:
    """prompts: (B, P) int32. Returns generated tokens (B, gen_len)."""
    b, plen = prompts.shape
    cache_len = plen + gen_len
    pre = make_prefill_step(cfg, mesh, cache_len=cache_len)
    srv = make_serve_step(cfg, mesh, cache_len=cache_len)
    params = pre.model.init(jax.random.PRNGKey(seed))

    batch = {"tokens": jnp.asarray(prompts)}
    if frontend is not None:
        batch["frontend"] = jnp.asarray(frontend)
    prefill = pre.jit_for(batch)
    decode = srv.jit_for(b)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed + 1)
    out = np.zeros((b, gen_len), dtype=np.int32)
    tok = logits[:, -1].argmax(-1).reshape(b, 1).astype(jnp.int32) \
        if temperature == 0.0 else None
    if tok is None:
        key, k = jax.random.split(key)
        tok = jax.random.categorical(k, logits[:, -1] / temperature).reshape(b, 1)
    t0 = time.perf_counter()
    for i in range(gen_len):
        out[:, i] = np.asarray(tok)[:, 0]
        positions = jnp.full((b, 1), plen + i, jnp.int32)
        logits, caches = decode(params, caches, tok.astype(jnp.int32), positions)
        if temperature == 0.0:
            tok = logits[:, -1].argmax(-1).reshape(b, 1)
        else:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits[:, -1] / temperature).reshape(b, 1)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    print_fn(f"[serve] batch={b} prefill({plen} tok) {t_prefill*1e3:.1f} ms; "
             f"decode {gen_len} tok x {t_decode/gen_len*1e3:.1f} ms/tok")
    return {"tokens": out, "prefill_s": t_prefill,
            "decode_s_per_tok": t_decode / gen_len}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    frontend = None
    if cfg.family in ("vlm", "audio"):
        frontend = rng.standard_normal(
            (args.batch, cfg.frontend_seq, cfg.frontend_dim)).astype(np.float32)
    res = serve_batch(cfg, mesh, prompts, args.gen,
                      temperature=args.temperature, frontend=frontend)
    print(f"[serve] sample generations (first 10 tokens per row):")
    for row in res["tokens"][:4]:
        print("  ", row[:10].tolist())


if __name__ == "__main__":
    main()

"""Assemble EXPERIMENTS.md tables from the results ledgers."""
from __future__ import annotations

import json
from pathlib import Path


def load_jsonl(path, key=None):
    out = {}
    p = Path(path)
    if not p.exists():
        return out
    for line in p.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        k = key(r) if key else (r.get("arch"), r.get("shape"), r.get("mesh"))
        out[k] = r  # last record wins
    return out


def dryrun_table() -> str:
    cells = load_jsonl("results/dryrun.jsonl")
    rows = ["| arch | shape | mesh | status | compile_s | args GB/chip | temp GB/chip | AR MB | AG MB | notes |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r["status"] == "skip":
            rows.append(f"| {arch} | {shape} | {mesh} | SKIP | — | — | — | — | — | "
                        f"{r['reason'][:60]} |")
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 2**30
        temp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
        ar = coll.get("all-reduce", 0) / 2**20
        ag = coll.get("all-gather", 0) / 2**20
        note = (r.get("plan_notes") or [""])[0][:40]
        rows.append(f"| {arch} | {shape} | {mesh} | {r['status'].upper()} | "
                    f"{r.get('compile_s', 0):.1f} | {args_gb:.2f} | {temp_gb:.2f} | "
                    f"{ar:.1f} | {ag:.1f} | {note} |")
    return "\n".join(rows)


def roofline_table() -> str:
    from repro.configs import get_config
    from repro.launch.roofline import PEAK_FLOPS, model_flops, roofline_terms

    cells = load_jsonl("results/roofline_raw.jsonl",
                       key=lambda r: (r.get("arch"), r.get("shape")))
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "6ND/HLO | roofline frac | what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    hints = {
        "train": "less DUS/copy traffic: fused cache-free train step; bf16 moments; fewer remat reads",
        "prefill": "fewer flash-pass temporaries; larger kv chunks; fused QKV",
        "decode": "quantized (int8) KV cache; grouped multi-token decode to amortize weight reads",
    }
    out = []
    for (arch, shape), r in cells.items():
        if r["status"] != "ok":
            continue
        c = r["counters"]
        rt = roofline_terms(c)
        cfg = get_config(arch)
        mf = model_flops(cfg, shape)
        hlo_glob = c.get("flops", 0) * 256
        ratio = mf / hlo_glob if hlo_glob else float("nan")
        frac = (mf / 256 / PEAK_FLOPS) / rt["bound_s"] if rt["bound_s"] else 0.0
        kind = ("train" if shape.startswith("train") else
                "prefill" if shape.startswith("prefill") else "decode")
        out.append((frac, f"| {arch} | {shape} | {rt['compute_s']:.3g} | "
                    f"{rt['memory_s']:.3g} | {rt['collective_s']:.3g} | "
                    f"{rt['dominant'].replace('_s', '')} | {ratio:.3f} | "
                    f"{frac:.4f} | {hints[kind]} |"))
    for _, row in sorted(out, reverse=True):
        rows.append(row)
    # skips
    for (arch, shape), r in sorted(cells.items()):
        if r["status"] == "skip":
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | SKIP | "
                        f"{r['reason'][:70]} |")
    return "\n".join(rows)


def perf_table() -> str:
    recs = load_jsonl("results/perf_iterations.jsonl", key=lambda r: r.get("tag"))
    base = load_jsonl("results/roofline_raw.jsonl",
                      key=lambda r: (r.get("arch"), r.get("shape")))
    rows = ["| iteration | compute s | memory s | collective s | 6ND/HLO | verdict vs hypothesis |",
            "|---|---|---|---|---|---|"]
    for (arch, shape) in [("deepseek-67b", "train_4k"),
                          ("qwen3-moe-30b-a3b", "train_4k"),
                          ("hymba-1.5b", "long_500k")]:
        b = base.get((arch, shape))
        if b and b.get("roofline"):
            rt = b["roofline"]
            rows.append(f"| **{arch} × {shape} baseline** | {rt['compute_s']:.4g} | "
                        f"{rt['memory_s']:.4g} | {rt['collective_s']:.4g} | "
                        f"{b.get('useful_ratio', 0):.3f} | paper-faithful |")
        for tag, r in sorted(recs.items()):
            if r.get("arch") == arch and r.get("shape") == shape \
                    and r.get("status") == "ok":
                rt = r["roofline"]
                rows.append(f"| {tag} | {rt['compute_s']:.4g} | {rt['memory_s']:.4g} | "
                            f"{rt['collective_s']:.4g} | {r.get('useful_ratio', 0):.3f} | "
                            f"see §Perf narrative |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("## Dry-run\n")
        print(dryrun_table())
    if which in ("roofline", "all"):
        print("\n## Roofline\n")
        print(roofline_table())
    if which in ("perf", "all"):
        print("\n## Perf\n")
        print(perf_table())

"""Post-SPMD HLO analysis: collective bytes, op census, roofline inputs.

Works on `compiled.as_text()` — the partitioned per-device module — so
every shape is already the per-chip shape and summed collective operand
bytes are per-chip wire bytes (what the ICI roofline term wants).

HLO prints operands as bare `%name` references, so a first pass builds a
name -> bytes table from every instruction's result type; the collective
pass then sums the mapped operand sizes.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "op_census", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.-]+)\s*=\s*(\([^=]*?\)|\S+)\s+(\S+?)\(")
_OPERAND_RE = re.compile(r"%[\w.-]+")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nbytes = DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective opcode across the module.

    Async `-start`/`-done` pairs are counted once (at -start).
    Returns {"all-gather": bytes, ..., "_count": total op count}.
    """
    sizes: dict[str, int] = {}
    # Pass 1: result sizes of every named instruction.
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str = m.group(1), m.group(2)
            sizes[name.lstrip("%")] = _shape_bytes(type_str)

    totals: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        base = opcode.removesuffix("-start")
        if base.endswith("-done") or base.rstrip(".0123456789") not in _COLLECTIVES:
            # strip trailing .N id if printed as part of opcode (rare)
            if base not in _COLLECTIVES:
                continue
        base = base if base in _COLLECTIVES else base.rstrip(".0123456789")
        # Operands: %refs inside the first paren group.
        args = line[m.end():]
        close = args.find(")")
        operand_str = args[:close] if close >= 0 else args
        arg_bytes = 0
        for ref in _OPERAND_RE.findall(operand_str):
            arg_bytes += sizes.get(ref.lstrip("%"), 0)
        if arg_bytes == 0:  # operand untracked: use result size as proxy
            arg_bytes = _shape_bytes(m.group(2))
        totals[base] += arg_bytes
        counts[base] += 1
    out = {k: int(v) for k, v in totals.items()}
    out["_count"] = int(sum(counts.values()))
    return out


def op_census(hlo_text: str, opcodes=("fusion", "all-gather", "all-reduce",
                                      "reduce-scatter", "all-to-all",
                                      "collective-permute", "dot", "custom-call",
                                      "copy", "transpose", "reshape",
                                      "dynamic-update-slice")) -> dict[str, int]:
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode = m.group(3).removesuffix("-start")
        base = opcode.rstrip(".0123456789")
        if base in opcodes:
            counts[base] += 1
    return dict(counts)

"""Fault-tolerant checkpointing: atomic, step-tagged, resumable.

Layout:
  <dir>/step_000123/arrays.npz     flattened pytree ('/'-joined key paths)
  <dir>/step_000123/manifest.json  step, treedef repr, dtype/shape index
  <dir>/LATEST                     committed step number (written last)

Writes go to step_*.tmp and are renamed into place before LATEST is
updated, so a host failure mid-write can never corrupt the restore path —
restore always reads the last committed step.  Old steps are pruned with
`keep` retention.  A background-thread `save_async` overlaps the host-side
serialization with the next training step (the device->host copy is the
only synchronous part).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._write_lock = threading.Lock()  # serialize sync vs async writers

    # ------------------------------------------------------------- save
    def save(self, step: int, tree) -> Path:
        host_tree = jax.tree.map(np.asarray, tree)  # device -> host sync
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        self._thread = threading.Thread(target=self._write, args=(step, host_tree))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> Path:
        with self._write_lock:
            return self._write_locked(step, host_tree)

    def _write_locked(self, step: int, host_tree) -> Path:
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit of the step directory
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(str(step))
        os.replace(latest_tmp, self.dir / "LATEST")  # atomic pointer flip
        self._prune()
        return final

    def _prune(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self) -> int | None:
        marker = self.dir / "LATEST"
        if not marker.exists():
            return None
        step = int(marker.read_text().strip())
        return step if (self.dir / f"step_{step:09d}").exists() else None

    def restore(self, template, step: int | None = None):
        """Restore into the structure (and shardings) of `template`."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        z = np.load(self.dir / f"step_{step:09d}" / "arrays.npz")
        flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat_template:
            key = "/".join(
                str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
                for e in path)
            arr = z[key]
            if hasattr(leaf, "sharding"):
                arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), step

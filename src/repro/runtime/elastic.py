"""Elastic scaling: rebuild the mesh after pod/node loss and reshard state.

Recovery path on a real cluster: (1) surviving hosts agree on the new
device set, (2) `make_production_mesh` is rebuilt at the reduced pod
count, (3) the sharding planner re-plans on the new mesh (divisibility
rules may change — e.g. the batch divisor halves when a pod drops), and
(4) parameters/optimizer state are re-placed, either from the live copies
(`remesh_params`) or from the last committed checkpoint
(`CheckpointManager.restore` with the new plan's template).  Data shards
are re-balanced by re-deriving `DataConfig.num_shards` from the new mesh —
the pipeline's (seed, step, shard) determinism makes this a pure re-index.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

__all__ = ["remesh_params"]


def remesh_params(tree, new_mesh: Mesh, new_specs):
    """Re-place a pytree onto a new mesh under new PartitionSpecs.

    Works on live arrays (device-to-device where possible) — the in-memory
    half of elastic recovery.  Values are preserved exactly; only the
    placement changes.
    """
    def place(leaf, spec):
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    return jax.tree.map(place, tree, new_specs)

from .checkpoint import CheckpointManager
from .elastic import remesh_params
from .faults import FaultEvent, FaultSchedule, FaultState, heartbeat_detect
from .health import HeartbeatMonitor

__all__ = [
    "CheckpointManager", "remesh_params", "HeartbeatMonitor",
    "FaultEvent", "FaultSchedule", "FaultState", "heartbeat_detect",
]

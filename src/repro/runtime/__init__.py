from .checkpoint import CheckpointManager
from .elastic import remesh_params
from .health import HeartbeatMonitor

__all__ = ["CheckpointManager", "remesh_params", "HeartbeatMonitor"]

"""Straggler detection and data-shard rebalancing bookkeeping (host-side).

On a real cluster each host reports per-step wall times; the monitor flags
hosts whose trailing-window median exceeds `threshold` x the fleet median
and emits a rebalancing plan (move whole data shards away from stragglers,
in shard units so the deterministic pipeline stays pure).  The dry-run and
tests drive it with synthetic timings.
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field

import numpy as np

__all__ = ["HeartbeatMonitor"]


@dataclass
class HeartbeatMonitor:
    num_hosts: int
    window: int = 16
    threshold: float = 1.5
    _times: dict = field(default_factory=lambda: defaultdict(deque))

    def report(self, host: int, step: int, seconds: float) -> None:
        q = self._times[host]
        q.append(seconds)
        if len(q) > self.window:
            q.popleft()

    def medians(self) -> np.ndarray:
        return np.array([
            np.median(self._times[h]) if self._times[h] else np.nan
            for h in range(self.num_hosts)
        ])

    def stragglers(self) -> list[int]:
        med = self.medians()
        fleet = np.nanmedian(med)
        if not np.isfinite(fleet):
            return []
        return [h for h in range(self.num_hosts)
                if np.isfinite(med[h]) and med[h] > self.threshold * fleet]

    def rebalance_plan(self, shards_per_host: dict[int, int]) -> dict[int, int]:
        """Return new shard counts: stragglers shed ~1/3 of their shards to
        the fastest hosts (shard-granular, total preserved)."""
        plan = dict(shards_per_host)
        lagging = self.stragglers()
        if not lagging:
            return plan
        med = self.medians()
        fast = sorted((h for h in plan if h not in lagging),
                      key=lambda h: med[h] if np.isfinite(med[h]) else np.inf)
        if not fast:
            return plan
        for i, h in enumerate(lagging):
            shed = max(plan[h] // 3, 1) if plan[h] > 1 else 0
            plan[h] -= shed
            plan[fast[i % len(fast)]] += shed
        return plan

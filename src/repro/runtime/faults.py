"""Deterministic fault model for the graceful-degradation scenario driver.

Real many-core neuromorphic platforms lose cores and links at run time;
this module gives the toolchain a seeded, reproducible way to say *when*
and *what*.  A `FaultSchedule` is a sorted list of `FaultEvent`s at
trace-window (SNN time step) granularity; folding the events up to a
window yields a `FaultState` — boolean dead-core / dead-link masks over
the mesh — which `repro.nocsim.simulate_noc(faults=...)` turns into
routing consequences:

  * packets whose source or destination core is dead are **dropped**;
  * packets whose XY route crosses a dead link/core try the **YX escape
    route** (the other dimension order — still static, minimal and
    deadlock-free on what remains of the mesh) and are counted as
    detoured;
  * packets with both orders blocked are dropped.

An empty state (``FaultState.none``) short-circuits to ``faults=None``
inside the simulator, so zero-fault runs stay bit-identical to the
fault-free engines.

`heartbeat_detect` wires `repro.runtime.health.HeartbeatMonitor` in as
the failure-*detection* source: dead cores report pathologically slow
synthetic step times, the monitor's straggler rule flags them, and the
scenario driver re-maps only after the detection window has elapsed —
the window during which spikes are genuinely lost.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.nocsim.xy import link_count, link_endpoints

__all__ = ["FaultEvent", "FaultState", "FaultSchedule", "heartbeat_detect"]


@dataclass(frozen=True)
class FaultEvent:
    """One failure: at window ``t``, the listed cores or links die."""

    t: int
    kind: str  # "core" | "link"
    ids: tuple[int, ...]

    def __post_init__(self):
        if self.kind not in ("core", "link"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        object.__setattr__(self, "ids", tuple(int(i) for i in self.ids))


@dataclass
class FaultState:
    """Cumulative platform health at one point in time (mesh masks)."""

    w: int
    h: int
    dead_cores: np.ndarray  # (w*h,) bool
    dead_links: np.ndarray  # (link_count(w, h),) bool

    @classmethod
    def none(cls, w: int, h: int) -> "FaultState":
        return cls(w, h, np.zeros(w * h, dtype=bool),
                   np.zeros(link_count(w, h), dtype=bool))

    def any(self) -> bool:
        return bool(self.dead_cores.any() or self.dead_links.any())

    def apply(self, event: FaultEvent) -> "FaultState":
        """New state with the event's failures added (inputs untouched)."""
        cores = self.dead_cores.copy()
        links = self.dead_links.copy()
        ids = np.asarray(event.ids, dtype=np.int64)
        if event.kind == "core":
            if ids.size and (ids.min() < 0 or ids.max() >= cores.shape[0]):
                raise ValueError(f"core ids {event.ids} outside mesh {self.w}x{self.h}")
            cores[ids] = True
        else:
            if ids.size and (ids.min() < 0 or ids.max() >= links.shape[0]):
                raise ValueError(f"link ids {event.ids} outside mesh {self.w}x{self.h}")
            links[ids] = True
        return FaultState(self.w, self.h, cores, links)

    def blocked_links(self) -> np.ndarray:
        """(nl,) mask of unusable links: dead ones plus every link whose
        tail or head router is dead (a dead core kills its whole router)."""
        nl = self.dead_links.shape[0]
        tail, head = link_endpoints(np.arange(nl), self.w, self.h)
        return self.dead_links | self.dead_cores[tail] | self.dead_cores[head]

    def alive_cores(self) -> np.ndarray:
        return np.flatnonzero(~self.dead_cores)


@dataclass
class FaultSchedule:
    """Time-sorted failure events over one trace replay."""

    events: list[FaultEvent] = field(default_factory=list)

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: e.t)

    def __len__(self) -> int:
        return len(self.events)

    def event_times(self) -> list[int]:
        return sorted({e.t for e in self.events})

    def events_at(self, t: int) -> list[FaultEvent]:
        return [e for e in self.events if e.t == t]

    def state_at(self, t: int, w: int, h: int) -> FaultState:
        """Cumulative `FaultState` with every event at or before ``t`` applied."""
        state = FaultState.none(w, h)
        for e in self.events:
            if e.t <= t:
                state = state.apply(e)
        return state

    @classmethod
    def random(
        cls,
        w: int,
        h: int,
        n_core_faults: int,
        t_max: int,
        n_link_faults: int = 0,
        seed: int = 0,
        t_min: int = 1,
    ) -> "FaultSchedule":
        """Seeded random schedule: distinct cores/links failing at distinct
        uniformly drawn windows in ``[t_min, t_max)`` — deterministic per
        seed, the generator the failure-rate benchmark sweeps."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        t_max = max(t_max, t_min + 1)
        if n_core_faults:
            cores = rng.choice(w * h, size=n_core_faults, replace=False)
            times = rng.integers(t_min, t_max, n_core_faults)
            events += [FaultEvent(int(t), "core", (int(c),))
                       for t, c in zip(times, cores)]
        if n_link_faults:
            links = rng.choice(link_count(w, h), size=n_link_faults,
                               replace=False)
            times = rng.integers(t_min, t_max, n_link_faults)
            events += [FaultEvent(int(t), "link", (int(l),))
                       for t, l in zip(times, links)]
        return cls(events)


def heartbeat_detect(monitor, dead_cores: np.ndarray,
                     base_s: float = 1.0, slow_factor: float = 8.0) -> list[int]:
    """Drive a `HeartbeatMonitor` with synthetic per-core step times and
    return the cores its straggler rule flags.

    Dead cores report ``slow_factor`` x the healthy step time for the
    monitor's full trailing window — the synthetic stand-in for a core
    that stopped making progress.  The scenario driver treats the returned
    straggler set (not the schedule itself) as the remap trigger, so the
    detection path exercises the same machinery a live deployment would.
    """
    dead_cores = np.asarray(dead_cores, dtype=bool)
    for step in range(monitor.window):
        for core in range(monitor.num_hosts):
            monitor.report(core, step,
                           base_s * slow_factor if dead_cores[core] else base_s)
    return monitor.stragglers()

"""Dynamic-energy model for spike traversal on the NoC.

The paper evaluates *dynamic* energy only (static energy is constant for a
fixed mesh, §5.3.2).  Dynamic energy is proportional to *link traversals*:
every traversal costs one router pass plus one inter-router wire pass.
Under unicast routing traversals equal spike-hops; under multicast XY-tree
routing a branch link is traversed once per firing regardless of how many
destinations lie beyond it, so callers pass the deduplicated tree-link
traversal count (see ``xy.multicast_tree_links``) instead of the
per-destination hop sum.  Constants are representative 32 nm figures
(ORION-class); all paper comparisons are ratios, so the absolute scale
cancels.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["EnergyModel"]


@dataclass(frozen=True)
class EnergyModel:
    router_pj_per_spike: float = 0.98  # switch + arbitration per traversal
    link_pj_per_spike: float = 0.34  # wire pass per traversal
    local_pj_per_spike: float = 0.10  # core-local delivery (no NoC hop)

    @property
    def pj_per_traversal(self) -> float:
        return self.router_pj_per_spike + self.link_pj_per_spike

    def dynamic_energy_pj(self, link_traversals: int, local_spikes: int = 0) -> float:
        """One router+wire pass per link traversal (== hop for unicast,
        distinct (firing, link) tree branch for multicast), plus the
        core-local delivery cost."""
        return (float(link_traversals) * self.pj_per_traversal
                + float(local_spikes) * self.local_pj_per_spike)

"""Dynamic-energy model for spike traversal on the NoC.

The paper evaluates *dynamic* energy only (static energy is constant for a
fixed mesh, §5.3.2).  Dynamic energy is proportional to spike-hops: every
hop costs one router traversal plus one inter-router link traversal.
Constants are representative 32 nm figures (ORION-class); all paper
comparisons are ratios, so the absolute scale cancels.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["EnergyModel"]


@dataclass(frozen=True)
class EnergyModel:
    router_pj_per_spike: float = 0.98  # switch + arbitration per hop
    link_pj_per_spike: float = 0.34  # wire traversal per hop
    local_pj_per_spike: float = 0.10  # core-local delivery (no NoC hop)

    def dynamic_energy_pj(self, total_hops: int, local_spikes: int = 0) -> float:
        per_hop = self.router_pj_per_spike + self.link_pj_per_spike
        return float(total_hops) * per_hop + float(local_spikes) * self.local_pj_per_spike

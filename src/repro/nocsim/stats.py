"""Result record of a NoC replay (shared by every engine).

Lives in its own module so the scalar reference engine (`sim._queued_ref`),
the batched replay (`replay`), and the analytic path can all construct the
same record without import cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

__all__ = ["NoCStats", "edge_stats", "combine_stats"]


@dataclass
class NoCStats:
    avg_latency: float  # cycles, averaged over NoC-traversing packets
    max_latency: int
    avg_hop: float
    total_hops: int
    congestion_count: int  # Eq. 3
    edge_variance: float  # Eq. 4-5
    dynamic_energy_pj: float
    num_noc_spikes: int  # NoC-traversing packets (deduplicated under multicast)
    num_local_spikes: int
    cycles_simulated: int
    # None only on hand-built records (engines always fill it); consumers
    # must guard — see `max_link_load`.
    per_link_hops: np.ndarray | None = field(repr=False, default=None)
    cast: str = "unicast"
    link_traversals: int = 0  # == total_hops for unicast; tree links for multicast
    # Fault accounting (repro.runtime.faults); both stay 0 on healthy
    # meshes so zero-fault records compare bit-identical to pre-fault ones.
    spikes_dropped: int = 0  # packets lost to dead endpoints / unroutable faults
    detour_hops: int = 0  # hops traversed on YX fault-escape routes

    def max_link_load(self) -> int:
        """Heaviest per-link traversal total (0 when loads were not kept)."""
        if self.per_link_hops is None or self.per_link_hops.size == 0:
            return 0
        return int(self.per_link_hops.max())


def edge_stats(per_link_hops: np.ndarray | None) -> float:
    """Edge variance (Eq. 4-5) of a per-link traversal histogram."""
    if per_link_hops is None or per_link_hops.size == 0:
        return 0.0
    return float(np.var(per_link_hops))


def combine_stats(parts: list[NoCStats]) -> NoCStats:
    """Aggregate per-segment replays into one trace-level record.

    The degraded scenario driver replays a trace in segments (between
    failure events, each possibly under a different mapping) and combines
    them here: counters and energies sum, packet-weighted means re-weight,
    maxima max, and edge variance is recomputed from the summed per-link
    histogram.  A single segment passes through unchanged.
    """
    if not parts:
        raise ValueError("combine_stats needs at least one segment")
    if len(parts) == 1:
        return parts[0]
    if len({p.cast for p in parts}) != 1:
        raise ValueError("segments mix casts")
    n_noc = sum(p.num_noc_spikes for p in parts)
    per_link = None
    if all(p.per_link_hops is not None for p in parts):
        per_link = np.sum([p.per_link_hops for p in parts], axis=0)
    return replace(
        parts[0],
        avg_latency=(sum(p.avg_latency * p.num_noc_spikes for p in parts)
                     / n_noc if n_noc else 0.0),
        max_latency=max(p.max_latency for p in parts),
        avg_hop=(sum(p.total_hops for p in parts) / n_noc if n_noc else 0.0),
        total_hops=sum(p.total_hops for p in parts),
        congestion_count=sum(p.congestion_count for p in parts),
        edge_variance=edge_stats(per_link),
        dynamic_energy_pj=sum(p.dynamic_energy_pj for p in parts),
        num_noc_spikes=n_noc,
        num_local_spikes=sum(p.num_local_spikes for p in parts),
        cycles_simulated=sum(p.cycles_simulated for p in parts),
        per_link_hops=per_link,
        link_traversals=sum(p.link_traversals for p in parts),
        spikes_dropped=sum(p.spikes_dropped for p in parts),
        detour_hops=sum(p.detour_hops for p in parts),
    )

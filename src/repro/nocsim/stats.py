"""Result record of a NoC replay (shared by every engine).

Lives in its own module so the scalar reference engine (`sim._queued_ref`),
the batched replay (`replay`), and the analytic path can all construct the
same record without import cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["NoCStats", "edge_stats"]


@dataclass
class NoCStats:
    avg_latency: float  # cycles, averaged over NoC-traversing packets
    max_latency: int
    avg_hop: float
    total_hops: int
    congestion_count: int  # Eq. 3
    edge_variance: float  # Eq. 4-5
    dynamic_energy_pj: float
    num_noc_spikes: int  # NoC-traversing packets (deduplicated under multicast)
    num_local_spikes: int
    cycles_simulated: int
    # None only on hand-built records (engines always fill it); consumers
    # must guard — see `max_link_load`.
    per_link_hops: np.ndarray | None = field(repr=False, default=None)
    cast: str = "unicast"
    link_traversals: int = 0  # == total_hops for unicast; tree links for multicast

    def max_link_load(self) -> int:
        """Heaviest per-link traversal total (0 when loads were not kept)."""
        if self.per_link_hops is None or self.per_link_hops.size == 0:
            return 0
        return int(self.per_link_hops.max())


def edge_stats(per_link_hops: np.ndarray | None) -> float:
    """Edge variance (Eq. 4-5) of a per-link traversal histogram."""
    if per_link_hops is None or per_link_hops.size == 0:
        return 0.0
    return float(np.var(per_link_hops))

"""XY dimension-order routing on a W x H 2D mesh — link indexing helpers.

Directed link id layout (total ``link_count(W, H)`` links):
  * East  (x,y)->(x+1,y): id =                        y*(W-1) + x
  * West  (x,y)->(x-1,y): id = (W-1)*H              + y*(W-1) + (x-1)
  * South (x,y)->(x,y+1): id = 2*(W-1)*H            + x*(H-1) + y
  * North (x,y)->(x,y-1): id = 2*(W-1)*H + W*(H-1)  + x*(H-1) + (y-1)

XY routing resolves X first, then Y — deadlock-free and static, which is
what makes the paper's analytic hop evaluation (and this module's fully
vectorized route expansion) possible.

The route expanders also accept a per-packet ``order`` flag selecting YX
(Y first, then X) instead: the fault-escape routes of the degradation
model (`repro.runtime.faults`) are dimension-ordered too, just along the
other axis, so every structural fact the engines rely on — static routes,
at most two consecutive link-id runs, minimal hop count — holds for both
orders and the same expansion code serves faulty and fault-free meshes.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "link_count",
    "route_hops",
    "next_link",
    "link_endpoints",
    "link_ids_for_routes",
    "multicast_tree_links",
    "multicast_tree_sizes",
    "routes_blocked",
    "span_to",
    "segment_extrema2",
]


def link_count(w: int, h: int) -> int:
    return 2 * (w - 1) * h + 2 * w * (h - 1)


def route_hops(src: np.ndarray, dst: np.ndarray, w: int) -> np.ndarray:
    sx, sy = src % w, src // w
    dx, dy = dst % w, dst // w
    return np.abs(sx - dx) + np.abs(sy - dy)


def next_link(
    cur: np.ndarray, dst: np.ndarray, w: int, h: int,
    yx: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized single dimension-ordered step: returns (next_core, link_id).

    Entries with cur == dst return (cur, -1).  ``yx`` flags packets that
    route Y-first (the fault-escape order); ``None`` keeps the pure XY
    behaviour bit-for-bit.
    """
    cx, cy = cur % w, cur // w
    dx, dy = dst % w, dst // w
    e_base = 0
    w_base = (w - 1) * h
    s_base = 2 * (w - 1) * h
    n_base = s_base + w * (h - 1)

    if yx is None:
        go_e = cx < dx
        go_w = cx > dx
        go_s = (cx == dx) & (cy < dy)
        go_n = (cx == dx) & (cy > dy)
    else:
        yx = np.asarray(yx, dtype=bool)
        h_turn = ~yx | (cy == dy)  # X moves: first leg of XY, last of YX
        v_turn = yx | (cx == dx)  # Y moves: first leg of YX, last of XY
        go_e = (cx < dx) & h_turn
        go_w = (cx > dx) & h_turn
        go_s = (cy < dy) & v_turn
        go_n = (cy > dy) & v_turn

    nxt = cur.copy()
    link = np.full(cur.shape, -1, dtype=np.int64)
    nxt = np.where(go_e, cur + 1, nxt)
    link = np.where(go_e, e_base + cy * (w - 1) + cx, link)
    nxt = np.where(go_w, cur - 1, nxt)
    link = np.where(go_w, w_base + cy * (w - 1) + (cx - 1), link)
    nxt = np.where(go_s, cur + w, nxt)
    link = np.where(go_s, s_base + cx * (h - 1) + cy, link)
    nxt = np.where(go_n, cur - w, nxt)
    link = np.where(go_n, n_base + cx * (h - 1) + (cy - 1), link)
    return nxt, link


def link_endpoints(ids: np.ndarray, w: int, h: int) -> tuple[np.ndarray, np.ndarray]:
    """Decode directed link ids into (tail, head) core ids (layout inverse).

    The tail is the router that drives the link, the head the router it
    enters — the orientation the tree-fork flit engine forks along.
    """
    ids = np.asarray(ids, dtype=np.int64)
    w_base = (w - 1) * h
    s_base = 2 * (w - 1) * h
    n_base = s_base + w * (h - 1)

    tail = np.empty(ids.shape, dtype=np.int64)
    head = np.empty(ids.shape, dtype=np.int64)

    m = ids < w_base  # East (x,y)->(x+1,y)
    y, x = ids[m] // (w - 1), ids[m] % (w - 1)
    tail[m], head[m] = y * w + x, y * w + x + 1

    m = (ids >= w_base) & (ids < s_base)  # West (x,y)->(x-1,y)
    r = ids[m] - w_base
    y, xm1 = r // (w - 1), r % (w - 1)
    tail[m], head[m] = y * w + xm1 + 1, y * w + xm1

    m = (ids >= s_base) & (ids < n_base)  # South (x,y)->(x,y+1)
    r = ids[m] - s_base
    x, y = r // (h - 1), r % (h - 1)
    tail[m], head[m] = y * w + x, (y + 1) * w + x

    m = ids >= n_base  # North (x,y)->(x,y-1)
    r = ids[m] - n_base
    x, ym1 = r // (h - 1), r % (h - 1)
    tail[m], head[m] = (ym1 + 1) * w + x, ym1 * w + x
    return tail, head


def link_ids_for_routes(
    src: np.ndarray, dst: np.ndarray, w: int, h: int, with_steps: bool = False,
    order: np.ndarray | None = None,
) -> tuple[np.ndarray, ...]:
    """Expand each (src, dst) pair's full XY route into directed link ids.

    Returns (link_ids, packet_index) — flat arrays, one entry per traversal.
    With ``with_steps=True`` also returns the 0-based hop index of each
    traversal along its packet's route (the cycle offset at which an
    unobstructed packet crosses that link), which is what the batched
    replay's contention screen schedules against.  Exploits the fact that
    a dimension-ordered route is at most two *consecutive* runs of link
    ids under the layout above.

    ``order`` flags packets routed YX instead of XY (the fault-escape
    order): the vertical run moves to the source column, the horizontal
    run to the destination row, and the step offsets compose Y-leg-first.
    ``None`` is the pure XY expansion, byte-identical to before.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    sx, sy = src % w, src // w
    dx, dy = dst % w, dst // w
    w_base = (w - 1) * h
    s_base = 2 * (w - 1) * h
    n_base = s_base + w * (h - 1)

    if order is None:
        h_row, v_col = sy, dx  # XY: horizontal on source row, vertical on dest column
        yx = None
    else:
        yx = np.asarray(order, dtype=bool)
        h_row = np.where(yx, dy, sy)
        v_col = np.where(yx, sx, dx)

    # Horizontal run (at row h_row).
    east = dx > sx
    west = dx < sx
    h_len = np.abs(dx - sx)
    h_start = np.where(
        east, h_row * (w - 1) + sx,  # E ids x = sx .. dx-1
        np.where(west, w_base + h_row * (w - 1) + dx, 0),  # W ids (x-1) = dx .. sx-1
    )
    # Vertical run (at column v_col).
    south = dy > sy
    north = dy < sy
    v_len = np.abs(dy - sy)
    v_start = np.where(
        south, s_base + v_col * (h - 1) + sy,  # S ids y = sy .. dy-1
        np.where(north, n_base + v_col * (h - 1) + dy, 0),  # N ids (y-1) = dy .. sy-1
    )

    def expand(starts, lens):
        total = int(lens.sum())
        if total == 0:
            e = np.empty(0, dtype=np.int64)
            return e, e, e
        pkt = np.repeat(np.arange(lens.shape[0]), lens)
        cum = np.concatenate([[0], np.cumsum(lens)])
        within = np.arange(total) - np.repeat(cum[:-1], lens)
        return np.repeat(starts, lens) + within, pkt, within

    h_ids, h_pkt, h_within = expand(h_start, h_len)
    v_ids, v_pkt, v_within = expand(v_start, v_len)
    ids = np.concatenate([h_ids, v_ids])
    pkt = np.concatenate([h_pkt, v_pkt])
    if not with_steps:
        return ids, pkt
    # Id runs ascend eastward/southward but a westbound (northbound) packet
    # crosses its run's ids in descending order — flip `within` there.
    # Under XY the vertical run follows the whole horizontal run; under YX
    # the horizontal run follows the whole vertical run.
    h_step = np.where(west[h_pkt], h_len[h_pkt] - 1 - h_within, h_within)
    v_step = np.where(north[v_pkt], v_len[v_pkt] - 1 - v_within, v_within)
    if yx is None:
        v_step = v_step + h_len[v_pkt]
    else:
        h_step = h_step + np.where(yx[h_pkt], v_len[h_pkt], 0)
        v_step = v_step + np.where(yx[v_pkt], 0, h_len[v_pkt])
    return ids, pkt, np.concatenate([h_step, v_step])


def multicast_tree_links(
    src: np.ndarray,
    dst: np.ndarray,
    group: np.ndarray,
    w: int,
    h: int,
    order: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Directed link ids traversed by each group's XY multicast tree.

    ``group`` labels packets that replicate from one firing (same source
    core): because XY routing is deterministic, the unicast routes of one
    group share their common prefix, and the union of the routes is the
    multicast tree — a branch link is traversed *once* per firing no
    matter how many destinations lie beyond it.  Returns (link_ids,
    group_ids), one entry per distinct (group, link) traversal.

    ``order`` routes flagged packets YX (fault escape).  A group must be
    order-pure (all XY or all YX) for the union to stay a tree entered at
    most once per node — the fault layer splits mixed firings into one
    subgroup per order before calling this.
    """
    ids, pkt = link_ids_for_routes(src, dst, w, h, order=order)
    nl = link_count(w, h)
    key = np.unique(group[pkt].astype(np.int64) * nl + ids)
    return key % nl, key // nl


def routes_blocked(
    src: np.ndarray,
    dst: np.ndarray,
    w: int,
    h: int,
    blocked: np.ndarray,
    order: np.ndarray | None = None,
) -> np.ndarray:
    """Per-packet flag: does the dimension-ordered route cross a blocked link?

    ``blocked`` is an (nl,) boolean mask of unusable links (dead links plus
    every link touching a dead core — see `FaultState.blocked_links`).
    Zero-hop routes (src == dst) are never blocked by links.
    """
    src = np.asarray(src, dtype=np.int64)
    out = np.zeros(src.shape[0], dtype=bool)
    ids, pkt = link_ids_for_routes(src, dst, w, h, order=order)
    hit = blocked[ids]
    if hit.any():
        out[pkt[hit]] = True
    return out


def multicast_tree_sizes(
    src: np.ndarray,
    dst: np.ndarray,
    group: np.ndarray,
    w: int,
    h: int,
    num_groups: int,
) -> np.ndarray:
    """Distinct-link count of each group's XY multicast tree, in closed form.

    ``sizes[g]`` is the number of directed links the tree of group ``g``
    traverses — the per-firing flit-hop count of the tree-fork replay, and
    the geometry the tree-hop placement objective
    (`repro.core.placecost.TreeHopObjective`) scores candidate placements
    with, so the mapper and the simulator share one accounting.  Group ids
    must lie in ``[0, num_groups)``; groups may repeat a source core but a
    group's entries must all share one source (as replicas of one firing
    do).

    Under XY routing every route of a group runs horizontally along the
    source's row, then vertically along its destination's column, so the
    union of the routes is: one horizontal segment on the source row
    spanning the leftmost/rightmost destination columns, plus one vertical
    segment per distinct destination column spanning that column's
    farthest destinations above/below the source row.  Summing those span
    lengths counts exactly ``len(multicast_tree_links(...))`` per group
    (pinned by the engine tests) without expanding any route.
    """
    group = np.asarray(group, dtype=np.int64)
    sizes = np.zeros(num_groups, dtype=np.int64)
    if group.shape[0] == 0:
        return sizes
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    dx = dst % w
    dv = dst // w - src // w  # signed vertical offset from the source row
    dh = dx - src % w  # signed horizontal offset from the source column
    # Both reductions are per-segment (min, max) of a signed offset, so
    # each rides on one plain sort of a shift-packed (segment, offset) key:
    # the first entry of a segment is its min, the last its max.  Segments
    # here average only a few entries, so sort + boundary picks beats
    # ufunc.reduceat's per-segment dispatch by ~10x; shift packing keeps
    # the unpack passes at mask/shift cost (int division is the slow part).
    return (
        sizes
        + _packed_span(group * w + dx, dv, h, num_groups, scale=w)  # vertical
        + _packed_span(group, dh, w, num_groups)  # horizontal, source row
    )


def span_to(origin, lo, hi):
    """Length of the directed-link segment from ``origin`` toward [lo, hi].

    ``max(hi - origin, 0) + max(origin - lo, 0)`` — the closed-form link
    count of one tree segment (a row span measured from the source column,
    or a column span measured from the source row), elementwise.  Computed
    as the identical ``max(hi, origin) - min(lo, origin)`` (equal whenever
    ``origin`` lies inside the dimension, whether or not the interval is
    empty) — one op fewer, and the empty-interval sentinels the aggregate
    tables use (``lo`` = dimension size, ``hi`` = -1) still make the span
    0 without masking.
    """
    return np.maximum(hi, origin) - np.minimum(lo, origin)


def segment_extrema2(
    seg: np.ndarray, val: np.ndarray, vmax: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Occupied-segment (ids, count, min1, min2, max1, max2) of ``val``.

    The top-2 reduction behind the tree-hop objective's incremental
    aggregates (`repro.core.placecost.TreeHopObjective`): knowing the two
    extreme members of every segment makes removing a *non-extreme* member
    free and removing the extreme an O(1) fallback to the runner-up, so a
    single-destination move re-prices a multicast-tree segment without
    rescanning it.  One packed sort (`_packed_span`'s idiom: segments
    contiguous, values ascending inside) yields all four extrema as
    boundary picks.  ``val`` must lie in [0, vmax).

    The reduction is *sparse*: only segments that have members are
    reported, in ascending segment-id order, and the caller scatters into
    (and sentinel-resets) its own tables — the segment space here is the
    (edge, mesh column) grid, mostly empty at large meshes, and never
    materializing the empty cells keeps a rebuild proportional to the
    members touched, not the mesh.  Singleton segments carry the
    ``vmax``/-1 runner-up sentinels `span_to` maps to span 0, so "no
    runner-up" needs no separate masking downstream.
    """
    seg = np.asarray(seg, dtype=np.int64)
    val = np.asarray(val, dtype=np.int64)
    if seg.shape[0] == 0:
        z = np.empty(0, dtype=np.int64)
        return z, z, z, z, z, z
    bits = int(max(vmax - 1, 1)).bit_length()
    key = (seg << bits) | val
    if ((int(seg.max()) + 1) << bits) < np.iinfo(np.int32).max:
        key = np.sort(key.astype(np.int32)).astype(np.int64)
    else:
        key = np.sort(key)
    kseg = key >> bits
    kval = key & ((1 << bits) - 1)
    m = key.shape[0]
    last = np.empty(m, dtype=bool)
    last[-1] = True
    np.not_equal(kseg[1:], kseg[:-1], out=last[:-1])
    first = np.empty(m, dtype=bool)
    first[0] = True
    first[1:] = last[:-1]
    fidx = np.flatnonzero(first)
    lidx = np.flatnonzero(last)
    useg = kseg[fidx]
    count = lidx - fidx + 1
    min1 = kval[fidx]
    max1 = kval[lidx]
    has2 = count > 1
    min2 = np.where(has2, kval[np.minimum(fidx + 1, m - 1)], vmax)
    max2 = np.where(has2, kval[np.maximum(lidx - 1, 0)], -1)
    return useg, count, min1, min2, max1, max2


def _packed_span(seg: np.ndarray, off: np.ndarray, radius: int,
                 num_groups: int, scale: int = 1) -> np.ndarray:
    """Per-group sum over segments of (max(off, 0) - min(off, 0)).

    ``off`` must lie in (-radius, radius); the group of segment ``s`` is
    ``s // scale``.  One sort of ``(seg << bits) | (off + radius)`` orders
    segments contiguously with offsets ascending inside, so each segment's
    min/max are its boundary entries.  Sorts in int32 when the packed key
    fits — ~2x faster for the sizes the mapping engine batches.
    """
    bits = int(2 * radius - 1).bit_length()
    key = (seg << bits) | (off + radius)
    top = (int(seg.max()) + 1) << bits
    if top < np.iinfo(np.int32).max:
        key = np.sort(key.astype(np.int32))
    else:
        key = np.sort(key)
    kseg = key >> bits
    m = key.shape[0]
    last = np.empty(m, dtype=bool)
    last[-1] = True
    np.not_equal(kseg[1:], kseg[:-1], out=last[:-1])
    first = np.empty(m, dtype=bool)
    first[0] = True
    first[1:] = last[:-1]
    mask = (1 << bits) - 1
    span = ((key[last] & mask) - radius).clip(min=0) \
        - ((key[first] & mask) - radius).clip(max=0)
    gid = kseg[last]
    if scale != 1:
        gid = gid // scale
    return np.bincount(gid, weights=span,
                       minlength=num_groups).astype(np.int64)

"""Trace-driven NoC simulator substrate (the toolchain's evaluation phase).

A Noxim++ substitute at the abstraction the paper measures: XY
deterministic routing on a W x H 2D mesh, per-link bandwidth limits per
cycle, per-core injection limits (a crossbar sends at most `capacity`
spikes per time step), and the four paper metrics — average spike latency,
dynamic energy, congestion count (Eq. 3) and edge variance (Eq. 4-5).

Two traffic models (``simulate_noc``'s ``cast``):

* ``unicast`` — every spike transmission is an independent packet; a
  neuron whose spikes fan out over d synapses injects d packets.  This is
  the replay model the paper's edge-cut objective implicitly assumes.
* ``multicast`` — one packet per (firing, destination core), delivered
  along the XY multicast tree (the union of the deterministic XY routes,
  which share their common prefix).  Link loads, edge variance and dynamic
  energy count each (firing, link) branch traversal once — the model the
  ``objective="volume"`` partitioning metric (`repro.core.graph.comm_volume`)
  optimizes, so partitioner and simulator measure the same quantity.  The
  queued replay simulates true tree-fork flits: one flit per firing forks
  at branch routers (`replay.queued_multicast_tree`), so latency and
  congestion are router-faithful rather than replica-based upper bounds.

The queued replay runs on the batched two-tier engine in `repro.nocsim.replay`
(contention screening + joint congested-window stepping); the scalar
reference engine survives as ``simulate_noc(engine="ref")`` for parity
diffs and as the replica-based multicast baseline.
"""
from .energy import EnergyModel
from .sim import NoCStats, dedupe_firings, simulate_noc
from .stats import combine_stats
from .xy import (
    link_count,
    link_endpoints,
    link_ids_for_routes,
    multicast_tree_links,
    multicast_tree_sizes,
    route_hops,
    routes_blocked,
)

__all__ = [
    "EnergyModel", "NoCStats", "combine_stats", "dedupe_firings",
    "simulate_noc", "link_count", "link_endpoints", "link_ids_for_routes",
    "multicast_tree_links", "multicast_tree_sizes", "route_hops",
    "routes_blocked",
]

"""Trace-driven NoC simulator substrate (the toolchain's evaluation phase).

A Noxim++ substitute at the abstraction the paper measures: XY
deterministic routing on a W x H 2D mesh, per-link bandwidth limits per
cycle, per-core injection limits (a crossbar sends at most `capacity`
spikes per time step), and the four paper metrics — average spike latency,
dynamic energy, congestion count (Eq. 3) and edge variance (Eq. 4-5).
"""
from .energy import EnergyModel
from .sim import NoCStats, simulate_noc
from .xy import link_count, link_ids_for_routes, route_hops

__all__ = [
    "EnergyModel", "NoCStats", "simulate_noc",
    "link_count", "link_ids_for_routes", "route_hops",
]

"""Trace-driven NoC simulator substrate (the toolchain's evaluation phase).

A Noxim++ substitute at the abstraction the paper measures: XY
deterministic routing on a W x H 2D mesh, per-link bandwidth limits per
cycle, per-core injection limits (a crossbar sends at most `capacity`
spikes per time step), and the four paper metrics — average spike latency,
dynamic energy, congestion count (Eq. 3) and edge variance (Eq. 4-5).

Two traffic models (``simulate_noc``'s ``cast``):

* ``unicast`` — every spike transmission is an independent packet; a
  neuron whose spikes fan out over d synapses injects d packets.  This is
  the replay model the paper's edge-cut objective implicitly assumes.
* ``multicast`` — one packet per (firing, destination core), replicated
  along the XY multicast tree (the union of the deterministic XY routes,
  which share their common prefix).  Link loads, edge variance and dynamic
  energy count each (firing, link) branch traversal once — the model the
  ``objective="volume"`` partitioning metric (`repro.core.graph.comm_volume`)
  optimizes, so partitioner and simulator finally measure the same
  quantity.
"""
from .energy import EnergyModel
from .sim import NoCStats, dedupe_firings, simulate_noc
from .xy import (
    link_count,
    link_ids_for_routes,
    multicast_tree_links,
    route_hops,
)

__all__ = [
    "EnergyModel", "NoCStats", "dedupe_firings", "simulate_noc",
    "link_count", "link_ids_for_routes", "multicast_tree_links", "route_hops",
]

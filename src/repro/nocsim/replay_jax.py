"""Optional JAX device path for the joint congested-window stepper.

A ``lax.while_loop`` version of `replay._joint_stepper` for large traces:
fixed-size state (no compaction), one fused device pass per NoC cycle.
Grant decisions mirror the numpy stepper exactly — per window-tagged link,
the ``link_capacity`` oldest-injected packets win, stable by record order —
so latencies and congestion are identical; only the execution substrate
differs.  Imported lazily by ``simulate_noc(stepper="jax")`` so the default
numpy path never pays the JAX import.

Runs under JAX's default 32-bit ints: the wrapper checks that window-tagged
link ids, cycles, and the blocked-packet count all fit, and refuses
otherwise (fall back to the numpy stepper).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["joint_stepper_jax"]

_SENTINEL = np.int32(2**31 - 1)


def _next_link_jnp(cur, dst, w: int, h: int):
    """jnp mirror of ``xy.next_link`` (single XY step -> next core, link)."""
    cx, cy = cur % w, cur // w
    dx, dy = dst % w, dst // w
    e_base = 0
    w_base = (w - 1) * h
    s_base = 2 * (w - 1) * h
    n_base = s_base + w * (h - 1)

    go_e = cx < dx
    go_w = cx > dx
    go_s = (cx == dx) & (cy < dy)
    go_n = (cx == dx) & (cy > dy)

    nxt = cur
    link = jnp.full(cur.shape, -1, dtype=jnp.int32)
    nxt = jnp.where(go_e, cur + 1, nxt)
    link = jnp.where(go_e, e_base + cy * (w - 1) + cx, link)
    nxt = jnp.where(go_w, cur - 1, nxt)
    link = jnp.where(go_w, w_base + cy * (w - 1) + (cx - 1), link)
    nxt = jnp.where(go_s, cur + w, nxt)
    link = jnp.where(go_s, s_base + cx * (h - 1) + cy, link)
    nxt = jnp.where(go_n, cur - w, nxt)
    link = jnp.where(go_n, n_base + cx * (h - 1) + (cy - 1), link)
    return nxt, link


@functools.partial(jax.jit,
                   static_argnames=("w", "h", "nl", "capacity", "max_cycles"))
def _run(cur, wd, inject, win, valid, *, w: int, h: int, nl: int,
         capacity: int, max_cycles: int):
    # ``valid`` masks padding: padded records start out arrived, so they
    # are never active, their sentinel tags sort to the tail, and no grant
    # decision of a real packet can see them — bitwise parity with the
    # unpadded run (pinned by the stepper parity tests).
    n = cur.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, arrived, _, _, _, cycle = state
        return (~jnp.all(arrived)) & (cycle < max_cycles)

    def body(state):
        cur, arrived, lat, cong, over, cycle = state
        active = (~arrived) & (inject <= cycle)
        nxt, link = _next_link_jnp(cur, wd, w, h)
        tag = jnp.where(active, win * nl + link, _SENTINEL)
        order = jnp.lexsort((idx, inject, tag))
        st = tag[order]
        newg = jnp.concatenate([jnp.ones(1, dtype=bool), st[1:] != st[:-1]])
        start = lax.cummax(jnp.where(newg, idx, 0))
        go_sorted = ((idx - start) < capacity) & active[order]
        go = jnp.zeros(n, dtype=bool).at[order].set(go_sorted)
        cong = cong + active.sum(dtype=jnp.int32) - go.sum(dtype=jnp.int32)
        # Latch before a 32-bit wrap is possible: per-cycle growth is < n
        # <= 2^30 (guarded in the wrapper), so cong passes 2^30 before it
        # can exceed 2^31.
        over = over | (cong >= jnp.int32(1 << 30))
        cur = jnp.where(go, nxt, cur)
        newly = go & (cur == wd)
        lat = jnp.where(newly, cycle + 1, lat)
        return cur, arrived | newly, lat, cong, over, cycle + 1

    init = (cur, ~valid, jnp.zeros(n, dtype=jnp.int32),
            jnp.int32(0), jnp.bool_(False), jnp.int32(0))
    _, arrived, lat, cong, over, cycle = lax.while_loop(cond, body, init)
    return lat, cong, jnp.all(arrived), over


def joint_stepper_jax(
    src: np.ndarray,
    dst: np.ndarray,
    inject: np.ndarray,
    win: np.ndarray,
    w: int,
    h: int,
    nl: int,
    link_capacity: int,
    max_cycles: int,
) -> tuple[np.ndarray, int]:
    """Drop-in device replacement for ``replay._joint_stepper``.

    The packet arrays are zero-padded to the next power of two (with a
    validity mask that keeps padded records inert), so replays of
    different traces — e.g. across a sweep's config grid — bucket into a
    handful of compiled program shapes instead of recompiling per trace
    length.  Padding is invisible in the results: grant decisions,
    latencies, and the congestion count are bitwise the unpadded run's.
    """
    n_cwin = int(win.max()) + 1 if win.shape[0] else 0
    n = int(src.shape[0])
    if (n_cwin * nl >= int(_SENTINEL) or max_cycles >= int(_SENTINEL)
            or n >= 1 << 30):
        raise ValueError("trace too large for the 32-bit JAX stepper; "
                         "use stepper='numpy'")
    m = 1 << max(n - 1, 0).bit_length() if n else 1  # next pow2, min 1
    pad = m - n

    def padded(a: np.ndarray) -> jnp.ndarray:
        a = np.asarray(a, dtype=np.int32)
        if pad:
            a = np.concatenate([a, np.zeros(pad, dtype=np.int32)])
        return jnp.asarray(a)

    valid = np.zeros(m, dtype=bool)
    valid[:n] = True
    lat, cong, drained, over = _run(
        padded(src), padded(dst), padded(inject), padded(win),
        jnp.asarray(valid),
        w=w, h=h, nl=nl, capacity=link_capacity, max_cycles=max_cycles)
    if bool(over):
        raise ValueError("blocked-packet count exceeds 32 bits; "
                         "use stepper='numpy'")
    if not bool(drained):
        raise RuntimeError("NoC window failed to drain — capacity too low?")
    return np.asarray(lat, dtype=np.int64)[:n], int(cong)

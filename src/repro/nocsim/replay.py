"""Batched two-tier queued NoC replay (the fast path behind ``simulate_noc``).

The scalar reference engine (`sim._queued_ref`) replays one SNN time-step
window at a time with a Python ``while`` loop and several lexsorts per NoC
cycle.  This module replaces it with a two-tier engine built on the one
structural fact XY routing gives us for free: routes are static, so the
*unobstructed* schedule of every packet — which link it wants at which
cycle — is known up front.

Tier 1 (contention screens, no cycle stepping):
  * Overloaded pairs.  An XY route crosses a directed link at most once,
    so a (window, link) pair's per-cycle demand is bounded by its
    whole-window load no matter how blocked packets repeat requests.
    Pairs at or under ``link_capacity`` can therefore never block, and a
    packet whose route avoids every overloaded pair is exact
    analytically: latency = injection stagger + hops.  Loads come from a
    ``bincount`` over the vectorized route expansion, or — on an
    accelerator — from the ``kernels/link_load`` indicator-matmul
    machinery via ``window_link_loads`` (per-window core-to-core traffic
    matrices), in which case routes are only expanded for windows that
    have an overloaded pair at all.
  * Static schedule screen.  Packet ``p`` crosses the ``j``-th link of its
    route at cycle ``inject(p) + j`` when nothing blocks; a window where
    no (cycle, link) bucket exceeds ``link_capacity`` under that schedule
    is self-consistent and contention-free even though some pair is
    overloaded in total (injection stagger diffuses it).  Those windows
    are scored analytically too.

Tier 2 (joint congested stepping): the surviving packets of all contending
windows are simulated in *one* vectorized cycle loop.  Packets from
different windows cannot interact, so links are tagged with a compact
window offset and arbitration runs across the concatenated packet set —
one numpy pass per cycle over every congested window instead of a Python
loop per window.  The loop keeps per-cycle work at a handful of O(active)
passes: packets are pre-sorted by the static arbitration priority (active
set = a row prefix, grants = one stable argsort over oversubscribed links
only), remaining (window, link) loads are maintained incrementally so a
window whose last overloaded pair drains finishes analytically mid-flight,
and windows that stay block-free for `_RESCREEN_EVERY` cycles are
re-screened against their remaining forward schedule and finished once it
fits capacity.

Every tier reproduces the reference engine's arbitration (per-link grants
to the ``link_capacity`` oldest-injected packets, stable order) exactly,
so unicast stats are bit-identical to ``_queued_ref``.

Multicast replays use true tree-fork flits (`queued_multicast_tree`): a
firing injects *one* flit that forks at branch routers — state is one
entity per (firing, tree link), each tree link is traversed once, and a
child link becomes ready the cycle after its parent is granted.  This
replaces the replica-based upper bound (ROADMAP item 2): latency and
congestion are those of a real multicast router, and the engine simulates
``tree links`` flit-hops instead of ``sum of replica routes`` — the
faithful model is also the faster one.  Link loads and dynamic energy keep
the exact tree accounting both engines already shared.

An optional JAX device stepper (``stepper="jax"``, `replay_jax`) runs the
joint congested loop as a ``lax.while_loop`` for large traces.
"""
from __future__ import annotations

import numpy as np

from .energy import EnergyModel
from .stats import NoCStats, edge_stats
from .xy import (
    link_count,
    link_endpoints,
    link_ids_for_routes,
    multicast_tree_links,
    route_hops,
)

__all__ = ["queued_unicast", "queued_multicast_tree"]

_INF = np.iinfo(np.int64).max // 4
# Attempt the exact (cycle, link) schedule screen at a blocking-free cycle
# at most every this many cycles (it re-expands remaining routes; cheap but
# not per-cycle cheap — the load-based over_cnt exit is the per-cycle one).
_RESCREEN_EVERY = 8
# Saturation detector (see queued_unicast): windows whose peak link load
# provably exceeds what any schedule could grant (load > capacity x the
# unobstructed cycle span — pigeonhole) are marked congested outright and
# bypass the static schedule screen; the screen's (window, cycle, link)
# sort runs only over the remaining screenable windows.  On saturated
# traces it admits (almost) nothing, so classifying those windows by the
# O(traversals) load bound instead closes the `saturated_unicast` speed
# gap without giving up the screen's pruning on merely-bursty traces.
# Results are unchanged either way: the detector only decides *who* is
# stepped, and the stepper reproduces the reference arbitration exactly.


# --------------------------------------------------------------- shared


def _window_ids(t: np.ndarray) -> tuple[np.ndarray, int]:
    """Compact window id per record of a t-sorted trace."""
    if t.shape[0] == 0:
        return np.empty(0, dtype=np.int64), 0
    new = np.empty(t.shape[0], dtype=bool)
    new[0] = True
    np.not_equal(t[1:], t[:-1], out=new[1:])
    win = np.cumsum(new) - 1
    return win, int(win[-1]) + 1


def _group_ranks(key: np.ndarray) -> np.ndarray:
    """Stable 0-based rank of each element within its key group."""
    n = key.shape[0]
    order = np.argsort(key, kind="stable")
    sk = key[order]
    new = np.empty(n, dtype=bool)
    new[0] = True
    np.not_equal(sk[1:], sk[:-1], out=new[1:])
    start = np.maximum.accumulate(np.where(new, np.arange(n), 0))
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n) - start
    return rank


def _inject_cycles(win: np.ndarray, src: np.ndarray, ncores: int,
                   inject_capacity: int) -> np.ndarray:
    """Crossbar egress stagger: the r-th injection from a core this window
    enters the NoC at cycle r // inject_capacity (reference semantics)."""
    return _group_ranks(win * np.int64(ncores) + src) // inject_capacity


def _capacity_grants(sorted_keys: np.ndarray, link_capacity: int) -> np.ndarray:
    """Grant mask over a key-sorted request array: True for the first
    ``link_capacity`` requests of each key group (the shared arbitration
    rule of both steppers — callers sort so that within a group the oldest
    requests come first)."""
    m = sorted_keys.shape[0]
    new = np.empty(m, dtype=bool)
    new[0] = True
    np.not_equal(sorted_keys[1:], sorted_keys[:-1], out=new[1:])
    start = np.maximum.accumulate(np.where(new, np.arange(m), 0))
    return (np.arange(m) - start) < link_capacity


def _hot_pairs(
    wl_key: np.ndarray,
    n_win: int,
    nl: int,
    link_capacity: int,
) -> tuple[np.ndarray, np.ndarray | None]:
    """Overloaded (window * nl + link) keys from per-traversal keys.

    Returns (sorted hot keys, dense per-key counts or None).  Only links
    whose *whole-window* load exceeds capacity can ever block: an XY route
    crosses a directed link at most once, so a link's per-cycle demand is
    bounded by its distinct-packet total no matter how requests repeat.
    """
    space = n_win * nl
    if space <= _DENSE_SCREEN_SPACE:
        counts = np.bincount(wl_key, minlength=space)
        return np.flatnonzero(counts > link_capacity), counts
    keys, counts = np.unique(wl_key, return_counts=True)
    return keys[counts > link_capacity], None


def _member(sorted_keys: np.ndarray, query: np.ndarray) -> np.ndarray:
    """Boolean membership of ``query`` values in a sorted key array."""
    if sorted_keys.shape[0] == 0:
        return np.zeros(query.shape[0], dtype=bool)
    pos = np.minimum(np.searchsorted(sorted_keys, query),
                     sorted_keys.shape[0] - 1)
    return sorted_keys[pos] == query


def _window_loads_linkload(
    win: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    n_win: int,
    w: int,
    h: int,
    backend: str,
) -> np.ndarray:
    """Per-window (n_win, nl) link loads via the kernels/link_load machinery.

    Builds per-window core-to-core traffic matrices and runs the
    indicator-matmul load maps batched over windows — the device
    alternative to histogramming the route expansion.  For multicast this
    is fed replica packets, whose pairwise loads upper-bound the tree
    loads — a sound (if looser) overload screen.
    """
    from repro.kernels.link_load import window_link_loads

    k = w * h
    nl = link_count(w, h)
    out = np.empty((n_win, nl), dtype=np.int64)
    # Chunk windows so the host-side (B, K, K) histogram stays bounded.
    step = max(1, (1 << 24) // (k * k))
    for lo in range(0, n_win, step):
        m = (win >= lo) & (win < lo + step)
        b = min(step, n_win - lo)
        key = ((win[m] - lo) * k + src_core[m]) * k + dst_core[m]
        counts = np.bincount(key, minlength=b * k * k).reshape(b, k, k)
        out[lo:lo + b] = window_link_loads(counts, w, h, backend=backend)
    return out


# Below this (window * cycle * link) key-space size the demand screen uses a
# dense bincount (O(n + space)); above it, a sort-based unique.
_DENSE_SCREEN_SPACE = 1 << 26


def _schedule_congested(
    sched_win: np.ndarray,
    sched_cycle: np.ndarray,
    sched_link: np.ndarray,
    nl: int,
    link_capacity: int,
) -> np.ndarray:
    """Window ids whose unobstructed (cycle, link) demand exceeds capacity."""
    if sched_win.shape[0] == 0:
        return np.empty(0, dtype=np.int64)
    span = int(sched_cycle.max()) + 1
    space = (int(sched_win.max()) + 1) * span * nl
    if space >= _INF:
        raise OverflowError("window/cycle/link key space too large to pack")
    key = (sched_win * span + sched_cycle) * nl + sched_link
    if space <= _DENSE_SCREEN_SPACE:
        counts = np.bincount(key, minlength=space)
        return np.unique(np.flatnonzero(counts > link_capacity) // (span * nl))
    keys, counts = np.unique(key, return_counts=True)
    return np.unique(keys[counts > link_capacity] // (span * nl))


def _per_window_max(values: np.ndarray, win: np.ndarray, n_win: int) -> np.ndarray:
    out = np.zeros(n_win, dtype=np.int64)
    np.maximum.at(out, win, values)
    return out


# ------------------------------------------------------------- unicast


def queued_unicast(
    trace_t: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    w: int,
    h: int,
    link_capacity: int,
    inject_capacity: int,
    energy: EnergyModel,
    n_local: int,
    max_cycles_per_window: int = 100_000,
    stepper: str = "numpy",
    screen: str = "numpy",
    order: np.ndarray | None = None,
) -> NoCStats:
    """Batched unicast queued replay; bit-identical to ``sim._queued_ref``.

    Inputs are the NoC-bound (remote) records only, t-sorted; ``n_local``
    carries the core-local delivery count for energy accounting.
    ``order`` flags records routed YX (fault-escape detours; numpy screen
    and stepper only) — ``None`` is the pure XY replay.
    """
    nl = link_count(w, h)
    ncores = w * h
    n = int(trace_t.shape[0])
    if n == 0:
        return _stats(np.empty(0, np.int64), 0, 0, np.zeros(nl, np.int64),
                      np.zeros(nl, np.int64), 0, n_local, energy, "unicast", 0)
    if order is not None and (stepper != "numpy"
                              or screen in ("linkload", "pallas", "interpret", "jnp")):
        raise ValueError("fault-escape routes require numpy stepper/screen")
    win, n_win = _window_ids(trace_t)
    inject = _inject_cycles(win, src_core, ncores, inject_capacity)
    hops = route_hops(src_core, dst_core, w)
    total_hops = int(hops.sum())

    # Tier 1: whole-window (window, link) loads -> overloaded pairs.  Only
    # packets whose route crosses an overloaded pair can ever be blocked
    # (or delay anything), so everything else is scored analytically.
    sids = spkt = sstep = None
    if screen in ("linkload", "pallas", "interpret", "jnp"):
        # Device path: per-window load maps via the link_load kernels; the
        # route expansion is only materialized for dirty windows.
        backend = "jnp" if screen in ("linkload", "jnp") else screen
        loads = _window_loads_linkload(win, src_core, dst_core, n_win, w, h,
                                       backend)
        per_link = loads.sum(axis=0)
        hot_keys = np.flatnonzero(loads.ravel() > link_capacity)
        stepped = np.zeros(n, dtype=bool)
        if hot_keys.shape[0]:
            dirty = np.zeros(n_win, dtype=bool)
            dirty[hot_keys // nl] = True
            sel = np.flatnonzero(dirty[win])
            ids, pkt = link_ids_for_routes(src_core[sel], dst_core[sel], w, h)
            pm = _member(hot_keys, win[sel[pkt]] * np.int64(nl) + ids)
            stepped[sel[np.unique(pkt[pm])]] = True
    else:
        # One route expansion serves both tiers: the (window, link) load
        # screen below and — via boolean masking that preserves the exact
        # h-runs-then-v-runs traversal order a subset re-expansion would
        # produce — the stepped packets' link/step arrays.  On saturated
        # traces (stepped ~= everything) this halves the expansion work,
        # the dominant cold-start cost of the batched engine.
        ids, pkt, steps = link_ids_for_routes(src_core, dst_core, w, h,
                                              order=order, with_steps=True)
        per_link = np.bincount(ids, minlength=nl)
        wl_key = win[pkt] * np.int64(nl) + ids
        hot_keys, counts = _hot_pairs(wl_key, n_win, nl, link_capacity)
        stepped = np.zeros(n, dtype=bool)
        if hot_keys.shape[0]:
            pm = (counts[wl_key] > link_capacity if counts is not None
                  else _member(hot_keys, wl_key))
            stepped[pkt[pm]] = True
            if stepped.any():
                tm = stepped[pkt]
                sids, sstep = ids[tm], steps[tm]
                spkt = (np.cumsum(stepped) - 1)[pkt[tm]]
    lat = inject + hops  # analytic fast path (exact off overloaded pairs)
    congestion = 0
    if stepped.any():
        sidx = np.flatnonzero(stepped)
        if sids is None:  # device screen materialized only dirty windows
            sids, spkt, sstep = link_ids_for_routes(
                src_core[sidx], dst_core[sidx], w, h, with_steps=True,
                order=order[sidx] if order is not None else None)
        # Static schedule screen: windows whose stepped packets never
        # oversubscribe any (cycle, link) bucket under the unobstructed
        # schedule (inject + step) cannot block — their overload is
        # diffused by injection stagger.  Keep only truly contending ones.
        # Saturation detector: a window whose peak link load exceeds
        # capacity x its unobstructed cycle span is congested by
        # pigeonhole — no schedule can grant that demand — so it skips
        # the screen's (window, cycle, link) sort; on fully saturated
        # traces that empties the screen entirely (the old
        # `saturated_unicast` 0.8x gap), while merely-bursty windows
        # still get screened (where the pruning pays for itself).
        uwin0 = np.unique(win[sidx])
        cwin0 = np.searchsorted(uwin0, win[sidx])
        nw0 = uwin0.shape[0]
        cw_t = cwin0[spkt]
        sched = inject[sidx[spkt]] + sstep
        span_w = np.zeros(nw0, dtype=np.int64)
        np.maximum.at(span_w, cw_t, sched)
        span_w += 1
        lkey = cw_t * np.int64(nl) + sids
        if nw0 * nl <= _DENSE_SCREEN_SPACE:
            loadmax_w = np.bincount(
                lkey, minlength=nw0 * nl).reshape(nw0, nl).max(axis=1)
        else:
            loadmax_w = np.zeros(nw0, dtype=np.int64)
            uk, uc = np.unique(lkey, return_counts=True)
            np.maximum.at(loadmax_w, uk // nl, uc)
        hopeless = loadmax_w > link_capacity * span_w
        if hopeless.all():
            bad = np.arange(nw0, dtype=np.int64)
        else:
            sub = ~hopeless[cw_t]
            bad = _schedule_congested(cw_t[sub], sched[sub], sids[sub],
                                      nl, link_capacity)
            bad = np.union1d(np.flatnonzero(hopeless), bad)
        if bad.shape[0] < nw0:
            keep_w = np.zeros(nw0, dtype=bool)
            keep_w[bad] = True
            keep_p = keep_w[cwin0]
            keep_t = keep_p[spkt]
            remap = np.cumsum(keep_p) - 1
            sids, sstep = sids[keep_t], sstep[keep_t]
            spkt = remap[spkt[keep_t]]
            sidx = sidx[keep_p]
        if sidx.shape[0]:
            uwin = np.unique(win[sidx])
            cwin = np.searchsorted(uwin, win[sidx])
            if stepper == "jax":
                from .replay_jax import joint_stepper_jax

                lat_s, congestion = joint_stepper_jax(
                    src_core[sidx], dst_core[sidx], inject[sidx], cwin,
                    w, h, nl, link_capacity, max_cycles_per_window)
            else:
                lat_s, congestion = _joint_stepper(
                    sids, spkt, sstep, hops[sidx], inject[sidx], cwin,
                    nl, link_capacity, max_cycles_per_window)
            lat[sidx] = lat_s

    cycles_total = int(_per_window_max(lat, win, n_win).sum())
    return _stats(lat, total_hops, congestion, per_link, per_link,
                  cycles_total, n_local, energy, "unicast", n)


def _joint_stepper(
    ids: np.ndarray,
    pkt: np.ndarray,
    step: np.ndarray,
    hops: np.ndarray,
    inject: np.ndarray,
    win: np.ndarray,
    nl: int,
    link_capacity: int,
    max_cycles: int,
) -> tuple[np.ndarray, int]:
    """Step all congested windows jointly; returns (latencies, blocked count).

    Takes the packets of the congested windows as a pre-expanded route set
    ((ids, pkt, step) traversals with ``pkt`` compact) so no XY geometry is
    recomputed while stepping.  ``win`` must be compact (0..c-1) so
    (window, link) tags stay bincountable.

    Reproduces the reference per-window arbitration exactly: a packet is
    active once injected, requests its next route link each cycle, and
    each link grants its ``link_capacity`` oldest-injected packets (stable
    by record order).  Three structural accelerations keep every cycle a
    handful of O(active) passes:

      * packets are pre-sorted by (inject, record order) — the static
        arbitration priority — so the active set is always a row prefix
        (one ``searchsorted``) and per-link grants need a single stable
        argsort over oversubscribed links only;
      * uncontended links (demand <= capacity) grant without sorting;
      * whenever a cycle blocks nothing the remaining forward schedule is
        re-screened, and the whole tail is finished analytically once it
        fits capacity.

    Packets are compacted away as they arrive.
    """
    n = hops.shape[0]
    n_cwin = int(win.max()) + 1 if n else 0
    # Static priority order (ascending inject, stable by record order).
    prio = np.argsort(inject, kind="stable")
    inject, win, hops = inject[prio], win[prio], hops[prio]
    newpos = np.empty(n, dtype=np.int64)
    newpos[prio] = np.arange(n)
    off = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(hops, out=off[1:])
    seq = np.empty(ids.shape[0], dtype=np.int64)  # links in traversal order
    seq[off[newpos[pkt]] + step] = ids
    wtag = np.repeat(win * np.int64(nl), hops)  # window tag per traversal
    space = n_cwin * nl
    # Remaining (window, link) loads of unfinished traversals and the
    # per-window count of still-overloaded pairs, both maintained
    # incrementally: a window whose last pair drains to <= capacity can
    # never block again and finishes analytically mid-flight.
    rem_loads = np.bincount(wtag + seq, minlength=space)
    over_pairs = np.flatnonzero(rem_loads > link_capacity)
    wover = np.bincount(over_pairs // nl, minlength=n_cwin)

    ptr = off[:-1].copy()  # next traversal of each packet
    end = off[1:].copy()
    orig = prio  # row -> caller's record index
    lat = np.zeros(n, dtype=np.int64)
    congestion = 0
    cycle = 0
    next_screen = _RESCREEN_EVERY  # entry screen already ran in the caller
    # Last cycle each window blocked a packet (or failed a screen): only
    # windows quiet for _RESCREEN_EVERY cycles are screen candidates.
    wlast = np.zeros(n_cwin, dtype=np.int64)

    def finish_windows(wmask: np.ndarray) -> None:
        """Analytically finish every alive packet of the flagged windows
        (their remaining pairs all fit capacity: nothing blocks again)."""
        nonlocal ptr, end, inject, win, orig
        m = wmask[win]
        if m.any():
            lat[orig[m]] = np.maximum(inject[m], cycle) + (end[m] - ptr[m])
            keep = ~m
            ptr, end, inject, win, orig = (
                ptr[keep], end[keep], inject[keep], win[keep], orig[keep])

    while orig.shape[0]:
        if cycle >= max_cycles:
            raise RuntimeError("NoC window failed to drain — capacity too low?")
        na = int(np.searchsorted(inject, cycle, side="right"))
        drained: np.ndarray | None = None
        if na:
            tag = wtag[ptr[:na]] + seq[ptr[:na]]
            demand = np.bincount(tag, minlength=space)
            go = np.ones(na, dtype=bool)
            hot = np.flatnonzero(demand[tag] > link_capacity)
            if hot.shape[0]:
                # Arbitrate only oversubscribed links: rows are already in
                # priority order, so a stable argsort on the tag alone
                # groups each link's requesters oldest-first.
                key = np.argsort(tag[hot], kind="stable")
                allow = np.empty(hot.shape[0], dtype=bool)
                allow[key] = _capacity_grants(tag[hot][key], link_capacity)
                go[hot] = allow
                nb = int(hot.shape[0] - allow.sum())
                congestion += nb
                if nb:
                    wlast[win[hot[~allow]]] = cycle
            granted_tags = tag[go]
            if granted_tags.shape[0]:
                dec = np.bincount(granted_tags, minlength=0)
                touched = np.flatnonzero(dec)
                before = rem_loads[touched]
                after = before - dec[touched]
                rem_loads[touched] = after
                crossed = touched[(before > link_capacity)
                                  & (after <= link_capacity)]
                if crossed.shape[0]:
                    cw = crossed // nl
                    wover -= np.bincount(cw, minlength=n_cwin)
                    drained = np.unique(cw)
                    drained = drained[wover[drained] == 0]
            ptr[:na] += go
            arr = np.flatnonzero(ptr[:na] == end[:na])
            if arr.shape[0]:
                lat[orig[arr]] = cycle + 1
                keep = np.ones(orig.shape[0], dtype=bool)
                keep[arr] = False
                ptr, end, inject, win, orig = (
                    ptr[keep], end[keep], inject[keep], win[keep], orig[keep])
        cycle += 1
        if drained is not None and drained.shape[0] and orig.shape[0]:
            wmask = np.zeros(n_cwin, dtype=bool)
            wmask[drained] = True
            finish_windows(wmask)
        if orig.shape[0] and cycle >= next_screen:
            # Exact (cycle, link) schedule screen over the remaining routes
            # of *quiet* windows (no block for _RESCREEN_EVERY cycles):
            # residual overloads diffused over cycles (stagger, queue
            # tails) never contend again and finish now — the load-based
            # drain exit cannot see those.  A window that fails the screen
            # is treated like a fresh block so it is not re-screened until
            # quiet again.
            next_screen = cycle + _RESCREEN_EVERY
            cand = wlast <= cycle - _RESCREEN_EVERY
            rows = np.flatnonzero(cand[win])
            if rows.shape[0]:
                start_c = np.maximum(inject[rows], cycle)
                rem = end[rows] - ptr[rows]
                rpkt = np.repeat(np.arange(rows.shape[0]), rem)
                cum = np.zeros(rows.shape[0] + 1, dtype=np.int64)
                np.cumsum(rem, out=cum[1:])
                within = np.arange(int(cum[-1])) - np.repeat(cum[:-1], rem)
                bad = _schedule_congested(win[rows[rpkt]],
                                          start_c[rpkt] + within,
                                          seq[ptr[rows[rpkt]] + within], nl,
                                          link_capacity)
                wlast[bad] = cycle
                wmask = cand.copy()
                wmask[bad] = False
                finish_windows(wmask)
    return lat, congestion


# ----------------------------------------------------------- multicast


def queued_multicast_tree(
    trace_t: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    group: np.ndarray,
    w: int,
    h: int,
    link_capacity: int,
    inject_capacity: int,
    energy: EnergyModel,
    n_local: int,
    max_cycles_per_window: int = 100_000,
    screen: str = "numpy",
    order: np.ndarray | None = None,
) -> NoCStats:
    """True tree-fork multicast replay over deduplicated (firing, dst) packets.

    One flit per firing is injected (so the crossbar egress stagger counts
    firings, not replicas) and forks along the XY multicast tree; each
    (firing, tree link) is traversed exactly once.  A destination's latency
    is the grant cycle of the tree link entering it, plus one.  Compared to
    the replica-based reference this is strictly tighter: fewer flits
    contend (tree links <= summed replica hops) and a firing occupies one
    injection slot instead of one per destination.

    ``order`` flags packets routed YX (fault escape; numpy screen only).
    Groups must then be order-pure — the fault layer splits each firing
    into an XY and a YX subgroup, so an escape copy is its own flit with
    its own tree and injection slot.
    """
    nl = link_count(w, h)
    ncores = w * h
    n = int(trace_t.shape[0])
    if n == 0:
        return _stats(np.empty(0, np.int64), 0, 0, np.zeros(nl, np.int64),
                      np.zeros(nl, np.int64), 0, n_local, energy,
                      "multicast", 0)
    if order is not None and screen in ("linkload", "pallas", "interpret", "jnp"):
        raise ValueError("fault-escape routes require the numpy screen")
    win, n_win = _window_ids(trace_t)
    hops = route_hops(src_core, dst_core, w)
    total_hops = int(hops.sum())

    # Firing entities (canonical order: ascending firing id).
    uf, finv = np.unique(group, return_inverse=True)
    f_src = np.zeros(uf.shape[0], dtype=np.int64)
    f_win = np.zeros(uf.shape[0], dtype=np.int64)
    f_src[finv] = src_core  # every packet of a firing shares (t, src core)
    f_win[finv] = win
    f_inject = _inject_cycles(f_win, f_src, ncores, inject_capacity)

    # Tree-link entities, canonically sorted by (firing, link id).
    tids, tgrp = multicast_tree_links(src_core, dst_core, group, w, h,
                                      order=order)
    tf = np.searchsorted(uf, tgrp)
    tail, head = link_endpoints(tids, w, h)
    depth = route_hops(f_src[tf], tail, w)
    per_link = np.bincount(tids, minlength=nl)
    e_win = f_win[tf]

    # XY trees enter each node at most once per firing, so (firing, head)
    # is unique: one sorted key array serves parent pointers and the
    # packet -> terminal-link lookup.
    hkey = tf * np.int64(ncores) + head
    horder = np.argsort(hkey)
    hsorted = hkey[horder]

    def entity_of(firing_idx: np.ndarray, node: np.ndarray) -> np.ndarray:
        """Tree-link entity entering ``node`` in ``firing_idx``'s tree
        (-1 when the node is the firing's source)."""
        q = firing_idx * np.int64(ncores) + node
        pos = np.minimum(np.searchsorted(hsorted, q), hsorted.shape[0] - 1)
        return np.where(hsorted[pos] == q, horder[pos], -1)

    par = entity_of(tf, tail)

    # Tier 1: overloaded (window, link) pairs over *tree* loads.  Only a
    # firing whose tree touches an overloaded pair can see queueing (or
    # shift anyone else's timing), so all other firings deliver on the
    # unobstructed schedule: depth-d links cross at inject + d.
    if screen in ("linkload", "pallas", "interpret", "jnp"):
        backend = "jnp" if screen in ("linkload", "jnp") else screen
        # Replica pairwise loads upper-bound tree loads: a sound (looser)
        # overload screen — extra firings get stepped, results identical.
        loads = _window_loads_linkload(win, src_core, dst_core, n_win, w, h,
                                       backend)
        hot_keys = np.flatnonzero(loads.ravel() > link_capacity)
        pm = _member(hot_keys, e_win * np.int64(nl) + tids)
    else:
        wl_key = e_win * np.int64(nl) + tids
        hot_keys, counts = _hot_pairs(wl_key, n_win, nl, link_capacity)
        pm = (counts[wl_key] > link_capacity if counts is not None
              else _member(hot_keys, wl_key))

    lat = f_inject[finv] + hops  # analytic fast path
    congestion = 0
    if pm.any():
        fstep = np.zeros(uf.shape[0], dtype=bool)
        fstep[tf[pm]] = True
        sub = np.flatnonzero(fstep[tf])  # every entity of a stepped firing
        # Static schedule screen: windows whose stepped tree links never
        # oversubscribe any (cycle, link) bucket at inject + depth cannot
        # block (stagger-diffused overloads); keep truly contending ones.
        uwin0 = np.unique(e_win[sub])
        cwin0 = np.searchsorted(uwin0, e_win[sub])
        bad = _schedule_congested(cwin0, f_inject[tf[sub]] + depth[sub],
                                  tids[sub], nl, link_capacity)
        if bad.shape[0] < uwin0.shape[0]:
            badw = np.zeros(n_win, dtype=bool)
            badw[uwin0[bad]] = True
            fstep &= badw[f_win]
            sub = np.flatnonzero(fstep[tf])
    if pm.any() and sub.shape[0]:
        remap = np.full(tf.shape[0], -1, dtype=np.int64)
        remap[sub] = np.arange(sub.shape[0])
        par_sub = np.where(par[sub] >= 0, remap[par[sub]], -1)
        uwin = np.unique(e_win[sub])
        cwin = np.searchsorted(uwin, e_win[sub])
        grant_sub, congestion = _tree_stepper(
            cwin * np.int64(nl) + tids[sub],
            f_inject[tf[sub]], par_sub, depth[sub],
            uwin.shape[0] * nl, nl, link_capacity, max_cycles_per_window)
        grant = np.full(tf.shape[0], -1, dtype=np.int64)
        grant[sub] = grant_sub
        pmask = fstep[finv]
        term = entity_of(finv[pmask], dst_core[pmask])
        lat[pmask] = grant[term] + 1

    cycles_total = int(_per_window_max(lat, win, n_win).sum())
    return _stats(lat, total_hops, congestion, per_link, per_link,
                  cycles_total, n_local, energy, "multicast", n)


def _tree_stepper(
    tag: np.ndarray,
    prio: np.ndarray,
    par: np.ndarray,
    depth: np.ndarray,
    n_tags: int,
    nl: int,
    link_capacity: int,
    max_cycles: int,
) -> tuple[np.ndarray, int]:
    """Cycle-step tree-fork flits of all congested windows jointly.

    One entity per (firing, tree link): ``tag`` is the window-tagged link
    (compact window * nl + link), ``prio`` the firing's injection cycle
    (root availability and the arbitration age), ``par`` the entity index
    of the parent link (-1 at the source).  A child becomes requestable
    the cycle after its parent is granted.  Every ``_RESCREEN_EVERY``
    cycles the pending forward schedule is re-screened per window and
    windows that can no longer contend are granted analytically.  Returns
    (grant cycle per entity, blocked flit-cycle count).
    """
    ne = tag.shape[0]
    n_cwin = n_tags // nl
    done = np.zeros(ne, dtype=bool)
    avail = np.where(par < 0, prio, _INF)
    grant = np.full(ne, -1, dtype=np.int64)
    congestion = 0
    cycle = 0
    next_screen = _RESCREEN_EVERY  # entry screen already ran in the caller
    wlast = np.zeros(n_cwin, dtype=np.int64)  # last blocked cycle per window
    remaining = ne
    while remaining:
        if cycle >= max_cycles:
            raise RuntimeError("NoC window failed to drain — capacity too low?")
        aidx = np.flatnonzero(~done & (avail <= cycle))
        if aidx.shape[0]:
            tagi = tag[aidx]
            demand = np.bincount(tagi, minlength=n_tags)
            hot = np.flatnonzero(demand[tagi] > link_capacity)
            go = np.ones(aidx.shape[0], dtype=bool)
            if hot.shape[0]:
                key = np.lexsort((prio[aidx[hot]], tagi[hot]))
                allow = np.empty(hot.shape[0], dtype=bool)
                allow[key] = _capacity_grants(tagi[hot][key], link_capacity)
                go[hot] = allow
                nb = int(hot.shape[0] - allow.sum())
                congestion += nb
                if nb:
                    wlast[tagi[hot[~allow]] // nl] = cycle
            granted = aidx[go]
            done[granted] = True
            grant[granted] = cycle
            remaining -= granted.shape[0]
            # Fork: children of a just-granted parent request from the next
            # cycle (avail is written exactly once per entity).
            upd = np.flatnonzero((par >= 0) & (avail == _INF))
            if upd.shape[0]:
                ready = done[par[upd]]
                avail[upd[ready]] = cycle + 1
        cycle += 1
        if remaining and cycle >= next_screen:
            # Per-window exact (cycle, link) screen over the pending
            # forward schedule of *quiet* windows: those that can no
            # longer oversubscribe any bucket finish analytically.
            next_screen = cycle + _RESCREEN_EVERY
            cand = wlast <= cycle - _RESCREEN_EVERY
            pend = np.flatnonzero(~done & cand[tag // nl])
            if pend.shape[0]:
                est = _tree_forward_schedule(avail, par, depth, done, cycle)
                bad = _schedule_congested(tag[pend] // nl, est[pend],
                                          tag[pend] % nl, nl, link_capacity)
                wlast[bad] = cycle
                wmask = cand.copy()
                wmask[bad] = False
                fin = pend[wmask[tag[pend] // nl]]
                if fin.shape[0]:
                    grant[fin] = est[fin]
                    done[fin] = True
                    remaining -= fin.shape[0]
    return grant, congestion


def _tree_forward_schedule(
    avail: np.ndarray,
    par: np.ndarray,
    depth: np.ndarray,
    done: np.ndarray,
    cycle: int,
) -> np.ndarray:
    """Earliest unobstructed grant cycle of each pending entity from ``cycle``.

    An entity with a known availability requests at max(avail, cycle); one
    still waiting on its parent goes one cycle after the parent's estimate.
    Resolved by ascending depth (a parent is always one level shallower).
    """
    est = np.full(avail.shape[0], _INF, dtype=np.int64)
    known = avail != _INF
    est[known] = np.maximum(avail[known], cycle)
    pending_unknown = ~known & ~done
    if pending_unknown.any():
        for lvl in range(int(depth[pending_unknown].min()),
                         int(depth[pending_unknown].max()) + 1):
            m = pending_unknown & (depth == lvl)
            if m.any():
                est[m] = est[par[m]] + 1
    return est


# --------------------------------------------------------------- stats


def _stats(
    lat: np.ndarray,
    total_hops: int,
    congestion: int,
    per_link: np.ndarray,
    traversal_link: np.ndarray,
    cycles_total: int,
    n_local: int,
    energy: EnergyModel,
    cast: str,
    n_noc: int,
) -> NoCStats:
    traversals = int(traversal_link.sum())
    return NoCStats(
        avg_latency=float(lat.mean()) if n_noc else 0.0,
        max_latency=int(lat.max()) if n_noc else 0,
        avg_hop=float(total_hops / max(n_noc, 1)),
        total_hops=total_hops,
        congestion_count=congestion,
        edge_variance=edge_stats(per_link),
        dynamic_energy_pj=energy.dynamic_energy_pj(traversals, n_local),
        num_noc_spikes=n_noc,
        num_local_spikes=n_local,
        cycles_simulated=cycles_total,
        per_link_hops=per_link,
        cast=cast,
        link_traversals=traversals,
    )

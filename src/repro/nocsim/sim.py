"""Trace-driven NoC simulation (evaluation phase, Noxim++ substitute).

Mode x cast matrix (what each combination computes):

  * ``queued`` / ``unicast`` — cycle-stepped replay, one packet per spike
    transmission.  The default ``engine="batched"`` is a two-tier replay
    (`repro.nocsim.replay`): windows whose per-cycle link demand provably
    fits ``link_capacity`` — screened from whole-window link loads and the
    static XY schedule (latency = injection stagger + hops), optionally via
    the ``kernels/link_load`` indicator-matmul maps — are scored
    analytically with zero cycle stepping; all congested windows are then
    stepped *jointly* in one vectorized loop (window-tagged link
    arbitration, hot-link-only sorting, analytic tail finish), optionally
    on device via ``stepper="jax"``.  Stats are bit-identical to the scalar
    reference engine, kept as ``engine="ref"`` (`_queued_ref`).
  * ``queued`` / ``multicast`` — true tree-fork flits: one flit per firing
    forks at branch routers (state per (firing, tree link); a child link
    becomes requestable the cycle after its parent is granted; one
    traversal per tree link).  Latency and congestion are those of a real
    multicast router — strictly tighter than ``engine="ref"``, which
    simulates every (firing, destination core) replica individually and is
    retained as the documented upper bound.  Link loads and dynamic energy
    use exact tree accounting under both engines.
  * ``analytic`` / either cast — fully vectorized, no queueing: latency =
    hop count, congestion per Eq. 3 from per-window link loads, edge
    variance from static route expansion.  Used for property tests and
    fast sweeps.

Queued replays canonicalize record order within each time step before
simulating (and deduplicate into firings under multicast), so every
reported stat is invariant to how the profiler ordered simultaneous
spikes.  Each SNN time step opens a fresh window; all spikes of the step
are injected (subject to the crossbar's per-step egress limit) and
simulated until drained, mirroring how Noxim++ replays a spike trace when
the SNN time step is much longer than the NoC clock.

Metrics (paper §4.3): average latency, dynamic energy, congestion count,
edge variance.
"""
from __future__ import annotations

import numpy as np

from repro.trace import dedupe_firings

from .energy import EnergyModel
from .replay import queued_multicast_tree, queued_unicast
from .stats import NoCStats, edge_stats
from .xy import (
    link_count,
    link_ids_for_routes,
    multicast_tree_links,
    next_link,
    route_hops,
    routes_blocked,
)

__all__ = ["NoCStats", "dedupe_firings", "simulate_noc"]


def _analytic(
    trace_t: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    w: int,
    h: int,
    link_capacity: int,
    energy: EnergyModel = EnergyModel(),
    group: np.ndarray | None = None,
    chunk_links: int = 20_000_000,
    route_order: np.ndarray | None = None,
) -> NoCStats:
    nl = link_count(w, h)
    local = src_core == dst_core
    n_local = int(local.sum())
    t, s, d = trace_t[~local], src_core[~local], dst_core[~local]
    g = group[~local] if group is not None else None
    o = route_order[~local] if route_order is not None else None
    hops = route_hops(s, d, w)
    total_hops = int(hops.sum())

    per_link = np.zeros(nl, dtype=np.int64)
    congestion = 0
    # Chunk over windows to bound route-expansion memory.
    order = np.argsort(t, kind="stable")
    t, s, d = t[order], s[order], d[order]
    if g is not None:
        g = g[order]
    if o is not None:
        o = o[order]
    bounds = np.flatnonzero(np.diff(t)) + 1
    windows = np.split(np.arange(t.shape[0]), bounds)
    batch: list[np.ndarray] = []
    batch_size = 0

    def flush(idxs: list[np.ndarray]) -> int:
        nonlocal per_link
        cong = 0
        for widx in idxs:
            ow = o[widx] if o is not None else None
            if g is None:
                ids, _ = link_ids_for_routes(s[widx], d[widx], w, h, order=ow)
            else:
                ids, _ = multicast_tree_links(s[widx], d[widx], g[widx], w, h,
                                              order=ow)
            loads = np.bincount(ids, minlength=nl)
            per_link += loads
            cong += int(np.maximum(loads - link_capacity, 0).sum())
        return cong

    for widx in windows:
        batch.append(widx)
        batch_size += widx.shape[0]
        if batch_size * 8 >= chunk_links:
            congestion += flush(batch)
            batch, batch_size = [], 0
    congestion += flush(batch)

    n_noc = int(t.shape[0])
    traversals = int(per_link.sum())  # == total_hops when unicast
    return NoCStats(
        avg_latency=float(hops.mean()) if n_noc else 0.0,
        max_latency=int(hops.max()) if n_noc else 0,
        avg_hop=float(total_hops / max(n_noc, 1)),
        total_hops=total_hops,
        congestion_count=congestion,
        edge_variance=edge_stats(per_link),
        dynamic_energy_pj=energy.dynamic_energy_pj(traversals, n_local),
        num_noc_spikes=n_noc,
        num_local_spikes=n_local,
        cycles_simulated=0,
        per_link_hops=per_link,
        cast="unicast" if group is None else "multicast",
        link_traversals=traversals,
    )


def _queued_ref(
    trace_t: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    w: int,
    h: int,
    link_capacity: int,
    inject_capacity: int,
    energy: EnergyModel,
    group: np.ndarray | None = None,
    max_cycles_per_window: int = 100_000,
    route_order: np.ndarray | None = None,
) -> NoCStats:
    """Scalar reference engine: Python loop per window, lexsorts per cycle.

    Kept verbatim as the parity oracle for the batched replay
    (`repro.nocsim.replay`) and as the replica-based multicast upper bound
    the tree-fork engine is measured against.  ``route_order`` flags
    records routed YX (fault-escape detours); ``None`` is pure XY.
    """
    nl = link_count(w, h)
    local = src_core == dst_core
    n_local = int(local.sum())
    t, s, d = trace_t[~local], src_core[~local], dst_core[~local]
    g = group[~local] if group is not None else None
    o = route_order[~local] if route_order is not None else None
    order = np.argsort(t, kind="stable")
    t, s, d = t[order], s[order], d[order]
    if g is not None:
        g = g[order]
    if o is not None:
        o = o[order]

    per_link = np.zeros(nl, dtype=np.int64)
    tree_per_link = np.zeros(nl, dtype=np.int64) if g is not None else None
    total_hops = int(route_hops(s, d, w).sum())
    congestion = 0
    latencies = np.zeros(t.shape[0], dtype=np.int64)
    cycles_total = 0

    bounds = np.flatnonzero(np.diff(t)) + 1
    for widx in np.split(np.arange(t.shape[0]), bounds):
        if widx.shape[0] == 0:
            continue
        ws, wd = s[widx], d[widx]
        wo = o[widx] if o is not None else None
        if g is not None:
            # Static tree accounting, chunked per window like the analytic
            # path (firing ids never span windows, so per-window dedup is
            # exact and the route expansion stays bounded).
            tids, _ = multicast_tree_links(ws, wd, g[widx], w, h, order=wo)
            tree_per_link += np.bincount(tids, minlength=nl)
        n = ws.shape[0]
        # Crossbar egress limit: the r-th spike from a core this step
        # injects at cycle r // inject_capacity.
        order_src = np.argsort(ws, kind="stable")
        rank = np.empty(n, dtype=np.int64)
        sorted_src = ws[order_src]
        grp_new = np.concatenate([[True], sorted_src[1:] != sorted_src[:-1]])
        grp_start = np.maximum.accumulate(np.where(grp_new, np.arange(n), 0))
        rank[order_src] = np.arange(n) - grp_start
        inject_cycle = rank // inject_capacity

        cur = ws.copy()
        arrived = cur == wd  # zero-hop impossible here (local removed)
        lat = np.zeros(n, dtype=np.int64)
        cycle = 0
        while not arrived.all():
            if cycle >= max_cycles_per_window:
                raise RuntimeError("NoC window failed to drain — capacity too low?")
            active = (~arrived) & (inject_cycle <= cycle)
            idx = np.flatnonzero(active)
            if idx.shape[0]:
                nxt, link = next_link(cur[idx], wd[idx], w, h,
                                      yx=wo[idx] if wo is not None else None)
                # Per-link arbitration: oldest (earliest inject, stable) first.
                key = np.lexsort((inject_cycle[idx], link))
                sl = link[key]
                grp_new = np.concatenate([[True], sl[1:] != sl[:-1]])
                grp_start = np.maximum.accumulate(np.where(grp_new, np.arange(sl.shape[0]), 0))
                rnk = np.arange(sl.shape[0]) - grp_start
                go = np.zeros(idx.shape[0], dtype=bool)
                go[key] = rnk < link_capacity
                moved = idx[go]
                per_link += np.bincount(link[go], minlength=nl)
                congestion += int(idx.shape[0] - moved.shape[0])  # Eq. 3: blocked this cycle
                cur[moved] = nxt[go]
                newly = moved[cur[moved] == wd[moved]]
                arrived[newly] = True
                lat[newly] = cycle + 1
            cycle += 1
        latencies[widx] = lat
        cycles_total += cycle

    n_noc = int(t.shape[0])
    if g is not None:
        # Static tree accounting overrides the replica-based link loads:
        # link traversals and energy depend only on the XY routes, not on
        # queueing, and a branch link carries one flit per firing.
        per_link = tree_per_link
    traversals = int(per_link.sum())
    return NoCStats(
        avg_latency=float(latencies.mean()) if n_noc else 0.0,
        max_latency=int(latencies.max()) if n_noc else 0,
        avg_hop=float(total_hops / max(n_noc, 1)),
        total_hops=total_hops,
        congestion_count=congestion,
        edge_variance=edge_stats(per_link),
        dynamic_energy_pj=energy.dynamic_energy_pj(traversals, n_local),
        num_noc_spikes=n_noc,
        num_local_spikes=n_local,
        cycles_simulated=cycles_total,
        per_link_hops=per_link,
        cast="unicast" if group is None else "multicast",
        link_traversals=traversals,
    )


def simulate_noc(
    trace_t: np.ndarray,
    trace_src: np.ndarray,
    trace_dst: np.ndarray,
    part: np.ndarray,
    placement: np.ndarray,
    mesh_w: int,
    mesh_h: int,
    link_capacity: int = 4,
    inject_capacity: int = 256,
    mode: str = "queued",
    cast: str = "unicast",
    energy: EnergyModel = EnergyModel(),
    engine: str = "batched",
    stepper: str = "numpy",
    screen: str = "numpy",
    max_cycles_per_window: int = 100_000,
    faults=None,
) -> NoCStats:
    """Replay a spike trace through the mapped NoC.

    Args:
      part: (num_neurons,) partition id per neuron.
      placement: (k,) core id per partition (the mapping M).
      mode: "queued" (cycle-accurate-style) or "analytic" (vectorized).
      cast: "unicast" (one packet per transmission) or "multicast" (one
        packet per (firing, destination core), tree link accounting).
      engine: "batched" (two-tier vectorized replay; tree-fork flits under
        multicast) or "ref" (scalar reference loop; replica-based
        multicast upper bound).  Queued mode only.
      stepper: "numpy" or "jax" — substrate for the batched engine's joint
        congested-window cycle loop (`repro.nocsim.replay_jax`).  Unicast
        only: the multicast tree-fork stepper is numpy-only, so "jax" is
        accepted but has no effect under cast="multicast".
      screen: "numpy" (bincount over route expansion) or "linkload" /
        "pallas" / "interpret" (per-window load maps through the
        ``kernels/link_load`` machinery) — backend for the batched
        engine's whole-window contention screen.  The choice never changes
        results, only where the screening work runs.
      faults: optional `repro.runtime.faults.FaultState` of dead cores and
        links.  Packets with a dead endpoint are dropped; packets whose XY
        route crosses a dead link/core detour via the YX escape order when
        that route is clean, and are dropped otherwise.  Drops and detours
        are reported in ``NoCStats.spikes_dropped`` / ``detour_hops``
        (detour hops count the escape routes' per-packet route hops; both
        orders are minimal, so a detour changes *which* links are crossed,
        not how many).  ``None`` — or a state with no failures — is
        bit-identical to the fault-free engines.  Fault-aware replay is
        host-only: it requires the default ``stepper="numpy"`` and
        ``screen="numpy"`` backends.
    """
    if mode not in ("queued", "analytic"):
        raise ValueError(f"unknown mode {mode!r}")
    if engine not in ("batched", "ref"):
        raise ValueError(f"unknown engine {engine!r}")
    if stepper not in ("numpy", "jax"):
        raise ValueError(f"unknown stepper {stepper!r}")
    if screen not in ("numpy", "linkload", "pallas", "interpret", "jnp"):
        raise ValueError(f"unknown screen {screen!r}")
    fault_on = faults is not None and faults.any()
    if fault_on:
        if (faults.w, faults.h) != (mesh_w, mesh_h):
            raise ValueError(
                f"fault state built for {faults.w}x{faults.h}, "
                f"mesh is {mesh_w}x{mesh_h}")
        if stepper != "numpy":
            raise ValueError("fault-aware replay requires stepper='numpy'")
        if screen != "numpy":
            raise ValueError("fault-aware replay requires screen='numpy'")
        dead = faults.dead_cores
        blocked = faults.blocked_links()
    core_of_neuron = placement[part]
    src_core = core_of_neuron[trace_src]
    dst_core = core_of_neuron[trace_dst]
    # Canonical record order within each time step: queued stats must
    # depend on the multiset of simultaneous records, not on the order the
    # profiler emitted them (injection-stagger and arbitration tie-breaks
    # would otherwise leak emission order into latencies).
    ncores = mesh_w * mesh_h
    tmax = int(trace_t.max()) + 1 if trace_t.shape[0] else 1
    if tmax * ncores * ncores < np.iinfo(np.int64).max // 4:
        packed = ((trace_t.astype(np.int64) * ncores + src_core) * ncores
                  + dst_core)
        order = np.argsort(packed, kind="stable")
    else:
        order = np.lexsort((dst_core, src_core, trace_t))
    trace_t = trace_t[order]
    trace_src = trace_src[order]
    src_core = src_core[order]
    dst_core = dst_core[order]
    local = src_core == dst_core
    n_local = int(local.sum())
    keep_local = local
    dropped = 0
    detour_hops = 0
    if fault_on:
        # A core-local delivery on a dead core is lost with the core.
        keep_local = local & ~dead[src_core]
        dropped += n_local - int(keep_local.sum())
        n_local = int(keep_local.sum())

    def _fates(s: np.ndarray, d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(deliver, detour) per remote packet under the fault masks:
        dead endpoint -> drop; XY route clean -> direct; else YX escape
        route clean -> detour; else drop."""
        ep_dead = dead[s] | dead[d]
        xy_bad = routes_blocked(s, d, mesh_w, mesh_h, blocked)
        yx_ok = ~routes_blocked(s, d, mesh_w, mesh_h, blocked,
                                order=np.ones(s.shape[0], dtype=bool))
        deliver = ~ep_dead & (~xy_bad | yx_ok)
        return deliver, deliver & xy_bad

    def _with_faults(stats: NoCStats) -> NoCStats:
        stats.spikes_dropped = dropped
        stats.detour_hops = detour_hops
        return stats

    if cast == "multicast":
        # Only NoC-bound transmissions deduplicate into packets: a
        # core-local delivery is a synaptic event, not a packet, so every
        # local record keeps its unicast-model energy accounting.
        rt, rsrc, rdst, firing = dedupe_firings(
            trace_t[~local], trace_src[~local], dst_core[~local],
            int(part.shape[0]), mesh_w * mesh_h,
        )
        rsrc_core = core_of_neuron[rsrc]
        route_order = None
        if fault_on:
            deliver, yx = _fates(rsrc_core, rdst)
            dropped += int((~deliver).sum())
            detour_hops = int(route_hops(rsrc_core[yx], rdst[yx], mesh_w).sum())
            rt, rsrc_core, rdst = rt[deliver], rsrc_core[deliver], rdst[deliver]
            route_order = yx[deliver]
            # Escape copies fork their own tree: splitting each firing into
            # an XY and a YX subgroup keeps every group's route union a
            # tree entered at most once per node — the invariant both the
            # tree-fork engine and the static tree accounting rely on.
            firing = firing[deliver] * 2 + route_order.astype(np.int64)
        if mode == "analytic" or engine == "ref":
            # Replica-record layout (locals first; they are filtered on a
            # src_core == dst_core test inside, so any group label works).
            trace_t = np.concatenate([trace_t[keep_local], rt])
            src_core = np.concatenate([src_core[keep_local], rsrc_core])
            dst_core = np.concatenate([dst_core[keep_local], rdst])
            group = np.concatenate([np.full(n_local, -1, dtype=np.int64),
                                    firing])
            order_cat = None
            if route_order is not None:
                order_cat = np.concatenate(
                    [np.zeros(n_local, dtype=bool), route_order])
        if mode == "analytic":
            return _with_faults(_analytic(
                trace_t, src_core, dst_core, mesh_w, mesh_h,
                link_capacity, energy, group, route_order=order_cat))
        if engine == "ref":
            return _with_faults(_queued_ref(
                trace_t, src_core, dst_core, mesh_w, mesh_h,
                link_capacity, inject_capacity, energy, group,
                max_cycles_per_window, route_order=order_cat))
        return _with_faults(queued_multicast_tree(
            rt, rsrc_core, rdst, firing, mesh_w, mesh_h, link_capacity,
            inject_capacity, energy, n_local, max_cycles_per_window,
            screen=screen, order=route_order))
    if cast != "unicast":
        raise ValueError(f"unknown cast {cast!r}")
    route_order = None
    if fault_on:
        rt2 = trace_t[~local]
        rs, rd = src_core[~local], dst_core[~local]
        deliver, yx = _fates(rs, rd)
        dropped += int((~deliver).sum())
        detour_hops = int(route_hops(rs[yx], rd[yx], mesh_w).sum())
        route_order = yx[deliver]
        trace_t = np.concatenate([trace_t[keep_local], rt2[deliver]])
        src_core = np.concatenate([src_core[keep_local], rs[deliver]])
        dst_core = np.concatenate([dst_core[keep_local], rd[deliver]])
        order_cat = np.concatenate([np.zeros(n_local, dtype=bool),
                                    route_order])
        local = src_core == dst_core
    else:
        order_cat = None
    if mode == "analytic":
        return _with_faults(_analytic(
            trace_t, src_core, dst_core, mesh_w, mesh_h,
            link_capacity, energy, route_order=order_cat))
    if engine == "ref":
        return _with_faults(_queued_ref(
            trace_t, src_core, dst_core, mesh_w, mesh_h,
            link_capacity, inject_capacity, energy, None,
            max_cycles_per_window, route_order=order_cat))
    return _with_faults(queued_unicast(
        trace_t[~local], src_core[~local], dst_core[~local], mesh_w, mesh_h,
        link_capacity, inject_capacity, energy, n_local,
        max_cycles_per_window, stepper=stepper, screen=screen,
        order=route_order))

"""Trace-driven NoC simulation (evaluation phase, Noxim++ substitute).

Two modes:
  * ``queued`` — cycle-stepped simulation with per-link bandwidth and
    per-core injection limits.  Each SNN time step opens a fresh window;
    all spikes of the step are injected (subject to the crossbar's
    256-spikes-per-step egress limit) and simulated until drained.  This
    mirrors how Noxim++ replays a spike trace when the SNN time step is
    much longer than the NoC clock.
  * ``analytic`` — fully vectorized: latency = hop count (+ no queueing),
    congestion per Eq. 3 from per-window link loads, edge variance from
    static route expansion.  Used for property tests and fast sweeps.

Two traffic models (``cast``):
  * ``unicast`` — one packet per spike transmission (per synapse crossing);
    the paper's replay model.
  * ``multicast`` — one packet per (firing, destination core): a neuron
    firing into d distinct cores injects d replicated packets, not one per
    synapse, and the replicas of one firing share their XY route prefix as
    a multicast tree — link loads, edge variance, and dynamic energy count
    each (firing, link) branch traversal once (``xy.multicast_tree_links``).
    In ``queued`` mode the replicas are *simulated* individually (latency
    and congestion are replica-based upper bounds — a true multicast router
    merges flits on shared branches), while link loads and energy are
    reported with exact tree accounting.

Metrics (paper §4.3): average latency, dynamic energy, congestion count,
edge variance.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.trace import dedupe_firings

from .energy import EnergyModel
from .xy import (
    link_count,
    link_ids_for_routes,
    multicast_tree_links,
    next_link,
    route_hops,
)

__all__ = ["NoCStats", "dedupe_firings", "simulate_noc"]


@dataclass
class NoCStats:
    avg_latency: float  # cycles, averaged over NoC-traversing packets
    max_latency: int
    avg_hop: float
    total_hops: int
    congestion_count: int  # Eq. 3
    edge_variance: float  # Eq. 4-5
    dynamic_energy_pj: float
    num_noc_spikes: int  # NoC-traversing packets (deduplicated under multicast)
    num_local_spikes: int
    cycles_simulated: int
    per_link_hops: np.ndarray = field(repr=False, default=None)
    cast: str = "unicast"
    link_traversals: int = 0  # == total_hops for unicast; tree links for multicast


def _edge_stats(per_link_hops: np.ndarray) -> float:
    return float(np.var(per_link_hops))


def _analytic(
    trace_t: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    w: int,
    h: int,
    link_capacity: int,
    energy: EnergyModel = EnergyModel(),
    group: np.ndarray | None = None,
    chunk_links: int = 20_000_000,
) -> NoCStats:
    nl = link_count(w, h)
    local = src_core == dst_core
    n_local = int(local.sum())
    t, s, d = trace_t[~local], src_core[~local], dst_core[~local]
    g = group[~local] if group is not None else None
    hops = route_hops(s, d, w)
    total_hops = int(hops.sum())

    per_link = np.zeros(nl, dtype=np.int64)
    congestion = 0
    # Chunk over windows to bound route-expansion memory.
    order = np.argsort(t, kind="stable")
    t, s, d = t[order], s[order], d[order]
    if g is not None:
        g = g[order]
    bounds = np.flatnonzero(np.diff(t)) + 1
    windows = np.split(np.arange(t.shape[0]), bounds)
    batch: list[np.ndarray] = []
    batch_size = 0

    def flush(idxs: list[np.ndarray]) -> int:
        nonlocal per_link
        cong = 0
        for widx in idxs:
            if g is None:
                ids, _ = link_ids_for_routes(s[widx], d[widx], w, h)
            else:
                ids, _ = multicast_tree_links(s[widx], d[widx], g[widx], w, h)
            loads = np.bincount(ids, minlength=nl)
            per_link += loads
            cong += int(np.maximum(loads - link_capacity, 0).sum())
        return cong

    for widx in windows:
        batch.append(widx)
        batch_size += widx.shape[0]
        if batch_size * 8 >= chunk_links:
            congestion += flush(batch)
            batch, batch_size = [], 0
    congestion += flush(batch)

    n_noc = int(t.shape[0])
    traversals = int(per_link.sum())  # == total_hops when unicast
    return NoCStats(
        avg_latency=float(hops.mean()) if n_noc else 0.0,
        max_latency=int(hops.max()) if n_noc else 0,
        avg_hop=float(total_hops / max(n_noc, 1)),
        total_hops=total_hops,
        congestion_count=congestion,
        edge_variance=_edge_stats(per_link),
        dynamic_energy_pj=energy.dynamic_energy_pj(traversals, n_local),
        num_noc_spikes=n_noc,
        num_local_spikes=n_local,
        cycles_simulated=0,
        per_link_hops=per_link,
        cast="unicast" if group is None else "multicast",
        link_traversals=traversals,
    )


def _queued(
    trace_t: np.ndarray,
    src_core: np.ndarray,
    dst_core: np.ndarray,
    w: int,
    h: int,
    link_capacity: int,
    inject_capacity: int,
    energy: EnergyModel,
    group: np.ndarray | None = None,
    max_cycles_per_window: int = 100_000,
) -> NoCStats:
    nl = link_count(w, h)
    local = src_core == dst_core
    n_local = int(local.sum())
    t, s, d = trace_t[~local], src_core[~local], dst_core[~local]
    g = group[~local] if group is not None else None
    order = np.argsort(t, kind="stable")
    t, s, d = t[order], s[order], d[order]
    if g is not None:
        g = g[order]

    per_link = np.zeros(nl, dtype=np.int64)
    tree_per_link = np.zeros(nl, dtype=np.int64) if g is not None else None
    total_hops = int(route_hops(s, d, w).sum())
    congestion = 0
    latencies = np.zeros(t.shape[0], dtype=np.int64)
    cycles_total = 0

    bounds = np.flatnonzero(np.diff(t)) + 1
    for widx in np.split(np.arange(t.shape[0]), bounds):
        if widx.shape[0] == 0:
            continue
        ws, wd = s[widx], d[widx]
        if g is not None:
            # Static tree accounting, chunked per window like the analytic
            # path (firing ids never span windows, so per-window dedup is
            # exact and the route expansion stays bounded).
            tids, _ = multicast_tree_links(ws, wd, g[widx], w, h)
            tree_per_link += np.bincount(tids, minlength=nl)
        n = ws.shape[0]
        # Crossbar egress limit: the r-th spike from a core this step
        # injects at cycle r // inject_capacity.
        order_src = np.argsort(ws, kind="stable")
        rank = np.empty(n, dtype=np.int64)
        sorted_src = ws[order_src]
        grp_new = np.concatenate([[True], sorted_src[1:] != sorted_src[:-1]])
        grp_start = np.maximum.accumulate(np.where(grp_new, np.arange(n), 0))
        rank[order_src] = np.arange(n) - grp_start
        inject_cycle = rank // inject_capacity

        cur = ws.copy()
        arrived = cur == wd  # zero-hop impossible here (local removed)
        lat = np.zeros(n, dtype=np.int64)
        cycle = 0
        while not arrived.all():
            if cycle >= max_cycles_per_window:
                raise RuntimeError("NoC window failed to drain — capacity too low?")
            active = (~arrived) & (inject_cycle <= cycle)
            idx = np.flatnonzero(active)
            if idx.shape[0]:
                nxt, link = next_link(cur[idx], wd[idx], w, h)
                # Per-link arbitration: oldest (earliest inject, stable) first.
                key = np.lexsort((inject_cycle[idx], link))
                sl = link[key]
                grp_new = np.concatenate([[True], sl[1:] != sl[:-1]])
                grp_start = np.maximum.accumulate(np.where(grp_new, np.arange(sl.shape[0]), 0))
                rnk = np.arange(sl.shape[0]) - grp_start
                go = np.zeros(idx.shape[0], dtype=bool)
                go[key] = rnk < link_capacity
                moved = idx[go]
                per_link += np.bincount(link[go], minlength=nl)
                congestion += int(idx.shape[0] - moved.shape[0])  # Eq. 3: blocked this cycle
                cur[moved] = nxt[go]
                newly = moved[cur[moved] == wd[moved]]
                arrived[newly] = True
                lat[newly] = cycle + 1
            cycle += 1
        latencies[widx] = lat
        cycles_total += cycle

    n_noc = int(t.shape[0])
    if g is not None:
        # Static tree accounting overrides the replica-based link loads:
        # link traversals and energy depend only on the XY routes, not on
        # queueing, and a branch link carries one flit per firing.
        per_link = tree_per_link
    traversals = int(per_link.sum())
    return NoCStats(
        avg_latency=float(latencies.mean()) if n_noc else 0.0,
        max_latency=int(latencies.max()) if n_noc else 0,
        avg_hop=float(total_hops / max(n_noc, 1)),
        total_hops=total_hops,
        congestion_count=congestion,
        edge_variance=_edge_stats(per_link),
        dynamic_energy_pj=energy.dynamic_energy_pj(traversals, n_local),
        num_noc_spikes=n_noc,
        num_local_spikes=n_local,
        cycles_simulated=cycles_total,
        per_link_hops=per_link,
        cast="unicast" if group is None else "multicast",
        link_traversals=traversals,
    )


def simulate_noc(
    trace_t: np.ndarray,
    trace_src: np.ndarray,
    trace_dst: np.ndarray,
    part: np.ndarray,
    placement: np.ndarray,
    mesh_w: int,
    mesh_h: int,
    link_capacity: int = 4,
    inject_capacity: int = 256,
    mode: str = "queued",
    cast: str = "unicast",
    energy: EnergyModel = EnergyModel(),
) -> NoCStats:
    """Replay a spike trace through the mapped NoC.

    Args:
      part: (num_neurons,) partition id per neuron.
      placement: (k,) core id per partition (the mapping M).
      mode: "queued" (cycle-accurate-style) or "analytic" (vectorized).
      cast: "unicast" (one packet per transmission) or "multicast" (one
        packet per (firing, destination core), tree link accounting).
    """
    core_of_neuron = placement[part]
    src_core = core_of_neuron[trace_src]
    dst_core = core_of_neuron[trace_dst]
    group = None
    if cast == "multicast":
        # Only NoC-bound transmissions deduplicate into packets: a
        # core-local delivery is a synaptic event, not a packet, so every
        # local record keeps its unicast-model energy accounting.
        local = src_core == dst_core
        rt, rsrc, rdst, firing = dedupe_firings(
            trace_t[~local], trace_src[~local], dst_core[~local],
            int(part.shape[0]), mesh_w * mesh_h,
        )
        trace_t = np.concatenate([trace_t[local], rt])
        src_core = np.concatenate([src_core[local], core_of_neuron[rsrc]])
        dst_core = np.concatenate([dst_core[local], rdst])
        # Firing id per record; local records never enter the tree expansion
        # (they are filtered as src_core == dst_core) so any label works.
        group = np.concatenate([np.full(int(local.sum()), -1, dtype=np.int64),
                                firing])
    elif cast != "unicast":
        raise ValueError(f"unknown cast {cast!r}")
    if mode == "analytic":
        return _analytic(trace_t, src_core, dst_core, mesh_w, mesh_h,
                         link_capacity, energy, group)
    if mode == "queued":
        return _queued(trace_t, src_core, dst_core, mesh_w, mesh_h,
                       link_capacity, inject_capacity, energy, group)
    raise ValueError(f"unknown mode {mode!r}")

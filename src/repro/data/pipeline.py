"""Deterministic sharded synthetic-token pipeline.

Every batch is a pure function of (seed, step, shard) so training is
reproducible across restarts and elastic rescaling: after a checkpoint
resume, batch `step` is bit-identical regardless of how many steps were
lost, and after a re-shard each host regenerates exactly its slice.

The "repeat" task (a random pattern of length `pattern_len` tiled across
the sequence) is learnable by every assigned family, so example training
runs show a real loss decrease rather than noise-fitting.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticLMData"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    task: str = "repeat"  # "repeat" | "uniform"
    pattern_len: int = 8
    num_shards: int = 1
    shard: int = 0


class SyntheticLMData:
    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global batch must divide by shards")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards

    def batch(self, step: int) -> dict:
        """Local shard of batch `step`: {"tokens": (B_local, S) int32}."""
        cfg = self.cfg
        rows = []
        for i in range(self.local_batch):
            global_row = cfg.shard * self.local_batch + i
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) * 65_536 + global_row)
            if cfg.task == "uniform":
                rows.append(rng.integers(0, cfg.vocab_size, cfg.seq_len))
            else:
                pat = rng.integers(0, cfg.vocab_size, cfg.pattern_len)
                reps = -(-cfg.seq_len // cfg.pattern_len)
                rows.append(np.tile(pat, reps)[: cfg.seq_len])
        tokens = np.stack(rows).astype(np.int32)
        return {"tokens": tokens}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1

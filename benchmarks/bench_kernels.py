"""Kernel microbenchmarks: oracle (jnp, jit'd on CPU) timings + interpret-
mode correctness spot-check.  On-TPU numbers come from the same ops with
backend='pallas'."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hop_eval import hop_cost
from repro.kernels.lif_step import lif_step
from repro.kernels.link_load import link_loads
from repro.kernels.swap_delta import swap_deltas

from .common import emit


def _time(fn, *args, iters=20, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(full: bool = False) -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    k, w = 256, 16
    c = jnp.asarray(rng.integers(0, 100, (k, k)), jnp.float32)
    sym = c + c.T
    x = jnp.asarray(rng.integers(0, w, k), jnp.float32)
    y = jnp.asarray(rng.integers(0, w, k), jnp.float32)

    us = _time(hop_cost, c, x, y, backend="jnp")
    ok = abs(float(hop_cost(c, x, y, backend="interpret"))
             - float(hop_cost(c, x, y, backend="jnp"))) < 1.0
    rows.append({"name": "kernel/hop_eval_k256", "us_per_call": round(us, 1),
                 "derived": f"interpret_matches_oracle={ok};flops={2*k*k}"})

    us = _time(swap_deltas, sym, x, y, backend="jnp")
    d_i = np.asarray(swap_deltas(sym, x, y, backend="interpret"))
    d_o = np.asarray(swap_deltas(sym, x, y, backend="jnp"))
    ok = np.allclose(d_i, d_o, rtol=1e-4, atol=1e-2)
    rows.append({"name": "kernel/swap_delta_k256", "us_per_call": round(us, 1),
                 "derived": f"interpret_matches_oracle={ok};flops={4*k**3}"})

    n = 8192
    v = jnp.asarray(rng.standard_normal(n), jnp.float32)
    refr = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    cur = jnp.asarray(rng.standard_normal(n), jnp.float32)
    kw = dict(decay=0.9, threshold=1.0, v_reset=0.0, refractory=2)
    us = _time(lif_step, v, refr, cur, backend="jnp", **kw)
    a = lif_step(v, refr, cur, backend="interpret", **kw)
    b = lif_step(v, refr, cur, backend="jnp", **kw)
    ok = np.allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-5, atol=1e-6)
    rows.append({"name": "kernel/lif_step_n8192", "us_per_call": round(us, 1),
                 "derived": f"interpret_matches_oracle={ok}"})

    us = _time(link_loads, c, x, y, w, w, backend="jnp")
    pa = link_loads(c, x, y, w, w, backend="interpret")
    pb = link_loads(c, x, y, w, w, backend="jnp")
    ok = all(np.allclose(np.asarray(i), np.asarray(j), rtol=1e-4)
             for i, j in zip(pa, pb))
    rows.append({"name": "kernel/link_load_k256_16x16", "us_per_call": round(us, 1),
                 "derived": f"interpret_matches_oracle={ok}"})
    emit(rows, "kernel microbenchmarks (CPU oracle timings)")
    return rows


if __name__ == "__main__":
    run(full=True)

"""Scale benchmarks: sharded + out-of-core partitioning at 1e5..1e6 neurons.

Two row families (ISSUE 10, the million-neuron direction):

* ``scale/parity_<n>`` — the same synthetic fan-out SNN partitioned
  single-host and device-sharded.  Sharded coarsening draws its tie keys
  from a hash of the global edge index instead of the single-host rng
  stream, so the two runs legitimately differ — the gate is *quality*:
  comm_volume drift beyond ``PARITY_TOL`` stamps ``MISMATCH`` into the
  row and CI greps for it.  Shard-count invariance (2 shards vs 4 shards
  bitwise-identical) is asserted in-process on the same run.
* ``scale/million`` — 1M neurons / 10M synapses end-to-end through the
  sharded matcher plus the out-of-core ``LevelStore`` (at most two levels
  resident during uncoarsening).  ``peak_rss_mb`` is stamped right after
  the partition call — the bounded-per-host-memory claim, measured.

``--smoke`` runs the ~100k parity row only (CI-sized).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.partition import sneap_partition

from .bench_partition import synthetic_fanout_graph
from .common import emit, peak_memory

# Sharded-vs-single-host comm_volume drift tolerance (the ISSUE's
# "quality within 5% of single-host" acceptance bound).
PARITY_TOL = 0.05

# Cores sized so the coarse k stays modest at these vertex counts
# (1e5/1024 ~ 108 parts) and the Phi table fits the incremental engine.
_CAPACITY = 1024


def parity_row(n: int, fan: int = 10, shards: int = 4) -> dict:
    """Single-host vs sharded partition of one synthetic fan-out SNN."""
    g = synthetic_fanout_graph(n, fan=fan)
    t0 = time.perf_counter()
    single = sneap_partition(g, capacity=_CAPACITY, seed=0, impl="vec",
                             objective="cut")
    t_single = time.perf_counter() - t0
    t0 = time.perf_counter()
    shard = sneap_partition(g, capacity=_CAPACITY, seed=0, impl="vec",
                            objective="cut", shards=shards)
    t_shard = time.perf_counter() - t0
    # Shard-count invariance: hash tie keys make the matching — and with
    # it the whole partition — independent of how many blocks it ran in.
    half = sneap_partition(g, capacity=_CAPACITY, seed=0, impl="vec",
                           objective="cut", shards=max(2, shards // 2))
    invariant = bool(np.array_equal(shard.part, half.part))
    drift = abs(shard.comm_volume - single.comm_volume) / max(
        single.comm_volume, 1)
    ok = invariant and drift <= PARITY_TOL
    return {
        "name": f"scale/parity_{n}",
        "us_per_call": round(t_shard * 1e6, 1),
        "derived": (
            f"n={n};edges={g.num_edges};shards={shards};"
            f"vol_single={single.comm_volume};vol_sharded={shard.comm_volume};"
            f"drift_pct={drift * 100:.2f};"
            f"shard_invariant={'yes' if invariant else 'no'};"
            f"cut_single={single.edge_cut};cut_sharded={shard.edge_cut};"
            f"time_single_s={t_single:.2f};time_sharded_s={t_shard:.2f};"
            f"k={shard.k};parity={'ok' if ok else 'MISMATCH'}"
        ),
        **peak_memory(),
    }


def million_row(n: int = 1_000_000, fan: int = 10, shards: int = 8) -> dict:
    """1M-neuron / 10M-synapse end-to-end sharded + out-of-core partition."""
    t0 = time.perf_counter()
    g = synthetic_fanout_graph(n, fan=fan)
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    r = sneap_partition(g, capacity=_CAPACITY, seed=0, impl="vec",
                        objective="cut", shards=shards, stream_levels=True)
    t_part = time.perf_counter() - t0
    return {
        "name": f"scale/million_{n}",
        "us_per_call": round(t_part * 1e6, 1),
        "derived": (
            f"n={n};synapses={n * fan};edges={g.num_edges};shards={shards};"
            f"stream_levels=1;levels={r.num_levels};"
            f"cut={r.edge_cut};comm_volume={r.comm_volume};k={r.k};"
            f"time_build_s={t_build:.1f};time_partition_s={t_part:.1f}"
        ),
        **peak_memory(),  # stamped right after the partition: the claim
    }


def run(full: bool = False, smoke: bool = False) -> list[dict]:
    rows = [parity_row(100_000)]
    if not smoke:
        if full:
            rows.append(parity_row(250_000))
        rows.append(million_row())
    emit(rows, "scale/* rows: sharded vs single-host parity (<=5% drift, "
               "shard-count invariant) and the 1M-neuron out-of-core run "
               "with peak-RSS telemetry")
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        run(smoke=True)
    else:
        run(full="--quick" not in sys.argv)

"""Queued NoC replay: batched two-tier engine vs the scalar reference.

Old-vs-new rows for the evaluation phase (`simulate_noc(mode="queued")`):
unicast and multicast, congested and uncongested, including a >=1M
transmission synthetic trace (trajectory ``nocsim/*``).  Every row carries
a ``parity`` column: ``exact`` means every NoCStats field (including the
per-link load histogram) is bit-identical between engines; multicast rows
report ``static_exact`` (loads/energy/hops/packets identical) plus the
tree-vs-replica latency and congestion, which are *expected* to differ —
the tree-fork engine is strictly tighter than the replica upper bound.

Trace shapes and what they probe:
  * ``uncongested``  — high capacity, every window clears the contention
    screens: measures the analytic fast path against full cycle stepping.
  * ``congested_1m`` — bursty hotspots on a 16x16 mesh (1M transmissions,
    ~16k time-step windows): the headline regime, where the reference
    engine pays a Python loop per window per cycle and the batched engine
    steps only the contending packet subset.
  * ``saturated``    — every window heavily queued (worst case for the
    batched engine: both engines do comparable element-work; kept honest
    in full mode so the speedup columns are not cherry-picked).

``--smoke`` runs scaled-down versions of all shapes — quick enough for CI,
so engine parity regressions surface there and not just locally.
"""
from __future__ import annotations

import sys
import time
from dataclasses import asdict

import numpy as np

from repro.nocsim import simulate_noc

from .common import emit


def synth_trace(seed=0, n_spikes=1_000_000, timesteps=16_000, n_neurons=16384,
                cores=256, hot_windows_frac=0.25, hot_frac=0.7, nhot=2):
    """Bursty synthetic spike trace: uniform background plus a minority of
    hot windows whose traffic converges on a few destination neurons."""
    r = np.random.default_rng(seed)
    part = r.integers(0, cores, n_neurons)
    placement = r.permutation(cores)
    t = np.sort(r.integers(0, timesteps, n_spikes))
    src = r.integers(0, n_neurons, n_spikes)
    dst = r.integers(0, n_neurons, n_spikes)
    if hot_windows_frac:
        hot_steps = r.permutation(timesteps)[:int(timesteps * hot_windows_frac)]
        hot_neurons = r.integers(0, n_neurons, nhot)
        m = np.isin(t, hot_steps) & (r.random(n_spikes) < hot_frac)
        dst[m] = hot_neurons[r.integers(0, nhot, int(m.sum()))]
    return t, src, dst, part, placement


def fanout_trace(seed=0, n_firings=125_000, fan=8, timesteps=16_000,
                 n_neurons=16384, cores=256, hot_windows_frac=0.25,
                 hot_frac=0.5, nhot=4):
    """Multicast-shaped trace: each firing fans out to ``fan`` targets, so
    replicas share XY-tree prefixes and the cast models diverge."""
    r = np.random.default_rng(seed)
    part = r.integers(0, cores, n_neurons)
    placement = r.permutation(cores)
    ft = np.sort(r.integers(0, timesteps, n_firings))
    fsrc = r.integers(0, n_neurons, n_firings)
    t, src = np.repeat(ft, fan), np.repeat(fsrc, fan)
    dst = r.integers(0, n_neurons, n_firings * fan)
    if hot_windows_frac:
        hot_steps = r.permutation(timesteps)[:int(timesteps * hot_windows_frac)]
        hot_neurons = r.integers(0, n_neurons, nhot)
        m = np.isin(t, hot_steps) & (r.random(t.shape[0]) < hot_frac)
        dst[m] = hot_neurons[r.integers(0, nhot, int(m.sum()))]
    return t, src, dst, part, placement


def _full_parity(a, b) -> bool:
    da, db = asdict(a), asdict(b)
    return all((np.array_equal(da[k], db[k]) if isinstance(da[k], np.ndarray)
                else da[k] == db[k]) for k in da)


def _static_parity(a, b) -> bool:
    return (a.num_noc_spikes == b.num_noc_spikes
            and a.num_local_spikes == b.num_local_spikes
            and a.total_hops == b.total_hops
            and a.link_traversals == b.link_traversals
            and a.dynamic_energy_pj == b.dynamic_energy_pj
            and np.array_equal(a.per_link_hops, b.per_link_hops))


def replay_row(name, trace, mesh, link_capacity, cast="unicast") -> dict:
    t, src, dst, part, placement = trace
    args = dict(link_capacity=link_capacity, cast=cast)

    def timed(engine):
        # Steady-state timing: one untimed warm-up call per engine.  The
        # batched engine's first call in a process faults in GBs of fresh
        # pages, and under a VM that first-touch backing costs seconds of
        # *sys* time with run-to-run variance larger than the engine's own
        # compute (user time is identical cold vs warm) — warming the
        # allocator keeps the speedup columns about the engines, not the
        # host's page-backing latency.
        simulate_noc(t, src, dst, part, placement, mesh, mesh,
                     engine=engine, **args)
        t0 = time.perf_counter()
        out = simulate_noc(t, src, dst, part, placement, mesh, mesh,
                           engine=engine, **args)
        return out, time.perf_counter() - t0

    new, t_new = timed("batched")
    ref, t_ref = timed("ref")
    if cast == "unicast":
        parity = "exact" if _full_parity(ref, new) else "MISMATCH"
        extra = ""
    else:
        parity = ("static_exact" if _static_parity(ref, new)
                  else "STATIC_MISMATCH")
        # Tree-fork vs replica: latency/congestion strictly tighter, and
        # the engine simulates tree-link flit-hops, not replica hop sums.
        extra = (f";lat_tree={new.avg_latency:.4f}"
                 f";lat_replica={ref.avg_latency:.4f}"
                 f";cong_tree={new.congestion_count}"
                 f";cong_replica={ref.congestion_count}"
                 f";flit_hops_tree={new.link_traversals}"
                 f";flit_hops_replica={ref.total_hops}")
    return {
        "name": f"nocsim/{name}",
        "us_per_call": round(t_new * 1e6, 1),
        "derived": (
            f"transmissions={t.shape[0]};windows={np.unique(t).shape[0]};"
            f"mesh={mesh}x{mesh};cap={link_capacity};cast={cast};"
            f"time_ref_s={t_ref:.3f};time_new_s={t_new:.3f};"
            f"speedup={t_ref / max(t_new, 1e-9):.1f}x;parity={parity};"
            f"congestion={new.congestion_count};"
            f"avg_latency={new.avg_latency:.4f}" + extra
        ),
    }


def run(full: bool = False, smoke: bool = False) -> list[dict]:
    if smoke:
        uni = dict(n_spikes=60_000, timesteps=1200, n_neurons=2048, cores=64)
        mc = dict(n_firings=8_000, fan=6, timesteps=1200, n_neurons=2048,
                  cores=64)
        mesh, sat_steps = 8, 60
    else:
        uni = dict(n_spikes=1_000_000, timesteps=16_000)
        mc = dict(n_firings=125_000, fan=8, timesteps=16_000)
        mesh, sat_steps = 16, 500
    rows = [
        replay_row("uncongested_unicast",
                   synth_trace(hot_windows_frac=0.0, **uni), mesh,
                   link_capacity=256),
        replay_row("congested_unicast_1m", synth_trace(**uni), mesh,
                   link_capacity=4),
        replay_row("uncongested_multicast",
                   fanout_trace(hot_windows_frac=0.0, **mc), mesh,
                   link_capacity=256, cast="multicast"),
        replay_row("congested_multicast_1m", fanout_trace(**mc), mesh,
                   link_capacity=4, cast="multicast"),
    ]
    if full:
        # Saturation worst case: every window queues heavily; both engines
        # must do comparable element-work (speedup ~1x, parity must hold).
        sat = synth_trace(n_spikes=1_000_000, timesteps=sat_steps,
                          n_neurons=4096, cores=64, hot_windows_frac=1.0,
                          hot_frac=0.2, nhot=4)
        rows.append(replay_row("saturated_unicast", sat, 8, link_capacity=4))
    emit(rows, "NoC queued replay: batched two-tier engine vs scalar "
               "reference (old-vs-new, unicast + multicast)")
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        run(smoke=True)
    else:
        run(full="--quick" not in sys.argv)

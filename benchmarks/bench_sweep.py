"""Batched toolchain sweep vs the sequential run_toolchain loop.

The sweep driver (`repro.launch.sweep.run_sweep`) answers the
design-space-exploration question — best (k, mesh, objective, mapper,
seed) for a workload — in one shot: shared partition/traffic phases are
deduplicated across the config grid, same-shape ``sa_jax`` searches run
as one vmapped device program, and ``stepper="jax"`` replays share
pow2-padded compiled programs.  This bench times that driver against the
honest baseline — the same configs run one `run_toolchain` call at a
time — and verifies *exact stat parity* per config along the way (every
sequential summary must equal its sweep row bitwise; any divergence
prints MISMATCH, a CI grep gate).

Row families (trajectory ``sweep/*``):

  * ``sweep/<mesh>_<n>cfg`` — sweep vs sequential wall-clock, the
    partition-run dedup factor, and the Pareto-front size.
  * ``sweep/parity`` — per-config exact-parity verdict over the whole
    grid (``exact`` or ``MISMATCH``).
  * ``sweep/measured_defaults`` — data-driven defaults for the
    CPU-reasoned crossover knobs measured by the grid itself: mean phase
    seconds per ``stepper``, ``score_backend``, and refiner-kernel knob
    setting at this scale (closes ROADMAP's hardware-threshold item).

``--smoke`` runs a small 6x6 grid for CI; full mode runs the
acceptance-scale 16x16 grid (32+ configs) and writes
``results/bench_sweep.csv``.
"""
from __future__ import annotations

import sys
import time
from collections import defaultdict

from repro.core import run_toolchain
from repro.launch.sweep import config_grid, run_sweep

from .common import emit, get_profile


def _grids(smoke: bool):
    """The config grid: device-bucketed sa_jax half + host-engine half."""
    if smoke:
        mesh, capacity, impl = (6, 6), 32, "vec"
        jax_kw = [{"iters": 1500, "chains": 4}]
        sa_kw = [{"impl": "vec", "iters": 1500, "score_backend": "numpy"}]
        seeds, seeds_host = [0, 1], [0, 1]
        knobs = [{}]
        steppers = ["numpy", "jax"]
    else:
        mesh, capacity, impl = (16, 16), 8, "vec"
        jax_kw = [{"iters": 4000, "chains": 8}, {"iters": 8000, "chains": 8}]
        sa_kw = [{"impl": "vec", "iters": 4000, "score_backend": "numpy"},
                 {"impl": "vec", "iters": 4000, "score_backend": "jnp"}]
        seeds, seeds_host = [0, 1], [0, 1, 2, 3]
        knobs = [{}, {"_KERNEL_MAX_N": 1024, "_KERNEL_MIN_K": 32}]
        steppers = ["numpy", "jax"]
    device = config_grid(
        mesh=[mesh], capacity=[capacity], partition_impl=[impl],
        seed=seeds, objective=["cut", "volume"], knobs=knobs,
        mapper=["sa_jax"], mapper_kwargs=jax_kw, stepper=["jax"],
    )
    host = config_grid(
        mesh=[mesh], capacity=[capacity], partition_impl=[impl],
        seed=seeds_host, objective=["cut"], mapper=["sa"],
        mapper_kwargs=sa_kw, stepper=steppers,
    )
    return device + host


def _measured_defaults(rows: list[dict]) -> str:
    """Mean phase seconds per knob setting -> recommended defaults."""
    out = []
    for axis, phase in (("stepper", "evaluate_s"),
                        ("score_backend", "mapping_s"),
                        ("knobs", "partition_s")):
        groups = defaultdict(list)
        for r in rows:
            key = r[axis] if r[axis] else "default"
            groups[key].append(float(r[phase]))
        if len(groups) < 2:
            continue
        means = {k: sum(v) / len(v) for k, v in groups.items()}
        best = min(means, key=means.get)
        detail = " ".join(f"{k}:{v:.3f}s" for k, v in sorted(means.items()))
        out.append(f"{axis}[{phase}] {detail} -> {best}")
    return " | ".join(out)


def run(full: bool = False, smoke: bool = False) -> list[dict]:
    snn = "smooth_320" if smoke else "smooth_1280"
    prof = get_profile(snn, full)
    configs = _grids(smoke)
    n = len(configs)
    mesh = f"{configs[0].mesh_w}x{configs[0].mesh_h}"

    # Sweep first: it pays every shared jit compile, so any cache warmth
    # biases the comparison *against* the sweep, never for it.
    t0 = time.perf_counter()
    res = run_sweep(prof, configs, progress=lambda m: print(f"# {m}",
                                                           file=sys.stderr))
    sweep_s = time.perf_counter() - t0

    # Sequential baseline doubles as the exact-parity check: every config
    # re-runs through run_toolchain and its summary must equal the sweep
    # row bitwise on all non-timing fields.
    t0 = time.perf_counter()
    mismatches = 0
    for cfg, row in zip(configs, res.rows):
        s = run_toolchain(prof, config=cfg).summary()
        for k, v in s.items():
            if not k.endswith("_s") and v != row[k]:
                mismatches += 1
                print(f"# MISMATCH {k}: sweep={row[k]} sequential={v} "
                      f"(mapper={cfg.mapper} seed={cfg.seed} "
                      f"objective={cfg.objective})", file=sys.stderr)
    seq_s = time.perf_counter() - t0

    part_runs = len({c.resolve(prof.graph.hyper).partition_key()
                     for c in configs})
    rows = [
        {
            "name": f"sweep/{mesh}_{n}cfg",
            "us_per_call": round(sweep_s * 1e6, 1),
            "derived": (
                f"sweep_s={sweep_s:.2f};sequential_s={seq_s:.2f};"
                f"speedup={seq_s / max(sweep_s, 1e-9):.2f}x;"
                f"configs={n};partition_runs={part_runs};"
                f"pareto_front={len(res.front())};workload={snn}"
            ),
        },
        {
            "name": "sweep/parity",
            "us_per_call": 0.0,
            "derived": (f"checked={n};parity="
                        + ("exact" if mismatches == 0
                           else f"MISMATCH({mismatches})")),
        },
        {
            "name": "sweep/measured_defaults",
            "us_per_call": 0.0,
            "derived": _measured_defaults(res.rows) or "n/a",
        },
    ]
    emit(rows, f"sweep driver vs sequential loop ({mesh}, {n} configs)")
    if not smoke:
        res.write_csv("results/bench_sweep_rows.csv")
        import csv

        with open("results/bench_sweep.csv", "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        run(smoke=True)
    else:
        run(full="--full" in sys.argv)

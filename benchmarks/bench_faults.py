"""Graceful degradation under injected faults: incremental vs scratch re-map.

Sweeps mid-trace core-failure counts (and a link-failure row) on 8x8 and
16x16 meshes through `run_toolchain(fault_schedule=...)`, comparing the
two repair strategies (`repro.core.remap`):

  * ``incremental`` — evict only what must move, warm-start the SA chain
    from the live placement under the migration-aware objective;
  * ``scratch``     — re-partition + re-place from nothing on the
    surviving cores (the from-scratch baseline).

Row families (trajectory ``faults/*``):

  * ``zero_fault_parity_*`` — a zero-event `FaultSchedule` must reproduce
    the fault-free replay bit for bit on every `NoCStats` field; the
    ``parity`` column says ``exact`` or ``MISMATCH`` (a CI grep gate).
  * ``<mesh>_core<n>_<strategy>`` — degraded energy/latency, spikes lost
    during the detection lag, neurons migrated, and remap wall time for
    one strategy under an ``n``-core mid-trace failure.
  * ``<mesh>_core<n>_inc_vs_scratch`` — the head-to-head: energy ratio
    and migration ratio (incremental / scratch), with the acceptance
    verdict ``accept=pass`` when incremental lands within 5% of scratch
    energy while moving < 25% of the neurons scratch moves.
  * ``<mesh>_link<n>`` — link-only failures re-route (detours) without
    any re-map event.

``--smoke`` runs the 8x8 sweep small enough for CI; full mode adds the
16x16 acceptance-scale sweep.
"""
from __future__ import annotations

import sys
from dataclasses import asdict

import numpy as np

from repro.core import run_toolchain
from repro.core.graph import build_graph, build_hypergraph
from repro.runtime.faults import FaultEvent, FaultSchedule
from repro.snn.simulate import ProfileResult

from .common import emit


def synth_profile(n, fan=8, n_spikes=50_000, timesteps=100, seed=1):
    """Fan-out SNN + random spike trace packaged as a ProfileResult."""
    r = np.random.default_rng(seed)
    syn_src = np.repeat(np.arange(n), fan)
    syn_dst = r.integers(0, n, n * fan)
    fire = r.integers(1, 20, n)
    g = build_graph(n, syn_src, syn_dst, fire[syn_src])
    g.hyper = build_hypergraph(n, syn_src, syn_dst, fire)
    t = np.sort(r.integers(0, timesteps, n_spikes))
    src = r.integers(0, n, n_spikes)
    dst = r.integers(0, n, n_spikes)
    return ProfileResult(
        name=f"synth_{n}", graph=g, trace_t=t, trace_src=src, trace_dst=dst,
        num_neurons=n, num_steps=timesteps,
        fire_counts=np.bincount(src, minlength=n), seconds=0.0,
    )


def _full_parity(a, b) -> bool:
    da, db = asdict(a), asdict(b)
    return all((np.array_equal(da[k], db[k]) if isinstance(da[k], np.ndarray)
                else da[k] == db[k]) for k in da)


def _strategy_row(mesh, nf, strat, res) -> dict:
    s = res.summary()
    d = res.degradation
    return {
        "name": f"faults/{mesh}x{mesh}_core{nf}_{strat}",
        "us_per_call": round(s["remap_s"] * 1e6, 1),
        "derived": (
            f"mesh={mesh}x{mesh};core_faults={nf};strategy={strat};"
            f"energy_pj={s['energy_pj']:.0f};avg_latency={s['avg_latency']:.4f};"
            f"spikes_dropped={s['spikes_dropped']};"
            f"neurons_migrated={s['neurons_migrated']};"
            f"neurons_evicted={d['neurons_evicted']};"
            f"remap_events={s['remap_events']};remap_s={s['remap_s']:.3f};"
            f"final_k={d['final_k']}"
        ),
    }


def mesh_sweep(mesh, prof, capacity, timesteps, fault_counts, link_faults,
               tc_kwargs) -> list[dict]:
    tc = dict(mesh_w=mesh, mesh_h=mesh, capacity=capacity, **tc_kwargs)
    rows = []
    base = run_toolchain(prof, **tc)
    empty = run_toolchain(prof, fault_schedule=FaultSchedule([]), **tc)
    parity = "exact" if _full_parity(base.noc, empty.noc) else "MISMATCH"
    rows.append({
        "name": f"faults/zero_fault_parity_{mesh}x{mesh}",
        "us_per_call": round(empty.phase_seconds["evaluate"] * 1e6, 1),
        "derived": (
            f"mesh={mesh}x{mesh};parity={parity};"
            f"energy_pj={base.noc.dynamic_energy_pj:.0f};"
            f"avg_latency={base.noc.avg_latency:.4f};k={base.partition.k}"
        ),
    })
    for nf in fault_counts:
        # victims: populated cores of the live placement -> the failure
        # actually displaces neurons (deterministic per run)
        victims = tuple(int(c) for c in base.mapping.placement[:nf])
        sched = FaultSchedule([FaultEvent(timesteps // 2, "core", victims)])
        res = {}
        for strat in ("incremental", "scratch"):
            res[strat] = run_toolchain(prof, fault_schedule=sched,
                                       remap_strategy=strat, **tc)
            rows.append(_strategy_row(mesh, nf, strat, res[strat]))
        inc, scr = res["incremental"], res["scratch"]
        e_ratio = (inc.noc.dynamic_energy_pj
                   / max(scr.noc.dynamic_energy_pj, 1e-9))
        m_ratio = (inc.degradation["neurons_migrated"]
                   / max(scr.degradation["neurons_migrated"], 1))
        verdict = "pass" if e_ratio <= 1.05 and m_ratio < 0.25 else "miss"
        rows.append({
            "name": f"faults/{mesh}x{mesh}_core{nf}_inc_vs_scratch",
            "us_per_call": round(inc.degradation["remap_s"] * 1e6, 1),
            "derived": (
                f"mesh={mesh}x{mesh};core_faults={nf};"
                f"energy_ratio={e_ratio:.4f};migration_ratio={m_ratio:.4f};"
                f"remap_s_inc={inc.degradation['remap_s']:.3f};"
                f"remap_s_scratch={scr.degradation['remap_s']:.3f};"
                f"accept={verdict}"
            ),
        })
    if link_faults:
        sched = FaultSchedule.random(mesh, mesh, 0, timesteps,
                                     n_link_faults=link_faults, seed=2)
        res = run_toolchain(prof, fault_schedule=sched, **tc)
        rows.append({
            "name": f"faults/{mesh}x{mesh}_link{link_faults}",
            "us_per_call": round(res.phase_seconds["evaluate"] * 1e6, 1),
            "derived": (
                f"mesh={mesh}x{mesh};link_faults={link_faults};"
                f"detour_hops={res.noc.detour_hops};"
                f"spikes_dropped={res.noc.spikes_dropped};"
                f"remap_events={res.degradation['remap_events']};"
                f"energy_pj={res.noc.dynamic_energy_pj:.0f}"
            ),
        })
    return rows


def run(full: bool = False, smoke: bool = False) -> list[dict]:
    rows = []
    tc = dict(seed=0, partition_impl="vec",
              mapper_kwargs={"iters": 4000 if smoke else 12_000})
    small = synth_profile(1500, fan=6,
                          n_spikes=30_000 if smoke else 80_000,
                          timesteps=60 if smoke else 120)
    rows += mesh_sweep(8, small, capacity=40,
                       timesteps=small.num_steps,
                       fault_counts=(2,) if smoke else (2, 4, 8),
                       link_faults=4, tc_kwargs=tc)
    if not smoke:
        big = synth_profile(6000, fan=8, n_spikes=200_000, timesteps=200,
                            seed=2)
        rows += mesh_sweep(16, big, capacity=40, timesteps=200,
                           fault_counts=(2, 4, 8), link_faults=8,
                           tc_kwargs=tc)
    emit(rows, "graceful degradation: fault sweep, incremental vs "
               "from-scratch re-mapping (zero-fault parity gated)")
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        run(smoke=True)
    else:
        run(full="--quick" not in sys.argv)

"""Paper Fig. 7: overall toolchain results — average latency, dynamic
energy, edge variance, congestion count for SNEAP / SpiNeMap / SCO,
normalized to SpiNeMap."""
from __future__ import annotations

from repro.core import run_toolchain

from .common import emit, get_profile, scale


def run(full: bool = False) -> list[dict]:
    s = scale(full)
    rows = []
    for snn in s["snns"]:
        prof = get_profile(snn, full)
        mesh_w = 5 if prof.num_neurons <= 25 * 256 else 8
        mode = "queued" if prof.num_spikes < 6_000_000 else "analytic"
        results = {}
        for method in ("sneap", "spinemap", "sco"):
            budget = {"sneap": {"iters": s["sa_iters"]},
                      "spinemap": {"iters": s["pso_iters"]},
                      "sco": {}}[method]
            results[method] = run_toolchain(
                prof, method=method, mesh_w=mesh_w, mesh_h=mesh_w, seed=0,
                noc_mode=mode, mapper_kwargs=budget)
        ref = results["spinemap"].noc
        for method, r in results.items():
            rows.append({
                "name": f"overall/{snn}/{method}",
                "us_per_call": round(r.total_seconds * 1e6, 1),
                "derived": (
                    f"latency={r.noc.avg_latency:.3f};"
                    f"latency_vs_spinemap={r.noc.avg_latency / max(ref.avg_latency, 1e-9):.3f};"
                    f"energy_vs_spinemap={r.noc.dynamic_energy_pj / max(ref.dynamic_energy_pj, 1e-9):.3f};"
                    f"edgevar_vs_spinemap={r.noc.edge_variance / max(ref.edge_variance, 1e-9):.3f};"
                    f"congestion_vs_spinemap={r.noc.congestion_count / max(ref.congestion_count, 1):.3f};"
                    f"cut={r.partition.edge_cut};avg_hop={r.mapping.avg_hop:.4f}"
                ),
            })
    emit(rows, "Fig7: overall toolchain metrics (normalized to SpiNeMap)")
    return rows


if __name__ == "__main__":
    run(full=True)

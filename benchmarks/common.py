"""Shared benchmark plumbing: profile cache, CSV emission, scale control."""
from __future__ import annotations

import csv
import io
import resource
import sys
from pathlib import Path

from repro.snn import PAPER_SNNS, make_snn, profile_snn

CACHE_DIR = Path("results/profile_cache")

# quick mode: short profiling window + small mapper budgets (CI-friendly);
# full mode: Table 1 spike counts + paper-scale budgets.
QUICK = {"num_steps": 250, "sa_iters": 6000, "pso_iters": 40, "tabu_iters": 60,
         "snns": ["smooth_320", "smooth_1280"]}
FULL = {"num_steps": 1200, "sa_iters": 40_000, "pso_iters": 150,
        "tabu_iters": 200, "snns": PAPER_SNNS}


def scale(full: bool) -> dict:
    return FULL if full else QUICK


def get_profile(name: str, full: bool):
    s = scale(full)
    return profile_snn(make_snn(name), num_steps=s["num_steps"], seed=0,
                       cache_dir=CACHE_DIR)


def peak_memory() -> dict:
    """Peak-memory telemetry: process RSS high-water plus, when a JAX
    backend is live, the first device's allocator high-water.

    ``ru_maxrss`` is monotone over the process lifetime (kilobytes on
    Linux), so a row stamped mid-run records "peak so far" — benchmarks
    that care about a specific phase call this right after the phase, and
    ``emit`` back-fills every row that did not stamp itself.
    """
    mem = {"peak_rss_mb":
           round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1)}
    try:
        import jax

        stats = jax.local_devices()[0].memory_stats() or {}
        peak = stats.get("peak_bytes_in_use")
        if peak is not None:
            mem["device_peak_mb"] = round(peak / 2**20, 1)
    except Exception:
        pass  # no jax / backend without memory_stats: RSS-only telemetry
    return mem


def emit(rows: list[dict], header: str = "") -> None:
    """Print rows as CSV to stdout (the benchmark contract).

    Every row is stamped with ``peak_memory()`` telemetry columns; rows
    that already carry a value (stamped at measurement time) keep theirs.
    """
    if not rows:
        return
    mem = peak_memory()
    for row in rows:
        for key, val in mem.items():
            row.setdefault(key, val)
    if header:
        print(f"# {header}")
    buf = io.StringIO()
    fields = list(dict.fromkeys(key for row in rows for key in row))
    w = csv.DictWriter(buf, fieldnames=fields, restval="")
    w.writeheader()
    w.writerows(rows)
    sys.stdout.write(buf.getvalue())

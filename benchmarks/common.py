"""Shared benchmark plumbing: profile cache, CSV emission, scale control."""
from __future__ import annotations

import csv
import io
import sys
from pathlib import Path

from repro.snn import PAPER_SNNS, make_snn, profile_snn

CACHE_DIR = Path("results/profile_cache")

# quick mode: short profiling window + small mapper budgets (CI-friendly);
# full mode: Table 1 spike counts + paper-scale budgets.
QUICK = {"num_steps": 250, "sa_iters": 6000, "pso_iters": 40, "tabu_iters": 60,
         "snns": ["smooth_320", "smooth_1280"]}
FULL = {"num_steps": 1200, "sa_iters": 40_000, "pso_iters": 150,
        "tabu_iters": 200, "snns": PAPER_SNNS}


def scale(full: bool) -> dict:
    return FULL if full else QUICK


def get_profile(name: str, full: bool):
    s = scale(full)
    return profile_snn(make_snn(name), num_steps=s["num_steps"], seed=0,
                       cache_dir=CACHE_DIR)


def emit(rows: list[dict], header: str = "") -> None:
    """Print rows as CSV to stdout (the benchmark contract)."""
    if not rows:
        return
    if header:
        print(f"# {header}")
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    w.writeheader()
    w.writerows(rows)
    sys.stdout.write(buf.getvalue())

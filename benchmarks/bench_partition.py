"""Paper Fig. 4: partitioning-phase global traffic + execution time,
SNEAP (multilevel) vs SpiNeMap (greedy KL), normalized to SpiNeMap."""
from __future__ import annotations

from repro.core import greedy_kl_partition, sneap_partition

from .common import emit, get_profile, scale


def run(full: bool = False) -> list[dict]:
    rows = []
    for snn in scale(full)["snns"]:
        prof = get_profile(snn, full)
        mesh_cores = 25 if prof.num_neurons <= 25 * 256 else 64
        sneap = sneap_partition(prof.graph, capacity=256, seed=0)
        spine = greedy_kl_partition(prof.graph, capacity=256, seed=0)
        rows.append({
            "name": f"partition/{snn}",
            "us_per_call": round(sneap.seconds * 1e6, 1),
            "derived": (
                f"cut_sneap={sneap.edge_cut};cut_spinemap={spine.edge_cut};"
                f"traffic_ratio={sneap.edge_cut / max(spine.edge_cut, 1):.3f};"
                f"time_sneap_s={sneap.seconds:.3f};time_spinemap_s={spine.seconds:.3f};"
                f"speedup={spine.seconds / max(sneap.seconds, 1e-9):.1f}x;"
                f"spikes={prof.num_spikes};k={sneap.k}"
            ),
        })
    emit(rows, "Fig4: partitioning traffic + time (SNEAP vs greedy-KL)")
    return rows


if __name__ == "__main__":
    run(full=True)

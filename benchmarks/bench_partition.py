"""Paper Fig. 4: partitioning-phase global traffic + execution time,
SNEAP (multilevel) vs SpiNeMap (greedy KL), normalized to SpiNeMap.

Also tracks the scalar-vs-vec partitioning engines (`sneap_partition`'s
`impl` switch): cut parity and wall-clock on the paper SNNs, plus a
>=100k-neuron synthetic graph where the array-parallel engine's >=10x
speedup is the headline (BENCH_* trajectory `partition_impl/*`).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import greedy_kl_partition, sneap_partition
from repro.core.graph import build_graph

from .common import emit, get_profile, scale

# >=100k neurons in both modes so the large-graph speedup is always
# measured; full mode doubles the synaptic density.
SYNTH_QUICK = dict(n=100_000, avg_deg=8)
SYNTH_FULL = dict(n=120_000, avg_deg=16)


def synthetic_graph(n: int, avg_deg: int, seed: int = 0, max_w: int = 50):
    """Sparse random spike graph (edge-list sampling; no dense n^2 mask)."""
    r = np.random.default_rng(seed)
    m = n * avg_deg // 2
    return build_graph(n, r.integers(0, n, m), r.integers(0, n, m),
                       r.integers(1, max_w, m))


def run(full: bool = False) -> list[dict]:
    rows = []
    for snn in scale(full)["snns"]:
        prof = get_profile(snn, full)
        sneap = sneap_partition(prof.graph, capacity=256, seed=0)
        vec = sneap_partition(prof.graph, capacity=256, seed=0, impl="vec")
        spine = greedy_kl_partition(prof.graph, capacity=256, seed=0)
        rows.append({
            "name": f"partition/{snn}",
            "us_per_call": round(sneap.seconds * 1e6, 1),
            "derived": (
                f"cut_sneap={sneap.edge_cut};cut_spinemap={spine.edge_cut};"
                f"traffic_ratio={sneap.edge_cut / max(spine.edge_cut, 1):.3f};"
                f"time_sneap_s={sneap.seconds:.3f};time_spinemap_s={spine.seconds:.3f};"
                f"speedup={spine.seconds / max(sneap.seconds, 1e-9):.1f}x;"
                f"spikes={prof.num_spikes};k={sneap.k}"
            ),
        })
        rows.append({
            "name": f"partition_impl/{snn}",
            "us_per_call": round(vec.seconds * 1e6, 1),
            "derived": (
                f"cut_scalar={sneap.edge_cut};cut_vec={vec.edge_cut};"
                f"cut_ratio={vec.edge_cut / max(sneap.edge_cut, 1):.3f};"
                f"time_scalar_s={sneap.seconds:.3f};time_vec_s={vec.seconds:.3f};"
                f"speedup={sneap.seconds / max(vec.seconds, 1e-9):.1f}x;k={vec.k}"
            ),
        })

    # Large synthetic graph: the scale where the scalar engine's per-vertex
    # Python loops become impractical and the vec engine must deliver >=10x.
    cfg = SYNTH_FULL if full else SYNTH_QUICK
    g = synthetic_graph(**cfg)
    t0 = time.perf_counter()
    vec = sneap_partition(g, capacity=256, seed=0, impl="vec")
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    scalar = sneap_partition(g, capacity=256, seed=0, impl="scalar")
    t_scalar = time.perf_counter() - t0
    rows.append({
        "name": f"partition_impl/synthetic_{cfg['n']}",
        "us_per_call": round(t_vec * 1e6, 1),
        "derived": (
            f"n={cfg['n']};edges={g.num_edges};"
            f"cut_scalar={scalar.edge_cut};cut_vec={vec.edge_cut};"
            f"cut_ratio={vec.edge_cut / max(scalar.edge_cut, 1):.3f};"
            f"time_scalar_s={t_scalar:.2f};time_vec_s={t_vec:.2f};"
            f"speedup={t_scalar / max(t_vec, 1e-9):.1f}x;k={vec.k}"
        ),
    })
    emit(rows, "Fig4: partitioning traffic + time (SNEAP vs greedy-KL; scalar vs vec)")
    return rows


if __name__ == "__main__":
    run(full=True)

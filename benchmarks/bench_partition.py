"""Paper Fig. 4: partitioning-phase global traffic + execution time,
SNEAP (multilevel) vs SpiNeMap (greedy KL), normalized to SpiNeMap.

Also tracks:
  * the scalar-vs-vec partitioning engines (`sneap_partition`'s `impl`
    switch): cut parity and wall-clock on the paper SNNs, plus a >=100k
    neuron synthetic graph where the array-parallel engine's >=10x speedup
    is the headline (BENCH_* trajectory `partition_impl/*`); and
  * the cut-vs-volume objectives (`objective` switch): communication
    volume and edge cut of both partitions on each SNN, i.e. how much
    multicast traffic the hMETIS-style connectivity-(λ−1) objective saves
    over the paper's edge-cut objective (trajectory `objective/*`); and
  * the volume-refinement *speed gap* (trajectory `volume/*`): volume vs
    cut wall-time through the vec engine on fan-out-heavy graphs, the
    regime where per-move λ-gain updates used to cost 5-10x the cut path
    before the incremental-Φ / plateau-walk refiner.

``--smoke`` runs a single small SNN + a small synthetic graph — quick
enough for CI, so objective regressions surface there and not just
locally.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import greedy_kl_partition, sneap_partition
from repro.core.graph import build_graph, build_hypergraph

from .common import emit, get_profile, scale

# >=100k neurons in both modes so the large-graph speedup is always
# measured; full mode doubles the synaptic density.
SYNTH_QUICK = dict(n=100_000, avg_deg=8)
SYNTH_FULL = dict(n=120_000, avg_deg=16)
SYNTH_SMOKE = dict(n=20_000, avg_deg=8)


def synthetic_graph(n: int, avg_deg: int, seed: int = 0, max_w: int = 50):
    """Sparse random spike graph (edge-list sampling; no dense n^2 mask)."""
    r = np.random.default_rng(seed)
    m = n * avg_deg // 2
    return build_graph(n, r.integers(0, n, m), r.integers(0, n, m),
                       r.integers(1, max_w, m))


def synthetic_fanout_graph(n: int, fan: int = 12, seed: int = 0):
    """Fan-out-heavy traffic with the multicast hypergraph attached —
    the regime where cut and volume objectives diverge most."""
    r = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), fan)
    dst = r.integers(0, n, n * fan)
    fire = r.integers(1, 30, n)
    g = build_graph(n, src, dst, fire[src])
    g.hyper = build_hypergraph(n, src, dst, fire)
    return g


# Tuned plateau budget for the fan-out speed rows.  The old per-hyperedge
# conflict scoping admitted ~2 movers per round on the n=4000 fan-out
# graph (every candidate pair co-scoped through the hub edges), making
# the round-dispatch overhead the dominant cost (ISSUE 7).  The
# per-(hyperedge, partition-column) slot scoping now admits every mover
# whose Φ columns sit clear of a presence threshold — ~9 movers per
# round on this graph — so fewer, fatter rounds both descend further
# (better untuned comm_volume) and leave a cheaper plateau budget: a
# stall budget of 1 (default 12) keeps the comm_volume premium under
# the previously recorded +1.4% while the ``*_tuned`` fields keep the
# knob's trade-off measured.
_FANOUT_PLATEAU = 1


def volume_row(name: str, graph, capacity: int = 64) -> dict:
    """One volume-vs-cut *speed* row through the vec engine.

    Tracks ROADMAP's "volume refinement is 5-10x slower than cut" item:
    ``time_ratio`` is volume wall-time over cut wall-time with identical
    arguments (impl="vec"), and both objectives' comm_volume is reported
    so speed never silently buys quality regressions.  The ``*_tuned``
    fields re-run volume with the fan-out-tuned plateau budget
    (``plateau_rounds=_FANOUT_PLATEAU``) — the measured mitigation for
    the round-structure cost described above.
    """
    t0 = time.perf_counter()
    cut = sneap_partition(graph, capacity=capacity, seed=0, impl="vec",
                          objective="cut")
    t_cut = time.perf_counter() - t0
    t0 = time.perf_counter()
    vol = sneap_partition(graph, capacity=capacity, seed=0, impl="vec",
                          objective="volume")
    t_vol = time.perf_counter() - t0
    t0 = time.perf_counter()
    tuned = sneap_partition(graph, capacity=capacity, seed=0, impl="vec",
                            objective="volume",
                            plateau_rounds=_FANOUT_PLATEAU)
    t_tuned = time.perf_counter() - t0
    return {
        "name": f"volume/{name}",
        "us_per_call": round(t_vol * 1e6, 1),
        "derived": (
            f"time_cut_s={t_cut:.3f};time_vol_s={t_vol:.3f};"
            f"time_ratio={t_vol / max(t_cut, 1e-9):.2f};"
            f"time_vol_tuned_s={t_tuned:.3f};"
            f"ratio_tuned={t_tuned / max(t_cut, 1e-9):.2f};"
            f"plateau_tuned={_FANOUT_PLATEAU};"
            f"vol_of_cutopt={cut.comm_volume};vol_of_volopt={vol.comm_volume};"
            f"vol_tuned={tuned.comm_volume};"
            f"volume_saved={1 - vol.comm_volume / max(cut.comm_volume, 1):.3f};"
            f"k={vol.k}"
        ),
    }


def objective_row(name: str, graph, capacity: int = 256, cut=None) -> dict:
    """One cut-vs-volume comparison row over an attached hypergraph.

    ``cut`` reuses an already-computed scalar cut-objective result
    (identical arguments) instead of re-running the slowest phase.
    """
    if cut is None:
        cut = sneap_partition(graph, capacity=capacity, seed=0, objective="cut")
    t_cut = cut.seconds
    t0 = time.perf_counter()
    vol = sneap_partition(graph, capacity=capacity, seed=0, objective="volume")
    t_vol = time.perf_counter() - t0
    saved = 1 - vol.comm_volume / max(cut.comm_volume, 1)
    return {
        "name": f"objective/{name}",
        "us_per_call": round(t_vol * 1e6, 1),
        "derived": (
            f"cut_of_cutopt={cut.edge_cut};vol_of_cutopt={cut.comm_volume};"
            f"cut_of_volopt={vol.edge_cut};vol_of_volopt={vol.comm_volume};"
            f"volume_saved={saved:.3f};"
            f"time_cut_s={t_cut:.3f};time_vol_s={t_vol:.3f};k={vol.k}"
        ),
    }


def run(full: bool = False, smoke: bool = False) -> list[dict]:
    rows = []
    snns = ["smooth_320"] if smoke else scale(full)["snns"]
    for snn in snns:
        prof = get_profile(snn, full)
        sneap = sneap_partition(prof.graph, capacity=256, seed=0)
        vec = sneap_partition(prof.graph, capacity=256, seed=0, impl="vec")
        spine = greedy_kl_partition(prof.graph, capacity=256, seed=0)
        rows.append({
            "name": f"partition/{snn}",
            "us_per_call": round(sneap.seconds * 1e6, 1),
            "derived": (
                f"cut_sneap={sneap.edge_cut};cut_spinemap={spine.edge_cut};"
                f"traffic_ratio={sneap.edge_cut / max(spine.edge_cut, 1):.3f};"
                f"time_sneap_s={sneap.seconds:.3f};time_spinemap_s={spine.seconds:.3f};"
                f"speedup={spine.seconds / max(sneap.seconds, 1e-9):.1f}x;"
                f"spikes={prof.num_spikes};k={sneap.k}"
            ),
        })
        rows.append({
            "name": f"partition_impl/{snn}",
            "us_per_call": round(vec.seconds * 1e6, 1),
            "derived": (
                f"cut_scalar={sneap.edge_cut};cut_vec={vec.edge_cut};"
                f"cut_ratio={vec.edge_cut / max(sneap.edge_cut, 1):.3f};"
                f"time_scalar_s={sneap.seconds:.3f};time_vec_s={vec.seconds:.3f};"
                f"speedup={sneap.seconds / max(vec.seconds, 1e-9):.1f}x;k={vec.k}"
            ),
        })
        rows.append(objective_row(snn, prof.graph, cut=sneap))

    # Fan-out-heavy synthetic hypergraph: where volume optimization pays.
    fan_n = 1000 if smoke else 4000
    rows.append(objective_row(f"fanout_{fan_n}",
                              synthetic_fanout_graph(fan_n), capacity=64))

    # Volume-vs-cut *speed* rows (vec engine, n >= _VEC_MIN_N so the
    # incremental-Φ/plateau-walk refiner actually engages): the ROADMAP
    # "close the volume-refinement speed gap" trajectory.
    rows.append(volume_row("fanout_2000", synthetic_fanout_graph(2000)))
    if not smoke:
        rows.append(volume_row("fanout_4000", synthetic_fanout_graph(4000)))

    # Large synthetic graph: the scale where the scalar engine's per-vertex
    # Python loops become impractical and the vec engine must deliver >=10x.
    cfg = SYNTH_SMOKE if smoke else (SYNTH_FULL if full else SYNTH_QUICK)
    g = synthetic_graph(**cfg)
    t0 = time.perf_counter()
    vec = sneap_partition(g, capacity=256, seed=0, impl="vec")
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    scalar = sneap_partition(g, capacity=256, seed=0, impl="scalar")
    t_scalar = time.perf_counter() - t0
    rows.append({
        "name": f"partition_impl/synthetic_{cfg['n']}",
        "us_per_call": round(t_vec * 1e6, 1),
        "derived": (
            f"n={cfg['n']};edges={g.num_edges};"
            f"cut_scalar={scalar.edge_cut};cut_vec={vec.edge_cut};"
            f"cut_ratio={vec.edge_cut / max(scalar.edge_cut, 1):.3f};"
            f"time_scalar_s={t_scalar:.2f};time_vec_s={t_vec:.2f};"
            f"speedup={t_scalar / max(t_vec, 1e-9):.1f}x;k={vec.k}"
        ),
    })
    emit(rows, "Fig4: partitioning traffic + time "
               "(SNEAP vs greedy-KL; scalar vs vec; cut vs volume)")
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        run(smoke=True)
    else:
        run(full="--quick" not in sys.argv)
